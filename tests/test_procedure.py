"""Procedure-centric serving API: BestOfK back-compat (bitwise), the
Route procedure end-to-end on a two-model shared paged pool, cascade
escalation through on_child_done, per-model metrics attribution, and the
module-level pool program cache.

The weak/strong model pair comes from the shared ``tiny``/``strong``
fixtures in conftest.py (single source: ``repro.models.fixtures``)."""
import jax
import numpy as np
import pytest

from repro.core.routing import eval_routing
from repro.serving import (AdaptiveScheduler, BestOfK, ChildGroup,
                           ContinuousBatchingRuntime, DecodeProcedure, Plan,
                           RequestState, Route, ServingEngine, Single)
from repro.serving.paged_pool import PagedKVPool


def _prompts(cfg, n, rng, lo=5, hi=11):
    return [rng.integers(0, cfg.vocab_size, (L,)).astype(np.int32)
            for L in rng.integers(lo, hi, size=n)]


# --------------------------------------------------------------- back-compat
@pytest.mark.parametrize("pool", ["paged", "slots"])
def test_bestofk_procedure_bitwise_backcompat(tiny, pool):
    """Old-style submit(budget=...) and an explicit BestOfK(k) procedure
    produce token-bitwise identical children under greedy decode —
    including EOS early termination, b_i = 0, and per-request max_new."""
    cfg, model, params = tiny
    rng = np.random.default_rng(3)
    prompts = _prompts(cfg, 4, rng)
    budgets = [2, 0, 3, 1]
    max_news = [4, 4, 2, 3]

    def run(style):
        rt = ContinuousBatchingRuntime(
            model, params, n_slots=3, max_len=16, max_new=4,
            temperature=0.0, seed=0, pool=pool, block_size=4, eos_id=7)
        ids = []
        for p, b, mn in zip(prompts, budgets, max_news):
            if style == "budget":
                ids.append(rt.submit(p, budget=b, max_new=mn))
            else:
                ids.append(rt.submit(p, max_new=mn,
                                     procedure=BestOfK(b)))
        rt.drain()
        return rt, ids

    rt_a, ids_a = run("budget")
    rt_b, ids_b = run("procedure")
    for ra, rb in zip(ids_a, ids_b):
        a, b = rt_a.result(ra), rt_b.result(rb)
        assert a.state == b.state == RequestState.DONE
        assert len(a.children) == len(b.children)
        for ca, cb in zip(a.children, b.children):
            assert ca.tokens == cb.tokens
        np.testing.assert_array_equal(a.response, b.response)
    assert rt_a.metrics.decode_tokens == rt_b.metrics.decode_tokens
    assert rt_a.metrics.prefill_tokens == rt_b.metrics.prefill_tokens


def test_submit_batch_backcompat_matches_procedure(tiny):
    """submit_batch (budgets + per-request max_new) equals per-request
    BestOfK(k) procedure submissions, bitwise."""
    cfg, model, params = tiny
    rng = np.random.default_rng(4)
    prompts = np.stack(_prompts(cfg, 3, rng, lo=6, hi=7))
    budgets, max_news = [2, 1, 2], [3, 4, 2]

    rt_a = ContinuousBatchingRuntime(model, params, n_slots=3, max_len=16,
                                     max_new=4, temperature=0.0, seed=0,
                                     block_size=4)
    ids_a = rt_a.submit_batch(prompts, budgets=budgets, max_new=max_news)
    rt_a.drain()
    rt_b = ContinuousBatchingRuntime(model, params, n_slots=3, max_len=16,
                                     max_new=4, temperature=0.0, seed=0,
                                     block_size=4)
    ids_b = [rt_b.submit(prompts[i], max_new=max_news[i],
                         procedure=BestOfK(budgets[i]))
             for i in range(3)]
    rt_b.drain()
    for ra, rb in zip(ids_a, ids_b):
        for ca, cb in zip(rt_a.result(ra).children,
                          rt_b.result(rb).children):
            assert ca.tokens == cb.tokens


def test_scheduler_facade_matches_procedure_path(tiny):
    """AdaptiveScheduler.serve_batch (the set_budget/deferred-plan shim)
    equals explicit BestOfK(k) submissions at the same budgets."""
    from repro.core import AdaptivePolicy
    from repro.core.difficulty import init_mlp_probe

    cfg, model, params = tiny
    engine = ServingEngine(model, params, max_new=3, temperature=0.0)
    probe = init_mlp_probe(jax.random.PRNGKey(4), cfg.d_model, 1)
    policy = AdaptivePolicy(probe_params=probe, kind="bce", b_max=3,
                            b_min=0)
    reward = lambda q, rows: np.asarray([float(r.sum() % 53) for r in rows])
    prompts = np.asarray(jax.random.randint(jax.random.PRNGKey(5), (4, 7),
                                            0, cfg.vocab_size))
    sched = AdaptiveScheduler(engine, policy, reward, seed=0, n_slots=3,
                              block_size=4)
    out = sched.serve_batch(list(range(4)), prompts, avg_budget=1.5)

    rt = ContinuousBatchingRuntime(model, params, n_slots=3,
                                   max_len=7 + 3 + 1, max_new=3,
                                   temperature=0.0, seed=0, block_size=4,
                                   reward_fn=reward)
    ids = [rt.submit(prompts[i], query=i,
                     procedure=BestOfK(int(out.budgets[i])))
           for i in range(4)]
    rt.drain()
    for i, rid in enumerate(ids):
        np.testing.assert_array_equal(out.responses[i],
                                      rt.result(rid).response)
        assert out.rewards[i] == rt.result(rid).reward


def test_single_matches_budget_one(tiny):
    cfg, model, params = tiny
    rng = np.random.default_rng(5)
    p = _prompts(cfg, 1, rng)[0]
    rt_a = ContinuousBatchingRuntime(model, params, n_slots=2, max_len=16,
                                     max_new=4, temperature=0.0, seed=0,
                                     block_size=4)
    ra = rt_a.submit(p, budget=1)
    rt_a.drain()
    rt_b = ContinuousBatchingRuntime(model, params, n_slots=2, max_len=16,
                                     max_new=4, temperature=0.0, seed=0,
                                     block_size=4)
    rb = rt_b.submit(p, procedure=Single())
    rt_b.drain()
    np.testing.assert_array_equal(rt_a.result(ra).response,
                                  rt_b.result(rb).response)


# ------------------------------------------------------------ multi-model
def test_route_end_to_end_two_models_one_pool(tiny, strong):
    """Route serves a stream on a weak/strong pair sharing one paged
    pool: strong-routed requests decode bitwise what a strong-only
    runtime produces, the block ledger balances across both models'
    tables, and every token/dispatch is attributed to its model."""
    cfg, model, params = tiny
    _, s_model, s_params = strong
    rng = np.random.default_rng(6)
    prompts = _prompts(cfg, 6, rng)
    route_strong = {0, 2, 5}                    # by query id

    rt = ContinuousBatchingRuntime(model, params, n_slots=4, max_len=16,
                                   max_new=4, temperature=0.0, seed=0,
                                   block_size=4)
    rt.register_model("strong", s_model, s_params)
    proc = Route(weak="default", strong="strong",
                 predictor=lambda r, h: 1.0 if r.query in route_strong
                 else -1.0, threshold=0.0)
    ids = [rt.submit(p, query=i, procedure=proc)
           for i, p in enumerate(prompts)]
    rt.drain()
    rt.assert_ledger_balanced()

    # reference runs: weak-only and strong-only single-model runtimes
    def reference(m, pr):
        ref = ContinuousBatchingRuntime(m, pr, n_slots=4, max_len=16,
                                        max_new=4, temperature=0.0, seed=0,
                                        block_size=4)
        rids = [ref.submit(p, budget=1) for p in prompts]
        ref.drain()
        return [list(ref.result(i).response) for i in rids]

    weak_rows = reference(model, params)
    strong_rows = reference(s_model, s_params)
    n_strong_tokens = 0
    for i, rid in enumerate(ids):
        r = rt.result(rid)
        assert r.state == RequestState.DONE
        assert len(r.children) == 1
        want_model = "strong" if i in route_strong else "default"
        assert r.children[0].model_id == want_model
        assert r.proc["route"] == ("strong" if i in route_strong
                                   else "weak")
        want = strong_rows[i] if i in route_strong else weak_rows[i]
        assert list(r.response) == want
        if i in route_strong:
            n_strong_tokens += len(r.children[0].tokens)

    # per-model attribution: the strong model's decode tokens are exactly
    # the routed children's, and the per-model split sums to the totals
    pm = rt.metrics.per_model
    assert pm["strong"].children == len(route_strong)
    assert pm["strong"].decode_tokens == n_strong_tokens
    assert (sum(m.decode_tokens for m in pm.values())
            == rt.metrics.decode_tokens)
    assert (sum(m.prefill_tokens for m in pm.values())
            == rt.metrics.prefill_tokens)
    assert (sum(m.device_dispatches for m in pm.values())
            == rt.metrics.device_dispatches)
    assert (sum(m.host_syncs for m in pm.values())
            == rt.metrics.host_syncs)
    s = rt.metrics.summary()
    assert s["model/strong/decode_tokens"] == n_strong_tokens
    # strong-routed prompts prefilled on the strong model too
    assert pm["strong"].prefill_tokens > 0


def test_route_cascade_escalates_on_low_reward(tiny, strong):
    """cascade=True decodes the weak child first and escalates through
    on_child_done only when the weak answer scores low; the strong child
    re-prefills the prompt as a second phase on the shared pool."""
    cfg, model, params = tiny
    _, s_model, s_params = strong
    rng = np.random.default_rng(7)
    prompts = _prompts(cfg, 3, rng)
    bad = {1}                                   # weak answer scores 0 here

    def reward(q, rows):
        return [0.0 if q in bad else 1.0 for _ in rows]

    rt = ContinuousBatchingRuntime(model, params, n_slots=3, max_len=16,
                                   max_new=3, temperature=0.0, seed=0,
                                   block_size=4, reward_fn=reward)
    rt.register_model("strong", s_model, s_params)
    proc = Route(weak="default", strong="strong",
                 predictor=lambda r, h: 1.0, threshold=0.0,
                 cascade=True, cascade_threshold=0.5)
    ids = [rt.submit(p, query=i, procedure=proc)
           for i, p in enumerate(prompts)]
    rt.drain()
    rt.assert_ledger_balanced()
    for i, rid in enumerate(ids):
        r = rt.result(rid)
        models = [c.model_id for c in r.children]
        if i in bad:
            assert models == ["default", "strong"]
            assert r.proc["escalated"]
        else:
            assert models == ["default"]
    assert rt.metrics.per_model["strong"].children == len(bad)


class _SpawnTwice(DecodeProcedure):
    """Escalation on the SAME model: the second child arrives after the
    probe stash is gone, so it must re-prefill as a phase (radix-hit)."""

    def plan(self, request, probe_hidden, runtime):
        return Plan([ChildGroup("default", 1)])

    def on_child_done(self, request, child, runtime):
        if len(request.children) == 1:
            return [ChildGroup("default", 1)]
        return None


def test_same_model_escalation_rephases_through_radix(tiny):
    cfg, model, params = tiny
    rng = np.random.default_rng(8)
    p = _prompts(cfg, 1, rng, lo=9, hi=10)[0]
    rt = ContinuousBatchingRuntime(model, params, n_slots=2, max_len=16,
                                   max_new=3, temperature=0.0, seed=0,
                                   block_size=4)
    rid = rt.submit(p, procedure=_SpawnTwice())
    rt.drain()
    rt.assert_ledger_balanced()
    r = rt.result(rid)
    assert len(r.children) == 2
    # greedy: the re-phased child reproduces the first bitwise
    assert r.children[0].tokens == r.children[1].tokens
    # the second phase's prefill hit the radix cache (published by the
    # first) instead of recomputing the full prompt
    assert rt.metrics.prefix_hits >= 1


def test_group_max_new_caps_child(tiny, strong):
    cfg, model, params = tiny
    _, s_model, s_params = strong
    rng = np.random.default_rng(9)
    p = _prompts(cfg, 1, rng)[0]
    rt = ContinuousBatchingRuntime(model, params, n_slots=2, max_len=16,
                                   max_new=5, temperature=0.0, seed=0,
                                   block_size=4)
    rt.register_model("strong", s_model, s_params)
    proc = Route(weak="default", strong="strong",
                 predictor=lambda r, h: 1.0, threshold=0.0,
                 max_new_strong=2)
    rid = rt.submit(p, procedure=proc)
    rt.drain()
    rt.assert_ledger_balanced()
    c = rt.result(rid).children[0]
    assert c.model_id == "strong" and len(c.tokens) == 2


@pytest.mark.slow
def test_adaptive_routing_dominates_random_baseline(tiny, strong):
    """The acceptance sweep: over strong-fraction targets, online Route
    with a gap predictor dominates core.routing's random baseline, and
    the runtime's measured reward equals eval_routing's offline
    prediction for the same mask (deterministic greedy pools)."""
    cfg, model, params = tiny
    _, s_model, s_params = strong
    rng = np.random.default_rng(10)
    prompts = _prompts(cfg, 8, rng)
    n = len(prompts)

    def reward(q, rows):
        # deterministic, query-dependent score of a token row
        return [float(((int(np.sum(r)) % 97) + 3 * q) % 13) for r in rows]

    def single_run(m, pr):
        rt = ContinuousBatchingRuntime(m, pr, n_slots=4, max_len=16,
                                       max_new=4, temperature=0.0, seed=0,
                                       block_size=4, reward_fn=reward)
        ids = [rt.submit(p, query=i, procedure=Single())
               for i, p in enumerate(prompts)]
        rt.drain()
        return np.asarray([rt.result(i).reward for i in ids])

    rew_w = single_run(model, params)
    rew_s = single_run(s_model, s_params)
    gap = rew_s - rew_w                         # oracle routing statistic
    pred = {i: float(gap[i]) for i in range(n)}

    rng2 = np.random.default_rng(0)
    for frac in (0.25, 0.5, 0.75):
        thr = Route.calibrate_threshold(gap, frac)
        rt = ContinuousBatchingRuntime(model, params, n_slots=4,
                                       max_len=16, max_new=4,
                                       temperature=0.0, seed=0,
                                       block_size=4, reward_fn=reward)
        rt.register_model("strong", s_model, s_params)
        proc = Route(weak="default", strong="strong",
                     predictor=lambda r, h: pred[r.query], threshold=thr)
        ids = [rt.submit(p, query=i, procedure=proc)
               for i, p in enumerate(prompts)]
        rt.drain()
        mask = np.asarray([rt.result(i).proc["route"] == "strong"
                           for i in ids])
        adaptive = float(np.mean([rt.result(i).reward for i in ids]))
        # online == offline evaluation of the same mask on the same pools
        assert adaptive == pytest.approx(
            eval_routing(rew_w[:, None], rew_s[:, None], mask))
        # random-mask baseline at the same strong fraction
        k = int(mask.sum())
        rnd_masks = []
        for _ in range(16):
            m = np.zeros(n, bool)
            m[rng2.permutation(n)[:k]] = True
            rnd_masks.append(eval_routing(rew_w[:, None], rew_s[:, None],
                                          m))
        assert adaptive >= np.mean(rnd_masks) - 1e-9
    # the oracle statistic must dominate strictly somewhere unless the
    # two models are reward-identical on every prompt
    assert np.any(gap != 0)


def test_single_holds_child_reservation_on_tight_pool(tiny):
    """Non-parking procedures must keep the standing one-child block
    reservation at prefill admission: on a pool too small to decode every
    prompt at once, Single requests serialize through it instead of all
    prefilling and then deadlocking on fan-out memory."""
    cfg, model, params = tiny
    rng = np.random.default_rng(11)
    prompts = [rng.integers(0, cfg.vocab_size, (8,)).astype(np.int32)
               for _ in range(3)]
    # 7 usable blocks; each request worst-cases 4 (2 prompt + 2 tail).
    # Without the standing reservation all three prompts would prefill
    # (6 blocks), leaving 1 < 2 for any child's tail — a permanent stall
    rt = ContinuousBatchingRuntime(model, params, n_slots=3, max_len=16,
                                   max_new=8, temperature=0.0, seed=0,
                                   block_size=4, n_blocks=8,
                                   prefix_cache=False)
    ids = [rt.submit(p, procedure=Single()) for p in prompts]
    rt.drain()                                  # must not stall
    rt.assert_ledger_balanced()
    one = ContinuousBatchingRuntime(model, params, n_slots=3, max_len=16,
                                    max_new=8, temperature=0.0, seed=0,
                                    block_size=4)
    ref = [one.submit(p, budget=1) for p in prompts]
    one.drain()
    for rid, rr in zip(ids, ref):
        np.testing.assert_array_equal(rt.result(rid).response,
                                      one.result(rr).response)


class _EscalateWhilePending(DecodeProcedure):
    """plan() fans out two children; the first retirement escalates with
    a third while the second still awaits admission — the request must
    not be enqueued into the fanout deque twice."""

    def plan(self, request, probe_hidden, runtime):
        return Plan([ChildGroup("default", 2)])

    def on_child_done(self, request, child, runtime):
        if len(request.children) == 2:
            return [ChildGroup("default", 1)]
        return None


def test_escalation_while_children_pending_no_duplicate_fanout(tiny):
    cfg, model, params = tiny
    rng = np.random.default_rng(12)
    p = rng.integers(0, cfg.vocab_size, (6,)).astype(np.int32)
    # find the first greedy token, then declare it EOS so the first
    # child retires AT ADMISSION, while its sibling is still pending
    # (n_slots=1 keeps the sibling un-admitted)
    probe = ContinuousBatchingRuntime(model, params, n_slots=1, max_len=16,
                                      max_new=2, temperature=0.0, seed=0,
                                      block_size=4)
    pid = probe.submit(p, budget=1)
    probe.drain()
    eos = int(probe.result(pid).response[0])

    rt = ContinuousBatchingRuntime(model, params, n_slots=1, max_len=16,
                                   max_new=2, temperature=0.0, seed=0,
                                   block_size=4, eos_id=eos)
    rid = rt.submit(p, procedure=_EscalateWhilePending())
    rt.drain()                                  # IndexError without guard
    rt.assert_ledger_balanced()
    r = rt.result(rid)
    assert len(r.children) == 3
    assert all(c.done() for c in r.children)


# --------------------------------------------------------- pool programs
def test_pool_programs_shared_across_instances(tiny, strong):
    """The jitted cache-IO helpers (copy_block et al.) are module-level,
    keyed on cache structure: two pools — and the weak/strong pair —
    share one program object instead of recompiling per instance."""
    cfg, model, params = tiny
    _, s_model, _ = strong
    p1 = PagedKVPool(model, 2, 16, block_size=4)
    p2 = PagedKVPool(model, 4, 32, block_size=8)
    assert p1._progs["default"] is p2._progs["default"]
    # layer count is a stacked axis, not pytree structure: the weak and
    # strong stacks share the same cached program object too (distinct
    # shapes just trace separately inside it)
    p1.add_model("strong", s_model)
    assert p1._progs["strong"] is p1._progs["default"]
    p3 = PagedKVPool(s_model, 2, 16, block_size=4)
    assert p3._progs["default"] is p1._progs["strong"]


def test_register_model_rejects_slot_pool_and_dupes(tiny, strong):
    cfg, model, params = tiny
    _, s_model, s_params = strong
    rt = ContinuousBatchingRuntime(model, params, n_slots=2, max_len=12,
                                   max_new=2, temperature=0.0, seed=0,
                                   pool="slots")
    with pytest.raises(ValueError, match="paged"):
        rt.register_model("strong", s_model, s_params)
    rt2 = ContinuousBatchingRuntime(model, params, n_slots=2, max_len=12,
                                    max_new=2, temperature=0.0, seed=0,
                                    block_size=4)
    rt2.register_model("strong", s_model, s_params)
    with pytest.raises(ValueError, match="already registered"):
        rt2.register_model("strong", s_model, s_params)
    with pytest.raises(KeyError, match="unregistered"):
        rt2.submit(np.zeros(4, np.int32),
                   procedure=Single("nonexistent"))
