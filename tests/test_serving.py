"""Serving engine: prefill==forward equivalence, deterministic decode,
scheduler budget accounting."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import build_model
from repro.serving import ServingEngine, prefill


@pytest.fixture(scope="module")
def tiny():
    cfg = dataclasses.replace(get_config("qwen2-0.5b").reduced(),
                              dtype="float32", n_layers=2)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def test_prefill_matches_forward(tiny):
    cfg, model, params = tiny
    prompts = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0,
                                 cfg.vocab_size)
    logits_f, hidden_f, _ = model.forward(params, prompts)
    logits_p, hidden_p, _cache = prefill(model, params, prompts, 16)
    np.testing.assert_allclose(np.asarray(logits_p),
                               np.asarray(logits_f[:, -1]),
                               atol=2e-4, rtol=2e-3)
    np.testing.assert_allclose(np.asarray(hidden_p),
                               np.asarray(hidden_f[:, -1]),
                               atol=2e-4, rtol=2e-3)


def test_decode_continuation_matches_forward(tiny):
    """Greedy decode via the cache == argmax over a re-run full forward."""
    cfg, model, params = tiny
    engine = ServingEngine(model, params, max_new=4, temperature=0.0)
    prompts = np.asarray(jax.random.randint(jax.random.PRNGKey(2), (2, 8),
                                            0, cfg.vocab_size))
    out = engine.generate(prompts, n_samples=1, seed=0, temperature=0.0)
    # re-derive greedily with full forwards
    seqs = prompts.copy()
    for _ in range(4):
        logits, _, _ = model.forward(params, jnp.asarray(seqs))
        nxt = np.asarray(jnp.argmax(logits[:, -1], -1))[:, None]
        seqs = np.concatenate([seqs, nxt], axis=1)
    np.testing.assert_array_equal(out.tokens, seqs[:, 8:])


def test_sliding_window_decode_runs(tiny):
    cfg, model, params = tiny
    cfg_w = dataclasses.replace(cfg, long_context="sliding_window",
                                sliding_window=8)
    model_w = build_model(cfg_w)
    engine = ServingEngine(model_w, params, max_new=12, temperature=0.0)
    prompts = np.asarray(jax.random.randint(jax.random.PRNGKey(3), (1, 6),
                                            0, cfg.vocab_size))
    out = engine.generate(prompts, n_samples=1, seed=0, temperature=0.0)
    assert out.tokens.shape == (1, 12)
    assert np.isfinite(out.probe_hidden).all()


def test_multisample_fanout_consistent(tiny):
    """n_samples>1 replicates each query's cache; sample 0 of a greedy
    fan-out must equal the single-sample greedy decode."""
    cfg, model, params = tiny
    engine = ServingEngine(model, params, max_new=4, temperature=0.0)
    prompts = np.asarray(jax.random.randint(jax.random.PRNGKey(9), (3, 8),
                                            0, cfg.vocab_size))
    one = engine.generate(prompts, n_samples=1, seed=0, temperature=0.0)
    three = engine.generate(prompts, n_samples=3, seed=0, temperature=0.0)
    assert three.tokens.shape == (9, 4)
    for i in range(3):
        for j in range(3):
            np.testing.assert_array_equal(three.tokens[i * 3 + j],
                                          one.tokens[i])


def test_scheduler_budget_accounting(tiny):
    from repro.core import AdaptivePolicy
    from repro.core.difficulty import init_mlp_probe
    from repro.serving import AdaptiveScheduler

    cfg, model, params = tiny
    engine = ServingEngine(model, params, max_new=4, temperature=1.0)
    probe = init_mlp_probe(jax.random.PRNGKey(4), cfg.d_model, 1)
    policy = AdaptivePolicy(probe_params=probe, kind="bce", b_max=6, b_min=1)
    reward = lambda q, rows: np.asarray([float(len(r)) for r in rows])
    sched = AdaptiveScheduler(engine, policy, reward)
    prompts = np.asarray(jax.random.randint(jax.random.PRNGKey(5), (6, 8),
                                            0, cfg.vocab_size))
    out = sched.serve_batch(list(range(6)), prompts, avg_budget=2.0)
    assert out.total_samples <= 2 * 6
    assert (out.budgets >= 1).all()
    assert out.generated_tokens == out.total_samples * 4
