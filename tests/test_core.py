"""Core-technique units: marginal identities, bootstrap estimators, probes
(MLP + LoRA), best-of-k evaluation, routing curves."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:        # hypothesis is dev-only: skip just those tests
    from conftest import given, settings, st  # noqa: F401

from repro.core import bestofk, marginal, routing
from repro.core.difficulty import (apply_lora, init_lora_probe,
                                   lora_probe_loss, probe_predict,
                                   train_mlp_probe)


@given(st.floats(0.0, 1.0), st.integers(1, 50))
@settings(max_examples=50, deadline=None)
def test_binary_q_delta_identity(lam, b):
    """q(b) == Σ_{j<=b} Δ_j  (paper's defining identity)."""
    lam_v = np.asarray([lam])
    delta = marginal.binary_marginals(lam_v, b)
    np.testing.assert_allclose(delta.sum(1),
                               marginal.binary_q(lam_v, np.asarray([b])),
                               atol=1e-12)


def test_bootstrap_matches_analytic_binary():
    """For binary rewards, bootstrap best-of-k ≈ 1-(1-λ)^k."""
    rng = np.random.default_rng(0)
    lam = np.array([0.1, 0.4, 0.8])
    pool = (rng.uniform(size=(3, 4000)) < lam[:, None]).astype(float)
    for k in (1, 3, 8):
        est = marginal.bootstrap_best_of_k(pool, k, n_boot=400, rng=rng)
        want = marginal.binary_q(lam, np.full(3, k))
        np.testing.assert_allclose(est, want, atol=0.05)


def test_preference_prob_extremes():
    strong = np.full((4, 6), 10.0)
    weak = np.zeros((4, 6))
    p = marginal.preference_prob(strong, weak)
    assert (p > 0.99).all()
    p2 = marginal.preference_prob(weak, strong)
    assert (p2 < 0.01).all()
    p3 = marginal.preference_prob(weak, weak)
    np.testing.assert_allclose(p3, 0.5, atol=1e-9)


def test_mlp_probe_learns_separable_signal():
    rng = np.random.default_rng(0)
    n, d = 600, 16
    feats = rng.normal(size=(n, d)).astype(np.float32)
    lam = 1 / (1 + np.exp(-2 * feats[:, 0]))          # depends on feature 0
    probe, info = train_mlp_probe(jax.random.PRNGKey(0), feats, lam,
                                  kind="bce", steps=800)
    pred = probe_predict(probe, feats, "bce")
    corr = np.corrcoef(pred, lam)[0, 1]
    assert corr > 0.8, corr


def test_mse_probe_vector_head():
    rng = np.random.default_rng(1)
    feats = rng.normal(size=(400, 8)).astype(np.float32)
    target = np.stack([feats[:, 0], feats[:, 1] * 0.5,
                       np.zeros(400)], axis=1)
    probe, info = train_mlp_probe(jax.random.PRNGKey(1), feats, target,
                                  kind="mse", steps=800)
    pred = probe_predict(probe, feats, "mse")
    assert pred.shape == (400, 3)
    assert np.mean((pred - target) ** 2) < 0.2


def test_lora_probe_applies_and_trains():
    from repro.configs import STANDINS
    from repro.models import build_model

    cfg = dataclasses.replace(STANDINS["reward-tiny"], n_layers=2,
                              dtype="float32")
    model = build_model(cfg)
    base = model.init(jax.random.PRNGKey(0))
    lora = init_lora_probe(jax.random.PRNGKey(1), base, cfg.d_model, 1,
                           rank=4)
    assert len(lora["adapters"]) > 0
    # zero-init b => merged params identical at start
    merged = apply_lora(base, lora)
    for a, b in zip(jax.tree.leaves(base), jax.tree.leaves(merged)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=1e-6)

    toks = jnp.asarray(np.random.default_rng(2).integers(
        0, cfg.vocab_size, size=(8, 12)))
    tgt = jnp.asarray(np.linspace(0, 1, 8), jnp.float32)

    def encode(params, tokens):
        _, hidden, _ = model.forward(params, tokens)
        return hidden[:, -1]

    loss0, g = jax.value_and_grad(lora_probe_loss)(lora, base, encode, toks,
                                                   tgt, "bce")
    gn = sum(float(jnp.abs(x).sum()) for x in jax.tree.leaves(g))
    assert np.isfinite(float(loss0)) and gn > 0      # grads flow into LoRA


def test_eval_reward_allocation_budget_zero_default():
    pool = np.array([[1.0, 2.0], [5.0, 3.0]])
    v = bestofk.eval_reward_allocation(pool, np.array([0, 1]))
    assert v == pytest.approx((0.0 + 4.0) / 2, abs=0.1)   # bootstrap noise


def test_routing_curves_monotone_oracle():
    rng = np.random.default_rng(0)
    n = 200
    rw = rng.normal(0, 1, size=(n, 4))
    rs = rw + rng.normal(0.5, 0.5, size=(n, 1))      # strong better on avg
    pref = marginal.preference_prob(rs, rw)
    c = routing.routing_curves(rw, rs, pref, [0.0, 0.5, 1.0])
    assert c["oracle"][1] >= c["random"][1] - 1e-9
    assert c["adaptive"][2] == pytest.approx(c["random"][2])  # all strong
