"""Per-kernel allclose sweeps vs ref.py oracles (interpret mode), as the
assignment requires: shapes x dtypes x masking variants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops

KEY = jax.random.PRNGKey(7)


def _tol(dtype):
    return dict(atol=2e-2, rtol=2e-2) if dtype == jnp.bfloat16 \
        else dict(atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,s,H,KV,hd,causal,window", [
    (2, 256, 4, 2, 64, True, 0),
    (1, 128, 4, 4, 32, True, 0),
    (1, 256, 2, 1, 64, True, 96),     # MQA + sliding window
    (2, 192, 4, 2, 64, False, 0),     # bidirectional (whisper encoder)
    (1, 512, 8, 8, 128, True, 0),     # MXU-aligned full block
])
def test_flash_attention_allclose(b, s, H, KV, hd, causal, window, dtype):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (b, s, H, hd), dtype)
    k = jax.random.normal(ks[1], (b, s, KV, hd), dtype)
    v = jax.random.normal(ks[2], (b, s, KV, hd), dtype)
    out = ops.flash_attention(q, k, v, causal=causal, window=window,
                              interpret=True)
    want = ops.flash_attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,S,H,KV,hd,block_k", [
    (2, 512, 8, 2, 64, 128),
    (1, 1024, 4, 1, 128, 256),
    (3, 300, 6, 6, 32, 128),          # ragged final block
])
def test_decode_attention_allclose(b, S, H, KV, hd, block_k, dtype):
    ks = jax.random.split(KEY, 4)
    q = jax.random.normal(ks[0], (b, H, hd), dtype)
    k = jax.random.normal(ks[1], (b, S, KV, hd), dtype)
    v = jax.random.normal(ks[2], (b, S, KV, hd), dtype)
    pos = jax.random.randint(ks[3], (b,), 0, S)
    out = ops.decode_attention(q, k, v, pos, block_k=block_k, interpret=True)
    want = ops.decode_attention_ref(q, k, v, pos)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


@pytest.mark.parametrize("b,s,d,n,chunk", [
    (2, 128, 128, 16, 64),
    (1, 256, 256, 8, 64),
    (2, 64, 128, 4, 32),
])
def test_ssm_scan_allclose(b, s, d, n, chunk):
    ks = jax.random.split(KEY, 5)
    dt = jax.nn.softplus(jax.random.normal(ks[0], (b, s, d)) - 1.0)
    A = -jnp.exp(jax.random.normal(ks[1], (d, n)) * 0.3)
    B = jax.random.normal(ks[2], (b, s, n))
    C = jax.random.normal(ks[3], (b, s, n))
    x = jax.random.normal(ks[4], (b, s, d))
    y, hT = ops.ssm_scan(dt, A, B, C, x, chunk=chunk, d_block=128,
                         interpret=True)
    y_ref, hT_ref = ops.ssm_scan_ref(dt, A, B, C, x)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               atol=2e-4, rtol=2e-3)
    np.testing.assert_allclose(np.asarray(hT), np.asarray(hT_ref),
                               atol=2e-4, rtol=2e-3)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("n,V,block_v", [
    (128, 1000, 256),                 # ragged vocab tail
    (256, 4096, 1024),
    (128, 50304, 8192),               # realistic LM vocab
])
def test_cross_entropy_allclose(n, V, block_v, dtype):
    ks = jax.random.split(KEY, 2)
    logits = jax.random.normal(ks[0], (n, V), dtype) * 4.0
    labels = jax.random.randint(ks[1], (n,), 0, V)
    out = ops.cross_entropy(logits, labels, block_rows=128, block_v=block_v,
                            interpret=True)
    want = ops.cross_entropy_ref(logits, labels)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=1e-2 if dtype == jnp.bfloat16 else 1e-4,
                               rtol=1e-2 if dtype == jnp.bfloat16 else 1e-5)


def test_attention_decode_pallas_call_site():
    """The model-level decode attention routed through the Pallas kernel
    (serving-runtime slot-pool path: heterogeneous per-batch `pos`)
    matches the XLA grouped-einsum path."""
    import dataclasses

    from repro.configs import get_config
    from repro.models import attention as A

    cfg = dataclasses.replace(get_config("qwen2-0.5b").reduced(),
                              dtype="float32")
    dims = A.attn_dims(cfg, 1)
    p = A.init_attention(jax.random.PRNGKey(11), cfg, 1, jnp.float32)
    b, S = 3, 12
    x = jax.random.normal(jax.random.PRNGKey(12), (b, 1, cfg.d_model))
    cache = {
        "k": jax.random.normal(jax.random.PRNGKey(13),
                               (b, S, dims.kv_padded, dims.head_dim)),
        "v": jax.random.normal(jax.random.PRNGKey(14),
                               (b, S, dims.kv_padded, dims.head_dim)),
    }
    pos = jnp.asarray([2, 7, 11], jnp.int32)   # slots at different depths
    o_ref, c_ref = A.attention_decode(p, x, cache, pos, dims,
                                      rope_theta=cfg.rope_theta,
                                      use_pallas=False)
    o_pal, c_pal = A.attention_decode(p, x, cache, pos, dims,
                                      rope_theta=cfg.rope_theta,
                                      use_pallas=True)
    np.testing.assert_allclose(np.asarray(o_pal), np.asarray(o_ref),
                               atol=2e-4, rtol=2e-3)
    for nm in ("k", "v"):   # both paths write the same cache slot
        np.testing.assert_array_equal(np.asarray(c_pal[nm]),
                                      np.asarray(c_ref[nm]))
