"""Tiny-mesh dry-run: proves the sharding machinery (specs, rules,
shard_map MoE, seq-sharded decode caches) lowers + compiles, in-process,
with 4 emulated host devices.

NOTE: runs in a subprocess because XLA_FLAGS device count locks at first
jax init and the rest of the suite needs the single real device.
"""
import json
import subprocess
import sys
import textwrap

import pytest

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import dataclasses, json
    import jax, jax.numpy as jnp
    import numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.configs import get_config
    from repro.launch.mesh import make_test_mesh
    from repro.launch.steps import make_train_step, make_serve_step
    from repro.models import build_model
    from repro.optim import adamw_init
    from repro.sharding import axis_rules, default_rules, logical_spec
    from repro.launch.hlo_analysis import analyze

    out = {}
    for arch in ("qwen2-0.5b", "grok-1-314b", "jamba-1.5-large-398b"):
        cfg = get_config(arch).reduced()
        cfg = dataclasses.replace(cfg, vocab_size=512)
        mesh = make_test_mesh((2, 2), ("data", "model"))
        rules = default_rules(cfg, mesh)
        model = build_model(cfg, tp=2)
        with axis_rules(mesh, rules):
            ps = model.param_shapes()
            spec = model.specs()
            p_sh = jax.tree.map(
                lambda n: NamedSharding(mesh, logical_spec(n, rules)),
                spec, is_leaf=lambda t: isinstance(t, tuple) or t is None)
            os_ = jax.eval_shape(adamw_init, ps)
            o_sh = type(os_)(step=NamedSharding(mesh, P()), m=p_sh,
                             v=jax.tree.map(lambda s: s, p_sh))
            sds = jax.ShapeDtypeStruct
            batch = {"tokens": sds((8, 64), jnp.int32),
                     "labels": sds((8, 64), jnp.int32)}
            b_sh = {k: NamedSharding(mesh, P("data", None)) for k in batch}
            step = make_train_step(model)
            with mesh:
                compiled = jax.jit(step, in_shardings=(p_sh, o_sh, b_sh),
                                   out_shardings=(p_sh, o_sh, None)) \\
                    .lower(ps, os_, batch).compile()
            ana = analyze(compiled.as_text())
            out[arch] = {"flops": ana["flops"],
                         "coll": ana["collective_bytes_total"]}
    print("RESULT " + json.dumps(out))
""")


@pytest.mark.slow
def test_tiny_mesh_dryrun_compiles():
    r = subprocess.run([sys.executable, "-c", SCRIPT], capture_output=True,
                       text=True, timeout=560,
                       env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                            "HOME": "/root"})
    assert r.returncode == 0, r.stderr[-3000:]
    line = [l for l in r.stdout.splitlines() if l.startswith("RESULT ")][0]
    out = json.loads(line[len("RESULT "):])
    for arch, v in out.items():
        assert v["flops"] > 0, arch
        assert v["coll"] > 0, arch        # sharded => collectives exist
