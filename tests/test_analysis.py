"""Static hot-path auditor: each pass catches its seeded violation class,
the repo itself is clean, and the one-sync contract holds on the compiled
tick programs."""
import json
import textwrap
from pathlib import Path

import pytest

from repro.analysis import blockspecs, common, recompiles, syncs
from repro.analysis.__main__ import main

REPO = Path(__file__).resolve().parents[1]


# ---------------------------------------------------------------------------
# sync pass
# ---------------------------------------------------------------------------

SYNC_BAD = textwrap.dedent("""\
    import numpy as np

    def dispatch_token(self, logits):
        x = float(logits[0])            # scalar pull
        y = np.asarray(logits)          # bulk pull
        n = len(logits)                 # shape via host
        return x, y, n
""")

SYNC_ALLOWED = textwrap.dedent("""\
    import numpy as np

    def dispatch_token(self, logits):
        buf = np.asarray(logits)  # analysis: allow(sync)
        return buf
""")


def test_sync_pass_flags_seeded_pulls(tmp_path):
    (tmp_path / "bad.py").write_text(SYNC_BAD)
    result = syncs.run(tmp_path)
    codes = sorted(f.code for f in result.findings if not f.suppressed)
    assert "scalar-pull" in codes
    assert "asarray" in codes
    assert "len" in codes


def test_sync_pass_honours_allow_comment(tmp_path):
    (tmp_path / "ok.py").write_text(SYNC_ALLOWED)
    result = syncs.run(tmp_path)
    assert all(f.suppressed for f in result.findings)
    assert any(f.code == "asarray" for f in result.findings)


def test_sync_pass_traced_branch(tmp_path):
    (tmp_path / "branch.py").write_text(textwrap.dedent("""\
        def horizon_program(model):
            pass

        def tick(self, logits):
            run = horizon_program(self)
            out = run(logits)
            if out > 0:                 # branch on a device value
                return 1
            return 0
    """))
    result = syncs.run(tmp_path)
    assert any(f.code == "branch" for f in result.findings)


def test_count_fetch_sites_sees_through_suppressions():
    # suppression comments must not hide fetch sites from the budget
    n = syncs.count_fetch_sites(SYNC_ALLOWED, "dispatch_token")
    assert n == 1


def test_repo_sync_findings_all_accounted():
    result = syncs.run(REPO)
    baseline = common.load_baseline(REPO / "experiments/analysis_baseline.json")
    new = [f for f in result.findings
           if not f.suppressed and f.key not in baseline]
    assert new == [], [f.render() for f in new]


# ---------------------------------------------------------------------------
# recompile pass
# ---------------------------------------------------------------------------

RECOMPILE_BAD = textwrap.dedent("""\
    import jax

    class Runtime:
        @jax.jit
        def step(self, x):              # jit-decorated method
            return x

        def __init__(self):
            self.f = jax.jit(lambda x: x)       # per-instance cache
            g = jax.jit(self.step)              # bound method

    def token_program(model):
        @jax.jit
        def run(x):
            return x
        return run                      # builder without lru_cache
""")


def test_recompile_pass_flags_all_shapes(tmp_path):
    (tmp_path / "bad.py").write_text(RECOMPILE_BAD)
    result = recompiles.run(tmp_path)
    codes = [f.code for f in result.findings]
    assert codes.count("bound-jit") == 3
    assert codes.count("uncached-builder") == 1


def test_recompile_pass_accepts_lru_cached_builder(tmp_path):
    (tmp_path / "ok.py").write_text(textwrap.dedent("""\
        import functools
        import jax

        @functools.lru_cache(maxsize=None)
        def token_program(model):
            @jax.jit
            def run(x):
                return x
            return run
    """))
    result = recompiles.run(tmp_path)
    assert result.findings == []


def test_builder_registry_is_memoized():
    from repro.serving import plan, tick_programs
    for kind in plan.PROGRAM_KINDS:
        assert kind in tick_programs.BUILDERS
        assert hasattr(tick_programs.BUILDERS[kind], "cache_info")


def test_compile_table_bound_tight():
    table = recompiles.compile_table()
    assert table and all(row["ok"] for row in table.values())
    # pow2 quantization makes the bound exactly tight, not just safe
    assert all(row["total"] == row["bound"] for row in table.values())


def test_horizon_widths_pow2():
    from repro.serving.plan import horizon_widths
    assert horizon_widths(1) == (1,)
    assert horizon_widths(8) == (1, 2, 4, 8)
    assert horizon_widths(12) == (1, 2, 4, 8)   # floor to pow2


# ---------------------------------------------------------------------------
# blockspec pass
# ---------------------------------------------------------------------------

def _toy_audit(index_map):
    from repro.kernels import registry
    B, T, n_table = 4, 5, 8
    pos = [0, 5, 19]
    live = [(p + B) // B for p in pos]          # blocks holding [0, pos]
    tables = registry.poison_tables(live, n_table)
    return registry.IndexMapAudit(
        kernel="toy", operand="k", grid=(len(pos), T),
        index_map=index_map, extents=(registry.POISON, 1, 1, 1),
        scalar_args=(tables, pos))


def test_blockspec_catches_unclamped_map():
    # the PR 7 bug: tbl[bi, ti] for ALL T entries walks table poison
    findings = blockspecs.check_audit(
        _toy_audit(lambda bi, ti, tbl, p: (tbl[bi][ti], 0, 0, 0)))
    assert any(f.code == "out-of-bounds" for f in findings)


def test_blockspec_accepts_clamped_map():
    findings = blockspecs.check_audit(
        _toy_audit(lambda bi, ti, tbl, p:
                   (tbl[bi][min(ti, p[bi] // 4)], 0, 0, 0)))
    assert findings == []


def test_blockspec_catches_arity_mismatch():
    findings = blockspecs.check_audit(
        _toy_audit(lambda bi, ti, tbl, p: (0, 0)))
    assert [f.code for f in findings] == ["arity"]


def test_production_index_maps_in_bounds():
    result = blockspecs.run(REPO)
    assert [f for f in result.findings if not f.suppressed] == []
    assert result.report["audits"] >= 10


def test_every_pallas_wrapper_registered():
    import ast
    from repro.kernels import registry
    names = set()
    for path in (REPO / "src/repro/kernels").glob("*.py"):
        for name in blockspecs._pallas_wrappers(ast.parse(path.read_text())):
            if not name.startswith("_"):
                names.add(name)
    assert names <= set(registry.AUDITED_KERNELS)
    audited = {a.kernel for a in registry.default_audits()}
    assert set(registry.AUDITED_KERNELS) <= audited


# ---------------------------------------------------------------------------
# program pass (compiles the tick programs once; shared via module fixture)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def program_result():
    from repro.analysis import programs
    return programs.run(REPO)


@pytest.mark.slow
def test_one_sync_contract(program_result):
    assert program_result.findings == [], \
        [f.render() for f in program_result.findings]
    for kind in ("token", "chunk", "horizon", "mixed", "admit"):
        rep = program_result.report[kind]
        assert rep["jaxpr_callbacks"] == 0
        assert rep["hlo_host_ops"] == 0
    for fn in ("dispatch_horizon", "dispatch_mixed"):
        assert program_result.report[fn]["fetch_sites"] == 1


@pytest.mark.slow
def test_debug_print_would_be_caught():
    """A jax.debug.print inside a program is exactly what the jaxpr audit
    exists to flag — prove the detector sees the callback primitive."""
    import jax
    import jax.numpy as jnp
    from repro.analysis import programs

    def leaky(x):
        jax.debug.print("x={x}", x=x)
        return x * 2

    prims = programs._collect_primitives(
        jax.make_jaxpr(leaky)(jnp.ones(3)).jaxpr, set())
    assert prims & programs.CALLBACK_PRIMS


# ---------------------------------------------------------------------------
# CLI / baseline plumbing
# ---------------------------------------------------------------------------

def test_cli_green_on_repo():
    assert main(["--check", "--skip", "programs"]) == 0


def test_cli_red_on_seeded_fixture(tmp_path, capsys):
    (tmp_path / "bad.py").write_text(SYNC_BAD)
    rc = main(["--check", "--root", str(tmp_path),
               "--skip", "programs", "--skip", "blockspecs"])
    assert rc == 1
    assert "new finding" in capsys.readouterr().out


def test_cli_update_baseline_roundtrip(tmp_path):
    (tmp_path / "bad.py").write_text(SYNC_BAD)
    base = tmp_path / "base.json"
    assert main(["--update-baseline", "--root", str(tmp_path),
                 "--baseline", str(base),
                 "--skip", "programs", "--skip", "blockspecs"]) == 0
    data = json.loads(base.read_text())
    assert data["findings"]
    # baselined findings no longer fail the check
    assert main(["--check", "--root", str(tmp_path),
                 "--baseline", str(base),
                 "--skip", "programs", "--skip", "blockspecs"]) == 0


def test_finding_keys_stable_under_line_moves():
    f1 = common.Finding("sync", "asarray", "a.py", 10, "f", "m")
    f2 = common.Finding("sync", "asarray", "a.py", 99, "f", "m")
    common.assign_occurrences([f1])
    common.assign_occurrences([f2])
    assert f1.key == f2.key


# ---------------------------------------------------------------------------
# metrics.Series (satellite: batched host transfer for recorded scalars)
# ---------------------------------------------------------------------------

def test_series_host_only():
    from repro.serving.metrics import Series
    s = Series()
    s.append(1.0)
    s.append(2.5)
    assert list(s) == [1.0, 2.5]
    assert len(s) == 2 and bool(s)


def test_series_defers_device_values_in_order():
    import jax.numpy as jnp
    from repro.serving.metrics import Series
    s = Series()
    s.append(1.0)
    s.append(jnp.float32(2.5))      # deferred — no sync yet
    s.append(3.0)                   # must stay AFTER the pending value
    assert len(s) == 3              # length known without flushing
    assert list(s) == [1.0, 2.5, 3.0]


def test_series_percentile_interop():
    from repro.serving.metrics import Series, percentile
    s = Series()
    for v in (4.0, 1.0, 3.0, 2.0):
        s.append(v)
    assert percentile(s, 50) == pytest.approx(2.5)
