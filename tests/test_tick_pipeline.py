"""Unified tick pipeline (plan -> dispatch -> retire) tests.

The refactor's contract: greedy outputs are token-bitwise identical to
the batch engine across every (horizon, prefill_chunk, prefill-overlap)
combination — the fused mixed program, which carries prefill rows inside
the decode horizon scan, must be invisible in the tokens. Plus the
planner's scheduling decisions (program kinds, per-dispatch horizon
re-degradation under load), retirement edge cases (mid-horizon EOS
while a neighbor prefills, radix hits feeding the fused path), ledger
integrity under randomized churn, and the streaming emit hooks that
give clients per-token progress under fused ticks.
"""
import asyncio
import pathlib

import numpy as np
import pytest

from repro.serving import (AsyncTokenStreamer, ContinuousBatchingRuntime,
                           ServingEngine, TrafficConfig)
from repro.serving.plan import ProgramPlan, TickPlan, plan_tick

BLOCK = 4
PROMPT_LENS = (5, 8, 7, 12)      # includes a block-aligned prompt: the
                                 # mixed program's frozen-row garbage
                                 # write lands in the null block there
BUDGETS = (2, 1, 3, 1)


@pytest.fixture(scope="module")
def workload(tiny):
    """Prompts plus the batch-engine greedy reference per request."""
    cfg, model, params = tiny
    rng = np.random.default_rng(11)
    prompts = [rng.integers(0, cfg.vocab_size, (L,)).astype(np.int32)
               for L in PROMPT_LENS]
    engine = ServingEngine(model, params, max_new=6, temperature=0.0)
    refs = [engine.generate(p[None], n_samples=1, seed=0,
                            temperature=0.0).tokens[0] for p in prompts]
    return prompts, refs


def _mk(model, params, **kw):
    kw.setdefault("n_slots", 4)
    kw.setdefault("max_len", 24)
    kw.setdefault("max_new", 6)
    kw.setdefault("temperature", 0.0)
    kw.setdefault("seed", 0)
    kw.setdefault("pool", "paged")
    kw.setdefault("block_size", BLOCK)
    return ContinuousBatchingRuntime(model, params, **kw)


def _run(model, params, prompts, budgets, *, stagger, **kw):
    """Drain the workload; stagger=True submits the second half only
    after the first half is decoding, forcing prefill/decode overlap."""
    rt = _mk(model, params, **kw)
    half = len(prompts) // 2 if stagger else len(prompts)
    ids = [rt.submit(p, budget=b)
           for p, b in zip(prompts[:half], budgets[:half])]
    if stagger:
        guard = 0
        while not any(c is not None for c in rt.slots):
            assert rt.step(), "stalled before any decode started"
            guard += 1
            assert guard < 100
        ids += [rt.submit(p, budget=b)
                for p, b in zip(prompts[half:], budgets[half:])]
    rt.drain()
    return rt, ids


# ------------------------------------------------------ bitwise invariance
@pytest.mark.slow
@pytest.mark.parametrize("horizon", [1, 4, 8])
@pytest.mark.parametrize("chunk", [1, BLOCK])
@pytest.mark.parametrize("stagger", [False, True])
def test_bitwise_invariance_cross_product(tiny, workload, horizon, chunk,
                                          stagger):
    """Every (H, prefill_chunk, overlap) combination reproduces the
    batch engine's greedy tokens bitwise, for every child."""
    cfg, model, params = tiny
    prompts, refs = workload
    rt, ids = _run(model, params, prompts, BUDGETS, stagger=stagger,
                   horizon=horizon, prefill_chunk=chunk)
    for rid, ref in zip(ids, refs):
        r = rt.result(rid)
        assert r.children, f"request {rid} spawned no children"
        for c in r.children:
            np.testing.assert_array_equal(np.asarray(c.tokens), ref)
    rt.assert_ledger_balanced()
    if stagger and horizon > 1:
        # overlap + fusion available: the mixed program must have run and
        # the pre-refactor fallback must not have
        assert rt.metrics.mixed_ticks >= 1
        assert rt.metrics.fallback_ticks == 0


@pytest.mark.slow
def test_fused_matches_unfused_exactly(tiny, workload):
    """fuse_prefill on/off is output-invisible on the same staggered
    workload — and only the unfused run pays fallback ticks."""
    cfg, model, params = tiny
    prompts, _ = workload
    rt_f, ids_f = _run(model, params, prompts, BUDGETS, stagger=True,
                       horizon=8, prefill_chunk=BLOCK, fuse_prefill=True)
    rt_u, ids_u = _run(model, params, prompts, BUDGETS, stagger=True,
                       horizon=8, prefill_chunk=BLOCK, fuse_prefill=False)
    for a, b in zip(ids_f, ids_u):
        ca, cb = rt_f.result(a).children, rt_u.result(b).children
        assert len(ca) == len(cb)
        for x, y in zip(ca, cb):
            assert x.tokens == y.tokens
    assert rt_f.metrics.fallback_ticks == 0
    assert rt_u.metrics.mixed_ticks == 0
    assert rt_u.metrics.fallback_ticks >= 1
    assert rt_u.metrics.summary()["fallback_fraction"] > 0.0
    # the fused run saw real overlap and reported it
    assert rt_f.metrics.prefill_decode_overlap_tokens > 0
    assert 0.0 < rt_f.metrics.summary()["fused_row_occupancy"] <= 1.0


# --------------------------------------------------------- retirement edges
def test_mid_horizon_eos_while_neighbor_prefills(tiny, workload):
    """A decode row EOSing inside the mixed scan freezes mid-horizon
    while a neighbor row is still consuming prompt tokens; both retire
    correctly and the ledger balances."""
    cfg, model, params = tiny
    prompts, refs = workload
    eos = int(refs[0][1])           # request 0 EOSes on its 2nd token

    def truncate(ref):
        out = []
        for t in ref:
            out.append(int(t))
            if t == eos:
                break
        return out

    rt, ids = _run(model, params, prompts, BUDGETS, stagger=True,
                   horizon=8, prefill_chunk=BLOCK, eos_id=eos)
    assert rt.metrics.mixed_ticks >= 1
    for rid, ref in zip(ids, refs):
        for c in rt.result(rid).children:
            assert c.tokens == truncate(ref)
    assert len(rt.result(ids[0]).children[0].tokens) < 6
    rt.assert_ledger_balanced()


def test_radix_hit_feeds_fused_path(tiny):
    """A prompt adopting radix-published prefix blocks prefills its tail
    inside the mixed scan; outputs match a cold cache-off run."""
    cfg, model, params = tiny
    rng = np.random.default_rng(23)
    shared = rng.integers(0, cfg.vocab_size, (12,)).astype(np.int32)
    donor = np.concatenate([shared, rng.integers(
        0, cfg.vocab_size, (4,)).astype(np.int32)])
    hitter = np.concatenate([shared, rng.integers(
        0, cfg.vocab_size, (3,)).astype(np.int32)])
    decoy = rng.integers(0, cfg.vocab_size, (9,)).astype(np.int32)

    rt = _mk(model, params, horizon=8, prefill_chunk=BLOCK)
    rt.drain()  # no-op; establishes programs
    a = rt.submit(donor, budget=1)
    rt.drain()
    b = rt.submit(hitter, budget=1)
    guard = 0
    while not any(c is not None for c in rt.slots):
        assert rt.step() and (guard := guard + 1) < 100
    d = rt.submit(decoy, budget=1)
    rt.drain()
    assert rt.metrics.prefix_hit_tokens > 0

    cold = _mk(model, params, horizon=8, prefill_chunk=BLOCK,
               prefix_cache=False)
    ids = [cold.submit(p, budget=1) for p in (donor, hitter, decoy)]
    cold.drain()
    for rid, cid in zip((a, b, d), ids):
        assert (rt.result(rid).children[0].tokens
                == cold.result(cid).children[0].tokens)
    rt.assert_ledger_balanced()


@pytest.mark.slow
def test_randomized_churn_ledger_audit(tiny):
    """Randomized arrivals/budgets/lengths churning through the fused
    pipeline: the block ledger balances at every audited step boundary
    and at drain, with zero fallback ticks."""
    cfg, model, params = tiny
    rng = np.random.default_rng(7)
    rt = _mk(model, params, max_len=24, horizon=4, prefill_chunk=BLOCK)
    ids = []
    for wave in range(4):
        for _ in range(3):
            L = int(rng.integers(3, 15))
            p = rng.integers(0, cfg.vocab_size, (L,)).astype(np.int32)
            ids.append(rt.submit(p, budget=int(rng.integers(1, 4)),
                                 max_new=int(rng.integers(2, 7))))
        for _ in range(int(rng.integers(1, 6))):
            rt.step()
        rt.assert_ledger_balanced()
    rt.drain()
    assert rt.metrics.fallback_ticks == 0
    for rid in ids:
        r = rt.result(rid)
        assert r.children and r.response is not None
        for c in r.children:
            assert 0 < len(c.tokens) <= c.max_new


# ----------------------------------------------------------------- planner
def test_plan_is_pure_and_slot_disjoint(tiny, workload):
    """plan_tick mutates nothing, is idempotent, and assigns every live
    slot to exactly one program."""
    cfg, model, params = tiny
    prompts, _ = workload
    # max_new big enough that decode budget can't collapse to 1 before
    # the overlap window (H = pow2floor(min remaining) must stay > 1)
    rt = _mk(model, params, max_len=48, max_new=32, horizon=8,
             prefill_chunk=BLOCK)
    ids = [rt.submit(p, budget=b) for p, b in zip(prompts[:2], BUDGETS[:2])]
    guard = 0
    while not any(c is not None for c in rt.slots):
        assert rt.step() and (guard := guard + 1) < 100
    ids += [rt.submit(p, budget=b) for p, b in zip(prompts[2:], BUDGETS[2:])]
    while not (any(c is not None for c in rt.slots) and rt._pref):
        assert rt.step() and (guard := guard + 1) < 100
    plan = plan_tick(rt)
    assert plan == plan_tick(rt)
    assert isinstance(plan, TickPlan)
    seen = []
    for pp in plan.programs:
        assert isinstance(pp, ProgramPlan)
        seen += list(pp.decode_slots) + list(pp.prefill_slots)
    assert sorted(seen) == sorted(
        [s for s, c in enumerate(rt.slots) if c is not None]
        + list(rt._pref))
    assert len(seen) == len(set(seen))
    # decode + prefill both live on an attention stack with fusion on:
    # ONE mixed program, never the fallback split
    kinds = [pp.kind for pp in plan.programs]
    assert kinds == ["mixed"]
    rt.drain()


def test_overload_shrinks_next_horizon_mid_request(tiny, workload,
                                                   monkeypatch):
    """Traffic degradation is re-read per dispatch: load arriving while
    a request is already decoding shrinks its very next horizon lease
    (power-of-two quantized, floored at min_horizon)."""
    cfg, model, params = tiny
    prompts, _ = workload
    rt = _mk(model, params, max_len=32, max_new=16, horizon=8,
             traffic=TrafficConfig(preempt=False))
    rt.submit(prompts[0], budget=1)
    guard = 0
    while not any(c is not None for c in rt.slots):
        assert rt.step() and (guard := guard + 1) < 100
    plan0 = plan_tick(rt)
    assert plan0.programs[0].kind == "horizon"
    # the admitting step already ran one full-width dispatch, so the
    # next unloaded lease is bounded by remaining budget — read it from
    # the plan rather than hardcoding, then require room to shrink
    h0 = plan0.programs[0].horizon
    assert h0 > rt.traffic.cfg.min_horizon
    # overload hits mid-request: the SAME resident request's next
    # dispatch plans a shorter lease, nothing re-admitted
    monkeypatch.setattr(rt.traffic, "price", lambda _rt: 2.0)
    plan1 = plan_tick(rt)
    h1 = plan1.programs[0].horizon
    assert h1 == max(rt.traffic.cfg.min_horizon, h0 >> 2)
    assert h1 < h0
    rt.drain()


# --------------------------------------------------------------- streaming
def test_emit_hooks_stream_through_fused_ticks(tiny, workload):
    """The streamer's emit-hook path delivers every token even when the
    runtime is driven by bare step()/drain() loops (no _pump between
    ticks) and whole horizons retire at once."""
    cfg, model, params = tiny
    prompts, refs = workload
    rt = _mk(model, params, horizon=8, prefill_chunk=BLOCK)
    streamer = AsyncTokenStreamer(rt)
    rid = streamer.submit(prompts[0], budget=2)
    rt.drain()                      # streamer.serve never runs
    session = streamer._sessions[rid]
    got = []
    while not session.queue.empty():
        got.append(session.queue.get_nowait())
    assert got == list(refs[0])     # child 0, in order, none dropped
    # watermark tolerates shrinkage (preemption replay): re-notifying
    # with a shorter list must not re-emit
    child = rt.result(rid).children[0]
    streamer._on_emit(rt.result(rid), child)
    assert session.queue.empty()


def test_streamer_end_to_end_under_fused_ticks(tiny, workload):
    """Full async path on a fused runtime: tokens arrive per-token and
    match child 0 exactly."""
    cfg, model, params = tiny
    prompts, refs = workload
    rt = _mk(model, params, horizon=8, prefill_chunk=BLOCK)
    streamer = AsyncTokenStreamer(rt)
    rids = [streamer.submit(p, budget=1) for p in prompts[:2]]

    async def main():
        server = asyncio.ensure_future(streamer.serve())
        outs = await asyncio.gather(*[collect(r) for r in rids])
        await server
        return outs

    async def collect(rid):
        return [t async for t in streamer.tokens(rid)]

    outs = asyncio.run(main())
    for rid, ref, out in zip(rids, refs, outs):
        assert out == rt.requests[rid].children[0].tokens
        assert out == list(ref)


# ------------------------------------------------------------------- meta
def test_serving_modules_stay_under_line_budget():
    """The refactor's point: no serving module grows back into a
    monolith. Hard cap 900 lines per module."""
    root = pathlib.Path(__file__).resolve().parents[1]
    for f in sorted((root / "src/repro/serving").rglob("*.py")):
        n = len(f.read_text().splitlines())
        assert n <= 900, f"{f.relative_to(root)} has {n} lines (cap 900)"
