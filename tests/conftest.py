"""Shared test scaffolding.

Provides no-op stand-ins for hypothesis' `given`/`settings`/`st` so the
property-based tests skip gracefully (instead of failing collection) when
hypothesis isn't installed — it is a dev-only dependency, see
requirements-dev.txt. Test modules fall back to these via
``from conftest import given, settings, st``.

Also hosts the canonical weak/strong tiny-model pair (``tiny`` /
``strong``) used by the procedure, routing, and traffic tests — single
source in ``repro.models.fixtures`` so no test can rebuild the pair from
raw init and silently reintroduce the zero routing gap (tied-embedding
greedy echo; see that module's docstring).
"""
import pytest


@pytest.fixture(scope="session")
def tiny():
    """Reduced 2-layer qwen2 at init scale: (cfg, model, params)."""
    from repro.models.fixtures import tiny_lm
    return tiny_lm(n_layers=2, seed=0)


@pytest.fixture(scope="session")
def strong():
    """The 'strong' half of a routing pair: 1 layer, params ×3 off init
    so the weak/strong greedy gap is nonzero (the roles are symbolic —
    what matters is distinct weights and a distinct cache store)."""
    from repro.models.fixtures import scaled_strong_lm
    return scaled_strong_lm(n_layers=1, seed=99, scale=3.0)


def given(*_args, **_kwargs):
    return lambda fn: pytest.mark.skip(
        reason="hypothesis not installed (pip install -r "
               "requirements-dev.txt)")(fn)


def settings(*_args, **_kwargs):
    return lambda fn: fn


class _StrategyStub:
    """`st.<anything>(...)` evaluates at collection time inside @given
    argument lists; return inert placeholders."""

    def __getattr__(self, _name):
        return lambda *a, **k: None


st = _StrategyStub()
