"""Shared test scaffolding.

Provides no-op stand-ins for hypothesis' `given`/`settings`/`st` so the
property-based tests skip gracefully (instead of failing collection) when
hypothesis isn't installed — it is a dev-only dependency, see
requirements-dev.txt. Test modules fall back to these via
``from conftest import given, settings, st``.
"""
import pytest


def given(*_args, **_kwargs):
    return lambda fn: pytest.mark.skip(
        reason="hypothesis not installed (pip install -r "
               "requirements-dev.txt)")(fn)


def settings(*_args, **_kwargs):
    return lambda fn: fn


class _StrategyStub:
    """`st.<anything>(...)` evaluates at collection time inside @given
    argument lists; return inert placeholders."""

    def __getattr__(self, _name):
        return lambda *a, **k: None


st = _StrategyStub()
