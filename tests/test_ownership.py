"""Ownership/donation static passes: seeded violations per rule (AST
reconstructions of the PR 3 double-decref, the PR 2 stash-window leak,
and the prefill-handoff leak-on-raise), clean-repo green runs,
allow/baseline round-trips, stale-suppression failures, CLI exit codes —
plus runtime regression tests for the exception-safety fixes the
ownership audit surfaced in ``runtime.py``/``retire.py``."""
import json
import textwrap
from pathlib import Path

import numpy as np
import pytest

from repro.analysis import common, donation, ownership
from repro.analysis.__main__ import main
from repro.serving import (ChildGroup, ContinuousBatchingRuntime,
                           DecodeProcedure, Plan, RequestState)


def _codes(result, suppressed=False):
    return {f.code for f in result.findings if f.suppressed == suppressed}


# ---------------------------------------------------------------------------
# ownership pass: one seeded violation per rule
# ---------------------------------------------------------------------------

STASH_LEAK = textwrap.dedent("""\
    def stash_window(self):
        blk = self.pool.alloc_block()
        if self.window_full:
            return None
        self.table.append(blk)
    """)


def test_ownership_flags_stash_window_leak(tmp_path):
    """PR 2 reconstruction: the allocated boundary block escapes on the
    early-return path with no owner."""
    (tmp_path / "bad.py").write_text(STASH_LEAK)
    result = ownership.run(tmp_path)
    assert "leak" in _codes(result)
    (f,) = [f for f in result.findings if f.code == "leak"]
    assert f.line == 2              # reported at the acquisition line


LEAK_ON_RAISE = textwrap.dedent("""\
    def admit(self, r):
        matched = self.radix.match(r.prompt)
        self.pool.reserve(2)
        r.table = matched
        r.reserved = 2
    """)


def test_ownership_flags_leak_on_raise(tmp_path):
    """Prefill-handoff reconstruction: the matched (caller-increfed)
    blocks are live across reserve(), whose raise orphans them."""
    (tmp_path / "bad.py").write_text(LEAK_ON_RAISE)
    result = ownership.run(tmp_path)
    assert "leak-on-raise" in _codes(result)
    (f,) = [f for f in result.findings if f.code == "leak-on-raise"]
    assert f.line == 2              # the match() acquisition


def test_ownership_try_suppresses_leak_on_raise(tmp_path):
    """The same shape inside try/except is exception-handled: no
    finding."""
    (tmp_path / "ok.py").write_text(textwrap.dedent("""\
        def admit(self, r):
            matched = self.radix.match(r.prompt)
            try:
                self.pool.reserve(2)
            except RuntimeError:
                self.radix.unmatch(matched)
                raise
            r.table = matched
            r.reserved = 2
        """))
    result = ownership.run(tmp_path)
    assert "leak-on-raise" not in _codes(result)


DOUBLE_DECREF = textwrap.dedent("""\
    def retire_child(self, c):
        t = c.table
        self.pool.release_table(t)
        self.pool.unreserve(c.reserved)
        self.pool.release_table(t)
    """)


def test_ownership_flags_double_release(tmp_path):
    """PR 3 reconstruction: two release_table calls reachable on one
    binding."""
    (tmp_path / "bad.py").write_text(DOUBLE_DECREF)
    result = ownership.run(tmp_path)
    assert "double-release" in _codes(result)


DECREF_LOOP = textwrap.dedent("""\
    def free_all(self, c):
        for blk in c.table:
            self.pool.decref(blk)
        c.table = None
    """)


def test_ownership_flags_raw_decref_loop(tmp_path):
    """The PR 3 substrate: a raw decref loop bypasses release_table's
    shared-block dedup."""
    (tmp_path / "bad.py").write_text(DECREF_LOOP)
    result = ownership.run(tmp_path)
    assert "decref-loop" in _codes(result)


UNMATCHED_RESERVE = textwrap.dedent("""\
    def grow(self, n):
        self.pool.reserve(n)
        if n > 4:
            return False
        blk = self.pool.alloc_block()
        self.table.append(blk)
        return True
    """)


def test_ownership_flags_unmatched_reserve(tmp_path):
    (tmp_path / "bad.py").write_text(UNMATCHED_RESERVE)
    result = ownership.run(tmp_path)
    assert "unmatched-reserve" in _codes(result)


def test_ownership_allow_comment_suppresses(tmp_path):
    (tmp_path / "ok.py").write_text(textwrap.dedent("""\
        def free_all(self, c):
            for blk in c.table:        # analysis: allow(ownership)
                self.pool.decref(blk)
            c.table = None
        """))
    result = ownership.run(tmp_path)
    assert "decref-loop" not in _codes(result)
    assert "decref-loop" in _codes(result, suppressed=True)


# ---------------------------------------------------------------------------
# donation pass: seeded misuse per rule
# ---------------------------------------------------------------------------

DONATION_MISSING = textwrap.dedent("""\
    import functools
    import jax

    @functools.partial(jax.jit, static_argnames=("n",))
    def bad_step(params, cache, tok, n):
        return cache
    """)

DONATION_DISPATCH = textwrap.dedent("""\
    import functools
    import jax

    @functools.lru_cache(maxsize=None)
    def tick_program(model):
        @functools.partial(jax.jit, donate_argnums=(1,))
        def run(params, cache, tok):
            return cache
        return run

    def dispatch(rt, pool, pp):
        run = tick_program(rt.model)
        out = run(rt.params, pool.caches[pp.model_id], rt.tok)
        stale = pool.caches[pp.model_id].sum()
        pool.caches[pp.model_id] = out
        return stale
    """)

DONATION_NO_REBIND = textwrap.dedent("""\
    import functools
    import jax

    @functools.lru_cache(maxsize=None)
    def tick_program(model):
        @functools.partial(jax.jit, donate_argnums=(1,))
        def run(params, cache, tok):
            return cache
        return run

    def dispatch(rt, pool, pp):
        run = tick_program(rt.model)
        out = run(rt.params, pool.caches[pp.model_id], rt.tok)
        return out
    """)


def test_donation_flags_undonated_cache_param(tmp_path):
    (tmp_path / "bad.py").write_text(DONATION_MISSING)
    result = donation.run(tmp_path)
    assert "donation-missing" in _codes(result)


def test_donation_flags_read_after_dispatch(tmp_path):
    (tmp_path / "bad.py").write_text(DONATION_DISPATCH)
    result = donation.run(tmp_path)
    assert "donated-read" in _codes(result)
    (f,) = [f for f in result.findings if f.code == "donated-read"]
    assert f.line == 14             # the stale read, before the rebind


def test_donation_flags_missing_rebind(tmp_path):
    (tmp_path / "bad.py").write_text(DONATION_NO_REBIND)
    result = donation.run(tmp_path)
    assert "donated-no-rebind" in _codes(result)


def test_donation_rebound_dispatch_is_clean(tmp_path):
    """The production shape — donate, then rebind the same expression —
    is clean (the DISPATCH fixture minus the stale read)."""
    clean = DONATION_DISPATCH.replace(
        "    stale = pool.caches[pp.model_id].sum()\n", "").replace(
        "    return stale\n", "    return out\n")
    assert "stale" not in clean
    (tmp_path / "ok.py").write_text(clean)
    result = donation.run(tmp_path)
    assert not _codes(result)


# ---------------------------------------------------------------------------
# clean repo, CLI exit codes, baseline round-trips, stale suppressions
# ---------------------------------------------------------------------------

def test_ownership_pass_clean_on_repo():
    result = ownership.run(common.repo_root())
    assert not _codes(result)
    # the protocol-internal radix allows are live, not stale
    assert _codes(result, suppressed=True)


def test_donation_pass_clean_on_repo():
    result = donation.run(common.repo_root())
    assert not _codes(result)
    assert "donation-missing" in _codes(result, suppressed=True)


FAST = ["--skip", "programs", "--skip", "blockspecs"]


@pytest.mark.parametrize("src,code", [
    (STASH_LEAK, "leak"),
    (DOUBLE_DECREF, "double-release"),
    (DECREF_LOOP, "decref-loop"),
    (UNMATCHED_RESERVE, "unmatched-reserve"),
    (LEAK_ON_RAISE, "leak-on-raise"),
    (DONATION_MISSING, "donation-missing"),
    (DONATION_DISPATCH, "donated-read"),
])
def test_cli_red_on_each_seeded_class(tmp_path, capsys, src, code):
    (tmp_path / "bad.py").write_text(src)
    rc = main(["--check", "--root", str(tmp_path)] + FAST)
    assert rc == 1
    assert code in capsys.readouterr().out


def test_cli_baseline_roundtrip_ownership(tmp_path):
    (tmp_path / "bad.py").write_text(STASH_LEAK)
    base = tmp_path / "base.json"
    assert main(["--update-baseline", "--root", str(tmp_path),
                 "--baseline", str(base)] + FAST) == 0
    keys = json.loads(base.read_text())["findings"]
    assert any(k.startswith("ownership:leak:") for k in keys)
    assert main(["--check", "--root", str(tmp_path),
                 "--baseline", str(base)] + FAST) == 0


def test_cli_fails_on_stale_allow(tmp_path, capsys):
    (tmp_path / "ok.py").write_text(
        "def f(self):\n"
        "    x = 1              # analysis: allow(ownership)\n"
        "    return x\n")
    rc = main(["--check", "--root", str(tmp_path)] + FAST)
    assert rc == 1
    assert "stale" in capsys.readouterr().out


def test_cli_fails_on_stale_baseline_and_prunes(tmp_path, capsys):
    (tmp_path / "ok.py").write_text("def f():\n    return 1\n")
    base = tmp_path / "base.json"
    common.write_baseline_entries(base, {
        "ownership:leak:gone.py:f:0": "a fixed finding",
        "program:hlo-host-op:x.py:f:0": "owned by a skipped pass"})
    rc = main(["--check", "--root", str(tmp_path),
               "--baseline", str(base)] + FAST)
    assert rc == 1
    assert "stale baseline" in capsys.readouterr().out
    assert main(["--prune-baseline", "--root", str(tmp_path),
                 "--baseline", str(base)] + FAST) == 0
    kept = json.loads(base.read_text())["findings"]
    # the fixed entry is gone; the skipped pass's entry is preserved
    assert list(kept) == ["program:hlo-host-op:x.py:f:0"]
    assert main(["--check", "--root", str(tmp_path),
                 "--baseline", str(base)] + FAST) == 0


def test_cli_green_on_repo_fast_passes():
    assert main(["--check"] + FAST) == 0


# ---------------------------------------------------------------------------
# runtime regression tests for the fixes the ownership audit surfaced
# ---------------------------------------------------------------------------

class _PlanWithBadModel(DecodeProcedure):
    """One valid group plus one naming an unregistered model."""

    def plan(self, request, probe_hidden, runtime):
        return Plan([ChildGroup("default", 1),
                     ChildGroup("no-such-model", 1)])


def test_apply_groups_rejects_bad_plan_atomically(tiny):
    """A plan naming an unregistered model must fail BEFORE any group is
    applied: the old code spawned the valid group first, leaving
    children with no admission path for the drain loop to hang on."""
    cfg, model, params = tiny
    rng = np.random.default_rng(0)
    p = rng.integers(0, cfg.vocab_size, (6,)).astype(np.int32)
    rt = ContinuousBatchingRuntime(model, params, n_slots=2, max_len=16,
                                   max_new=2, temperature=0.0, seed=0,
                                   pool="paged", block_size=4)
    rid = rt.submit(p, procedure=_PlanWithBadModel())
    with pytest.raises(KeyError, match="no-such-model"):
        rt.drain()
    r = rt.requests[rid]
    assert r.children == []          # nothing half-applied
    assert not r.pending and not r.pending_phases
    assert len(rt.fanout) == 0


def test_fanout_copy_block_raise_keeps_ledger_balanced(tiny):
    """A device failure in the COW boundary copy mid-fanout must not
    orphan the refs already taken for that child: with the child's
    table registered up front, the ledger still balances and the
    preemption teardown recovers the half-admitted child."""
    cfg, model, params = tiny
    rng = np.random.default_rng(1)
    p = rng.integers(0, cfg.vocab_size, (6,)).astype(np.int32)  # 6 % 4 != 0

    def run(break_at):
        rt = ContinuousBatchingRuntime(model, params, n_slots=3,
                                       max_len=16, max_new=3,
                                       temperature=0.0, seed=0,
                                       pool="paged", block_size=4)
        orig = rt.pool.copy_block
        calls = {"n": 0}

        def flaky(*a, **kw):
            calls["n"] += 1
            if calls["n"] == break_at:
                raise RuntimeError("injected device failure")
            return orig(*a, **kw)

        rt.pool.copy_block = flaky
        rid = rt.submit(p, budget=2)
        with pytest.raises(RuntimeError, match="injected"):
            rt.drain()
        # every ref taken before the raise is owner-accounted
        rt.assert_ledger_balanced()
        r = rt.requests[rid]
        # recovery: evict the casualty; the half-admitted child is torn
        # down (table freed) and re-queued with its siblings
        rt.retire.preempt_request(r)
        rt.assert_ledger_balanced()
        assert all(c.table is None and c.slot is None
                   for c in r.children)
        assert len(r.pending) == len(r.children)
        rt.pool.copy_block = orig
        rt.drain()
        rt.assert_ledger_balanced()
        res = rt.result(rid)
        assert res.state == RequestState.DONE
        return [c.tokens for c in res.children]

    undisturbed = ContinuousBatchingRuntime(
        model, params, n_slots=3, max_len=16, max_new=3,
        temperature=0.0, seed=0, pool="paged", block_size=4)
    rid = undisturbed.submit(p, budget=2)
    undisturbed.drain()
    want = [c.tokens for c in undisturbed.result(rid).children]
    # break_at=2: first child fully admitted, second mid-window
    assert run(break_at=2) == want


def test_admission_reserve_raise_keeps_matched_refs_owned(tiny):
    """A raise in admission AFTER the radix match (reservation, slot
    churn) must leave the matched refs owner-accounted in r.table — the
    old code kept them in a local, orphaning them on the exception
    edge."""
    cfg, model, params = tiny
    rng = np.random.default_rng(2)
    p = rng.integers(0, cfg.vocab_size, (9,)).astype(np.int32)  # 2 blocks+1
    rt = ContinuousBatchingRuntime(model, params, n_slots=2, max_len=16,
                                   max_new=2, temperature=0.0, seed=0,
                                   pool="paged", block_size=4,
                                   prefill_slots=1)
    first = rt.submit(p, budget=1)
    rt.drain()                       # publishes the full prompt blocks
    assert rt.result(first).state == RequestState.DONE

    orig = rt.pool.reserve

    def broken(n):
        raise RuntimeError("injected reservation failure")

    rt.pool.reserve = broken
    rid = rt.submit(p, budget=1)
    with pytest.raises(RuntimeError, match="injected"):
        rt.drain()
    r = rt.requests[rid]
    assert r.table                   # matched refs adopted by the owner
    rt.assert_ledger_balanced()      # ...so the ledger still balances
    # recovery: release through the owner and re-admit
    rt.pool.reserve = orig
    rt._release_prompt_table(r)
    rt.assert_ledger_balanced()
    rt.queue.append(r)
    rt.drain()
    np.testing.assert_array_equal(rt.result(rid).response,
                                  rt.result(first).response)


class _EscalateAcrossModels(DecodeProcedure):
    """Two weak children with staggered lifetimes; the first retirement
    escalates to the strong model while the sibling still decodes."""

    def plan(self, request, probe_hidden, runtime):
        return Plan([ChildGroup("default", 1, 1),
                     ChildGroup("default", 1, 3)])

    def on_child_done(self, request, child, runtime):
        if not request.proc.get("escalated"):
            request.proc["escalated"] = True
            return [ChildGroup("strong", 1, 2)]
        return None


def test_escalation_waits_for_live_siblings(tiny, strong):
    """The QUEUED re-entry guard: an escalation phase must not start its
    prefill while a sibling child still occupies a slot — the request
    stays DECODE until the last sibling retires, then phases through
    QUEUED, and the ledger balances throughout."""
    cfg, model, params = tiny
    _, s_model, s_params = strong
    rng = np.random.default_rng(3)
    p = rng.integers(0, cfg.vocab_size, (6,)).astype(np.int32)
    rt = ContinuousBatchingRuntime(model, params, n_slots=3, max_len=16,
                                   max_new=3, temperature=0.0, seed=0,
                                   pool="paged", block_size=4)
    rt.register_model("strong", s_model, s_params)
    rid = rt.submit(p, procedure=_EscalateAcrossModels())
    r = rt.requests[rid]
    for _ in range(200):
        if not rt.step():
            break
        if any(c.slot is not None for c in r.children):
            assert r.state is not RequestState.QUEUED
        rt.assert_ledger_balanced()
    res = rt.result(rid)
    assert res.state == RequestState.DONE
    assert [c.model_id for c in res.children] == ["default", "default",
                                                  "strong"]
    assert all(c.done() for c in res.children)
    rt.assert_ledger_balanced()
