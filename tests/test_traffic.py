"""Traffic subsystem: priority scheduler protocol + ordering, preemption
correctness (ledger-audited, bitwise-identical resume), SLO-aware
degradation, queue-wait/TTFT metrics, and the async streaming surface.

The churn tests compare a traffic run against a strict-FIFO run of the
SAME submissions: preemption and priority may reorder *service*, but
under greedy decode every (request, child-index) pair must produce
token-bitwise identical rows — the per-child RNG streams restart from
``fold_in(fold_in(seed, id), index)`` on resume, so eviction is
invisible in the outputs."""
import asyncio

import numpy as np
import pytest

from repro.serving import (ContinuousBatchingRuntime, PriorityClassQueues,
                           RequestState, TrafficConfig)
from repro.serving.traffic import AsyncTokenStreamer, TrafficController


class _Req:
    """Stand-in with the scheduler-visible fields."""

    def __init__(self, rid, tenant="default", priority=1):
        self.id, self.tenant, self.priority = rid, tenant, priority

    def __repr__(self):
        return f"R{self.id}"


# ------------------------------------------------------------- scheduler
def test_scheduler_deque_protocol_consistency():
    """len/iter/[i]/popleft agree: the materialized order IS the pop
    order, and deletion by index removes the peeked element."""
    q = PriorityClassQueues()
    reqs = [_Req(i, tenant=f"t{i % 2}", priority=i % 3) for i in range(9)]
    for r in reqs:
        q.append(r)
    assert len(q) == 9 and bool(q)
    order = list(q)
    assert [q[i] for i in range(len(q))] == order
    del q[3]                            # removes order[3] specifically
    assert order[3] not in list(q)
    got = []
    while q:
        assert q[0] is list(q)[0]       # peek == next pop, always
        got.append(q.popleft())
    # deletion shifts the WRR credit state, so later picks may reorder —
    # but the drain must be exactly the surviving set, no dupes/losses
    assert sorted(r.id for r in got) == sorted(
        r.id for r in order if r is not order[3])


def test_scheduler_priority_wins_under_contention():
    """With classes at priority 0 and 2 queued, the smooth-WRR pick
    serves the high class weight_base^2 : 1 — the first pops are high."""
    q = PriorityClassQueues(weight_base=4.0)
    lows = [_Req(i, priority=0) for i in range(8)]
    highs = [_Req(100 + i, priority=2) for i in range(8)]
    for r in lows + highs:
        q.append(r)
    first8 = [q.popleft() for _ in range(8)]
    # 16:1 weighting -> at most one low sneaks into the first eight
    assert sum(r.priority == 2 for r in first8) >= 7


def test_scheduler_front_slot_preserved():
    """appendleft (the radix lookahead's pull-forward) bypasses the
    weighted pick entirely."""
    q = PriorityClassQueues()
    q.append(_Req(1, priority=2))
    hit = _Req(2, priority=0)
    q.appendleft(hit)
    assert q[0] is hit and q.popleft() is hit


def test_scheduler_tenant_budget_skips_hog():
    """A tenant over its sliding-window budget is passed over while
    another tenant has work — but served anyway when alone (work-
    conserving)."""
    seen = {}

    def budget_fn(weights, window):
        seen.update(weights)
        return {t: 2 for t in weights}      # everyone: 2 per window

    q = PriorityClassQueues(window=8, budget_fn=budget_fn)
    hogs = [_Req(i, tenant="hog") for i in range(5)]
    one = _Req(99, tenant="small")
    for r in hogs:
        q.append(r)
    q.append(one)
    assert set(seen) == {"hog", "small"}
    got = [q.popleft() for _ in range(4)]
    # hog is capped at 2 admissions before small must be served
    assert one in got[:3]
    while q:                                # work-conserving drain
        q.popleft()


def test_tenant_budgets_weighted_fair_share():
    """The price-dual split gives the heavier tenant the larger share of
    the admission window, and every tenant at least 1."""
    tc = TrafficController(TrafficConfig())
    b = tc.tenant_budgets({"big": 16.0, "small": 1.0}, 32)
    assert b["big"] > b["small"] >= 1


# ----------------------------------------------------- preemption + churn
def _mk(model, params, traffic, **kw):
    base = dict(n_slots=2, max_len=64, max_new=24, block_size=4,
                n_blocks=20, prefill_window=2, horizon=1,
                temperature=0.0, seed=0)
    base.update(kw)
    return ContinuousBatchingRuntime(model, params, traffic=traffic, **base)


def test_preempt_request_direct_bitwise_resume(tiny):
    """Preempt a mid-decode request by hand, drain, and compare against
    an untouched run: ledger balanced, same tokens, preemption counted,
    and the resume re-prefilled through the radix cache."""
    cfg, model, params = tiny
    rng = np.random.default_rng(7)
    prompt = rng.integers(1, cfg.vocab_size, size=9).astype(np.int32)

    rt = _mk(model, params, TrafficConfig())
    rid = rt.submit(prompt, budget=2, priority=0)
    for _ in range(8):                      # prefill + a few decode ticks
        rt.step()
    r = rt.requests[rid]
    assert r.state is RequestState.DECODE
    assert any(c.slot is not None for c in r.children)
    rt._preempt_request(r)
    assert r.state is RequestState.QUEUED and r.preemptions == 1
    assert all(c.slot is None and c.table is None for c in r.children)
    rt.assert_ledger_balanced()             # valid mid-flight, post-evict
    rt.drain()
    assert rt.metrics.preemptions == 1
    assert rt.metrics.prefix_hits >= 1      # resume adopted published blocks

    ref = _mk(model, params, None)
    ref_id = ref.submit(prompt, budget=2)
    ref.drain()
    assert ([c.tokens for c in rt.requests[rid].children]
            == [c.tokens for c in ref.requests[ref_id].children])
    np.testing.assert_array_equal(rt.requests[rid].response,
                                  ref.requests[ref_id].response)


@pytest.mark.parametrize("seed", [1, 5])
def test_randomized_churn_ledger_and_bitwise(tiny, seed):
    """Randomized churn: a low-priority resident keeps getting evicted by
    later high-priority arrivals on a tight pool. After drain the ledger
    balances exactly and every request's children match a strict-FIFO
    replay of the same submissions bitwise."""
    cfg, model, params = tiny
    rng = np.random.default_rng(seed)
    # priorities rise with arrival order so later arrivals always outrank
    # the residents — guarantees the evict/resume path actually churns;
    # lengths, budgets, and interleave remain randomized
    subs = [(rng.integers(1, cfg.vocab_size,
                          size=int(rng.integers(5, 12))).astype(np.int32),
             int(rng.integers(1, 3)),       # budget
             i)                             # priority
            for i in range(6)]

    rt = _mk(model, params, TrafficConfig(degrade=False))
    ids = []
    for i, (p, b, pri) in enumerate(subs):
        ids.append(rt.submit(p, budget=b, priority=pri,
                             tenant=f"t{i % 2}"))
        for _ in range(int(rng.integers(2, 7))):    # interleave decode
            if rt.pending():
                rt.step()
    rt.drain()                              # asserts the ledger itself
    assert rt.metrics.preemptions >= 1, "churn never preempted"

    ref = _mk(model, params, None)
    ref_ids = []
    for i, (p, b, _) in enumerate(subs):
        ref_ids.append(ref.submit(p, budget=b))
        for _ in range(3):
            if ref.pending():
                ref.step()
    ref.drain()
    for ra, rb in zip(ids, ref_ids):
        assert ([c.tokens for c in rt.requests[ra].children]
                == [c.tokens for c in ref.requests[rb].children]), ra


def test_preemption_respects_priority_and_cap(tiny):
    """No victim at or above the beneficiary's priority; a request is
    never evicted more than max_preemptions times."""
    cfg, model, params = tiny
    rng = np.random.default_rng(3)
    p = rng.integers(1, cfg.vocab_size, size=8).astype(np.int32)
    rt = _mk(model, params, TrafficConfig(max_preemptions=1,
                                          degrade=False))
    r0 = rt.submit(p, budget=2, priority=1)
    for _ in range(8):
        rt.step()
    # same priority: never a victim
    rt.submit(rng.integers(1, cfg.vocab_size, size=8).astype(np.int32),
              budget=1, priority=1)
    for _ in range(4):
        rt.step()
    assert rt.requests[r0].preemptions == 0
    # higher priority may evict, but only max_preemptions times
    for k in range(3):
        rt.submit(rng.integers(1, cfg.vocab_size,
                               size=8).astype(np.int32),
                  budget=1, priority=3)
    rt.drain()
    assert rt.requests[r0].preemptions <= 1


# ----------------------------------------------------------- degradation
def test_degradation_shaves_budget_under_load(tiny):
    """With a tight pool, target_load 0 and a positive price, the
    budget_fn ask is shaved (never below b_min) and flagged."""
    cfg, model, params = tiny
    rng = np.random.default_rng(11)
    rt = _mk(model, params,
             TrafficConfig(target_load=0.0, price_gain=50.0, b_min=1),
             n_slots=4, budget_fn=lambda r, h: 4)
    ids = [rt.submit(rng.integers(1, cfg.vocab_size,
                                  size=8).astype(np.int32))
           for _ in range(4)]
    rt.drain()
    s = rt.metrics.summary()
    assert s["degraded_requests"] >= 1
    assert 0 < s["degraded_share"] <= 1
    degraded = [rt.requests[i] for i in ids if rt.requests[i].degraded]
    assert degraded
    assert all(1 <= len(r.children) < 4 for r in degraded)


def test_degradation_priority_keeps_more(tiny):
    """At the same load price a higher-priority request keeps a budget at
    least as large (harmonic marginals scale with class weight)."""
    cfg, model, params = tiny
    rt = _mk(model, params, TrafficConfig(target_load=0.0, price_gain=4.0))
    tc = rt.traffic
    lo = rt.submit(np.arange(1, 7, dtype=np.int32), budget=None, priority=0)
    hi = rt.submit(np.arange(1, 7, dtype=np.int32), budget=None, priority=3)
    b_lo = tc.degrade_budget(rt, rt.requests[lo], 8)
    b_hi = tc.degrade_budget(rt, rt.requests[hi], 8)
    assert 1 <= b_lo <= b_hi <= 8


def test_effective_horizon_shrinks_with_price(tiny):
    cfg, model, params = tiny
    rt = _mk(model, params, TrafficConfig(target_load=0.0, price_gain=1.0,
                                          min_horizon=1))
    tc = rt.traffic
    # queue demand lifts the load price above zero without any decode
    for _ in range(3):
        rt.submit(np.arange(1, 10, dtype=np.int32), budget=2)
    assert tc.price(rt) > 0
    assert tc.effective_horizon(rt, 8) < 8
    assert tc.effective_horizon(rt, 1) == 1     # floor respected


# ---------------------------------------------------------------- metrics
def test_queue_wait_and_ttft_metrics(tiny):
    cfg, model, params = tiny
    rng = np.random.default_rng(2)
    rt = ContinuousBatchingRuntime(model, params, n_slots=3, max_len=24,
                                   max_new=4, block_size=4)
    ids = [rt.submit(rng.integers(1, cfg.vocab_size,
                                  size=6).astype(np.int32), budget=1)
           for _ in range(3)]
    rt.drain()
    s = rt.metrics.summary()
    assert len(rt.metrics.queue_waits) == 3
    assert len(rt.metrics.ttfts) == 3
    for k in ("queue_wait_p50_s", "queue_wait_p95_s", "ttft_p50_s",
              "ttft_p95_s", "preemptions", "degraded_share"):
        assert k in s
    assert s["ttft_p50_s"] >= s["queue_wait_p50_s"] >= 0
    for i in ids:
        r = rt.requests[i]
        assert r.admit_t is not None and r.first_token_t is not None
        assert r.first_token_t >= r.admit_t >= r.submit_t


def test_met_slo():
    from repro.serving.request import Request
    r = Request(id=0, prompt=np.arange(3, dtype=np.int32))
    assert r.met_slo() is None              # no SLO, in flight
    r.slo = 10.0
    r.done_t = r.submit_t + 1.0
    assert r.met_slo() is True
    r.slo = 0.5
    assert r.met_slo() is False


# --------------------------------------------------------------- streaming
def test_async_token_streaming_matches_drain(tiny):
    """Tokens stream out in order as they decode and match the finished
    child's rows; a parallel drained runtime confirms the values."""
    cfg, model, params = tiny
    rng = np.random.default_rng(6)
    prompts = [rng.integers(1, cfg.vocab_size, size=6).astype(np.int32)
               for _ in range(2)]

    ref = ContinuousBatchingRuntime(model, params, n_slots=2, max_len=24,
                                    max_new=5, block_size=4)
    ref_ids = [ref.submit(p, budget=1) for p in prompts]
    ref.drain()

    rt = ContinuousBatchingRuntime(model, params, n_slots=2, max_len=24,
                                   max_new=5, block_size=4,
                                   traffic=TrafficConfig())
    streamer = AsyncTokenStreamer(rt)
    rids = [streamer.submit(p, budget=1, priority=i)
            for i, p in enumerate(prompts)]

    async def main():
        server = asyncio.ensure_future(streamer.serve())
        outs = await asyncio.gather(*[
            _collect(streamer, rid) for rid in rids])
        await server
        return outs

    async def _collect(s, rid):
        return [t async for t in s.tokens(rid)]

    outs = asyncio.run(main())
    for rid, ref_id, out in zip(rids, ref_ids, outs):
        assert out == rt.requests[rid].children[0].tokens
        assert out == ref.requests[ref_id].children[0].tokens
        assert streamer.response(rid) is not None


def test_traffic_requires_paged_pool(tiny):
    cfg, model, params = tiny
    with pytest.raises(ValueError, match="paged"):
        ContinuousBatchingRuntime(model, params, n_slots=2, max_len=24,
                                  pool="slots", traffic=TrafficConfig())
