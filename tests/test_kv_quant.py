"""Int8 quantized paged KV (`kv_quant="int8"`): write-path round-trip
error bounds, dequant-fused Pallas kernels vs the explicit-dequant XLA
reference, greedy e2e quality parity vs the fp cache on the weak/strong
fixture pair, radix hit-vs-cold consistency under quant, and churn with
ledger balance plus scale-store conservation."""
import math

import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops
from repro.models import attention
from repro.serving import ContinuousBatchingRuntime, RequestState


# ---------------------------------------------------------------------------
# write-path round-trip: per-(block, kv-head) error bound
# ---------------------------------------------------------------------------

def _dequant(blocks, scales, tables):
    return (np.asarray(blocks)[np.asarray(tables)].astype(np.float32)
            * np.asarray(scales)[np.asarray(tables)][..., None])


def _roundtrip_bound(got, want):
    """|err| <= B * amax / 254 per (block, kv-head). One symmetric round
    costs half a step (amax/254). Requant-on-write re-rounds existing
    rows exactly when the block's scale is unchanged (round(q*s/s) == q,
    and the amax row dequantizes to 127*s exactly, so the recomputed
    scale is bit-stable) — error only grows when a new row RAISES the
    block amax, re-rounding older rows once under the new scale. A block
    holds B rows, so at most B such growth events: B half-steps total,
    not one per rewrite."""
    B = want.shape[-3]
    amax = np.abs(want).max(axis=(-3, -1), keepdims=True)   # (..,1,KVp,1)
    err = np.abs(got - want)
    assert (err <= B * amax / 254.0 * (1 + 1e-5) + 1e-7).all(), err.max()


def test_token_write_roundtrip_error_bounded():
    rng = np.random.default_rng(0)
    b, T, B, KV, hd = 3, 4, 4, 2, 6
    nb = 1 + b * T
    blocks = jnp.zeros((nb, B, KV, hd), jnp.int8)
    scales = jnp.zeros((nb, 1, KV), jnp.float32)
    tables = jnp.asarray(np.arange(1, nb).reshape(b, T), jnp.int32)
    ref = rng.normal(size=(b, T * B, KV, hd)).astype(np.float32)
    ref *= rng.uniform(0.1, 10.0, size=(b, 1, KV, 1))       # mixed head mag
    for p in range(T * B):
        blocks, scales = attention.paged_write_quant(
            blocks, scales, jnp.asarray(ref[:, p]), tables,
            jnp.full((b,), p, jnp.int32))
    got = _dequant(blocks, scales, tables).reshape(b, T * B, KV, hd)
    _roundtrip_bound(got.reshape(b, T, B, KV, hd),
                     ref.reshape(b, T, B, KV, hd))
    # never-written blocks (the null block) dequantize to exact zeros
    assert np.asarray(scales)[0].max() == 0.0


def test_chunk_write_roundtrip_error_bounded_any_alignment():
    rng = np.random.default_rng(1)
    b, T, B, KV, hd, C = 2, 5, 4, 2, 5, 6            # C deliberately != kB
    nb = 1 + b * T
    blocks = jnp.zeros((nb, B, KV, hd), jnp.int8)
    scales = jnp.zeros((nb, 1, KV), jnp.float32)
    tables = jnp.asarray(np.arange(1, nb).reshape(b, T), jnp.int32)
    total = T * B
    ref = rng.normal(size=(b, total, KV, hd)).astype(np.float32)
    written = np.zeros(b, int)
    while written.min() < total:
        valid = np.minimum(rng.integers(1, C + 1, size=b),
                           total - written)
        valid = np.maximum(valid, 0)
        new = np.zeros((b, C, KV, hd), np.float32)
        for i in range(b):
            new[i, :valid[i]] = ref[i, written[i]:written[i] + valid[i]]
        blocks, scales = attention.paged_write_chunk_quant(
            blocks, scales, jnp.asarray(new), tables,
            jnp.asarray(written, jnp.int32), jnp.asarray(valid, jnp.int32))
        written += valid
    got = _dequant(blocks, scales, tables).reshape(b, T, B, KV, hd)
    _roundtrip_bound(got, ref.reshape(b, T, B, KV, hd))
    # out-of-table window slots scatter only requantized-zero content
    # into the null block: its scale must still be exactly zero
    assert np.asarray(scales)[0].max() == 0.0


# ---------------------------------------------------------------------------
# fused kernels vs the explicit-dequant XLA reference
# ---------------------------------------------------------------------------

def _random_store(rng, nb, B, KV, hd):
    q8 = rng.integers(-127, 128, size=(nb, B, KV, hd)).astype(np.int8)
    sc = rng.uniform(0.01, 0.2, size=(nb, 1, KV)).astype(np.float32)
    return jnp.asarray(q8), jnp.asarray(sc)


def _ref_attention(q, ck, cv, qpos):
    """Dense grouped attention over dequantized (b, S, KV, hd) views with
    `k <= qpos` validity; q (b, Q, H, hd), qpos (b, Q)."""
    b, Q, H, hd = q.shape
    KV = ck.shape[2]
    g = H // KV
    qg = np.asarray(q).reshape(b, Q, KV, g, hd)
    s = np.einsum("bqkgd,bskd->bqkgs", qg, np.asarray(ck)) / math.sqrt(hd)
    S = ck.shape[1]
    valid = np.arange(S)[None, None, :] <= np.asarray(qpos)[:, :, None]
    s = np.where(valid[:, :, None, None, :], s, -1e30)
    w = np.exp(s - s.max(-1, keepdims=True))
    w = w / w.sum(-1, keepdims=True)
    o = np.einsum("bqkgs,bskd->bqkgd", w, np.asarray(cv))
    return o.reshape(b, Q, H, hd)


def test_fused_decode_kernel_matches_explicit_dequant():
    rng = np.random.default_rng(2)
    b, T, B, KV, g, hd, nb = 3, 4, 4, 2, 2, 8, 11
    H = KV * g
    kb, ks = _random_store(rng, nb, B, KV, hd)
    vb, vs = _random_store(rng, nb, B, KV, hd)
    tables = jnp.asarray(rng.integers(1, nb, size=(b, T)), jnp.int32)
    pos = jnp.asarray([3, 7, 14], jnp.int32)
    q = jnp.asarray(rng.normal(size=(b, H, hd)), jnp.float32)

    out = ops.paged_decode_attention_quant(q, kb, ks, vb, vs, tables, pos,
                                           interpret=True)
    ck = attention.paged_gather_dequant(kb, ks, tables, jnp.float32)
    cv = attention.paged_gather_dequant(vb, vs, tables, jnp.float32)
    ref = _ref_attention(q[:, None], ck, cv, np.asarray(pos)[:, None])[:, 0]
    np.testing.assert_allclose(np.asarray(out), ref, atol=2e-5)


def test_fused_chunk_kernel_matches_explicit_dequant():
    rng = np.random.default_rng(3)
    b, T, B, KV, g, hd, nb, C = 2, 4, 4, 2, 2, 8, 9, 5
    H = KV * g
    kb, ks = _random_store(rng, nb, B, KV, hd)
    vb, vs = _random_store(rng, nb, B, KV, hd)
    tables = jnp.asarray(rng.integers(1, nb, size=(b, T)), jnp.int32)
    pos = jnp.asarray([2, 9], jnp.int32)                  # chunk starts
    q = jnp.asarray(rng.normal(size=(b, C, H, hd)), jnp.float32)

    out = ops.paged_chunk_attention_quant(q, kb, ks, vb, vs, tables, pos,
                                          interpret=True)
    ck = attention.paged_gather_dequant(kb, ks, tables, jnp.float32)
    cv = attention.paged_gather_dequant(vb, vs, tables, jnp.float32)
    qpos = np.asarray(pos)[:, None] + np.arange(C)[None, :]
    ref = _ref_attention(q, ck, cv, qpos)
    np.testing.assert_allclose(np.asarray(out), ref, atol=2e-5)


# ---------------------------------------------------------------------------
# e2e: greedy quality parity, radix consistency, churn conservation
# ---------------------------------------------------------------------------

def _greedy_tokens(model, params, prompts, *, kv_quant, max_new=8,
                   **kw):
    rt = ContinuousBatchingRuntime(
        model, params, n_slots=4, max_len=48, max_new=max_new,
        temperature=0.0, seed=0, pool="paged", block_size=4,
        kv_quant=kv_quant, **kw)
    ids = [rt.submit(p, budget=1) for p in prompts]
    rt.drain()
    rt.assert_ledger_balanced()
    return [list(rt.result(i).response) for i in ids]


@pytest.mark.parametrize("which", ["weak", "strong"])
def test_greedy_quality_parity_fp_vs_int8(tiny, strong, which):
    """Int8 KV must not change greedy behavior on the fixture pair beyond
    the accuracy policy: a near-tie argmax may flip under the ~amax/254
    per-entry cache error, and greedy feedback then conditions every
    later token on the changed prefix — so the honest unit is the child,
    not the token. On the weak fixture no tie is close enough: every
    child must match the fp stream within one token. The strong fixture
    (params x3 amplifies the perturbation) may lose at most one child of
    the four to a single flip-then-cascade; the rest stay exact. Both
    runs are fully deterministic (fixed seeds), so these are equalities
    in practice, not tolerances."""
    cfg, model, params = tiny if which == "weak" else strong
    rng = np.random.default_rng(4)
    prompts = [rng.integers(0, cfg.vocab_size, (L,)).astype(np.int32)
               for L in (5, 7, 9, 11)]
    fp = _greedy_tokens(model, params, prompts, kv_quant=None)
    q8 = _greedy_tokens(model, params, prompts, kv_quant="int8")
    assert all(len(a) == len(b) for a, b in zip(fp, q8))
    if which == "weak":
        for a, b in zip(fp, q8):
            assert sum(x != y for x, y in zip(a, b)) <= 1, (a, b)
    else:
        assert sum(a == b for a, b in zip(fp, q8)) >= len(prompts) - 1, \
            (fp, q8)


def test_radix_hit_vs_cold_consistent_under_quant(tiny):
    """Prefix-cache hits replay *quantized* blocks written by an earlier
    request; the hit path must be token-identical to a cold quant run
    (block scales travel with the shared block ids, so a hit dequantizes
    exactly what the cold path would recompute-and-requantize)."""
    cfg, model, params = tiny
    rng = np.random.default_rng(5)
    pre = rng.integers(0, cfg.vocab_size, (8,)).astype(np.int32)
    prompts = [np.concatenate(
        [pre, rng.integers(0, cfg.vocab_size, (4,)).astype(np.int32)])
        for _ in range(3)]

    def run(prefix_cache):
        rt = ContinuousBatchingRuntime(
            model, params, n_slots=4, max_len=20, max_new=4,
            temperature=0.0, seed=0, pool="paged", block_size=4,
            prefill_slots=1, prefix_cache=prefix_cache, kv_quant="int8")
        ids = [rt.submit(p, budget=2) for p in prompts]
        rt.drain()
        return rt, ids

    hot, ids_h = run(True)
    cold, ids_c = run(False)
    for ih, ic in zip(ids_h, ids_c):
        for ch, cc in zip(hot.result(ih).children, cold.result(ic).children):
            np.testing.assert_array_equal(ch.tokens, cc.tokens)
    assert hot.metrics.prefix_hits == 2
    assert hot.metrics.prefix_hit_tokens == 16
    hot.assert_ledger_balanced()


def _scale_leaves(pool):
    """(q8_store, scale_store) pairs from the pool's cache pytree: an
    int8 leaf (n_repeat, nb, B, KVp, hd) is a block store, its scale
    sibling the fp32 (n_repeat, nb, 1, KVp) leaf. Pairing by dtype and
    the singleton row axis is enough — the layers share one structure."""
    import jax
    leaves = jax.tree_util.tree_leaves(pool.cache)
    q8 = [x for x in leaves if x.dtype == jnp.int8]
    sc = [x for x in leaves if x.dtype == jnp.float32
          and x.ndim == 4 and x.shape[2] == 1]
    assert q8 and len(q8) == len(sc)
    return list(zip(q8, sc))


def test_quant_churn_ledger_balanced_and_scales_conserved(tiny):
    """Randomized submit/EOS/b_i=0 churn on the quantized pool: the block
    ledger must balance at every step and at drain exactly as in fp mode,
    and the scale store must stay structurally conserved — one finite
    non-negative scale row per physical block, per store."""
    cfg, model, params = tiny
    rng = np.random.default_rng(11)
    lengths = rng.integers(4, 12, size=6)
    budgets = [2, 0, 3, 1, 2, 1]
    prompts = [rng.integers(1, cfg.vocab_size, (int(L),)).astype(np.int32)
               for L in lengths]
    rt = ContinuousBatchingRuntime(
        model, params, n_slots=2, max_len=16, max_new=4, temperature=0.0,
        seed=0, pool="paged", block_size=4, prefill_chunk=4, eos_id=7,
        kv_quant="int8")
    ids = [rt.submit(p, budget=b) for p, b in zip(prompts, budgets)]
    steps = 0
    while rt.pending():
        rt.step()
        steps += 1
        pool = rt.pool
        pool.check_conservation()
        assert (pool.available_blocks + pool._reserved
                + pool.blocks_in_use == pool.n_blocks - 1)
        assert steps < 10_000
    rt.drain()
    for rid in ids:
        assert rt.result(rid).state == RequestState.DONE
    rt.assert_ledger_balanced()
    for q8, sc in _scale_leaves(rt.pool):
        assert q8.shape[:2] == sc.shape[:2]         # one scale row / block
        s = np.asarray(sc)
        assert np.isfinite(s).all() and (s >= 0).all()
