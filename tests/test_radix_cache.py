"""Radix-tree prefix cache: cross-request block dedup semantics
(match / publish / LRU evict / clear), bitwise hit-vs-cold greedy parity,
multi-token chunked prefill (grid alignment, per-token parity, Pallas
chunk kernel), `release_table` hardening, and randomized pool-conservation
churn over submit / EOS / b_i=0 / drain sequences on both pools."""
import dataclasses

import jax
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:        # hypothesis is dev-only: skip just those tests
    from conftest import given, settings, st  # noqa: F401

from repro.configs import get_config
from repro.models import build_model
from repro.serving import (ContinuousBatchingRuntime, PagedKVPool,
                           RadixCache, RequestState, ServingEngine)


@pytest.fixture(scope="module")
def tiny():
    cfg = dataclasses.replace(get_config("qwen2-0.5b").reduced(),
                              dtype="float32", n_layers=2)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _prefix_prompts(cfg, rng, *, n, pre_len, tail_len):
    pre = rng.integers(0, cfg.vocab_size, (pre_len,)).astype(np.int32)
    return [np.concatenate(
        [pre, rng.integers(0, cfg.vocab_size, (tail_len,)).astype(np.int32)])
        for _ in range(n)]


# ---------------------------------------------------------------------------
# RadixCache unit semantics (bare pool, no model ticks)
# ---------------------------------------------------------------------------

def test_radix_match_publish_evict_unit(tiny):
    cfg, model, params = tiny
    pool = PagedKVPool(model, 2, 16, block_size=4, n_blocks=12)
    radix = RadixCache(pool)
    rng = np.random.default_rng(0)
    toks = rng.integers(0, 100, (12,)).astype(np.int32)

    # simulate a prefilled prompt: 3 full blocks owned by a "request"
    pool.reserve(3)
    table = [pool.alloc_block() for _ in range(3)]
    assert radix.match(toks) == []             # empty tree: no match
    assert radix.publish(toks, table, 3) == 3
    assert radix.held_blocks == 3
    assert [pool.refcount(b) for b in table] == [2, 2, 2]

    # a second request with the same first 2 blocks matches exactly those,
    # increfed on its behalf
    other = np.concatenate([toks[:8], toks[8:] + 1]).astype(np.int32)
    got = radix.match(other)
    assert got == table[:2]
    assert [pool.refcount(b) for b in table] == [3, 3, 2]
    radix.unmatch(got)

    # re-publishing dedups: existing nodes win, nothing new inserted
    assert radix.publish(toks, table, 3) == 0

    # request releases its table; the tree keeps the blocks alive
    pool.release_table(table)
    assert pool.blocks_in_use == 3

    # eviction is leaf-first and only frees tree-only blocks
    assert radix.evict(1) == 1
    assert radix.held_blocks == 2
    assert pool.refcount(table[2]) == 0        # deepest leaf went first
    # clearing returns the pool to pristine
    assert radix.clear() == 2
    assert pool.blocks_in_use == 0
    pool.check_conservation()


def test_radix_evict_skips_blocks_shared_with_live_requests(tiny):
    """A published block still referenced by a live request is not
    evictable (freeing it would return no memory); eviction takes the
    LRU tree-only leaf instead."""
    cfg, model, params = tiny
    pool = PagedKVPool(model, 2, 16, block_size=4, n_blocks=12)
    radix = RadixCache(pool)
    rng = np.random.default_rng(1)
    a = rng.integers(0, 100, (4,)).astype(np.int32)
    b = rng.integers(0, 100, (4,)).astype(np.int32)
    pool.reserve(2)
    ta = [pool.alloc_block()]
    tb = [pool.alloc_block()]
    radix.publish(a, ta, 1)                    # older
    radix.publish(b, tb, 1)                    # newer
    pool.release_table(tb)                     # only b is tree-only
    assert radix.evict(2) == 1                 # a is pinned by its request
    assert pool.refcount(ta[0]) == 2 and pool.refcount(tb[0]) == 0
    radix.clear()
    pool.release_table(ta)
    pool.check_conservation()


def test_release_table_dedup_null_and_invalid(tiny):
    """Satellite: release_table must decref each distinct id once, skip
    the reserved null block (table padding), and raise on genuinely
    invalid entries instead of corrupting the ledger."""
    cfg, model, params = tiny
    pool = PagedKVPool(model, 2, 16, block_size=4, n_blocks=8)
    pool.reserve(2)
    a, b = pool.alloc_block(), pool.alloc_block()
    pool.incref(a)                             # someone else shares a
    # repeated COW-shared id + null-block padding: one decref per distinct
    pool.release_table([a, a, 0, b, 0])
    assert pool.refcount(a) == 1               # not double-decrefed
    assert pool.refcount(b) == 0
    with pytest.raises(RuntimeError, match="invalid block"):
        pool.release_table([b])                # already free
    with pytest.raises(RuntimeError, match="invalid block"):
        pool.release_table([pool.n_blocks + 3])
    pool.release_table([a])
    pool.check_conservation()


# ---------------------------------------------------------------------------
# Hit-vs-cold parity and savings (the acceptance criterion)
# ---------------------------------------------------------------------------

def test_prefix_hit_bitwise_matches_cold_path(tiny):
    """A request admitted after its prefix was published skips that
    prefill (metered in prefix_hit_tokens) and still produces tokens
    bitwise identical to a cold run and to the batch engine."""
    cfg, model, params = tiny
    engine = ServingEngine(model, params, max_new=4, temperature=0.0)
    rng = np.random.default_rng(2)
    prompts = _prefix_prompts(cfg, rng, n=3, pre_len=8, tail_len=4)

    def run(prefix_cache):
        # prefill_slots=1 serializes prefill, so request i+1 is admitted
        # after request i published its blocks — deterministic hits
        rt = ContinuousBatchingRuntime(
            model, params, n_slots=4, max_len=20, max_new=4,
            temperature=0.0, seed=0, pool="paged", block_size=4,
            prefill_slots=1, prefix_cache=prefix_cache)
        ids = [rt.submit(p, budget=2) for p in prompts]
        rt.drain()
        return rt, ids

    hot, ids_h = run(True)
    cold, ids_c = run(False)
    for i, p in enumerate(prompts):
        want = engine.generate(p[None], n_samples=1, seed=0,
                               temperature=0.0).tokens[0]
        for ch, cc in zip(hot.result(ids_h[i]).children,
                          cold.result(ids_c[i]).children):
            np.testing.assert_array_equal(np.asarray(ch.tokens), want)
            np.testing.assert_array_equal(ch.tokens, cc.tokens)
    # requests 1 and 2 each skipped the 8-token shared preamble
    assert hot.metrics.prefix_hits == 2
    assert hot.metrics.prefix_hit_tokens == 16
    assert cold.metrics.prefix_hit_tokens == 0
    assert (hot.metrics.prefill_tokens
            == cold.metrics.prefill_tokens - 16)
    assert hot.requests[ids_h[1]].prefix_len == 8
    hot.assert_ledger_balanced()


def test_fully_matched_prompt_recomputes_final_token(tiny):
    """An identical repeated prompt matches every full block; the probe
    still needs the last token's logits/hidden, so the hit path drops the
    final matched block and recomputes at least one token."""
    cfg, model, params = tiny
    rng = np.random.default_rng(3)
    prompt = rng.integers(0, cfg.vocab_size, (8,)).astype(np.int32)  # 2 blocks
    rt = ContinuousBatchingRuntime(model, params, n_slots=2, max_len=16,
                                   max_new=3, temperature=0.0, seed=0,
                                   pool="paged", block_size=4,
                                   prefill_slots=1)
    ra = rt.submit(prompt, budget=1)
    rt.drain()
    rb = rt.submit(prompt, budget=1)
    rt.drain()
    a, b = rt.result(ra), rt.result(rb)
    np.testing.assert_array_equal(a.response, b.response)
    assert b.prefix_len == 4                   # one block, not both
    assert b.hidden is not None
    np.testing.assert_allclose(a.hidden, b.hidden, rtol=1e-5, atol=1e-5)
    assert rt.metrics.prefix_hit_tokens == 4
    rt.assert_ledger_balanced()


def test_eviction_under_pressure_keeps_stream_exact(tiny):
    """A tiny pool under sustained distinct-prompt traffic must evict LRU
    radix leaves to admit new work — outputs stay exact and the ledger
    balances (no leak from the evict/adopt paths)."""
    cfg, model, params = tiny
    engine = ServingEngine(model, params, max_new=3, temperature=0.0)
    rng = np.random.default_rng(4)
    prompts = [rng.integers(0, cfg.vocab_size, (7,)).astype(np.int32)
               for _ in range(6)]
    rt = ContinuousBatchingRuntime(model, params, n_slots=2, max_len=12,
                                   max_new=3, temperature=0.0, seed=0,
                                   pool="paged", block_size=4, n_blocks=9,
                                   budget_fn=lambda r, h: 2)
    ids = [rt.submit(p) for p in prompts]
    rt.drain()
    for p, rid in zip(prompts, ids):
        want = engine.generate(p[None], n_samples=1, seed=0,
                               temperature=0.0).tokens[0][:3]
        np.testing.assert_array_equal(rt.result(rid).response, want)
    assert rt.metrics.radix_evicted_blocks > 0
    rt.assert_ledger_balanced()


# ---------------------------------------------------------------------------
# Multi-token chunked prefill
# ---------------------------------------------------------------------------

def test_chunk_width_invariance_and_tick_savings(tiny):
    """Any prefill_chunk yields the same greedy tokens, stash logits and
    probe hidden as the per-token interleave (chunk=1), while cutting the
    number of host-visible prefill steps by ~C."""
    cfg, model, params = tiny
    rng = np.random.default_rng(5)
    prompts = [rng.integers(0, cfg.vocab_size, (L,)).astype(np.int32)
               for L in (9, 13, 6)]

    def run(chunk):
        rt = ContinuousBatchingRuntime(
            model, params, n_slots=3, max_len=20, max_new=3,
            temperature=0.0, seed=0, pool="paged", block_size=4,
            prefill_chunk=chunk, prefix_cache=False)
        ids = [rt.submit(p, budget=1) for p in prompts]
        rt.drain()
        return rt, ids

    base, ids0 = run(1)
    for C in (4, 8):
        rt, ids = run(C)
        assert rt.prefill_chunk == C
        for r0, r1 in zip(ids0, ids):
            np.testing.assert_array_equal(base.result(r0).response,
                                          rt.result(r1).response)
            np.testing.assert_allclose(base.result(r0).hidden,
                                       rt.result(r1).hidden,
                                       rtol=2e-5, atol=2e-5)
        # same tokens computed, far fewer prefill program launches
        assert rt.metrics.prefill_tokens == base.metrics.prefill_tokens
        assert rt.metrics.prefill_calls < base.metrics.prefill_calls
        rt.assert_ledger_balanced()


def test_chunked_prefill_pallas_kernel_matches_xla(tiny, monkeypatch):
    """REPRO_DECODE_KERNEL=pallas routes chunked prefill through the
    varlen paged chunk kernel; greedy outputs match the XLA gather path
    and the kernel is actually traced."""
    from repro.kernels import ops
    from repro.models import build_model as _build
    cfg, model, params = tiny
    rng = np.random.default_rng(6)
    prompts = [rng.integers(0, cfg.vocab_size, (L,)).astype(np.int32)
               for L in (9, 6)]

    calls = []
    orig = ops.paged_chunk_attention
    monkeypatch.setattr(
        ops, "paged_chunk_attention",
        lambda *a, **k: (calls.append(1), orig(*a, **k))[1])

    def run(m):
        rt = ContinuousBatchingRuntime(m, params, n_slots=2, max_len=16,
                                       max_new=3, temperature=0.0, seed=0,
                                       pool="paged", block_size=4,
                                       prefill_chunk=4)
        ids = [rt.submit(p, budget=1) for p in prompts]
        rt.drain()
        return [list(rt.result(i).response) for i in ids]

    xla = run(model)
    assert not calls
    monkeypatch.setenv("REPRO_DECODE_KERNEL", "pallas")
    pallas = run(_build(cfg))                  # fresh Model -> fresh trace
    assert calls
    assert xla == pallas


def test_paged_chunk_kernel_unit_matches_reference():
    """The varlen chunk kernel against a dense causal reference on an
    irregular shape (chunk crossing block boundaries, partial tail)."""
    from repro.kernels.decode_attention import paged_chunk_attention
    rng = np.random.default_rng(7)
    b, C, H, KV, hd, B, T = 2, 5, 4, 2, 8, 4, 4
    nb = 1 + b * T
    k_blocks = rng.normal(size=(nb, B, KV, hd)).astype(np.float32)
    v_blocks = rng.normal(size=(nb, B, KV, hd)).astype(np.float32)
    q = rng.normal(size=(b, C, H, hd)).astype(np.float32)
    tables = np.asarray([[1, 2, 3, 4], [5, 6, 7, 8]], np.int32)
    pos = np.asarray([3, 6], np.int32)         # chunks straddle boundaries
    out = np.asarray(paged_chunk_attention(
        jax.numpy.asarray(q), jax.numpy.asarray(k_blocks),
        jax.numpy.asarray(v_blocks), jax.numpy.asarray(tables),
        jax.numpy.asarray(pos)))
    g = H // KV
    for i in range(b):
        dense_k = k_blocks[tables[i]].reshape(T * B, KV, hd)
        dense_v = v_blocks[tables[i]].reshape(T * B, KV, hd)
        for c in range(C):
            p = pos[i] + c
            for h in range(H):
                kv = h // g
                s = dense_k[: p + 1, kv] @ q[i, c, h] / np.sqrt(hd)
                w = np.exp(s - s.max())
                w /= w.sum()
                want = w @ dense_v[: p + 1, kv]
                np.testing.assert_allclose(out[i, c, h], want,
                                           rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# Pool conservation under randomized churn (satellite)
# ---------------------------------------------------------------------------

def _churn_once(tiny, pool_kind, lengths, budgets, eos_pick, chunk):
    cfg, model, params = tiny
    rng = np.random.default_rng(11)
    prompts = [rng.integers(1, cfg.vocab_size, (L,)).astype(np.int32)
               for L in lengths]
    kw = {}
    if pool_kind == "paged":
        kw = dict(block_size=4, prefill_chunk=chunk)
    rt = ContinuousBatchingRuntime(
        model, params, n_slots=2, max_len=16, max_new=4, temperature=0.0,
        seed=0, pool=pool_kind, eos_id=int(eos_pick), **kw)
    ids = [rt.submit(p, budget=b) for p, b in zip(prompts, budgets)]
    steps = 0
    while rt.pending():
        rt.step()
        steps += 1
        if pool_kind == "paged":
            # conservation must hold at EVERY step boundary, not just
            # at drain: available + reserved + in_use == usable blocks
            pool = rt.pool
            pool.check_conservation()
            assert (pool.available_blocks + pool._reserved
                    + pool.blocks_in_use == pool.n_blocks - 1)
        assert steps < 10_000
    rt.drain()
    for rid in ids:
        assert rt.result(rid).state == RequestState.DONE
    rt.assert_ledger_balanced()
    if pool_kind == "paged":
        held = rt.radix.held_blocks if rt.radix is not None else 0
        assert rt.pool.blocks_in_use == held
        assert rt.pool._reserved == 0
    else:
        assert rt.pool.n_free == rt.pool.n_slots
    return rt


@pytest.mark.slow
@pytest.mark.parametrize("pool_kind", ["paged", "slots"])
def test_pool_conservation_fixed_churn(tiny, pool_kind):
    """Deterministic mixed sequence: b_i=0, EOS-prone children (eos_id
    drawn from the live vocab so some child hits it), mixed lengths and
    budgets — free/in-use/reserved must balance after every step and the
    drain ledger must cross-check exactly."""
    _churn_once(tiny, pool_kind, lengths=(5, 9, 7, 6, 11),
                budgets=(2, 0, 3, 1, 2), eos_pick=7, chunk=4)


@pytest.mark.slow
@given(lengths=st.lists(st.integers(4, 12), min_size=1, max_size=5),
       budgets=st.lists(st.integers(0, 3), min_size=5, max_size=5),
       eos_pick=st.integers(1, 50), chunk=st.sampled_from([1, 4, 8]))
@settings(max_examples=8, deadline=None)
def test_pool_conservation_random_churn(tiny, lengths, budgets, eos_pick,
                                        chunk):
    """Hypothesis: arbitrary submit/EOS/b_i=0 sequences on the paged pool
    keep the ledger conserved at every step and balanced at drain."""
    _churn_once(tiny, "paged", lengths, budgets[:len(lengths)], eos_pick,
                chunk)
