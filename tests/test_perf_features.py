"""Tests for §Perf beyond-paper features: W8A16 quantization and the
mixed-precision / value-sharded mLSTM."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import build_model
from repro.models import modules as nn


def test_int8_linear_close_to_fp():
    key = jax.random.PRNGKey(0)
    pf = nn.init_linear(key, 64, 32, dtype=jnp.float32)
    # quantize the SAME weight for a faithful comparison
    w = pf["w"]
    amax = jnp.max(jnp.abs(w), axis=0) + 1e-8
    pq = {"w_q8": jnp.clip(jnp.round(w / amax * 127), -127, 127
                           ).astype(jnp.int8),
          "w_scale": (amax / 127)}
    x = jax.random.normal(jax.random.PRNGKey(1), (8, 64))
    yf = nn.linear(pf, x)
    yq = nn.linear(pq, x)
    # int8 per-channel error bound: ~ (amax/127) * sqrt(d_in) levels
    err = float(jnp.abs(yf - yq).max())
    scale = float(jnp.abs(yf).max())
    assert err < 0.05 * scale + 1e-3, (err, scale)


def test_int8_model_forward_finite_and_close():
    cfg = dataclasses.replace(get_config("qwen2-0.5b").reduced(),
                              dtype="float32")
    cfg_q = dataclasses.replace(cfg, quant_int8=True)
    build_model(cfg)
    mq = build_model(cfg_q)
    params_q = mq.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0,
                              cfg.vocab_size)
    lo, hid, _ = mq.forward(params_q, toks)
    assert np.isfinite(np.asarray(lo, np.float32)).all()
    # decode path too
    cache = mq.init_cache(2, 24)
    lo2, _, _ = mq.decode_step(params_q, toks[:, :1], cache,
                               jnp.zeros((2,), jnp.int32))
    assert np.isfinite(np.asarray(lo2, np.float32)).all()


def test_int8_moe_close_to_fp():
    """Expert-weight W8A16: quantized MoE output stays close to fp."""
    import jax.numpy as jnp

    from repro.models import moe as moe_mod

    cfg = dataclasses.replace(get_config("grok-1-314b").reduced(),
                              dtype="float32")
    cfg_q = dataclasses.replace(cfg, quant_int8=True)
    key = jax.random.PRNGKey(0)
    p = moe_mod.init_moe(key, cfg, jnp.float32)
    pq = moe_mod.init_moe(key, cfg_q, jnp.float32)   # same underlying draws
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, cfg.d_model)) * 0.5
    y, _ = moe_mod.moe_apply(p, x, cfg)
    yq, _ = moe_mod.moe_apply(pq, x, cfg_q)
    denom = float(jnp.abs(y).max()) + 1e-6
    assert float(jnp.abs(y - yq).max()) / denom < 0.1
    assert np.isfinite(np.asarray(yq)).all()


def test_mlstm_bf16_chunk_close_to_fp32():
    """The §Perf mixed-precision claim: bf16 matmuls with fp32 accumulation
    stay close to the all-fp32 reference."""
    from repro.models.xlstm import _mlstm_chunk

    rng = jax.random.PRNGKey(3)
    ks = jax.random.split(rng, 5)
    b, H, L, hd = 2, 2, 32, 64
    q = jax.random.normal(ks[0], (b, H, L, hd))
    k = jax.random.normal(ks[1], (b, H, L, hd))
    v = jax.random.normal(ks[2], (b, H, L, hd))
    li = jax.random.normal(ks[3], (b, H, L)) * 0.5
    lf = jax.random.normal(ks[4], (b, H, L)) * 0.5
    st = (jnp.zeros((b, H, hd, hd)), jnp.zeros((b, H, hd)),
          jnp.full((b, H), -1e30))
    h16, s16 = _mlstm_chunk(q, k, v, li, lf, st,
                            matmul_dtype=jnp.bfloat16)
    h32, s32 = _mlstm_chunk(q, k, v, li, lf, st,
                            matmul_dtype=jnp.float32)
    denom = float(jnp.abs(h32).max()) + 1e-6
    assert float(jnp.abs(h16 - h32).max()) / denom < 2e-2


def test_mlstm_chunked_equals_smaller_chunks():
    """Chunk size must not change the function (exact chunkwise form)."""
    import repro.models.xlstm as xl
    from repro.configs import get_config

    cfg = dataclasses.replace(get_config("xlstm-1.3b").reduced(),
                              dtype="float32")
    key = jax.random.PRNGKey(0)
    p = xl.init_mlstm(key, cfg, tp=1, dtype=jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 64, cfg.d_model))
    # exact in fp32
    y_a = xl.mlstm_mix(p, x, cfg, tp=1, chunk=64, matmul_dtype=jnp.float32)
    y_b = xl.mlstm_mix(p, x, cfg, tp=1, chunk=16, matmul_dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(y_a), np.asarray(y_b),
                               atol=2e-4, rtol=2e-4)
    # bf16 matmuls: chunk-boundary rounding only (loose bound)
    y_c = xl.mlstm_mix(p, x, cfg, tp=1, chunk=16)
    assert float(jnp.abs(y_c - y_a).max()) < 0.3


def test_mlstm_decode_matches_mix():
    """Recurrent decode reproduces the chunked-parallel forward, step by
    step (the prefill->decode handoff invariant)."""
    import repro.models.xlstm as xl

    cfg = dataclasses.replace(get_config("xlstm-1.3b").reduced(),
                              dtype="float32")
    p = xl.init_mlstm(jax.random.PRNGKey(0), cfg, tp=1, dtype=jnp.float32)
    b, s = 1, 12
    x = jax.random.normal(jax.random.PRNGKey(1), (b, s, cfg.d_model)) * 0.5
    # fp32 matmuls: the decode path is fp32, so compare like-for-like
    y_par = xl.mlstm_mix(p, x, cfg, tp=1, chunk=256,
                         matmul_dtype=jnp.float32)
    cache = xl.init_mlstm_cache(b, cfg, tp=1)
    outs = []
    for t in range(s):
        o, cache = xl.mlstm_decode(p, x[:, t:t + 1], cache, cfg, tp=1)
        outs.append(o)
    y_seq = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(y_seq), np.asarray(y_par),
                               atol=3e-2, rtol=3e-2)
