"""Per-architecture smoke tests (assignment deliverable f).

Each assigned architecture is instantiated as its REDUCED variant
(<= 4 layers in interleaved families, d_model <= 256, <= 4 experts) and runs
one forward pass + one train step on CPU, asserting output shapes and
finiteness.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config
from repro.models import build_model

ARCH_IDS = sorted(ARCHS)


def _inputs(cfg, key, batch=2, seq=16):
    ks = jax.random.split(key, 3)
    batch_d = {
        "tokens": jax.random.randint(ks[0], (batch, seq), 0, cfg.vocab_size),
        "labels": jax.random.randint(ks[1], (batch, seq), 0, cfg.vocab_size),
    }
    if cfg.family == "audio":
        batch_d["frames"] = jax.random.normal(
            ks[2], (batch, cfg.encoder.seq_len, cfg.d_model), jnp.float32)
    if cfg.family == "vlm":
        batch_d["patches"] = jax.random.normal(
            ks[2], (batch, 4, cfg.d_model), jnp.float32)
    return batch_d


@pytest.fixture(scope="module")
def rng():
    return jax.random.PRNGKey(0)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_shapes_and_finite(arch, rng):
    cfg = dataclasses.replace(get_config(arch).reduced(), dtype="float32")
    model = build_model(cfg)
    params = model.init(rng)
    batch = _inputs(cfg, rng)
    logits, hidden, aux = jax.jit(
        lambda p, b: model.forward(p, b["tokens"], frames=b.get("frames"),
                                   patches=b.get("patches")))(params, batch)
    s_total = batch["tokens"].shape[1] + (
        batch["patches"].shape[1] if "patches" in batch else 0)
    assert logits.shape == (2, s_total, cfg.vocab_size)
    assert hidden.shape == (2, s_total, cfg.d_model)
    assert np.isfinite(np.asarray(logits, np.float32)).all(), arch
    assert np.isfinite(float(aux))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_one_train_step_decreases_or_finite(arch, rng):
    from repro.optim import adamw_init, adamw_update

    cfg = dataclasses.replace(get_config(arch).reduced(), dtype="float32")
    model = build_model(cfg)
    params = model.init(rng)
    batch = _inputs(cfg, rng)

    @jax.jit
    def step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(model.loss_fn)(params, batch)
        params, opt_state = adamw_update(params, grads, opt_state, lr=1e-3)
        return params, opt_state, loss

    opt_state = adamw_init(params)
    params2, opt_state, loss0 = step(params, opt_state, batch)
    _, _, loss1 = step(params2, opt_state, batch)
    assert np.isfinite(float(loss0)) and np.isfinite(float(loss1)), arch
    # one step on the same batch should not blow up
    assert float(loss1) < float(loss0) + 1.0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_step_runs(arch, rng):
    cfg = dataclasses.replace(get_config(arch).reduced(), dtype="float32")
    model = build_model(cfg)
    params = model.init(rng)
    b, S = 2, 32
    cache = model.init_cache(b, S)
    if cfg.is_encdec:
        # fill cross-kv with zeros (stub); valid structurally
        pass
    token = jnp.zeros((b, 1), jnp.int32)
    pos = jnp.zeros((b,), jnp.int32)
    logits, hidden, new_cache = jax.jit(model.decode_step)(
        params, token, cache, pos)
    assert logits.shape == (b, 1, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits, np.float32)).all(), arch
    assert jax.tree.structure(new_cache) == jax.tree.structure(cache)
