"""Continuous-batching runtime: greedy equivalence vs the batch engine,
slot reuse/backfill, variable prompt lengths, facade parity, streaming
admission. Pool-agnostic behavior is parametrized over both KV backends;
slot-pool-specific mechanics (batched prefill metrics, alloc counts) pin
pool="slots". Paged-pool mechanics live in tests/test_paged_pool.py."""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import build_model
from repro.serving import (AdaptiveScheduler, ContinuousBatchingRuntime,
                           RequestState, ServingEngine)


@pytest.fixture(scope="module")
def tiny():
    cfg = dataclasses.replace(get_config("qwen2-0.5b").reduced(),
                              dtype="float32", n_layers=2)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


@pytest.mark.parametrize("pool", ["slots", "paged"])
def test_runtime_matches_batch_engine(tiny, pool):
    """Greedy continuous-batching output == batch ServingEngine.generate
    for the same budgets: every child token row is bitwise identical —
    for both KV backends."""
    cfg, model, params = tiny
    engine = ServingEngine(model, params, max_new=4, temperature=0.0)
    prompts = np.asarray(jax.random.randint(jax.random.PRNGKey(2), (3, 8),
                                            0, cfg.vocab_size))
    budgets = [2, 1, 3]
    sel = np.repeat(np.arange(3), budgets)
    ref = engine.generate(prompts[sel], n_samples=1, seed=0, temperature=0.0)

    rt = ContinuousBatchingRuntime(model, params, n_slots=6, max_len=13,
                                   max_new=4, temperature=0.0, seed=0,
                                   pool=pool, block_size=4)
    assert rt.pool_kind == pool
    ids = rt.submit_batch(prompts, budgets=budgets)
    rt.drain()
    off = 0
    for rid, b in zip(ids, budgets):
        r = rt.result(rid)
        assert r.state == RequestState.DONE and len(r.children) == b
        for c in r.children:
            np.testing.assert_array_equal(np.asarray(c.tokens),
                                          ref.tokens[off])
            off += 1
    # cost accounting: every prompt token prefilled once, every decode
    # token counted once, in both pools
    assert rt.metrics.prefill_tokens == 3 * 8
    assert rt.metrics.decode_tokens == sum(budgets) * 4
    if pool == "slots":
        assert rt.metrics.prefill_calls == 1    # one batched prefill pass


def test_slot_reuse_and_backfill(tiny):
    """More children than slots: the pool must recycle slots mid-flight
    and still produce exact outputs."""
    cfg, model, params = tiny
    engine = ServingEngine(model, params, max_new=4, temperature=0.0)
    prompts = np.asarray(jax.random.randint(jax.random.PRNGKey(3), (3, 8),
                                            0, cfg.vocab_size))
    one = engine.generate(prompts, n_samples=1, seed=0, temperature=0.0)

    rt = ContinuousBatchingRuntime(model, params, n_slots=2, max_len=13,
                                   max_new=4, temperature=0.0, seed=0,
                                   pool="slots")
    ids = rt.submit_batch(prompts, budgets=[2, 2, 2])
    rt.drain()
    for i, rid in enumerate(ids):
        for c in rt.result(rid).children:      # greedy: children identical
            np.testing.assert_array_equal(np.asarray(c.tokens), one.tokens[i])
    assert rt.pool.alloc_count == 6            # 6 children through 2 slots
    assert rt.pool.n_free == 2                 # all reclaimed
    assert rt.metrics.decode_tokens == 6 * 4
    assert rt.metrics.ticks >= 3 * 4           # >= ceil(6/2) waves
    assert 0.9 < rt.metrics.occupancy <= 1.0   # backfill keeps slots busy


def test_slot_pool_heap_free_list_and_double_release(tiny):
    """SlotKVPool allocates the lowest free slot via the heap and raises
    (not asserts) on double release / bad ids."""
    from repro.serving import SlotKVPool
    cfg, model, params = tiny
    pool = SlotKVPool(model, 4, 8)
    a, b = pool.alloc(), pool.alloc()
    assert (a, b) == (0, 1)
    pool.release(a)
    assert pool.alloc() == 0                   # lowest-first, heap order
    pool.release(b)                            # legitimate release: no raise
    with pytest.raises(RuntimeError, match="double release"):
        pool.release(b)
    with pytest.raises(RuntimeError, match="bad slot"):
        pool.release(99)


@pytest.mark.slow
def test_variable_prompt_lengths_interleave(tiny):
    """Different-length prompts decode concurrently in one pool; each
    request matches its own single-prompt batch-engine run."""
    cfg, model, params = tiny
    engine = ServingEngine(model, params, max_new=3, temperature=0.0)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, (L,)).astype(np.int32)
               for L in (5, 8, 11)]
    rt = ContinuousBatchingRuntime(model, params, n_slots=3, max_len=16,
                                   max_new=3, temperature=0.0, seed=0,
                                   pool="slots")
    ids = [rt.submit(p, budget=1) for p in prompts]
    rt.drain()
    for p, rid in zip(prompts, ids):
        want = engine.generate(p[None], n_samples=1, seed=0,
                               temperature=0.0).tokens[0]
        np.testing.assert_array_equal(rt.result(rid).response, want)
    # all three decoded in the same ticks (no per-length barrier)
    assert rt.metrics.ticks == 3
    assert rt.metrics.occupancy == 1.0


@pytest.mark.parametrize("pool", ["slots", "paged"])
def test_scheduler_backends_agree(tiny, pool):
    """The runtime facade (either KV backend) and the (patched
    single-prefill) batch path give identical responses/budgets under
    greedy decoding."""
    from repro.core import AdaptivePolicy
    from repro.core.difficulty import init_mlp_probe

    cfg, model, params = tiny
    engine = ServingEngine(model, params, max_new=4, temperature=0.0)
    probe = init_mlp_probe(jax.random.PRNGKey(4), cfg.d_model, 1)
    policy = AdaptivePolicy(probe_params=probe, kind="bce", b_max=4, b_min=1)
    reward = lambda q, rows: np.asarray([float(r.sum() % 97) for r in rows])
    prompts = np.asarray(jax.random.randint(jax.random.PRNGKey(5), (5, 8),
                                            0, cfg.vocab_size))
    outs = {}
    for backend in ("runtime", "batch"):
        sched = AdaptiveScheduler(engine, policy, reward, seed=0,
                                  backend=backend, n_slots=4, pool=pool,
                                  block_size=4)
        outs[backend] = sched.serve_batch(list(range(5)), prompts,
                                          avg_budget=2.0)
    a, b = outs["runtime"], outs["batch"]
    np.testing.assert_array_equal(a.budgets, b.budgets)
    assert a.total_samples == b.total_samples
    assert a.generated_tokens == b.generated_tokens
    assert a.prefill_tokens == b.prefill_tokens == 5 * 8  # single prefill
    np.testing.assert_allclose(a.rewards, b.rewards)
    for ra, rb in zip(a.responses, b.responses):
        np.testing.assert_array_equal(ra, rb)
    assert a.metrics is not None and a.metrics["occupancy"] > 0


def test_streaming_budget_admission(tiny):
    """budget_fn resolves budgets at admission (price-dual allocation):
    requests flow QUEUED -> DONE without any batch-level allocate call.
    Runs on the default (paged) pool, where the resolved budget is also
    gated on free blocks."""
    from repro.core import AdaptivePolicy
    from repro.core.difficulty import init_mlp_probe

    cfg, model, params = tiny
    probe = init_mlp_probe(jax.random.PRNGKey(6), cfg.d_model, 1)
    policy = AdaptivePolicy(probe_params=probe, kind="bce", b_max=4, b_min=1)
    engine = ServingEngine(model, params, max_new=2, temperature=0.0)
    prompts = np.asarray(jax.random.randint(jax.random.PRNGKey(7), (6, 8),
                                            0, cfg.vocab_size))
    # calibrate the price on the first half, stream the second half
    calib_hidden = engine.probe_features(prompts[:3])
    price = policy.calibrate_price(calib_hidden, avg_budget=2.0)
    budget_fn = lambda req, hidden: int(
        policy.allocate_streaming(hidden, price)[0])
    rt = ContinuousBatchingRuntime(model, params, n_slots=4, max_len=11,
                                   max_new=2, temperature=0.0, seed=0,
                                   budget_fn=budget_fn)
    assert rt.pool_kind == "paged"             # the default backend
    ids = rt.submit_batch(prompts[3:])
    rt.drain()
    for rid in ids:
        r = rt.result(rid)
        assert r.state == RequestState.DONE
        assert 1 <= r.budget <= 4
        assert all(len(c.tokens) == 2 for c in r.children)


def test_prefill_window_bounds_stash_rows(tiny):
    """A deep backlog must not stash one prefill cache per queued request:
    step()'s auto-prefill is throttled to prefill_window outstanding
    stash cache *rows*, and outputs are unaffected."""
    cfg, model, params = tiny
    engine = ServingEngine(model, params, max_new=2, temperature=0.0)
    prompts = np.asarray(jax.random.randint(jax.random.PRNGKey(9), (8, 6),
                                            0, cfg.vocab_size))
    one = engine.generate(prompts, n_samples=1, seed=0, temperature=0.0)
    rt = ContinuousBatchingRuntime(model, params, n_slots=2, max_len=9,
                                   max_new=2, temperature=0.0, seed=0,
                                   prefill_window=2, pool="slots",
                                   budget_fn=lambda r, h: 1)
    ids = rt.submit_batch(prompts)
    max_rows = 0
    while rt.pending():
        rt.step()
        max_rows = max(max_rows, rt._window_used())
    assert max_rows <= 2
    assert rt._window_used() == 0 and not rt._groups   # all released
    for i, rid in enumerate(ids):
        np.testing.assert_array_equal(rt.result(rid).response, one.tokens[i])


def test_stash_rows_pinned_until_group_dies(tiny):
    """S3 regression: a same-length group's prefill cache has batch dim =
    group size and only frees when the LAST member drops its stash, so
    the window must keep counting every row until then — per-request
    decrements under-throttled memory on large groups."""
    cfg, model, params = tiny
    prompts = np.asarray(jax.random.randint(jax.random.PRNGKey(10), (4, 6),
                                            0, cfg.vocab_size))
    rt = ContinuousBatchingRuntime(model, params, n_slots=2, max_len=9,
                                   max_new=2, temperature=0.0, seed=0,
                                   pool="slots",
                                   budget_fn=lambda r, h: 2)
    rt.submit_batch(prompts)
    rt.prefill_queued()                        # one same-length group of 4
    assert len(rt._groups) == 1
    assert rt._window_used() == 4              # 4 pinned cache rows
    rt.step()                                  # admits request 0's fan-out
    assert rt.requests[0].stash is None        # member dropped its stash...
    assert rt._window_used() == 4              # ...but the cache is alive
    rt.drain()
    assert not rt._groups and rt._window_used() == 0


def test_drain_not_stalled_by_budget_deferred_requests(tiny):
    """S1 regression: requests parked on an un-called set_budget() used to
    saturate the prefill window, so later arrivals could never prefill
    and drain() raised a spurious RuntimeError. Deferred stashes are now
    excluded from window accounting."""
    cfg, model, params = tiny
    prompts = np.asarray(jax.random.randint(jax.random.PRNGKey(11), (4, 6),
                                            0, cfg.vocab_size))
    rt = ContinuousBatchingRuntime(model, params, n_slots=2, max_len=9,
                                   max_new=2, temperature=0.0, seed=0,
                                   prefill_window=1, pool="slots")
    # no budget, no budget_fn: every request parks in PREFILL (deferred)
    ids = [rt.submit(p) for p in prompts]
    rt.drain()                                 # must NOT raise
    for rid in ids:
        r = rt.result(rid)
        assert r.state == RequestState.PREFILL and r.hidden is not None
    # late budgets still run to completion
    for rid in ids:
        rt.set_budget(rid, 1)
    rt.drain()
    assert all(rt.result(i).state == RequestState.DONE for i in ids)


def test_stall_report_names_blockers(tiny):
    """A genuine stall must name what is stuck instead of a bare id list:
    a fan-out that can never fit reports the blocking request and the
    pool's free resources."""
    cfg, model, params = tiny
    prompts = np.asarray(jax.random.randint(jax.random.PRNGKey(12), (1, 6),
                                            0, cfg.vocab_size))
    rt = ContinuousBatchingRuntime(model, params, n_slots=2, max_len=9,
                                   max_new=2, temperature=0.0, seed=0,
                                   pool="slots")
    rid = rt.submit(prompts[0], budget=1)
    rt.prefill_queued()
    # simulate a wedged pool: every slot leaked
    rt.pool.alloc(), rt.pool.alloc()
    with pytest.raises(RuntimeError, match="fan-out blocked for request "
                                           f"{rid}"):
        rt.drain()


@pytest.mark.parametrize("pool", ["slots", "paged"])
def test_b0_default_response(tiny, pool):
    """S2 regression: budget 0 must produce the documented default
    response (empty token row, reward 0.0) and count in the metrics —
    r.response used to stay None."""
    cfg, model, params = tiny
    prompts = np.asarray(jax.random.randint(jax.random.PRNGKey(13), (2, 6),
                                            0, cfg.vocab_size))
    rt = ContinuousBatchingRuntime(model, params, n_slots=2, max_len=9,
                                   max_new=2, temperature=0.0, seed=0,
                                   pool=pool, block_size=4)
    ra = rt.submit(prompts[0], budget=0)
    rb = rt.submit(prompts[1], budget=2)
    rt.drain()
    r = rt.result(ra)
    assert r.state == RequestState.DONE
    np.testing.assert_array_equal(r.response, np.zeros((0,), np.int32))
    assert r.reward == 0.0
    assert rt.metrics.default_responses == 1
    assert rt.result(rb).response is not None
    assert len(rt.result(rb).response) == 2


@pytest.mark.parametrize("pool", ["slots", "paged"])
def test_eos_early_termination(tiny, pool):
    """S4: a child that samples EOS stops immediately (freeing its slot /
    blocks), post-EOS tokens never reach the reranker, and the savings
    are metered."""
    cfg, model, params = tiny
    prompts = np.asarray(jax.random.randint(jax.random.PRNGKey(14), (1, 6),
                                            0, cfg.vocab_size))
    # find the greedy continuation, then declare its second token EOS
    probe_rt = ContinuousBatchingRuntime(model, params, n_slots=1,
                                         max_len=14, max_new=6,
                                         temperature=0.0, seed=0, pool=pool,
                                         block_size=4)
    pid = probe_rt.submit(prompts[0], budget=1)
    probe_rt.drain()
    full = [int(t) for t in probe_rt.result(pid).response]
    assert len(full) == 6
    eos = full[1]
    want = full[: full.index(eos) + 1]         # up to & including first EOS

    rt = ContinuousBatchingRuntime(model, params, n_slots=1, max_len=14,
                                   max_new=6, temperature=0.0, seed=0,
                                   pool=pool, block_size=4, eos_id=eos)
    rid = rt.submit(prompts[0], budget=1)
    rt.drain()
    r = rt.result(rid)
    got = list(r.response)
    assert got == want                         # truncated at EOS, EOS kept
    assert r.children[0].eos
    assert rt.metrics.eos_terminated == 1
    assert rt.metrics.eos_saved_tokens == 6 - len(want)
    # the early stop really saved decode work
    assert rt.metrics.decode_tokens < 6
    if pool == "paged":
        # blocks freed immediately — only the radix prefix cache's
        # published prompt blocks (a cache, evictable) remain alive
        held = rt.radix.held_blocks if rt.radix is not None else 0
        assert rt.pool.blocks_in_use == held
        rt.assert_ledger_balanced()


def test_per_request_max_new_staggered_retirement(tiny):
    """Children with different max_new retire at different ticks; freed
    slots backfill pending fan-out immediately (default paged pool)."""
    cfg, model, params = tiny
    prompts = np.asarray(jax.random.randint(jax.random.PRNGKey(8), (2, 6),
                                            0, cfg.vocab_size))
    rt = ContinuousBatchingRuntime(model, params, n_slots=2, max_len=16,
                                   max_new=8, temperature=0.0, seed=0)
    ra = rt.submit(prompts[0], budget=2, max_new=2)
    rb = rt.submit(prompts[1], budget=2, max_new=6)
    rt.drain()
    assert [len(c.tokens) for c in rt.result(ra).children] == [2, 2]
    assert [len(c.tokens) for c in rt.result(rb).children] == [6, 6]
    # total active-slot tokens: 2*2 + 2*6
    assert rt.metrics.decode_tokens == 16
