"""Continuous-batching runtime: greedy equivalence vs the batch engine,
slot reuse/backfill, variable prompt lengths, facade parity, streaming
admission."""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import build_model
from repro.serving import (AdaptiveScheduler, ContinuousBatchingRuntime,
                           RequestState, ServingEngine)


@pytest.fixture(scope="module")
def tiny():
    cfg = dataclasses.replace(get_config("qwen2-0.5b").reduced(),
                              dtype="float32", n_layers=2)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def test_runtime_matches_batch_engine(tiny):
    """Greedy continuous-batching output == batch ServingEngine.generate
    for the same budgets: every child token row is bitwise identical."""
    cfg, model, params = tiny
    engine = ServingEngine(model, params, max_new=4, temperature=0.0)
    prompts = np.asarray(jax.random.randint(jax.random.PRNGKey(2), (3, 8),
                                            0, cfg.vocab_size))
    budgets = [2, 1, 3]
    sel = np.repeat(np.arange(3), budgets)
    ref = engine.generate(prompts[sel], n_samples=1, seed=0, temperature=0.0)

    rt = ContinuousBatchingRuntime(model, params, n_slots=6, max_len=13,
                                   max_new=4, temperature=0.0, seed=0)
    ids = rt.submit_batch(prompts, budgets=budgets)
    rt.drain()
    off = 0
    for rid, b in zip(ids, budgets):
        r = rt.result(rid)
        assert r.state == RequestState.DONE and len(r.children) == b
        for c in r.children:
            np.testing.assert_array_equal(np.asarray(c.tokens),
                                          ref.tokens[off])
            off += 1
    # cost accounting: one prefill, every decode token counted once
    assert rt.metrics.prefill_tokens == 3 * 8
    assert rt.metrics.prefill_calls == 1
    assert rt.metrics.decode_tokens == sum(budgets) * 4


def test_slot_reuse_and_backfill(tiny):
    """More children than slots: the pool must recycle slots mid-flight
    and still produce exact outputs."""
    cfg, model, params = tiny
    engine = ServingEngine(model, params, max_new=4, temperature=0.0)
    prompts = np.asarray(jax.random.randint(jax.random.PRNGKey(3), (3, 8),
                                            0, cfg.vocab_size))
    one = engine.generate(prompts, n_samples=1, seed=0, temperature=0.0)

    rt = ContinuousBatchingRuntime(model, params, n_slots=2, max_len=13,
                                   max_new=4, temperature=0.0, seed=0)
    ids = rt.submit_batch(prompts, budgets=[2, 2, 2])
    rt.drain()
    for i, rid in enumerate(ids):
        for c in rt.result(rid).children:      # greedy: children identical
            np.testing.assert_array_equal(np.asarray(c.tokens), one.tokens[i])
    assert rt.pool.alloc_count == 6            # 6 children through 2 slots
    assert rt.pool.n_free == 2                 # all reclaimed
    assert rt.metrics.decode_tokens == 6 * 4
    assert rt.metrics.ticks >= 3 * 4           # >= ceil(6/2) waves
    assert 0.9 < rt.metrics.occupancy <= 1.0   # backfill keeps slots busy


def test_variable_prompt_lengths_interleave(tiny):
    """Different-length prompts decode concurrently in one pool; each
    request matches its own single-prompt batch-engine run."""
    cfg, model, params = tiny
    engine = ServingEngine(model, params, max_new=3, temperature=0.0)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, (L,)).astype(np.int32)
               for L in (5, 8, 11)]
    rt = ContinuousBatchingRuntime(model, params, n_slots=3, max_len=16,
                                   max_new=3, temperature=0.0, seed=0)
    ids = [rt.submit(p, budget=1) for p in prompts]
    rt.drain()
    for p, rid in zip(prompts, ids):
        want = engine.generate(p[None], n_samples=1, seed=0,
                               temperature=0.0).tokens[0]
        np.testing.assert_array_equal(rt.result(rid).response, want)
    # all three decoded in the same ticks (no per-length barrier)
    assert rt.metrics.ticks == 3
    assert rt.metrics.occupancy == 1.0


def test_scheduler_backends_agree(tiny):
    """The runtime facade and the (patched single-prefill) batch path give
    identical responses/budgets under greedy decoding."""
    from repro.core import AdaptivePolicy
    from repro.core.difficulty import init_mlp_probe

    cfg, model, params = tiny
    engine = ServingEngine(model, params, max_new=4, temperature=0.0)
    probe = init_mlp_probe(jax.random.PRNGKey(4), cfg.d_model, 1)
    policy = AdaptivePolicy(probe_params=probe, kind="bce", b_max=4, b_min=1)
    reward = lambda q, rows: np.asarray([float(r.sum() % 97) for r in rows])
    prompts = np.asarray(jax.random.randint(jax.random.PRNGKey(5), (5, 8),
                                            0, cfg.vocab_size))
    outs = {}
    for backend in ("runtime", "batch"):
        sched = AdaptiveScheduler(engine, policy, reward, seed=0,
                                  backend=backend, n_slots=4)
        outs[backend] = sched.serve_batch(list(range(5)), prompts,
                                          avg_budget=2.0)
    a, b = outs["runtime"], outs["batch"]
    np.testing.assert_array_equal(a.budgets, b.budgets)
    assert a.total_samples == b.total_samples
    assert a.generated_tokens == b.generated_tokens
    assert a.prefill_tokens == b.prefill_tokens == 5 * 8  # single prefill
    np.testing.assert_allclose(a.rewards, b.rewards)
    for ra, rb in zip(a.responses, b.responses):
        np.testing.assert_array_equal(ra, rb)
    assert a.metrics is not None and a.metrics["occupancy"] > 0


def test_streaming_budget_admission(tiny):
    """budget_fn resolves budgets at admission (price-dual allocation):
    requests flow QUEUED -> DONE without any batch-level allocate call."""
    from repro.core import AdaptivePolicy
    from repro.core.difficulty import init_mlp_probe

    cfg, model, params = tiny
    probe = init_mlp_probe(jax.random.PRNGKey(6), cfg.d_model, 1)
    policy = AdaptivePolicy(probe_params=probe, kind="bce", b_max=4, b_min=1)
    engine = ServingEngine(model, params, max_new=2, temperature=0.0)
    prompts = np.asarray(jax.random.randint(jax.random.PRNGKey(7), (6, 8),
                                            0, cfg.vocab_size))
    # calibrate the price on the first half, stream the second half
    calib_hidden = engine.probe_features(prompts[:3])
    price = policy.calibrate_price(calib_hidden, avg_budget=2.0)
    budget_fn = lambda req, hidden: int(
        policy.allocate_streaming(hidden, price)[0])
    rt = ContinuousBatchingRuntime(model, params, n_slots=4, max_len=11,
                                   max_new=2, temperature=0.0, seed=0,
                                   budget_fn=budget_fn)
    ids = rt.submit_batch(prompts[3:])
    rt.drain()
    for rid in ids:
        r = rt.result(rid)
        assert r.state == RequestState.DONE
        assert 1 <= r.budget <= 4
        assert all(len(c.tokens) == 2 for c in r.children)


def test_prefill_window_bounds_stashes(tiny):
    """A deep backlog must not stash one prefill cache per queued request:
    step()'s auto-prefill is throttled to prefill_window outstanding
    stashes, and outputs are unaffected."""
    cfg, model, params = tiny
    engine = ServingEngine(model, params, max_new=2, temperature=0.0)
    prompts = np.asarray(jax.random.randint(jax.random.PRNGKey(9), (8, 6),
                                            0, cfg.vocab_size))
    one = engine.generate(prompts, n_samples=1, seed=0, temperature=0.0)
    rt = ContinuousBatchingRuntime(model, params, n_slots=2, max_len=9,
                                   max_new=2, temperature=0.0, seed=0,
                                   prefill_window=2,
                                   budget_fn=lambda r, h: 1)
    ids = rt.submit_batch(prompts)
    max_stashed = 0
    while rt.pending():
        rt.step()
        max_stashed = max(max_stashed, rt._stashed)
    assert max_stashed <= 2
    assert rt._stashed == 0                    # all stashes released
    for i, rid in enumerate(ids):
        np.testing.assert_array_equal(rt.result(rid).response, one.tokens[i])


def test_per_request_max_new_staggered_retirement(tiny):
    """Children with different max_new retire at different ticks; freed
    slots backfill pending fan-out immediately."""
    cfg, model, params = tiny
    prompts = np.asarray(jax.random.randint(jax.random.PRNGKey(8), (2, 6),
                                            0, cfg.vocab_size))
    rt = ContinuousBatchingRuntime(model, params, n_slots=2, max_len=16,
                                   max_new=8, temperature=0.0, seed=0)
    ra = rt.submit(prompts[0], budget=2, max_new=2)
    rb = rt.submit(prompts[1], budget=2, max_new=6)
    rt.drain()
    assert [len(c.tokens) for c in rt.result(ra).children] == [2, 2]
    assert [len(c.tokens) for c in rt.result(rb).children] == [6, 6]
    # total active-slot tokens: 2*2 + 2*6
    assert rt.metrics.decode_tokens == 16
