"""Substrate tests: optimizer reference equality, checkpoint roundtrip,
data pipeline determinism, reward model training, schedules."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import load_checkpoint, save_checkpoint
from repro.data import ArithTaskGen, LMDataPipeline, PipelineConfig
from repro.data.tasks import ArithProblem, decode_digits
from repro.optim import (adamw_init, adamw_update, clip_by_global_norm,
                         linear_warmup_cosine)


def test_adamw_matches_manual_reference():
    rng = np.random.default_rng(0)
    p = {"w": jnp.asarray(rng.normal(size=(4, 3)), jnp.float32)}
    g = {"w": jnp.asarray(rng.normal(size=(4, 3)), jnp.float32)}
    st = adamw_init(p)
    lr, b1, b2, eps, wd = 1e-2, 0.9, 0.95, 1e-8, 0.1
    p1, st1 = adamw_update(p, g, st, lr=lr, b1=b1, b2=b2, eps=eps,
                           weight_decay=wd)
    # manual first-step math: mhat = g, vhat = g^2
    gg = np.asarray(g["w"])
    want = np.asarray(p["w"]) - lr * (gg / (np.sqrt(gg * gg) + eps)
                                      + wd * np.asarray(p["w"]))
    np.testing.assert_allclose(np.asarray(p1["w"]), want, atol=1e-6)
    assert int(st1.step) == 1


def test_grad_clip():
    g = {"a": jnp.ones((10,)) * 10.0}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert float(norm) > 1.0
    n2 = float(jnp.linalg.norm(clipped["a"]))
    assert abs(n2 - 1.0) < 1e-5


def test_schedule_shapes():
    lrs = [float(linear_warmup_cosine(jnp.float32(s), base_lr=1.0,
                                      warmup_steps=10, total_steps=100))
           for s in range(0, 100, 10)]
    assert lrs[0] < lrs[1]             # warmup rises
    assert lrs[-1] < lrs[1]            # cosine decays


def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "b": {"c": jnp.ones((4,), jnp.bfloat16)}}
    path = str(tmp_path / "ckpt_10")
    save_checkpoint(path, tree, step=10, extra={"note": "x"})
    back = load_checkpoint(path, jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree))
    assert jax.tree.structure(back) == jax.tree.structure(tree)
    np.testing.assert_array_equal(np.asarray(back["a"]),
                                  np.asarray(tree["a"]))
    assert back["b"]["c"].dtype == jnp.bfloat16


def test_pipeline_deterministic_and_shaped():
    pipe = LMDataPipeline(PipelineConfig(global_batch=4, seq_len=32, seed=7))
    b1 = pipe.batch_at(3)
    b2 = pipe.batch_at(3)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert b1["tokens"].shape == (4, 32)
    # labels are next-token shifted
    np.testing.assert_array_equal(b1["tokens"][:, 1:], b1["labels"][:, :-1])


def test_arith_task_verifier():
    p = ArithProblem(a=123, b=456, op="+", digits=3)
    assert p.answer == 579
    assert p.check(p.answer_tokens())
    assert not p.check(ArithProblem(a=1, b=1, op="+", digits=3)
                       .answer_tokens())
    assert decode_digits(p.answer_tokens()) == 579


def test_task_difficulty_gradient():
    """More digits => larger answer space => trivially harder for a random
    guesser; the generator must expose the full difficulty range."""
    gen = ArithTaskGen(max_digits=6, seed=0)
    probs = gen.sample(200)
    digits = np.asarray([p.digits for p in probs])
    assert digits.min() == 1 and digits.max() == 6


def test_reward_model_trains():
    import dataclasses

    from repro.configs import STANDINS
    from repro.rewards import RewardModel

    cfg = dataclasses.replace(STANDINS["reward-tiny"], n_layers=1,
                              d_model=64, n_heads=2, n_kv_heads=2, d_ff=128,
                              dtype="float32")
    rm = RewardModel(cfg)
    rng = np.random.default_rng(0)
    toks = rng.integers(4, 14, size=(64, 12))
    # target: fraction of token-7 occurrences (learnable from content)
    tgt = (toks == 7).mean(axis=1) * 4 - 1
    params, hist = rm.train(jax.random.PRNGKey(0), toks, tgt, steps=150)
    assert hist[-1][1] < hist[0][1] * 0.8     # loss went down
