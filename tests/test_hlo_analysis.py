"""HLO analyzer: FLOP counting with loop multipliers, on a controlled jit."""
import jax
import jax.numpy as jnp

from repro.launch.hlo_analysis import analyze, parse_hlo


def _hlo_of(fn, *args):
    return jax.jit(fn).lower(*args).compile().as_text()


def test_single_dot_flops():
    a = jnp.zeros((64, 128), jnp.float32)
    b = jnp.zeros((128, 32), jnp.float32)
    r = analyze(_hlo_of(lambda a, b: a @ b, a, b))
    assert r["flops"] == 2 * 64 * 128 * 32


def test_scan_multiplies_flops():
    a = jnp.zeros((32, 32), jnp.float32)

    def f(a):
        def body(x, _):
            return x @ a, None
        x, _ = jax.lax.scan(body, a, None, length=7)
        return x

    r = analyze(_hlo_of(f, a))
    # 7 iterations of one 32^3 matmul
    assert r["flops"] == 7 * 2 * 32 ** 3


def test_nested_scan_multiplies():
    a = jnp.zeros((16, 16), jnp.float32)

    def f(a):
        def outer(x, _):
            def inner(y, _):
                return y @ a, None
            y, _ = jax.lax.scan(inner, x, None, length=3)
            return y, None
        x, _ = jax.lax.scan(outer, a, None, length=5)
        return x

    r = analyze(_hlo_of(f, a))
    assert r["flops"] == 5 * 3 * 2 * 16 ** 3


def test_parse_hlo_computations():
    hlo = _hlo_of(lambda x: jnp.sin(x) @ x, jnp.zeros((8, 8)))
    comps = parse_hlo(hlo)
    assert any(c.is_entry for c in comps.values())
    assert sum(len(c.ops) for c in comps.values()) > 0
