"""Horizon-fused decode: multi-tick lax.scan with on-device EOS/budget
masking and one host sync per horizon.

The hard contract: greedy decode through `_paged_horizon_tick` is
token-bitwise identical to the per-token tick for every horizon width —
including mid-horizon EOS freezes, children with different max_new, and
the prefix-cache hit path — while host syncs per generated token drop
from ~1 to ~1/H. Plus the PR's satellites: `PagedKVPool.preallocate`
ledger discipline under churn, batched same-tick fan-out admission,
radix-aware admission ordering, and the `submit_batch` max_new fix.
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import build_model
from repro.serving import (ContinuousBatchingRuntime, PagedKVPool,
                           ServingEngine)


@pytest.fixture(scope="module")
def tiny():
    cfg = dataclasses.replace(get_config("qwen2-0.5b").reduced(),
                              dtype="float32", n_layers=2)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _clean(rt: ContinuousBatchingRuntime):
    pool = rt.pool
    rt.assert_ledger_balanced()
    held = rt.radix.held_blocks if rt.radix is not None else 0
    assert pool.blocks_in_use == held
    assert pool.n_free_slots == pool.n_slots
    assert pool._reserved == 0


def _run(model, params, prompts, budgets, *, horizon, max_new=6,
         temperature=0.0, eos_id=None, n_slots=4, max_len=16,
         per_max_new=None, **kw):
    rt = ContinuousBatchingRuntime(
        model, params, n_slots=n_slots, max_len=max_len, max_new=max_new,
        temperature=temperature, seed=0, pool="paged", block_size=4,
        eos_id=eos_id, horizon=horizon, **kw)
    ids = [rt.submit(p, budget=b,
                     max_new=None if per_max_new is None else per_max_new[i])
           for i, (p, b) in enumerate(zip(prompts, budgets))]
    rt.drain()
    rows = [[list(c.tokens) for c in rt.result(i).children] for i in ids]
    _clean(rt)
    return rt, rows


def test_horizon_width_invariance(tiny):
    """H in {1, 3, 8}: bitwise-equal greedy outputs on a mixed-length,
    mixed-budget workload, and equal to the batch engine. H=1 is the
    per-token tick (fusion disabled), so this pins the fused scan to the
    unfused reference exactly."""
    cfg, model, params = tiny
    engine = ServingEngine(model, params, max_new=6, temperature=0.0)
    rng = np.random.default_rng(2)
    prompts = [rng.integers(0, cfg.vocab_size, (L,)).astype(np.int32)
               for L in (5, 9, 7)]
    budgets = [2, 1, 3]
    outs, syncs = {}, {}
    for H in (1, 3, 8):
        rt, outs[H] = _run(model, params, prompts, budgets, horizon=H)
        syncs[H] = rt.metrics.host_syncs
        assert (rt.metrics.horizon_ticks > 0) == (H > 1)
    assert outs[1] == outs[3] == outs[8]
    assert syncs[8] <= syncs[3] < syncs[1]     # fewer dispatch round-trips
    for i, p in enumerate(prompts):
        want = engine.generate(p[None], n_samples=1, seed=0,
                               temperature=0.0).tokens[0]
        for row in outs[8][i]:
            np.testing.assert_array_equal(row, want)


def test_horizon_mid_eos_freezes_slot(tiny):
    """A child that samples EOS mid-horizon must stop emitting inside the
    scan (frozen by its on-device remaining counter): outputs, EOS
    metering, and decode-token savings all match the per-token tick."""
    cfg, model, params = tiny
    rng = np.random.default_rng(14)
    # greedy on the untrained tiny model fixates on one token, so mid-
    # stream EOS needs a hot (T=50) sampled stream: find a token whose FIRST
    # occurrence is at index >= 1 — EOS then fires inside a horizon, not
    # at admission (the scan's remaining counter must freeze the slot)
    eos = prompt = None
    for _ in range(20):
        cand = rng.integers(0, cfg.vocab_size, (6,)).astype(np.int32)
        _, rows = _run(model, params, [cand], [1], horizon=1,
                       temperature=50.0)
        full = rows[0][0]
        fresh = [t for i, t in enumerate(full) if t not in full[:i] and i >= 1]
        if fresh:
            prompt, eos = cand, fresh[0]
            break
    assert eos is not None, "no usable EOS token found"
    r1, a = _run(model, params, [prompt], [2], horizon=1, eos_id=eos,
                 temperature=50.0)
    r8, b = _run(model, params, [prompt], [2], horizon=8, eos_id=eos,
                 temperature=50.0)
    assert a == b
    assert any(row[-1] == eos and len(row) < 6 for row in a[0])  # truncated
    assert r8.metrics.eos_terminated >= 1
    for m in ("eos_terminated", "eos_saved_tokens", "decode_tokens"):
        assert getattr(r1.metrics, m) == getattr(r8.metrics, m)
    assert r8.metrics.decode_tokens < 2 * 6            # savings are real


def test_horizon_children_with_different_max_new(tiny):
    """H = min(horizon, min remaining): staggered budgets retire at
    different horizons and short children never overshoot max_new."""
    cfg, model, params = tiny
    rng = np.random.default_rng(8)
    prompts = [rng.integers(0, cfg.vocab_size, (L,)).astype(np.int32)
               for L in (6, 5)]
    outs = {}
    for H in (1, 3, 8):
        rt, outs[H] = _run(model, params, prompts, [2, 2], horizon=H,
                           max_new=7, per_max_new=[2, 7])
    assert outs[1] == outs[3] == outs[8]
    assert [len(r) for r in outs[8][0]] == [2, 2]
    assert [len(r) for r in outs[8][1]] == [7, 7]


def test_horizon_sampling_parity(tiny):
    """Per-child fold_in RNG streams survive fusion: temperature>0
    sampling through the scan matches the per-token tick token-for-token
    (same split/categorical sequence per executed step)."""
    cfg, model, params = tiny
    rng = np.random.default_rng(5)
    prompts = [rng.integers(0, cfg.vocab_size, (6,)).astype(np.int32)]
    _, a = _run(model, params, prompts, [3], horizon=1, temperature=1.0)
    _, b = _run(model, params, prompts, [3], horizon=8, temperature=1.0)
    assert a == b


def test_horizon_one_sync_per_horizon(tiny):
    """Decode-heavy single stream: the per-token tick pays one blocking
    sync per generated token; the fused path pays one per horizon —
    decode syncs drop to <= 1/H per token and total syncs collapse to a
    handful (prefill chunks + one admission + the horizons)."""
    cfg, model, params = tiny
    rng = np.random.default_rng(6)
    prompts = [rng.integers(0, cfg.vocab_size, (5,)).astype(np.int32)]
    H, mn = 8, 33
    r1, a = _run(model, params, prompts, [1], horizon=1, max_new=mn,
                 max_len=40)
    rh, b = _run(model, params, prompts, [1], horizon=H, max_new=mn,
                 max_len=40)
    assert a == b
    # per-token path: every one of the mn-1 decode ticks blocks once
    assert r1.metrics.host_syncs >= mn - 1
    assert r1.metrics.syncs_per_token > 0.9            # ~1 per token
    # fused path: ceil(32/8) = 4 horizon syncs on the decode path ...
    assert rh.metrics.horizon_ticks == -(-(mn - 1) // H)
    assert rh.metrics.horizon_ticks / rh.metrics.decode_tokens <= 1.0 / H
    # ... plus an O(1) prefill/admission constant overall
    assert rh.metrics.host_syncs <= rh.metrics.horizon_ticks + 4
    assert rh.metrics.host_syncs < r1.metrics.host_syncs / 4
    assert rh.metrics.device_dispatches < r1.metrics.device_dispatches / 3
    assert rh.metrics.horizon_fused_steps >= mn - 1


def test_preallocate_is_reservation_backed(tiny):
    """PagedKVPool.preallocate extends a table to cover end_pos, draws
    from the reservation ledger, and conserves blocks."""
    cfg, model, params = tiny
    pool = PagedKVPool(model, 2, 16, block_size=4, n_blocks=10)
    pool.reserve(4)
    table = [pool.alloc_block(from_reservation=False)]  # covers pos 0..3
    assert pool.preallocate(table, 4) == 0             # already covered
    got = pool.preallocate(table, 13)                  # pos 0..12 -> 4 blks
    assert got == 3 and len(table) == 4
    assert pool._reserved == 1
    pool.check_conservation()
    assert pool.preallocate(table, 16) == 0            # 16 pos = 4 blocks
    pool.release_table(table)
    pool.unreserve(1)
    pool.check_conservation()
    assert pool.blocks_in_use == 0


def test_horizon_churn_keeps_ledger_balanced(tiny):
    """Sustained traffic through a small pool with horizon preallocation:
    blocks recycle, every request matches its own batch-engine run, and
    the drain-time ledger audit (refcounts + reservations) balances."""
    cfg, model, params = tiny
    engine = ServingEngine(model, params, max_new=4, temperature=0.0)
    rng = np.random.default_rng(7)
    prompts = [rng.integers(0, cfg.vocab_size, (L,)).astype(np.int32)
               for L in (5, 6, 7, 5, 6, 7, 5, 6)]
    rt = ContinuousBatchingRuntime(model, params, n_slots=2, max_len=12,
                                   max_new=4, temperature=0.0, seed=0,
                                   pool="paged", block_size=4, horizon=4,
                                   budget_fn=lambda r, h: 2)
    ids = [rt.submit(p) for p in prompts]
    rt.drain()
    for p, rid in zip(prompts, ids):
        want = engine.generate(p[None], n_samples=1, seed=0,
                               temperature=0.0).tokens[0]
        np.testing.assert_array_equal(rt.result(rid).response, want)
    assert rt.pool.block_alloc_count > rt.pool.n_blocks - 1   # reuse
    assert rt.metrics.horizon_ticks > 0
    _clean(rt)


def test_submit_batch_forwards_max_new(tiny):
    """Regression: submit_batch silently dropped per-request max_new."""
    cfg, model, params = tiny
    rng = np.random.default_rng(3)
    prompts = np.stack([rng.integers(0, cfg.vocab_size, (5,))
                        for _ in range(2)]).astype(np.int32)
    rt = ContinuousBatchingRuntime(model, params, n_slots=2, max_len=14,
                                   max_new=8, temperature=0.0, seed=0)
    ids = rt.submit_batch(prompts, budgets=[1, 1], max_new=[2, 5])
    rt.drain()
    assert len(rt.result(ids[0]).response) == 2
    assert len(rt.result(ids[1]).response) == 5
    _clean(rt)


def test_radix_aware_admission_ordering(tiny):
    """With a published preamble in the radix tree and prefill_slots=1,
    a queued prefix-cache hit is admitted before an earlier-queued miss
    (bounded lookahead), metered as prefix_reordered — and outputs stay
    exactly the no-reorder run's."""
    cfg, model, params = tiny
    rng = np.random.default_rng(4)
    pre = rng.integers(0, cfg.vocab_size, (8,)).astype(np.int32)
    warm = np.concatenate([pre, rng.integers(0, cfg.vocab_size, (2,))
                           .astype(np.int32)])
    hit = np.concatenate([pre, rng.integers(0, cfg.vocab_size, (3,))
                          .astype(np.int32)])
    miss = rng.integers(0, cfg.vocab_size, (9,)).astype(np.int32)

    def run(lookahead):
        rt = ContinuousBatchingRuntime(
            model, params, n_slots=4, max_len=18, max_new=3,
            temperature=0.0, seed=0, pool="paged", block_size=4,
            prefill_slots=1, admission_lookahead=lookahead)
        a = rt.submit(warm, budget=1)
        rt.drain()                      # publishes the preamble's blocks
        b = rt.submit(miss, budget=1)   # FIFO head: a cold miss
        c = rt.submit(hit, budget=1)    # behind it: a 2-block hit
        rt.drain()
        _clean(rt)
        return rt, [list(rt.result(i).response) for i in (a, b, c)]

    rt_f, fifo = run(1)                 # strict FIFO reference
    rt_r, reord = run(4)
    assert fifo == reord                # ordering never changes tokens
    assert rt_f.metrics.prefix_reordered == 0
    assert rt_r.metrics.prefix_reordered >= 1
    assert rt_r.metrics.prefix_hits >= 1
    assert rt_r.metrics.prefix_hit_tokens >= 8


def test_match_len_is_a_pure_peek(tiny):
    """match_len must take no refs and refresh no LRU clocks — the
    admission scan cannot perturb eviction order or the block ledger."""
    cfg, model, params = tiny
    rng = np.random.default_rng(11)
    prompt = rng.integers(0, cfg.vocab_size, (9,)).astype(np.int32)
    rt = ContinuousBatchingRuntime(model, params, n_slots=2, max_len=16,
                                   max_new=2, temperature=0.0, seed=0,
                                   pool="paged", block_size=4)
    rt.submit(prompt, budget=1)
    rt.drain()
    radix = rt.radix
    assert radix.held_blocks > 0
    refs = list(rt.pool._ref)
    clocks = {id(n): n.last_used for n in radix.root.values()}
    assert radix.match_len(prompt) == 8                # 2 full blocks
    assert radix.match_len(prompt[:3]) == 0
    assert list(rt.pool._ref) == refs                  # no refs taken
    for n in radix.root.values():
        assert n.last_used == clocks[id(n)]            # no LRU refresh
