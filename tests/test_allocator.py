"""Allocator correctness: exactness vs brute force, property tests
(hypothesis), jnp/np agreement, offline-policy behaviour."""
import itertools

import jax.numpy as jnp
import numpy as np

try:
    from hypothesis import given, settings, strategies as st
except ImportError:        # hypothesis is dev-only: skip just those tests
    from conftest import given, settings, st  # noqa: F401

from repro.core import allocator as alloc
from repro.core import marginal


def brute_force(delta: np.ndarray, total: int) -> float:
    """Optimal objective of Eq. 5 by enumeration (tiny instances)."""
    n, B = delta.shape
    best = -np.inf
    pre = np.concatenate([np.zeros((n, 1)), np.cumsum(delta, 1)], axis=1)
    for combo in itertools.product(range(B + 1), repeat=n):
        if sum(combo) <= total:
            best = max(best, sum(pre[i, b] for i, b in enumerate(combo)))
    return best


def objective(delta, b):
    pre = np.concatenate([np.zeros((len(delta), 1)), np.cumsum(delta, 1)], 1)
    return float(sum(pre[i, int(bi)] for i, bi in enumerate(b)))


@given(st.lists(st.lists(st.floats(0, 1, width=32), min_size=3, max_size=3),
                min_size=2, max_size=4),
       st.integers(0, 12))
@settings(max_examples=60, deadline=None)
def test_greedy_matches_bruteforce_monotone(rows, total):
    # sort each row descending => monotone marginals => greedy exact
    delta = np.sort(np.asarray(rows, np.float64), axis=1)[:, ::-1]
    b = alloc.greedy_allocate(delta, total)
    assert b.sum() <= total
    assert np.isclose(objective(delta, b), brute_force(delta, total),
                      atol=1e-9)


@given(st.lists(st.lists(st.floats(0, 1, width=32), min_size=3, max_size=3),
                min_size=2, max_size=3),
       st.integers(0, 9))
@settings(max_examples=40, deadline=None)
def test_greedy_nonmonotone_within_one_block(rows, total):
    """With ironing, greedy is optimal up to one pooled block at the budget
    boundary; verify objective is within the max single marginal."""
    delta = np.asarray(rows, np.float64)
    b = alloc.greedy_allocate(delta, total)
    assert b.sum() <= total
    opt = brute_force(delta, total)
    gap = opt - objective(delta, b)
    assert gap <= delta.max() * delta.shape[1] + 1e-9


@given(st.lists(st.floats(0.0, 1.0, width=32), min_size=2, max_size=30),
       st.integers(1, 64), st.integers(1, 16))
@settings(max_examples=60, deadline=None)
def test_binary_budget_and_prefix_properties(lams, bmax, avg_b):
    lam = np.asarray(lams)
    delta = marginal.binary_marginals(lam, bmax)
    # binary marginals are monotone non-increasing
    assert (np.diff(delta, axis=1) <= 1e-12).all()
    total = avg_b * len(lam)
    b = alloc.greedy_allocate(delta, total)
    assert b.sum() <= total
    assert (b >= 0).all() and (b <= bmax).all()
    # threshold allocation agrees with greedy objective
    b2 = alloc.allocate_threshold(delta, total, assume_monotone=True)
    assert np.isclose(objective(delta, b), objective(delta, b2), atol=1e-9)


def test_harder_queries_get_more_at_high_budget():
    """Paper Fig. 6: at high budgets most compute goes to hard queries."""
    lam = np.array([0.9, 0.5, 0.05])
    delta = marginal.binary_marginals(lam, 128)
    b_low = alloc.greedy_allocate(delta, 3)
    b_high = alloc.greedy_allocate(delta, 3 * 64)
    assert b_low[0] >= 1           # easy query served first at tiny budget
    assert b_high[2] > b_high[0]   # hard query dominates at large budget


def test_zero_success_gets_zero():
    lam = np.array([0.0, 0.3])
    delta = marginal.binary_marginals(lam, 16)
    b = alloc.greedy_allocate(delta, 8)
    assert b[0] == 0               # impossible query: default answer


def test_b_min_respected():
    lam = np.array([0.0, 0.3, 0.9])
    delta = marginal.binary_marginals(lam, 8)
    b = alloc.greedy_allocate(delta, 6, b_min=1)
    assert (b >= 1).all()


def test_iron_rows_properties():
    rng = np.random.default_rng(0)
    d = rng.normal(size=(20, 12))
    ir = alloc.iron_rows(d)
    assert np.allclose(ir.sum(1), d.sum(1))            # sum-preserving
    assert (np.diff(ir, axis=1) <= 1e-9).all()         # non-increasing
    # prefix sums dominate (concave hull)
    assert (np.cumsum(ir, 1) >= np.cumsum(d, 1) - 1e-9).all()


def test_iron_rows_jnp_matches_numpy():
    rng = np.random.default_rng(1)
    d = rng.normal(size=(8, 10))
    a = alloc.iron_rows(d)
    b = np.asarray(alloc.iron_rows_jnp(jnp.asarray(d)))
    assert np.allclose(a, b, atol=1e-4)


def test_offline_policy_budget_and_monotonicity():
    rng = np.random.default_rng(2)
    lam = rng.beta(0.6, 1.2, size=500)
    delta = marginal.binary_marginals(lam, 32)
    pol = alloc.build_offline_policy(delta, lam, avg_budget=4.0, n_bins=8)
    b = pol(lam)
    assert b.mean() <= 4.0 + 1e-9
    # the policy maps harder (lower λ, up to the impossible cliff) bins to
    # budgets; check it spends everything it can on positive-marginal bins
    assert b.max() > b.min()


def test_price_dual_matches_batch_allocation():
    """Streaming (price-thresholded, per-row) allocation spends ~the same
    total as the batch-coupled greedy at the calibration budget, and is
    identical when calibration == deployment rows."""
    rng = np.random.default_rng(4)
    lam = rng.beta(0.8, 1.5, size=200)
    delta = marginal.binary_marginals(lam, 16)       # monotone rows
    price = alloc.price_for_budget(delta, avg_budget=3.0)
    b_stream = alloc.allocate_at_price(delta, price)
    b_batch = alloc.greedy_allocate(delta, 3 * 200)
    assert abs(int(b_stream.sum()) - int(b_batch.sum())) <= 200 * 0.05
    # rows can be processed one at a time with the same result
    one_at_a_time = np.concatenate(
        [alloc.allocate_at_price(delta[i], price) for i in range(20)])
    assert np.array_equal(one_at_a_time, b_stream[:20])


def test_price_with_b_min_respects_average_budget():
    """The b_min floor is charged against the calibrated budget: realized
    mean spend stays ~avg_budget instead of overshooting by the floor."""
    rng = np.random.default_rng(6)
    lam = rng.beta(0.5, 3.0, size=400)               # many near-zero λ
    delta = marginal.binary_marginals(lam, 8)
    price = alloc.price_for_budget(delta, avg_budget=1.0, b_min=1)
    b = alloc.allocate_at_price(delta, price, b_min=1)
    assert (b >= 1).all()
    assert b.mean() <= 1.0 + 0.05


def test_price_for_budget_edges():
    delta = marginal.binary_marginals(np.array([0.3, 0.9]), 4)
    assert alloc.price_for_budget(delta, 0.0) == float("inf")
    assert (alloc.allocate_at_price(delta, float("inf")) == 0).all()
    # budget >= all units: price floors at 0, all positive units admitted
    p = alloc.price_for_budget(delta, 100.0)
    assert (alloc.allocate_at_price(delta, p) == 4).all()
    # b_min floor applies even at infinite price
    assert (alloc.allocate_at_price(delta, float("inf"), b_min=1) == 1).all()


def test_routing_topk_exact_fraction():
    rng = np.random.default_rng(3)
    pref = rng.uniform(size=100)
    for f in (0.0, 0.25, 0.5, 1.0):
        m = alloc.route_by_preference(pref, f)
        assert m.sum() == int(round(f * 100))
    # routed set is the top of the distribution
    m = alloc.route_by_preference(pref, 0.3)
    assert pref[m].min() >= pref[~m].max() - 1e-12
