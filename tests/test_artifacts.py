"""Regression net over the dry-run artifacts: every (arch x shape x mesh)
combination must exist and be ok=true, with physically-sane analysis
fields. Catches silent dry-run regressions without recompiling."""
import json
from pathlib import Path

import pytest

from repro.configs import ARCHS, INPUT_SHAPES

ARTIFACTS = Path(__file__).resolve().parents[1] / "experiments" / "artifacts"

COMBOS = [(a, s.name, m) for a in sorted(ARCHS) for s in INPUT_SHAPES
          for m in ("pod16x16", "pod2x16x16")]


@pytest.mark.skipif(not ARTIFACTS.exists(), reason="dry-run not yet run")
@pytest.mark.parametrize("arch,shape,mesh", COMBOS)
def test_artifact_ok_and_sane(arch, shape, mesh):
    f = ARTIFACTS / f"{arch}__{shape}__{mesh}.json"
    assert f.exists(), f"missing dry-run artifact {f.name}"
    r = json.loads(f.read_text())
    assert r.get("ok"), r.get("error", "")[:200]
    a = r["hlo_analysis"]
    assert a["flops"] > 0
    assert a["bytes"] > 0
    assert r["n_devices"] == (512 if mesh == "pod2x16x16" else 256)
    # sharded program must communicate (except pure-local decode of tiny
    # replicated models — still true in practice for every combo here)
    assert a["collective_bytes_total"] > 0, "no collectives: not sharded?"
    # decode steps must be far cheaper than prefill/train
    if r["kind"] == "decode":
        assert a["flops"] < 1e13


@pytest.mark.skipif(not ARTIFACTS.exists(), reason="dry-run not yet run")
def test_multipod_halves_flops():
    """Per-device FLOPs must halve going 1 pod -> 2 pods (data parallel)."""
    checked = 0
    for arch in sorted(ARCHS):
        f1 = ARTIFACTS / f"{arch}__train_4k__pod16x16.json"
        f2 = ARTIFACTS / f"{arch}__train_4k__pod2x16x16.json"
        if not (f1.exists() and f2.exists()):
            continue
        r1, r2 = json.loads(f1.read_text()), json.loads(f2.read_text())
        if not (r1.get("ok") and r2.get("ok")):
            continue
        ratio = r2["hlo_analysis"]["flops"] / r1["hlo_analysis"]["flops"]
        assert 0.4 < ratio < 0.62, (arch, ratio)
        checked += 1
    assert checked >= 8
