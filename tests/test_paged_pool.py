"""Paged KV pool: three-way bitwise equivalence (paged / slots / batch
engine), chunked-prefill parity with engine.prefill, COW-sharing and
block-reuse invariants under churn, reservation-gated admission, capacity
vs the slot pool at equal memory, and the paged Pallas kernel."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import build_model
from repro.serving import (ContinuousBatchingRuntime, PagedKVPool,
                           RequestState, ServingEngine)


@pytest.fixture(scope="module")
def tiny():
    cfg = dataclasses.replace(get_config("qwen2-0.5b").reduced(),
                              dtype="float32", n_layers=2)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _pool_invariants_clean(rt: ContinuousBatchingRuntime):
    """After drain the only blocks still alive are the radix prefix
    cache's (retired prompts kept warm for future hits); the full ledger
    cross-check must balance, and clearing the cache must return the pool
    to pristine."""
    pool = rt.pool
    rt.assert_ledger_balanced()
    held = rt.radix.held_blocks if rt.radix is not None else 0
    assert pool.blocks_in_use == held
    assert pool.n_free_slots == pool.n_slots
    assert pool._reserved == 0
    if rt.radix is not None:
        assert rt.radix.clear() == held
    assert pool.blocks_in_use == 0
    assert all(r == 0 for r in pool._ref)


@pytest.mark.slow
def test_three_way_bitwise_equivalence(tiny):
    """Greedy decode is bitwise identical across the paged pool, the slot
    pool, and the batch engine, on a mixed-length mixed-budget workload."""
    cfg, model, params = tiny
    engine = ServingEngine(model, params, max_new=4, temperature=0.0)
    rng = np.random.default_rng(2)
    prompts = [rng.integers(0, cfg.vocab_size, (L,)).astype(np.int32)
               for L in (5, 9, 7, 9)]
    budgets = [2, 1, 3, 2]

    def run(pool):
        rt = ContinuousBatchingRuntime(model, params, n_slots=4, max_len=16,
                                       max_new=4, temperature=0.0, seed=0,
                                       pool=pool, block_size=4)
        ids = [rt.submit(p, budget=b) for p, b in zip(prompts, budgets)]
        rt.drain()
        return rt, ids

    rt_p, ids_p = run("paged")
    rt_s, ids_s = run("slots")
    for i, (p, b) in enumerate(zip(prompts, budgets)):
        want = engine.generate(p[None], n_samples=1, seed=0,
                               temperature=0.0).tokens[0]
        for c in rt_p.result(ids_p[i]).children:
            np.testing.assert_array_equal(np.asarray(c.tokens), want)
        for cp, cs in zip(rt_p.result(ids_p[i]).children,
                          rt_s.result(ids_s[i]).children):
            np.testing.assert_array_equal(cp.tokens, cs.tokens)
    _pool_invariants_clean(rt_p)


def test_chunked_prefill_parity_with_engine_prefill(tiny):
    """The chunked (one-prompt-token-per-tick) prefill inside the decode
    tick reproduces engine.prefill: same probe hidden state and next-token
    logits to float tolerance (the batched scan fuses differently than the
    per-token tick), and the same greedy next token exactly."""
    from repro.serving.engine import prefill as engine_prefill
    cfg, model, params = tiny
    rng = np.random.default_rng(3)
    prompt = rng.integers(0, cfg.vocab_size, (7,)).astype(np.int32)
    logits_ref, hidden_ref, _ = engine_prefill(model, params,
                                               jnp.asarray(prompt[None]), 12)
    rt = ContinuousBatchingRuntime(model, params, n_slots=2, max_len=12,
                                   max_new=2, temperature=0.0, seed=0,
                                   pool="paged", block_size=4)
    rid = rt.submit(prompt)                    # no budget: parks in PREFILL
    rt.prefill_queued()
    r = rt.result(rid)
    assert r.state == RequestState.PREFILL
    np.testing.assert_allclose(r.hidden,
                               np.asarray(hidden_ref[0], np.float32),
                               rtol=2e-5, atol=2e-5)
    # paged stash holds the probe's (V,) logits row directly
    got_logits = np.asarray(r.stash.logits).reshape(-1)
    np.testing.assert_allclose(got_logits, np.asarray(logits_ref[0]),
                               rtol=2e-5, atol=2e-5)
    assert int(got_logits.argmax()) == int(np.asarray(logits_ref[0]).argmax())


def test_cow_sharing_bounds_fanout_memory(tiny):
    """Fan-out children share the prompt's full blocks copy-on-write: b_i
    children cost the shared prompt + one boundary copy + their decode
    tails, not b_i full rows."""
    cfg, model, params = tiny
    rng = np.random.default_rng(4)
    sp, max_new, B, b_i = 8, 4, 4, 4
    prompt = rng.integers(0, cfg.vocab_size, (sp,)).astype(np.int32)
    rt = ContinuousBatchingRuntime(model, params, n_slots=4, max_len=16,
                                   max_new=max_new, temperature=0.0, seed=0,
                                   pool="paged", block_size=B)
    rid = rt.submit(prompt, budget=b_i)
    rt.drain()
    # prompt = 2 full shared blocks; each child owns 1 decode-tail block
    # (sp % B == 0 -> no boundary copy). Slot-pool equivalent would be
    # b_i * ceil(max_len/B) = 16 blocks.
    shared = sp // B
    assert rt.metrics.peak_blocks <= shared + b_i * rt.pool.blocks_for(max_new)
    assert rt.metrics.peak_blocks < b_i * rt.pool.blocks_per_seq
    # greedy children identical (all reads went through shared blocks)
    rows = [list(c.tokens) for c in rt.result(rid).children]
    assert all(row == rows[0] for row in rows)
    _pool_invariants_clean(rt)


@pytest.mark.slow
def test_block_reuse_under_churn(tiny):
    """Sustained traffic through a small pool recycles blocks (lifetime
    allocations exceed the pool) and every block/slot/reservation returns
    to the free state afterwards."""
    cfg, model, params = tiny
    engine = ServingEngine(model, params, max_new=3, temperature=0.0)
    rng = np.random.default_rng(5)
    prompts = [rng.integers(0, cfg.vocab_size, (L,)).astype(np.int32)
               for L in (5, 6, 7, 5, 6, 7, 5, 6)]
    rt = ContinuousBatchingRuntime(model, params, n_slots=2, max_len=10,
                                   max_new=3, temperature=0.0, seed=0,
                                   pool="paged", block_size=4,
                                   budget_fn=lambda r, h: 2)
    ids = [rt.submit(p) for p in prompts]
    rt.drain()
    for p, rid in zip(prompts, ids):
        want = engine.generate(p[None], n_samples=1, seed=0,
                               temperature=0.0).tokens[0]
        np.testing.assert_array_equal(rt.result(rid).response, want)
    assert rt.pool.block_alloc_count > rt.pool.n_blocks - 1   # reuse
    _pool_invariants_clean(rt)


def test_paged_beats_slots_on_concurrency_at_equal_memory(tiny):
    """The acceptance claim in miniature: at the same device KV memory
    (token capacity), the paged pool sustains more concurrent children
    than the slot pool when sequences are shorter than the worst case —
    the slot pool queues first."""
    cfg, model, params = tiny
    rng = np.random.default_rng(6)
    max_len, B = 16, 4
    mem_tokens = 4 * max_len                   # slot pool: 4 rows
    sp, max_new, n_req = 4, 4, 6
    prompts = np.stack([rng.integers(0, cfg.vocab_size, (sp,))
                        for _ in range(n_req)]).astype(np.int32)

    rt_s = ContinuousBatchingRuntime(model, params,
                                     n_slots=mem_tokens // max_len,
                                     max_len=max_len, max_new=max_new,
                                     temperature=0.0, seed=0, pool="slots")
    ids = rt_s.submit_batch(prompts, budgets=[1] * n_req)
    rt_s.drain()

    rt_p = ContinuousBatchingRuntime(model, params, n_slots=n_req,
                                     max_len=max_len, max_new=max_new,
                                     temperature=0.0, seed=0, pool="paged",
                                     block_size=B,
                                     n_blocks=mem_tokens // B + 1,
                                     prefill_slots=n_req)
    ids_p = rt_p.submit_batch(prompts, budgets=[1] * n_req)
    rt_p.drain()

    for a, b in zip(ids, ids_p):
        np.testing.assert_array_equal(rt_s.result(a).response,
                                      rt_p.result(b).response)
    # 6 short children fit the paged pool at once; the slot pool tops out
    # at its 4 full-length rows
    assert rt_p.metrics.peak_children > rt_s.metrics.peak_children
    assert rt_s.metrics.peak_children == mem_tokens // max_len
    _pool_invariants_clean(rt_p)


def test_reservations_prevent_deadlock_when_blocks_scarce(tiny):
    """With barely more blocks than one worst-case child, admission must
    serialize via reservations instead of deadlocking mid-decode."""
    cfg, model, params = tiny
    rng = np.random.default_rng(7)
    prompts = [rng.integers(0, cfg.vocab_size, (6,)).astype(np.int32)
               for _ in range(3)]
    rt = ContinuousBatchingRuntime(model, params, n_slots=3, max_len=12,
                                   max_new=4, temperature=0.0, seed=0,
                                   pool="paged", block_size=4,
                                   n_blocks=2 * 3 + 1 + 1)  # ~2 children
    ids = [rt.submit(p, budget=2) for p in prompts]
    rt.drain()                                 # must complete, not stall
    for rid in ids:
        assert rt.result(rid).state == RequestState.DONE
        assert all(len(c.tokens) == 4 for c in rt.result(rid).children)
    _pool_invariants_clean(rt)


def test_streaming_budget_gated_on_free_blocks(tiny):
    """The paged runtime caps budget_fn's answer at what unreserved
    blocks can carry (floor 1): a greedy budget of 64 on a tiny pool
    admits a bounded fan-out instead of over-committing memory."""
    cfg, model, params = tiny
    rng = np.random.default_rng(8)
    prompt = rng.integers(0, cfg.vocab_size, (6,)).astype(np.int32)
    rt = ContinuousBatchingRuntime(model, params, n_slots=2, max_len=12,
                                   max_new=4, temperature=0.0, seed=0,
                                   pool="paged", block_size=4, n_blocks=8,
                                   budget_fn=lambda r, h: 64)
    rid = rt.submit(prompt)
    rt.drain()
    r = rt.result(rid)
    assert r.state == RequestState.DONE
    assert 1 <= r.budget < 64                  # gated, not granted
    _pool_invariants_clean(rt)


def test_submit_rejects_request_that_can_never_fit(tiny):
    """The worst case for one child includes the request's held prompt
    table plus the child's COW boundary copy — a pool sized only for
    blocks_for(sp + max_new) would deadlock at fan-out, so submit must
    reject it up front; one block more and the request completes."""
    cfg, model, params = tiny
    rng = np.random.default_rng(10)
    prompt = rng.integers(0, cfg.vocab_size, (6,)).astype(np.int32)
    # sp=6, B=4, max_new=4: prompt 2 blocks + child owns 2 => worst 4
    rt = ContinuousBatchingRuntime(model, params, n_slots=1, max_len=12,
                                   max_new=4, temperature=0.0, seed=0,
                                   pool="paged", block_size=4, n_blocks=4)
    with pytest.raises(ValueError, match="blocks"):
        rt.submit(prompt, budget=1)
    rt_ok = ContinuousBatchingRuntime(model, params, n_slots=1, max_len=12,
                                      max_new=4, temperature=0.0, seed=0,
                                      pool="paged", block_size=4, n_blocks=5)
    rid = rt_ok.submit(prompt, budget=1)
    rt_ok.drain()
    assert rt_ok.result(rid).state == RequestState.DONE
    _pool_invariants_clean(rt_ok)


@pytest.mark.slow
def test_state_model_slot_reuse_resets_recurrent_state(tiny):
    """Recurrent-state leaves (here xLSTM) live per-slot, and the uniform
    tick keeps mutating freed slots' rows with garbage — so chunked
    prefill must reset a reused slot's state to its init values or the
    previous occupant contaminates the new request's probe and tokens.
    Forces reuse with n_slots=1 and checks each request against its own
    batch-engine run."""
    cfg = dataclasses.replace(get_config("xlstm-1.3b").reduced(),
                              dtype="float32", n_layers=8)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    engine = ServingEngine(model, params, max_new=3, temperature=0.0)
    rng = np.random.default_rng(12)
    prompts = [rng.integers(0, cfg.vocab_size, (L,)).astype(np.int32)
               for L in (6, 5, 7)]
    rt = ContinuousBatchingRuntime(model, params, n_slots=1, max_len=10,
                                   max_new=3, temperature=0.0, seed=0,
                                   pool="paged", block_size=4)
    assert rt.pool._has_state            # the model really carries state
    ids = [rt.submit(p, budget=1) for p in prompts]
    rt.drain()
    for p, rid in zip(prompts, ids):
        want = engine.generate(p[None], n_samples=1, seed=0,
                               temperature=0.0).tokens[0]
        np.testing.assert_array_equal(rt.result(rid).response, want)
    _pool_invariants_clean(rt)


def test_deferred_backlog_fits_one_block_row_per_request(tiny):
    """Facade-sizing regression: budget-deferred requests must pin only
    their prompt blocks (no standing child reservation — they will not
    decode until set_budget), so a batch-exact backlog sized at one
    block-row per request probes completely instead of stalling on block
    exhaustion."""
    cfg, model, params = tiny
    rng = np.random.default_rng(11)
    n, sp, mn, B, max_len, n_slots = 10, 5, 4, 4, 12, 2
    per_seq = -(-max_len // B)
    prompts = [rng.integers(0, cfg.vocab_size, (sp,)).astype(np.int32)
               for _ in range(n)]
    rt = ContinuousBatchingRuntime(model, params, n_slots=n_slots,
                                   max_len=max_len, max_new=mn,
                                   temperature=0.0, seed=0, pool="paged",
                                   block_size=B, prefill_slots=n_slots,
                                   n_blocks=(n + n_slots) * per_seq + 1)
    ids = [rt.submit(p) for p in prompts]      # all budget-deferred
    assert rt.prefill_queued() == n            # must not stall
    for rid in ids:
        assert rt.result(rid).hidden is not None
        rt.set_budget(rid, 2)
    rt.drain()
    assert all(rt.result(i).state == RequestState.DONE for i in ids)
    _pool_invariants_clean(rt)


def test_policy_allocate_streaming_max_children():
    """AdaptivePolicy.allocate_streaming clamps to the runtime-provided
    memory cap without touching the dual price."""
    from repro.core import AdaptivePolicy
    from repro.core.difficulty import init_mlp_probe
    probe = init_mlp_probe(jax.random.PRNGKey(1), 8, 1)
    policy = AdaptivePolicy(probe_params=probe, kind="bce", b_max=8, b_min=1)
    h = np.random.default_rng(0).normal(size=(5, 8)).astype(np.float32)
    free = policy.allocate_streaming(h, price=0.0)       # price 0: max out
    capped = policy.allocate_streaming(h, price=0.0, max_children=2)
    assert free.max() > 2
    assert capped.max() <= 2
    np.testing.assert_array_equal(np.minimum(free, 2), capped)


def test_paged_pool_block_double_release_raises(tiny):
    cfg, model, params = tiny
    pool = PagedKVPool(model, 2, 8, block_size=4, n_blocks=6)
    pool.reserve(1)
    blk = pool.alloc_block()
    pool.decref(blk)
    with pytest.raises(RuntimeError, match="double release"):
        pool.decref(blk)
    with pytest.raises(RuntimeError, match="double release|bad block"):
        pool.decref(0)                         # the null block is sacred
    s = pool.alloc_slot()
    pool.release_slot(s)
    with pytest.raises(RuntimeError, match="double release"):
        pool.release_slot(s)


def test_paged_pallas_kernel_matches_xla(tiny, monkeypatch):
    """REPRO_DECODE_KERNEL=pallas routes the paged runtime through the
    block-table Pallas kernel; greedy outputs match the XLA gather path.

    The env var is read at *trace* time, and _paged_tick's jit cache is
    keyed on the Model object — so the pallas run must use a freshly
    built Model (same weights) to force a retrace, and the kernel call
    count proves the pallas path was actually traced (a cache hit would
    silently re-execute the XLA program)."""
    from repro.kernels import ops
    from repro.models import build_model as _build
    cfg, model, params = tiny
    rng = np.random.default_rng(9)
    prompts = np.stack([rng.integers(0, cfg.vocab_size, (6,))
                        for _ in range(2)]).astype(np.int32)

    calls = []
    orig = ops.paged_decode_attention
    monkeypatch.setattr(
        ops, "paged_decode_attention",
        lambda *a, **k: (calls.append(1), orig(*a, **k))[1])

    def run(m):
        rt = ContinuousBatchingRuntime(m, params, n_slots=2, max_len=12,
                                       max_new=3, temperature=0.0, seed=0,
                                       pool="paged", block_size=4)
        ids = rt.submit_batch(prompts, budgets=[1, 1])
        rt.drain()
        return [list(rt.result(i).response) for i in ids]

    xla = run(model)
    assert not calls                           # default path: no kernel
    monkeypatch.setenv("REPRO_DECODE_KERNEL", "pallas")
    pallas = run(_build(cfg))                  # fresh Model -> fresh trace
    assert calls                               # kernel actually traced
    assert xla == pallas
