"""Quickstart: the three layers of the framework in ~60 lines.

1. build any assigned architecture (reduced) and run a train + decode step
2. the paper's allocator on analytic binary marginals
3. a difficulty probe trained on synthetic features

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, list_archs
from repro.core import allocator, marginal
from repro.core.difficulty import probe_predict, train_mlp_probe
from repro.models import build_model
from repro.optim import adamw_init, adamw_update

print("assigned architectures:", ", ".join(list_archs()))

# -- 1. model: any --arch id works; reduced() gives the CPU-sized variant --
cfg = dataclasses.replace(get_config("jamba-1.5-large-398b").reduced(),
                          dtype="float32")
model = build_model(cfg)
params = model.init(jax.random.PRNGKey(0))
tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0,
                            cfg.vocab_size)
batch = {"tokens": tokens, "labels": tokens}
loss, grads = jax.value_and_grad(model.loss_fn)(params, batch)
params, _ = adamw_update(params, grads, adamw_init(params), lr=1e-3)
print(f"[1] {cfg.name}: train loss {float(loss):.3f}")

cache = model.init_cache(batch=2, seq_len=64)
logits, hidden, cache = model.decode_step(
    params, tokens[:, :1], cache, jnp.zeros((2,), jnp.int32))
print(f"[1] decode step -> logits {logits.shape}, hidden {hidden.shape}")

# -- 2. allocation: 6 queries, budget 2x6 units --------------------------
lam = np.array([0.95, 0.6, 0.45, 0.2, 0.02, 0.0])
delta = marginal.binary_marginals(lam, b_max=16)
b = allocator.greedy_allocate(delta, total_budget=12)
print(f"[2] λ={lam} -> budgets {b} (hard queries get more; impossible get 0)")

# -- 3. difficulty probe --------------------------------------------------
rng = np.random.default_rng(0)
feats = rng.normal(size=(500, 32)).astype(np.float32)
lam_true = 1 / (1 + np.exp(-feats[:, 0] * 2))
probe, info = train_mlp_probe(jax.random.PRNGKey(2), feats, lam_true,
                              kind="bce", steps=400)
pred = probe_predict(probe, feats[:5], "bce")
print(f"[3] probe val loss {info['val_loss']:.4f}; "
      f"pred={np.round(pred, 2)} true={np.round(lam_true[:5], 2)}")
