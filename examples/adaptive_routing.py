"""Adaptive weak/strong routing demo (paper §4.2, Fig. 5).

Trains a weak (2L) and strong (6L) LM on the arithmetic suite, learns the
preference predictor p(p^S ≻ p^W | x) from the WEAK model's hidden states,
and shows the adaptive router matching the strong decoder's success rate
while calling it on only a fraction of queries.

Run:  PYTHONPATH=src python examples/adaptive_routing.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import numpy as np

from benchmarks.bench_routing import run_setting


def main():
    c = run_setting("model_size", n_train=160, n_test=160, m=6)
    print("\nstrong-fraction  adaptive  random  oracle")
    for f, a, r, o in zip(c["frac"], c["adaptive"], c["random"],
                          c["oracle"]):
        print(f"      {f:4.2f}       {a:.3f}    {r:.3f}   {o:.3f}")
    print(f"\nadaptive matches the all-strong reward at "
          f"{c['strong_match_frac']:.0%} strong calls "
          f"(paper: 50-75%)")


if __name__ == "__main__":
    main()
