"""Lower + compile ONE (arch x shape x mesh) combination and print its
memory/cost/roofline summary — the smallest entry point into deliverables
(e) and (g).

Run:  PYTHONPATH=src python examples/dryrun_one.py [arch] [shape]
"""
import sys

from repro.launch.dryrun import run_one

if __name__ == "__main__":
    arch = sys.argv[1] if len(sys.argv) > 1 else "qwen2-0.5b"
    shape = sys.argv[2] if len(sys.argv) > 2 else "decode_32k"
    rec = run_one(arch, shape, multi_pod=False, tag="example")
    ana = rec["hlo_analysis"]
    print(f"\n{arch} x {shape} on 16x16:")
    print(f"  per-device HLO FLOPs      {ana['flops']:.3e}")
    print(f"  per-device HLO bytes      {ana['bytes']:.3e}")
    print(f"  per-device collective B   {ana['collective_bytes_total']:.3e}")
    print(f"  compile temp              "
          f"{rec['memory']['temp_bytes']/2**30:.2f} GiB")
