"""End-to-end driver (deliverable b): train a small LM, train its
difficulty probe, and SERVE batched requests through the adaptive
best-of-k scheduler — the paper's full loop, with an adaptive-vs-uniform
comparison printed at the end.

Run:  PYTHONPATH=src python examples/serve_adaptive.py
(~10 min on this CPU container; tune --train-steps down for a faster demo)
"""
from repro.launch.serve import main

if __name__ == "__main__":
    main(["--train-steps", "300", "--n-train-queries", "160",
          "--n-queries", "64", "--budget", "4"])
