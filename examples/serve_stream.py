"""Streaming adaptive serving demo: requests of mixed prompt lengths flow
through the continuous-batching runtime one at a time, each budgeted the
moment its probe prefill lands (price-dual allocation — no batch barrier,
no second prefill).

Run:  PYTHONPATH=src python examples/serve_stream.py   (~1 min on CPU)
"""
import dataclasses

import jax
import numpy as np

from repro.configs import get_config
from repro.core import AdaptivePolicy
from repro.core.difficulty import init_mlp_probe
from repro.models import build_model
from repro.serving import ContinuousBatchingRuntime, ServingEngine

cfg = dataclasses.replace(get_config("qwen2-0.5b").reduced(),
                          dtype="float32", n_layers=2)
model = build_model(cfg)
params = model.init(jax.random.PRNGKey(0))
engine = ServingEngine(model, params, max_new=8, temperature=1.0)

# an (untrained) difficulty probe + a price calibrated offline
policy = AdaptivePolicy(
    probe_params=init_mlp_probe(jax.random.PRNGKey(1), cfg.d_model, 1),
    kind="bce", b_max=6, b_min=1)
rng = np.random.default_rng(0)
calib = rng.integers(0, cfg.vocab_size, size=(16, 12)).astype(np.int32)
price = policy.calibrate_price(engine.probe_features(calib), avg_budget=2.5)
print(f"calibrated price λ* = {price:.4f}")

rt = ContinuousBatchingRuntime(
    model, params, n_slots=6, max_len=32, max_new=8, temperature=1.0,
    seed=0,
    budget_fn=lambda req, h: int(policy.allocate_streaming(h, price)[0]),
    reward_fn=lambda q, rows: [float(len(set(r.tolist()))) for r in rows])

ids = [rt.submit(rng.integers(0, cfg.vocab_size, size=(L,)), query=i)
       for i, L in enumerate(rng.integers(6, 20, size=12))]
rt.drain()

for rid in ids:
    r = rt.result(rid)
    print(f"req {rid}: prompt_len={r.prompt_len:2d} budget={r.budget} "
          f"reward={r.reward:.1f} latency={r.latency*1e3:.0f}ms")
print("metrics:", {k: round(v, 3) for k, v in rt.metrics.summary().items()})
