"""Streaming adaptive serving demo: requests of mixed prompt lengths flow
through the continuous-batching runtime one at a time, each planned by a
pluggable DecodeProcedure the moment its probe prefill lands (no batch
barrier, no second prefill).

    --procedure bestofk   price-dual budgets, best-of-k fan-out (default)
    --procedure route     the model zoo's gemma-weak-tiny/gemma-strong-tiny
                          routing pair sharing ONE paged pool: the probe
                          prefill runs on the weak model, a preference
                          statistic routes ~strong-frac of the stream to
                          the strong model, and the metrics report the
                          per-model compute split
    --procedure single    one child per request (uniform b=1 floor)
    --stream              async token-by-token delivery: mixed-priority
                          requests through the traffic subsystem's
                          AsyncTokenStreamer, tokens printed the tick
                          they decode (high-priority tokens interleave
                          ahead of earlier low-priority submissions)

Run:  PYTHONPATH=src python examples/serve_stream.py [--procedure route]
(~1 min on CPU; untrained weights — the demo shows the serving machinery,
not model quality.)
"""
import argparse
import asyncio
import dataclasses

import jax
import numpy as np

from repro.configs import get_config
from repro.core import AdaptivePolicy
from repro.core.difficulty import init_mlp_probe
from repro.models import build_model
from repro.serving import (ContinuousBatchingRuntime, Route, ServingEngine,
                           Single, TrafficConfig)
from repro.serving.traffic import AsyncTokenStreamer

ap = argparse.ArgumentParser()
ap.add_argument("--procedure", choices=("bestofk", "route", "single"),
                default="bestofk")
ap.add_argument("--strong-frac", type=float, default=0.4,
                help="route: targeted strong-model fraction")
ap.add_argument("--stream", action="store_true",
                help="async token-by-token streaming over the traffic "
                     "subsystem (priority classes + SLO plumbing)")
args = ap.parse_args()

rng = np.random.default_rng(0)

if args.stream:
    cfg = dataclasses.replace(get_config("qwen2-0.5b").reduced(),
                              dtype="float32", n_layers=2)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rt = ContinuousBatchingRuntime(
        model, params, n_slots=4, max_len=32, max_new=8, temperature=0.0,
        seed=0, traffic=TrafficConfig())
    streamer = AsyncTokenStreamer(rt)
    jobs = []                               # (rid, tenant, priority)
    for i, L in enumerate(rng.integers(6, 16, size=6)):
        tenant = "acme" if i % 3 == 0 else "bulk"
        pri = 2 if tenant == "acme" else 0
        rid = streamer.submit(rng.integers(0, cfg.vocab_size, size=(L,)),
                              budget=1, tenant=tenant, priority=pri,
                              slo=5.0)
        jobs.append((rid, tenant, pri))

    async def consume(rid, tenant, pri):
        async for tok in streamer.tokens(rid):
            print(f"  req {rid} [{tenant}/p{pri}] -> {tok}")
        r = rt.result(rid)
        print(f"req {rid} done: {len(r.children[0].tokens)} tokens "
              f"latency={r.latency*1e3:.0f}ms met_slo={r.met_slo()}")

    async def main():
        server = asyncio.ensure_future(streamer.serve())
        await asyncio.gather(*[consume(*j) for j in jobs])
        await server

    asyncio.run(main())
    print("metrics:",
          {k: round(v, 3) for k, v in rt.metrics.summary().items()})
    raise SystemExit(0)

if args.procedure == "route":
    # two model-zoo configs, one shared paged pool
    w_cfg = dataclasses.replace(get_config("gemma-weak-tiny"),
                                dtype="float32")
    s_cfg = dataclasses.replace(get_config("gemma-strong-tiny"),
                                dtype="float32")
    w_model, s_model = build_model(w_cfg), build_model(s_cfg)
    w_params = w_model.init(jax.random.PRNGKey(0))
    s_params = jax.tree.map(lambda x: x * 3.0,
                            s_model.init(jax.random.PRNGKey(1)))
    reward_fn = lambda q, rows: [float(len(set(r.tolist()))) for r in rows]
    rt = ContinuousBatchingRuntime(
        w_model, w_params, n_slots=6, max_len=32, max_new=8,
        temperature=1.0, seed=0, reward_fn=reward_fn)
    rt.register_model("strong", s_model, s_params)

    # an (untrained) preference statistic: any request-measurable scalar
    # works — here the probe hidden's mean activation stands in for the
    # learned p(strong beats weak); calibrate its threshold on a few
    # warm-up prompts so ~strong-frac of matching traffic routes strong
    predictor = lambda r, h: float(np.tanh(np.mean(h)))
    calib = [rng.integers(0, w_cfg.vocab_size, size=(L,))
             for L in rng.integers(6, 20, size=8)]
    probe_rt = ContinuousBatchingRuntime(w_model, w_params, n_slots=4,
                                         max_len=32, max_new=1,
                                         temperature=0.0, seed=0)
    cids = [probe_rt.submit(p, procedure=Single(max_new=1)) for p in calib]
    probe_rt.drain()
    scores = [predictor(None, probe_rt.result(i).hidden) for i in cids]
    thr = Route.calibrate_threshold(scores, args.strong_frac)
    print(f"calibrated routing threshold = {thr:.4f} "
          f"(strong_frac target {args.strong_frac})")
    proc = Route(weak="default", strong="strong", predictor=predictor,
                 threshold=thr)

    ids = [rt.submit(rng.integers(0, w_cfg.vocab_size, size=(L,)), query=i,
                     procedure=proc)
           for i, L in enumerate(rng.integers(6, 20, size=12))]
    rt.drain()
    for rid in ids:
        r = rt.result(rid)
        print(f"req {rid}: prompt_len={r.prompt_len:2d} "
              f"route={r.proc['route']:6s} pref={r.proc['pref']:+.3f} "
              f"reward={r.reward:.1f} latency={r.latency*1e3:.0f}ms")
    pm = {m: mm.summary() for m, mm in rt.metrics.per_model.items()}
    for m, s in sorted(pm.items()):
        print(f"model {m}: prefill={s['prefill_tokens']} "
              f"decode={s['decode_tokens']} children={s['children']} "
              f"dispatches={s['device_dispatches']}")
    raise SystemExit(0)

cfg = dataclasses.replace(get_config("qwen2-0.5b").reduced(),
                          dtype="float32", n_layers=2)
model = build_model(cfg)
params = model.init(jax.random.PRNGKey(0))

if args.procedure == "single":
    rt = ContinuousBatchingRuntime(
        model, params, n_slots=6, max_len=32, max_new=8, temperature=1.0,
        seed=0,
        reward_fn=lambda q, rows: [float(len(set(r.tolist())))
                                   for r in rows])
    ids = [rt.submit(rng.integers(0, cfg.vocab_size, size=(L,)), query=i,
                     procedure=Single())
           for i, L in enumerate(rng.integers(6, 20, size=12))]
    rt.drain()
    for rid in ids:
        r = rt.result(rid)
        print(f"req {rid}: prompt_len={r.prompt_len:2d} b=1 "
              f"reward={r.reward:.1f} latency={r.latency*1e3:.0f}ms")
    print("metrics:",
          {k: round(v, 3) for k, v in rt.metrics.summary().items()})
    raise SystemExit(0)

engine = ServingEngine(model, params, max_new=8, temperature=1.0)

# an (untrained) difficulty probe + a price calibrated offline
policy = AdaptivePolicy(
    probe_params=init_mlp_probe(jax.random.PRNGKey(1), cfg.d_model, 1),
    kind="bce", b_max=6, b_min=1)
calib = rng.integers(0, cfg.vocab_size, size=(16, 12)).astype(np.int32)
price = policy.calibrate_price(engine.probe_features(calib), avg_budget=2.5)
print(f"calibrated price λ* = {price:.4f}")

rt = ContinuousBatchingRuntime(
    model, params, n_slots=6, max_len=32, max_new=8, temperature=1.0,
    seed=0,
    budget_fn=lambda req, h: int(policy.allocate_streaming(h, price)[0]),
    reward_fn=lambda q, rows: [float(len(set(r.tolist()))) for r in rows])

ids = [rt.submit(rng.integers(0, cfg.vocab_size, size=(L,)), query=i)
       for i, L in enumerate(rng.integers(6, 20, size=12))]
rt.drain()

for rid in ids:
    r = rt.result(rid)
    print(f"req {rid}: prompt_len={r.prompt_len:2d} budget={r.budget} "
          f"reward={r.reward:.1f} latency={r.latency*1e3:.0f}ms")
print("metrics:", {k: round(v, 3) for k, v in rt.metrics.summary().items()})
