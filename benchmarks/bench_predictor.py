"""Paper Table 1 reproduction: intrinsic predictor quality.

For each setting: Ours (probe test loss) vs Avg. (predict the dataset-mean
target) vs Opt.* (loss of a perfect predictor of the soft labels) vs Acc
(above/below-median accuracy).
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, get_arith_fixture, save_result
from repro.core import marginal
from repro.core.difficulty import probe_predict, train_mlp_probe


def _bce(pred, target, eps=1e-6):
    p = np.clip(pred, eps, 1 - eps)
    return float(np.mean(-(target * np.log(p) + (1 - target)
                           * np.log(1 - p))))


def table_row(pred, target):
    ours = _bce(pred, target)
    avg = _bce(np.full_like(target, target.mean()), target)
    opt = _bce(target, target)      # soft labels: entropy floor
    med = np.median(target)
    acc = float(((pred > np.median(pred)) == (target > med)).mean())
    return {"ours": ours, "avg": avg, "opt": opt, "acc": acc}


def lora_probe_row(fix, *, rank: int = 8, steps: int = 300, lr: float = 3e-4):
    """Paper's LoRA difficulty-model variant on the arith fixture."""
    import jax
    import jax.numpy as jnp

    from repro.core.difficulty import (apply_lora, init_lora_probe,
                                       lora_probe_loss, mlp_probe_apply)
    from repro.optim import adamw_init, adamw_update

    model, params = fix["model"], fix["params"]
    lam_tr = marginal.empirical_lambda(fix["train_succ"])
    lam_te = marginal.empirical_lambda(fix["test_succ"])
    d_model = model.cfg.d_model
    lora = init_lora_probe(jax.random.PRNGKey(7), params, d_model, 1,
                           rank=rank)

    def encode(p, toks):
        _, hidden, _ = model.forward(p, toks)
        return hidden[:, -1]

    tr_t = jnp.asarray(fix["train_prompts"])
    tr_y = jnp.asarray(lam_tr, jnp.float32)

    @jax.jit
    def step(lora, opt, idx):
        loss, g = jax.value_and_grad(lora_probe_loss)(
            lora, params, encode, tr_t[idx], tr_y[idx], "bce")
        lora, opt = adamw_update(lora, g, opt, lr=lr)
        return lora, opt, loss

    import numpy as _np
    rng = _np.random.default_rng(0)
    opt = adamw_init(lora)
    for s in range(steps):
        idx = jnp.asarray(rng.integers(0, len(tr_t), size=64))
        lora, opt, loss = step(lora, opt, idx)
    merged = apply_lora(params, lora)
    te_h = np.asarray(encode(merged, jnp.asarray(fix["test_prompts"])),
                      np.float32)
    pred = 1 / (1 + np.exp(-np.asarray(
        mlp_probe_apply(lora["head"], jnp.asarray(te_h)))[:, 0]))
    return table_row(pred, lam_te)


def run():
    import jax

    rows = {}

    # Math/Code-like: λ prediction on the arithmetic suite
    fix = get_arith_fixture()
    lam_tr = marginal.empirical_lambda(fix["train_succ"])
    lam_te = marginal.empirical_lambda(fix["test_succ"])
    probe, _ = train_mlp_probe(jax.random.PRNGKey(0), fix["train_feats"],
                               lam_tr, kind="bce", steps=1500)
    lam_hat = probe_predict(probe, fix["test_feats"], "bce")
    rows["arith(BCE λ)"] = table_row(lam_hat, lam_te)

    # LoRA variant (paper §3.1's second parameterization): adapter
    # fine-tuning of the base LM + head, trained end-to-end
    try:
        rows["arith(LoRA λ)"] = lora_probe_row(fix)
    except Exception as e:   # pragma: no cover
        rows["arith(LoRA λ)"] = {"error": str(e)[:120]}

    # Routing preference (reuse routing pools if present)
    try:
        from benchmarks.bench_routing import run_setting

        c = run_setting("model_size")
        rows["routing(model)"] = {"ours": c["probe_val_loss"],
                                  "avg": float("nan"), "opt": float("nan"),
                                  "acc": float("nan")}
    except Exception as e:   # pragma: no cover
        rows["routing(model)"] = {"error": str(e)[:100]}

    save_result("table1_predictors", rows)
    r = rows["arith(BCE λ)"]
    emit("table1_arith", 0.0,
         f"ours={r['ours']:.3f};avg={r['avg']:.3f};opt={r['opt']:.3f};"
         f"acc={r['acc']:.2f}")
    lr = rows.get("arith(LoRA λ)", {})
    if "ours" in lr:
        emit("table1_arith_lora", 0.0,
             f"ours={lr['ours']:.3f};avg={lr['avg']:.3f};"
             f"opt={lr['opt']:.3f};acc={lr['acc']:.2f}")


if __name__ == "__main__":
    run()
