"""Benchmark driver: one section per paper table/figure + system benches.

Prints ``name,us_per_call,derived`` CSV lines (assignment format). The
paper-repro benches train tiny in-framework models on first run and cache
them under experiments/cache/.
"""
from __future__ import annotations

import sys
import time
import traceback


def main() -> None:
    from benchmarks import (bench_allocation, bench_allocator,
                            bench_bestofk, bench_chat, bench_predictor,
                            bench_roofline, bench_routing, bench_serving)

    sections = [
        ("serving", bench_serving.run),
        ("allocator", bench_allocator.run),
        ("fig3_bestofk", bench_bestofk.run),
        ("fig4_chat", bench_chat.run),
        ("fig5_routing", bench_routing.run),
        ("table1_predictor", bench_predictor.run),
        ("fig6_allocation", bench_allocation.run),
        ("roofline", bench_roofline.run),
    ]
    print("name,us_per_call,derived")
    failures = []
    for name, fn in sections:
        t0 = time.time()
        print(f"# --- {name} ---")
        try:
            fn()
        except Exception as e:
            traceback.print_exc()
            failures.append((name, str(e)[:200]))
        print(f"# {name} done in {time.time()-t0:.1f}s")
    if failures:
        print("# FAILURES:", failures)
        sys.exit(1)


if __name__ == "__main__":
    main()
