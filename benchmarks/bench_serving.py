"""Continuous-batching runtime vs batch-synchronous engine under a Poisson
arrival stream with mixed adaptive budgets.

Both systems replay the identical workload (same prompts, same per-request
budgets b_i ~ {1..4}, same exponential inter-arrival gaps) in wall-clock
time. The batch engine admits every queued arrival as one synchronous
batch (single prefill — the patched path — then a barriered Σb_i-row
decode), so each distinct (batch, fan-out) shape costs a fresh jit
compile and late arrivals wait out the barrier. The runtime streams
children through a fixed slot pool: one compiled decode program total,
freed slots backfilled immediately.

Reports tokens/sec and p50/p95 request latency for both, plus runtime
slot occupancy.

    PYTHONPATH=src python benchmarks/bench_serving.py
"""
from __future__ import annotations

import dataclasses
import time
from typing import List

import numpy as np

from benchmarks.common import emit, save_result


def _make_workload(n: int, vocab: int, width: int, *, mean_gap: float,
                   seed: int):
    rng = np.random.default_rng(seed)
    prompts = rng.integers(0, vocab, size=(n, width)).astype(np.int32)
    budgets = rng.integers(1, 5, size=n).astype(int)          # mixed 1..4
    arrivals = np.cumsum(rng.exponential(mean_gap, size=n))
    return prompts, budgets, arrivals


def _run_batch_engine(engine, prompts, budgets, arrivals):
    """Greedy batching baseline: serve everything that has arrived as one
    synchronous batch, repeat until drained."""
    n = len(prompts)
    lat: List[float] = []
    gen_tokens = 0
    t0 = time.perf_counter()
    i = 0
    while i < n:
        now = time.perf_counter() - t0
        k = i
        while k < n and arrivals[k] <= now:
            k += 1
        if k == i:
            time.sleep(min(max(arrivals[i] - now, 0.0), 0.005))
            continue
        logits, _, cache, sp = engine.prefill_for_generate(prompts[i:k])
        sel = np.repeat(np.arange(k - i), budgets[i:k])
        engine.generate_from_prefill(cache, logits, sel, sp, seed=0)
        done = time.perf_counter() - t0
        lat.extend(done - arrivals[j] for j in range(i, k))
        gen_tokens += int(budgets[i:k].sum()) * engine.max_new
        i = k
    wall = time.perf_counter() - t0
    return dict(tokens_per_sec=gen_tokens / wall, wall_s=wall,
                decode_tokens=gen_tokens,
                latency_p50_s=float(np.percentile(lat, 50)),
                latency_p95_s=float(np.percentile(lat, 95)))


def _run_runtime(model, params, prompts, budgets, arrivals, *, n_slots,
                 max_new, temperature, max_len):
    from repro.serving import ContinuousBatchingRuntime

    rt = ContinuousBatchingRuntime(
        model, params, n_slots=n_slots, max_len=max_len, max_new=max_new,
        temperature=temperature, seed=0)
    n = len(prompts)
    ids = []
    t0 = time.perf_counter()
    i = 0
    while i < n or rt.pending():
        now = time.perf_counter() - t0
        while i < n and arrivals[i] <= now:
            ids.append(rt.submit(prompts[i], budget=int(budgets[i])))
            i += 1
        if rt.pending():
            rt.step()
        elif i < n:
            time.sleep(min(max(arrivals[i] - now, 0.0), 0.005))
    s = rt.metrics.summary()
    # latency relative to *arrival*, matching the batch baseline (a submit
    # can lag its arrival by up to one decode tick of the poll loop)
    lat = [rt.requests[rid].done_t - (t0 + arrivals[j])
           for j, rid in enumerate(ids)]
    return dict(tokens_per_sec=s["tokens_per_sec"], wall_s=s["wall_s"],
                decode_tokens=s["decode_tokens"],
                latency_p50_s=float(np.percentile(lat, 50)),
                latency_p95_s=float(np.percentile(lat, 95)),
                occupancy=s["occupancy"])


def run(n_requests: int = 40, width: int = 12, max_new: int = 8,
        n_slots: int = 8, mean_gap: float = 0.05, seed: int = 0) -> None:
    import jax

    from repro.configs import get_config
    from repro.models import build_model
    from repro.serving import ServingEngine

    cfg = dataclasses.replace(get_config("qwen2-0.5b").reduced(),
                              dtype="float32", n_layers=2)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(seed))
    engine = ServingEngine(model, params, max_new=max_new, temperature=1.0)
    max_len = width + max_new + 1

    prompts, budgets, arrivals = _make_workload(
        n_requests, cfg.vocab_size, width, mean_gap=mean_gap, seed=seed)

    # warm both drivers on a small all-at-once prefix so first-compile cost
    # of the *common* shapes is off the clock. The batch engine still
    # recompiles per distinct (batch, Σb) shape during the timed run —
    # that is inherent to barriered batching, and the runtime's static
    # shapes are the fix being measured.
    warm = slice(0, 6)
    _run_batch_engine(engine, prompts[warm], budgets[warm], np.zeros(6))
    _run_runtime(model, params, prompts[warm], budgets[warm], np.zeros(6),
                 n_slots=n_slots, max_new=max_new, temperature=1.0,
                 max_len=max_len)

    batch = _run_batch_engine(engine, prompts, budgets, arrivals)
    cont = _run_runtime(model, params, prompts, budgets, arrivals,
                        n_slots=n_slots, max_new=max_new, temperature=1.0,
                        max_len=max_len)

    for name, r in (("batch_engine", batch), ("continuous_runtime", cont)):
        emit(f"serving/{name}/wall", r["wall_s"] * 1e6,
             f"{r['tokens_per_sec']:.1f} tok/s")
        emit(f"serving/{name}/latency_p50", r["latency_p50_s"] * 1e6,
             f"p95={r['latency_p95_s']*1e3:.0f}ms")
    emit("serving/continuous_runtime/occupancy", 0.0,
         f"{cont['occupancy']:.2f}")
    speedup = cont["tokens_per_sec"] / max(batch["tokens_per_sec"], 1e-9)
    emit("serving/speedup", 0.0, f"{speedup:.2f}x tokens/sec")
    save_result("bench_serving", dict(
        batch=batch, runtime=cont, n_requests=n_requests, width=width,
        max_new=max_new, n_slots=n_slots, mean_gap=mean_gap,
        budgets_mean=float(np.mean(budgets)), speedup=speedup))
    print(f"# continuous-batching vs batch: {speedup:.2f}x tokens/sec, "
          f"p50 latency {batch['latency_p50_s']/max(cont['latency_p50_s'],1e-9):.2f}x lower")


if __name__ == "__main__":
    run()
