"""Serving benchmarks: continuous-batching runtime (paged + slot pools)
vs the batch-synchronous engine, plus an equal-memory capacity probe.

Poisson stream: all three systems replay the identical workload (same
prompts, same per-request budgets b_i ~ {1..4}, same exponential
inter-arrival gaps) in wall-clock time. The batch engine admits every
queued arrival as one synchronous batch (single prefill — the patched
path — then a barriered Σb_i-row decode), so each distinct (batch,
fan-out) shape costs a fresh jit compile and late arrivals wait out the
barrier. The runtime streams children through a fixed pool: one compiled
decode program total, freed slots backfilled immediately. The paged pool
additionally folds chunked prefill into that same program and shares
prompt blocks copy-on-write across fan-out.

Capacity probe: at equal device KV memory (token capacity), short
sequences let the paged pool sustain strictly more concurrent children
than the slot pool's full-`max_len` rows — the slot pool queues first.

Prefix-heavy probe: realistic adaptive-best-of-k traffic shares a task
preamble / few-shot header across requests. The same greedy stream runs
with the radix prefix cache on and off; the cache must cut prefill tokens
computed by >= 30% (metered via `prefix_hit_tokens`) at bitwise-identical
outputs. `REPRO_DECODE_KERNEL=pallas` routes it through the paged chunk
kernel (interpret mode on CPU) — that combination is the CI gate.

    PYTHONPATH=src python benchmarks/bench_serving.py            # full
    PYTHONPATH=src python benchmarks/bench_serving.py --smoke    # CI gate
    PYTHONPATH=src python benchmarks/bench_serving.py --smoke --prefix-heavy
"""
from __future__ import annotations

import dataclasses
import time
from typing import List

import numpy as np

from benchmarks.common import emit, save_result


def _make_workload(n: int, vocab: int, width: int, *, mean_gap: float,
                   seed: int):
    rng = np.random.default_rng(seed)
    prompts = rng.integers(0, vocab, size=(n, width)).astype(np.int32)
    budgets = rng.integers(1, 5, size=n).astype(int)          # mixed 1..4
    arrivals = np.cumsum(rng.exponential(mean_gap, size=n))
    return prompts, budgets, arrivals


def _run_batch_engine(engine, prompts, budgets, arrivals):
    """Greedy batching baseline: serve everything that has arrived as one
    synchronous batch, repeat until drained."""
    n = len(prompts)
    lat: List[float] = []
    gen_tokens = 0
    t0 = time.perf_counter()
    i = 0
    while i < n:
        now = time.perf_counter() - t0
        k = i
        while k < n and arrivals[k] <= now:
            k += 1
        if k == i:
            time.sleep(min(max(arrivals[i] - now, 0.0), 0.005))
            continue
        logits, _, cache, sp = engine.prefill_for_generate(prompts[i:k])
        sel = np.repeat(np.arange(k - i), budgets[i:k])
        engine.generate_from_prefill(cache, logits, sel, sp, seed=0)
        done = time.perf_counter() - t0
        lat.extend(done - arrivals[j] for j in range(i, k))
        gen_tokens += int(budgets[i:k].sum()) * engine.max_new
        i = k
    wall = time.perf_counter() - t0
    return dict(tokens_per_sec=gen_tokens / wall, wall_s=wall,
                decode_tokens=gen_tokens,
                latency_p50_s=float(np.percentile(lat, 50)),
                latency_p95_s=float(np.percentile(lat, 95)))


def _run_runtime(model, params, prompts, budgets, arrivals, *, n_slots,
                 max_new, temperature, max_len, pool, block_size=8):
    from repro.serving import ContinuousBatchingRuntime

    rt = ContinuousBatchingRuntime(
        model, params, n_slots=n_slots, max_len=max_len, max_new=max_new,
        temperature=temperature, seed=0, pool=pool, block_size=block_size)
    n = len(prompts)
    ids = []
    t0 = time.perf_counter()
    i = 0
    while i < n or rt.pending():
        now = time.perf_counter() - t0
        while i < n and arrivals[i] <= now:
            ids.append(rt.submit(prompts[i], budget=int(budgets[i])))
            i += 1
        if rt.pending():
            rt.step()
        elif i < n:
            time.sleep(min(max(arrivals[i] - now, 0.0), 0.005))
    s = rt.metrics.summary()
    # latency relative to *arrival*, matching the batch baseline (a submit
    # can lag its arrival by up to one decode tick of the poll loop)
    lat = [rt.requests[rid].done_t - (t0 + arrivals[j])
           for j, rid in enumerate(ids)]
    return dict(tokens_per_sec=s["tokens_per_sec"], wall_s=s["wall_s"],
                decode_tokens=s["decode_tokens"],
                latency_p50_s=float(np.percentile(lat, 50)),
                latency_p95_s=float(np.percentile(lat, 95)),
                occupancy=s["occupancy"], peak_blocks=s["peak_blocks"])


def _capacity_probe(model, params, vocab, *, mem_tokens, max_len,
                    block_size, sp, max_new, n_req, seed=0):
    """Equal device KV memory (mem_tokens of cache positions) for both
    pools; short requests (sp + max_new << max_len). Reports the peak
    concurrent-child count each backend sustains — the slot pool tops out
    at mem_tokens/max_len full rows and queues the rest."""
    from repro.serving import ContinuousBatchingRuntime

    rng = np.random.default_rng(seed)
    prompts = rng.integers(0, vocab, size=(n_req, sp)).astype(np.int32)
    out = {}
    slot_rows = mem_tokens // max_len
    rt_s = ContinuousBatchingRuntime(
        model, params, n_slots=slot_rows, max_len=max_len, max_new=max_new,
        temperature=0.0, seed=0, pool="slots")
    rt_s.submit_batch(prompts, budgets=[1] * n_req)
    rt_s.drain()
    out["slots"] = dict(peak_children=rt_s.metrics.peak_children,
                        mem_rows=slot_rows)
    rt_p = ContinuousBatchingRuntime(
        model, params, n_slots=n_req, max_len=max_len, max_new=max_new,
        temperature=0.0, seed=0, pool="paged", block_size=block_size,
        n_blocks=mem_tokens // block_size + 1, prefill_slots=n_req)
    rt_p.submit_batch(prompts, budgets=[1] * n_req)
    rt_p.drain()
    out["paged"] = dict(peak_children=rt_p.metrics.peak_children,
                        peak_blocks=rt_p.metrics.peak_blocks,
                        n_blocks=mem_tokens // block_size)
    return out


def _prefix_heavy_probe(model, params, vocab, *, n_req, pre_len, tail_len,
                        max_new, n_slots, block_size, seed=0):
    """Replay one greedy prefix-heavy stream (shared preamble, distinct
    tails) with the radix prefix cache on and off. prefill_slots is kept
    below n_req so most requests are admitted after the preamble's blocks
    were published — the cross-request hit path, not the same-tick burst.
    Returns per-mode prefill accounting plus the bitwise-parity verdict."""
    from repro.serving import ContinuousBatchingRuntime

    rng = np.random.default_rng(seed)
    pre = rng.integers(0, vocab, size=(pre_len,)).astype(np.int32)
    prompts = [np.concatenate(
        [pre, rng.integers(0, vocab, size=(tail_len,)).astype(np.int32)])
        for _ in range(n_req)]

    def replay(prefix_cache: bool):
        rt = ContinuousBatchingRuntime(
            model, params, n_slots=n_slots, max_len=pre_len + tail_len
            + max_new + 1, max_new=max_new, temperature=0.0, seed=0,
            pool="paged", block_size=block_size, prefill_slots=2,
            prefix_cache=prefix_cache)
        ids = [rt.submit(p, budget=1) for p in prompts]
        rt.drain()
        s = rt.metrics.summary()
        return [list(rt.result(i).response) for i in ids], s

    hot_rows, hot = replay(True)
    cold_rows, cold = replay(False)
    reduction = 1.0 - hot["prefill_tokens"] / max(cold["prefill_tokens"], 1)
    return dict(
        hit_tokens=int(hot["prefix_hit_tokens"]),
        hits=int(hot["prefix_hits"]),
        prefill_hot=int(hot["prefill_tokens"]),
        prefill_cold=int(cold["prefill_tokens"]),
        reduction=reduction,
        bitwise_equal=(hot_rows == cold_rows),
        evicted=int(hot["radix_evicted_blocks"]))


def run(n_requests: int = 40, width: int = 12, max_new: int = 8,
        n_slots: int = 8, mean_gap: float = 0.05, seed: int = 0,
        smoke: bool = False, prefix_only: bool = False) -> None:
    import jax

    from repro.configs import get_config
    from repro.models import build_model
    from repro.serving import ServingEngine

    if smoke:
        n_requests, width, max_new, n_slots, mean_gap = 8, 6, 4, 4, 0.01

    cfg = dataclasses.replace(get_config("qwen2-0.5b").reduced(),
                              dtype="float32", n_layers=2)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(seed))

    if prefix_only:
        # the standalone prefix-heavy gate (CI runs this twice: XLA and
        # REPRO_DECODE_KERNEL=pallas interpret mode)
        pf = _prefix_heavy_probe(
            model, params, cfg.vocab_size,
            n_req=8 if smoke else 24, pre_len=8, tail_len=4,
            max_new=max_new if not smoke else 4, n_slots=4, block_size=4,
            seed=seed)
        emit("serving/prefix_heavy/hit_tokens", float(pf["hit_tokens"]),
             f"{pf['reduction']*100:.0f}% prefill reduction")
        save_result("bench_serving_prefix", pf)
        print(f"# prefix-heavy: {pf['hit_tokens']} prompt tokens skipped, "
              f"{pf['reduction']*100:.0f}% fewer prefill tokens computed, "
              f"bitwise_equal={pf['bitwise_equal']}")
        if smoke:
            assert pf["bitwise_equal"], "prefix-cache hit path diverged"
            assert pf["reduction"] >= 0.30, pf
            print("# prefix smoke OK")
        return

    engine = ServingEngine(model, params, max_new=max_new, temperature=1.0)
    max_len = width + max_new + 1

    prompts, budgets, arrivals = _make_workload(
        n_requests, cfg.vocab_size, width, mean_gap=mean_gap, seed=seed)

    # warm all drivers on a small all-at-once prefix so first-compile cost
    # of the *common* shapes is off the clock. The batch engine still
    # recompiles per distinct (batch, Σb) shape during the timed run —
    # that is inherent to barriered batching, and the runtimes' static
    # shapes are the fix being measured.
    w = min(6, n_requests)
    warm = slice(0, w)
    _run_batch_engine(engine, prompts[warm], budgets[warm], np.zeros(w))
    for pool in ("paged", "slots"):
        _run_runtime(model, params, prompts[warm], budgets[warm],
                     np.zeros(w), n_slots=n_slots, max_new=max_new,
                     temperature=1.0, max_len=max_len, pool=pool)

    batch = _run_batch_engine(engine, prompts, budgets, arrivals)
    paged = _run_runtime(model, params, prompts, budgets, arrivals,
                         n_slots=n_slots, max_new=max_new, temperature=1.0,
                         max_len=max_len, pool="paged")
    slots = _run_runtime(model, params, prompts, budgets, arrivals,
                         n_slots=n_slots, max_new=max_new, temperature=1.0,
                         max_len=max_len, pool="slots")

    cap = _capacity_probe(
        model, params, cfg.vocab_size,
        mem_tokens=(2 if smoke else 4) * 2 * max_len,
        max_len=2 * max_len, block_size=4, sp=max(2, width // 3),
        max_new=max_new, n_req=(6 if smoke else 12))

    pf = _prefix_heavy_probe(
        model, params, cfg.vocab_size, n_req=8 if smoke else 24,
        pre_len=8, tail_len=4, max_new=4, n_slots=4, block_size=4,
        seed=seed)

    for name, r in (("batch_engine", batch), ("paged_runtime", paged),
                    ("slot_runtime", slots)):
        emit(f"serving/{name}/wall", r["wall_s"] * 1e6,
             f"{r['tokens_per_sec']:.1f} tok/s")
        emit(f"serving/{name}/latency_p50", r["latency_p50_s"] * 1e6,
             f"p95={r['latency_p95_s']*1e3:.0f}ms")
    emit("serving/paged_runtime/occupancy", 0.0,
         f"{paged['occupancy']:.2f}")
    speedup = paged["tokens_per_sec"] / max(batch["tokens_per_sec"], 1e-9)
    parity = paged["tokens_per_sec"] / max(slots["tokens_per_sec"], 1e-9)
    emit("serving/speedup_vs_batch", 0.0, f"{speedup:.2f}x tokens/sec")
    emit("serving/paged_vs_slots", 0.0, f"{parity:.2f}x tokens/sec")
    emit("serving/capacity/slots", float(cap["slots"]["peak_children"]),
         f"{cap['slots']['peak_children']} children")
    emit("serving/capacity/paged", float(cap["paged"]["peak_children"]),
         f"{cap['paged']['peak_children']} children")
    emit("serving/prefix_heavy/hit_tokens", float(pf["hit_tokens"]),
         f"{pf['reduction']*100:.0f}% prefill reduction")
    save_result("bench_serving", dict(
        batch=batch, paged=paged, slots=slots, capacity=cap,
        prefix_heavy=pf,
        n_requests=n_requests, width=width, max_new=max_new,
        n_slots=n_slots, mean_gap=mean_gap,
        budgets_mean=float(np.mean(budgets)), speedup_vs_batch=speedup,
        paged_vs_slots=parity, smoke=smoke))
    print(f"# paged vs batch: {speedup:.2f}x tokens/sec; "
          f"paged vs slots: {parity:.2f}x; capacity at equal memory: "
          f"paged {cap['paged']['peak_children']} vs slot "
          f"{cap['slots']['peak_children']} concurrent children; "
          f"prefix-heavy: {pf['reduction']*100:.0f}% fewer prefill tokens")

    if smoke:
        # CI regression gate for the throughput path (fixed seeds, tiny
        # model): correctness is pytest's job, this guards the *runtime*
        # plumbing — all three drivers drain, the paged pool strictly
        # beats the slot pool on concurrency at equal memory, cleans up
        # its blocks, and the prefix cache pays for itself on a
        # prefix-heavy stream without perturbing outputs.
        assert batch["decode_tokens"] > 0 and paged["decode_tokens"] > 0
        assert paged["decode_tokens"] == slots["decode_tokens"]
        assert (cap["paged"]["peak_children"]
                > cap["slots"]["peak_children"]), cap
        assert pf["bitwise_equal"], "prefix-cache hit path diverged"
        assert pf["reduction"] >= 0.30, pf
        print("# smoke OK")


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny fixed-seed run with hard assertions (CI)")
    ap.add_argument("--prefix-heavy", action="store_true",
                    help="run only the prefix-heavy radix-cache probe "
                         "(pairs with REPRO_DECODE_KERNEL=pallas in CI)")
    args = ap.parse_args()
    run(smoke=args.smoke, prefix_only=args.prefix_heavy)
