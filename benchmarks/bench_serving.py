"""Serving benchmarks: continuous-batching runtime (paged + slot pools)
vs the batch-synchronous engine, plus an equal-memory capacity probe.

Poisson stream: all three systems replay the identical workload (same
prompts, same per-request budgets b_i ~ {1..4}, same exponential
inter-arrival gaps) in wall-clock time. The batch engine admits every
queued arrival as one synchronous batch (single prefill — the patched
path — then a barriered Σb_i-row decode), so each distinct (batch,
fan-out) shape costs a fresh jit compile and late arrivals wait out the
barrier. The runtime streams children through a fixed pool: one compiled
decode program total, freed slots backfilled immediately. The paged pool
additionally folds chunked prefill into that same program and shares
prompt blocks copy-on-write across fan-out.

Capacity probe: at equal device KV memory (token capacity), short
sequences let the paged pool sustain strictly more concurrent children
than the slot pool's full-`max_len` rows — the slot pool queues first.
A third arm re-runs the paged pool with the int8 quantized KV layout
(`kv_quant="int8"`) at the same *byte* budget — `pool.kv_bytes()` is
the ruler, since token capacity stops being one once a position's byte
cost depends on the layout — and must sustain >= 1.8x the fp arm's
concurrent children (the smoke gate; ~3.9x in practice for fp32 KV).

Prefix-heavy probe: realistic adaptive-best-of-k traffic shares a task
preamble / few-shot header across requests. The same greedy stream runs
with the radix prefix cache on and off; the cache must cut prefill tokens
computed by >= 30% (metered via `prefix_hit_tokens`) at bitwise-identical
outputs. `REPRO_DECODE_KERNEL=pallas` routes it through the paged chunk
kernel (interpret mode on CPU) — that combination is the CI gate.

Routing probe (`--routing` standalone, and part of the full/smoke run):
the procedure API's weak/strong pair on ONE shared paged pool. Single
procedures give the weak-only / strong-only reward endpoints (greedy:
deterministic 1-sample pools), then `Route` serves the stream at a sweep
of strong-fraction targets with an oracle gap predictor; the measured
reward must dominate `core.routing.eval_routing`'s random-mask baseline
at every fraction. Per-model metrics report the strong token share.

Horizon probe (`--horizon`, default 8): the same decode-heavy greedy
stream with horizon-fused decode on vs off. Fusion folds H decode steps
into one `lax.scan` dispatch with a single host sync per horizon, so on
the dispatch-bound probe it must deliver >= 1.5x tokens/sec at bitwise-
identical outputs with syncs/token <= 1/H — the smoke gate. Results land
in `experiments/results/BENCH_serving.json` (tokens/sec, p50 latency,
dispatches and syncs per token) which CI uploads as an artifact so the
perf trajectory is tracked across PRs.

    PYTHONPATH=src python benchmarks/bench_serving.py            # full
    PYTHONPATH=src python benchmarks/bench_serving.py --smoke    # CI gate
    PYTHONPATH=src python benchmarks/bench_serving.py --smoke --prefix-heavy
    PYTHONPATH=src python benchmarks/bench_serving.py --smoke --horizon 16
    PYTHONPATH=src python benchmarks/bench_serving.py --smoke --gauntlet

Traffic gauntlet (`--gauntlet`): a seeded trace with bursty arrivals,
mixed lengths, hot shared prefixes, a weak/strong mix, and tenant skew,
replayed with the traffic subsystem (priority scheduling + radix-cheap
preemption + SLO degradation) and strict FIFO. Gates: strictly higher
goodput-under-SLO than FIFO, >= 1 preemption, ledger balanced after
drain, and preempted-then-resumed requests bitwise identical.
"""
from __future__ import annotations

import dataclasses
import time
from typing import List

import numpy as np

from benchmarks.common import emit, merge_result, save_result, scaled_strong_lm


def _make_workload(n: int, vocab: int, width: int, *, mean_gap: float,
                   seed: int):
    rng = np.random.default_rng(seed)
    prompts = rng.integers(0, vocab, size=(n, width)).astype(np.int32)
    budgets = rng.integers(1, 5, size=n).astype(int)          # mixed 1..4
    arrivals = np.cumsum(rng.exponential(mean_gap, size=n))
    return prompts, budgets, arrivals


def _run_batch_engine(engine, prompts, budgets, arrivals):
    """Greedy batching baseline: serve everything that has arrived as one
    synchronous batch, repeat until drained."""
    n = len(prompts)
    lat: List[float] = []
    gen_tokens = 0
    t0 = time.perf_counter()
    i = 0
    while i < n:
        now = time.perf_counter() - t0
        k = i
        while k < n and arrivals[k] <= now:
            k += 1
        if k == i:
            time.sleep(min(max(arrivals[i] - now, 0.0), 0.005))
            continue
        logits, _, cache, sp = engine.prefill_for_generate(prompts[i:k])
        sel = np.repeat(np.arange(k - i), budgets[i:k])
        engine.generate_from_prefill(cache, logits, sel, sp, seed=0)
        done = time.perf_counter() - t0
        lat.extend(done - arrivals[j] for j in range(i, k))
        gen_tokens += int(budgets[i:k].sum()) * engine.max_new
        i = k
    wall = time.perf_counter() - t0
    return dict(tokens_per_sec=gen_tokens / wall, wall_s=wall,
                decode_tokens=gen_tokens,
                latency_p50_s=float(np.percentile(lat, 50)),
                latency_p95_s=float(np.percentile(lat, 95)))


def _run_runtime(model, params, prompts, budgets, arrivals, *, n_slots,
                 max_new, temperature, max_len, pool, block_size=8):
    from repro.serving import ContinuousBatchingRuntime

    rt = ContinuousBatchingRuntime(
        model, params, n_slots=n_slots, max_len=max_len, max_new=max_new,
        temperature=temperature, seed=0, pool=pool, block_size=block_size)
    n = len(prompts)
    ids = []
    t0 = time.perf_counter()
    i = 0
    while i < n or rt.pending():
        now = time.perf_counter() - t0
        while i < n and arrivals[i] <= now:
            ids.append(rt.submit(prompts[i], budget=int(budgets[i])))
            i += 1
        if rt.pending():
            rt.step()
        elif i < n:
            time.sleep(min(max(arrivals[i] - now, 0.0), 0.005))
    s = rt.metrics.summary()
    # latency relative to *arrival*, matching the batch baseline (a submit
    # can lag its arrival by up to one decode tick of the poll loop)
    lat = [rt.requests[rid].done_t - (t0 + arrivals[j])
           for j, rid in enumerate(ids)]
    return dict(tokens_per_sec=s["tokens_per_sec"], wall_s=s["wall_s"],
                decode_tokens=s["decode_tokens"],
                latency_p50_s=float(np.percentile(lat, 50)),
                latency_p95_s=float(np.percentile(lat, 95)),
                occupancy=s["occupancy"], peak_blocks=s["peak_blocks"])


def _capacity_probe(model, params, vocab, *, mem_tokens, max_len,
                    block_size, sp, max_new, n_req, seed=0):
    """Equal device KV memory (mem_tokens of cache positions) for both
    pools; short requests (sp + max_new << max_len). Reports the peak
    concurrent-child count each backend sustains — the slot pool tops out
    at mem_tokens/max_len full rows and queues the rest — plus each arm's
    actual store bytes (from the pool's own cache shapes/dtypes, so the
    equal-memory claim is checkable, not asserted). A third arm re-runs
    the paged pool with the int8 quantized KV layout at the fp arm's
    byte budget and a 4x deeper backlog, so its sustained concurrency is
    memory-limited like the fp arm's rather than request-limited."""
    import os

    # the probe IS the fp-vs-int8 A/B: each arm pins its layout via the
    # ctor arg, so an ambient REPRO_KV_QUANT (the CI quant lane sets it)
    # must not flip the fp arms — or crash the slot arm, which has no
    # block granularity to quantize
    env_quant = os.environ.pop("REPRO_KV_QUANT", None)
    try:
        return _capacity_arms(model, params, vocab, mem_tokens=mem_tokens,
                              max_len=max_len, block_size=block_size, sp=sp,
                              max_new=max_new, n_req=n_req, seed=seed)
    finally:
        if env_quant is not None:
            os.environ["REPRO_KV_QUANT"] = env_quant


def _capacity_arms(model, params, vocab, *, mem_tokens, max_len,
                   block_size, sp, max_new, n_req, seed):
    from repro.serving import ContinuousBatchingRuntime
    from repro.serving.paged_pool import kv_block_bytes

    rng = np.random.default_rng(seed)
    prompts = rng.integers(0, vocab, size=(n_req, sp)).astype(np.int32)
    out = {}
    slot_rows = mem_tokens // max_len
    rt_s = ContinuousBatchingRuntime(
        model, params, n_slots=slot_rows, max_len=max_len, max_new=max_new,
        temperature=0.0, seed=0, pool="slots")
    rt_s.submit_batch(prompts, budgets=[1] * n_req)
    rt_s.drain()
    out["slots"] = dict(peak_children=rt_s.metrics.peak_children,
                        mem_rows=slot_rows,
                        kv_bytes=slot_rows * kv_block_bytes(model, max_len))
    rt_p = ContinuousBatchingRuntime(
        model, params, n_slots=n_req, max_len=max_len, max_new=max_new,
        temperature=0.0, seed=0, pool="paged", block_size=block_size,
        n_blocks=mem_tokens // block_size + 1, prefill_slots=n_req)
    rt_p.submit_batch(prompts, budgets=[1] * n_req)
    rt_p.drain()
    byte_budget = rt_p.pool.kv_bytes()
    out["paged"] = dict(peak_children=rt_p.metrics.peak_children,
                        peak_blocks=rt_p.metrics.peak_blocks,
                        n_blocks=mem_tokens // block_size,
                        kv_bytes=byte_budget)
    # int8 arm: same store bytes (null block inside the budget, like the
    # fp arm's), block count derived from the quantized layout's own
    # per-block cost — never a hardcoded compression ratio
    n_req_q = 4 * n_req
    prompts_q = rng.integers(0, vocab, size=(n_req_q, sp)).astype(np.int32)
    rt_q = ContinuousBatchingRuntime(
        model, params, n_slots=n_req_q, max_len=max_len, max_new=max_new,
        temperature=0.0, seed=0, pool="paged", block_size=block_size,
        n_blocks=byte_budget // kv_block_bytes(model, block_size, "int8"),
        prefill_slots=n_req_q, kv_quant="int8")
    assert rt_q.pool.kv_bytes() <= byte_budget, (rt_q.pool.kv_bytes(),
                                                 byte_budget)
    rt_q.submit_batch(prompts_q, budgets=[1] * n_req_q)
    rt_q.drain()
    out["int8"] = dict(peak_children=rt_q.metrics.peak_children,
                       peak_blocks=rt_q.metrics.peak_blocks,
                       kv_bytes=rt_q.pool.kv_bytes(),
                       ratio_vs_fp=rt_q.metrics.peak_children
                       / max(out["paged"]["peak_children"], 1))
    return out


def _horizon_probe(base_cfg, *, horizon, n_req=4, sp=6, max_new=33,
                   n_slots=4, block_size=4, seed=0):
    """Decode-heavy probe for horizon-fused decode: same greedy stream
    through the paged runtime with fusion on (`horizon`) and off (1).

    This measures exactly what the fusion attacks — per-token scheduler
    overhead (jit dispatch, host sync, table rebuild/upload) — so it uses
    a deliberately small 1-layer model where that overhead, not model
    FLOPs, is the bottleneck (the production regime once device compute
    is async), and a *warm* runtime: wave 1 pays every compile (incl. the
    pool's per-instance jitted helpers), wave 2 is timed. max_new is
    chosen so every fused dispatch is the same full-width scan (one
    compile). Reports per-wave tokens/sec, request p50 latency, and
    dispatch/sync per-token rates; fused vs unfused outputs must stay
    bitwise equal."""
    import dataclasses as _dc
    import time as _time

    import jax

    from repro.models import build_model
    from repro.serving import ContinuousBatchingRuntime

    cfg = _dc.replace(base_cfg, dtype="float32", n_layers=1, d_model=128,
                      n_heads=2, n_kv_heads=2, d_ff=256)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(seed))
    rng = np.random.default_rng(seed)
    waves = [[rng.integers(0, cfg.vocab_size, (sp,)).astype(np.int32)
              for _ in range(n_req)] for _ in range(2)]

    def replay(h):
        rt = ContinuousBatchingRuntime(
            model, params, n_slots=n_slots, max_len=sp + max_new + 1,
            max_new=max_new, temperature=0.0, seed=0, pool="paged",
            block_size=block_size, horizon=h, prefix_cache=False)
        for p in waves[0]:
            rt.submit(p, budget=1)
        rt.drain()                          # warm: every compile lands here
        base = (rt.metrics.host_syncs, rt.metrics.device_dispatches,
                rt.metrics.decode_tokens)
        ids = [rt.submit(p, budget=1) for p in waves[1]]
        t0 = _time.perf_counter()
        rt.drain()
        wall = _time.perf_counter() - t0
        rows = [list(rt.result(i).response) for i in ids]
        toks = rt.metrics.decode_tokens - base[2]
        lat = [rt.requests[i].latency for i in ids]
        return rows, dict(
            tokens_per_sec=toks / wall, wall_s=wall, decode_tokens=toks,
            latency_p50_s=float(np.percentile(lat, 50)),
            syncs_per_token=(rt.metrics.host_syncs - base[0]) / toks,
            dispatches_per_token=(rt.metrics.device_dispatches - base[1])
            / toks,
            horizon_ticks=rt.metrics.horizon_ticks)

    replay(horizon)                         # jit warm across runtimes too
    replay(1)
    rows_h, fused = replay(horizon)
    rows_1, unfused = replay(1)
    # the width fused dispatches actually run at: the runtime caps H at
    # min remaining (max_new - 1 after the admission token) quantized to
    # a power of two — the smoke gate must assert against this, not the
    # raw CLI value (a legal --horizon 64 could never hit 1/64)
    eff = 1 << (max(1, min(horizon, max_new - 1)).bit_length() - 1)
    return dict(horizon=horizon, effective_horizon=eff,
                fused=fused, unfused=unfused,
                speedup=fused["tokens_per_sec"]
                / max(unfused["tokens_per_sec"], 1e-9),
                sync_reduction=unfused["syncs_per_token"]
                / max(fused["syncs_per_token"], 1e-9),
                bitwise_equal=(rows_h == rows_1))


def _mixed_probe(base_cfg, *, horizon=8, n_req=8, sp=40, max_new=25,
                 n_slots=4, block_size=4, seed=0):
    """Prefill/decode-interference probe for the fused mixed tick: a
    deterministic scheduler-tick arrival rule (submit the next request
    the moment no prefill is in flight) keeps a prompt streaming through
    chunked prefill for nearly the whole run, so resident decodes face
    continuous interference. Replayed three ways on the same tiny
    1-layer dispatch-bound model as `_horizon_probe`, warm wave first:

    * fused   — fuse_prefill=True: prefill rows ride the horizon scan
      (the mixed program); the pre-refactor whole-pool fallback never
      fires (`fallback_ticks == 0` on attention stacks);
    * fallback — fuse_prefill=False: the pre-refactor behavior, decode
      dropping to per-token dispatch whenever any slot prefills;
    * floor   — each request submitted only after the previous drained:
      zero overlap ever, so decode runs pure horizon ticks with the
      same per-request probe/admission overheads. Its syncs/token is
      the no-interference floor the fused run must stay within 1.2x of.

    Greedy outputs are (seed, request, child)-determined, so all three
    replays must be token-bitwise identical."""
    import dataclasses as _dc
    import time as _time

    import jax

    from repro.models import build_model
    from repro.serving import ContinuousBatchingRuntime

    cfg = _dc.replace(base_cfg, dtype="float32", n_layers=1, d_model=128,
                      n_heads=2, n_kv_heads=2, d_ff=256)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(seed))
    rng = np.random.default_rng(seed)
    waves = [[rng.integers(0, cfg.vocab_size, (sp,)).astype(np.int32)
              for _ in range(n_req)] for _ in range(2)]

    def replay(fuse, serial=False):
        rt = ContinuousBatchingRuntime(
            model, params, n_slots=n_slots, max_len=sp + max_new + 1,
            max_new=max_new, temperature=0.0, seed=0, pool="paged",
            block_size=block_size, horizon=horizon, prefix_cache=False,
            fuse_prefill=fuse, prefill_chunk=block_size)

        def wave(prompts):
            ids, i = [], 0
            while i < len(prompts) or rt.pending():
                if i < len(prompts) and not rt._pref and not rt.queue:
                    if serial and rt.pending():
                        pass            # strictly one request at a time
                    else:
                        ids.append(rt.submit(prompts[i], budget=1))
                        i += 1
                if rt.pending():
                    rt.step()
            return ids

        wave(waves[0])                  # warm: compiles land off-clock
        m = rt.metrics
        base = (m.host_syncs, m.decode_tokens, m.mixed_ticks,
                m.fallback_ticks, m.prefill_decode_overlap_tokens,
                m.horizon_ticks)
        t0 = _time.perf_counter()
        ids = wave(waves[1])
        wall = _time.perf_counter() - t0
        rows = [list(rt.result(i).response) for i in ids]
        rt.assert_ledger_balanced()
        toks = m.decode_tokens - base[1]
        fb = m.fallback_ticks - base[3]
        fused_ticks = (m.mixed_ticks - base[2]) + (m.horizon_ticks - base[5])
        return rows, dict(
            tokens_per_sec=toks / wall, wall_s=wall, decode_tokens=toks,
            syncs_per_token=(m.host_syncs - base[0]) / toks,
            mixed_ticks=m.mixed_ticks - base[2],
            fallback_ticks=fb,
            fallback_fraction=fb / max(1, fb + fused_ticks),
            overlap_tokens=m.prefill_decode_overlap_tokens - base[4])

    replay(True)                        # cross-runtime jit warm
    rows_f, fused = replay(True)
    rows_u, fallback = replay(False)
    rows_s, floor = replay(True, serial=True)
    return dict(
        horizon=horizon, fused=fused, fallback=fallback, floor=floor,
        speedup=fused["tokens_per_sec"]
        / max(fallback["tokens_per_sec"], 1e-9),
        sync_ratio=fused["syncs_per_token"]
        / max(floor["syncs_per_token"], 1e-9),
        bitwise_equal=(rows_f == rows_u == rows_s))


def _prefix_heavy_probe(model, params, vocab, *, n_req, pre_len, tail_len,
                        max_new, n_slots, block_size, seed=0):
    """Replay one greedy prefix-heavy stream (shared preamble, distinct
    tails) with the radix prefix cache on and off. prefill_slots is kept
    below n_req so most requests are admitted after the preamble's blocks
    were published — the cross-request hit path, not the same-tick burst.
    Returns per-mode prefill accounting plus the bitwise-parity verdict."""
    from repro.serving import ContinuousBatchingRuntime

    rng = np.random.default_rng(seed)
    pre = rng.integers(0, vocab, size=(pre_len,)).astype(np.int32)
    prompts = [np.concatenate(
        [pre, rng.integers(0, vocab, size=(tail_len,)).astype(np.int32)])
        for _ in range(n_req)]

    def replay(prefix_cache: bool):
        rt = ContinuousBatchingRuntime(
            model, params, n_slots=n_slots, max_len=pre_len + tail_len
            + max_new + 1, max_new=max_new, temperature=0.0, seed=0,
            pool="paged", block_size=block_size, prefill_slots=2,
            prefix_cache=prefix_cache)
        ids = [rt.submit(p, budget=1) for p in prompts]
        rt.drain()
        s = rt.metrics.summary()
        return [list(rt.result(i).response) for i in ids], s

    hot_rows, hot = replay(True)
    cold_rows, cold = replay(False)
    reduction = 1.0 - hot["prefill_tokens"] / max(cold["prefill_tokens"], 1)
    return dict(
        hit_tokens=int(hot["prefix_hit_tokens"]),
        hits=int(hot["prefix_hits"]),
        prefill_hot=int(hot["prefill_tokens"]),
        prefill_cold=int(cold["prefill_tokens"]),
        reduction=reduction,
        bitwise_equal=(hot_rows == cold_rows),
        evicted=int(hot["radix_evicted_blocks"]))


def _routing_probe(model, params, vocab, *, n_req, sp_lo, sp_hi, max_new,
                   n_slots, block_size, fracs=(0.0, 0.25, 0.5, 0.75, 1.0),
                   seed=0):
    """Weak/strong routing on the procedure API: one runtime, two
    registry models sharing the paged pool. The weak-only and strong-only
    endpoints come from `Single` runs (which double as the deterministic
    greedy reward pools); a sweep over strong-fraction targets then
    serves the same stream through `Route` with an oracle gap predictor
    and compares the measured reward to `core.routing.eval_routing`'s
    random-mask baseline at the same fraction — adaptive must dominate.
    Also reports the per-model compute split (`ServingMetrics.per_model`)
    so the strong fraction is visible in tokens, not just request
    counts."""
    from repro.core.routing import eval_routing
    from repro.serving import ContinuousBatchingRuntime, Route, Single

    # shared fixture (benchmarks/common.py -> repro.models.fixtures): the
    # ×3 param scaling breaks the tied-embedding greedy-echo degeneracy
    # that would zero the weak/strong reward gap
    _, s_model, s_params = scaled_strong_lm(n_layers=1, seed=seed + 7)
    rng = np.random.default_rng(seed)
    prompts = [rng.integers(0, vocab, (L,)).astype(np.int32)
               for L in rng.integers(sp_lo, sp_hi, size=n_req)]
    max_len = sp_hi + max_new + 1

    def reward(q, rows):
        return [float(((int(np.sum(r)) % 97) + 3 * q) % 13) for r in rows]

    def multi_rt():
        rt = ContinuousBatchingRuntime(
            model, params, n_slots=n_slots, max_len=max_len,
            max_new=max_new, temperature=0.0, seed=0, pool="paged",
            block_size=block_size, reward_fn=reward)
        rt.register_model("strong", s_model, s_params)
        return rt

    def serve(proc_of):
        rt = multi_rt()
        ids = [rt.submit(p, query=i, procedure=proc_of(i))
               for i, p in enumerate(prompts)]
        rt.drain()
        rews = np.asarray([rt.result(i).reward for i in ids])
        routes = [rt.result(i).proc.get("route", "weak") for i in ids]
        return rews, routes, rt.metrics

    rew_w, _, _ = serve(lambda i: Single("default"))
    rew_s, _, _ = serve(lambda i: Single("strong"))
    gap = rew_s - rew_w
    pred = {i: float(gap[i]) for i in range(n_req)}

    rng2 = np.random.default_rng(seed + 1)
    curve = {"frac": [], "adaptive": [], "random": [],
             "strong_frac_real": [], "strong_token_share": []}
    for f in fracs:
        thr = Route.calibrate_threshold(gap, f)
        rews, routes, metrics = serve(lambda i: Route(
            weak="default", strong="strong", threshold=thr,
            predictor=lambda r, h: pred[r.query]))
        mask = np.asarray([r == "strong" for r in routes])
        k = int(mask.sum())
        rnd = []
        for _ in range(32):
            m = np.zeros(n_req, bool)
            m[rng2.permutation(n_req)[:k]] = True
            rnd.append(eval_routing(rew_w[:, None], rew_s[:, None], m))
        pm = {mid: mm.summary() for mid, mm in metrics.per_model.items()}
        tot = sum(m["total_tokens"] for m in pm.values())
        share = pm.get("strong", {}).get("total_tokens", 0) / max(tot, 1)
        curve["frac"].append(float(f))
        curve["adaptive"].append(float(rews.mean()))
        curve["random"].append(float(np.mean(rnd)))
        curve["strong_frac_real"].append(k / n_req)
        curve["strong_token_share"].append(float(share))
    return dict(curve=curve,
                weak_only=float(rew_w.mean()),
                strong_only=float(rew_s.mean()),
                gap_nonzero=bool(np.any(gap != 0)),
                per_model_last=pm)


def _traffic_gauntlet(model, params, vocab, *, seed=0, n_bulk=10, n_acme=6,
                      n_misc=4, smoke=False):
    """Trace-replay gauntlet for the traffic subsystem: one seeded trace
    with bursty arrivals, mixed prompt/output lengths, hot shared
    prefixes, a weak/strong procedure mix, and tenant skew — replayed
    twice through the SAME runtime shape, once with the traffic subsystem
    (priority + preemption + SLO degradation) and once strict-FIFO.

    The trace: a 'bulk' tenant floods priority-0 best-of-k work at t=0
    (resolved via budget_fn, so SLO degradation can shave it), an 'acme'
    tenant sends priority-2 requests sharing a hot 2-block prefix
    shortly after (the latency-sensitive class), and a 'misc' tenant
    sends priority-1 Single('strong') requests (the weak/strong mix).

    Goodput-under-SLO is scored post hoc: every acme request's deadline
    is 0.6x its OWN latency under the FIFO replay (bulk/misc get
    effectively-infinite deadlines). SLOs never influence scheduling, so
    this is a pure relative gate — 'priority scheduling + preemption must
    cut high-priority latency under overload by >= 40% vs FIFO' — robust
    to machine speed: arrivals are scheduler-tick based (deterministic
    schedules) and the deadline scale comes from the FIFO run itself.

    Correctness rides along: both replays drain with the block ledger
    audited exactly, and every (request, child-index) pair present in
    both runs must be token-bitwise identical under greedy — preemption
    and degradation may change child COUNTS, never common children."""
    from repro.serving import (ContinuousBatchingRuntime, Single,
                               TrafficConfig)

    rng = np.random.default_rng(seed)
    hot = rng.integers(0, vocab, size=(8,)).astype(np.int32)
    # arrivals are in SCHEDULER TICKS, not wall seconds — the replay is
    # bitwise deterministic across machine speeds (a wall-clock replay
    # made preemption counts flaky: a fast box drained the burst before
    # the high-priority tenant ever arrived). Wall time is only measured.
    trace = []                  # (arrival_tick, tenant, priority, kwargs)
    for i in range(n_bulk):     # burst at tick 0: longer outputs, fan-out
        p = rng.integers(0, vocab, size=(int(rng.integers(6, 12)),))
        trace.append((0, "bulk", 0,
                      dict(prompt=p.astype(np.int32), max_new=8)))
    for i in range(n_acme):     # hot shared prefix, short tails + outputs
        tail = rng.integers(0, vocab, size=(int(rng.integers(2, 4)),))
        p = np.concatenate([hot, tail.astype(np.int32)])
        trace.append((6 + 2 * i, "acme", 2,
                      dict(prompt=p, max_new=4, budget=1)))
    for i in range(n_misc):     # strong-model singles, mid priority
        p = rng.integers(0, vocab, size=(int(rng.integers(4, 8)),))
        trace.append((8 + 4 * i, "misc", 1,
                      dict(prompt=p.astype(np.int32), max_new=4,
                           procedure=Single("strong"))))
    trace.sort(key=lambda e: e[0])
    _, s_model, s_params = scaled_strong_lm(n_layers=1, seed=seed + 7)

    def replay(traffic):
        rt = ContinuousBatchingRuntime(
            model, params, n_slots=4, max_len=24, max_new=8,
            temperature=0.0, seed=0, pool="paged", block_size=4,
            n_blocks=30, prefill_window=4, horizon=2,
            budget_fn=lambda r, h: 3, traffic=traffic)
        rt.register_model("strong", s_model, s_params)
        ids, meta = [], []
        i = tick = 0
        while i < len(trace) or rt.pending():
            while i < len(trace) and trace[i][0] <= tick:
                _, tenant, pri, kw = trace[i]
                sub_t = time.perf_counter()
                ids.append(rt.submit(tenant=tenant, priority=pri,
                                     procedure=kw.get("procedure"),
                                     prompt=kw["prompt"],
                                     max_new=kw["max_new"],
                                     budget=kw.get("budget")))
                meta.append((sub_t, tenant))
                i += 1
            if rt.pending():
                rt.step()
            tick += 1
        rt.assert_ledger_balanced()
        lat = {rid: rt.requests[rid].done_t - sub_t
               for rid, (sub_t, _) in zip(ids, meta)}
        kids = {rid: [list(c.tokens) for c in rt.requests[rid].children]
                for rid in ids}
        return dict(ids=ids, meta=meta, lat=lat, kids=kids,
                    summary=rt.metrics.summary(),
                    queue_waits=list(rt.metrics.queue_waits),
                    ttfts=list(rt.metrics.ttfts))

    fifo = replay(None)
    traf = replay(TrafficConfig(target_load=0.5, min_horizon=1,
                                weight_base=4.0))

    # post-hoc SLOs from the FIFO replay (see docstring)
    slo = {rid: (0.6 * fifo["lat"][rid] if tenant == "acme" else 1e6)
           for rid, (_, tenant) in zip(fifo["ids"], fifo["meta"])}
    goodput_fifo = sum(fifo["lat"][r] <= slo[r] for r in fifo["ids"])
    goodput_traf = sum(traf["lat"][r] <= slo[r] for r in traf["ids"])
    acme = [r for r, (_, t) in zip(fifo["ids"], fifo["meta"])
            if t == "acme"]
    bitwise = all(
        fifo["kids"][r][j] == traf["kids"][r][j]
        for r in fifo["ids"]
        for j in range(min(len(fifo["kids"][r]), len(traf["kids"][r]))))
    s = traf["summary"]

    def pct(xs, q):
        return float(np.percentile(xs, q)) if xs else float("nan")

    out = dict(
        n_requests=len(trace), seed=seed,
        goodput_under_slo=goodput_traf, goodput_fifo=goodput_fifo,
        acme_latency_fifo_p50=pct([fifo["lat"][r] for r in acme], 50),
        acme_latency_traffic_p50=pct([traf["lat"][r] for r in acme], 50),
        queue_wait_p50_s=pct(traf["queue_waits"], 50),
        queue_wait_p99_s=pct(traf["queue_waits"], 99),
        ttft_p50_s=pct(traf["ttfts"], 50),
        ttft_p99_s=pct(traf["ttfts"], 99),
        preemptions=int(s["preemptions"]),
        preempted_blocks_freed=int(s["preempted_blocks_freed"]),
        degraded_requests=int(s["degraded_requests"]),
        degraded_share=float(s["degraded_share"]),
        bitwise_equal=bool(bitwise), smoke=smoke)
    return out


def _assert_mixed(mx) -> None:
    """The --mixed acceptance gate: under continuous prefill/decode
    interference the fused pipeline never drops to the pre-refactor
    per-token fallback, beats it by >= 1.5x tokens/sec, and keeps
    syncs/token within 1.2x of the no-overlap pure-horizon floor — all
    token-bitwise identical to both baselines."""
    assert mx["bitwise_equal"], "mixed fused tick perturbed greedy tokens"
    assert mx["fused"]["fallback_ticks"] == 0, mx
    assert mx["fused"]["mixed_ticks"] >= 1, mx
    assert mx["fused"]["overlap_tokens"] > 0, mx
    assert mx["fallback"]["fallback_ticks"] >= 1, mx["fallback"]
    assert mx["speedup"] >= 1.5, mx
    assert mx["sync_ratio"] <= 1.2, mx


def run(n_requests: int = 40, width: int = 12, max_new: int = 8,
        n_slots: int = 8, mean_gap: float = 0.05, seed: int = 0,
        smoke: bool = False, prefix_only: bool = False,
        routing_only: bool = False, gauntlet_only: bool = False,
        mixed_only: bool = False, capacity_only: bool = False,
        horizon: int = 8) -> None:
    import jax

    from repro.configs import get_config
    from repro.models import build_model
    from repro.serving import ServingEngine

    if smoke:
        n_requests, width, max_new, n_slots, mean_gap = 8, 6, 4, 4, 0.01

    cfg = dataclasses.replace(get_config("qwen2-0.5b").reduced(),
                              dtype="float32", n_layers=2)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(seed))

    if capacity_only:
        # the standalone equal-memory capacity gate (CI runs this in the
        # quantized-KV lane; the probe pins each arm's layout itself)
        max_len = width + max_new + 1
        cap = _capacity_probe(
            model, params, cfg.vocab_size,
            mem_tokens=(2 if smoke else 4) * 2 * max_len,
            max_len=2 * max_len, block_size=4, sp=max(2, width // 3),
            max_new=max_new, n_req=(6 if smoke else 12))
        emit("serving/capacity/int8", float(cap["int8"]["peak_children"]),
             f"{cap['int8']['ratio_vs_fp']:.2f}x fp at equal bytes")
        save_result("bench_serving_capacity", cap)
        # merge into the CI artifact (the main smoke run writes the rest)
        merge_result("BENCH_serving", {
            "capacity_fp_children": cap["paged"]["peak_children"],
            "capacity_quant_children": cap["int8"]["peak_children"],
            "capacity_quant_ratio": cap["int8"]["ratio_vs_fp"],
            "capacity_kv_bytes": cap["paged"]["kv_bytes"]})
        print(f"# capacity at equal memory: paged "
              f"{cap['paged']['peak_children']} vs slot "
              f"{cap['slots']['peak_children']} children; int8 KV at "
              f"equal bytes ({cap['int8']['kv_bytes']} <= "
              f"{cap['paged']['kv_bytes']}): "
              f"{cap['int8']['peak_children']} children = "
              f"{cap['int8']['ratio_vs_fp']:.2f}x fp")
        if smoke:
            assert (cap["paged"]["peak_children"]
                    > cap["slots"]["peak_children"]), cap
            assert cap["int8"]["kv_bytes"] <= cap["paged"]["kv_bytes"], cap
            assert cap["int8"]["ratio_vs_fp"] >= 1.8, cap
            print("# capacity smoke OK")
        return

    if routing_only:
        # the standalone routing gate: weak-only vs routed vs strong-only
        # reward curves on a shared two-model pool (procedure API)
        ro = _routing_probe(
            model, params, cfg.vocab_size, n_req=8 if smoke else 16,
            sp_lo=5, sp_hi=11, max_new=4 if smoke else max_new,
            n_slots=4, block_size=4, seed=seed)
        emit("serving/routing/adaptive_mid",
             float(ro["curve"]["adaptive"][len(ro["curve"]["frac"]) // 2]),
             f"weak {ro['weak_only']:.2f} strong {ro['strong_only']:.2f}")
        save_result("bench_serving_routing", ro)
        print(f"# routing: weak-only {ro['weak_only']:.3f}, strong-only "
              f"{ro['strong_only']:.3f}; adaptive vs random by frac: "
              + ", ".join(
                  f"{f:.2f}:{a:.2f}/{r:.2f}" for f, a, r in
                  zip(ro["curve"]["frac"], ro["curve"]["adaptive"],
                      ro["curve"]["random"])))
        if smoke:
            assert ro["gap_nonzero"], "weak/strong reward gap is zero"
            for a, r in zip(ro["curve"]["adaptive"], ro["curve"]["random"]):
                assert a >= r - 1e-9, ro["curve"]
            assert max(a - r for a, r in zip(ro["curve"]["adaptive"],
                                             ro["curve"]["random"])) > 0, \
                ro["curve"]
            print("# routing smoke OK")
        return

    if gauntlet_only:
        # the traffic-subsystem gate: priority + preemption + SLO
        # degradation vs strict FIFO on one seeded trace
        tg = _traffic_gauntlet(
            model, params, cfg.vocab_size, seed=seed,
            n_bulk=10 if smoke else 16, n_acme=6 if smoke else 10,
            n_misc=4 if smoke else 8, smoke=smoke)
        emit("serving/gauntlet/goodput", float(tg["goodput_under_slo"]),
             f"fifo {tg['goodput_fifo']}")
        emit("serving/gauntlet/preemptions", float(tg["preemptions"]),
             f"{tg['preempted_blocks_freed']} blocks freed")
        emit("serving/gauntlet/acme_p50",
             tg["acme_latency_traffic_p50"] * 1e6,
             f"fifo {tg['acme_latency_fifo_p50']*1e3:.0f}ms")
        save_result("bench_serving_gauntlet", tg)
        # merge into the CI artifact (the main smoke run writes the rest)
        merge_result("BENCH_serving", {"traffic_gauntlet": tg})
        print(f"# gauntlet: goodput-under-SLO {tg['goodput_under_slo']} vs "
              f"FIFO {tg['goodput_fifo']} on {tg['n_requests']} requests; "
              f"acme p50 {tg['acme_latency_traffic_p50']*1e3:.0f}ms vs "
              f"{tg['acme_latency_fifo_p50']*1e3:.0f}ms FIFO; "
              f"{tg['preemptions']} preemptions, degraded share "
              f"{tg['degraded_share']:.2f}, "
              f"bitwise_equal={tg['bitwise_equal']}")
        if smoke:
            assert tg["bitwise_equal"], \
                "preemption/degradation perturbed greedy tokens"
            assert tg["goodput_under_slo"] > tg["goodput_fifo"], tg
            assert tg["preemptions"] >= 1, tg
            print("# gauntlet smoke OK")
        return

    if mixed_only:
        # the standalone fused-mixed-tick gate: prefill/decode
        # interference must no longer pay the pre-refactor whole-pool
        # per-token fallback tax
        mx = _mixed_probe(get_config("qwen2-0.5b").reduced(),
                          horizon=max(2, horizon), seed=seed)
        emit("serving/mixed/speedup", float(mx["speedup"]),
             f"{mx['speedup']:.2f}x tokens/sec under interference")
        emit("serving/mixed/syncs_per_token",
             float(mx["fused"]["syncs_per_token"]),
             f"{mx['sync_ratio']:.2f}x the no-overlap floor")
        save_result("bench_serving_mixed", mx)
        merge_result("BENCH_serving", {"mixed": mx})
        print(f"# mixed H={mx['horizon']}: {mx['speedup']:.2f}x tokens/sec "
              "vs pre-refactor fallback under continuous prefill "
              "interference; fused fallback_ticks="
              f"{mx['fused']['fallback_ticks']}, mixed_ticks="
              f"{mx['fused']['mixed_ticks']}, overlap_tokens="
              f"{mx['fused']['overlap_tokens']}; syncs/token "
              f"{mx['fused']['syncs_per_token']:.3f} = "
              f"{mx['sync_ratio']:.2f}x the pure-decode floor; "
              f"bitwise_equal={mx['bitwise_equal']}")
        if smoke:
            _assert_mixed(mx)
            print("# mixed smoke OK")
        return

    if prefix_only:
        # the standalone prefix-heavy gate (CI runs this twice: XLA and
        # REPRO_DECODE_KERNEL=pallas interpret mode)
        pf = _prefix_heavy_probe(
            model, params, cfg.vocab_size,
            n_req=8 if smoke else 24, pre_len=8, tail_len=4,
            max_new=max_new if not smoke else 4, n_slots=4, block_size=4,
            seed=seed)
        emit("serving/prefix_heavy/hit_tokens", float(pf["hit_tokens"]),
             f"{pf['reduction']*100:.0f}% prefill reduction")
        save_result("bench_serving_prefix", pf)
        print(f"# prefix-heavy: {pf['hit_tokens']} prompt tokens skipped, "
              f"{pf['reduction']*100:.0f}% fewer prefill tokens computed, "
              f"bitwise_equal={pf['bitwise_equal']}")
        if smoke:
            assert pf["bitwise_equal"], "prefix-cache hit path diverged"
            assert pf["reduction"] >= 0.30, pf
            print("# prefix smoke OK")
        return

    engine = ServingEngine(model, params, max_new=max_new, temperature=1.0)
    max_len = width + max_new + 1

    prompts, budgets, arrivals = _make_workload(
        n_requests, cfg.vocab_size, width, mean_gap=mean_gap, seed=seed)

    # warm all drivers on a small all-at-once prefix so first-compile cost
    # of the *common* shapes is off the clock. The batch engine still
    # recompiles per distinct (batch, Σb) shape during the timed run —
    # that is inherent to barriered batching, and the runtimes' static
    # shapes are the fix being measured.
    w = min(6, n_requests)
    warm = slice(0, w)
    _run_batch_engine(engine, prompts[warm], budgets[warm], np.zeros(w))
    for pool in ("paged", "slots"):
        _run_runtime(model, params, prompts[warm], budgets[warm],
                     np.zeros(w), n_slots=n_slots, max_new=max_new,
                     temperature=1.0, max_len=max_len, pool=pool)

    batch = _run_batch_engine(engine, prompts, budgets, arrivals)
    paged = _run_runtime(model, params, prompts, budgets, arrivals,
                         n_slots=n_slots, max_new=max_new, temperature=1.0,
                         max_len=max_len, pool="paged")
    slots = _run_runtime(model, params, prompts, budgets, arrivals,
                         n_slots=n_slots, max_new=max_new, temperature=1.0,
                         max_len=max_len, pool="slots")

    cap = _capacity_probe(
        model, params, cfg.vocab_size,
        mem_tokens=(2 if smoke else 4) * 2 * max_len,
        max_len=2 * max_len, block_size=4, sp=max(2, width // 3),
        max_new=max_new, n_req=(6 if smoke else 12))

    pf = _prefix_heavy_probe(
        model, params, cfg.vocab_size, n_req=8 if smoke else 24,
        pre_len=8, tail_len=4, max_new=4, n_slots=4, block_size=4,
        seed=seed)

    hz = _horizon_probe(get_config("qwen2-0.5b").reduced(), horizon=horizon,
                        seed=seed)

    mx = _mixed_probe(get_config("qwen2-0.5b").reduced(),
                      horizon=max(2, horizon), seed=seed)

    ro = _routing_probe(
        model, params, cfg.vocab_size, n_req=8 if smoke else 16,
        sp_lo=5, sp_hi=11, max_new=4 if smoke else max_new,
        n_slots=4, block_size=4, seed=seed)

    for name, r in (("batch_engine", batch), ("paged_runtime", paged),
                    ("slot_runtime", slots)):
        emit(f"serving/{name}/wall", r["wall_s"] * 1e6,
             f"{r['tokens_per_sec']:.1f} tok/s")
        emit(f"serving/{name}/latency_p50", r["latency_p50_s"] * 1e6,
             f"p95={r['latency_p95_s']*1e3:.0f}ms")
    emit("serving/paged_runtime/occupancy", 0.0,
         f"{paged['occupancy']:.2f}")
    speedup = paged["tokens_per_sec"] / max(batch["tokens_per_sec"], 1e-9)
    parity = paged["tokens_per_sec"] / max(slots["tokens_per_sec"], 1e-9)
    emit("serving/speedup_vs_batch", 0.0, f"{speedup:.2f}x tokens/sec")
    emit("serving/paged_vs_slots", 0.0, f"{parity:.2f}x tokens/sec")
    emit("serving/capacity/slots", float(cap["slots"]["peak_children"]),
         f"{cap['slots']['peak_children']} children")
    emit("serving/capacity/paged", float(cap["paged"]["peak_children"]),
         f"{cap['paged']['peak_children']} children")
    emit("serving/capacity/int8", float(cap["int8"]["peak_children"]),
         f"{cap['int8']['ratio_vs_fp']:.2f}x fp at equal bytes")
    emit("serving/prefix_heavy/hit_tokens", float(pf["hit_tokens"]),
         f"{pf['reduction']*100:.0f}% prefill reduction")
    emit("serving/horizon/speedup", float(hz["speedup"]),
         f"{hz['speedup']:.2f}x tokens/sec at H={horizon}")
    emit("serving/horizon/syncs_per_token",
         float(hz["fused"]["syncs_per_token"]),
         f"vs {hz['unfused']['syncs_per_token']:.2f} unfused")
    emit("serving/mixed/speedup", float(mx["speedup"]),
         f"{mx['speedup']:.2f}x tokens/sec under prefill interference")
    emit("serving/mixed/syncs_per_token",
         float(mx["fused"]["syncs_per_token"]),
         f"{mx['sync_ratio']:.2f}x the no-overlap floor")
    mid_i = len(ro["curve"]["frac"]) // 2
    emit("serving/routing/adaptive_mid",
         float(ro["curve"]["adaptive"][mid_i]),
         f"random {ro['curve']['random'][mid_i]:.2f} at frac "
         f"{ro['curve']['frac'][mid_i]:.2f}")
    save_result("bench_serving", dict(
        batch=batch, paged=paged, slots=slots, capacity=cap,
        prefix_heavy=pf, horizon=hz, mixed=mx, routing=ro,
        n_requests=n_requests, width=width, max_new=max_new,
        n_slots=n_slots, mean_gap=mean_gap,
        budgets_mean=float(np.mean(budgets)), speedup_vs_batch=speedup,
        paged_vs_slots=parity, smoke=smoke))
    # the machine-readable perf trajectory CI uploads across PRs
    save_result("BENCH_serving", dict(
        horizon=horizon,
        effective_horizon=hz["effective_horizon"],
        fused_tokens_per_sec=hz["fused"]["tokens_per_sec"],
        unfused_tokens_per_sec=hz["unfused"]["tokens_per_sec"],
        horizon_speedup=hz["speedup"],
        fused_latency_p50_s=hz["fused"]["latency_p50_s"],
        unfused_latency_p50_s=hz["unfused"]["latency_p50_s"],
        fused_syncs_per_token=hz["fused"]["syncs_per_token"],
        unfused_syncs_per_token=hz["unfused"]["syncs_per_token"],
        fused_dispatches_per_token=hz["fused"]["dispatches_per_token"],
        unfused_dispatches_per_token=hz["unfused"]["dispatches_per_token"],
        bitwise_equal=hz["bitwise_equal"],
        mixed_speedup=mx["speedup"],
        mixed_sync_ratio=mx["sync_ratio"],
        mixed_fallback_ticks=mx["fused"]["fallback_ticks"],
        mixed_fallback_fraction=mx["fused"]["fallback_fraction"],
        mixed_overlap_tokens=mx["fused"]["overlap_tokens"],
        mixed_bitwise_equal=mx["bitwise_equal"],
        capacity_fp_children=cap["paged"]["peak_children"],
        capacity_quant_children=cap["int8"]["peak_children"],
        capacity_quant_ratio=cap["int8"]["ratio_vs_fp"],
        capacity_kv_bytes=cap["paged"]["kv_bytes"],
        stream_tokens_per_sec=paged["tokens_per_sec"],
        stream_latency_p50_s=paged["latency_p50_s"],
        speedup_vs_batch=speedup, smoke=smoke,
        routing_curve=ro["curve"],
        routing_weak_only=ro["weak_only"],
        routing_strong_only=ro["strong_only"]))
    print(f"# paged vs batch: {speedup:.2f}x tokens/sec; "
          f"paged vs slots: {parity:.2f}x; capacity at equal memory: "
          f"paged {cap['paged']['peak_children']} vs slot "
          f"{cap['slots']['peak_children']} concurrent children; "
          f"int8 KV at equal bytes ({cap['int8']['kv_bytes']} <= "
          f"{cap['paged']['kv_bytes']}): {cap['int8']['peak_children']} "
          f"children = {cap['int8']['ratio_vs_fp']:.2f}x fp; "
          f"prefix-heavy: {pf['reduction']*100:.0f}% fewer prefill tokens")
    print(f"# horizon H={horizon}: {hz['speedup']:.2f}x tokens/sec on the "
          "decode-heavy probe, syncs/token "
          f"{hz['fused']['syncs_per_token']:.3f} vs "
          f"{hz['unfused']['syncs_per_token']:.3f} "
          f"({hz['sync_reduction']:.1f}x fewer), "
          f"bitwise_equal={hz['bitwise_equal']}")
    print(f"# mixed H={mx['horizon']}: {mx['speedup']:.2f}x tokens/sec vs "
          "pre-refactor fallback under continuous prefill interference; "
          f"fused fallback_ticks={mx['fused']['fallback_ticks']}, "
          f"fallback_fraction={mx['fused']['fallback_fraction']:.2f}, "
          f"syncs/token {mx['fused']['syncs_per_token']:.3f} = "
          f"{mx['sync_ratio']:.2f}x the pure-decode floor; "
          f"bitwise_equal={mx['bitwise_equal']}")
    print(f"# routing: weak-only {ro['weak_only']:.3f}, strong-only "
          f"{ro['strong_only']:.3f}; adaptive/random by frac: "
          + ", ".join(f"{f:.2f}:{a:.2f}/{r:.2f}" for f, a, r in
                      zip(ro["curve"]["frac"], ro["curve"]["adaptive"],
                          ro["curve"]["random"])))

    if smoke:
        # horizon-fusion acceptance gate: saved dispatches must be real
        # wall-clock at identical tokens, and syncs amortize to <= 1/H
        # (H = the width fused dispatches actually ran at; --horizon 1
        # disables fusion, so there is no speedup to gate)
        assert hz["bitwise_equal"], "horizon fusion perturbed greedy tokens"
        if hz["effective_horizon"] > 1:
            assert hz["speedup"] >= 1.5, hz
            assert (hz["fused"]["syncs_per_token"]
                    <= 1.0 / hz["effective_horizon"]), hz
        # fused-mixed-tick acceptance: no fallback tax under continuous
        # prefill/decode interference
        _assert_mixed(mx)
        # CI regression gate for the throughput path (fixed seeds, tiny
        # model): correctness is pytest's job, this guards the *runtime*
        # plumbing — all three drivers drain, the paged pool strictly
        # beats the slot pool on concurrency at equal memory, cleans up
        # its blocks, and the prefix cache pays for itself on a
        # prefix-heavy stream without perturbing outputs.
        assert batch["decode_tokens"] > 0 and paged["decode_tokens"] > 0
        assert paged["decode_tokens"] == slots["decode_tokens"]
        assert (cap["paged"]["peak_children"]
                > cap["slots"]["peak_children"]), cap
        # int8 KV acceptance: at the fp arm's exact byte budget the
        # quantized layout must sustain >= 1.8x its concurrency (the
        # fp32 store compresses ~3.9x; 1.8 leaves headroom for scale
        # overhead and block-granularity loss at other configs)
        assert cap["int8"]["kv_bytes"] <= cap["paged"]["kv_bytes"], cap
        assert cap["int8"]["ratio_vs_fp"] >= 1.8, cap
        assert pf["bitwise_equal"], "prefix-cache hit path diverged"
        assert pf["reduction"] >= 0.30, pf
        # routing acceptance: adaptive dominates the random baseline at
        # every strong-fraction target (strictly somewhere), on a genuine
        # weak/strong reward gap
        assert ro["gap_nonzero"], "weak/strong reward gap is zero"
        for a, r in zip(ro["curve"]["adaptive"], ro["curve"]["random"]):
            assert a >= r - 1e-9, ro["curve"]
        assert max(a - r for a, r in zip(ro["curve"]["adaptive"],
                                         ro["curve"]["random"])) > 0, \
            ro["curve"]
        print("# smoke OK")


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny fixed-seed run with hard assertions (CI)")
    ap.add_argument("--prefix-heavy", action="store_true",
                    help="run only the prefix-heavy radix-cache probe "
                         "(pairs with REPRO_DECODE_KERNEL=pallas in CI)")
    ap.add_argument("--routing", action="store_true",
                    help="run only the weak/strong routing probe "
                         "(two-model shared pool, procedure API)")
    ap.add_argument("--gauntlet", action="store_true",
                    help="run only the traffic-subsystem trace-replay "
                         "gauntlet (priority + preemption + SLO vs FIFO)")
    ap.add_argument("--mixed", action="store_true",
                    help="run only the fused mixed-tick probe (continuous "
                         "prefill/decode interference vs the pre-refactor "
                         "per-token fallback)")
    ap.add_argument("--capacity", action="store_true",
                    help="run only the equal-memory capacity probe "
                         "(slots vs paged fp vs paged int8 KV at the "
                         "same byte budget)")
    ap.add_argument("--horizon", type=int, default=8,
                    help="horizon-fused decode width for the decode-heavy "
                         "probe (1 disables fusion)")
    ap.add_argument("--seed", type=int, default=0,
                    help="seed for the arrival/length/budget RNGs (makes "
                         "runs and the --smoke gates reproducible)")
    args = ap.parse_args()
    run(smoke=args.smoke, prefix_only=args.prefix_heavy,
        routing_only=args.routing, gauntlet_only=args.gauntlet,
        mixed_only=args.mixed, capacity_only=args.capacity,
        horizon=args.horizon, seed=args.seed)
