"""Paper Fig. 4 (Chat best-of-k, full + tranches) reproduction.

Continuous rewards from the chat-like task family (ChatTaskGen): each query
has a latent (mu, sigma) reward landscape encoded in its tokens. The probe
predicts the Δ vector (MSE head, paper Eq. 6) from LM hidden states of the
query; allocation uses the predicted marginals directly (non-binary path).

The **tranches** variant selects the lowest-10% + highest-10% reward-
variance queries, exactly as §4.1 describes — here we can verify against
the TRUE variance because we control the generator.
"""
from __future__ import annotations


import numpy as np

from benchmarks.common import emit, save_result
from repro.core import allocator as alloc
from repro.core import bestofk, marginal
from repro.core.difficulty import probe_predict, train_mlp_probe


def _features(queries):
    """Query features: normalized token histogram (bag-of-tokens).

    The paper probes a PRETRAINED LM's hidden states; with no pretrained
    chat LM offline, the bag-of-tokens featurization is the stand-in —
    it is what a trained LM's pooled representation exposes about these
    queries (DESIGN.md assumption table). An untrained-LM-hidden-state
    probe was tried first and measured too weak (val loss ~= mean
    predictor), which itself reproduces the paper's point that the
    *representation* carries the difficulty signal."""
    from repro.data.tasks import VOCAB

    toks = np.asarray([q.tokens for q in queries], np.int32)
    hist = np.stack([np.bincount(t, minlength=VOCAB) / len(t)
                     for t in toks]).astype(np.float32)
    return hist * np.sqrt(VOCAB)          # unit-ish scale for the MLP


def run_variant(n_train=600, n_test=400, m=16, b_max=8,
                budgets=(1, 2, 3, 4, 6, 8), tranches=False, seed=0):
    import jax

    from repro.data.tasks import ChatTaskGen

    gen = ChatTaskGen(seed=seed)
    train_q = gen.sample(n_train)
    test_q = gen.sample(n_test)
    if tranches:
        # lowest/highest 10% by reward variance (measured from samples,
        # like the paper — not from the latent)
        pool = gen.sample(n_test * 5)
        rs = gen.sample_rewards(pool, m, seed=seed + 1)
        var = rs.var(axis=1)
        lo = np.argsort(var)[: n_test // 2]
        hi = np.argsort(var)[-n_test // 2:]
        test_q = [pool[i] for i in np.concatenate([lo, hi])]
    r_train = gen.sample_rewards(train_q, m, seed=seed + 2)
    r_test = gen.sample_rewards(test_q, m, seed=seed + 3)

    # targets: empirical Δ vectors by bootstrap (paper's supervision)
    d_train = marginal.bootstrap_marginals(r_train, b_max)
    feats_train = _features(train_q)
    feats_test = _features(test_q)
    probe, info = train_mlp_probe(jax.random.PRNGKey(seed + 4), feats_train,
                                  d_train, kind="mse", steps=1500)
    d_hat = probe_predict(probe, feats_test, "mse")
    d_true = marginal.bootstrap_marginals(r_test, b_max)

    out = {"budgets": list(budgets), "uniform": [], "adaptive": [],
           "oracle": [], "tranches": tranches,
           "probe_val_loss": info["val_loss"]}
    n = len(test_q)
    for B in budgets:
        total = int(round(B * n))
        out["uniform"].append(bestofk.eval_reward_allocation(
            r_test, np.full(n, B)))
        # chat: b>=1 and SPEND the budget (bootstrap Δ estimates carry
        # negative noise; stopping at Δ<=0 strands budget vs uniform)
        b_ad = alloc.greedy_allocate(d_hat, total, b_min=1,
                                     allow_negative=True)
        out["adaptive"].append(bestofk.eval_reward_allocation(r_test, b_ad))
        b_or = alloc.greedy_allocate(d_true, total, b_min=1,
                                     allow_negative=True)
        out["oracle"].append(bestofk.eval_reward_allocation(r_test, b_or))
    return out


def run():
    full = run_variant(tranches=False)
    tr = run_variant(tranches=True)
    save_result("fig4_chat_full", full)
    save_result("fig4_chat_tranches", tr)
    for name, c in (("full", full), ("tranches", tr)):
        i = c["budgets"].index(4)
        emit(f"fig4_chat_{name}_B4", 0.0,
             f"uniform={c['uniform'][i]:.4f};adaptive={c['adaptive'][i]:.4f};"
             f"oracle={c['oracle'][i]:.4f}")


if __name__ == "__main__":
    run()
