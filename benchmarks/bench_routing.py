"""Paper Fig. 5 (routing) reproduction.

Two (weak, strong) pairs, as in §4.2:

* **Model size**: gemma-weak-tiny (2L/128) vs gemma-strong-tiny (6L/320),
  both trained in-framework on the arithmetic suite for different step
  counts — a real capability gap.
* **VAS-like**: the same weak model, where the strong "decoder" is
  best-of-4 with verifier reranking (decode-time search at ~4x cost —
  the value-augmented-sampling analogue in our substrate).

The preference predictor Δ̂ ≈ p(p^S ≻ p^W | x) (Eq. 8) is an MLP probe on
the WEAK model's hidden states (paper: "we train using the hidden states of
p^W ... p^S does not even have to be called at all").
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import CACHE, emit, save_result
from repro.core import marginal, routing
from repro.core.difficulty import probe_predict, train_mlp_probe


def _train_pair(seed=0):
    import jax

    from repro.checkpoint import load_checkpoint, save_checkpoint
    from repro.launch import train as train_mod

    out = {}
    for name, steps in (("gemma-weak-tiny", 120), ("gemma-strong-tiny", 500)):
        ck = CACHE / f"router_{name}"
        params, model = train_mod.main([
            "--arch", name, "--steps",
            "0" if ck.with_suffix(".npz").exists() else str(steps),
            "--batch", "32", "--seq", "64", "--seed", str(seed),
            "--log-every", "200"])
        if ck.with_suffix(".npz").exists():
            params = load_checkpoint(str(ck), jax.tree.map(
                lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), params))
        else:
            CACHE.mkdir(parents=True, exist_ok=True)
            save_checkpoint(str(ck), params)
        out[name] = (params, model)
    return out


def _success_pool(engine, problems, prompts, m, seed):
    res = engine.generate(prompts, n_samples=m, seed=seed)
    succ = np.zeros((len(problems), m))
    for i, q in enumerate(problems):
        for j in range(m):
            succ[i, j] = q.check(list(res.tokens[i * m + j]))
    return succ, res.probe_hidden


def run_setting(setting: str, n_train=256, n_test=256, m=8, seed=0):
    import jax

    from repro.data.tasks import ArithTaskGen
    from repro.serving import ServingEngine

    pair = _train_pair(seed)
    wk_params, wk_model = pair["gemma-weak-tiny"]
    st_params, st_model = pair["gemma-strong-tiny"]
    weak = ServingEngine(wk_model, wk_params, max_new=8, temperature=1.0)
    if setting == "model_size":
        strong = ServingEngine(st_model, st_params, max_new=8,
                               temperature=1.0)
        strong_m, cost_s = m, 3.0
    else:  # vas-like: weak base model + search (best-of-4 + verifier)
        strong = ServingEngine(wk_model, wk_params, max_new=8,
                               temperature=1.0)
        strong_m, cost_s = 4 * m, 4.0

    gen = ArithTaskGen(max_digits=4, seed=seed + 21)
    prompts_of = lambda ps: np.asarray(
        [[0] * (12 - len(r)) + r for r in (p.prompt_tokens() for p in ps)],
        np.int32)
    tag = f"routing_{setting}_{n_train}_{n_test}_{m}_{seed}"
    f = CACHE / (tag + ".npz")
    if f.exists():
        d = np.load(f)
        sw_tr, ss_tr, fw_tr = d["sw_tr"], d["ss_tr"], d["fw_tr"]
        sw_te, ss_te, fw_te = d["sw_te"], d["ss_te"], d["fw_te"]
    else:
        tr, te = gen.sample(n_train), gen.sample(n_test)
        ptr, pte = prompts_of(tr), prompts_of(te)
        sw_tr, fw_tr = _success_pool(weak, tr, ptr, m, seed + 1)
        sw_te, fw_te = _success_pool(weak, te, pte, m, seed + 2)
        ss_tr, _ = _success_pool(strong, tr, ptr, strong_m, seed + 3)
        ss_te, _ = _success_pool(strong, te, pte, strong_m, seed + 4)
        if setting != "model_size":
            # best-of-4 search: group every 4 samples into one "decode"
            ss_tr = ss_tr.reshape(n_train, m, 4).max(-1)
            ss_te = ss_te.reshape(n_test, m, 4).max(-1)
        np.savez(f, sw_tr=sw_tr, ss_tr=ss_tr, fw_tr=fw_tr,
                 sw_te=sw_te, ss_te=ss_te, fw_te=fw_te)

    # Eq. 11 Monte-Carlo preference targets on the training pool
    pref_tr = marginal.preference_prob(ss_tr, sw_tr, sigma_scale=4.0)
    probe, info = train_mlp_probe(jax.random.PRNGKey(seed + 5), fw_tr,
                                  pref_tr, kind="pref", steps=1500)
    pref_hat = probe_predict(probe, fw_te, "pref")
    fracs = [0.0, 0.125, 0.25, 0.375, 0.5, 0.625, 0.75, 0.875, 1.0]
    curves = routing.routing_curves(sw_te, ss_te, pref_hat, fracs)
    curves["setting"] = setting
    curves["probe_val_loss"] = info["val_loss"]
    curves["cost_strong"] = cost_s
    # strong-matching fraction: smallest f whose adaptive reward >= strong
    strong_reward = curves["adaptive"][-1]
    match = next((f for f, r in zip(fracs, curves["adaptive"])
                  if r >= strong_reward - 0.005), 1.0)
    curves["strong_match_frac"] = match
    return curves


def run():
    for setting in ("model_size", "vas"):
        c = run_setting(setting)
        save_result(f"fig5_routing_{setting}", c)
        i = c["frac"].index(0.5)
        emit(f"fig5_routing_{setting}_f50", 0.0,
             f"adaptive={c['adaptive'][i]:.3f};random={c['random'][i]:.3f};"
             f"oracle={c['oracle'][i]:.3f};weak={c['adaptive'][0]:.3f};"
             f"strong={c['adaptive'][-1]:.3f};"
             f"match_frac={c['strong_match_frac']:.3f}")


if __name__ == "__main__":
    run()
