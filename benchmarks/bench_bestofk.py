"""Paper Fig. 3 (Math/Code best-of-k) reproduction.

Two stages, mirroring DESIGN.md's assumption table:

A. **End-to-end (real LM)**: mathstral-tiny trained in-framework on the
   arithmetic suite; empirical λ labels from 24 samples/query; an MLP probe
   on prefill hidden states predicts λ̂; Online Ada-BoK / Offline Ada-BoK /
   uniform Best-of-k / Oracle curves over budgets — evaluated with the
   analytic binary form q=1-(1-λ)^b on held-out queries.

B. **Calibrated simulation at paper scale**: λ pools shaped like the
   paper's domains (Code: ~50% zero-success mass; Math: ~5%), a predictor
   with the paper's observed accuracy (~74-84%) simulated by noising the
   true λ in logit space, n=1000 queries, B_max=100/128 — reproduces the
   25-50% compute-saving claims quantitatively.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, get_arith_fixture, save_result, timeit
from repro.core import allocator as alloc
from repro.core import bestofk, marginal
from repro.core.difficulty import probe_predict, train_mlp_probe


def _curves(lam_true, lam_pred, budgets, b_max, *, n_bins=10,
            lam_hold_true=None, lam_hold_pred=None):
    """success-rate curves for uniform / online / offline / oracle.

    The offline policy is built the paper's way (§3.2): EMPIRICAL marginals
    of a held-out set, binned by the PREDICTED statistic — this is what
    regularizes away the zero-success pathology that hurts the online
    variant on Code. If no holdout is passed, the eval split is halved.
    """
    n_all = len(lam_true)
    if lam_hold_true is None:
        h = n_all // 2
        lam_hold_true, lam_hold_pred = lam_true[:h], lam_pred[:h]
        lam_true, lam_pred = lam_true[h:], lam_pred[h:]
    out = {"budgets": list(budgets), "uniform": [], "online": [],
           "offline": [], "oracle": []}
    delta_pred = marginal.binary_marginals(lam_pred, b_max)
    delta_true = marginal.binary_marginals(lam_true, b_max)
    delta_hold = marginal.binary_marginals(lam_hold_true, b_max)
    n = len(lam_true)
    for B in budgets:
        total = int(round(B * n))
        out["uniform"].append(bestofk.eval_binary_allocation(
            lam_true, np.full(n, B)))
        b_on = alloc.greedy_allocate(delta_pred, total)
        out["online"].append(bestofk.eval_binary_allocation(lam_true, b_on))
        pol = alloc.build_offline_policy(delta_hold, lam_hold_pred, B,
                                         n_bins=n_bins)
        b_off = np.minimum(pol(lam_pred), b_max)
        # offline policies satisfy the budget on average by construction
        out["offline"].append(bestofk.eval_binary_allocation(lam_true, b_off))
        b_or = alloc.greedy_allocate(delta_true, total)
        out["oracle"].append(bestofk.eval_binary_allocation(lam_true, b_or))
    return out


def compute_saving(budgets, uniform, adaptive) -> float:
    """Max over budgets of (1 - B_adaptive/B_uniform) at matched success,
    with linear interpolation of the adaptive curve between budget points
    (the paper reads savings off continuous curves)."""
    budgets = np.asarray(budgets, float)
    adaptive = np.asarray(adaptive, float)
    best = 0.0
    for i, B in enumerate(budgets):
        target = uniform[i]
        if adaptive[0] >= target - 1e-12:
            b_need = budgets[0]
        elif (adaptive >= target).any():
            j = int(np.argmax(adaptive >= target))
            x0, x1 = budgets[j - 1], budgets[j]
            y0, y1 = adaptive[j - 1], adaptive[j]
            b_need = x0 + (x1 - x0) * (target - y0) / max(y1 - y0, 1e-12)
        else:
            continue
        best = max(best, 1.0 - b_need / B)
    return best


def run_end_to_end(budgets=(1, 2, 4, 8, 16), b_max=24):
    import jax

    fix = get_arith_fixture()
    lam_tr = marginal.empirical_lambda(fix["train_succ"])
    lam_te = marginal.empirical_lambda(fix["test_succ"])
    probe, info = train_mlp_probe(jax.random.PRNGKey(3), fix["train_feats"],
                                  lam_tr, kind="bce", steps=1500)
    lam_hat = probe_predict(probe, fix["test_feats"], "bce")
    curves = _curves(lam_te, lam_hat, budgets, b_max)
    curves["probe_val_loss"] = info["val_loss"]
    curves["lambda_zero_frac"] = float((lam_te == 0).mean())
    curves["saving_online"] = compute_saving(budgets, curves["uniform"],
                                             curves["online"])
    curves["saving_offline"] = compute_saving(budgets, curves["uniform"],
                                              curves["offline"])
    return curves


def _noisy_logit_predictor(lam, acc_target, rng, floor=1e-3):
    z = np.log(np.clip(lam, floor, 1 - floor) / (1 - np.clip(lam, floor,
                                                             1 - floor)))
    for noise in np.linspace(0.1, 6.0, 40):
        zz = z + rng.normal(0, noise, size=z.shape)
        pred = 1 / (1 + np.exp(-zz))
        pred = np.where(lam == 0, np.minimum(pred, 0.05 * rng.uniform(
            size=z.shape)), pred)
        med = np.median(lam)
        acc = ((pred > np.median(pred)) == (lam > med)).mean()
        if acc <= acc_target:
            return pred
    return pred


def run_simulation(domain: str, n=1000, seed=0):
    rng = np.random.default_rng(seed)
    if domain == "code":      # TACO-like: 50% zero-success
        lam = rng.beta(0.35, 1.6, size=n)
        lam[rng.uniform(size=n) < 0.5] = 0.0
        b_max, acc = 100, 0.74
        budgets = (1, 2, 3, 4, 6, 8, 12, 16, 24, 32, 48, 64)
    else:                     # math (Numina-like): ~5% impossible, flat-ish
        lam = rng.beta(0.9, 1.4, size=n)
        lam[rng.uniform(size=n) < 0.05] = 0.0
        b_max, acc = 128, 0.84
        budgets = (1, 2, 3, 4, 6, 8, 12, 16, 24, 32, 48, 64, 96, 128)
    pred = _noisy_logit_predictor(lam, acc, rng)
    curves = _curves(lam, pred, budgets, b_max)
    curves["domain"] = domain
    curves["saving_online"] = compute_saving(budgets, curves["uniform"],
                                             curves["online"])
    curves["saving_offline"] = compute_saving(budgets, curves["uniform"],
                                              curves["offline"])
    return curves


def run():
    e2e = run_end_to_end()
    save_result("fig3_end_to_end", e2e)
    t = timeit(lambda: alloc.greedy_allocate(
        marginal.binary_marginals(np.random.default_rng(0).uniform(
            size=256), 24), 1024), repeats=3)
    emit("fig3_e2e_online_B4", t,
         f"uniform={e2e['uniform'][2]:.3f};online={e2e['online'][2]:.3f};"
         f"offline={e2e['offline'][2]:.3f};oracle={e2e['oracle'][2]:.3f};"
         f"save_on={e2e['saving_online']:.2f};"
         f"save_off={e2e['saving_offline']:.2f}")
    for dom in ("code", "math"):
        sim = run_simulation(dom)
        save_result(f"fig3_sim_{dom}", sim)
        i8 = sim["budgets"].index(8)
        emit(f"fig3_sim_{dom}_B8", 0.0,
             f"uniform={sim['uniform'][i8]:.3f};"
             f"online={sim['online'][i8]:.3f};"
             f"offline={sim['offline'][i8]:.3f};"
             f"oracle={sim['oracle'][i8]:.3f};"
             f"save_on={sim['saving_online']:.2f};"
             f"save_off={sim['saving_offline']:.2f}")


if __name__ == "__main__":
    run()
