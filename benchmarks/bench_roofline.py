"""Roofline analysis (assignment deliverable g).

Reads the dry-run artifacts (experiments/artifacts/*.json) and derives the
three per-device roofline terms on TPU v5e constants:

    compute    = HLO_FLOPs            / 197e12  FLOP/s (bf16)
    memory     = HLO_bytes            / 819e9   B/s    (HBM)
    collective = collective_bytes     / 4*50e9  B/s    (ICI, ~4 usable links)

plus the dominant term, MODEL_FLOPS = 6·N·D (6·N_active·D for MoE) vs
HLO_FLOPs useful-ratio, and a one-line lever per row. Emits a markdown
table (used verbatim in EXPERIMENTS.md §Roofline) and CSV lines.
"""
from __future__ import annotations

import json
from pathlib import Path


ARTIFACTS = Path(__file__).resolve().parents[1] / "experiments" / "artifacts"

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # B/s / chip
ICI_BW = 4 * 50e9            # B/s / chip (4 usable links x ~50 GB/s)

LEVERS = {
    "compute": "reduce redundant FLOPs (remat policy, causal block skipping,"
               " head-padding waste)",
    "memory": "fuse/stream large intermediates (fused CE kernel, bf16 "
              "accumulators, better layouts)",
    "collective": "reshard to cut all-gathers (SP residual, fp32->bf16 "
                  "collectives, overlap with compute)",
}


def tokens_of(shape_name: str) -> int:
    return {"train_4k": 4096 * 256, "prefill_32k": 32768 * 32,
            "decode_32k": 128, "long_500k": 1}[shape_name]


def load_records(mesh: str = "pod16x16", tag: str = "baseline"):
    recs = []
    for f in sorted(ARTIFACTS.glob("*.json")):
        r = json.loads(f.read_text())
        if r.get("mesh") != mesh or not r.get("ok"):
            continue
        if r.get("tag", "baseline") != tag:
            continue
        recs.append(r)
    return recs


def roofline_row(r: dict) -> dict:
    ana = r["hlo_analysis"]
    n_dev = r["n_devices"]
    t_c = ana["flops"] / PEAK_FLOPS
    t_m = ana["bytes"] / HBM_BW
    t_i = ana["collective_bytes_total"] / ICI_BW
    terms = {"compute": t_c, "memory": t_m, "collective": t_i}
    dom = max(terms, key=terms.get)
    # MODEL_FLOPS: useful model flops for this step, per device
    toks = tokens_of(r["shape"])
    n_act = r["n_active_params"]
    mult = {"train": 6, "prefill": 2, "decode": 2}[r["kind"]]
    model_flops = mult * n_act * toks / n_dev
    useful = model_flops / max(ana["flops"], 1.0)
    return {
        "arch": r["arch"], "shape": r["shape"], "kind": r["kind"],
        "compute_s": t_c, "memory_s": t_m, "collective_s": t_i,
        "dominant": dom, "model_flops": model_flops,
        "useful_ratio": useful,
        "hlo_flops": ana["flops"], "hlo_bytes": ana["bytes"],
        "coll_bytes": ana["collective_bytes_total"],
        "temp_gib": r["memory"]["temp_bytes"] / 2**30,
        "lever": LEVERS[dom],
    }


def markdown_table(rows) -> str:
    hdr = ("| arch | shape | compute s | memory s | collective s | dominant "
           "| useful 6ND/HLO | temp GiB |\n|---|---|---|---|---|---|---|---|")
    lines = [hdr]
    for w in rows:
        lines.append(
            f"| {w['arch']} | {w['shape']} | {w['compute_s']:.3e} "
            f"| {w['memory_s']:.3e} | {w['collective_s']:.3e} "
            f"| **{w['dominant']}** | {w['useful_ratio']:.2f} "
            f"| {w['temp_gib']:.1f} |")
    return "\n".join(lines)


def run(mesh: str = "pod16x16"):
    recs = load_records(mesh)
    rows = [roofline_row(r) for r in recs]
    rows.sort(key=lambda w: (w["arch"], w["shape"]))
    out = Path(__file__).resolve().parents[1] / "experiments" / "results"
    out.mkdir(parents=True, exist_ok=True)
    with open(out / f"roofline_{mesh}.json", "w") as f:
        json.dump(rows, f, indent=1, default=float)
    with open(out / f"roofline_{mesh}.md", "w") as f:
        f.write(markdown_table(rows) + "\n")
    for w in rows:
        print(f"roofline_{w['arch']}_{w['shape']},0.0,"
              f"dom={w['dominant']};c={w['compute_s']:.2e};"
              f"m={w['memory_s']:.2e};i={w['collective_s']:.2e};"
              f"useful={w['useful_ratio']:.2f}")
    return rows


if __name__ == "__main__":
    run()
