"""Allocator micro-benchmarks (system-performance table): greedy vs
vectorized threshold vs offline lookup, across batch sizes — the serving
scheduler's per-batch overhead budget."""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, timeit
from repro.core import allocator as alloc
from repro.core import marginal


def run():
    rng = np.random.default_rng(0)
    for n, b_max in ((64, 16), (512, 32), (4096, 128)):
        lam = rng.beta(0.5, 1.5, size=n)
        delta = marginal.binary_marginals(lam, b_max)
        total = 4 * n
        t_g = timeit(lambda: alloc.greedy_allocate(delta, total), repeats=5)
        emit(f"alloc_greedy_n{n}_B{b_max}", t_g,
             f"units={total};per_unit_ns={1000*t_g/total:.1f}")
        t_t = timeit(lambda: alloc.allocate_threshold(
            delta, total, assume_monotone=True), repeats=5)
        emit(f"alloc_threshold_n{n}_B{b_max}", t_t, "vectorized")
        pol = alloc.build_offline_policy(delta, lam, 4.0)
        t_o = timeit(lambda: pol(lam), repeats=5)
        emit(f"alloc_offline_n{n}_B{b_max}", t_o, "lookup")


if __name__ == "__main__":
    run()
