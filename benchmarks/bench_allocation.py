"""Paper Fig. 6 reproduction: how allocation shifts across difficulty bins.

Queries are stratified into three evenly-sized bins (easy/medium/hard) by
predicted success probability; we report the fraction of total compute each
bin receives at increasing budgets. Expected pattern (paper): low budgets
favour easy/medium; high budgets pour compute into the hard bin.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, get_arith_fixture, save_result
from repro.core import allocator as alloc
from repro.core import marginal
from repro.core.difficulty import probe_predict, train_mlp_probe


def run(budgets=(1, 2, 4, 8, 16), b_max=24):
    import jax

    fix = get_arith_fixture()
    lam_tr = marginal.empirical_lambda(fix["train_succ"])
    probe, _ = train_mlp_probe(jax.random.PRNGKey(1), fix["train_feats"],
                               lam_tr, kind="bce", steps=1500)
    lam_hat = probe_predict(probe, fix["test_feats"], "bce")
    # bin among plausibly-solvable queries (the paper's Math/Code hard bins
    # have low-but-nonzero λ; our task's hard tail is λ=0 "impossible" and
    # correctly gets b=0 — excluded so the easy/medium/hard shift is
    # visible, as in Fig. 6)
    keep = lam_hat > 0.02
    lam_hat = lam_hat[keep]
    delta = marginal.binary_marginals(lam_hat, b_max)
    n = len(lam_hat)
    # evenly-sized difficulty bins by predicted λ (high λ = easy)
    order = np.argsort(-lam_hat)
    bins = np.zeros(n, np.int64)
    bins[order[n // 3: 2 * n // 3]] = 1
    bins[order[2 * n // 3:]] = 2
    out = {"budgets": list(budgets), "easy": [], "medium": [], "hard": []}
    for B in budgets:
        b = alloc.greedy_allocate(delta, int(round(B * n)))
        tot = max(b.sum(), 1)
        for gi, gname in enumerate(("easy", "medium", "hard")):
            out[gname].append(float(b[bins == gi].sum() / tot))
    save_result("fig6_allocation", out)
    emit("fig6_alloc_shift", 0.0,
         f"hard_frac_B1={out['hard'][0]:.2f};"
         f"hard_frac_B{budgets[-1]}={out['hard'][-1]:.2f}")
    return out


if __name__ == "__main__":
    run()
