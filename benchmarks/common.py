"""Benchmark utilities: timing + CSV emission + cached tiny-LM training."""
from __future__ import annotations

import time
from pathlib import Path
from typing import Callable

import numpy as np

CACHE = Path(__file__).resolve().parents[1] / "experiments" / "cache"
RESULTS = Path(__file__).resolve().parents[1] / "experiments" / "results"


def timeit(fn: Callable, *args, repeats: int = 5, warmup: int = 1) -> float:
    """Median wall time per call in microseconds."""
    for _ in range(warmup):
        fn(*args)
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn(*args)
        ts.append((time.perf_counter() - t0) * 1e6)
    return float(np.median(ts))


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    print(f"{name},{us_per_call:.1f},{derived}")


def save_result(name: str, obj) -> None:
    import json

    RESULTS.mkdir(parents=True, exist_ok=True)
    with open(RESULTS / f"{name}.json", "w") as f:
        json.dump(obj, f, indent=1, default=float)


def merge_result(name: str, patch: dict) -> None:
    """Merge keys into an existing result JSON (or create it). Lets two
    bench modes (e.g. the serving smoke and the traffic gauntlet) share
    one artifact without the later run clobbering the earlier one."""
    import json

    path = RESULTS / f"{name}.json"
    obj = {}
    if path.exists():
        with open(path) as f:
            obj = json.load(f)
    obj.update(patch)
    save_result(name, obj)


# canonical weak/strong tiny-model pair (single source, shared with
# tests/conftest.py — see repro.models.fixtures for the greedy-echo
# rationale behind the ×3 scaling)
def tiny_lm(*args, **kwargs):
    from repro.models.fixtures import tiny_lm as fn
    return fn(*args, **kwargs)


def scaled_strong_lm(*args, **kwargs):
    from repro.models.fixtures import scaled_strong_lm as fn
    return fn(*args, **kwargs)


def weak_strong_pair(*args, **kwargs):
    from repro.models.fixtures import weak_strong_pair as fn
    return fn(*args, **kwargs)


# ---------------------------------------------------------------------------
# shared experiment fixture: trained tiny LM + labeled query pools
# ---------------------------------------------------------------------------

_FIXTURE = {}


def get_arith_fixture(*, train_steps: int = 400, n_train: int = 256,
                      n_test: int = 256, m_samples: int = 24,
                      seed: int = 0, force: bool = False):
    """Train (or load cached) mathstral-tiny on the arithmetic suite; label
    train/test query pools with empirical λ via sampling; return everything
    the paper's experiments need."""
    key = ("arith", train_steps, n_train, n_test, m_samples, seed)
    if key in _FIXTURE and not force:
        return _FIXTURE[key]

    import jax

    from repro.checkpoint import load_checkpoint, save_checkpoint
    from repro.data.tasks import ArithTaskGen
    from repro.launch import train as train_mod
    from repro.serving import ServingEngine

    CACHE.mkdir(parents=True, exist_ok=True)
    tag = f"arith_s{train_steps}_n{n_train}_{n_test}_m{m_samples}_{seed}"
    ck = CACHE / tag

    params, model = train_mod.main([
        "--arch", "mathstral-tiny", "--steps",
        "0" if (ck.with_suffix(".npz")).exists() else str(train_steps),
        "--batch", "32", "--seq", "64", "--seed", str(seed),
        "--log-every", "100"])
    if (ck.with_suffix(".npz")).exists():
        params = load_checkpoint(str(ck), jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), params))
    else:
        save_checkpoint(str(ck), params, step=train_steps)

    gen = ArithTaskGen(max_digits=6, seed=seed + 1)
    engine = ServingEngine(model, params, max_new=8, temperature=1.0)

    def prompts_of(problems, width=16):
        rows = [p.prompt_tokens() for p in problems]
        return np.asarray([[0] * (width - len(r)) + r for r in rows],
                          np.int32)

    def label(problems, prompts, seed):
        npz = CACHE / f"{tag}_lam{len(problems)}_{seed}.npz"
        if npz.exists():
            d = np.load(npz)
            return d["succ"], d["feats"]
        res = engine.generate(prompts, n_samples=m_samples, seed=seed)
        succ = np.zeros((len(problems), m_samples))
        for i, q in enumerate(problems):
            for j in range(m_samples):
                succ[i, j] = q.check(list(res.tokens[i * m_samples + j]))
        feats = res.probe_hidden
        np.savez(npz, succ=succ, feats=feats)
        return succ, feats

    train_q = gen.sample(n_train)
    test_q = gen.sample(n_test)
    train_p, test_p = prompts_of(train_q), prompts_of(test_q)
    train_succ, train_feats = label(train_q, train_p, seed + 10)
    test_succ, test_feats = label(test_q, test_p, seed + 11)

    fix = dict(model=model, params=params, engine=engine,
               train_q=train_q, test_q=test_q,
               train_prompts=train_p, test_prompts=test_p,
               train_succ=train_succ, test_succ=test_succ,
               train_feats=train_feats, test_feats=test_feats,
               prompts_of=prompts_of, gen=gen)
    _FIXTURE[key] = fix
    return fix
