from repro.data.pipeline import LMDataPipeline, PipelineConfig  # noqa: F401
from repro.data.tasks import (  # noqa: F401
    ArithProblem,
    ArithTaskGen,
    ChatQuery,
    ChatTaskGen,
    VOCAB,
)
