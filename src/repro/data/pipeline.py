"""Deterministic, sharding-aware data pipeline.

Produces global batches as numpy (host) arrays; the launcher places them
with the batch PartitionSpec. Deterministic by (seed, step): any worker can
reproduce any batch — the property the resume path and the multi-host
launcher rely on (each host materializes only its shard slice).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterator, Optional

import numpy as np

from repro.data.tasks import ArithTaskGen, VOCAB


@dataclass
class PipelineConfig:
    global_batch: int
    seq_len: int
    seed: int = 0
    max_digits: int = 6
    vocab_size: int = VOCAB


class LMDataPipeline:
    """Packed next-token-prediction batches over the synthetic corpus."""

    def __init__(self, cfg: PipelineConfig):
        self.cfg = cfg

    def batch_at(self, step: int, *, host_slice: Optional[slice] = None
                 ) -> Dict[str, np.ndarray]:
        gen = ArithTaskGen(max_digits=self.cfg.max_digits,
                           seed=hash((self.cfg.seed, step)) % (2 ** 31))
        seqs = gen.training_sequences(self.cfg.global_batch,
                                      self.cfg.seq_len + 1)
        if host_slice is not None:
            seqs = seqs[host_slice]
        return {"tokens": seqs[:, :-1].astype(np.int32),
                "labels": seqs[:, 1:].astype(np.int32)}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1
