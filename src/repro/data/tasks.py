"""Difficulty-graded synthetic task suite (the paper-repro workload).

The paper's Math/Code/Chat datasets can't be shipped offline, so the
reproduction uses a *controlled* task family where ground-truth difficulty
exists but is hidden from the model: multi-digit modular arithmetic.

    query  : "a+b=" / "a*b="  (digit tokens), a,b with d digits
    answer : the result mod 10^d, as digit tokens
    reward : exact-match (binary) — the "unit test" / oracle verifier

Difficulty rises sharply with digit count; a small LM trained for a few
hundred steps solves 1-2 digit problems reliably, is stochastic at 3-4, and
fails at >=6 — giving the full λ spectrum the paper's Fig. 3 needs
(including a zero-success mass like TACO's 50%).

Everything is tokenized with a fixed 64-symbol vocabulary (digits,
operators, BOS/EOS/SEP/PAD + filler letters for chat-like tasks).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Sequence, Tuple

import numpy as np

PAD, BOS, EOS, SEP = 0, 1, 2, 3
DIGIT0 = 4                   # tokens 4..13 are digits 0..9
PLUS, TIMES, EQ = 14, 15, 16
LETTER0 = 20                 # letters for chat-like filler
VOCAB = 64


def encode_digits(n: int, width: int) -> List[int]:
    s = str(n).zfill(width)
    return [DIGIT0 + int(c) for c in s]


def decode_digits(toks: Sequence[int]) -> Optional[int]:
    ds = []
    for t in toks:
        if DIGIT0 <= t < DIGIT0 + 10:
            ds.append(str(t - DIGIT0))
        elif t == EOS:
            break
        else:
            return None
    if not ds:
        return None
    return int("".join(ds))


@dataclass(frozen=True)
class ArithProblem:
    a: int
    b: int
    op: str                  # '+' or '*'
    digits: int

    @property
    def answer(self) -> int:
        mod = 10 ** self.digits
        return (self.a + self.b) % mod if self.op == "+" else \
            (self.a * self.b) % mod

    def prompt_tokens(self) -> List[int]:
        op_tok = PLUS if self.op == "+" else TIMES
        return ([BOS] + encode_digits(self.a, self.digits) + [op_tok]
                + encode_digits(self.b, self.digits) + [EQ])

    def answer_tokens(self) -> List[int]:
        return encode_digits(self.answer, self.digits) + [EOS]

    def check(self, generated: Sequence[int]) -> bool:
        """Binary reward: exact-match verifier (the 'unit test')."""
        return decode_digits(generated) == self.answer


class ArithTaskGen:
    """Samples problems with difficulty mixture over digit counts."""

    def __init__(self, *, max_digits: int = 6, ops=("+",), seed: int = 0,
                 digit_weights: Optional[Sequence[float]] = None):
        self.max_digits = max_digits
        self.ops = ops
        self.rng = np.random.default_rng(seed)
        w = np.asarray(digit_weights if digit_weights is not None
                       else np.ones(max_digits), np.float64)
        self.w = w / w.sum()

    def sample(self, n: int) -> List[ArithProblem]:
        out = []
        for _ in range(n):
            d = int(self.rng.choice(self.max_digits, p=self.w)) + 1
            lo, hi = 0, 10 ** d
            a = int(self.rng.integers(lo, hi))
            b = int(self.rng.integers(lo, hi))
            op = str(self.rng.choice(self.ops))
            out.append(ArithProblem(a=a, b=b, op=op, digits=d))
        return out

    def training_sequences(self, n: int, seq_len: int) -> np.ndarray:
        """Packed LM training batches: BOS a op b = answer EOS ..."""
        toks = []
        while sum(len(t) for t in toks) < n * seq_len:
            p = self.sample(1)[0]
            toks.append(p.prompt_tokens() + p.answer_tokens())
        flat = [t for seq in toks for t in seq]
        flat = flat[: n * seq_len]
        return np.asarray(flat, np.int32).reshape(n, seq_len)


# ---------------------------------------------------------------------------
# Chat-like continuous-reward task (for the Chat/Fig.4 reproduction)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ChatQuery:
    """A query with latent 'reward landscape' parameters.

    mu: the mean reward the base LM achieves; sigma: per-sample reward
    spread (the variance tranches of Fig. 4 select extremes of sigma).
    """
    tokens: Tuple[int, ...]
    mu: float
    sigma: float


class ChatTaskGen:
    """Queries whose token content ENCODES the latent (mu, sigma) through a
    noisy linear map — so difficulty is predictable from the tokens (by a
    probe), but not trivially."""

    def __init__(self, *, seq_len: int = 24, seed: int = 0):
        self.seq_len = seq_len
        self.rng = np.random.default_rng(seed)
        # random projection from token histogram -> (mu, sigma)
        self.proj = self.rng.normal(size=(VOCAB, 2)) / np.sqrt(VOCAB)

    def sample(self, n: int) -> List[ChatQuery]:
        out = []
        # token histograms over seq_len~24 have std ~0.03 per entry, so the
        # projection is rescaled to spread (mu, sigma) over their full
        # ranges — otherwise every query lands at sigma~0.35 and there is
        # no difficulty signal to allocate against (measured; see
        # bench_chat docstring)
        for _ in range(n):
            toks = self.rng.integers(LETTER0, VOCAB,
                                     size=self.seq_len).astype(np.int32)
            hist = np.bincount(toks, minlength=VOCAB) / self.seq_len
            z = hist @ self.proj
            mu = float(np.tanh(25.0 * z[0]))                # in (-1, 1)
            sigma = float(0.05 + 0.6 * (1 / (1 + np.exp(-50 * z[1]))))
            out.append(ChatQuery(tokens=tuple(int(t) for t in toks),
                                 mu=mu, sigma=sigma))
        return out

    def sample_rewards(self, qs: Sequence[ChatQuery], m: int,
                       seed: int = 0) -> np.ndarray:
        rng = np.random.default_rng(seed)
        return np.stack([rng.normal(q.mu, q.sigma, size=m) for q in qs])
