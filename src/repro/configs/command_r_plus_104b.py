"""command-r-plus-104b — dense 64L d_model=12288 96H (GQA kv=8) d_ff=33792
vocab=256000 — GQA, no-bias. [hf:CohereForAI/c4ai-command-r-v01]

Full-attention dense arch; long_500k uses the sliding-window variant
(flagged — see DESIGN.md §Arch-applicability).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="command-r-plus-104b",
    family="dense",
    n_layers=64,
    d_model=12288,
    n_heads=96,
    n_kv_heads=8,
    head_dim=128,
    d_ff=33792,
    vocab_size=256000,
    qkv_bias=False,
    norm="layernorm",            # cohere uses LayerNorm (no bias)
    act="silu",
    gated_mlp=True,
    rope_theta=75_000_000.0,     # command-r family long-rope base
    long_context="sliding_window",
    sliding_window=4096,
    source="hf:CohereForAI/c4ai-command-r-v01",
)
