from repro.configs.base import (  # noqa: F401
    EncoderConfig,
    INPUT_SHAPES,
    InputShape,
    MLAConfig,
    ModelConfig,
    MoEConfig,
    SHAPES_BY_NAME,
    SSMConfig,
    TrainConfig,
    XLSTMConfig,
)
from repro.configs.registry import ARCHS, STANDINS, get_config, list_archs  # noqa: F401
