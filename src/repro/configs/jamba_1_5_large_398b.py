"""jamba-1.5-large-398b — hybrid 72L d_model=8192 64H (GQA kv=8) d_ff=24576
vocab=65536, MoE 16 experts top-2 — Mamba+attention 1:7 interleave.
[arXiv:2403.19887]

Every 8th layer is attention (GQA kv=8), the other 7 are Mamba blocks.
Every other layer's FFN is MoE (16 experts top-2, expert-parallel 16-way).
long_500k: Mamba layers carry O(1) state; the 9 attention layers keep full
KV (sharded seq-wise over the model axis at decode).
"""
from repro.configs.base import ModelConfig, MoEConfig, SSMConfig

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    n_layers=72,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=24576,
    vocab_size=65536,
    qkv_bias=False,
    norm="rmsnorm",
    act="silu",
    gated_mlp=True,
    long_context="native",       # mamba state + seq-sharded attn KV
    attn_every=8,                # layer i is attention iff i % 8 == 7
    moe_every=2,                 # every other layer MoE
    moe=MoEConfig(n_experts=16, top_k=2, expert_d_ff=24576),
    ssm=SSMConfig(d_state=16, d_conv=4, expand=2),
    source="arXiv:2403.19887",
)
