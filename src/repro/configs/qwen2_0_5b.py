"""qwen2-0.5b — dense 24L d_model=896 14H (GQA kv=2) d_ff=4864
vocab=151936 — GQA, QKV bias. [arXiv:2407.10671]

14 heads padded to 16 for 16-way TP (zero o-rows, exact).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-0.5b",
    family="dense",
    n_layers=24,
    d_model=896,
    n_heads=14,
    n_kv_heads=2,
    d_ff=4864,
    vocab_size=151936,
    qkv_bias=True,
    norm="rmsnorm",
    act="silu",
    gated_mlp=True,
    tie_embeddings=True,
    rope_theta=1_000_000.0,
    long_context="sliding_window",
    sliding_window=4096,
    source="arXiv:2407.10671",
)
