"""Architecture registry: ``--arch <id>`` resolution for launch scripts.

Also holds the paper's own experiment-scale configs (tiny in-framework
stand-ins for Mathstral-7B / Starcoder-15B / Gemma-2B/7B — see DESIGN.md
assumption table) used by the reproduction benchmarks.
"""
from __future__ import annotations

from typing import Dict

from repro.configs.base import ModelConfig, SHAPES_BY_NAME, INPUT_SHAPES  # noqa: F401
from repro.configs.command_r_plus_104b import CONFIG as COMMAND_R_PLUS_104B
from repro.configs.paligemma_3b import CONFIG as PALIGEMMA_3B
from repro.configs.xlstm_1_3b import CONFIG as XLSTM_1_3B
from repro.configs.qwen1_5_0_5b import CONFIG as QWEN1_5_0_5B
from repro.configs.whisper_small import CONFIG as WHISPER_SMALL
from repro.configs.grok_1_314b import CONFIG as GROK_1_314B
from repro.configs.qwen2_5_32b import CONFIG as QWEN2_5_32B
from repro.configs.deepseek_v2_236b import CONFIG as DEEPSEEK_V2_236B
from repro.configs.qwen2_0_5b import CONFIG as QWEN2_0_5B
from repro.configs.jamba_1_5_large_398b import CONFIG as JAMBA_1_5_LARGE_398B

ARCHS: Dict[str, ModelConfig] = {
    c.name: c
    for c in (
        COMMAND_R_PLUS_104B,
        PALIGEMMA_3B,
        XLSTM_1_3B,
        QWEN1_5_0_5B,
        WHISPER_SMALL,
        GROK_1_314B,
        QWEN2_5_32B,
        DEEPSEEK_V2_236B,
        QWEN2_0_5B,
        JAMBA_1_5_LARGE_398B,
    )
}

# ---------------------------------------------------------------------------
# Paper-experiment stand-ins (trainable on CPU; same structural family as the
# paper's models). Used by examples/ and benchmarks/ for the faithful repro.
# ---------------------------------------------------------------------------

def _tiny(name: str, n_layers: int, d_model: int, n_heads: int, d_ff: int,
          vocab: int, **kw) -> ModelConfig:
    return ModelConfig(
        name=name, family="dense", n_layers=n_layers, d_model=d_model,
        n_heads=n_heads, n_kv_heads=kw.pop("n_kv_heads", n_heads),
        d_ff=d_ff, vocab_size=vocab, max_seq_len=kw.pop("max_seq_len", 256),
        tie_embeddings=True, source="in-framework paper stand-in", **kw)

# "Mathstral-7B" stand-in: the best-of-k generator for Math-like tasks.
MATHSTRAL_TINY = _tiny("mathstral-tiny", 4, 256, 4, 512, 64)
# "Starcoder-15B" stand-in: Code-like tasks.
STARCODER_TINY = _tiny("starcoder-tiny", 4, 256, 4, 512, 64)
# "Gemma-2B" / "Gemma-7B" routing pair stand-ins (weak / strong).
GEMMA_WEAK_TINY = _tiny("gemma-weak-tiny", 2, 128, 4, 256, 64)
GEMMA_STRONG_TINY = _tiny("gemma-strong-tiny", 6, 320, 4, 768, 64)
# Reward-model stand-in (OffsetBias-RM-8B analogue): scalar head on a tiny LM.
REWARD_TINY = _tiny("reward-tiny", 2, 128, 4, 256, 64)

STANDINS: Dict[str, ModelConfig] = {
    c.name: c for c in (MATHSTRAL_TINY, STARCODER_TINY, GEMMA_WEAK_TINY,
                        GEMMA_STRONG_TINY, REWARD_TINY)
}


def get_config(arch: str) -> ModelConfig:
    if arch in ARCHS:
        return ARCHS[arch]
    if arch in STANDINS:
        return STANDINS[arch]
    if arch.endswith("-reduced"):
        return get_config(arch[: -len("-reduced")]).reduced()
    raise KeyError(
        f"unknown arch {arch!r}; known: {sorted(ARCHS) + sorted(STANDINS)}")


def list_archs():
    return sorted(ARCHS)
