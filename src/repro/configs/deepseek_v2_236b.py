"""deepseek-v2-236b — moe 60L d_model=5120 128H MLA d_ff=1536 vocab=102400,
MoE 160 routed experts top-6 + 2 shared — MLA kv_lora=512. [arXiv:2405.04434]

MLA: queries/keys split into nope+rope parts; KV is compressed to a 512-dim
latent + 64-dim shared rope key. Decode uses the absorbed form (scores
against the compressed cache) so the long_500k cache is
524288 x (512+64) x 2 B = 604 MB/seq — runs WITHOUT sliding window.
First layer is dense (paper: first layer dense FFN d_ff=12288 intermediate);
we model every layer as MoE + 2 shared experts per the assignment line.
160 experts shard 16-way (10 experts/device, expert-parallel).
"""
from repro.configs.base import MLAConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-v2-236b",
    family="moe",
    n_layers=60,
    d_model=5120,
    n_heads=128,
    n_kv_heads=128,
    d_ff=1536,
    vocab_size=102400,
    qkv_bias=False,
    norm="rmsnorm",
    act="silu",
    gated_mlp=True,
    long_context="native",       # compressed MLA cache fits at 500k
    mla=MLAConfig(kv_lora_rank=512, q_lora_rank=1536,
                  qk_nope_head_dim=128, qk_rope_head_dim=64, v_head_dim=128),
    moe=MoEConfig(n_experts=160, top_k=6, n_shared_experts=2,
                  expert_d_ff=1536),
    source="arXiv:2405.04434",
)
