"""xlstm-1.3b — ssm 48L d_model=2048 4H (kv=4) d_ff=0 vocab=50304 —
sLSTM + mLSTM blocks. [arXiv:2405.04517]

xLSTM[7:1]: every 8th block is sLSTM (sequential scan), the rest mLSTM
(matrix-memory, parallelizable linear-attention form). d_ff=0: blocks use
internal projection factors instead of a separate FFN (paper §4).
Attention-free => long_500k runs natively (O(1) recurrent state decode).
"""
from repro.configs.base import ModelConfig, XLSTMConfig

CONFIG = ModelConfig(
    name="xlstm-1.3b",
    family="ssm",
    n_layers=48,
    d_model=2048,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    norm="layernorm",
    gated_mlp=False,
    long_context="native",
    xlstm=XLSTMConfig(slstm_every=8, mlstm_proj_factor=2.0,
                      slstm_proj_factor=4.0 / 3.0, d_conv=4),
    source="arXiv:2405.04517",
)
