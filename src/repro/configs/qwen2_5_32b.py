"""qwen2.5-32b — dense 64L d_model=5120 40H (GQA kv=8) d_ff=27648
vocab=152064 — GQA, QKV bias. [hf:Qwen/Qwen2.5-32B]

40 heads are padded to 48 for 16-way tensor parallelism (zero output-
projection rows — exact; FLOP inflation reported in roofline useful-ratio).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-32b",
    family="dense",
    n_layers=64,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    head_dim=128,
    d_ff=27648,
    vocab_size=152064,
    qkv_bias=True,
    norm="rmsnorm",
    act="silu",
    gated_mlp=True,
    rope_theta=1_000_000.0,
    long_context="sliding_window",
    sliding_window=4096,
    source="hf:Qwen/Qwen2.5-32B",
)
