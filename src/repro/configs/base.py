"""Model / run configuration dataclasses.

Every assigned architecture is expressed as a ``ModelConfig``. Configs are
plain frozen dataclasses so they hash, print, and diff cleanly; ``reduced()``
derives the CPU smoke-test variant required by the assignment (<=2 layers,
d_model<=512, <=4 experts).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional, Tuple


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    expert_d_ff: int = 0           # per-expert hidden dim
    capacity_factor: float = 1.25
    router_aux_loss: float = 0.01  # load-balance loss weight


@dataclass(frozen=True)
class MLAConfig:
    """DeepSeek-V2 multi-head latent attention."""
    kv_lora_rank: int = 512
    q_lora_rank: int = 0            # 0 => full-rank q projection
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclass(frozen=True)
class SSMConfig:
    """Mamba-style selective state space block."""
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: int = 0                # 0 => ceil(d_model/16)


@dataclass(frozen=True)
class XLSTMConfig:
    """xLSTM block stack: ratio of mLSTM blocks to sLSTM blocks (paper 7:1)."""
    slstm_every: int = 8            # every k-th block is sLSTM; others mLSTM
    mlstm_proj_factor: float = 2.0
    slstm_proj_factor: float = 4.0 / 3.0
    d_conv: int = 4


@dataclass(frozen=True)
class EncoderConfig:
    """Stubbed-modality encoder (audio frames / vision patches).

    The frontend (mel+conv / SigLIP) is a stub per the assignment carve-out:
    input_specs() supplies precomputed frame/patch embeddings with these shapes.
    """
    n_layers: int = 0
    d_model: int = 0
    n_heads: int = 0
    d_ff: int = 0
    seq_len: int = 0                # frames / patches


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                     # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0               # 0 => d_model // n_heads
    qkv_bias: bool = False
    mlp_bias: bool = False
    tie_embeddings: bool = False
    norm: str = "rmsnorm"          # rmsnorm | layernorm
    norm_eps: float = 1e-6
    rope_theta: float = 10000.0
    act: str = "silu"              # silu (gated) | gelu (non-gated, whisper)
    gated_mlp: bool = True
    max_seq_len: int = 8192
    # long-context behaviour for decode shapes:
    #   full            — full attention KV cache (must fit)
    #   sliding_window  — fixed window cache (dense archs at long_500k)
    #   native          — recurrent/compressed state (ssm / mla)
    long_context: str = "full"
    sliding_window: int = 4096
    # sub-configs
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    xlstm: Optional[XLSTMConfig] = None
    encoder: Optional[EncoderConfig] = None
    # hybrid (jamba): an attention layer every `attn_every` layers, rest mamba
    attn_every: int = 0
    # moe layers interleave (jamba: every other layer is MoE)
    moe_every: int = 1              # every k-th layer is MoE (if moe set)
    # probing / LoRA support for the paper's difficulty models
    lora_rank: int = 0
    # W8A16 int8 weight quantization (serving; §Perf beyond-paper knob)
    quant_int8: bool = False
    dtype: str = "bfloat16"
    # citation for config provenance
    source: str = ""

    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // self.n_heads

    @property
    def is_encdec(self) -> bool:
        return self.family == "audio"

    @property
    def n_params_estimate(self) -> int:
        """Rough dense-equivalent parameter count (for roofline MODEL_FLOPS)."""
        d, L, V = self.d_model, self.n_layers, self.vocab_size
        hd = self.resolved_head_dim
        emb = V * d * (1 if self.tie_embeddings else 2)
        if self.family == "ssm" and self.xlstm is not None:
            # xlstm blocks: rough 8*d^2 per mLSTM-ish block
            return emb + L * int(8 * d * d)
        total = 0
        for i in range(L):
            is_attn = (self.attn_every == 0) or ((i % self.attn_every) == (self.attn_every - 1))
            if self.ssm is not None and not is_attn:
                e = self.ssm.expand
                total += 2 * d * e * d + e * d * self.ssm.d_state * 2
            elif self.mla is not None:
                m = self.mla
                total += d * self.n_heads * (m.qk_nope_head_dim + m.qk_rope_head_dim)
                total += d * (m.kv_lora_rank + m.qk_rope_head_dim)
                total += m.kv_lora_rank * self.n_heads * (m.qk_nope_head_dim + m.v_head_dim)
                total += self.n_heads * m.v_head_dim * d
            else:
                total += d * hd * (self.n_heads + 2 * self.n_kv_heads) + self.n_heads * hd * d
            is_moe = self.moe is not None and ((i % self.moe_every) == 0)
            if is_moe:
                m = self.moe
                ff = m.expert_d_ff or self.d_ff
                per_e = d * ff * (3 if self.gated_mlp else 2)
                total += (m.n_experts + m.n_shared_experts) * per_e + d * m.n_experts
            elif self.d_ff:
                total += d * self.d_ff * (3 if self.gated_mlp else 2)
        if self.encoder is not None:
            e = self.encoder
            total += e.n_layers * (4 * e.d_model * e.d_model + 2 * e.d_model * e.d_ff)
            if self.is_encdec:  # cross attention in decoder
                total += L * 4 * d * d
        return emb + total

    @property
    def n_active_params_estimate(self) -> int:
        """Active params per token (MoE top-k instead of all experts)."""
        if self.moe is None:
            return self.n_params_estimate
        m = self.moe
        full = self.n_params_estimate
        ff = m.expert_d_ff or self.d_ff
        per_e = self.d_model * ff * (3 if self.gated_mlp else 2)
        n_moe_layers = len([i for i in range(self.n_layers) if (i % self.moe_every) == 0])
        inactive = n_moe_layers * (m.n_experts - m.top_k) * per_e
        return full - inactive

    def reduced(self) -> "ModelConfig":
        """Smoke-test variant: same family/topology pattern, tiny dims."""
        changes = dict(
            n_layers=2,
            d_model=min(self.d_model, 256),
            n_heads=min(self.n_heads, 4),
            n_kv_heads=min(self.n_kv_heads, 2),
            d_ff=min(self.d_ff, 512) if self.d_ff else 0,
            vocab_size=min(self.vocab_size, 512),
            head_dim=64 if self.resolved_head_dim >= 64 else self.resolved_head_dim,
            max_seq_len=256,
            name=self.name + "-reduced",
        )
        if self.n_kv_heads == self.n_heads:
            changes["n_kv_heads"] = changes["n_heads"]
        if self.moe is not None:
            changes["moe"] = dataclasses.replace(
                self.moe,
                n_experts=min(self.moe.n_experts, 4),
                top_k=min(self.moe.top_k, 2),
                n_shared_experts=min(self.moe.n_shared_experts, 1),
                expert_d_ff=min(self.moe.expert_d_ff, 256) if self.moe.expert_d_ff else 0,
            )
        if self.mla is not None:
            changes["mla"] = dataclasses.replace(
                self.mla, kv_lora_rank=64, q_lora_rank=0,
                qk_nope_head_dim=32, qk_rope_head_dim=16, v_head_dim=32)
            changes["head_dim"] = 0
        if self.ssm is not None:
            changes["ssm"] = dataclasses.replace(self.ssm, d_state=8)
        if self.encoder is not None:
            changes["encoder"] = dataclasses.replace(
                self.encoder, n_layers=1, d_model=changes["d_model"],
                n_heads=changes["n_heads"], d_ff=min(self.encoder.d_ff, 512),
                seq_len=16)
        if self.attn_every:
            changes["attn_every"] = 2
            changes["n_layers"] = 4
        if self.xlstm is not None:
            changes["xlstm"] = dataclasses.replace(self.xlstm, slstm_every=2)
            changes["n_layers"] = 4
        return dataclasses.replace(self, **changes)


@dataclass(frozen=True)
class InputShape:
    """One assigned (seq_len, global_batch) workload."""
    name: str
    seq_len: int
    global_batch: int
    kind: str                       # train | prefill | decode

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


INPUT_SHAPES: Tuple[InputShape, ...] = (
    InputShape("train_4k", 4096, 256, "train"),
    InputShape("prefill_32k", 32768, 32, "prefill"),
    InputShape("decode_32k", 32768, 128, "decode"),
    InputShape("long_500k", 524288, 1, "decode"),
)

SHAPES_BY_NAME = {s.name: s for s in INPUT_SHAPES}


@dataclass(frozen=True)
class TrainConfig:
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 1000
    weight_decay: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    grad_clip: float = 1.0
    seed: int = 0
    remat: bool = True
    microbatch: int = 0             # 0 => no microbatching
