"""grok-1-314b — moe 64L d_model=6144 48H (GQA kv=8) d_ff=32768
vocab=131072, MoE 8 experts top-2. [hf:xai-org/grok-1]

8 experts do not divide the 16-way model axis, so each expert is
tensor-sharded over d_ff (experts replicated count-wise) — see DESIGN.md.
"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="grok-1-314b",
    family="moe",
    n_layers=64,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    head_dim=128,
    d_ff=32768,
    vocab_size=131072,
    qkv_bias=False,
    norm="rmsnorm",
    act="gelu",
    gated_mlp=True,
    long_context="sliding_window",
    sliding_window=4096,
    moe=MoEConfig(n_experts=8, top_k=2, expert_d_ff=32768),
    source="hf:xai-org/grok-1",
)
