"""whisper-small — audio enc-dec 12L d_model=768 12H (kv=12) d_ff=3072
vocab=51865 — enc-dec, conv frontend (stub). [arXiv:2212.04356]

The mel-spectrogram + conv feature extractor is STUBBED per the assignment
carve-out: input_specs() supplies 1500 precomputed frame embeddings
(whisper's 30 s / 2x-downsampled audio context). The 12L encoder transformer
and 12L decoder (self-attn cache + cross-attn to encoder states) are real.

Whisper uses non-gated GELU MLPs, LayerNorm with bias, learned positions
(we use sinusoidal-equivalent learned tables), and biased projections.
long_500k runs via the sliding-window decoder variant (structurally valid;
semantically whisper is bounded to 30 s windows — see DESIGN.md).
"""
from repro.configs.base import EncoderConfig, ModelConfig

N_AUDIO_FRAMES = 1500

CONFIG = ModelConfig(
    name="whisper-small",
    family="audio",
    n_layers=12,
    d_model=768,
    n_heads=12,
    n_kv_heads=12,
    d_ff=3072,
    vocab_size=51865,
    qkv_bias=True,
    mlp_bias=True,
    norm="layernorm",
    act="gelu",
    gated_mlp=False,
    tie_embeddings=True,
    long_context="sliding_window",
    sliding_window=4096,
    encoder=EncoderConfig(n_layers=12, d_model=768, n_heads=12, d_ff=3072,
                          seq_len=N_AUDIO_FRAMES),
    source="arXiv:2212.04356",
)
