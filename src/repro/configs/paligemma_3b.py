"""paligemma-3b — vlm 18L d_model=2048 8H (GQA kv=1, i.e. MQA) d_ff=16384
vocab=257216 — SigLIP + gemma. [arXiv:2407.07726]

The SigLIP vision tower is STUBBED per the assignment carve-out:
input_specs() supplies 256 precomputed patch embeddings (d=2048 after the
projector). The gemma-2b text decoder is implemented in full (prefix-LM
attention over image tokens, causal over text).
"""
from repro.configs.base import EncoderConfig, ModelConfig

N_PATCHES = 256

CONFIG = ModelConfig(
    name="paligemma-3b",
    family="vlm",
    n_layers=18,
    d_model=2048,
    n_heads=8,
    n_kv_heads=1,
    head_dim=256,                # gemma-2b: 8 heads x 256
    d_ff=16384,
    vocab_size=257216,
    qkv_bias=False,
    norm="rmsnorm",
    act="gelu",                  # gemma uses gelu-gated MLP
    gated_mlp=True,
    tie_embeddings=True,
    long_context="sliding_window",
    sliding_window=4096,
    encoder=EncoderConfig(n_layers=0, d_model=2048, n_heads=0, d_ff=0,
                          seq_len=N_PATCHES),   # stub: projected patch embeds
    source="arXiv:2407.07726",
)
