"""Reward models (paper's r(x, y)).

* `VerifierReward` — binary programmatic verifier (Math/Code analogue:
  exact-match / unit-test oracle from the task suite).
* `RewardModel`    — a scalar-head LM (OffsetBias-RM analogue): pools the
  final hidden state over (query, response) and projects to a score.
  Trained with Bradley-Terry pairwise loss or MSE regression on
  synthetic preference data.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import build_model
from repro.models import modules as nn


@dataclass
class VerifierReward:
    """check_fn(query, response_tokens) -> bool."""
    check_fn: Callable

    def __call__(self, query, responses: Sequence) -> np.ndarray:
        return np.asarray([1.0 if self.check_fn(query, r) else 0.0
                           for r in responses])


class RewardModel:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self.model = build_model(cfg)

    def init(self, key):
        k1, k2 = jax.random.split(key)
        return {"lm": self.model.init(k1)["lm"],
                "head": nn.init_linear(k2, self.cfg.d_model, 1, bias=True)}

    def score(self, params, tokens: jnp.ndarray,
              mask: Optional[jnp.ndarray] = None) -> jnp.ndarray:
        """tokens (b, s) query+SEP+response -> scalar scores (b,)."""
        _, hidden, _ = self.model.forward({"lm": params["lm"]}, tokens)
        if mask is None:
            pooled = hidden[:, -1]
        else:
            m = mask.astype(hidden.dtype)[..., None]
            pooled = (hidden * m).sum(1) / jnp.maximum(m.sum(1), 1.0)
        return nn.linear(params["head"], pooled.astype(jnp.float32))[:, 0]

    def bt_loss(self, params, tok_chosen, tok_rejected) -> jnp.ndarray:
        """Bradley-Terry pairwise preference loss."""
        s_c = self.score(params, tok_chosen)
        s_r = self.score(params, tok_rejected)
        return jnp.mean(jax.nn.softplus(-(s_c - s_r)))

    def mse_loss(self, params, tokens, targets) -> jnp.ndarray:
        return jnp.mean((self.score(params, tokens)
                         - targets.astype(jnp.float32)) ** 2)

    def train(self, key, tokens: np.ndarray, targets: np.ndarray, *,
              steps: int = 300, lr: float = 1e-3, batch: int = 64):
        """MSE regression training on (sequence, reward) pairs."""
        from repro.optim import adamw_init, adamw_update

        params = self.init(key)
        opt = adamw_init(params)
        tok = jnp.asarray(tokens)
        tgt = jnp.asarray(targets, jnp.float32)
        rng = np.random.default_rng(0)

        @jax.jit
        def step(params, opt, idx):
            loss, g = jax.value_and_grad(self.mse_loss)(params, tok[idx],
                                                        tgt[idx])
            params, opt = adamw_update(params, g, opt, lr=lr)
            return params, opt, loss

        hist = []
        for s in range(steps):
            idx = jnp.asarray(rng.integers(0, len(tok),
                                           size=min(batch, len(tok))))
            params, opt, loss = step(params, opt, idx)
            if s % 50 == 0:
                hist.append((s, float(loss)))
        return params, hist
