from repro.rewards.reward_model import RewardModel, VerifierReward  # noqa: F401
