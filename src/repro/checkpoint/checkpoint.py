"""Sharding-aware npz checkpointing (orbax is not installed offline).

Pytrees are flattened to path-keyed arrays; metadata (step, config, tree
structure) rides in a JSON sidecar. On restore under a mesh, arrays are
placed with `jax.device_put(x, sharding)` leaf-wise.
"""
from __future__ import annotations

import json
import re
from pathlib import Path
from typing import Any, Dict, Optional

import jax
import numpy as np


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        arr = np.asarray(leaf)
        if arr.dtype.kind not in "fiub" or str(arr.dtype) == "bfloat16":
            # npz can't roundtrip ml_dtypes (bf16 etc.): store as fp32
            arr = arr.astype(np.float32)
        out[key] = arr
    return out


def save_checkpoint(path: str, tree, *, step: int = 0,
                    extra: Optional[Dict[str, Any]] = None) -> str:
    path = Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    arrays = _flatten(tree)
    np.savez(str(path) + ".npz", **arrays)
    treedef = jax.tree.structure(tree)
    meta = {"step": step, "treedef": str(treedef),
            "keys": sorted(arrays), "extra": extra or {}}
    with open(str(path) + ".json", "w") as f:
        json.dump(meta, f, indent=1)
    return str(path)


def load_checkpoint(path: str, like, *, shardings=None):
    """Restore into the structure of `like` (a pytree of arrays or
    ShapeDtypeStructs). If shardings (same-structure pytree) is given,
    leaves are device_put with them."""
    data = np.load(str(path) + ".npz")
    flat_like = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    for path_k, leaf in flat_like[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path_k)
        arr = data[key]
        if hasattr(leaf, "dtype") and arr.dtype != leaf.dtype:
            import jax.numpy as jnp
            arr = np.asarray(jnp.asarray(arr).astype(leaf.dtype))
        leaves.append(arr)
    tree = jax.tree.unflatten(jax.tree.structure(like), leaves)
    if shardings is not None:
        tree = jax.tree.map(jax.device_put, tree, shardings)
    return tree


def load_meta(path: str) -> Dict[str, Any]:
    with open(str(path) + ".json") as f:
        return json.load(f)


def latest_checkpoint(ckpt_dir: str, prefix: str = "ckpt_"):
    d = Path(ckpt_dir)
    if not d.exists():
        return None
    steps = []
    for f in d.glob(prefix + "*.json"):
        m = re.match(prefix + r"(\d+)", f.stem)
        if m:
            steps.append(int(m.group(1)))
    if not steps:
        return None
    return str(d / f"{prefix}{max(steps)}")
