from repro.checkpoint.checkpoint import (  # noqa: F401
    latest_checkpoint,
    load_checkpoint,
    load_meta,
    save_checkpoint,
)
