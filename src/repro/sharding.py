"""Logical-axis sharding: modules name axes logically; a per-run rule set maps
logical names to mesh axes (MaxText/flax "logical axis rules" pattern, built
from scratch — flax is not available here).

Modules call ``lshard(x, "batch", "seq_sp", None)``; outside a mesh context
this is a no-op, so smoke tests and CPU benchmarks never touch device state.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Dict, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Logical = Union[str, None, Tuple[str, ...]]

_state = threading.local()


def _ctx():
    if not hasattr(_state, "stack"):
        _state.stack = []
    return _state.stack


@contextlib.contextmanager
def axis_rules(mesh: Optional[Mesh], rules: Dict[str, Union[str, Tuple[str, ...], None]]):
    """Activate a (mesh, logical->mesh-axis) mapping for lshard/lspec calls."""
    _ctx().append((mesh, dict(rules)))
    try:
        yield
    finally:
        _ctx().pop()


def current_rules() -> Optional[Tuple[Optional[Mesh], Dict]]:
    stack = _ctx()
    return stack[-1] if stack else None


def logical_spec(names: Sequence[Logical],
                 rules: Optional[Dict] = None) -> P:
    """Resolve a tuple of logical axis names to a PartitionSpec."""
    if rules is None:
        cur = current_rules()
        rules = cur[1] if cur else {}
    out = []
    used = set()
    for nm in names:
        if nm is None:
            out.append(None)
            continue
        axes = rules.get(nm)
        if axes is None:
            out.append(None)
        else:
            if isinstance(axes, str):
                axes = (axes,)
            axes = tuple(a for a in axes if a not in used)
            used.update(axes)
            if not axes:
                out.append(None)
            elif len(axes) == 1:
                out.append(axes[0])
            else:
                out.append(tuple(axes))
    while out and out[-1] is None:
        out.pop()
    return P(*out)


def lshard(x: jax.Array, *names: Logical) -> jax.Array:
    """with_sharding_constraint by logical names (no-op without rules/mesh)."""
    cur = current_rules()
    if cur is None:
        return x
    mesh, rules = cur
    if mesh is None:
        return x
    spec = logical_spec(names, rules)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def spec_tree_to_shardings(mesh: Mesh, spec_tree, rules: Dict):
    """Map a pytree of logical-name tuples to NamedShardings."""
    def _one(names):
        return NamedSharding(mesh, logical_spec(names, rules))
    return jax.tree.map(_one, spec_tree,
                        is_leaf=lambda x: isinstance(x, tuple) or x is None)


def default_rules(cfg, mesh: Mesh) -> Dict[str, Union[str, Tuple[str, ...], None]]:
    """Per-arch logical->mesh mapping for the production meshes.

    'model' shards heads/ff/vocab; batch shards over ('pod','data') when the
    pod axis exists. Divisibility-dependent decisions (kv heads, experts) are
    made here so module code stays shape-agnostic.
    """
    axes = mesh.axis_names
    batch_axes = tuple(a for a in ("pod", "data") if a in axes)
    tp = mesh.shape["model"] if "model" in axes else 1
    rules: Dict[str, Union[str, Tuple[str, ...], None]] = {
        "batch": batch_axes,
        "seq_sp": "model",          # Megatron-SP residual stream
        "heads": "model",           # q heads are padded to a multiple of tp
        "embed": None,
        "mlp": "model",
        "vocab": "model",
        "kv_seq": "model",          # decode KV caches shard the seq dim
        "mamba_inner": "model",
        "mlstm_v": "model",
        "q_lora": None,
        "kv_lora": None,
    }
    kv = getattr(cfg, "n_kv_heads", 0)
    rules["kv_heads"] = "model" if (kv and kv % tp == 0) else None
    moe = getattr(cfg, "moe", None)
    if moe is not None:
        if moe.n_experts % tp == 0:
            rules["experts"] = "model"
            rules["expert_ff"] = None
        else:
            rules["experts"] = None
            rules["expert_ff"] = "model"
    return rules


def pad_to_multiple(n: int, m: int) -> int:
    return ((n + m - 1) // m) * m
