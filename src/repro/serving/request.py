"""Request lifecycle for the continuous-batching runtime.

A *request* is one user query; the adaptive policy turns it into ``b_i``
*child sequences* (best-of-k fan-out) that share a single probe prefill.
Children occupy decode slots independently, so a request's fan-out can
start on different ticks when the pool is momentarily full.

State machine::

    QUEUED   submitted, awaiting prefill
    PREFILL  probed (hidden state + prefill cache stashed), awaiting a
             budget and/or free slots
    DECODE   at least one child admitted to a slot
    RERANK   all children finished, reward ranking in progress
    DONE     best response selected (or default response for b_i = 0)
"""
from __future__ import annotations

import enum
import time
from dataclasses import dataclass, field
from typing import Any, List, Optional

import numpy as np


class RequestState(enum.Enum):
    QUEUED = "queued"
    PREFILL = "prefill"
    DECODE = "decode"
    RERANK = "rerank"
    DONE = "done"


@dataclass
class PrefillStash:
    """Device-resident prefill result shared by all requests of one
    prefill group: cache leaves (n_repeat, g, S, ...), logits (g, V).
    Row `row` belongs to this request. Dropped once the last child has
    been admitted (the pool slots then hold the only copies)."""
    cache: Any
    logits: Any
    row: int
    start_pos: int          # prompt_len - 1 (next decode writes slot sp)


@dataclass
class ChildSeq:
    """One best-of-k sample; owns a decode slot while live. Identity (for
    RNG streams and results) is (request_id, index)."""
    request_id: int
    index: int                              # j within the request
    slot: Optional[int] = None
    tokens: List[int] = field(default_factory=list)

    def done(self, max_new: int) -> bool:
        return len(self.tokens) >= max_new


@dataclass
class Request:
    id: int
    prompt: np.ndarray                      # (sp,) int32
    query: Any = None                       # opaque object for the reward fn
    budget: Optional[int] = None            # None until the policy decides
    max_new: int = 16
    state: RequestState = RequestState.QUEUED
    children: List[ChildSeq] = field(default_factory=list)
    pending: List[ChildSeq] = field(default_factory=list)   # not yet slotted
    stash: Optional[PrefillStash] = None
    hidden: Optional[np.ndarray] = None     # (d,) probe feature
    response: Optional[np.ndarray] = None
    reward: float = 0.0
    submit_t: float = field(default_factory=time.perf_counter)
    done_t: Optional[float] = None

    @property
    def prompt_len(self) -> int:
        return int(len(self.prompt))

    @property
    def latency(self) -> Optional[float]:
        return None if self.done_t is None else self.done_t - self.submit_t

    def all_children_done(self) -> bool:
        return (not self.pending
                and all(c.done(self.max_new) for c in self.children))
