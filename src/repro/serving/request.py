"""Request lifecycle for the continuous-batching runtime.

A *request* is one user query; its :class:`DecodeProcedure` turns it into
*child sequences* — best-of-k fan-out, a routed weak-or-strong child,
cascade escalations — grouped per model. Children occupy decode slots
independently, so a request's fan-out can start on different ticks when
the pool is momentarily full.

State machine::

    QUEUED      submitted (or re-queued for a later model phase),
                awaiting prefill on ``model_id``
    PREFILLING  paged mode: chunked prefill in flight (up to
                ``prefill_chunk`` prompt tokens per tick through the
                varlen chunk program — or one per decode tick for
                recurrent-state stacks — starting at the radix-matched
                prefix length)
    PREFILL     probed (hidden state + prefill cache/blocks stashed),
                awaiting a plan/budget and/or free slots
    DECODE      at least one child admitted to a slot
    RERANK      all children finished, procedure finalize in progress
    DONE        response selected (or default response for an empty plan)

A request may pass through QUEUED → PREFILL more than once: a procedure
group on a model whose prompt KV is not resident (routing escalation, a
cascade's strong retry) queues a fresh prefill *phase* on that model —
``pending_phases`` holds the groups awaiting one, and the radix prefix
cache makes a same-model re-prefill nearly free.
"""
from __future__ import annotations

import enum
import time
from dataclasses import dataclass, field
from typing import Any, List, Optional

import numpy as np


class RequestState(enum.Enum):
    QUEUED = "queued"
    PREFILLING = "prefilling"
    PREFILL = "prefill"
    DECODE = "decode"
    RERANK = "rerank"
    DONE = "done"


@dataclass(eq=False)        # identity-hashed: lives in the runtime's set
class StashGroup:
    """One device-resident prefill cache shared by a same-length prefill
    group. Its batch dim is the group's original size (`rows`) and it is
    only freeable when the *last* member drops its stash — so the prefill
    window must keep counting every row until the group dies, not
    decrement per member (that released window capacity while the cache
    was still fully alive, under-throttling memory on large groups).
    `nondeferred` counts live members still flowing through the pipeline;
    groups whose every member is parked on an un-called set_budget() are
    excluded from the window so they cannot starve new arrivals."""
    size: int = 0
    nondeferred: int = 0
    rows: int = 0               # original size: cache rows pinned


@dataclass
class PrefillStash:
    """Device-resident prefill result. Slot mode: `cache` holds the group
    prefill (leaves (n_repeat, g, S, ...)) and `row` this request's row.
    Paged mode: the prompt lives in the request's blocks already, so
    `cache` is None and `logits` is this request's probe row alone — a
    (V,) array (`row` stays 0), which is exactly what the batched fan-out
    admission program stacks across same-tick children; `state` snapshots
    recurrent-state rows for fan-out. Dropped once the last child has
    been admitted."""
    cache: Any
    logits: Any
    row: int
    start_pos: int          # prompt_len - 1 (next decode writes slot sp)
    group: Optional[StashGroup] = None
    state: Any = None       # paged mode: recurrent-state snapshot
    deferred: bool = False  # awaiting an explicit set_budget() call


@dataclass
class ChildSeq:
    """One sampled continuation; owns a decode slot while live. Identity
    (for RNG streams and results) is (request_id, index) — the index is
    global across the request's groups/models, so escalation children get
    fresh streams. ``model_id`` names the registry model that decodes it;
    ``max_new`` is its own token budget (a procedure group may cap it
    below the request's)."""
    request_id: int
    index: int                              # j within the request
    model_id: str = "default"               # registry model decoding it
    max_new: int = 0                        # per-child token budget
    slot: Optional[int] = None
    tokens: List[int] = field(default_factory=list)
    eos: bool = False                       # emitted EOS -> retired early
    table: Optional[List[int]] = None       # paged mode: block table
    reserved: int = 0                       # paged mode: unclaimed blocks

    def done(self, max_new: Optional[int] = None) -> bool:
        limit = self.max_new if max_new is None else max_new
        return self.eos or len(self.tokens) >= limit

    def output_tokens(self, eos_id: Optional[int] = None) -> np.ndarray:
        """Reranker/response view: tokens truncated after the first EOS
        (the EOS itself is kept; anything past it is decode waste)."""
        toks = np.asarray(self.tokens, np.int32)
        if eos_id is not None:
            hits = np.flatnonzero(toks == eos_id)
            if hits.size:
                toks = toks[: int(hits[0]) + 1]
        return toks


@dataclass
class Request:
    id: int
    prompt: np.ndarray                      # (sp,) int32
    query: Any = None                       # opaque object for the reward fn
    budget: Optional[int] = None            # None until the policy decides
    max_new: int = 16
    procedure: Any = None                   # DecodeProcedure driving it
    proc: dict = field(default_factory=dict)    # procedure-owned state
    model_id: str = "default"               # model of the current phase
    planned: bool = False                   # procedure.plan already ran
    pending_phases: List[Any] = field(default_factory=list)  # ChildGroups
    state: RequestState = RequestState.QUEUED
    children: List[ChildSeq] = field(default_factory=list)
    pending: List[ChildSeq] = field(default_factory=list)   # not yet slotted
    stash: Optional[PrefillStash] = None
    hidden: Optional[np.ndarray] = None     # (d,) probe feature
    table: Optional[List[int]] = None       # paged mode: prompt block table
    prefill_pos: int = 0                    # paged mode: chunked progress
    prefix_len: int = 0                     # radix-matched tokens (skipped)
    reserved: int = 0                       # paged: standing 1-child reserve
    response: Optional[np.ndarray] = None
    reward: float = 0.0
    submit_t: float = field(default_factory=time.perf_counter)
    done_t: Optional[float] = None
    # --- traffic subsystem (priority scheduling / preemption / SLO) ---
    tenant: str = "default"                 # admission-budget accounting key
    priority: int = 1                       # higher = served first
    slo: Optional[float] = None             # deadline in seconds from submit
    admit_t: Optional[float] = None         # first pop from the queue
    first_token_t: Optional[float] = None   # first sampled token (TTFT)
    preemptions: int = 0                    # times evicted and requeued
    degraded: bool = False                  # budget shaved under load

    @property
    def prompt_len(self) -> int:
        return int(len(self.prompt))

    @property
    def latency(self) -> Optional[float]:
        return None if self.done_t is None else self.done_t - self.submit_t

    def met_slo(self) -> Optional[bool]:
        """True/False once finished against a deadline; None when no SLO
        is set or the request is still in flight."""
        lat = self.latency
        if self.slo is None or lat is None:
            return None
        return lat <= self.slo

    def all_children_done(self) -> bool:
        """No child (live or queued) and no phase awaiting a prefill —
        the procedure's finalize can run."""
        return (not self.pending and not self.pending_phases
                and all(c.done() for c in self.children))
