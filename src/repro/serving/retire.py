"""Retirement layer of the serving tick pipeline (plan -> dispatch ->
retire).

Everything that happens to a request AFTER a device program returns
lives here: consuming each program's host buffers (token appends, EOS
and max_new accounting, prefill-probe stashes), radix publishing, slot
and block frees, the procedure lifecycle (``plan`` / ``on_child_done``
/ ``finalize`` routing and phase scheduling), preemption, streaming
emit hooks, and the block-ledger audits. The runtime keeps thin
delegates for the names tests and procedures reach for
(``_preempt_request``, ``assert_ledger_balanced``, ``_run_plan``);
all state still lives on the runtime — this class is behavior, not
storage, so the pieces stay individually readable and the runtime
module stays a scheduler.
"""
from __future__ import annotations

import time
from typing import Dict, List

import numpy as np

from repro.serving.procedure import ChildGroup
from repro.serving.request import (ChildSeq, Request, RequestState,
                                   StashGroup)


class Retirement:
    """Host-side consumer of every tick program's results; owns the
    request/child lifecycle from token to DONE. Holds only the runtime
    reference."""

    def __init__(self, rt):
        self.rt = rt

    # ------------------------------------------------- procedure routing
    def run_plan(self, r: Request) -> None:
        """Ask the request's procedure for its plan (probe prefill has
        landed). None parks the request — the stash is marked deferred
        and excluded from the prefill window until set_budget re-plans."""
        rt = self.rt
        plan = r.procedure.plan(r, r.hidden, rt)
        if plan is None:
            rt._defer_stash(r)
            return
        r.planned = True
        self.apply_groups(r, list(plan.groups))

    def apply_groups(self, r: Request, groups: List[ChildGroup]) -> None:
        """Turn procedure child-groups into work. Groups on the model
        whose prefill stash is live spawn immediately (they share the
        probe prefill, exactly the old fan-out); groups on other models —
        or arriving after the stash was dropped — queue a prefill *phase*
        on their model. An empty plan with no children is the paper's
        b_i = 0: release everything and answer with the default."""
        rt = self.rt
        # validate the WHOLE plan before mutating anything: a KeyError
        # raised mid-loop would leave earlier groups' children spawned
        # but never admitted (pending entries with no fanout slot), a
        # corrupt half-applied plan the drain loop hangs on
        for g in groups:
            if g.model_id not in rt.models:
                raise KeyError("plan names unregistered model "
                               f"{g.model_id!r}")
        was_pending = bool(r.pending)   # already in the fanout deque
        spawned = 0
        for g in groups:
            if r.stash is not None and g.model_id == r.model_id:
                spawned += self._spawn_group(r, g)
            else:
                r.pending_phases.append(g)
        if spawned:
            r.state = RequestState.DECODE
            # invariant: a request appears in rt.fanout exactly once,
            # iff it has pending children — an on_child_done escalation
            # landing while earlier children still await admission must
            # not enqueue a duplicate (the stale entry would outlive the
            # first pop and crash the admission loop on empty pending)
            if not was_pending:
                rt.fanout.append(r)
        elif r.stash is not None and not r.pending:
            # nothing rides the current stash: drop it (and the standing
            # child reservation sized for a child that will never spawn).
            # `not r.pending` guards the preemption-resume path — there
            # the fresh stash/table/reservation belong to the evicted
            # children about to re-admit, even when no NEW group spawned
            if rt.pool_kind == "paged":
                rt._release_prompt_table(r)
                rt.pool.unreserve(r.reserved)
                r.reserved = 0
            rt._drop_stash(r)
        if not r.children and not r.pending_phases and not r.pending:
            self.finalize(r)            # empty plan: default response
            return
        self.maybe_start_next_phase(r)

    def _spawn_group(self, r: Request, g: ChildGroup) -> int:
        """Create g.n children on g.model_id sharing the live stash."""
        mn = r.max_new if g.max_new is None else int(g.max_new)
        if mn > r.max_new:
            raise ValueError(
                f"group max_new {mn} exceeds the request's {r.max_new}: "
                "admission reservations are sized to the request")
        for _ in range(int(g.n)):
            c = ChildSeq(request_id=r.id, index=len(r.children),
                         model_id=g.model_id, max_new=mn)
            r.children.append(c)
            r.pending.append(c)
        return int(g.n)

    def maybe_start_next_phase(self, r: Request) -> None:
        """Queue the next pending phase's prefill once the current
        stash/table are gone and no children await admission (phases are
        sequential per request; distinct requests' phases interleave
        freely)."""
        if (not r.pending_phases or r.pending or r.stash is not None
                or r.state in (RequestState.QUEUED,
                               RequestState.PREFILLING)
                or any(c.slot is not None for c in r.children)):
            # the live-children guard: an escalation landing while a
            # sibling still decodes must NOT re-enter QUEUED yet — the
            # phase prefill would run concurrently with the sibling's
            # decode, and admission's `r.table = matched` adoption plus
            # the preemption teardown both assume a QUEUED request has
            # no slotted children. The phase starts when the last
            # sibling retires (retire_child re-calls this).
            return
        r.model_id = r.pending_phases[0].model_id
        r.state = RequestState.QUEUED
        r.prefill_pos = 0
        r.prefix_len = 0
        self.rt.queue.append(r)

    def on_prefill_complete(self, r: Request) -> None:
        """Prefill landed (probe or phase): plan once, then spawn every
        queued group this phase's model satisfies."""
        rt = self.rt
        r.state = RequestState.PREFILL
        if not r.planned:
            self.run_plan(r)
            return
        if r.pending:
            # preemption resume: the evicted children are back in
            # ``pending`` and this fresh prefill is their prompt — re-enter
            # the fan-out backlog (the append is safe: preemption removed
            # the request from ``fanout``, and a request is never preempted
            # twice without an intervening resume)
            r.state = RequestState.DECODE
            rt.fanout.append(r)
        groups: List[ChildGroup] = []
        while (r.pending_phases
               and r.pending_phases[0].model_id == r.model_id):
            groups.append(r.pending_phases.pop(0))
        self.apply_groups(r, groups)

    # ------------------------------------------- program result consumers
    def _append_token(self, r: Request, c: ChildSeq, t: int) -> None:
        c.tokens.append(t)
        rt = self.rt
        if rt.eos_id is not None and t == rt.eos_id:
            c.eos = True
            rt.metrics.record_eos(c.max_new - len(c.tokens))

    def _finish_probe(self, s: int, r: Request, logits_row, hidden_row,
                      state=None) -> None:
        """A prefill slot computed its final prompt token: publishable
        blocks are already in the radix tree (the caller published), so
        stash the probe row, free the slot, and route to the
        procedure."""
        rt = self.rt
        r.hidden = hidden_row
        group = StashGroup()
        # stash only this request's probe row (a (V,) device row —
        # exactly what batched fan-out admission stacks): stashing the
        # whole tick tensor would pin the full dispatch footprint until
        # fan-out — indefinitely for budget-deferred requests
        rt._make_stash(r, group, cache=None, logits=logits_row, row=0,
                       start_pos=r.prompt_len - 1, state=state)
        del rt._pref[s]
        rt.pool.release_slot(s)
        rt._tok[s] = 0
        rt._pos[s] = 0
        self.on_prefill_complete(r)

    def retire_token(self, pp, sampled_np, logits, hidden_np) -> None:
        """Consume a per-token dispatch: advance the chunk-1 prefill
        interleave and append each decode slot's sampled token."""
        rt = self.rt
        B = rt.pool.block_size
        radix = rt._radix_of(pp.model_id)
        for s in pp.prefill_slots:
            r = rt._pref[s]
            t = int(rt._pos[s])
            if t == r.prompt_len - 1:           # probe complete
                if radix is not None:
                    created = radix.publish(r.prompt, r.table,
                                            r.prompt_len // B)
                    if created:
                        rt.metrics.record_radix(published=created)
                self._finish_probe(
                    s, r, logits[s], hidden_np[s],
                    state=rt.pool.snapshot_slot_state(
                        s, model_id=pp.model_id))
            else:
                r.prefill_pos = t + 1
                rt._pos[s] = t + 1
                rt._tok[s] = int(r.prompt[t + 1])
        for s in pp.decode_slots:
            c = rt.slots[s]
            if c is None:
                continue
            r = rt.requests[c.request_id]
            self._append_token(r, c, int(sampled_np[s]))
            rt._notify_emit(r, c)
            if c.done():
                self.retire_child(c, r)
            else:
                rt._tok[s] = c.tokens[-1]
                rt._pos[s] = int(rt._pos[s]) + 1

    def retire_chunk(self, pp, logits, hidden, take: Dict[int, int]) -> None:
        """Consume a chunked-prefill dispatch: publish whole blocks the
        chunk finished into the radix tree immediately (not at probe
        completion), and stash completed probes."""
        rt = self.rt
        radix = rt._radix_of(pp.model_id)
        hidden_np = None
        for i, s in enumerate(pp.prefill_slots):
            r = rt._pref[s]
            L = take[s]
            end = r.prefill_pos + L
            if radix is not None:
                created = radix.publish(r.prompt, r.table,
                                        end // rt.pool.block_size)
                if created:
                    rt.metrics.record_radix(published=created)
            if end == r.prompt_len:             # probe complete
                if hidden_np is None:
                    hidden_np = np.asarray(hidden, np.float32)  # analysis: allow(sync)
                    rt.metrics.record_sync(model=pp.model_id)
                self._finish_probe(s, r, logits[i, L - 1],
                                   hidden_np[i, L - 1])
            else:
                r.prefill_pos = end
                # keep the slot's scan-entry state in sync: a later tick
                # may pick this row up in the MIXED program, which seeds
                # its scan from _tok/_pos (the chunk dispatcher itself
                # reads the prompt directly and ignores these)
                rt._tok[s] = int(r.prompt[end])
                rt._pos[s] = end

    def _drain_decode_rows(self, pp, buf) -> int:
        """Append each decode slot's horizon tokens from the (H, 2, N)
        [token; alive] buffer until its row froze (EOS / budget), retire
        finished children, and return how many tokens were emitted."""
        rt = self.rt
        emitted = 0
        for s in pp.decode_slots:
            c = rt.slots[s]
            r = rt.requests[c.request_id]
            took = 0
            for h in range(pp.horizon):
                if not buf[h, 1, s]:            # frozen: EOS'd earlier
                    break
                t = int(buf[h, 0, s])
                c.tokens.append(t)
                took += 1
                if rt.eos_id is not None and t == rt.eos_id:
                    c.eos = True
                    rt.metrics.record_eos(c.max_new - len(c.tokens))
                    break
            emitted += took
            rt._notify_emit(r, c)
            if c.done():
                self.retire_child(c, r)
            else:                               # survivor: emitted all H
                rt._tok[s] = c.tokens[-1]
                rt._pos[s] = int(rt._pos[s]) + took
        return emitted

    def retire_horizon(self, pp, buf) -> None:
        """Consume a pure-decode horizon dispatch."""
        emitted = self._drain_decode_rows(pp, buf)
        self.rt.metrics.record_horizon(len(pp.decode_slots), pp.horizon,
                                       emitted, model=pp.model_id)

    def retire_mixed(self, pp, buf, probe_lg, probe_hid,
                     consumed: Dict[int, int]) -> None:
        """Consume a fused mixed dispatch: decode rows get exactly the
        horizon retirement; each prefill row advances by the prompt
        tokens its role consumed, publishing finished whole blocks, and
        a row whose LAST prompt token landed mid-horizon stashes its
        captured probe logits/hidden rows — same values the chunk
        program would have produced at those positions."""
        rt = self.rt
        B = rt.pool.block_size
        emitted = self._drain_decode_rows(pp, buf)
        radix = rt._radix_of(pp.model_id)
        hid_np = None
        pref_tokens = 0
        for s in pp.prefill_slots:
            r = rt._pref[s]
            took = consumed[s]
            pref_tokens += took
            end = r.prefill_pos + took
            if radix is not None:
                created = radix.publish(r.prompt, r.table, end // B)
                if created:
                    rt.metrics.record_radix(published=created)
            if end == r.prompt_len:             # probe landed mid-scan
                if hid_np is None:
                    hid_np = np.asarray(probe_hid, np.float32)  # analysis: allow(sync)
                    rt.metrics.record_sync(model=pp.model_id)
                self._finish_probe(s, r, probe_lg[s], hid_np[s])
            else:
                r.prefill_pos = end
                rt._tok[s] = int(r.prompt[end])
                rt._pos[s] = end
        rt.metrics.record_prefill(pref_tokens, model=pp.model_id)
        rt.metrics.record_mixed(len(pp.decode_slots),
                                len(pp.prefill_slots), pp.horizon,
                                emitted, pref_tokens, model=pp.model_id)

    # -------------------------------------------------- child / request
    def retire_child(self, c: ChildSeq, r: Request) -> None:
        """Free the child's slot, blocks (shared ones decref), and any
        unclaimed reservation — immediately, so EOS/short children return
        memory to the pool the same tick they finish. The procedure's
        `on_child_done` hook then gets a chance to spawn more work
        (cascade escalation to another model, extra fan-out)."""
        rt = self.rt
        slot = c.slot
        rt.slots[slot] = None
        rt.pool.release_slot(slot)
        rt._tok[slot] = 0
        rt._pos[slot] = 0
        c.slot = None
        rt.pool.release_table(c.table)
        c.table = None
        rt.pool.unreserve(c.reserved)
        c.reserved = 0
        more = r.procedure.on_child_done(r, c, rt)
        if more:
            self.apply_groups(r, list(more))
        if r.all_children_done():
            self.finalize(r)
        else:
            # this retirement may have been the last live sibling
            # holding a queued escalation phase back
            self.maybe_start_next_phase(r)

    def finalize(self, r: Request) -> None:
        rt = self.rt
        if r.children:
            r.state = RequestState.RERANK
            r.procedure.finalize(r, rt)
        else:
            # empty plan (b_i = 0): the documented default response — an
            # empty token row with zero reward (the paper's "answer with
            # the default")
            r.response = np.zeros((0,), np.int32)
            r.reward = 0.0
            rt.metrics.record_default()
        r.state = RequestState.DONE
        r.done_t = time.perf_counter()
        rt.metrics.record_done(r.latency)

    # --------------------------------------------------------- preemption
    def preempt_request(self, r: Request) -> int:
        """Evict a resident request and requeue it through the existing
        phase/QUEUED re-entry path; returns blocks freed.

        The eviction is radix-cheap: before any block is released, the
        request's full prompt blocks are published into the model's radix
        tree (idempotent — chunked prefill usually already did), so the
        tree's refcounts keep the prompt KV alive across the eviction and
        the resumed request re-prefills near-free (adopting the published
        blocks at admission, recomputing only the final prompt token).
        Live children are reset to token 0; their per-child RNG streams
        (``fold_in(fold_in(seed, id), index)``) restart from scratch on
        re-admission, so the regenerated sequences — and the request's
        final response — are bitwise identical to an unpreempted run.
        Already-retired children (EOS / budget done) keep their tokens."""
        rt = self.rt
        pool = rt.pool
        free_before = pool.available_blocks
        live = [c for c in r.children if c.slot is not None]
        # a raise inside the fanout admission window (copy_block device
        # failure, ledger assert) leaves a child popped from r.pending
        # with its table filled but no slot yet: it holds real block
        # refs, so tear it down and re-queue it like any evicted child
        # — skipping it here is a permanent leak AND a lost child
        orphans = [c for c in r.children
                   if c.slot is None and c.table is not None]
        model = live[0].model_id if live else r.model_id
        radix = rt._radix_of(model)
        table = r.table if r.table is not None else (
            live[0].table if live else None)
        full = r.prompt_len // pool.block_size
        if radix is not None and table is not None and len(table) >= full:
            created = radix.publish(r.prompt, table, full)
            if created:
                rt.metrics.record_radix(published=created)
        for c in live:
            s = c.slot
            rt.slots[s] = None
            pool.release_slot(s)
            rt._tok[s] = 0
            rt._pos[s] = 0
            c.slot = None
            pool.release_table(c.table)
            c.table = None
            pool.unreserve(c.reserved)
            c.reserved = 0
            c.tokens = []
            c.eos = False
        for c in orphans:
            pool.release_table(c.table)
            c.table = None
            pool.unreserve(c.reserved)
            c.reserved = 0
            c.tokens = []
            c.eos = False
        try:
            rt.fanout.remove(r)         # mid-fanout victim (rare)
        except ValueError:
            pass
        # evicted children rejoin any never-slotted ones in index order so
        # re-admission replays the original fan-out sequence
        merged = {c.index: c for c in r.pending}
        merged.update({c.index: c for c in live + orphans})
        r.pending = [merged[i] for i in sorted(merged)]
        rt._drop_stash(r)
        rt._release_prompt_table(r)
        pool.unreserve(r.reserved)
        r.reserved = 0
        r.hidden = None             # recomputed (identically) on resume
        r.model_id = model
        r.state = RequestState.QUEUED
        r.prefill_pos = 0
        r.prefix_len = 0
        r.preemptions += 1
        rt.queue.append(r)
        freed = pool.available_blocks - free_before
        rt.metrics.record_preemption(freed)
        return freed

    def preempt_for(self, beneficiary: Request) -> bool:
        """Pick (policy: TrafficController.choose_victim) and evict one
        resident request strictly below ``beneficiary``'s priority."""
        victim = self.rt.traffic.choose_victim(self.rt, beneficiary)
        if victim is None:
            return False
        self.preempt_request(victim)
        return True

    # ------------------------------------------------------------- audits
    def stall_report(self, ctx: str = "drain") -> str:
        rt = self.rt
        parts = [f"runtime stalled in {ctx}"]
        deferred = [r.id for r in rt.requests.values()
                    if r.state is RequestState.PREFILL
                    and r.stash is not None and r.stash.deferred]
        if deferred:
            parts.append(f"requests awaiting set_budget(): {deferred}")
        if rt.queue:
            parts.append(
                f"queued, cannot prefill: {[r.id for r in rt.queue]}")
        if rt.fanout:
            head = rt.fanout[0]
            if rt.pool_kind == "paged":
                parts.append(
                    f"fan-out blocked for request {head.id} "
                    f"(free_slots={rt.pool.n_free_slots}, "
                    f"free_blocks={rt.pool.n_free_blocks}, "
                    f"reserved={rt.pool._reserved}, "
                    f"radix_held={rt._radix_held})")
            else:
                parts.append(f"fan-out blocked for request {head.id} "
                             f"(free_slots={rt.pool.n_free})")
        phased = [r.id for r in rt.requests.values() if r.pending_phases]
        if phased:
            parts.append(f"requests with pending model phases: {phased}")
        return "; ".join(parts)

    def assert_ledger_balanced(self) -> None:
        """Block-ledger balance: every refcount is explained by a live
        owner (request prompt tables, child tables, radix nodes) and the
        pool's reservation counter equals the live owners' unclaimed
        worst cases. Valid at any step boundary. A leak — e.g. an EOS
        retirement dropping blocks but not its remaining reservation —
        fails here loudly instead of silently shrinking
        ``available_blocks`` until admission starves."""
        rt = self.rt
        if rt.pool_kind != "paged":
            return
        pool = rt.pool
        pool.check_conservation()
        refs = [0] * pool.n_blocks
        reserved = 0
        for r in rt.requests.values():
            if r.table is not None:
                for blk in set(r.table):
                    refs[blk] += 1
            reserved += r.reserved
            if r.state is RequestState.PREFILLING:
                # remaining prompt-growth reservation is implicit: the
                # blocks the prompt still needs beyond its current table
                reserved += pool.blocks_for(r.prompt_len) - len(r.table)
            for c in r.children:
                if c.table is not None:
                    for blk in set(c.table):
                        refs[blk] += 1
                reserved += c.reserved
        for radix in rt._radices.values():
            stack = list(radix.root.values())
            while stack:
                n = stack.pop()
                stack.extend(n.children.values())
                refs[n.block] += 1
        assert refs == pool._ref, (
            "block refcount leak: owners "
            f"{[(i, a, b) for i, (a, b) in enumerate(zip(refs, pool._ref)) if a != b]}")
        assert reserved == pool._reserved, (
            f"reservation leak: owners hold {reserved}, "
            f"pool ledger says {pool._reserved}")
