"""Procedure-centric serving API: pluggable decode procedures.

The paper evaluates *procedures* — best-of-k fan-out (§4.1) and
weak/strong routing (§4.2) — not a single decoding rule, and the serving
runtime should be exactly as general. A :class:`DecodeProcedure` owns one
request's lifecycle through three hooks the runtime calls at fixed points:

``plan(request, probe_hidden, runtime) -> Plan | None``
    Called once, when the request's probe prefill completes on
    ``probe_model``. Decides which model(s) decode the request, how many
    children each fans out, and at what per-child token budget. Returning
    ``None`` parks the request (the back-compat path behind
    :meth:`ContinuousBatchingRuntime.set_budget`, which re-plans).

``on_child_done(request, child, runtime) -> list[ChildGroup] | None``
    Called each time a child retires (EOS or max_new). May spawn more
    work — including on a *different* model (escalation / cascades). The
    runtime schedules any prefill the new groups need; a group on a model
    whose prompt KV is gone re-prefills through the radix prefix cache.

``finalize(request, runtime) -> None``
    Called when every child is done and no phases are pending. Sets
    ``request.response`` / ``request.reward`` from the children — rerank,
    pick-one, ensemble, whatever the procedure means by "the answer".

A :class:`Plan` is a list of :class:`ChildGroup` — ``(model_id, n,
max_new)`` — against the runtime's **model registry**: every model
registered via :meth:`ContinuousBatchingRuntime.register_model` shares
one paged pool (one block ledger, per-model KV stores and radix caches),
so a procedure mixing weak and strong decoders competes for the same
memory the scheduler already meters. Per-request procedure state lives in
``request.proc`` (a dict), so one procedure instance serves any number of
concurrent requests.

Shipped procedures:

* :class:`BestOfK` — the paper's adaptive best-of-k, bitwise identical
  to the pre-procedure runtime under greedy decode (it *is* the default
  procedure behind ``submit(prompt, budget=...)``).
* :class:`Route` — the paper's §4.2 weak/strong router, online and
  continuous-batched: the probe prefill runs on the weak model, a
  predictor estimates p(strong ≻ weak | x), and queries above a
  calibrated threshold decode on the strong model instead (optionally as
  a cascade: decode weak first, escalate only if its answer scores low).
* :class:`Single` — one child on one model; the trivial baseline and the
  building block for weak-only / strong-only reference curves.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, List, Optional, Sequence

import numpy as np

DEFAULT_MODEL = "default"


@dataclass(frozen=True)
class ChildGroup:
    """``n`` fan-out children decoded by ``model_id``. ``max_new`` caps
    each child's generated tokens (None: the request's own max_new; must
    not exceed it — admission reservations are sized to the request)."""
    model_id: str = DEFAULT_MODEL
    n: int = 1
    max_new: Optional[int] = None


@dataclass
class Plan:
    """What a procedure wants decoded for one request. An empty plan is
    the paper's b_i = 0: answer with the default response, decode
    nothing."""
    groups: List[ChildGroup] = field(default_factory=list)

    @property
    def n_children(self) -> int:
        return sum(g.n for g in self.groups)


class DecodeProcedure:
    """Base procedure; subclasses override the three hooks. ``runtime``
    is passed for read access to policy-relevant state (``reward_fn``,
    ``eos_id``, metrics, gating helpers) — procedures must not mutate
    scheduler internals directly; they act by returning plans/groups."""

    #: model whose prefill doubles as the difficulty probe (its final
    #: hidden state is what ``plan`` receives)
    probe_model: str = DEFAULT_MODEL

    def plan(self, request, probe_hidden, runtime) -> Optional[Plan]:
        raise NotImplementedError

    def may_defer(self, request, runtime) -> bool:
        """True when plan() could return None for this request (park
        until set_budget). Prefill admission skips the standing one-child
        block reservation ONLY for such parked work — a procedure that
        always plans immediately must keep the reservation, or a tight
        pool could prefill more prompts than it can ever decode
        (deadlock: every plan's first child blocked on blocks that no
        live child will free)."""
        return False

    def on_child_done(self, request, child, runtime
                      ) -> Optional[List[ChildGroup]]:
        return None

    def finalize(self, request, runtime) -> None:
        _rerank(request, runtime, getattr(self, "reward_fn", None))


def _rerank(request, runtime, reward_fn=None) -> None:
    """Shared finalizer: score every child's (EOS-truncated) token row
    and keep the argmax — exactly the pre-procedure runtime's rerank, so
    BestOfK stays bitwise compatible. With no reward fn, child 0 wins."""
    rows = [c.output_tokens(runtime.eos_id) for c in request.children]
    fn = reward_fn if reward_fn is not None else runtime.reward_fn
    if fn is not None:
        scores = np.asarray(fn(request.query, rows), np.float64)
        j = int(scores.argmax())
        request.response, request.reward = rows[j], float(scores[j])
    else:
        request.response = rows[0]


class BestOfK(DecodeProcedure):
    """Adaptive best-of-k fan-out (paper §4.1) on the procedure API.

    The budget b_i resolves exactly as the pre-procedure runtime did:
    an explicit ``submit(budget=...)`` wins; else the runtime's
    ``budget_fn`` (price-dual streaming allocation, block-gated on the
    paged pool); else the request parks until ``set_budget`` (the
    batch-exact AdaptiveScheduler path). ``k`` pins a fixed fan-out
    instead, ignoring all three. Greedy outputs are token-bitwise
    identical to the old ``submit(prompt, budget=...)`` path — this class
    IS that path now.
    """

    def __init__(self, k: Optional[int] = None, *,
                 model_id: str = DEFAULT_MODEL,
                 reward_fn: Optional[Callable] = None):
        self.k = None if k is None else int(k)
        self.model_id = model_id
        self.probe_model = model_id
        self.reward_fn = reward_fn

    def plan(self, request, probe_hidden, runtime) -> Optional[Plan]:
        b = self.k if self.k is not None else request.budget
        if b is None:
            if runtime.budget_fn is None:
                return None                     # park until set_budget()
            b = int(runtime.budget_fn(request, probe_hidden))
            if runtime.pool_kind == "paged":
                b = runtime._gate_budget(request, b)
            request.budget = b
        b = int(b)
        return Plan([ChildGroup(self.model_id, b)] if b > 0 else [])

    def may_defer(self, request, runtime) -> bool:
        return (self.k is None and request.budget is None
                and runtime.budget_fn is None)

    def finalize(self, request, runtime) -> None:
        _rerank(request, runtime, self.reward_fn)


class Single(DecodeProcedure):
    """One child on one model — the uniform-b=1 baseline, and the probe
    used by routing benchmarks for the weak-only / strong-only
    endpoints."""

    def __init__(self, model_id: str = DEFAULT_MODEL, *,
                 max_new: Optional[int] = None,
                 reward_fn: Optional[Callable] = None):
        self.model_id = model_id
        self.probe_model = model_id
        self.max_new = max_new
        self.reward_fn = reward_fn

    def plan(self, request, probe_hidden, runtime) -> Optional[Plan]:
        return Plan([ChildGroup(self.model_id, 1, self.max_new)])

    def finalize(self, request, runtime) -> None:
        _rerank(request, runtime, self.reward_fn)


class Route(DecodeProcedure):
    """Weak/strong routing (paper §4.2), online in the serving runtime.

    The probe prefill runs on the **weak** model (its hidden state is the
    paper's free predictor input). ``predictor(request, hidden)`` returns
    the routing statistic — the learned p(p^S ≻ p^W | x) of Eq. 8, or any
    monotone stand-in — and queries with statistic >= ``threshold`` decode
    on the strong model instead. Calibrate the threshold to a strong-
    fraction target with :meth:`calibrate_threshold` (the price-dual /
    top-B-percentile rule of ``core.allocator.route_by_preference``, made
    batch-free: at threshold = the (1 - f) quantile of the calibration
    scores, a fraction f of matching traffic routes strong).

    Routing strong releases the weak prompt KV immediately and schedules
    a strong-model prefill *phase*; both models share one paged pool, so
    the strong prefill competes for (and is reservation-gated on) the
    same blocks, and repeats of a routed prompt hit the strong model's
    radix prefix cache.

    ``cascade=True`` decodes the weak child first and escalates through
    ``on_child_done``: only if the statistic clears the threshold AND the
    weak answer's reward is <= ``cascade_threshold`` does the strong
    model run — trading latency for strictly fewer strong calls.
    """

    def __init__(self, *, predictor: Callable, threshold: float = 0.0,
                 weak: str = "weak", strong: str = "strong",
                 reward_fn: Optional[Callable] = None,
                 cascade: bool = False, cascade_threshold: float = 0.0,
                 max_new_weak: Optional[int] = None,
                 max_new_strong: Optional[int] = None):
        self.predictor = predictor
        self.threshold = float(threshold)
        self.weak, self.strong = weak, strong
        self.probe_model = weak
        self.reward_fn = reward_fn
        self.cascade = bool(cascade)
        self.cascade_threshold = float(cascade_threshold)
        self.max_new_weak = max_new_weak
        self.max_new_strong = max_new_strong

    @staticmethod
    def calibrate_threshold(scores: Sequence[float],
                            strong_frac: float) -> float:
        """Threshold that routes ~``strong_frac`` of traffic matching the
        calibration distribution to the strong model."""
        s = np.asarray(scores, np.float64)
        if strong_frac <= 0.0:
            return float("inf")
        if strong_frac >= 1.0:
            return float("-inf")
        return float(np.quantile(s, 1.0 - strong_frac))

    def plan(self, request, probe_hidden, runtime) -> Optional[Plan]:
        stat = float(self.predictor(request, probe_hidden))
        request.proc["pref"] = stat
        want_strong = stat >= self.threshold
        if self.cascade:
            request.proc["route"] = "weak"
            request.proc["may_escalate"] = want_strong
            return Plan([ChildGroup(self.weak, 1, self.max_new_weak)])
        request.proc["route"] = "strong" if want_strong else "weak"
        if want_strong:
            return Plan([ChildGroup(self.strong, 1, self.max_new_strong)])
        return Plan([ChildGroup(self.weak, 1, self.max_new_weak)])

    def on_child_done(self, request, child, runtime
                      ) -> Optional[List[ChildGroup]]:
        if (not self.cascade or child.model_id != self.weak
                or request.proc.get("escalated")):
            return None
        fn = self.reward_fn if self.reward_fn is not None \
            else runtime.reward_fn
        if fn is not None:
            row = child.output_tokens(runtime.eos_id)
            request.proc["weak_reward"] = float(
                np.asarray(fn(request.query, [row]), np.float64)[0])
        if request.proc.get("may_escalate") and (
                fn is None
                or request.proc["weak_reward"] <= self.cascade_threshold):
            request.proc["escalated"] = True
            request.proc["route"] = "strong"
            return [ChildGroup(self.strong, 1, self.max_new_strong)]
        return None

    def finalize(self, request, runtime) -> None:
        _rerank(request, runtime, self.reward_fn)
