"""Slot-pooled KV cache.

The pool is a single model cache pytree sized for ``n_slots`` sequences of
up to ``max_len`` positions. Every leaf is layer-stacked —
``(n_repeat, batch, ...)`` — so *axis 1 is the slot axis* for all cache
families (attention KV ``(r, b, S, KV, hd)``, MLA latents, mamba/xlstm
states). Slots are allocated/freed host-side (free list); cache rows move
with two jitted primitives that compile once for the whole runtime:

    copy_row    write row `src_row` of a prefill cache into slot `slot`
                (the adaptive fan-out replicates one probe prefill into
                b_i slots this way — no second prefill)
    read_row    slice one slot back out as a batch-1 cache

Per-slot ``pos`` vectors live in the runtime and are fed straight to the
model's decode step — and, with ``REPRO_DECODE_KERNEL=pallas``, to the
Pallas flash-decoding kernel, whose per-batch `pos` validity masking was
built for exactly this layout (slots at heterogeneous positions).
"""
from __future__ import annotations

import functools
from typing import Any, List

import jax
import jax.numpy as jnp


@functools.partial(jax.jit, donate_argnums=(0,))   # pool is rebound by caller
def _copy_row(dst, src, src_row, slot):
    """dst leaves (r, N, ...); src leaves (r, g, ...): dst[:, slot] = src[:, src_row]."""
    def one(d, s):
        row = jax.lax.dynamic_index_in_dim(s, src_row, axis=1, keepdims=False)
        return jax.lax.dynamic_update_index_in_dim(d, row, slot, axis=1)
    return jax.tree.map(one, dst, src)


@jax.jit
def _read_row(cache, slot):
    return jax.tree.map(
        lambda x: jax.lax.dynamic_index_in_dim(x, slot, axis=1,
                                               keepdims=True), cache)


class SlotKVPool:
    """Fixed pool of decode-slot cache rows with host-side lifetime."""

    def __init__(self, model, n_slots: int, max_len: int):
        self.model = model
        self.n_slots = int(n_slots)
        self.max_len = int(max_len)
        self.cache = model.init_cache(self.n_slots, self.max_len)
        self._free: List[int] = list(range(self.n_slots))
        self.alloc_count = 0            # lifetime allocations (reuse metric)

    # ------------------------------------------------------------ lifetime
    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def occupancy(self) -> float:
        return 1.0 - self.n_free / self.n_slots

    def alloc(self) -> int:
        """Claim the lowest free slot (deterministic placement)."""
        if not self._free:
            raise RuntimeError("KV pool exhausted")
        self._free.sort()
        slot = self._free.pop(0)
        self.alloc_count += 1
        return slot

    def release(self, slot: int) -> None:
        assert 0 <= slot < self.n_slots and slot not in self._free
        self._free.append(slot)

    # ------------------------------------------------------------ cache io
    def write_row(self, src_cache: Any, src_row: int, slot: int) -> None:
        """Copy one prefilled sequence (row of a group prefill) into a slot."""
        self.cache = _copy_row(self.cache, src_cache, src_row, slot)

    def read_row(self, slot: int) -> Any:
        return _read_row(self.cache, slot)
