"""Slot-pooled KV cache.

The pool is a single model cache pytree sized for ``n_slots`` sequences of
up to ``max_len`` positions. Every leaf is layer-stacked —
``(n_repeat, batch, ...)`` — so *axis 1 is the slot axis* for all cache
families (attention KV ``(r, b, S, KV, hd)``, MLA latents, mamba/xlstm
states). Slots are allocated/freed host-side (free list); cache rows move
with two jitted primitives that compile once for the whole runtime:

    copy_row    write row `src_row` of a prefill cache into slot `slot`
                (the adaptive fan-out replicates one probe prefill into
                b_i slots this way — no second prefill)
    read_row    slice one slot back out as a batch-1 cache

Per-slot ``pos`` vectors live in the runtime and are fed straight to the
model's decode step — and, with ``REPRO_DECODE_KERNEL=pallas``, to the
Pallas flash-decoding kernel, whose per-batch `pos` validity masking was
built for exactly this layout (slots at heterogeneous positions).
"""
from __future__ import annotations

import functools
import heapq
from typing import Any, List

import jax


@functools.partial(jax.jit, donate_argnums=(0,))   # pool is rebound by caller
def _copy_row(dst, src, src_row, slot):
    """dst leaves (r, N, ...); src leaves (r, g, ...): dst[:, slot] = src[:, src_row]."""
    def one(d, s):
        row = jax.lax.dynamic_index_in_dim(s, src_row, axis=1, keepdims=False)
        return jax.lax.dynamic_update_index_in_dim(d, row, slot, axis=1)
    return jax.tree.map(one, dst, src)


@jax.jit
def _read_row(cache, slot):   # analysis: allow(donation)  (pure read)
    return jax.tree.map(
        lambda x: jax.lax.dynamic_index_in_dim(x, slot, axis=1,
                                               keepdims=True), cache)


class FreeList:
    """Min-heap free list shared by the slot and paged pools: O(log n)
    pop/push with deterministic lowest-id placement, and an O(1)
    double-release / bad-id guard that raises instead of asserting."""

    def __init__(self, ids, label: str):
        self._heap: List[int] = list(ids)
        heapq.heapify(self._heap)
        self._free = set(self._heap)
        self._valid = frozenset(self._heap)
        self._label = label

    def __len__(self) -> int:
        return len(self._heap)

    def __contains__(self, i: int) -> bool:
        return i in self._free

    def pop(self) -> int:
        if not self._heap:
            raise RuntimeError(f"{self._label} pool exhausted")
        i = heapq.heappop(self._heap)
        self._free.discard(i)
        return i

    def push(self, i: int) -> None:
        if i not in self._valid or i in self._free:
            raise RuntimeError(
                f"double release / bad {self._label} id {i}")
        heapq.heappush(self._heap, i)
        self._free.add(i)


class SlotKVPool:
    """Fixed pool of decode-slot cache rows with host-side lifetime."""

    def __init__(self, model, n_slots: int, max_len: int):
        self.model = model
        self.n_slots = int(n_slots)
        self.max_len = int(max_len)
        self.cache = model.init_cache(self.n_slots, self.max_len)
        # heap free list: the old per-call sort() + pop(0) was
        # O(n log n) per alloc
        self._free = FreeList(range(self.n_slots), "slot")
        self.alloc_count = 0            # lifetime allocations (reuse metric)

    # ------------------------------------------------------------ lifetime
    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def occupancy(self) -> float:
        return 1.0 - self.n_free / self.n_slots

    def alloc(self) -> int:
        """Claim the lowest free slot (deterministic placement)."""
        slot = self._free.pop()
        self.alloc_count += 1
        return slot

    def release(self, slot: int) -> None:
        self._free.push(slot)

    # ------------------------------------------------------------ cache io
    def write_row(self, src_cache: Any, src_row: int, slot: int) -> None:
        """Copy one prefilled sequence (row of a group prefill) into a slot."""
        self.cache = _copy_row(self.cache, src_cache, src_row, slot)

    def read_row(self, slot: int) -> Any:
        return _read_row(self.cache, slot)
