from repro.serving.engine import GenerationResult, ServingEngine, prefill  # noqa: F401
from repro.serving.scheduler import AdaptiveScheduler, ServeBatchResult  # noqa: F401
