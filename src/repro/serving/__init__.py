from repro.serving.engine import GenerationResult, ServingEngine, prefill  # noqa: F401
from repro.serving.kv_pool import SlotKVPool  # noqa: F401
from repro.serving.metrics import ModelMetrics, ServingMetrics  # noqa: F401
from repro.serving.paged_pool import PagedKVPool  # noqa: F401
from repro.serving.plan import ProgramPlan, TickPlan, plan_tick  # noqa: F401
from repro.serving.procedure import (BestOfK, ChildGroup, DecodeProcedure,  # noqa: F401
                                     Plan, Route, Single)
from repro.serving.radix_cache import RadixCache  # noqa: F401
from repro.serving.request import ChildSeq, Request, RequestState  # noqa: F401
from repro.serving.runtime import ContinuousBatchingRuntime  # noqa: F401
from repro.serving.scheduler import AdaptiveScheduler, ServeBatchResult  # noqa: F401
from repro.serving.traffic import (AsyncTokenStreamer, PriorityClassQueues,  # noqa: F401
                                   TrafficConfig, TrafficController)
