"""Continuous-batching decode runtime with in-flight adaptive fan-out.

Replaces the batch-synchronous serve loop (same-length prompts, full-batch
barriers, double prefill) with a fixed pool of decode slots that variable-
length, variable-budget requests stream through:

* **At most one prefill per request — often less.** The probe prefill
  that feeds the difficulty predictor IS the generation prefill. In the
  default **paged** pool the prompt's KV blocks are shared copy-on-write
  across the b_i children AND deduped across requests through a radix
  prefix cache (`serving/radix_cache.py`): a prompt whose full-block
  prefix was already prefilled — by a live or recently retired request —
  adopts those blocks and starts prefill at `pos = matched_len`. In the
  **slot** pool the prefill cache row is replicated per child
  (`SlotKVPool.write_row`). Either way the paper's "free" probe stays
  free at serving time.
* **Statically-shaped programs, compiled once.** Decode runs one jitted
  step per tick over the whole pool; prefill advances every prefilling
  slot by up to `prefill_chunk` prompt tokens per tick through one
  varlen chunk program at static shape (prefill_slots, prefill_chunk)
  (`_paged_chunk_tick`; recurrent-state stacks fall back to the PR-2
  one-token-per-tick interleave inside the decode tick). No
  per-(group, prompt_len) recompiles anywhere. (The slot pool keeps the
  legacy batched prefill.)
* **Memory tracks actual sequence length.** Paged-pool blocks are
  allocated on demand as `pos` crosses block boundaries and freed the
  moment a child retires (or hits EOS), so the adaptive policy's saved
  budget becomes saved memory, not just saved ticks. A worst-case
  reservation ledger makes on-demand growth deadlock-free.
* **Immediate slot reclamation.** A child that finishes frees its slot
  (and blocks) at the end of the tick; queued fan-out backfills it on the
  next tick, so saved budget becomes saved wall-clock.
* **Horizon-fused decode, one host sync per horizon.** When no slot is
  prefilling, the paged pool runs up to `horizon` decode steps inside a
  single jitted `lax.scan` (`_paged_horizon_tick`): sampling, EOS
  detection, and budget exhaustion stay on device (per-slot `remaining`
  counters freeze finished slots mid-horizon), block tables are extended
  for the whole horizon up front (`PagedKVPool.preallocate`) and
  uploaded once, and the host reads back one (H, 2, n_slots)
  token/alive buffer — 1 dispatch + 1 blocking sync where the per-token
  tick paid H of each. Greedy outputs are bitwise identical to the
  per-token tick (same traced step, same fold_in RNG streams);
  recurrent-state stacks and ticks with prefill in flight fall back to
  the per-token program.

* **Procedure-centric, multi-model.** The runtime serves pluggable
  :class:`DecodeProcedure` objects (``serving/procedure.py``): a
  procedure plans which registry model(s) decode a request and how many
  children each fans out, reacts to finished children (escalation /
  cascades), and finalizes the response. ``register_model`` adds models
  (a weak/strong routing pair) sharing ONE paged pool — one block
  ledger, per-model KV stores and radix caches — and each tick groups
  slots per model: one dispatch per model with live work, foreign slots
  masked to the null block (and their RNG keys frozen), so any model mix
  runs the same statically-shaped programs. ``submit(prompt,
  budget=...)`` remains as a thin shim over the default ``BestOfK``
  procedure and is token-bitwise identical to the pre-procedure runtime
  under greedy decode.

Sampling uses per-child RNG streams — ``fold_in(fold_in(seed, request_id),
child_index)`` — so outputs are a function of (seed, request, child) only,
independent of slot placement, pool backend, model mix, and of what else
is in flight. Greedy decoding (temperature 0) is bitwise-reproducible
across paged pool, slot pool, and the batch engine (see
tests/test_runtime.py, tests/test_paged_pool.py).
"""
from __future__ import annotations

import functools
import time
from collections import deque
from typing import Any, Callable, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.model_zoo import Model
from repro.serving.engine import prefill
from repro.serving.kv_pool import SlotKVPool
from repro.serving.metrics import ServingMetrics
from repro.serving.paged_pool import PagedKVPool, cdiv, supports_paging
from repro.serving.procedure import (BestOfK, ChildGroup, DecodeProcedure,
                                     Plan)
from repro.serving.radix_cache import RadixCache
from repro.serving.request import (ChildSeq, PrefillStash, Request,
                                   RequestState, StashGroup)
from repro.serving.traffic.controller import TrafficConfig, TrafficController


# cache/logits/pos/keys are donated: the caller rebinds all four every tick,
# and without donation XLA would copy the whole slot-pool KV cache per token.
@functools.partial(jax.jit, static_argnames=("model", "temperature_zero"),
                   donate_argnums=(2, 3, 4, 5))
def _pool_tick(model: Model, params, cache, logits, pos, keys, active,
               temperature, *, temperature_zero: bool):
    """One slot-pool decode tick over every slot.

    Sample a token from each slot's current next-token logits, advance
    active slots' positions, and run one decode step over the whole pool.
    Inactive slots still flow through the model (their rows are unused and
    row-independent) but their pos/logits are frozen so admission state
    stays intact.
    """
    if temperature_zero:
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        new_keys = keys
    else:
        split = jax.vmap(jax.random.split)(keys)            # (N, 2, 2)
        new_keys = split[:, 0]
        tok = jax.vmap(jax.random.categorical)(
            split[:, 1], logits.astype(jnp.float32) / temperature
        ).astype(jnp.int32)
    new_pos = jnp.where(active, pos + 1, pos)
    new_logits, _, cache = model.decode_step(params, tok[:, None], cache,
                                             new_pos)
    logits = jnp.where(active[:, None], new_logits[:, 0], logits)
    return tok, logits, cache, new_pos, new_keys


@functools.partial(jax.jit, donate_argnums=(0, 1, 2))
def _admit_slot(logits, pos, keys, src_logits, src_row, slot, start_pos,
                child_key):
    """Point a freshly allocated slot at a prefilled sequence: install its
    next-token logits, start position, and RNG stream."""
    lrow = jax.lax.dynamic_index_in_dim(src_logits, src_row, axis=0,
                                        keepdims=False)
    logits = jax.lax.dynamic_update_index_in_dim(logits, lrow, slot, axis=0)
    pos = jax.lax.dynamic_update_index_in_dim(
        pos, jnp.asarray(start_pos, pos.dtype), slot, axis=0)
    keys = jax.lax.dynamic_update_index_in_dim(keys, child_key, slot, axis=0)
    return logits, pos, keys


@functools.partial(jax.jit, static_argnames=("model", "temperature_zero"),
                   donate_argnums=(2, 6))
def _paged_tick(model: Model, params, cache, tables, tokens, pos, keys,
                advance, temperature, *, temperature_zero: bool):
    """One paged-pool tick: decode every slot's current token at its
    position through the block tables, then sample each slot's next token.

    The same program serves chunked prefill and decode: a prefilling slot's
    input token is the next *prompt* token (its sampled output is simply
    not used by the host), a decoding slot's input is its last sampled
    token. Dead slots point at the reserved null block and compute
    harmless garbage — no per-slot control flow, one compile total.

    `advance` flags the slots whose RNG streams this tick owns (this
    model's live decode children). Other slots still sample — their rows
    are unused garbage, vmapped counter-based threefry is element-wise so
    they cannot perturb the advancing rows — but their keys are frozen:
    with several models sharing the pool, another model's tick must never
    burn a live foreign child's stream.
    """
    logits, hidden, cache = model.decode_step(params, tokens[:, None], cache,
                                              pos, block_tables=tables)
    lg = logits[:, 0]
    if temperature_zero:
        sampled = jnp.argmax(lg, axis=-1).astype(jnp.int32)
        new_keys = keys
    else:
        split = jax.vmap(jax.random.split)(keys)            # (N, 2, 2)
        new_keys = jnp.where(advance[:, None], split[:, 0], keys)
        sampled = jax.vmap(jax.random.categorical)(
            split[:, 1], lg.astype(jnp.float32) / temperature
        ).astype(jnp.int32)
    return sampled, lg, hidden[:, 0], cache, new_keys


@functools.partial(jax.jit, static_argnames=("model",), donate_argnums=(2,))
def _paged_chunk_tick(model: Model, params, cache, tables, tokens, pos,
                      valid):
    """One varlen chunked-prefill program: every prefilling slot advances
    by up to C prompt tokens (its own `valid` count) in a single compiled
    step. Shapes are static — (prefill_slots, prefill_chunk) — so mixed
    prompt lengths, partial tail chunks, and idle prefill slots (valid 0,
    null tables) all run the same program; there is exactly one compile
    for the whole runtime, like the decode tick."""
    logits, hidden, cache = model.decode_chunk(params, tokens, cache, pos,
                                               valid, block_tables=tables)
    return logits, hidden, cache


@functools.partial(jax.jit, static_argnames=("temperature_zero",))
def _sample_first(logits, row, key, temperature, *, temperature_zero: bool):
    """Sample a fan-out child's first token from its request's stashed
    probe logits. Performs exactly the split/categorical sequence the
    slot-pool tick would, so per-child RNG streams are identical across
    pool backends. (The paged runtime admits through the vmapped
    `_admit_children`, which is this program batched over children —
    kept as the single-child reference the tests compare against.)"""
    lrow = jax.lax.dynamic_index_in_dim(logits, row, axis=0, keepdims=False)
    if temperature_zero:
        return jnp.argmax(lrow).astype(jnp.int32), key
    split = jax.random.split(key)
    tok = jax.random.categorical(
        split[1], lrow.astype(jnp.float32) / temperature).astype(jnp.int32)
    return tok, split[0]


@functools.partial(jax.jit, static_argnames=("temperature_zero",),
                   donate_argnums=(5,))
def _admit_children(lrows, base_key, rids, idxs, slots, keys, temperature,
                    *, temperature_zero: bool):
    """Batched fan-out admission: derive every child's RNG stream
    (fold_in(fold_in(seed, request), child)), sample each first token
    from its request's stashed probe logits, and install the advanced
    keys into the pool rows — all children spawned this tick in ONE
    program, where the per-child path paid one jit dispatch for the
    fold_ins, one for the sample, and one `keys.at[slot].set` device op
    per child. The caller pads every argument to the pool width with
    out-of-range slot indices (scatter drops them), so exactly one
    program compiles regardless of how many children a tick admits.
    vmap of fold_in/split/categorical is element-wise (counter-based
    threefry), so per-child streams are bitwise the per-child
    program's."""
    lg = jnp.stack(lrows)                                   # (m, V)
    ck = jax.vmap(lambda r, j: jax.random.fold_in(
        jax.random.fold_in(base_key, r), j))(rids, idxs)    # (m, 2)
    if temperature_zero:
        toks = jnp.argmax(lg, axis=-1).astype(jnp.int32)
        nk = ck
    else:
        split = jax.vmap(jax.random.split)(ck)              # (m, 2, 2)
        nk = split[:, 0]
        toks = jax.vmap(jax.random.categorical)(
            split[:, 1], lg.astype(jnp.float32) / temperature
        ).astype(jnp.int32)
    keys = keys.at[slots].set(nk)
    return toks, keys


@functools.partial(jax.jit,
                   static_argnames=("model", "H", "temperature_zero",
                                    "eos_id"),
                   donate_argnums=(2, 6))
def _paged_horizon_tick(model: Model, params, cache, tables, tok, pos, keys,
                        remaining, temperature, *, H: int,
                        temperature_zero: bool, eos_id: Optional[int]):
    """H decode steps fused into one compiled `lax.scan` program — the
    horizon tick. Per scan step this is exactly `_paged_tick`'s
    decode-then-sample sequence (greedy tokens are bitwise identical),
    but sampling, EOS detection, and budget exhaustion all stay on
    device: each slot carries a `remaining` counter, and a slot whose
    counter hits zero (EOS sampled, or max_new reached) is frozen mid-
    horizon — its token/pos stop advancing and its masked steps write
    garbage K/V at its frozen position, which lands in the finished
    child's private block and is never read. The host gets one
    (H, 2, n_slots) [token; alive] buffer per horizon — a single
    device->host sync where the per-token loop paid H.

    Block tables are scan-invariant: the caller pre-extends every live
    slot's table to cover the whole horizon (`PagedKVPool.preallocate`),
    so tables upload once per horizon. Unwritten preallocated blocks sit
    above each slot's current position and are masked by the `idx <= pos`
    validity rule, contributing exact zeros — values are unchanged.

    Slots outside this model's group (remaining = 0 at entry — dead, or
    live under ANOTHER registry model) never advance their keys: a
    member slot's stream evolves exactly as the per-token tick's, a
    foreign live child's stream is untouched by this model's horizon."""
    member = remaining > 0                  # this model's live slots

    def transition(lg, tok, pos, aux):
        keys, remaining = aux
        if temperature_zero:
            sampled = jnp.argmax(lg, axis=-1).astype(jnp.int32)
            new_keys = keys
        else:
            split = jax.vmap(jax.random.split)(keys)        # (N, 2, 2)
            new_keys = jnp.where(member[:, None], split[:, 0], keys)
            sampled = jax.vmap(jax.random.categorical)(
                split[:, 1], lg.astype(jnp.float32) / temperature
            ).astype(jnp.int32)
        alive = remaining > 0
        new_rem = jnp.maximum(remaining - 1, 0)
        if eos_id is not None:
            new_rem = jnp.where(sampled == eos_id, 0, new_rem)
        tok = jnp.where(alive, sampled, tok)
        pos = jnp.where(alive, pos + 1, pos)
        emit = jnp.stack([sampled, alive.astype(jnp.int32)])  # (2, N)
        return tok, pos, (new_keys, new_rem), emit

    tok, pos, cache, (keys, remaining), emits = model.decode_horizon(
        params, tok, cache, pos, (keys, remaining), H, transition,
        block_tables=tables)
    return emits, cache, keys


class ContinuousBatchingRuntime:
    """Pooled decode runtime; see module docstring.

    pool="paged" (default) stores KV in block-granular pages with COW
    prompt sharing, a cross-request radix prefix cache
    (prefix_cache=True; stateless stacks only), varlen multi-token
    chunked prefill (prefill_chunk, default block_size; recurrent-state
    stacks use the per-token interleave), and horizon-fused decode
    (horizon, default 8: that many decode steps per compiled dispatch
    and per host sync, H=min(horizon, min remaining) per dispatch);
    pool="slots" keeps the PR-1 full-row slot pool (used by the
    bitwise-equivalence tests and as the fallback for sliding-window
    configs whose cache would wrap). admission_lookahead bounds the
    radix-aware admission scan that pulls the longest prefix-cache hit
    to the front of the prefill queue.

    budget_fn(request, hidden) -> int resolves budgets at admission
    (streaming mode, e.g. ``AdaptivePolicy.allocate_streaming`` at a
    calibrated price); in paged mode the result is additionally gated on
    free *blocks* (not free slots), so difficulty-driven fan-out cannot
    over-commit memory. Leave it None and call :meth:`set_budget` for
    batch-exact allocation (the AdaptiveScheduler facade does this).
    reward_fn(query, rows) -> scores reranks a request's children when the
    last one finishes; None keeps child 0. eos_id terminates a child
    early when sampled, immediately freeing its slot/blocks and excluding
    post-EOS tokens from the reranker input.
    """

    def __init__(self, model: Model, params, *, n_slots: int = 8,
                 max_len: int = 64, max_new: int = 16,
                 temperature: float = 0.0, seed: int = 0,
                 reward_fn: Optional[Callable] = None,
                 budget_fn: Optional[Callable] = None,
                 prefill_window: Optional[int] = None,
                 pool: str = "paged", block_size: int = 16,
                 n_blocks: Optional[int] = None,
                 prefill_slots: Optional[int] = None,
                 eos_id: Optional[int] = None,
                 prefix_cache: bool = True,
                 prefill_chunk: Optional[int] = None,
                 horizon: int = 8,
                 admission_lookahead: int = 4,
                 traffic: Optional[TrafficConfig] = None):
        assert pool in ("paged", "slots")
        if pool == "paged" and not supports_paging(model, max_len):
            pool = "slots"          # sliding-window wrap: paged is inexact
        self.pool_kind = pool
        self.model, self.params = model, params
        # model registry: the constructor model is "default"; routing
        # pairs etc. join via register_model (paged pool only)
        self.models: Dict[str, Model] = {"default": model}
        self.model_params: Dict[str, Any] = {"default": params}
        self.default_procedure: DecodeProcedure = BestOfK()
        self.max_new = int(max_new)
        self.temperature = float(temperature)
        self.reward_fn, self.budget_fn = reward_fn, budget_fn
        self.eos_id = None if eos_id is None else int(eos_id)
        # admission control: at most this many *stash groups* (device-
        # resident prefill caches / prompt-block tables) may be live at
        # once, bounding memory under a deep backlog. Requests parked on
        # an un-called set_budget() are excluded — they are the caller's
        # memory, and counting them starved new arrivals (spurious
        # drain() stalls).
        if prefill_window is None:
            prefill_window = 2 * n_slots
        assert prefill_window >= 1
        self.prefill_window = prefill_window
        self._groups: set = set()           # live StashGroups
        self.metrics = ServingMetrics(n_slots=n_slots)
        self._base_key = jax.random.PRNGKey(seed)
        self.n_slots = int(n_slots)
        V = model.lm.vocab_padded
        self.keys = jnp.zeros((n_slots, 2), jnp.uint32)
        self.slots: List[Optional[ChildSeq]] = [None] * n_slots
        # traffic subsystem: priority scheduling + preemption + SLO-aware
        # degradation (serving/traffic/). The scheduler replaces the FIFO
        # deque behind the same peek/pop protocol, so every admission path
        # below is policy-agnostic.
        self.traffic: Optional[TrafficController] = None
        if traffic is not None:
            if pool != "paged":
                raise ValueError(
                    "the traffic subsystem needs the paged pool "
                    "(preemption is a block-ledger operation)")
            self.traffic = TrafficController(traffic)
        self.queue = (deque() if self.traffic is None
                      else self.traffic.make_queue())  # awaiting prefill
        self.fanout: deque = deque()      # Requests with un-slotted children
        self.requests: Dict[int, Request] = {}
        self._next_id = 0
        self._prefix_cache = False
        self._radices: Dict[str, RadixCache] = {}
        if pool == "paged":
            if n_blocks is None:
                # in-flight children worst case + one stashed-window's
                # worth of prompts + the null block
                n_blocks = ((n_slots + prefill_window)
                            * cdiv(max_len, block_size) + 1)
            self.pool = PagedKVPool(model, n_slots, max_len,
                                    block_size=block_size, n_blocks=n_blocks)
            # chunked prefill may use the whole pool: fan-out admission
            # runs first each tick, so decode children always reclaim
            # freed slots before new prompts do; lower this to bound
            # prompt tokens per tick (prefill work) explicitly
            if prefill_slots is None:
                prefill_slots = n_slots
            self.prefill_slots = int(prefill_slots)
            self._pref: Dict[int, Request] = {}   # slot -> prefilling req
            self._tok = np.zeros(n_slots, np.int32)   # next input token
            self._pos = np.zeros(n_slots, np.int32)   # its decode position
            self._fanout_blocked = False
            self._prefill_blocked = False   # admission starved (traffic)
            # multi-token chunked prefill: up to `prefill_chunk` prompt
            # tokens per prefilling slot per tick under one compiled
            # varlen program. Recurrent-state stacks advance state one
            # token per step, so they stay on the per-token interleave
            # (chunk 1 == the PR-2 path, also selectable explicitly).
            if not self.model.supports_chunked_prefill:
                prefill_chunk = 1
            elif prefill_chunk is None:
                prefill_chunk = block_size
            self.prefill_chunk = max(1, int(prefill_chunk))
            # radix prefix cache: cross-request dedup of full prompt
            # blocks, one tree per registry model (a prefix's KV is
            # model-specific) on the shared block ledger. Sound only when
            # skipping prefix tokens skips no recurrent-state updates —
            # i.e. stateless stacks.
            self._prefix_cache = (bool(prefix_cache)
                                  and not self.pool._has_state)
            if self._prefix_cache:
                self._radices["default"] = RadixCache(self.pool)
            # horizon-fused decode: up to `horizon` decode steps per
            # compiled dispatch (one host sync per horizon instead of
            # one per token). Engages only when no slot is prefilling
            # (the per-token interleave owns prefill for chunk-1 stacks)
            # and the stack is stateless; recurrent-state pools stay on
            # the per-token tick. horizon=1 disables fusion entirely.
            self.horizon = max(1, int(horizon))
            if self.pool._has_state:
                self.horizon = 1
            # radix-aware admission ordering: scan this many queued
            # requests and admit the longest published-prefix hit first
            # (1 = strict FIFO). Bounded, so a miss is bypassed at most
            # while hits keep landing inside the lookahead window.
            self.admission_lookahead = max(1, int(admission_lookahead))
        else:
            self.pool = SlotKVPool(model, n_slots, max_len)
            self.logits = jnp.zeros((n_slots, V), model.lm.dtype)
            self.pos = jnp.zeros((n_slots,), jnp.int32)

    # ----------------------------------------------------- model registry
    def register_model(self, model_id: str, model: Model, params) -> None:
        """Add a model to the registry (paged pool only): it gets its own
        KV store and radix prefix cache on the SHARED block ledger, and
        each tick dispatches one program per model with live work.
        Procedures address it by ``model_id`` in their plans."""
        if self.pool_kind != "paged":
            raise ValueError("multi-model serving needs the paged pool")
        if model_id in self.models:
            raise ValueError(f"model id {model_id!r} already registered")
        if not model.supports_chunked_prefill:
            raise ValueError(
                f"model {model_id!r}: multi-model serving requires a "
                "stateless (attention/MLA) stack")
        self.pool.add_model(model_id, model)     # checks statelessness
        self.models[model_id] = model
        self.model_params[model_id] = params
        if self._prefix_cache:
            self._radices[model_id] = RadixCache(self.pool)

    @property
    def radix(self) -> Optional[RadixCache]:
        """Default model's prefix cache (back-compat view; multi-model
        callers use the per-model trees internally)."""
        return self._radices.get("default") if self.pool_kind == "paged" \
            else None

    def _radix_of(self, model_id: str) -> Optional[RadixCache]:
        return self._radices.get(model_id)

    @property
    def _radix_held(self) -> int:
        return sum(rx.held_blocks for rx in self._radices.values())

    # ------------------------------------------------------------- submit
    def submit(self, prompt: np.ndarray, *, budget: Optional[int] = None,
               query: Any = None, max_new: Optional[int] = None,
               procedure: Optional[DecodeProcedure] = None,
               tenant: str = "default", priority: int = 1,
               slo: Optional[float] = None) -> int:
        """Enqueue one request. ``procedure`` drives its lifecycle (see
        serving/procedure.py); omitted, the runtime's default ``BestOfK``
        reproduces the historical budget/fan-out semantics exactly —
        ``budget=``/``budget_fn``/``set_budget`` all still work.
        ``tenant``/``priority``/``slo`` feed the traffic subsystem
        (serving/traffic/): without ``traffic=`` they are recorded but
        scheduling stays strict FIFO."""
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        mn = self.max_new if max_new is None else int(max_new)
        if len(prompt) + mn > self.pool.max_len:
            raise ValueError(
                f"prompt_len {len(prompt)} + max_new {mn} exceeds pool "
                f"max_len {self.pool.max_len}")
        proc = self.default_procedure if procedure is None else procedure
        probe = proc.probe_model
        if probe not in self.models:
            raise KeyError(f"procedure probes unregistered model "
                           f"{probe!r}; register_model it first")
        if self.pool_kind != "paged" and not isinstance(proc, BestOfK):
            raise ValueError("the slot pool serves only the BestOfK "
                             "procedure; use pool='paged'")
        if self.pool_kind == "paged":
            # one child's worst case while the request's prompt table is
            # still held: the prompt's blocks plus the child's privately
            # owned tail (incl. its COW boundary copy)
            sp = len(prompt)
            owned = (self.pool.blocks_for(sp + mn)
                     - sp // self.pool.block_size)
            worst = self.pool.blocks_for(sp) + owned
            if worst > self.pool.n_blocks - 1:
                raise ValueError(
                    f"request needs up to {worst} blocks but the pool has "
                    f"{self.pool.n_blocks - 1} usable")
        if slo is None and self.traffic is not None:
            slo = self.traffic.cfg.default_slo
        r = Request(id=self._next_id, prompt=prompt, query=query,
                    budget=None if budget is None else int(budget),
                    max_new=mn, procedure=proc, model_id=probe,
                    tenant=str(tenant), priority=int(priority),
                    slo=None if slo is None else float(slo))
        self._next_id += 1
        self.requests[r.id] = r
        self.queue.append(r)
        return r.id

    def submit_batch(self, prompts: np.ndarray,
                     budgets: Optional[Sequence[int]] = None,
                     queries: Optional[Sequence] = None,
                     max_new: Optional[Sequence[int]] = None) -> List[int]:
        """Batch submit. `max_new` is per-request, like `budgets` — it
        used to be silently dropped (every request fell back to the
        runtime default even though `submit` accepts it)."""
        n = len(prompts)
        return [self.submit(prompts[i],
                            budget=None if budgets is None else budgets[i],
                            query=None if queries is None else queries[i],
                            max_new=None if max_new is None
                            else int(max_new[i]))
                for i in range(n)]

    # --------------------------------------------------- stash accounting
    def _window_used(self) -> int:
        """Device cache rows pinned by live stash groups. A group's cache
        has batch dim = its original size and is only freeable when the
        *last* member drops its stash, so every row stays counted until
        the group dies — the old per-request count released window
        capacity as members dropped while the cache was still fully
        alive, under-throttling memory on large same-length groups.
        Groups whose every live member awaits set_budget() are excluded
        (they starved arrivals -> spurious drain() stalls; their memory
        belongs to the caller)."""
        return sum(g.rows for g in self._groups if g.nondeferred > 0)

    def _make_stash(self, r: Request, group: StashGroup, **kw) -> None:
        # stashes start non-deferred; a plan() returning None (BestOfK
        # awaiting set_budget) flips the flag in _run_plan
        r.stash = PrefillStash(group=group, deferred=False, **kw)
        group.size += 1
        group.rows += 1             # pinned until the whole group dies
        group.nondeferred += 1
        self._groups.add(group)

    def _defer_stash(self, r: Request) -> None:
        st = r.stash
        if st is not None and not st.deferred:
            st.deferred = True
            st.group.nondeferred -= 1

    def _drop_stash(self, r: Request) -> None:
        st = r.stash
        if st is None:
            return
        r.stash = None
        g = st.group
        g.size -= 1
        if not st.deferred:
            g.nondeferred -= 1
        if g.size == 0:
            self._groups.discard(g)

    # ------------------------------------------------------------ prefill
    def prefill_queued(self, limit: Optional[int] = None) -> int:
        """Prefill up to `limit` queued requests (all of them when None)
        and return how many. Slot pool: batch same-length prompts into
        one jitted pass (the probe prefill — note it compiles per
        distinct (group, prompt_len) shape; each row it stashes counts
        against the prefill window until its group dies). Paged pool:
        drive the chunked prefill to completion for those requests by
        running ticks (the varlen chunk program, or the decode-tick
        interleave for recurrent-state stacks). Resolves budgets via
        budget_fn when present."""
        if self.pool_kind == "paged":
            n = len(self.queue) if limit is None else min(int(limit),
                                                          len(self.queue))
            targets = [r.id for r in list(self.queue)[:n]]
            while any(self.requests[i].hidden is None for i in targets):
                if not self.step():
                    raise RuntimeError(self._stall_report("prefill_queued"))
            return n
        by_len: Dict[int, List[Request]] = {}
        taken = 0
        while self.queue and (limit is None or taken < limit):
            r = self.queue.popleft()
            if r.admit_t is None:
                r.admit_t = time.perf_counter()
                self.metrics.record_queue_wait(r.admit_t - r.submit_t)
            by_len.setdefault(r.prompt_len, []).append(r)
            taken += 1
        for sp, reqs in by_len.items():
            P = jnp.asarray(np.stack([r.prompt for r in reqs]))
            logits, hidden, cache = prefill(self.model, self.params, P,
                                            self.pool.max_len)
            self.metrics.record_prefill(len(reqs) * sp)
            hidden_np = np.asarray(hidden, np.float32)
            group = StashGroup()        # one shared device cache
            for i, r in enumerate(reqs):
                r.hidden = hidden_np[i]
                self._make_stash(r, group, cache=cache, logits=logits,
                                 row=i, start_pos=sp - 1)
                r.state = RequestState.PREFILL
                self._run_plan(r)
        return taken

    def set_budget(self, request_id: int, budget: int) -> None:
        """Resolve a deferred budget (batch-exact allocation path): the
        parked request's procedure re-plans with the budget now known."""
        r = self.requests[request_id]
        assert r.state == RequestState.PREFILL and r.stash is not None
        if r.stash.deferred:
            r.stash.deferred = False
            r.stash.group.nondeferred += 1
        r.budget = int(budget)
        self._run_plan(r)

    # ----------------------------------------------------- procedure plan
    def _run_plan(self, r: Request) -> None:
        """Ask the request's procedure for its plan (probe prefill has
        landed). None parks the request — the stash is marked deferred
        and excluded from the prefill window until set_budget re-plans."""
        plan = r.procedure.plan(r, r.hidden, self)
        if plan is None:
            self._defer_stash(r)
            return
        r.planned = True
        self._apply_groups(r, list(plan.groups))

    def _apply_groups(self, r: Request, groups: List[ChildGroup]) -> None:
        """Turn procedure child-groups into work. Groups on the model
        whose prefill stash is live spawn immediately (they share the
        probe prefill, exactly the old fan-out); groups on other models —
        or arriving after the stash was dropped — queue a prefill *phase*
        on their model. An empty plan with no children is the paper's
        b_i = 0: release everything and answer with the default."""
        was_pending = bool(r.pending)   # already in the fanout deque
        spawned = 0
        for g in groups:
            if r.stash is not None and g.model_id == r.model_id:
                spawned += self._spawn_group(r, g)
            else:
                if g.model_id not in self.models:
                    raise KeyError(f"plan names unregistered model "
                                   f"{g.model_id!r}")
                r.pending_phases.append(g)
        if spawned:
            r.state = RequestState.DECODE
            # invariant: a request appears in self.fanout exactly once,
            # iff it has pending children — an on_child_done escalation
            # landing while earlier children still await admission must
            # not enqueue a duplicate (the stale entry would outlive the
            # first pop and crash the admission loop on empty pending)
            if not was_pending:
                self.fanout.append(r)
        elif r.stash is not None and not r.pending:
            # nothing rides the current stash: drop it (and the standing
            # child reservation sized for a child that will never spawn).
            # `not r.pending` guards the preemption-resume path — there
            # the fresh stash/table/reservation belong to the evicted
            # children about to re-admit, even when no NEW group spawned
            if self.pool_kind == "paged":
                self._release_prompt_table(r)
                self.pool.unreserve(r.reserved)
                r.reserved = 0
            self._drop_stash(r)
        if (not r.children and not r.pending_phases
                and not r.pending):
            self._finalize(r)               # empty plan: default response
            return
        self._maybe_start_next_phase(r)

    def _spawn_group(self, r: Request, g: ChildGroup) -> int:
        """Create g.n children on g.model_id sharing the live stash."""
        mn = r.max_new if g.max_new is None else int(g.max_new)
        if mn > r.max_new:
            raise ValueError(
                f"group max_new {mn} exceeds the request's {r.max_new}: "
                "admission reservations are sized to the request")
        for _ in range(int(g.n)):
            c = ChildSeq(request_id=r.id, index=len(r.children),
                         model_id=g.model_id, max_new=mn)
            r.children.append(c)
            r.pending.append(c)
        return int(g.n)

    def _maybe_start_next_phase(self, r: Request) -> None:
        """Queue the next pending phase's prefill once the current
        stash/table are gone and no children await admission (phases are
        sequential per request; distinct requests' phases interleave
        freely)."""
        if (not r.pending_phases or r.pending or r.stash is not None
                or r.state in (RequestState.QUEUED,
                               RequestState.PREFILLING)):
            return
        r.model_id = r.pending_phases[0].model_id
        r.state = RequestState.QUEUED
        r.prefill_pos = 0
        r.prefix_len = 0
        self.queue.append(r)

    def _on_prefill_complete(self, r: Request) -> None:
        """Prefill landed (probe or phase): plan once, then spawn every
        queued group this phase's model satisfies."""
        r.state = RequestState.PREFILL
        if not r.planned:
            self._run_plan(r)
            return
        if r.pending:
            # preemption resume: the evicted children are back in
            # ``pending`` and this fresh prefill is their prompt — re-enter
            # the fan-out backlog (the append is safe: preemption removed
            # the request from ``fanout``, and a request is never preempted
            # twice without an intervening resume)
            r.state = RequestState.DECODE
            self.fanout.append(r)
        groups: List[ChildGroup] = []
        while (r.pending_phases
               and r.pending_phases[0].model_id == r.model_id):
            groups.append(r.pending_phases.pop(0))
        self._apply_groups(r, groups)

    def _gate_budget(self, r: Request, budget: int) -> int:
        """Paged streaming admission is gated on free *blocks*: cap the
        resolved budget at what unreserved memory can eventually carry.
        The request's standing one-child reservation (made at prefill
        admission) already pays for its first child, so that child is
        granted on top of the open-market capacity; the floor of 1 covers
        the degenerate no-reservation path."""
        if self.pool_kind != "paged" or budget <= 0:
            return budget
        if self.traffic is not None:
            # SLO-aware degradation: under load, shave the ask to what
            # clears the load price *before* gating on free memory —
            # degrade deliberately (priority-weighted) rather than letting
            # the memory gate clip everyone equally
            budget = self.traffic.degrade_budget(self, r, budget)
        per_child = self._child_owned_blocks(r)
        guaranteed = 1 if r.reserved else 0
        # radix-held blocks are a cache, not a commitment: fan-out
        # admission evicts them on demand, so they count as capacity
        # here. held_blocks is an O(1) upper bound on what eviction can
        # free; over-granting is safe — the standing one-child
        # reservation guarantees progress and surplus children just wait
        # in the fan-out backlog
        cap = guaranteed + ((self.pool.available_blocks + self._radix_held)
                            // max(1, per_child))
        return max(1, min(budget, cap))

    def _child_owned_blocks(self, r: Request,
                            max_new: Optional[int] = None) -> int:
        """Blocks a fan-out child may come to own privately: its COW copy
        of the partial boundary block plus its decode tail. Full prompt
        blocks are shared and stay the request's."""
        B = self.pool.block_size
        mn = r.max_new if max_new is None else int(max_new)
        full = r.prompt_len // B
        return self.pool.blocks_for(r.prompt_len + mn) - full

    def _can_reserve_or_evict(self, k: int) -> bool:
        """Admission headroom check that spends the radix caches first:
        retired prompts' published blocks are a cache, not a commitment,
        so when a reservation cannot be met the LRU evictable leaves are
        freed — from every model's tree — before giving up."""
        if self.pool.can_reserve(k):
            return True
        for rx in self._radices.values():
            need = k - self.pool.available_blocks
            if need <= 0:
                break
            freed = rx.evict(need)
            if freed:
                self.metrics.record_radix(evicted=freed)
        return self.pool.can_reserve(k)

    def _release_prompt_table(self, r: Request) -> None:
        if r.table is not None:
            self.pool.release_table(r.table)
            r.table = None

    # ------------------------------------------------------------- fanout
    def _try_fanout(self) -> int:
        """Admit pending children into free slots (FIFO over requests).
        Slot pool: each admission replicates the request's probe-prefill
        cache row into the slot — the fan-out shares one prefill."""
        admitted = 0
        while self.pool.n_free and self.fanout:
            r = self.fanout[0]
            c = r.pending.pop(0)
            slot = self.pool.alloc()
            st = r.stash
            self.pool.write_row(st.cache, st.row, slot)
            ck = jax.random.fold_in(
                jax.random.fold_in(self._base_key, r.id), c.index)
            self.logits, self.pos, self.keys = _admit_slot(
                self.logits, self.pos, self.keys, st.logits, st.row, slot,
                st.start_pos, ck)
            c.slot = slot
            self.slots[slot] = c
            admitted += 1
            if not r.pending:
                self.fanout.popleft()
                self._drop_stash(r)     # pool rows now hold the only copies
        return admitted

    def _try_fanout_paged(self) -> int:
        """Admit pending children: share the request's full prompt blocks
        copy-on-write (incref), privately copy only the partial boundary
        block, reserve the child's worst-case decode tail, and sample
        first tokens from the stashed probe logits.

        All children spawned in the same tick are admitted through ONE
        vmapped program (`_admit_children`): host bookkeeping (slots,
        tables, reservations) is collected first, then a single dispatch
        derives every child's RNG stream, samples every first token, and
        scatters the advanced keys — the per-child path paid ~3 device
        ops per child. The outer loop re-runs collection when an
        admission-time retirement (EOS / max_new=1) frees slots that more
        pending children can take within the same tick."""
        admitted = 0
        self._fanout_blocked = False
        tz = self.temperature == 0.0
        B = self.pool.block_size
        while True:
            batch: List = []        # (request, child) admitted this round
            copies: Dict[str, int] = {}
            while self.fanout and self.pool.n_free_slots:
                r = self.fanout[0]
                c0 = r.pending[0]
                owned = self._child_owned_blocks(r, c0.max_new)
                if r.reserved:
                    # first child: consume the standing reservation made
                    # at prefill admission (guaranteed progress; sized to
                    # the request's max_new, so a group-capped child may
                    # need less — the surplus is returned)
                    assert r.reserved >= owned
                elif not self._can_reserve_or_evict(owned):
                    self._fanout_blocked = True   # hold new prefills back
                    break
                c = r.pending.pop(0)
                slot = self.pool.alloc_slot()
                if r.reserved:
                    self.pool.unreserve(r.reserved - owned)
                    r.reserved = 0                # transfer to the child
                else:
                    self.pool.reserve(owned)
                c.reserved = owned
                full = r.prompt_len // B
                table = []
                for t in range(full):           # shared, read-only forever
                    self.pool.incref(r.table[t])
                    table.append(r.table[t])
                if r.prompt_len % B:            # COW the boundary block
                    blk = self.pool.alloc_block()
                    c.reserved -= 1
                    self.pool.copy_block(r.table[full], blk,
                                         model_id=c.model_id)
                    copies[c.model_id] = copies.get(c.model_id, 0) + 1
                    table.append(blk)
                c.table = table
                self.pool.restore_slot_state(r.stash.state, slot,
                                             model_id=c.model_id)
                c.slot = slot
                self.slots[slot] = c
                self._pos[slot] = r.prompt_len  # first decode position
                batch.append((r, c, r.stash.logits))
                if not r.pending:
                    self.fanout.popleft()
                    self._release_prompt_table(r)  # children hold refs
                    self._drop_stash(r)
                    self._maybe_start_next_phase(r)
            if not batch:
                break
            # one admission program per model present (probe-logit rows
            # have per-model vocab widths); the common case is one
            N = self.n_slots
            by_model: Dict[str, List] = {}
            for entry in batch:
                by_model.setdefault(entry[1].model_id, []).append(entry)
            for mid in sorted(by_model):
                sub = by_model[mid]
                m = len(sub)
                # pad to the pool width so every admission batch size
                # runs the SAME compiled program; padded rows sample
                # garbage that the host drops, and their out-of-range
                # slot index makes the keys scatter a documented no-op
                # (jax drops OOB scatter updates by default)
                pad = N - m
                toks, self.keys = _admit_children(
                    tuple(st for _, _, st in sub) + (sub[0][2],) * pad,
                    self._base_key,
                    jnp.asarray([r.id for r, _, _ in sub] + [0] * pad,
                                jnp.int32),
                    jnp.asarray([c.index for _, c, _ in sub] + [0] * pad,
                                jnp.int32),
                    jnp.asarray([c.slot for _, c, _ in sub] + [N] * pad,
                                jnp.int32),
                    self.keys, self.temperature, temperature_zero=tz)
                self.metrics.record_dispatch(1 + copies.get(mid, 0),
                                             model=mid)
                toks_np = np.asarray(toks)      # one sync per model batch
                self.metrics.record_sync(model=mid)
                self.metrics.record_first_token(m, model=mid)
                for (r, c, _), tok_i in zip(sub, toks_np):
                    tok_i = int(tok_i)
                    c.tokens.append(tok_i)
                    if r.first_token_t is None:
                        r.first_token_t = time.perf_counter()
                        self.metrics.record_ttft(r.first_token_t
                                                 - r.submit_t)
                    if self.eos_id is not None and tok_i == self.eos_id:
                        c.eos = True
                        self.metrics.record_eos(c.max_new - len(c.tokens))
                    self._tok[c.slot] = tok_i
                    if c.done():            # EOS/max_new=1 at admission
                        self._retire_paged_child(c, r)
                admitted += m
        return admitted

    def _admit_prefill_paged(self) -> int:
        """Move queued requests into chunked prefill: claim a slot, the
        prompt's worst-case block reservation PLUS one child's worst case
        (guaranteed progress: anything admitted to prefill can eventually
        decode at least one child — its first fan-out child draws this
        standing reservation instead of competing for fresh memory).
        While the fan-out backlog is blocked on memory, no new prompts
        are admitted (their blocks belong to the backlog head).

        With the radix prefix cache, the prompt is first matched against
        published full blocks: matched blocks are adopted (increfed)
        straight into the request's table, its reservation shrinks by the
        match, and prefill starts at ``pos = matched_len`` — the hit path
        never recomputes the shared prefix. The final prompt token is
        always recomputed (the probe needs its logits/hidden), so a
        fully-matched prompt drops its last matched block."""
        admitted = 0
        B = self.pool.block_size
        self._prefill_blocked = False
        while (self.queue and not self._fanout_blocked
               and len(self._pref) < self.prefill_slots
               and self.pool.n_free_slots > 0
               and self._window_used() < self.prefill_window):
            self._reorder_queue_by_prefix()
            r = self.queue[0]
            radix = self._radix_of(r.model_id)
            sp = r.prompt_len
            matched: List[int] = []
            if radix is not None:
                matched = radix.match(r.prompt)
                while len(matched) * B > sp - 1:
                    radix.unmatch([matched.pop()])
            m = len(matched)
            need = self.pool.blocks_for(sp) - m
            # plan-deferrable requests (BestOfK with no budget and no
            # budget_fn — parked until set_budget) take no child
            # reservation: they will not decode promptly, and pinning a
            # tail per deferred request would let a deep batch-exact
            # backlog reserve the whole pool (the facade sizes one
            # block-row per request, not two). Procedures that always
            # plan immediately (Single, Route) MUST keep the standing
            # reservation — the procedure, not the budget fields, knows
            # whether it can park. Phase prefills (already planned)
            # reserve for their group's first child.
            if not r.planned and r.procedure.may_defer(r, self):
                child_need = 0
            elif r.pending:
                # preemption resume: the first re-admitted child is
                # pending[0], so the standing reservation is sized to it
                # (not to a future phase's group)
                child_need = self._child_owned_blocks(
                    r, r.pending[0].max_new)
            elif r.planned and r.pending_phases:
                child_need = self._child_owned_blocks(
                    r, r.pending_phases[0].max_new)
            else:
                child_need = self._child_owned_blocks(r)
            if not self._can_reserve_or_evict(need + child_need):
                if matched:
                    radix.unmatch(matched)
                self._prefill_blocked = True    # preemption-addressable
                break
            self.queue.popleft()
            if r.admit_t is None:
                r.admit_t = time.perf_counter()
                self.metrics.record_queue_wait(r.admit_t - r.submit_t)
            self.pool.reserve(need + child_need)
            r.reserved = child_need
            slot = self.pool.alloc_slot()
            self.pool.reset_slot_state(slot)    # purge previous occupant
            # matched blocks head the table; growth allocates the rest as
            # prefill crosses block boundaries (reservation-backed)
            r.table = matched
            r.prefix_len = m * B
            if m:
                self.metrics.record_prefix_hit(m * B)
            r.state = RequestState.PREFILLING
            r.prefill_pos = m * B
            self._pref[slot] = r
            self._tok[slot] = int(r.prompt[m * B])
            self._pos[slot] = m * B
            admitted += 1
        if (self.queue and not self._fanout_blocked
                and len(self._pref) < self.prefill_slots
                and self._window_used() < self.prefill_window
                and self.pool.n_free_slots == 0):
            # queue starved on *slots* (not the prefill-slot cap or the
            # stash window): evicting a resident would unblock it
            self._prefill_blocked = True
        return admitted

    def _reorder_queue_by_prefix(self) -> None:
        """Radix-aware admission ordering: peek at the first
        `admission_lookahead` queued requests and pull the longest
        published-prefix hit to the front. A hit's prefill both starts
        later-arriving work sooner (skipped tokens) and keeps its shared
        blocks hot, so admitting it before a cold miss strictly reduces
        total prefill compute without starving the miss: the lookahead is
        bounded, FIFO order breaks ties (including the all-miss case, a
        no-op), and `match_len` is a pure peek — no refcounts taken, no
        LRU clocks touched, so the scan itself cannot perturb eviction."""
        L = self.admission_lookahead
        if not self._radices or L <= 1 or len(self.queue) <= 1:
            return
        B = self.pool.block_size

        def eff_hit(r: Request) -> int:
            # mirror admission's trim: the final prompt token is always
            # recomputed, so a full match drops back below sp - 1
            radix = self._radix_of(r.model_id)
            if radix is None:
                return 0
            m = radix.match_len(r.prompt)
            return min(m, ((r.prompt_len - 1) // B) * B)

        cand = list(self.queue)[:L]
        hits = [eff_hit(r) for r in cand]
        j = max(range(len(cand)), key=lambda i: (hits[i], -i))
        if j > 0 and hits[j] > hits[0]:
            r = cand[j]
            del self.queue[j]
            self.queue.appendleft(r)
            self.metrics.record_reordered()

    # --------------------------------------------------------------- step
    def step(self) -> bool:
        """One scheduler tick: admit work, run one jitted decode step over
        the pool, retire finished children. Returns True on progress."""
        if self.pool_kind == "paged":
            return self._step_paged()
        return self._step_slots()

    def _step_slots(self) -> bool:
        progressed = False
        if self.queue:
            # room is in cache rows: each admitted request stashes one
            room = self.prefill_window - self._window_used()
            if room > 0 and self.prefill_queued(room):
                progressed = True
        if self._try_fanout():
            progressed = True
        active_idx = [s for s, c in enumerate(self.slots) if c is not None]
        if not active_idx:
            return progressed
        active = np.zeros(self.pool.n_slots, bool)
        active[active_idx] = True
        tok, self.logits, self.pool.cache, self.pos, self.keys = _pool_tick(
            self.model, self.params, self.pool.cache, self.logits, self.pos,
            self.keys, jnp.asarray(active), self.temperature,
            temperature_zero=(self.temperature == 0.0))
        self.metrics.record_dispatch()
        self.metrics.record_tick(len(active_idx))
        tok_np = np.asarray(tok)
        self.metrics.record_sync()
        for s in active_idx:
            c = self.slots[s]
            t = int(tok_np[s])
            c.tokens.append(t)
            r = self.requests[c.request_id]
            if r.first_token_t is None:
                r.first_token_t = time.perf_counter()
                self.metrics.record_ttft(r.first_token_t - r.submit_t)
            if self.eos_id is not None and t == self.eos_id:
                c.eos = True
                self.metrics.record_eos(c.max_new - len(c.tokens))
            if c.done():
                self.slots[s] = None
                self.pool.release(s)
                c.slot = None
                more = r.procedure.on_child_done(r, c, self)
                if more:
                    raise ValueError("the slot pool cannot schedule "
                                     "procedure escalations")
                if r.all_children_done():
                    self._finalize(r)
        return True

    def _chunk_prefill_tick(self) -> bool:
        """Advance every prefilling slot by up to `prefill_chunk` prompt
        tokens through the varlen chunk program. Chunk ends are aligned to
        the absolute C-grid, so a prefix-cache hit (which starts prefill
        mid-prompt) computes every remaining position in exactly the batch
        shape a cold run would — the hit path stays bitwise identical.
        Whole blocks finished by the chunk are published into the radix
        tree immediately, not at probe completion."""
        B = self.pool.block_size
        C = self.prefill_chunk
        P = self.prefill_slots
        by_model: Dict[str, List[int]] = {}
        for s in sorted(self._pref):
            by_model.setdefault(self._pref[s].model_id, []).append(s)
        for mid in sorted(by_model):
            pref_slots = by_model[mid]
            toks = np.zeros((P, C), np.int32)
            pos = np.zeros((P,), np.int32)
            valid = np.zeros((P,), np.int32)
            tables = np.zeros((P, self.pool.blocks_per_seq), np.int32)
            take: Dict[int, int] = {}
            for i, s in enumerate(pref_slots):
                r = self._pref[s]
                p = r.prefill_pos
                L = min(C - p % C, r.prompt_len - p)
                # allocate the blocks this chunk writes into up front
                # (reservation-backed, like per-token growth)
                while (p + L - 1) // B >= len(r.table):
                    r.table.append(self.pool.alloc_block())
                toks[i, :L] = r.prompt[p:p + L]
                pos[i] = p
                valid[i] = L
                tables[i, :len(r.table)] = r.table
                take[s] = L
            logits, hidden, cache = _paged_chunk_tick(
                self.models[mid], self.model_params[mid],
                self.pool.caches[mid], jnp.asarray(tables),
                jnp.asarray(toks), jnp.asarray(pos), jnp.asarray(valid))
            self.pool.caches[mid] = cache
            self.metrics.record_dispatch(model=mid)
            self.metrics.record_prefill(int(valid.sum()), model=mid)
            self.metrics.record_blocks(self.pool.blocks_in_use)
            radix = self._radix_of(mid)
            hidden_np = None
            for i, s in enumerate(pref_slots):
                r = self._pref[s]
                L = take[s]
                end = r.prefill_pos + L
                if radix is not None:
                    created = radix.publish(r.prompt, r.table, end // B)
                    if created:
                        self.metrics.record_radix(published=created)
                if end == r.prompt_len:                 # probe complete
                    if hidden_np is None:
                        hidden_np = np.asarray(hidden, np.float32)
                        self.metrics.record_sync(model=mid)
                    r.hidden = hidden_np[i, L - 1]
                    group = StashGroup()
                    # stash only this request's probe row (a (V,) copy —
                    # exactly what batched fan-out admission stacks):
                    # stashing the whole (P*C, V) tick tensor would pin
                    # prefill_chunk times PR-2's footprint until fan-out —
                    # indefinitely for budget-deferred requests
                    self._make_stash(r, group, cache=None,
                                     logits=logits[i, L - 1], row=0,
                                     start_pos=end - 1, state=None)
                    del self._pref[s]
                    self.pool.release_slot(s)
                    self._tok[s] = 0
                    self._pos[s] = 0
                    self._on_prefill_complete(r)
                else:
                    r.prefill_pos = end
        return True

    def _horizon_width(self, live_dec: List[int]) -> int:
        """H = min(horizon, min remaining over live slots), quantized
        down to a power of two. min-remaining means no slot can outrun
        its budget inside the scan (the only mid-horizon freeze left is
        EOS) and a fused dispatch never computes steps every slot has
        already finished. The quantization bounds distinct compiled scan
        programs to log2(horizon)+1: on a staggered stream min-remaining
        takes nearly every value in [1, horizon], and compiling a fresh
        program per width mid-run cost more wall-clock than fusion saved
        (measured on the Poisson bench: paged dropped to 0.7x the batch
        engine before quantization, 2x+ after)."""
        rem = min(self.slots[s].max_new - len(self.slots[s].tokens)
                  for s in live_dec)
        H = max(1, min(self.horizon, rem))
        return 1 << (H.bit_length() - 1)

    def _horizon_tick(self, mid: str, live_dec: List[int], H: int) -> bool:
        """Dispatch one horizon-fused scan over model `mid`'s live decode
        slots and retire/advance from its (H, 2, n_slots) token/alive
        buffer — one jitted dispatch and ONE blocking device->host sync
        for up to H x len(live_dec) generated tokens. Retirement,
        fan-out, and admission run between horizons (the caller's next
        step()). Slots of other registry models ride along frozen
        (remaining 0: no token/pos/key advance; their writes land in
        `mid`'s null block)."""
        remaining = np.zeros(self.n_slots, np.int32)
        for s in live_dec:
            c = self.slots[s]
            remaining[s] = c.max_new - len(c.tokens)
            # extend the slot's table to cover the whole horizon up front
            # (reservation-backed), so tables are scan-invariant and
            # upload once per horizon instead of once per token
            c.reserved -= self.pool.preallocate(c.table,
                                                int(self._pos[s]) + H)
        tables = np.zeros((self.n_slots, self.pool.blocks_per_seq), np.int32)
        for s in live_dec:
            t = self.slots[s].table
            tables[s, :len(t)] = t
        emits, cache, keys = _paged_horizon_tick(
            self.models[mid], self.model_params[mid], self.pool.caches[mid],
            jnp.asarray(tables),
            jnp.asarray(self._tok), jnp.asarray(self._pos), self.keys,
            jnp.asarray(remaining), self.temperature, H=H,
            temperature_zero=(self.temperature == 0.0), eos_id=self.eos_id)
        self.pool.caches[mid] = cache
        self.keys = keys
        self.metrics.record_dispatch(model=mid)
        # the dispatch above is asynchronous: host-side bookkeeping that
        # does not depend on the sampled tokens overlaps device compute,
        # and the buffer is forced in one transfer at the end
        self.metrics.record_blocks(self.pool.blocks_in_use)
        buf = np.asarray(emits)                 # (H, 2, N): [token; alive]
        self.metrics.record_sync(model=mid)
        emitted = 0
        for s in live_dec:
            c = self.slots[s]
            r = self.requests[c.request_id]
            took = 0
            for h in range(H):
                if not buf[h, 1, s]:            # frozen: EOS'd earlier
                    break
                t = int(buf[h, 0, s])
                c.tokens.append(t)
                took += 1
                if self.eos_id is not None and t == self.eos_id:
                    c.eos = True
                    self.metrics.record_eos(c.max_new - len(c.tokens))
                    break
            emitted += took
            if c.done():
                self._retire_paged_child(c, r)
            else:                               # survivor: emitted all H
                self._tok[s] = c.tokens[-1]
                self._pos[s] = int(self._pos[s]) + took
        self.metrics.record_horizon(len(live_dec), H, emitted, model=mid)
        return True

    # --------------------------------------------------------- preemption
    def _preempt_request(self, r: Request) -> int:
        """Evict a resident request and requeue it through the existing
        phase/QUEUED re-entry path; returns blocks freed.

        The eviction is radix-cheap: before any block is released, the
        request's full prompt blocks are published into the model's radix
        tree (idempotent — chunked prefill usually already did), so the
        tree's refcounts keep the prompt KV alive across the eviction and
        the resumed request re-prefills near-free (adopting the published
        blocks at admission, recomputing only the final prompt token).
        Live children are reset to token 0; their per-child RNG streams
        (``fold_in(fold_in(seed, id), index)``) restart from scratch on
        re-admission, so the regenerated sequences — and the request's
        final response — are bitwise identical to an unpreempted run.
        Already-retired children (EOS / budget done) keep their tokens."""
        pool = self.pool
        B = pool.block_size
        free_before = pool.available_blocks
        live = [c for c in r.children if c.slot is not None]
        model = live[0].model_id if live else r.model_id
        radix = self._radix_of(model)
        table = r.table if r.table is not None else (
            live[0].table if live else None)
        full = r.prompt_len // B
        if radix is not None and table is not None and len(table) >= full:
            created = radix.publish(r.prompt, table, full)
            if created:
                self.metrics.record_radix(published=created)
        for c in live:
            s = c.slot
            self.slots[s] = None
            pool.release_slot(s)
            self._tok[s] = 0
            self._pos[s] = 0
            c.slot = None
            pool.release_table(c.table)
            c.table = None
            pool.unreserve(c.reserved)
            c.reserved = 0
            c.tokens = []
            c.eos = False
        try:
            self.fanout.remove(r)       # mid-fanout victim (rare)
        except ValueError:
            pass
        # evicted children rejoin any never-slotted ones in index order so
        # re-admission replays the original fan-out sequence
        merged = {c.index: c for c in r.pending}
        merged.update({c.index: c for c in live})
        r.pending = [merged[i] for i in sorted(merged)]
        self._drop_stash(r)
        self._release_prompt_table(r)
        pool.unreserve(r.reserved)
        r.reserved = 0
        r.hidden = None                 # recomputed (identically) on resume
        r.model_id = model
        r.state = RequestState.QUEUED
        r.prefill_pos = 0
        r.prefix_len = 0
        r.preemptions += 1
        self.queue.append(r)
        freed = pool.available_blocks - free_before
        self.metrics.record_preemption(freed)
        return freed

    def _preempt_for(self, beneficiary: Request) -> bool:
        """Pick (policy: TrafficController.choose_victim) and evict one
        resident request strictly below ``beneficiary``'s priority."""
        victim = self.traffic.choose_victim(self, beneficiary)
        if victim is None:
            return False
        self._preempt_request(victim)
        return True

    def _step_paged(self) -> bool:
        progressed = bool(self._try_fanout_paged())
        traffic = self.traffic
        preempt = traffic is not None and traffic.cfg.preempt
        if (preempt and self._fanout_blocked and self.fanout
                and self._preempt_for(self.fanout[0])):
            # freed blocks belong to the backlog head: retry immediately
            progressed = bool(self._try_fanout_paged()) or True
        progressed = bool(self._admit_prefill_paged()) or progressed
        if (preempt and self._prefill_blocked and self.queue
                and self._preempt_for(self.queue[0])):
            progressed = bool(self._admit_prefill_paged()) or True
        chunked = self.prefill_chunk > 1
        if chunked and self._pref:
            progressed = self._chunk_prefill_tick() or progressed
        # group live work per registry model: each model with live slots
        # gets its own dispatch this tick (foreign slots masked to the
        # null block and their RNG keys frozen) — single-model runs see
        # exactly one group and the historical dispatch sequence
        dec_by_model: Dict[str, List[int]] = {}
        for s, c in enumerate(self.slots):
            if c is not None:
                dec_by_model.setdefault(c.model_id, []).append(s)
        # the per-token interleave (chunk 1: recurrent-state stacks) keeps
        # prefilling slots inside the decode tick; the chunk program above
        # owns them otherwise
        pref_by_model: Dict[str, List[int]] = {}
        if not chunked:
            for s, r in self._pref.items():
                pref_by_model.setdefault(r.model_id, []).append(s)
        if not dec_by_model and not pref_by_model:
            return progressed
        n_live = sum(len(v) for v in dec_by_model.values())
        if len(self.models) > 1:
            self.metrics.record_live(n_live)
        for mid in sorted(set(dec_by_model) | set(pref_by_model)):
            live_dec = dec_by_model.get(mid, [])
            live_pref = pref_by_model.get(mid, [])
            # horizon-fused decode: engages only when decode has the
            # device to itself (no prefill interleave in flight —
            # admission and chunked prefill run between horizons) and
            # the stack is stateless. H=1 would recompile the scan for
            # nothing, so the per-token program below keeps that case.
            if (self.horizon > 1 and live_dec and not self._pref
                    and not self.pool._has_state):
                H = self._horizon_width(live_dec)
                if self.traffic is not None:
                    # load shedding: shorter horizon leases return freed
                    # slots/blocks to admission sooner under pressure
                    # (halving preserves the power-of-two quantization)
                    H = self.traffic.effective_horizon(self, H)
                if H > 1:
                    self._horizon_tick(mid, live_dec, H)
                    continue
            self._token_tick(mid, live_dec, live_pref)
        return True

    def _token_tick(self, mid: str, live_dec: List[int],
                    live_pref: List[int]) -> None:
        """One per-token program over model `mid`'s slots (decode + the
        chunk-1 prefill interleave). Slots belonging to other models run
        through as dead rows: null tables, frozen keys, outputs
        dropped."""
        B = self.pool.block_size
        # allocate blocks on demand before the tick's writes cross into
        # them (reservation-backed: can_reserve was checked at admission)
        for s in live_dec:
            c = self.slots[s]
            if self._pos[s] // B == len(c.table):
                c.table.append(self.pool.alloc_block())
                c.reserved -= 1
        for s in live_pref:
            r = self._pref[s]
            if self._pos[s] // B == len(r.table):
                r.table.append(self.pool.alloc_block())
        tables = np.zeros((self.n_slots, self.pool.blocks_per_seq), np.int32)
        for s in live_dec:
            t = self.slots[s].table
            tables[s, :len(t)] = t
        for s in live_pref:
            t = self._pref[s].table
            tables[s, :len(t)] = t
        advance = np.zeros((self.n_slots,), bool)
        advance[live_dec] = True
        sampled, logits, hidden, cache, self.keys = _paged_tick(
            self.models[mid], self.model_params[mid], self.pool.caches[mid],
            jnp.asarray(tables),
            jnp.asarray(self._tok), jnp.asarray(self._pos), self.keys,
            jnp.asarray(advance), self.temperature,
            temperature_zero=(self.temperature == 0.0))
        self.pool.caches[mid] = cache
        self.metrics.record_dispatch(model=mid)
        self.metrics.record_tick(len(live_dec) + len(live_pref),
                                 n_sampled=len(live_dec), model=mid)
        self.metrics.record_blocks(self.pool.blocks_in_use)
        if live_pref:
            self.metrics.record_prefill(len(live_pref), model=mid)
        sampled_np = np.asarray(sampled)
        self.metrics.record_sync(model=mid)
        hidden_np = (np.asarray(hidden, np.float32) if live_pref else None)
        if live_pref:
            self.metrics.record_sync(model=mid)
        radix = self._radix_of(mid)
        for s in live_pref:
            r = self._pref[s]
            t = int(self._pos[s])
            if t == r.prompt_len - 1:           # probe complete
                if radix is not None:
                    created = radix.publish(r.prompt, r.table,
                                            r.prompt_len // B)
                    if created:
                        self.metrics.record_radix(published=created)
                r.hidden = hidden_np[s]
                group = StashGroup()
                self._make_stash(r, group, cache=None, logits=logits[s],
                                 row=0, start_pos=t,
                                 state=self.pool.snapshot_slot_state(
                                     s, model_id=mid))
                del self._pref[s]
                self.pool.release_slot(s)
                self._tok[s] = 0
                self._pos[s] = 0
                self._on_prefill_complete(r)
            else:
                r.prefill_pos = t + 1
                self._pos[s] = t + 1
                self._tok[s] = int(r.prompt[t + 1])
        for s in live_dec:
            c = self.slots[s]
            if c is None:
                continue
            r = self.requests[c.request_id]
            t = int(sampled_np[s])
            c.tokens.append(t)
            if self.eos_id is not None and t == self.eos_id:
                c.eos = True
                self.metrics.record_eos(c.max_new - len(c.tokens))
            if c.done():
                self._retire_paged_child(c, r)
            else:
                self._tok[s] = t
                self._pos[s] = int(self._pos[s]) + 1
        return

    def _retire_paged_child(self, c: ChildSeq, r: Request) -> None:
        """Free the child's slot, blocks (shared ones decref), and any
        unclaimed reservation — immediately, so EOS/short children return
        memory to the pool the same tick they finish. The procedure's
        `on_child_done` hook then gets a chance to spawn more work
        (cascade escalation to another model, extra fan-out)."""
        slot = c.slot
        self.slots[slot] = None
        self.pool.release_slot(slot)
        self._tok[slot] = 0
        self._pos[slot] = 0
        c.slot = None
        self.pool.release_table(c.table)
        c.table = None
        self.pool.unreserve(c.reserved)
        c.reserved = 0
        more = r.procedure.on_child_done(r, c, self)
        if more:
            self._apply_groups(r, list(more))
        if r.all_children_done():
            self._finalize(r)

    def _finalize(self, r: Request) -> None:
        if r.children:
            r.state = RequestState.RERANK
            r.procedure.finalize(r, self)
        else:
            # empty plan (b_i = 0): the documented default response — an
            # empty token row with zero reward (the paper's "answer with
            # the default")
            r.response = np.zeros((0,), np.int32)
            r.reward = 0.0
            self.metrics.record_default()
        r.state = RequestState.DONE
        r.done_t = time.perf_counter()
        self.metrics.record_done(r.latency)

    # ---------------------------------------------------------------- run
    @property
    def n_inflight(self) -> int:
        return sum(c is not None for c in self.slots)

    def pending(self) -> bool:
        prefilling = self.pool_kind == "paged" and bool(self._pref)
        return bool(self.queue or self.fanout or self.n_inflight
                    or prefilling)

    def _stall_report(self, ctx: str = "drain") -> str:
        parts = [f"runtime stalled in {ctx}"]
        deferred = [r.id for r in self.requests.values()
                    if r.state is RequestState.PREFILL and r.stash is not None
                    and r.stash.deferred]
        if deferred:
            parts.append(f"requests awaiting set_budget(): {deferred}")
        if self.queue:
            parts.append(
                f"queued, cannot prefill: {[r.id for r in self.queue]}")
        if self.fanout:
            head = self.fanout[0]
            if self.pool_kind == "paged":
                parts.append(
                    f"fan-out blocked for request {head.id} "
                    f"(free_slots={self.pool.n_free_slots}, "
                    f"free_blocks={self.pool.n_free_blocks}, "
                    f"reserved={self.pool._reserved}, "
                    f"radix_held={self._radix_held})")
            else:
                parts.append(f"fan-out blocked for request {head.id} "
                             f"(free_slots={self.pool.n_free})")
        phased = [r.id for r in self.requests.values() if r.pending_phases]
        if phased:
            parts.append(f"requests with pending model phases: {phased}")
        return "; ".join(parts)

    def assert_ledger_balanced(self) -> None:
        """Block-ledger balance: every refcount is explained by a live
        owner (request prompt tables, child tables, radix nodes) and the
        pool's reservation counter equals the live owners' unclaimed
        worst cases. Valid at any step boundary. A leak — e.g. an EOS
        retirement dropping blocks but not its remaining reservation —
        fails here loudly instead of silently shrinking
        ``available_blocks`` until admission starves."""
        if self.pool_kind != "paged":
            return
        pool = self.pool
        pool.check_conservation()
        refs = [0] * pool.n_blocks
        reserved = 0
        for r in self.requests.values():
            if r.table is not None:
                for blk in set(r.table):
                    refs[blk] += 1
            reserved += r.reserved
            if r.state is RequestState.PREFILLING:
                # remaining prompt-growth reservation is implicit: the
                # blocks the prompt still needs beyond its current table
                reserved += pool.blocks_for(r.prompt_len) - len(r.table)
            for c in r.children:
                if c.table is not None:
                    for blk in set(c.table):
                        refs[blk] += 1
                reserved += c.reserved
        for radix in self._radices.values():
            stack = list(radix.root.values())
            while stack:
                n = stack.pop()
                stack.extend(n.children.values())
                refs[n.block] += 1
        assert refs == pool._ref, (
            "block refcount leak: owners "
            f"{[(i, a, b) for i, (a, b) in enumerate(zip(refs, pool._ref)) if a != b]}")
        assert reserved == pool._reserved, (
            f"reservation leak: owners hold {reserved}, "
            f"pool ledger says {pool._reserved}")

    def drain(self) -> None:
        """Run until every runnable request is DONE. Requests still waiting
        on :meth:`set_budget` are left in PREFILL (they are not runnable
        and do not count against the prefill window). On completion the
        block ledger must balance exactly (see
        :meth:`assert_ledger_balanced`)."""
        while self.pending():
            if not self.step():
                raise RuntimeError(self._stall_report())
        self.assert_ledger_balanced()

    def result(self, request_id: int) -> Request:
        return self.requests[request_id]
