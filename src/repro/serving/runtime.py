"""Continuous-batching decode runtime with in-flight adaptive fan-out.

A fixed pool of decode slots that variable-length, variable-budget
requests stream through (vs the batch engine's full-batch barriers):

* **At most one prefill per request — often less.** The probe prefill
  that feeds the difficulty predictor IS the generation prefill: KV
  blocks shared copy-on-write across children and deduped across
  requests via a radix prefix cache (paged pool), or the prefill row
  replicated per child (slot pool).
* **Statically-shaped programs compiled once**, block-granular memory
  tracking actual sequence length, deadlock-free worst-case
  reservation ledger, immediate slot reclamation.
* **A unified tick pipeline: plan -> dispatch -> retire.** Each paged
  tick a pure planner (`serving/plan.py`) partitions live slots per
  model into static-shape device programs; the program layer
  (`serving/tick_programs.py`) launches compiled dispatches; the
  retirement layer (`serving/retire.py`) consumes the host buffers.
  Decode runs up to `horizon` steps per `lax.scan` dispatch (one host
  sync per horizon), and when prefill is in flight the scan carries
  the prefill rows too (`mixed_program`): prefill rows consume queued
  prompt tokens under a per-row role mask while decode rows sample, so
  an arriving request no longer drops resident decodes to per-token
  dispatch. The per-token interleave survives for recurrent-state
  stacks and `horizon=1`; `fuse_prefill=False` restores the
  pre-refactor fallback (decode per-token while any slot prefills).
* **Procedure-centric, multi-model.** Pluggable
  :class:`DecodeProcedure` objects plan which registry model(s) decode
  a request and how many children fan out; ``register_model`` adds
  models sharing ONE paged pool, one dispatch per model with live work
  per tick.

Sampling uses per-child RNG streams — ``fold_in(fold_in(seed, request_id),
child_index)`` — so outputs depend only on (seed, request, child):
greedy decoding is bitwise-reproducible across paged pool, slot pool,
the batch engine, and fused vs unfused ticks (tests/test_runtime.py,
tests/test_paged_pool.py, tests/test_tick_pipeline.py).
"""
from __future__ import annotations

import time
from collections import deque
from typing import Any, Callable, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.model_zoo import Model
from repro.serving import tick_programs
from repro.serving.engine import prefill
from repro.serving.kv_pool import SlotKVPool
from repro.serving.metrics import ServingMetrics
from repro.serving.paged_pool import (PagedKVPool, cdiv, resolve_kv_quant,
                                      supports_paging)
from repro.serving.plan import plan_tick
from repro.serving.procedure import BestOfK, DecodeProcedure
from repro.serving.radix_cache import RadixCache
from repro.serving.request import (ChildSeq, PrefillStash, Request,
                                   RequestState, StashGroup)
from repro.serving.retire import Retirement
from repro.serving.traffic.controller import TrafficConfig, TrafficController


class ContinuousBatchingRuntime:
    """Pooled decode runtime; see module docstring.

    pool="paged" (default): block-granular pages with COW prompt
    sharing, a radix prefix cache (prefix_cache=True; stateless stacks
    only), chunked prefill (prefill_chunk, default block_size), and
    horizon-fused decode (horizon, default 8 scan steps per dispatch
    and host sync); fuse_prefill (default True) lets the horizon scan
    carry prefill rows alongside decode instead of dropping decode to
    per-token dispatch while any slot prefills. pool="slots" keeps the
    PR-1 full-row pool (bitwise-equivalence tests; sliding-window
    fallback). admission_lookahead bounds the radix-aware admission
    scan that pulls the longest prefix-cache hit forward.

    budget_fn(request, hidden) -> int resolves budgets at admission
    (streaming mode); in paged mode the result is additionally gated on
    free *blocks*, so difficulty-driven fan-out cannot over-commit
    memory. Leave it None and call :meth:`set_budget` for batch-exact
    allocation (the AdaptiveScheduler facade does this).
    reward_fn(query, rows) -> scores reranks a request's children when
    the last one finishes; None keeps child 0. eos_id terminates a
    child early, freeing its slot/blocks immediately.
    """

    def __init__(self, model: Model, params, *, n_slots: int = 8,
                 max_len: int = 64, max_new: int = 16,
                 temperature: float = 0.0, seed: int = 0,
                 reward_fn: Optional[Callable] = None,
                 budget_fn: Optional[Callable] = None,
                 prefill_window: Optional[int] = None,
                 pool: str = "paged", block_size: int = 16,
                 n_blocks: Optional[int] = None,
                 prefill_slots: Optional[int] = None,
                 eos_id: Optional[int] = None,
                 prefix_cache: bool = True,
                 prefill_chunk: Optional[int] = None,
                 horizon: int = 8,
                 fuse_prefill: bool = True,
                 admission_lookahead: int = 4,
                 traffic: Optional[TrafficConfig] = None,
                 kv_quant: Optional[str] = None):
        assert pool in ("paged", "slots")
        if pool == "paged" and not supports_paging(model, max_len):
            pool = "slots"          # sliding-window wrap: paged is inexact
        self.kv_quant = kv_quant = resolve_kv_quant(kv_quant, pool)
        self.pool_kind = pool
        self.model, self.params = model, params
        # model registry: the constructor model is "default"; routing
        # pairs etc. join via register_model (paged pool only)
        self.models: Dict[str, Model] = {"default": model}
        self.model_params: Dict[str, Any] = {"default": params}
        self.default_procedure: DecodeProcedure = BestOfK()
        self.max_new = int(max_new)
        self.temperature = float(temperature)
        self.reward_fn, self.budget_fn = reward_fn, budget_fn
        self.eos_id = None if eos_id is None else int(eos_id)
        # admission control: at most this many *stash groups* (device-
        # resident prefill caches / prompt-block tables) may be live at
        # once, bounding memory under a deep backlog. Requests parked on
        # an un-called set_budget() are excluded — the caller's memory,
        # and counting them starved new arrivals (spurious drain stalls).
        if prefill_window is None:
            prefill_window = 2 * n_slots
        assert prefill_window >= 1
        self.prefill_window = prefill_window
        self._groups: set = set()           # live StashGroups
        self.metrics = ServingMetrics(n_slots=n_slots)
        self._base_key = jax.random.PRNGKey(seed)
        self.n_slots = int(n_slots)
        V = model.lm.vocab_padded
        self.keys = jnp.zeros((n_slots, 2), jnp.uint32)
        self.slots: List[Optional[ChildSeq]] = [None] * n_slots
        self.retire = Retirement(self)      # host-side retirement layer
        # streaming emit hooks: fn(request, child) fired whenever a
        # child's token list grows — AsyncTokenStreamer subscribes so
        # clients see per-token progress while internal drain loops run
        self._emit_hooks: List[Callable] = []
        # traffic subsystem: priority scheduling + preemption + SLO-aware
        # degradation (serving/traffic/). The scheduler replaces the FIFO
        # deque behind the same peek/pop protocol, so every admission path
        # below is policy-agnostic.
        self.traffic: Optional[TrafficController] = None
        if traffic is not None:
            if pool != "paged":
                raise ValueError(
                    "the traffic subsystem needs the paged pool "
                    "(preemption is a block-ledger operation)")
            self.traffic = TrafficController(traffic)
        self.queue = (deque() if self.traffic is None
                      else self.traffic.make_queue())  # awaiting prefill
        self.fanout: deque = deque()      # Requests with un-slotted children
        self.requests: Dict[int, Request] = {}
        self._next_id = 0
        self._prefix_cache = False
        self._radices: Dict[str, RadixCache] = {}
        self.fuse_prefill = bool(fuse_prefill)
        if pool == "paged":
            if n_blocks is None:
                # in-flight children worst case + one stashed-window's
                # worth of prompts + the null block
                n_blocks = ((n_slots + prefill_window)
                            * cdiv(max_len, block_size) + 1)
            self.pool = PagedKVPool(model, n_slots, max_len,
                                    block_size=block_size, n_blocks=n_blocks,
                                    kv_quant=kv_quant)
            self.metrics.register_kv_store_from(self.pool)
            # chunked prefill may use the whole pool (fan-out admission
            # runs first each tick, so decode children reclaim freed
            # slots before new prompts); lower to bound prefill per tick
            if prefill_slots is None:
                prefill_slots = n_slots
            self.prefill_slots = int(prefill_slots)
            self._pref: Dict[int, Request] = {}   # slot -> prefilling req
            self._tok = np.zeros(n_slots, np.int32)   # next input token
            self._pos = np.zeros(n_slots, np.int32)   # its decode position
            self._fanout_blocked = False
            self._prefill_blocked = False   # admission starved (traffic)
            # multi-token chunked prefill: up to `prefill_chunk` prompt
            # tokens per prefilling slot per tick under one compiled
            # varlen program. Recurrent-state stacks advance one token
            # per step, so stay per-token (chunk 1 == the PR-2 path).
            if not self.model.supports_chunked_prefill:
                prefill_chunk = 1
            elif prefill_chunk is None:
                prefill_chunk = block_size
            self.prefill_chunk = max(1, int(prefill_chunk))
            # radix prefix cache: cross-request dedup of full prompt
            # blocks, one tree per registry model (a prefix's KV is
            # model-specific) on the shared block ledger. Sound only for
            # stateless stacks (skipped tokens must skip no state).
            self._prefix_cache = (bool(prefix_cache)
                                  and not self.pool._has_state)
            if self._prefix_cache:
                self._radices["default"] = RadixCache(self.pool)
            # horizon-fused decode: up to `horizon` decode steps per
            # compiled dispatch (one host sync per horizon, not per
            # token); the planner (serving/plan.py) picks the width and
            # whether prefill rows ride along. Recurrent-state pools
            # stay per-token; horizon=1 disables fusion.
            self.horizon = max(1, int(horizon))
            if self.pool._has_state:
                self.horizon = 1
            # radix-aware admission ordering: scan this many queued
            # requests and admit the longest published-prefix hit first
            # (1 = strict FIFO). Bounded, so a miss is bypassed at most
            # while hits keep landing inside the lookahead window.
            self.admission_lookahead = max(1, int(admission_lookahead))
        else:
            self.pool = SlotKVPool(model, n_slots, max_len)
            self.logits = jnp.zeros((n_slots, V), model.lm.dtype)
            self.pos = jnp.zeros((n_slots,), jnp.int32)

    # ----------------------------------------------------- model registry
    def register_model(self, model_id: str, model: Model, params) -> None:
        """Add a model to the registry (paged pool only): it gets its own
        KV store and radix prefix cache on the SHARED block ledger, and
        each tick dispatches one program per model with live work.
        Procedures address it by ``model_id`` in their plans."""
        if self.pool_kind != "paged":
            raise ValueError("multi-model serving needs the paged pool")
        if model_id in self.models:
            raise ValueError(f"model id {model_id!r} already registered")
        if not model.supports_chunked_prefill:
            raise ValueError(
                f"model {model_id!r}: multi-model serving requires a "
                "stateless (attention/MLA) stack")
        self.pool.add_model(model_id, model)     # checks statelessness
        self.models[model_id] = model
        self.model_params[model_id] = params
        self.metrics.register_kv_store_from(self.pool)
        if self._prefix_cache:
            self._radices[model_id] = RadixCache(self.pool)

    @property
    def radix(self) -> Optional[RadixCache]:
        """Default model's prefix cache (back-compat view; multi-model
        callers use the per-model trees internally)."""
        return self._radices.get("default") if self.pool_kind == "paged" \
            else None

    def _radix_of(self, model_id: str) -> Optional[RadixCache]:
        return self._radices.get(model_id)

    @property
    def _radix_held(self) -> int:
        return sum(rx.held_blocks for rx in self._radices.values())

    # ------------------------------------------------------------- submit
    def submit(self, prompt: np.ndarray, *, budget: Optional[int] = None,
               query: Any = None, max_new: Optional[int] = None,
               procedure: Optional[DecodeProcedure] = None,
               tenant: str = "default", priority: int = 1,
               slo: Optional[float] = None) -> int:
        """Enqueue one request. ``procedure`` drives its lifecycle (see
        serving/procedure.py); omitted, the runtime's default ``BestOfK``
        reproduces the historical budget/fan-out semantics exactly —
        ``budget=``/``budget_fn``/``set_budget`` all still work.
        ``tenant``/``priority``/``slo`` feed the traffic subsystem
        (serving/traffic/): without ``traffic=`` they are recorded but
        scheduling stays strict FIFO."""
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        mn = self.max_new if max_new is None else int(max_new)
        if len(prompt) + mn > self.pool.max_len:
            raise ValueError(
                f"prompt_len {len(prompt)} + max_new {mn} exceeds pool "
                f"max_len {self.pool.max_len}")
        proc = self.default_procedure if procedure is None else procedure
        probe = proc.probe_model
        if probe not in self.models:
            raise KeyError("procedure probes unregistered model "
                           f"{probe!r}; register_model it first")
        if self.pool_kind != "paged" and not isinstance(proc, BestOfK):
            raise ValueError("the slot pool serves only the BestOfK "
                             "procedure; use pool='paged'")
        if self.pool_kind == "paged":
            # one child's worst case while the request's prompt table is
            # still held: the prompt's blocks plus the child's privately
            # owned tail (incl. its COW boundary copy)
            sp = len(prompt)
            owned = (self.pool.blocks_for(sp + mn)
                     - sp // self.pool.block_size)
            worst = self.pool.blocks_for(sp) + owned
            if worst > self.pool.n_blocks - 1:
                raise ValueError(
                    f"request needs up to {worst} blocks but the pool has "
                    f"{self.pool.n_blocks - 1} usable")
        if slo is None and self.traffic is not None:
            slo = self.traffic.cfg.default_slo
        r = Request(id=self._next_id, prompt=prompt, query=query,
                    budget=None if budget is None else int(budget),
                    max_new=mn, procedure=proc, model_id=probe,
                    tenant=str(tenant), priority=int(priority),
                    slo=None if slo is None else float(slo))
        self._next_id += 1
        self.requests[r.id] = r
        self.queue.append(r)
        return r.id

    def submit_batch(self, prompts: np.ndarray,
                     budgets: Optional[Sequence[int]] = None,
                     queries: Optional[Sequence] = None,
                     max_new: Optional[Sequence[int]] = None) -> List[int]:
        """Batch submit. `max_new` is per-request, like `budgets` — it
        used to be silently dropped (every request fell back to the
        runtime default even though `submit` accepts it)."""
        n = len(prompts)
        return [self.submit(prompts[i],
                            budget=None if budgets is None else budgets[i],
                            query=None if queries is None else queries[i],
                            max_new=None if max_new is None
                            else int(max_new[i]))
                for i in range(n)]

    # --------------------------------------------------- stash accounting
    def _window_used(self) -> int:
        """Device cache rows pinned by live stash groups. A group's cache
        has batch dim = its original size and is only freeable when the
        *last* member drops its stash, so every row stays counted until
        the group dies — the old per-request count released window
        capacity as members dropped while the cache was still fully
        alive, under-throttling memory on large same-length groups.
        Groups whose every live member awaits set_budget() are excluded
        (they starved arrivals -> spurious drain() stalls; their memory
        belongs to the caller)."""
        return sum(g.rows for g in self._groups if g.nondeferred > 0)

    def _make_stash(self, r: Request, group: StashGroup, **kw) -> None:
        # stashes start non-deferred; a plan() returning None (BestOfK
        # awaiting set_budget) flips the flag in run_plan
        r.stash = PrefillStash(group=group, deferred=False, **kw)
        group.size += 1
        group.rows += 1             # pinned until the whole group dies
        group.nondeferred += 1
        self._groups.add(group)

    def _defer_stash(self, r: Request) -> None:
        st = r.stash
        if st is not None and not st.deferred:
            st.deferred = True
            st.group.nondeferred -= 1

    def _drop_stash(self, r: Request) -> None:
        st = r.stash
        if st is None:
            return
        r.stash = None
        g = st.group
        g.size -= 1
        if not st.deferred:
            g.nondeferred -= 1
        if g.size == 0:
            self._groups.discard(g)

    # -------------------------------------------------- streaming hooks
    def add_emit_hook(self, fn: Callable) -> None:
        """Register ``fn(request, child)`` to run whenever a child's
        token list grows — at fan-out admission (first token) and at
        every token/horizon/mixed retirement. Hooks fire inside step(),
        so streaming consumers observe per-token progress even when the
        runtime is driven by internal drain loops; they must be cheap
        and must tolerate a child's token list SHRINKING between calls
        (preemption resets live children to replay bitwise)."""
        self._emit_hooks.append(fn)

    def _notify_emit(self, r: Request, c: ChildSeq) -> None:
        for fn in self._emit_hooks:
            fn(r, c)

    # ------------------------------------------------------------ prefill
    def prefill_queued(self, limit: Optional[int] = None) -> int:
        """Prefill up to `limit` queued requests (all of them when None)
        and return how many. Slot pool: batch same-length prompts into
        one jitted pass (the probe prefill — note it compiles per
        distinct (group, prompt_len) shape; each row it stashes counts
        against the prefill window until its group dies). Paged pool:
        drive the chunked prefill to completion for those requests by
        running ticks (the varlen chunk program, the fused mixed scan,
        or the decode-tick interleave for recurrent-state stacks).
        Resolves budgets via budget_fn when present."""
        if self.pool_kind == "paged":
            n = len(self.queue) if limit is None else min(int(limit),
                                                          len(self.queue))
            targets = [r.id for r in list(self.queue)[:n]]
            while any(self.requests[i].hidden is None for i in targets):
                if not self.step():
                    raise RuntimeError(self._stall_report("prefill_queued"))
            return n
        by_len: Dict[int, List[Request]] = {}
        taken = 0
        while self.queue and (limit is None or taken < limit):
            r = self.queue.popleft()
            if r.admit_t is None:
                r.admit_t = time.perf_counter()
                self.metrics.record_queue_wait(r.admit_t - r.submit_t)
            by_len.setdefault(r.prompt_len, []).append(r)
            taken += 1
        for sp, reqs in by_len.items():
            P = jnp.asarray(np.stack([r.prompt for r in reqs]))
            logits, hidden, cache = prefill(self.model, self.params, P,
                                            self.pool.max_len)
            self.metrics.record_prefill(len(reqs) * sp)
            hidden_np = np.asarray(hidden, np.float32)
            group = StashGroup()        # one shared device cache
            for i, r in enumerate(reqs):
                r.hidden = hidden_np[i]
                self._make_stash(r, group, cache=cache, logits=logits,
                                 row=i, start_pos=sp - 1)
                r.state = RequestState.PREFILL
                self._run_plan(r)
        return taken

    def set_budget(self, request_id: int, budget: int) -> None:
        """Resolve a deferred budget (batch-exact allocation path): the
        parked request's procedure re-plans with the budget now known."""
        r = self.requests[request_id]
        assert r.state == RequestState.PREFILL and r.stash is not None
        if r.stash.deferred:
            r.stash.deferred = False
            r.stash.group.nondeferred += 1
        r.budget = int(budget)
        self._run_plan(r)

    # -------------------------------------- retirement-layer delegations
    # (thin names kept on the runtime: procedures and tests reach for
    # them, and pre-split call sites — _gate_budget, _preempt_request —
    # are documented API surface)
    def _run_plan(self, r: Request) -> None:
        self.retire.run_plan(r)

    def _apply_groups(self, r: Request, groups) -> None:
        self.retire.apply_groups(r, groups)

    def _maybe_start_next_phase(self, r: Request) -> None:
        self.retire.maybe_start_next_phase(r)

    def _on_prefill_complete(self, r: Request) -> None:
        self.retire.on_prefill_complete(r)

    def _retire_paged_child(self, c: ChildSeq, r: Request) -> None:
        self.retire.retire_child(c, r)

    def _finalize(self, r: Request) -> None:
        self.retire.finalize(r)

    def _preempt_request(self, r: Request) -> int:
        return self.retire.preempt_request(r)

    def _preempt_for(self, beneficiary: Request) -> bool:
        return self.retire.preempt_for(beneficiary)

    def _stall_report(self, ctx: str = "drain") -> str:
        return self.retire.stall_report(ctx)

    def assert_ledger_balanced(self) -> None:
        self.retire.assert_ledger_balanced()

    # --------------------------------------------------- admission gates
    def _gate_budget(self, r: Request, budget: int) -> int:
        """Paged streaming admission is gated on free *blocks*: cap the
        resolved budget at what unreserved memory can eventually carry.
        The request's standing one-child reservation (made at prefill
        admission) already pays for its first child, so that child is
        granted on top of the open-market capacity; the floor of 1 covers
        the degenerate no-reservation path."""
        if self.pool_kind != "paged" or budget <= 0:
            return budget
        if self.traffic is not None:
            # SLO-aware degradation: under load, shave the ask to what
            # clears the load price *before* gating on free memory —
            # degrade deliberately (priority-weighted) rather than letting
            # the memory gate clip everyone equally
            budget = self.traffic.degrade_budget(self, r, budget)
        per_child = self._child_owned_blocks(r)
        guaranteed = 1 if r.reserved else 0
        # radix-held blocks are a cache, not a commitment: fan-out
        # admission evicts them on demand, so they count as capacity
        # here. held_blocks is an O(1) upper bound on what eviction can
        # free; over-granting is safe — the standing one-child
        # reservation guarantees progress and surplus children just wait
        # in the fan-out backlog
        cap = guaranteed + ((self.pool.available_blocks + self._radix_held)
                            // max(1, per_child))
        return max(1, min(budget, cap))

    def _child_owned_blocks(self, r: Request,
                            max_new: Optional[int] = None) -> int:
        """Blocks a fan-out child may come to own privately: its COW copy
        of the partial boundary block plus its decode tail. Full prompt
        blocks are shared and stay the request's."""
        B = self.pool.block_size
        mn = r.max_new if max_new is None else int(max_new)
        full = r.prompt_len // B
        return self.pool.blocks_for(r.prompt_len + mn) - full

    def _can_reserve_or_evict(self, k: int) -> bool:
        """Admission headroom check that spends the radix caches first:
        retired prompts' published blocks are a cache, not a commitment,
        so when a reservation cannot be met the LRU evictable leaves are
        freed — from every model's tree — before giving up."""
        if self.pool.can_reserve(k):
            return True
        for rx in self._radices.values():
            need = k - self.pool.available_blocks
            if need <= 0:
                break
            freed = rx.evict(need)
            if freed:
                self.metrics.record_radix(evicted=freed)
        return self.pool.can_reserve(k)

    def _release_prompt_table(self, r: Request) -> None:
        if r.table is not None:
            self.pool.release_table(r.table)
            r.table = None

    # ------------------------------------------------------------- fanout
    def _try_fanout(self) -> int:
        """Admit pending children into free slots (FIFO over requests).
        Slot pool: each admission replicates the request's probe-prefill
        cache row into the slot — the fan-out shares one prefill."""
        admitted = 0
        while self.pool.n_free and self.fanout:
            r = self.fanout[0]
            c = r.pending.pop(0)
            slot = self.pool.alloc()
            st = r.stash
            self.pool.write_row(st.cache, st.row, slot)
            ck = jax.random.fold_in(
                jax.random.fold_in(self._base_key, r.id), c.index)
            self.logits, self.pos, self.keys = tick_programs.admit_slot(
                self.logits, self.pos, self.keys, st.logits, st.row, slot,
                st.start_pos, ck)
            c.slot = slot
            self.slots[slot] = c
            admitted += 1
            if not r.pending:
                self.fanout.popleft()
                self._drop_stash(r)     # pool rows now hold the only copies
        return admitted

    def _try_fanout_paged(self) -> int:
        """Admit pending children: share the request's full prompt blocks
        copy-on-write (incref), privately copy only the partial boundary
        block, reserve the child's worst-case decode tail, and sample
        first tokens from the stashed probe logits.

        All children spawned in the same tick are admitted through ONE
        vmapped program (`tick_programs.admit_program`): host bookkeeping
        (slots, tables, reservations) is collected first, then a single
        dispatch derives every child's RNG stream, samples every first
        token, and scatters the advanced keys — the per-child path paid
        ~3 device ops per child. The outer loop re-runs collection when
        an admission-time retirement (EOS / max_new=1) frees slots that
        more pending children can take within the same tick."""
        admitted = 0
        self._fanout_blocked = False
        tz = self.temperature == 0.0
        B = self.pool.block_size
        while True:
            batch: List = []        # (request, child) admitted this round
            copies: Dict[str, int] = {}
            while self.fanout and self.pool.n_free_slots:
                r = self.fanout[0]
                c0 = r.pending[0]
                owned = self._child_owned_blocks(r, c0.max_new)
                if r.reserved:
                    # first child: consume the standing reservation made
                    # at prefill admission (guaranteed progress; sized to
                    # the request's max_new, so a group-capped child may
                    # need less — the surplus is returned)
                    assert r.reserved >= owned
                elif not self._can_reserve_or_evict(owned):
                    self._fanout_blocked = True   # hold new prefills back
                    break
                c = r.pending.pop(0)
                slot = self.pool.alloc_slot()
                if r.reserved:
                    self.pool.unreserve(r.reserved - owned)
                    r.reserved = 0                # transfer to the child
                else:
                    self.pool.reserve(owned)
                c.reserved = owned
                full = r.prompt_len // B
                # registered BEFORE it fills: a raise mid-window then
                # leaves the refs owner-accounted for the teardown paths
                c.table = table = []
                for t in range(full):           # shared, read-only forever
                    self.pool.incref(r.table[t])
                    table.append(r.table[t])
                if r.prompt_len % B:            # COW the boundary block
                    blk = self.pool.alloc_block()
                    c.reserved -= 1
                    table.append(blk)
                    self.pool.copy_block(r.table[full], blk,
                                         model_id=c.model_id)
                    copies[c.model_id] = copies.get(c.model_id, 0) + 1
                self.pool.restore_slot_state(r.stash.state, slot,
                                             model_id=c.model_id)
                c.slot = slot
                self.slots[slot] = c
                self._pos[slot] = r.prompt_len  # first decode position
                batch.append((r, c, r.stash.logits))
                if not r.pending:
                    self.fanout.popleft()
                    self._release_prompt_table(r)  # children hold refs
                    self._drop_stash(r)
                    self._maybe_start_next_phase(r)
            if not batch:
                break
            # one admission program per model present (probe-logit rows
            # have per-model vocab widths); the common case is one
            N = self.n_slots
            by_model: Dict[str, List] = {}
            for entry in batch:
                by_model.setdefault(entry[1].model_id, []).append(entry)
            for mid in sorted(by_model):
                sub = by_model[mid]
                m = len(sub)
                # pad to the pool width so every admission batch size
                # runs the SAME compiled program; padded rows sample
                # garbage that the host drops, and their out-of-range
                # slot index makes the keys scatter a documented no-op
                # (jax drops OOB scatter updates by default)
                pad = N - m
                toks, self.keys = tick_programs.admit_program(tz)(
                    tuple(st for _, _, st in sub) + (sub[0][2],) * pad,
                    self._base_key,
                    jnp.asarray([r.id for r, _, _ in sub] + [0] * pad,
                                jnp.int32),
                    jnp.asarray([c.index for _, c, _ in sub] + [0] * pad,
                                jnp.int32),
                    jnp.asarray([c.slot for _, c, _ in sub] + [N] * pad,
                                jnp.int32),
                    self.keys, self.temperature)
                self.metrics.record_dispatch(1 + copies.get(mid, 0),
                                             model=mid)
                toks_np = np.asarray(toks)  # analysis: allow(sync) per batch
                self.metrics.record_sync(model=mid)
                self.metrics.record_first_token(m, model=mid)
                for (r, c, _), tok_i in zip(sub, toks_np):
                    tok_i = int(tok_i)
                    c.tokens.append(tok_i)
                    if r.first_token_t is None:
                        r.first_token_t = time.perf_counter()
                        self.metrics.record_ttft(r.first_token_t
                                                 - r.submit_t)
                    if self.eos_id is not None and tok_i == self.eos_id:
                        c.eos = True
                        self.metrics.record_eos(c.max_new - len(c.tokens))
                    self._tok[c.slot] = tok_i
                    self._notify_emit(r, c)
                    if c.done():            # EOS/max_new=1 at admission
                        self._retire_paged_child(c, r)
                admitted += m
        return admitted

    def _admit_prefill_paged(self) -> int:
        """Move queued requests into chunked prefill: claim a slot, the
        prompt's worst-case block reservation PLUS one child's worst case
        (guaranteed progress: anything admitted to prefill can eventually
        decode at least one child — its first fan-out child draws this
        standing reservation instead of competing for fresh memory).
        While the fan-out backlog is blocked on memory, no new prompts
        are admitted (their blocks belong to the backlog head).

        With the radix prefix cache, the prompt is first matched against
        published full blocks: matched blocks are adopted (increfed)
        straight into the request's table, its reservation shrinks by the
        match, and prefill starts at ``pos = matched_len`` — the hit path
        never recomputes the shared prefix. The final prompt token is
        always recomputed (the probe needs its logits/hidden), so a
        fully-matched prompt drops its last matched block."""
        admitted = 0
        B = self.pool.block_size
        self._prefill_blocked = False
        while (self.queue and not self._fanout_blocked
               and len(self._pref) < self.prefill_slots
               and self.pool.n_free_slots > 0
               and self._window_used() < self.prefill_window):
            self._reorder_queue_by_prefix()
            r = self.queue[0]
            radix = self._radix_of(r.model_id)
            sp = r.prompt_len
            matched: List[int] = []
            if radix is not None:
                matched = radix.match(r.prompt)
                while len(matched) * B > sp - 1:
                    radix.unmatch([matched.pop()])
            m = len(matched)
            # adopted by the owner NOW: a raise below (eviction,
            # overdraft) then leaves the matched refs owned, not orphaned
            r.table = matched
            need = self.pool.blocks_for(sp) - m
            # plan-deferrable requests (BestOfK with no budget and no
            # budget_fn — parked until set_budget) take no child
            # reservation: they will not decode promptly, and pinning a
            # tail per deferred request would let a deep batch-exact
            # backlog reserve the whole pool (the facade sizes one
            # block-row per request, not two). Procedures that always
            # plan immediately (Single, Route) MUST keep the standing
            # reservation — the procedure, not the budget fields, knows
            # whether it can park. Phase prefills (already planned)
            # reserve for their group's first child.
            if not r.planned and r.procedure.may_defer(r, self):
                child_need = 0
            elif r.pending:
                # preemption resume: the first re-admitted child is
                # pending[0], so the standing reservation is sized to it
                # (not to a future phase's group)
                child_need = self._child_owned_blocks(
                    r, r.pending[0].max_new)
            elif r.planned and r.pending_phases:
                child_need = self._child_owned_blocks(
                    r, r.pending_phases[0].max_new)
            else:
                child_need = self._child_owned_blocks(r)
            if not self._can_reserve_or_evict(need + child_need):
                self._release_prompt_table(r)   # returns the matched refs
                self._prefill_blocked = True    # preemption-addressable
                break
            self.queue.popleft()
            if r.admit_t is None:
                r.admit_t = time.perf_counter()
                self.metrics.record_queue_wait(r.admit_t - r.submit_t)
            self.pool.reserve(need + child_need)
            r.reserved = child_need
            slot = self.pool.alloc_slot()
            self.pool.reset_slot_state(slot)    # purge previous occupant
            r.prefix_len = m * B
            if m:
                self.metrics.record_prefix_hit(m * B)
            r.state = RequestState.PREFILLING
            r.prefill_pos = m * B
            self._pref[slot] = r
            self._tok[slot] = int(r.prompt[m * B])
            self._pos[slot] = m * B
            admitted += 1
        if (self.queue and not self._fanout_blocked
                and len(self._pref) < self.prefill_slots
                and self._window_used() < self.prefill_window
                and self.pool.n_free_slots == 0):
            # queue starved on *slots* (not the prefill-slot cap or the
            # stash window): evicting a resident would unblock it
            self._prefill_blocked = True
        return admitted

    def _reorder_queue_by_prefix(self) -> None:
        """Radix-aware admission ordering: peek at the first
        `admission_lookahead` queued requests and pull the longest
        published-prefix hit to the front. A hit's prefill both starts
        later-arriving work sooner (skipped tokens) and keeps its shared
        blocks hot, so admitting it before a cold miss strictly reduces
        total prefill compute without starving the miss: the lookahead is
        bounded, FIFO order breaks ties (including the all-miss case, a
        no-op), and `match_len` is a pure peek — no refcounts taken, no
        LRU clocks touched, so the scan itself cannot perturb eviction."""
        L = self.admission_lookahead
        if not self._radices or L <= 1 or len(self.queue) <= 1:
            return
        B = self.pool.block_size

        def eff_hit(r: Request) -> int:
            # mirror admission's trim: the final prompt token is always
            # recomputed, so a full match drops back below sp - 1
            radix = self._radix_of(r.model_id)
            if radix is None:
                return 0
            m = radix.match_len(r.prompt)
            return min(m, ((r.prompt_len - 1) // B) * B)

        cand = list(self.queue)[:L]
        hits = [eff_hit(r) for r in cand]
        j = max(range(len(cand)), key=lambda i: (hits[i], -i))
        if j > 0 and hits[j] > hits[0]:
            r = cand[j]
            del self.queue[j]
            self.queue.appendleft(r)
            self.metrics.record_reordered()

    # --------------------------------------------------------------- step
    def step(self) -> bool:
        """One scheduler tick: admit work, plan this tick's device
        programs, dispatch them, retire finished children. Returns True
        on progress."""
        if self.pool_kind == "paged":
            return self._step_paged()
        return self._step_slots()

    def _step_slots(self) -> bool:
        progressed = False
        if self.queue:
            # room is in cache rows: each admitted request stashes one
            room = self.prefill_window - self._window_used()
            if room > 0 and self.prefill_queued(room):
                progressed = True
        if self._try_fanout():
            progressed = True
        active_idx = [s for s, c in enumerate(self.slots) if c is not None]
        if not active_idx:
            return progressed
        active = np.zeros(self.pool.n_slots, bool)
        active[active_idx] = True
        tok, self.logits, self.pool.cache, self.pos, self.keys = \
            tick_programs.pool_tick(
                self.model, self.params, self.pool.cache, self.logits,
                self.pos, self.keys, jnp.asarray(active), self.temperature,
                temperature_zero=(self.temperature == 0.0))
        self.metrics.record_dispatch()
        self.metrics.record_tick(len(active_idx))
        tok_np = np.asarray(tok)                # analysis: allow(sync)
        self.metrics.record_sync()
        for s in active_idx:
            c = self.slots[s]
            t = int(tok_np[s])
            c.tokens.append(t)
            r = self.requests[c.request_id]
            if r.first_token_t is None:
                r.first_token_t = time.perf_counter()
                self.metrics.record_ttft(r.first_token_t - r.submit_t)
            if self.eos_id is not None and t == self.eos_id:
                c.eos = True
                self.metrics.record_eos(c.max_new - len(c.tokens))
            self._notify_emit(r, c)
            if c.done():
                self.slots[s] = None
                self.pool.release(s)
                c.slot = None
                more = r.procedure.on_child_done(r, c, self)
                if more:
                    raise ValueError("the slot pool cannot schedule "
                                     "procedure escalations")
                if r.all_children_done():
                    self._finalize(r)
        return True

    def _step_paged(self) -> bool:
        """One paged tick: admission (fan-out first, so decode children
        reclaim freed slots before new prompts), then the unified
        pipeline — plan the tick's device programs, dispatch each, and
        hand its host buffers to the retirement layer."""
        progressed = bool(self._try_fanout_paged())
        traffic = self.traffic
        preempt = traffic is not None and traffic.cfg.preempt
        if (preempt and self._fanout_blocked and self.fanout
                and self._preempt_for(self.fanout[0])):
            # freed blocks belong to the backlog head: retry immediately
            progressed = bool(self._try_fanout_paged()) or True
        progressed = bool(self._admit_prefill_paged()) or progressed
        if (preempt and self._prefill_blocked and self.queue
                and self._preempt_for(self.queue[0])):
            progressed = bool(self._admit_prefill_paged()) or True
        plan = plan_tick(self)
        if not plan.programs:
            return progressed
        if len(self.models) > 1:
            self.metrics.record_live(plan.n_live)
        for pp in plan.programs:
            if pp.kind == "horizon":
                self.retire.retire_horizon(
                    pp, tick_programs.dispatch_horizon(self, pp))
            elif pp.kind == "mixed":
                self.retire.retire_mixed(
                    pp, *tick_programs.dispatch_mixed(self, pp))
            elif pp.kind == "chunk":
                self.retire.retire_chunk(
                    pp, *tick_programs.dispatch_chunk(self, pp))
            else:
                if pp.fallback:
                    self.metrics.record_fallback(model=pp.model_id)
                self.retire.retire_token(
                    pp, *tick_programs.dispatch_token(self, pp))
        return True

    # ---------------------------------------------------------------- run
    @property
    def n_inflight(self) -> int:
        return sum(c is not None for c in self.slots)

    def pending(self) -> bool:
        prefilling = self.pool_kind == "paged" and bool(self._pref)
        return bool(self.queue or self.fanout or self.n_inflight
                    or prefilling)

    def drain(self) -> None:
        """Run until every runnable request is DONE. Requests still waiting
        on :meth:`set_budget` are left in PREFILL (they are not runnable
        and do not count against the prefill window). On completion the
        block ledger must balance exactly (see
        :meth:`assert_ledger_balanced`)."""
        while self.pending():
            if not self.step():
                raise RuntimeError(self._stall_report())
        self.assert_ledger_balanced()

    def result(self, request_id: int) -> Request:
        return self.requests[request_id]
