"""Continuous-batching decode runtime with in-flight adaptive fan-out.

Replaces the batch-synchronous serve loop (same-length prompts, full-batch
barriers, double prefill) with a fixed pool of decode slots that variable-
length, variable-budget requests stream through:

* **One prefill per request.** The probe prefill that feeds the difficulty
  predictor IS the generation prefill: its cache is replicated into the
  b_i child slots (`SlotKVPool.write_row`), so the paper's "free" probe
  stays free at serving time.
* **One jitted decode step per tick over the whole pool.** Shapes are
  static (n_slots, max_len), so the runtime compiles exactly once no
  matter how budgets/prompt lengths mix — the batch engine re-jits for
  every distinct fan-out shape.
* **Immediate slot reclamation.** A child that finishes frees its slot at
  the end of the tick; queued fan-out backfills it on the next tick, so
  saved budget becomes saved wall-clock.

Sampling uses per-child RNG streams — ``fold_in(fold_in(seed, request_id),
child_index)`` — so outputs are a function of (seed, request, child) only,
independent of slot placement and of what else is in flight. Greedy
decoding (temperature 0) is bitwise-reproducible against the batch engine
(see tests/test_runtime.py).
"""
from __future__ import annotations

import functools
import time
from collections import deque
from typing import Any, Callable, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.model_zoo import Model
from repro.serving.engine import prefill
from repro.serving.kv_pool import SlotKVPool
from repro.serving.metrics import ServingMetrics
from repro.serving.request import (ChildSeq, PrefillStash, Request,
                                   RequestState)


# cache/logits/pos/keys are donated: the caller rebinds all four every tick,
# and without donation XLA would copy the whole slot-pool KV cache per token.
@functools.partial(jax.jit, static_argnames=("model", "temperature_zero"),
                   donate_argnums=(2, 3, 4, 5))
def _pool_tick(model: Model, params, cache, logits, pos, keys, active,
               temperature, *, temperature_zero: bool):
    """One decode tick over every slot.

    Sample a token from each slot's current next-token logits, advance
    active slots' positions, and run one decode step over the whole pool.
    Inactive slots still flow through the model (their rows are unused and
    row-independent) but their pos/logits are frozen so admission state
    stays intact.
    """
    if temperature_zero:
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        new_keys = keys
    else:
        split = jax.vmap(jax.random.split)(keys)            # (N, 2, 2)
        new_keys = split[:, 0]
        tok = jax.vmap(jax.random.categorical)(
            split[:, 1], logits.astype(jnp.float32) / temperature
        ).astype(jnp.int32)
    new_pos = jnp.where(active, pos + 1, pos)
    new_logits, _, cache = model.decode_step(params, tok[:, None], cache,
                                             new_pos)
    logits = jnp.where(active[:, None], new_logits[:, 0], logits)
    return tok, logits, cache, new_pos, new_keys


@functools.partial(jax.jit, donate_argnums=(0, 1, 2))
def _admit_slot(logits, pos, keys, src_logits, src_row, slot, start_pos,
                child_key):
    """Point a freshly allocated slot at a prefilled sequence: install its
    next-token logits, start position, and RNG stream."""
    lrow = jax.lax.dynamic_index_in_dim(src_logits, src_row, axis=0,
                                        keepdims=False)
    logits = jax.lax.dynamic_update_index_in_dim(logits, lrow, slot, axis=0)
    pos = jax.lax.dynamic_update_index_in_dim(
        pos, jnp.asarray(start_pos, pos.dtype), slot, axis=0)
    keys = jax.lax.dynamic_update_index_in_dim(keys, child_key, slot, axis=0)
    return logits, pos, keys


class ContinuousBatchingRuntime:
    """Slot-pooled decode runtime; see module docstring.

    budget_fn(request, hidden) -> int resolves budgets at admission
    (streaming mode, e.g. ``AdaptivePolicy.allocate_streaming`` at a
    calibrated price). Leave it None and call :meth:`set_budget` for
    batch-exact allocation (the AdaptiveScheduler facade does this).
    reward_fn(query, rows) -> scores reranks a request's children when the
    last one finishes; None keeps child 0.
    """

    def __init__(self, model: Model, params, *, n_slots: int = 8,
                 max_len: int = 64, max_new: int = 16,
                 temperature: float = 0.0, seed: int = 0,
                 reward_fn: Optional[Callable] = None,
                 budget_fn: Optional[Callable] = None,
                 prefill_window: Optional[int] = None):
        self.model, self.params = model, params
        self.max_new = int(max_new)
        self.temperature = float(temperature)
        self.reward_fn, self.budget_fn = reward_fn, budget_fn
        # admission control: at most this many requests may hold a
        # device-resident prefill stash at once, bounding memory under a
        # deep backlog (stashes drop once the last child reaches a slot).
        # Applies to step()'s auto-prefill; an explicit prefill_queued()
        # call (the facade's batch-exact path) is unthrottled.
        if prefill_window is None:
            prefill_window = 2 * n_slots
        assert prefill_window >= 1
        self.prefill_window = prefill_window
        self._stashed = 0
        self.pool = SlotKVPool(model, n_slots, max_len)
        self.metrics = ServingMetrics(n_slots=n_slots)
        self._base_key = jax.random.PRNGKey(seed)
        V = model.lm.vocab_padded
        self.logits = jnp.zeros((n_slots, V), model.lm.dtype)
        self.pos = jnp.zeros((n_slots,), jnp.int32)
        self.keys = jnp.zeros((n_slots, 2), jnp.uint32)
        self.slots: List[Optional[ChildSeq]] = [None] * n_slots
        self.queue: deque = deque()       # Requests awaiting prefill
        self.fanout: deque = deque()      # Requests with un-slotted children
        self.requests: Dict[int, Request] = {}
        self._next_id = 0

    # ------------------------------------------------------------- submit
    def submit(self, prompt: np.ndarray, *, budget: Optional[int] = None,
               query: Any = None, max_new: Optional[int] = None) -> int:
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        mn = self.max_new if max_new is None else int(max_new)
        if len(prompt) + mn > self.pool.max_len:
            raise ValueError(
                f"prompt_len {len(prompt)} + max_new {mn} exceeds pool "
                f"max_len {self.pool.max_len}")
        r = Request(id=self._next_id, prompt=prompt, query=query,
                    budget=None if budget is None else int(budget),
                    max_new=mn)
        self._next_id += 1
        self.requests[r.id] = r
        self.queue.append(r)
        return r.id

    def submit_batch(self, prompts: np.ndarray,
                     budgets: Optional[Sequence[int]] = None,
                     queries: Optional[Sequence] = None) -> List[int]:
        n = len(prompts)
        return [self.submit(prompts[i],
                            budget=None if budgets is None else budgets[i],
                            query=None if queries is None else queries[i])
                for i in range(n)]

    # ------------------------------------------------------------ prefill
    def prefill_queued(self, limit: Optional[int] = None) -> int:
        """Prefill up to `limit` queued requests (all of them when None),
        batching same-length prompts into one jitted pass (the probe
        prefill — the only prefill a request ever gets; note it compiles
        per distinct (group, prompt_len) shape, unlike the decode tick).
        Resolves budgets via budget_fn when present. Returns the number
        of requests prefilled."""
        by_len: Dict[int, List[Request]] = {}
        taken = 0
        while self.queue and (limit is None or taken < limit):
            r = self.queue.popleft()
            by_len.setdefault(r.prompt_len, []).append(r)
            taken += 1
        for sp, reqs in by_len.items():
            P = jnp.asarray(np.stack([r.prompt for r in reqs]))
            logits, hidden, cache = prefill(self.model, self.params, P,
                                            self.pool.max_len)
            self.metrics.record_prefill(len(reqs) * sp)
            hidden_np = np.asarray(hidden, np.float32)
            for i, r in enumerate(reqs):
                r.hidden = hidden_np[i]
                r.stash = PrefillStash(cache=cache, logits=logits, row=i,
                                       start_pos=sp - 1)
                self._stashed += 1
                r.state = RequestState.PREFILL
                if r.budget is None and self.budget_fn is not None:
                    r.budget = int(self.budget_fn(r, r.hidden))
                if r.budget is not None:
                    self._spawn_children(r)
        return taken

    def set_budget(self, request_id: int, budget: int) -> None:
        """Resolve a deferred budget (batch-exact allocation path)."""
        r = self.requests[request_id]
        assert r.state == RequestState.PREFILL and r.stash is not None
        r.budget = int(budget)
        self._spawn_children(r)

    def _drop_stash(self, r: Request) -> None:
        if r.stash is not None:
            r.stash = None
            self._stashed -= 1

    def _spawn_children(self, r: Request) -> None:
        if r.budget <= 0:
            # paper: b_i = 0 answers with the default response
            self._drop_stash(r)
            self._finalize(r)
            return
        for j in range(r.budget):
            c = ChildSeq(request_id=r.id, index=j)
            r.children.append(c)
            r.pending.append(c)
        r.state = RequestState.DECODE
        self.fanout.append(r)

    # ------------------------------------------------------------- fanout
    def _try_fanout(self) -> int:
        """Admit pending children into free slots (FIFO over requests).
        Each admission replicates the request's probe-prefill cache row
        into the slot — the fan-out shares one prefill."""
        admitted = 0
        while self.pool.n_free and self.fanout:
            r = self.fanout[0]
            c = r.pending.pop(0)
            slot = self.pool.alloc()
            st = r.stash
            self.pool.write_row(st.cache, st.row, slot)
            ck = jax.random.fold_in(
                jax.random.fold_in(self._base_key, r.id), c.index)
            self.logits, self.pos, self.keys = _admit_slot(
                self.logits, self.pos, self.keys, st.logits, st.row, slot,
                st.start_pos, ck)
            c.slot = slot
            self.slots[slot] = c
            admitted += 1
            if not r.pending:
                self.fanout.popleft()
                self._drop_stash(r)     # pool rows now hold the only copies
        return admitted

    # --------------------------------------------------------------- step
    def step(self) -> bool:
        """One scheduler tick: prefill arrivals, backfill free slots, run
        one jitted decode step over the pool, retire finished children.
        Returns True if any progress was made."""
        progressed = False
        if self.queue:
            room = self.prefill_window - self._stashed
            if room > 0 and self.prefill_queued(room):
                progressed = True
        if self._try_fanout():
            progressed = True
        active_idx = [s for s, c in enumerate(self.slots) if c is not None]
        if not active_idx:
            return progressed
        active = np.zeros(self.pool.n_slots, bool)
        active[active_idx] = True
        tok, self.logits, self.pool.cache, self.pos, self.keys = _pool_tick(
            self.model, self.params, self.pool.cache, self.logits, self.pos,
            self.keys, jnp.asarray(active), self.temperature,
            temperature_zero=(self.temperature == 0.0))
        self.metrics.record_tick(len(active_idx))
        tok_np = np.asarray(tok)
        for s in active_idx:
            c = self.slots[s]
            c.tokens.append(int(tok_np[s]))
            r = self.requests[c.request_id]
            if c.done(r.max_new):
                self.slots[s] = None
                self.pool.release(s)
                c.slot = None
                if r.all_children_done():
                    self._finalize(r)
        return True

    def _finalize(self, r: Request) -> None:
        if r.children:
            r.state = RequestState.RERANK
            rows = [np.asarray(c.tokens, np.int32) for c in r.children]
            if self.reward_fn is not None:
                scores = np.asarray(self.reward_fn(r.query, rows), np.float64)
                j = int(scores.argmax())
                r.response, r.reward = rows[j], float(scores[j])
            else:
                r.response = rows[0]
        r.state = RequestState.DONE
        r.done_t = time.perf_counter()
        self.metrics.record_done(r.latency)

    # ---------------------------------------------------------------- run
    @property
    def n_inflight(self) -> int:
        return sum(c is not None for c in self.slots)

    def pending(self) -> bool:
        return bool(self.queue or self.fanout or self.n_inflight)

    def drain(self) -> None:
        """Run until every runnable request is DONE. Requests still waiting
        on :meth:`set_budget` are left in PREFILL (they are not runnable)."""
        while self.pending():
            if not self.step():
                waiting = [r.id for r in self.requests.values()
                           if r.state not in (RequestState.DONE,)]
                raise RuntimeError(f"runtime stalled; waiting={waiting}")

    def result(self, request_id: int) -> Request:
        return self.requests[request_id]
