"""Cost / latency metering for the serving runtime.

Counts exactly what the paper's reward-vs-compute plots need (prefill
tokens + generated tokens) plus the systems quantities the batch engine
cannot report: slot occupancy per tick and per-request wall latency.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np


def percentile(xs: List[float], q: float) -> float:
    return float(np.percentile(np.asarray(xs), q)) if xs else float("nan")


@dataclass
class ServingMetrics:
    n_slots: int = 0
    prefill_tokens: int = 0
    prefill_calls: int = 0
    decode_tokens: int = 0          # sampled tokens on *active* slots only
    ticks: int = 0
    active_sum: int = 0             # Σ active slots over ticks
    requests_done: int = 0
    latencies: List[float] = field(default_factory=list)
    start_t: Optional[float] = None
    end_t: Optional[float] = None

    def _touch(self) -> float:
        now = time.perf_counter()
        if self.start_t is None:
            self.start_t = now
        self.end_t = now
        return now

    def record_prefill(self, n_tokens: int) -> None:
        self._touch()
        self.prefill_tokens += int(n_tokens)
        self.prefill_calls += 1

    def record_tick(self, n_active: int) -> None:
        self._touch()
        self.ticks += 1
        self.active_sum += int(n_active)
        self.decode_tokens += int(n_active)

    def record_done(self, latency: Optional[float]) -> None:
        self._touch()
        self.requests_done += 1
        if latency is not None:
            self.latencies.append(float(latency))

    @property
    def occupancy(self) -> float:
        """Mean fraction of slots active per decode tick."""
        if self.ticks == 0 or self.n_slots == 0:
            return 0.0
        return self.active_sum / (self.ticks * self.n_slots)

    @property
    def wall(self) -> float:
        if self.start_t is None or self.end_t is None:
            return 0.0
        return self.end_t - self.start_t

    @property
    def tokens_per_sec(self) -> float:
        return self.decode_tokens / self.wall if self.wall > 0 else 0.0

    def summary(self) -> Dict[str, float]:
        return {
            "prefill_tokens": self.prefill_tokens,
            "prefill_calls": self.prefill_calls,
            "decode_tokens": self.decode_tokens,
            "total_tokens": self.prefill_tokens + self.decode_tokens,
            "ticks": self.ticks,
            "occupancy": self.occupancy,
            "requests_done": self.requests_done,
            "wall_s": self.wall,
            "tokens_per_sec": self.tokens_per_sec,
            "latency_p50_s": percentile(self.latencies, 50),
            "latency_p95_s": percentile(self.latencies, 95),
        }
