"""Cost / latency metering for the serving runtime.

Counts exactly what the paper's reward-vs-compute plots need (prefill
tokens + generated tokens) plus the systems quantities the batch engine
cannot report: slot occupancy per tick and per-request wall latency.

With a multi-model registry (weak/strong routing), every token, dispatch,
and sync is additionally attributed to the model that ran it
(``per_model``): routing benchmarks report the weak-vs-strong compute
split instead of one aggregate — previously a routed run's cost breakdown
was unrecoverable from the metrics.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, Optional

import numpy as np


def percentile(xs, q: float) -> float:
    return float(np.percentile(np.asarray(xs), q)) if len(xs) else float("nan")


class Series:
    """Append-only metric series with numpy-side accumulation.

    Retirement bookkeeping appends one value per retired request. The
    old ``List[float]`` + ``append(float(x))`` pattern forces a blocking
    device->host transfer *per value* whenever the value is a device
    scalar (e.g. plucked from a batched latency buffer) — exactly the
    per-scalar pull the host-sync auditor (`repro.analysis.syncs`)
    flags. Here host numbers land directly in a growable numpy buffer,
    while device values are parked in a pending list and converted in
    ONE batched transfer at the next read (len / iter / asarray), so
    record paths never touch the device one scalar at a time.
    """
    __slots__ = ("_buf", "_n", "_pending")

    def __init__(self, values=()):
        self._buf = np.empty(16, np.float64)
        self._n = 0
        self._pending: list = []
        for v in values:
            self.append(v)

    def append(self, value) -> None:
        host = isinstance(value, (int, float, np.integer, np.floating))
        if host and not self._pending:
            if self._n == len(self._buf):
                self._buf = np.concatenate(
                    [self._buf, np.empty(len(self._buf), np.float64)])
            self._buf[self._n] = value
            self._n += 1
        else:
            # device scalar: defer — flushed in one batched transfer.
            # (Host values queue behind any pending device value so the
            # series stays insertion-ordered.)
            self._pending.append(value)

    def _flush(self) -> None:
        if not self._pending:
            return
        import jax.numpy as jnp     # lazy: only if device values recorded
        vals = np.asarray(             # analysis: allow(sync)
            jnp.stack([jnp.asarray(v) for v in self._pending]), np.float64)
        self._pending.clear()
        for v in vals.ravel():
            self.append(float(v))

    def __len__(self) -> int:
        return self._n + len(self._pending)

    def __bool__(self) -> bool:
        return len(self) > 0

    def __iter__(self):
        self._flush()
        return iter(self._buf[:self._n])

    def __array__(self, dtype=None, copy=None):
        self._flush()
        out = self._buf[:self._n]
        return out.astype(dtype) if dtype is not None else np.array(out)

    def __repr__(self) -> str:
        self._flush()
        return f"Series({self._buf[:self._n].tolist()!r})"


@dataclass
class ModelMetrics:
    """Per-model compute attribution (one entry per registry model)."""
    prefill_tokens: int = 0
    decode_tokens: int = 0
    device_dispatches: int = 0
    host_syncs: int = 0
    children: int = 0               # children admitted on this model
    # KV memory gauges, registered by the runtime from the pool's own
    # cache shapes/dtypes (register_kv_store): bytes one physical block
    # pins in this model's store, the block size, and the latest
    # blocks-in-use reading (record_blocks fans it out to every model —
    # a block id indexes every registered store)
    kv_block_bytes: int = 0
    kv_block_size: int = 0
    kv_resident_blocks: int = 0

    @property
    def kv_bytes_per_token(self) -> float:
        return (self.kv_block_bytes / self.kv_block_size
                if self.kv_block_size else 0.0)

    @property
    def hbm_kv_resident_bytes(self) -> int:
        return self.kv_resident_blocks * self.kv_block_bytes

    def summary(self) -> Dict[str, float]:
        return {
            "prefill_tokens": self.prefill_tokens,
            "decode_tokens": self.decode_tokens,
            "total_tokens": self.prefill_tokens + self.decode_tokens,
            "device_dispatches": self.device_dispatches,
            "host_syncs": self.host_syncs,
            "children": self.children,
            "kv_bytes_per_token": self.kv_bytes_per_token,
            "hbm_kv_resident_bytes": self.hbm_kv_resident_bytes,
        }


@dataclass
class ServingMetrics:
    n_slots: int = 0
    prefill_tokens: int = 0
    prefill_calls: int = 0
    decode_tokens: int = 0          # sampled tokens on *active* slots only
    ticks: int = 0
    active_sum: int = 0             # Σ active slots over ticks
    requests_done: int = 0
    default_responses: int = 0      # b_i = 0 requests answered by default
    eos_terminated: int = 0         # children retired early on EOS
    eos_saved_tokens: int = 0       # decode ticks EOS termination avoided
    peak_children: int = 0          # max concurrent in-flight children
    peak_blocks: int = 0            # paged pool: max blocks in use
    prefix_hit_tokens: int = 0      # prefill tokens skipped via radix hits
    prefix_hits: int = 0            # requests admitted with a nonzero match
    prefix_reordered: int = 0       # admissions pulled forward for a hit
    radix_published_blocks: int = 0  # full blocks inserted into the tree
    radix_evicted_blocks: int = 0   # tree blocks evicted under pressure
    device_dispatches: int = 0      # jitted program launches (decode path)
    host_syncs: int = 0             # blocking device->host transfers
    horizon_ticks: int = 0          # fused multi-step scan dispatches
    horizon_fused_steps: int = 0    # decode steps executed inside horizons
    mixed_ticks: int = 0            # fused dispatches carrying prefill rows
    fallback_ticks: int = 0         # decode forced per-token by a prefill
    prefill_decode_overlap_tokens: int = 0  # prompt tokens fed inside scans
    fused_dispatches: int = 0       # horizon + mixed dispatches
    fused_rows_sum: int = 0         # Σ rows carried by fused dispatches
    per_model: Dict[str, ModelMetrics] = field(default_factory=dict)
    latencies: Series = field(default_factory=Series)
    queue_waits: Series = field(default_factory=Series)  # submit->admit
    ttfts: Series = field(default_factory=Series)       # submit->1st token
    preemptions: int = 0            # traffic: victims evicted + requeued
    preempted_blocks_freed: int = 0  # blocks released by preemption
    degraded_requests: int = 0      # budgets shaved by the load price
    degraded_budget_children: int = 0   # Σ children shaved off
    start_t: Optional[float] = None
    end_t: Optional[float] = None

    def _touch(self) -> float:
        now = time.perf_counter()
        if self.start_t is None:
            self.start_t = now
        self.end_t = now
        return now

    def model(self, model_id: str) -> ModelMetrics:
        m = self.per_model.get(model_id)
        if m is None:
            m = self.per_model[model_id] = ModelMetrics()
        return m

    def record_prefill(self, n_tokens: int, model: str = "default") -> None:
        self._touch()
        self.prefill_tokens += int(n_tokens)
        self.prefill_calls += 1
        self.model(model).prefill_tokens += int(n_tokens)

    def record_tick(self, n_active: int, n_sampled: Optional[int] = None,
                    model: str = "default") -> None:
        """n_active: occupied slots this tick (decode + chunked prefill).
        n_sampled: tokens actually sampled (decode slots); defaults to
        n_active for the slot pool, where every active slot samples.

        A *tick* is one compiled pool-wide program dispatch. With a
        multi-model registry each model group dispatches its own program
        per scheduler step (sequentially, each computing all n_slots
        rows), so a two-model step counts two ticks — and `occupancy`
        then reads useful rows per *computed* row, which is the honest
        device-utilization number for grouped dispatch (foreign slots
        really are wasted compute in that model's program)."""
        self._touch()
        self.ticks += 1
        self.active_sum += int(n_active)
        n_children = int(n_active if n_sampled is None else n_sampled)
        self.decode_tokens += n_children
        self.model(model).decode_tokens += n_children
        self.peak_children = max(self.peak_children, n_children)

    def record_first_token(self, n: int = 1, model: str = "default") -> None:
        """Paged mode samples a child's first token at admission (from the
        stashed probe logits) rather than inside a tick."""
        self._touch()
        self.decode_tokens += int(n)
        m = self.model(model)
        m.decode_tokens += int(n)
        m.children += int(n)

    def register_kv_store(self, model_id: str, block_bytes: int,
                          block_size: int) -> None:
        """Register a model's paged-store byte cost (from the pool's own
        cache shapes/dtypes, never a hardcoded itemsize) so the KV memory
        gauges can be attributed per model."""
        m = self.model(model_id)
        m.kv_block_bytes = int(block_bytes)
        m.kv_block_size = int(block_size)

    def register_kv_store_from(self, pool) -> None:
        """Register every model the pool hosts (idempotent; the runtime
        calls this at pool construction and again per add_model)."""
        for mid in pool.model_ids:
            self.register_kv_store(mid, pool.kv_block_bytes_for(mid),
                                   pool.block_size)

    def record_blocks(self, in_use: int) -> None:
        self.peak_blocks = max(self.peak_blocks, int(in_use))
        # the block ledger is shared: `in_use` blocks are resident in
        # every registered model's physical store
        for m in self.per_model.values():
            if m.kv_block_bytes:
                m.kv_resident_blocks = int(in_use)

    def record_live(self, n_children: int) -> None:
        """Total concurrent in-flight children across every model this
        tick (per-model record_tick calls only see their own group)."""
        self.peak_children = max(self.peak_children, int(n_children))

    def record_prefix_hit(self, n_tokens: int) -> None:
        """A request matched `n_tokens` of radix-cached prompt prefix at
        admission: that much prefill is skipped entirely (the saved-
        prefill counter the reward-vs-compute plots need)."""
        self._touch()
        self.prefix_hits += 1
        self.prefix_hit_tokens += int(n_tokens)

    def record_horizon(self, n_live: int, width: int, n_emitted: int,
                       model: str = "default") -> None:
        """One horizon-fused decode dispatch: `width` scan steps over
        `n_live` slots emitted `n_emitted` real tokens (frozen slots'
        masked steps are not tokens). Keeps `ticks`/occupancy comparable
        with the per-token path: a horizon counts as `width` ticks."""
        self._touch()
        self.ticks += width
        self.active_sum += int(n_emitted)
        self.decode_tokens += int(n_emitted)
        self.model(model).decode_tokens += int(n_emitted)
        self.peak_children = max(self.peak_children, int(n_live))
        self.horizon_ticks += 1
        self.horizon_fused_steps += int(width)
        self.fused_dispatches += 1
        self.fused_rows_sum += int(n_live)

    def record_mixed(self, n_dec: int, n_pref: int, width: int,
                     n_emitted: int, pref_tokens: int,
                     model: str = "default") -> None:
        """One mixed fused dispatch: `width` scan steps carried `n_dec`
        decode rows (emitting `n_emitted` sampled tokens) AND `n_pref`
        prefill rows (consuming `pref_tokens` queued prompt tokens) in a
        single program — the overlap the pre-refactor fallback threw
        away. Tick/occupancy accounting mirrors record_horizon, with the
        prefill rows' consumed tokens counted active (they occupied real
        scan rows); prefill-token totals are recorded separately by the
        caller via record_prefill."""
        self._touch()
        self.ticks += width
        self.active_sum += int(n_emitted) + int(pref_tokens)
        self.decode_tokens += int(n_emitted)
        self.model(model).decode_tokens += int(n_emitted)
        self.peak_children = max(self.peak_children, int(n_dec))
        self.horizon_fused_steps += int(width)
        self.mixed_ticks += 1
        self.prefill_decode_overlap_tokens += int(pref_tokens)
        self.fused_dispatches += 1
        self.fused_rows_sum += int(n_dec) + int(n_pref)

    def record_fallback(self, model: str = "default") -> None:
        """Decode dropped to per-token dispatch because a prefill was in
        flight and fusion was off — the tax the mixed program removes."""
        self.fallback_ticks += 1

    def record_dispatch(self, n: int = 1, model: str = "default") -> None:
        self.device_dispatches += int(n)
        self.model(model).device_dispatches += int(n)

    def record_sync(self, n: int = 1, model: str = "default") -> None:
        self.host_syncs += int(n)
        self.model(model).host_syncs += int(n)

    def record_reordered(self, n: int = 1) -> None:
        self.prefix_reordered += int(n)

    def record_radix(self, published: int = 0, evicted: int = 0) -> None:
        self.radix_published_blocks += int(published)
        self.radix_evicted_blocks += int(evicted)

    def record_eos(self, saved_tokens: int) -> None:
        self.eos_terminated += 1
        self.eos_saved_tokens += max(0, int(saved_tokens))

    def record_default(self) -> None:
        self._touch()
        self.default_responses += 1

    def record_queue_wait(self, wait: float) -> None:
        """Seconds from submit() to the admission pop that starts the
        request's first prefill (requeues do not re-stamp). Appends into
        the numpy-side Series: a device-scalar wait is deferred and
        batch-converted at read time, never pulled here."""
        self._touch()
        self.queue_waits.append(wait)

    def record_ttft(self, ttft: float) -> None:
        """Seconds from submit() to the request's first sampled token."""
        self._touch()
        self.ttfts.append(ttft)

    def record_preemption(self, blocks_freed: int = 0) -> None:
        self._touch()
        self.preemptions += 1
        self.preempted_blocks_freed += max(0, int(blocks_freed))

    def record_degraded(self, children_shaved: int) -> None:
        self._touch()
        self.degraded_requests += 1
        self.degraded_budget_children += max(0, int(children_shaved))

    def record_done(self, latency: Optional[float]) -> None:
        self._touch()
        self.requests_done += 1
        if latency is not None:
            self.latencies.append(latency)

    @property
    def occupancy(self) -> float:
        """Mean fraction of slots active per decode tick."""
        if self.ticks == 0 or self.n_slots == 0:
            return 0.0
        return self.active_sum / (self.ticks * self.n_slots)

    @property
    def wall(self) -> float:
        if self.start_t is None or self.end_t is None:
            return 0.0
        return self.end_t - self.start_t

    @property
    def tokens_per_sec(self) -> float:
        return self.decode_tokens / self.wall if self.wall > 0 else 0.0

    @property
    def syncs_per_token(self) -> float:
        """Blocking device->host transfers per generated token — the
        scheduler-overhead number the horizon fusion attacks (~1.0 on the
        per-token tick, ~1/H with horizon fusion)."""
        return self.host_syncs / max(self.decode_tokens, 1)

    @property
    def dispatches_per_token(self) -> float:
        return self.device_dispatches / max(self.decode_tokens, 1)

    def summary(self) -> Dict[str, float]:
        out = self._summary_base()
        # flatten per-model attribution only when more than one model ran
        # — single-model summaries stay exactly the historical key set
        if len(self.per_model) > 1:
            for mid, m in sorted(self.per_model.items()):
                for k, v in m.summary().items():
                    out[f"model/{mid}/{k}"] = v
        return out

    def _summary_base(self) -> Dict[str, float]:
        return {
            "prefill_tokens": self.prefill_tokens,
            "prefill_calls": self.prefill_calls,
            "decode_tokens": self.decode_tokens,
            "total_tokens": self.prefill_tokens + self.decode_tokens,
            "ticks": self.ticks,
            "occupancy": self.occupancy,
            "requests_done": self.requests_done,
            "default_responses": self.default_responses,
            "eos_terminated": self.eos_terminated,
            "eos_saved_tokens": self.eos_saved_tokens,
            "peak_children": self.peak_children,
            "peak_blocks": self.peak_blocks,
            "kv_bytes_per_token": sum(
                m.kv_bytes_per_token for m in self.per_model.values()),
            "hbm_kv_resident_bytes": sum(
                m.hbm_kv_resident_bytes for m in self.per_model.values()),
            "prefix_hit_tokens": self.prefix_hit_tokens,
            "prefix_hits": self.prefix_hits,
            "prefix_reordered": self.prefix_reordered,
            "radix_published_blocks": self.radix_published_blocks,
            "radix_evicted_blocks": self.radix_evicted_blocks,
            "device_dispatches": self.device_dispatches,
            "host_syncs": self.host_syncs,
            "syncs_per_token": self.syncs_per_token,
            "dispatches_per_token": self.dispatches_per_token,
            "horizon_ticks": self.horizon_ticks,
            "horizon_fused_steps": self.horizon_fused_steps,
            "mixed_ticks": self.mixed_ticks,
            "fallback_ticks": self.fallback_ticks,
            "fallback_fraction": (
                self.fallback_ticks
                / max(1, self.fallback_ticks + self.horizon_ticks
                      + self.mixed_ticks)),
            "prefill_decode_overlap_tokens":
                self.prefill_decode_overlap_tokens,
            "fused_row_occupancy": (
                self.fused_rows_sum
                / max(1, self.fused_dispatches * self.n_slots)
                if self.n_slots else 0.0),
            "wall_s": self.wall,
            "tokens_per_sec": self.tokens_per_sec,
            "latency_p50_s": percentile(self.latencies, 50),
            "latency_p95_s": percentile(self.latencies, 95),
            "queue_wait_p50_s": percentile(self.queue_waits, 50),
            "queue_wait_p95_s": percentile(self.queue_waits, 95),
            "ttft_p50_s": percentile(self.ttfts, 50),
            "ttft_p95_s": percentile(self.ttfts, 95),
            "preemptions": self.preemptions,
            "preempted_blocks_freed": self.preempted_blocks_freed,
            "degraded_requests": self.degraded_requests,
            "degraded_share": (self.degraded_requests
                               / max(self.requests_done, 1)),
        }
