"""Pure per-tick dispatch planner for the paged serving runtime.

First layer of the tick pipeline (plan -> dispatch -> retire):
:func:`plan_tick` reads the runtime's live state — resident decode
children, prefilling requests, horizon knobs, traffic pressure — and
partitions the slots per registry model into the device programs
(serving/tick_programs.py) this tick will launch, as a static-shape
:class:`TickPlan`. It mutates nothing: planning is a pure function of
runtime state, so tests can assert scheduling decisions (which program,
what horizon width) without dispatching anything.

Program selection per model, in order:

* recurrent-state stacks — the per-token interleave is the only exact
  path (state must advance token-by-token), so everything runs the
  ``token`` program regardless of horizon;
* decode + prefill both live, fusion on, H > 1 — ONE ``mixed`` program:
  the horizon scan carries the prefill rows alongside decode (the
  whole point of the unified pipeline; prefill consumes one prompt
  token per scan step, which is bitwise the chunk program's result);
* decode + prefill, fusion off (``fuse_prefill=False``) or H == 1 —
  the pre-refactor fallback: prefill gets its own ``chunk`` program
  (or rides the ``token`` interleave at chunk 1) and decode drops to
  per-token dispatch, flagged ``fallback`` so the tax is visible in
  `ServingMetrics.fallback_ticks`;
* decode only — ``horizon`` when H > 1, else ``token``;
* prefill only — ``chunk`` when chunked, else ``token``.

Traffic degradation is re-read HERE, per dispatch (not latched at
admission): a runtime that crosses into overload mid-request shrinks
the very next horizon lease, returning slots/blocks to admission
sooner. The degraded width is re-quantized down to a power of two so
the compiled-scan-variant bound (log2(horizon)+1 programs) holds.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Tuple


@dataclass(frozen=True)
class ProgramPlan:
    """One device program to launch this tick: which model, which
    program kind ("horizon" | "mixed" | "chunk" | "token"), the slots
    in each role, the fused width, and whether this is the pre-refactor
    fallback (decode forced per-token by a concurrent prefill)."""
    model_id: str
    kind: str
    decode_slots: Tuple[int, ...] = ()
    prefill_slots: Tuple[int, ...] = ()
    horizon: int = 1
    fallback: bool = False


@dataclass(frozen=True)
class TickPlan:
    """The tick's full dispatch schedule. Slot-disjoint by construction:
    every live slot appears in exactly one program."""
    programs: Tuple[ProgramPlan, ...] = ()
    n_live: int = 0          # live decode children across all models


#: every program kind a ProgramPlan can carry. The static auditor
#: (`repro.analysis.recompiles`) cross-checks this against the builder
#: registry in tick_programs.py — a kind the planner can emit without a
#: registered lru_cached builder is a finding.
PROGRAM_KINDS = ("token", "chunk", "horizon", "mixed")


def _pow2_floor(h: int) -> int:
    return 1 << (max(1, int(h)).bit_length() - 1)


def horizon_widths(horizon: int) -> Tuple[int, ...]:
    """Every width :func:`horizon_width` can emit for a configured max
    `horizon` — the pow2 quantization lattice {1, 2, 4, ..., floor}.
    This IS the static-arg key space of the scan-carrying builders: on a
    staggered stream min-remaining takes nearly every value in
    [1, horizon], and each distinct width is a fresh XLA compile, so the
    compiled-variant bound (log2(horizon)+1) only holds because dispatch
    quantizes through this lattice."""
    out, w = [], 1
    top = _pow2_floor(horizon)
    while w <= top:
        out.append(w)
        w *= 2
    return tuple(out)


def compile_cardinality(horizon: int, *, n_models: int = 1,
                        chunked: bool = True,
                        fuse_prefill: bool = True,
                        kv_quant: bool = False) -> Dict[str, int]:
    """Worst-case compile counts per builder kind for one runtime
    config — the key space reachable from :func:`plan_tick`'s TickPlan:
    kind x pow2 horizon width x model x cache layout. Widths > 1 are the
    scan programs (horizon / mixed); width 1 falls back to the token
    program, so the scan kinds each contribute len(widths) - 1 entries.
    `admit` (sampling the first token of an admitted prompt) touches no
    cache and is model- and layout-independent; the per-model cache
    plumbing programs (paged_pool's gather/scatter jits) key on the
    cache *structure*, at most one treedef per model per layout.
    `kv_quant=True` means the config space includes BOTH cache layouts
    (fp and int8+scales — e.g. an A/B capacity probe in one process):
    every cache-carrying kind doubles, because the quantized cache is a
    different pytree and a different traced program. A runtime instance
    only ever uses one layout, but the auditor bounds the process-wide
    worst case. The total is the number the recompile auditor bounds
    and the table the CLI prints."""
    widths = horizon_widths(horizon)
    scan_widths = len([w for w in widths if w > 1])
    kva = 2 if kv_quant else 1      # fp + int8 cache layouts
    per_kind = {
        "token": n_models * kva,
        "chunk": (n_models if chunked else 0) * kva,
        "horizon": n_models * scan_widths * kva,
        "mixed": (n_models * scan_widths * kva
                  if (chunked and fuse_prefill) else 0),
        "admit": 1,
        "pool": n_models * kva,
    }
    per_kind["total"] = sum(per_kind.values())
    return per_kind


def horizon_width(rt, decode_slots) -> int:
    """H = min(horizon, min remaining over the decode slots), quantized
    down to a power of two, then passed through the traffic
    controller's load-price degradation. min-remaining means no slot
    can outrun its budget inside the scan (the only mid-horizon freeze
    left is EOS) and a fused dispatch never computes steps every slot
    has already finished. The quantization bounds distinct compiled
    scan programs to log2(horizon)+1: on a staggered stream
    min-remaining takes nearly every value in [1, horizon], and
    compiling a fresh program per width mid-run cost more wall-clock
    than fusion saved (measured on the Poisson bench: paged dropped to
    0.7x the batch engine before quantization, 2x+ after)."""
    rem = min(rt.slots[s].max_new - len(rt.slots[s].tokens)
              for s in decode_slots)
    H = _pow2_floor(min(rt.horizon, rem))
    if rt.traffic is not None:
        # load shedding: shorter horizon leases return freed slots and
        # blocks to admission sooner under pressure. Re-read at EVERY
        # dispatch — overload arriving mid-request shrinks the next
        # lease, not just newly admitted ones.
        H = _pow2_floor(rt.traffic.effective_horizon(rt, H))
    return H


def plan_tick(rt) -> TickPlan:
    """Partition the runtime's live slots into this tick's device
    programs. Pure: reads runtime state, allocates nothing."""
    dec: Dict[str, List[int]] = {}
    for s, c in enumerate(rt.slots):
        if c is not None:
            dec.setdefault(c.model_id, []).append(s)
    pref: Dict[str, List[int]] = {}
    for s in sorted(rt._pref):
        pref.setdefault(rt._pref[s].model_id, []).append(s)
    chunked = rt.prefill_chunk > 1
    stateless = not rt.pool._has_state
    programs: List[ProgramPlan] = []
    for mid in sorted(set(dec) | set(pref)):
        d = tuple(dec.get(mid, ()))
        p = tuple(pref.get(mid, ()))
        if not stateless:
            # recurrent state advances token-by-token: the per-token
            # interleave (decode + prefill in one program) is exact
            programs.append(ProgramPlan(mid, "token", d, p))
            continue
        H = horizon_width(rt, d) if d and rt.horizon > 1 else 1
        if d and p:
            if rt.fuse_prefill and H > 1:
                programs.append(ProgramPlan(mid, "mixed", d, p, horizon=H))
            elif chunked:
                programs.append(ProgramPlan(mid, "chunk", (), p))
                programs.append(ProgramPlan(
                    mid, "token", d, (),
                    fallback=not rt.fuse_prefill and rt.horizon > 1))
            else:
                programs.append(ProgramPlan(
                    mid, "token", d, p,
                    fallback=not rt.fuse_prefill and rt.horizon > 1))
        elif d:
            if H > 1:
                programs.append(ProgramPlan(mid, "horizon", d, horizon=H))
            else:
                programs.append(ProgramPlan(mid, "token", d))
        else:
            if chunked:
                programs.append(ProgramPlan(mid, "chunk", (), p))
            else:
                programs.append(ProgramPlan(mid, "token", (), p))
    return TickPlan(tuple(programs),
                    n_live=sum(len(v) for v in dec.values()))
