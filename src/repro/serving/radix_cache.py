"""Radix-tree prefix cache over the paged KV pool.

Adaptive best-of-k traffic is prefix-heavy by construction: every probe
shares its task preamble / few-shot header with its siblings, and often
with most of the stream. The paged pool already shares a *single
request's* prompt blocks copy-on-write across its b_i fan-out children;
this module extends that sharing **across requests**: a trie keyed on
block-sized token chunks whose nodes own refcounted physical KV blocks,
so a new request whose prompt shares a full-block prefix with any live or
recently retired request reuses those blocks and skips their prefill
entirely (its chunked prefill starts at ``pos = matched_len``).

Sharing is sound because full prompt blocks are read-only for their whole
life (decode never writes below ``prompt_len``, and the partial boundary
block is never published) and because attention KV at a position depends
only on the token prefix up to it — two prompts with identical first
``k * block_size`` tokens have bitwise-identical KV for those positions.
Recurrent-state families (mamba, xLSTM) violate that premise at the
*runtime* level — skipping prefix tokens would skip their state updates —
so the runtime only attaches a cache to stateless (attention/MLA) stacks.

Ownership protocol (all refcounts live in :class:`PagedKVPool`):

* ``publish`` — after chunked prefill fills a whole block, the tree
  inserts a node for its token chunk and takes **one ref** of its own.
  If a node for that chunk already exists (a concurrent request published
  first), the existing node wins and the caller's block stays private —
  dedup for *future* requests happens at match time.
* ``match`` — walks the trie over the prompt's full-block chunks, increfs
  every matched block **on the caller's behalf** (so eviction can never
  free a block between match and use) and returns the block ids; the
  caller installs them in the request's block table, where the normal
  ``release_table`` decref applies.
* ``evict`` — when ``available_blocks`` runs low the runtime evicts LRU
  *leaves* whose only remaining ref is the tree's (shared interior nodes
  and blocks still referenced by live requests are never freed — evicting
  them would return no memory). Evicting a leaf can expose its parent as
  the next candidate, so eviction proceeds until enough blocks are freed
  or nothing evictable remains.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.serving.paged_pool import PagedKVPool

Chunk = Tuple[int, ...]


class RadixNode:
    """One full KV block: edge label `key` (block_size token ids) from its
    parent, physical `block` id (the tree holds one ref on it)."""

    __slots__ = ("key", "block", "children", "parent", "last_used")

    def __init__(self, key: Chunk, block: int,
                 parent: Optional["RadixNode"], last_used: int):
        self.key = key
        self.block = block
        self.children: Dict[Chunk, "RadixNode"] = {}
        self.parent = parent
        self.last_used = last_used

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"RadixNode(block={self.block}, n_children={len(self.children)})"


class RadixCache:
    """Trie of published full prompt blocks; see module docstring."""

    def __init__(self, pool: PagedKVPool):
        self.pool = pool
        self.block_size = pool.block_size
        self.root: Dict[Chunk, RadixNode] = {}      # virtual root's children
        self._clock = 0
        # live state only; lifetime hit/publish/evict accounting is
        # ServingMetrics' job (the runtime records trimmed, admission-
        # final numbers there — a second counter here would drift)
        self.held_blocks = 0        # == number of nodes (one block each)

    def __len__(self) -> int:
        return self.held_blocks

    def _tick(self) -> int:
        self._clock += 1
        return self._clock

    def _chunk(self, tokens: np.ndarray, i: int) -> Chunk:
        B = self.block_size
        return tuple(int(t) for t in tokens[i * B:(i + 1) * B])

    # -------------------------------------------------------------- match
    def match(self, tokens: np.ndarray) -> List[int]:
        """Longest full-block prefix of `tokens` present in the tree.

        Returns the matched physical block ids in prefix order, each
        **already increfed for the caller** (install them in a block table
        and release via the table as usual). Refreshes LRU clocks on the
        whole matched path."""
        now = self._tick()
        out: List[int] = []
        children = self.root
        for i in range(len(tokens) // self.block_size):
            node = children.get(self._chunk(tokens, i))
            if node is None:
                break
            node.last_used = now
            # refs accumulate in `out` until the caller installs them in
            # a request table; an assert here means the tree itself is
            # corrupt, at which point no unwind can help
            self.pool.incref(node.block)    # analysis: allow(ownership)
            out.append(node.block)
            children = node.children
        return out

    def match_len(self, tokens: np.ndarray) -> int:
        """Pure peek: length in *tokens* of the longest full-block prefix
        present in the tree, with no incref and no LRU refresh. Used by
        radix-aware admission ordering to rank queued prompts without
        perturbing eviction order or block ownership."""
        children = self.root
        n = 0
        for i in range(len(tokens) // self.block_size):
            node = children.get(self._chunk(tokens, i))
            if node is None:
                break
            n += 1
            children = node.children
        return n * self.block_size

    def unmatch(self, blocks: List[int]) -> None:
        """Return refs taken by :meth:`match` when the caller cannot use
        (all of) them — e.g. a fully-matched prompt must still recompute
        its final token, or admission failed after the match."""
        # a raw decref loop is correct HERE (and only here): match takes
        # exactly one ref per matched node, nodes are distinct, so there
        # is nothing for release_table's dedup to dedup
        for blk in blocks:                  # analysis: allow(ownership)
            self.pool.decref(blk)

    # ------------------------------------------------------------ publish
    def publish(self, tokens: np.ndarray, table: List[int],
                n_full: int) -> int:
        """Insert the first `n_full` (fully written) blocks of a prompt's
        table into the tree; returns how many nodes were newly created.
        Idempotent: chunks already present are LRU-refreshed, not replaced
        — their original block stays canonical and the caller's duplicate
        block remains privately owned (freed with the request)."""
        now = self._tick()
        children = self.root
        parent: Optional[RadixNode] = None
        created = 0
        for i in range(n_full):
            key = self._chunk(tokens, i)
            node = children.get(key)
            if node is None:
                node = RadixNode(key, table[i], parent, now)
                # the tree's own ref: owned by the node created above,
                # returned by _remove/clear — an owner kind the static
                # pass does not model
                self.pool.incref(table[i])  # analysis: allow(ownership)
                children[key] = node
                self.held_blocks += 1
                created += 1
            node.last_used = now
            parent = node
            children = node.children
        return created

    # ------------------------------------------------------------- evict
    def _evictable(self) -> List[RadixNode]:
        """Leaves whose block would actually return to the free list
        (refcount 1: the tree holds the only reference)."""
        out = []
        stack = list(self.root.values())
        while stack:
            n = stack.pop()
            if n.children:
                stack.extend(n.children.values())
            elif self.pool.refcount(n.block) == 1:
                out.append(n)
        return out

    def _remove(self, node: RadixNode) -> None:
        siblings = node.parent.children if node.parent else self.root
        del siblings[node.key]
        self.held_blocks -= 1
        self.pool.decref(node.block)

    def evict(self, n_blocks: int) -> int:
        """Free up to `n_blocks` blocks by evicting LRU leaves; returns
        how many were actually freed. Evicting a leaf can expose its
        parent, so candidates are re-scanned until the target is met or
        nothing evictable remains."""
        freed = 0
        while freed < n_blocks:
            cands = self._evictable()
            if not cands:
                break
            cands.sort(key=lambda n: n.last_used)
            for n in cands:
                if freed >= n_blocks:
                    break
                self._remove(n)
                freed += 1
        return freed

    def clear(self) -> int:
        """Drop every node (refs returned to the pool); returns how many
        blocks the tree was holding. Blocks still shared with live
        requests stay allocated until those requests release them."""
        dropped = 0
        stack = list(self.root.values())
        while stack:
            n = stack.pop()
            stack.extend(n.children.values())
            self.pool.decref(n.block)
            dropped += 1
        self.root = {}
        self.held_blocks = 0
        return dropped
