"""Traffic control policy: load-responsive pricing, SLO-aware
degradation, preemption victim selection, and tenant budget pricing.

The paper's allocation story is a price dual: a request deserves its
``i``-th child while the marginal value ``w/(i)`` clears the price
``lambda``. This module reuses exactly that machinery
(``core/allocator.py``'s :func:`allocate_at_price` /
:func:`price_for_budget`) for serving economics:

* **Load price.** ``price()`` maps block-pool pressure (resident +
  queued demand over capacity) to a scalar ``lambda >= 0`` — zero below
  ``target_load``, rising linearly above it.
* **Budget degradation.** Under load, a request's best-of-``b`` ask is
  shaved to the longest prefix of its harmonic marginal-value row
  ``weight / (j+1)`` that clears the price — high-priority requests
  (larger ``weight``) keep more children at the same price, exactly the
  paper's adaptive ``b_i`` but driven by load instead of predicted
  difficulty. Never below ``b_min``: degrade, don't starve.
* **Horizon degradation.** The fused decode horizon halves per unit of
  price down to ``min_horizon`` — shorter host-sync leases return freed
  blocks faster when the pool is tight (greedy tokens are horizon-
  invariant, so this is latency-shaping, not output-shaping).
* **Tenant budgets.** Each tenant's share of a sliding admission window
  is an ``allocate_at_price`` split of the window across tenant-weight
  harmonic rows — weighted max-min fairness from the same dual.
* **Victims.** Preemption picks the cheapest-to-kill resident:
  lowest priority first, then fewest generated tokens (least sunk
  decode work to regenerate), id as the deterministic tie-break.

Pure policy: no pool mutation happens here (the runtime's
``_preempt_request`` owns the ledger dance).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import numpy as np

from repro.core.allocator import allocate_at_price, price_for_budget
from repro.serving.request import Request, RequestState
from repro.serving.traffic.scheduler import PriorityClassQueues


@dataclasses.dataclass(frozen=True)
class TrafficConfig:
    """Knobs for the traffic subsystem (all optional; defaults give
    priority scheduling + preemption + degradation)."""

    weight_base: float = 4.0        # class weight = weight_base ** priority
    tenant_window: int = 32         # sliding admission window (requests)
    b_min: int = 1                  # degradation floor for best-of-b
    b_max: int = 32                 # longest harmonic row we price
    preempt: bool = True            # evict under block/slot pressure
    max_preemptions: int = 4        # per-request cap (no livelock)
    degrade: bool = True            # shave budgets/horizons under load
    target_load: float = 0.75       # pool load where the price lifts off
    price_gain: float = 8.0         # d(price)/d(load) above target
    min_horizon: int = 2            # floor for degraded fused horizon
    default_slo: Optional[float] = None  # seconds; per-request slo wins


class TrafficController:
    """Stateless-ish policy object the runtime consults; see module doc."""

    def __init__(self, cfg: TrafficConfig):
        self.cfg = cfg

    # ---------------------------------------------------------- scheduler
    def make_queue(self) -> PriorityClassQueues:
        return PriorityClassQueues(weight_base=self.cfg.weight_base,
                                   window=self.cfg.tenant_window,
                                   budget_fn=self.tenant_budgets)

    def tenant_budgets(self, tenant_weights: Dict[str, float],
                       window: int) -> Dict[str, int]:
        """Split a ``window``-admission budget across tenants by pricing
        their weight-scaled harmonic rows at the dual that spends exactly
        the window — weighted fair shares, never below 1 (every tenant
        always gets *some* service)."""
        tenants = sorted(tenant_weights)
        if not tenants:
            return {}
        if len(tenants) == 1:
            return {tenants[0]: window}
        rows = np.stack([tenant_weights[t] / np.arange(1, window + 1)
                         for t in tenants])
        price = price_for_budget(rows, window / len(tenants), b_min=1,
                                 iron=False)
        shares = allocate_at_price(rows, price, b_min=1, iron=False)
        return {t: int(s) for t, s in zip(tenants, shares)}

    # --------------------------------------------------------------- load
    def load(self, rt) -> float:
        """Pool pressure in [0, inf): blocks resident plus worst-case
        queued demand, over usable capacity."""
        pool = rt.pool
        capacity = max(1, pool.n_blocks - 1)        # minus the null block
        used = capacity - pool.available_blocks
        queued = sum(pool.blocks_for(r.prompt_len + r.max_new)
                     for r in rt.queue)
        return (used + queued) / capacity

    def price(self, rt) -> float:
        return max(0.0, self.cfg.price_gain * (self.load(rt)
                                               - self.cfg.target_load))

    # --------------------------------------------------------- degradation
    def degrade_budget(self, rt, r: Request, budget: int) -> int:
        """Shave a best-of-``budget`` ask to what clears the load price.
        Returns the (possibly smaller) budget; flags the request and
        records the shave when it bites."""
        if not self.cfg.degrade or budget <= self.cfg.b_min:
            return budget
        price = self.price(rt)
        if price <= 0.0:
            return budget
        width = min(budget, self.cfg.b_max)
        row = (self.cfg.weight_base ** r.priority) / np.arange(1, width + 1)
        b = int(allocate_at_price(row[None, :], price,
                                  b_min=self.cfg.b_min, iron=False)[0])
        b = min(budget, max(self.cfg.b_min, b))
        if b < budget:
            r.degraded = True
            rt.metrics.record_degraded(budget - b)
        return b

    def effective_horizon(self, rt, horizon: int) -> int:
        """Halve the fused horizon once per whole unit of price, floored
        at ``min_horizon`` — cheap load shedding with bitwise-identical
        greedy output. The tick planner (serving/plan.py:horizon_width)
        re-reads this at EVERY dispatch, never latching it at admission:
        a runtime crossing into overload mid-request shrinks the very
        next horizon lease of already-resident work."""
        if not self.cfg.degrade or horizon <= self.cfg.min_horizon:
            return horizon
        h = horizon >> min(int(self.price(rt)), 30)
        return max(self.cfg.min_horizon, h)

    # ----------------------------------------------------------- victims
    def choose_victim(self, rt, beneficiary: Request) -> Optional[Request]:
        """Cheapest resident request strictly below the beneficiary's
        priority, eligible for (another) preemption. Requests mid-fanout
        or spanning models are skipped — their ledger state is transient
        and not worth the complexity of unwinding."""
        best, best_key = None, None
        seen = set()
        for c in rt.slots:
            if c is None or c.request_id in seen:
                continue
            seen.add(c.request_id)
            r = rt.requests[c.request_id]
            if r is beneficiary or r.priority >= beneficiary.priority:
                continue
            if r.state is not RequestState.DECODE:
                continue
            if r.preemptions >= self.cfg.max_preemptions:
                continue
            live = [c for c in r.children if c.slot is not None]
            if not live:
                continue
            models = {c.model_id for c in live} | {c.model_id
                                                   for c in r.pending}
            if len(models) != 1:
                continue
            sunk = sum(len(ch.tokens) for ch in live)
            key = (r.priority, sunk, -r.id)
            if best_key is None or key < best_key:
                best, best_key = r, key
        return best
