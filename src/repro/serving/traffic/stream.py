"""Async token-by-token streaming over the runtime's step loop.

The runtime is deliberately synchronous — one thread, one ``step()``
tick. This surface adds streaming without changing that: a single
``serve()`` coroutine ticks the runtime and, after every tick, pumps
newly decoded tokens of each subscribed request into per-request asyncio
queues; ``tokens(rid)`` is an async generator a client awaits.

Under fused ticks a single runtime ``step()`` can retire a whole
horizon of tokens — and drain loops inside the runtime
(``prefill_queued``, ``drain``) can run many steps before control
returns here. So the streamer also subscribes to the runtime's emit
hooks (``runtime.add_emit_hook``): every token append anywhere in the
tick pipeline pushes straight through the watermark, giving clients
per-token progress regardless of who is driving the step loop. The
post-tick ``_pump`` remains as the completion path (DONE sentinel) and
as a safety net for runtimes without hooks.

Preemption-safe by construction: emission tracks a per-request
``emitted`` watermark over child 0's token list. A preempted request's
children restart from their per-child RNG streams
(``fold_in(fold_in(seed, rid), j)``), so the regenerated prefix is
bitwise identical to what was already streamed — the watermark simply
waits for the replay to catch back up (token lists shorter than the
watermark are a no-op), and the client never sees a duplicate or a
divergent token.
"""
from __future__ import annotations

import asyncio
from dataclasses import dataclass, field
from typing import Any, AsyncIterator, Dict, Optional

from repro.serving.request import RequestState

_DONE = object()


@dataclass
class _Session:
    queue: "asyncio.Queue" = field(default_factory=asyncio.Queue)
    emitted: int = 0
    finished: bool = False


class AsyncTokenStreamer:
    """Wraps a runtime; see module docstring.

    Usage::

        streamer = AsyncTokenStreamer(rt)
        rid = streamer.submit(prompt, max_new=16, priority=2)
        server = asyncio.ensure_future(streamer.serve())
        async for tok in streamer.tokens(rid):
            ...
        await server
    """

    def __init__(self, runtime):
        self.rt = runtime
        self._sessions: Dict[int, _Session] = {}
        hook = getattr(runtime, "add_emit_hook", None)
        if hook is not None:
            hook(self._on_emit)

    def _on_emit(self, r, child) -> None:
        """Runtime emit hook: push child 0's fresh tokens through the
        watermark the moment they are appended — inside fused-tick
        retirement, admission, or any internal drain loop. Replayed
        prefixes (preemption) land below the watermark and no-op."""
        if child.index != 0:
            return
        s = self._sessions.get(r.id)
        if s is None or s.finished:
            return
        if len(child.tokens) > s.emitted:
            for tok in child.tokens[s.emitted:]:
                s.queue.put_nowait(int(tok))
            s.emitted = len(child.tokens)

    def submit(self, prompt, **kwargs) -> int:
        rid = self.rt.submit(prompt, **kwargs)
        self._sessions[rid] = _Session()
        return rid

    # ------------------------------------------------------------- serving
    async def serve(self) -> None:
        """Tick until the runtime drains, pumping tokens between ticks
        and yielding to the event loop so consumers run interleaved."""
        while self.rt.pending():
            self.rt.step()
            self._pump()
            await asyncio.sleep(0)
        self._pump()

    def _pump(self) -> None:
        for rid, s in self._sessions.items():
            if s.finished:
                continue
            r = self.rt.requests.get(rid)
            if r is None:
                continue
            child = r.children[0] if r.children else None
            if child is not None and len(child.tokens) > s.emitted:
                for tok in child.tokens[s.emitted:]:
                    s.queue.put_nowait(int(tok))
                s.emitted = len(child.tokens)
            if r.state is RequestState.DONE:
                s.finished = True
                s.queue.put_nowait(_DONE)

    async def tokens(self, rid: int) -> AsyncIterator[int]:
        """Yield request ``rid``'s first-child tokens as they decode;
        terminates when the request completes."""
        s = self._sessions[rid]
        while True:
            item = await s.queue.get()
            if item is _DONE:
                return
            yield item

    def response(self, rid: int) -> Optional[Any]:
        r = self.rt.requests.get(rid)
        return None if r is None else r.response
