"""Priority scheduler: per-(tenant, priority-class) queues behind the
runtime's queue protocol.

The runtime's admission loop was written against ``collections.deque``
(``[0]`` peek, ``popleft``, ``append``, ``appendleft``, iteration,
``del q[j]``). :class:`PriorityClassQueues` keeps that exact protocol —
so the radix-aware admission lookahead (``_reorder_queue_by_prefix``)
keeps working unchanged as the tie-break — while replacing FIFO order
with a smooth weighted-round-robin pick over per-class queues:

* every request lands in the deque for its ``(tenant, priority)`` class;
* each pick, every eligible class earns credit equal to its weight
  (``weight_base ** priority``) and the class with the most credit wins
  (ties: higher priority, then tenant name) and pays back the total —
  classic smooth WRR, so service is proportional to weight, higher
  classes go first under contention, and no class starves;
* a tenant whose share of the last ``window`` admissions has exhausted
  its token budget (priced by the price-dual allocator — see
  ``TrafficController.tenant_budgets``) is skipped until the window
  rolls, unless every queued tenant is over budget (work-conserving);
* ``appendleft`` (used by the radix lookahead to pull a prefix-cache hit
  forward, and nothing else) bypasses the pick: a dedicated front slot
  is always served first, so peek-then-popleft stays coherent.

Everything is deterministic: no RNG, no wall clock — same submissions,
same pick order, every run.
"""
from __future__ import annotations

from collections import deque
from typing import Callable, Dict, List, Optional, Tuple

ClassKey = Tuple[str, int]                      # (tenant, priority)


class PriorityClassQueues:
    """Deque-compatible multi-class queue; see module docstring.

    ``budget_fn(tenant_weights, window) -> {tenant: admissions}`` prices
    each tenant's share of a ``window``-admission sliding window; None
    disables tenant budgets (every tenant unlimited).
    """

    def __init__(self, *, weight_base: float = 4.0, window: int = 32,
                 budget_fn: Optional[Callable[[Dict[str, float], int],
                                              Dict[str, int]]] = None):
        self.weight_base = float(weight_base)
        self._front: deque = deque()            # appendleft'd: always first
        self._classes: Dict[ClassKey, deque] = {}
        self._credit: Dict[ClassKey, float] = {}
        self._recent: deque = deque(maxlen=max(1, int(window)))
        self._budget_fn = budget_fn
        self._budgets: Dict[str, int] = {}
        self._tenant_w: Dict[str, float] = {}

    # ------------------------------------------------------------ weights
    def weight(self, key: ClassKey) -> float:
        return self.weight_base ** key[1]

    def _refresh_budgets(self) -> None:
        if self._budget_fn is None:
            self._budgets = {t: 1 << 30 for t in self._tenant_w}
        else:
            self._budgets = dict(
                self._budget_fn(dict(self._tenant_w), self._recent.maxlen))

    # ---------------------------------------------------- deque protocol
    def append(self, r) -> None:
        key = (str(getattr(r, "tenant", "default")),
               int(getattr(r, "priority", 1)))
        q = self._classes.get(key)
        if q is None:
            q = self._classes[key] = deque()
            self._credit.setdefault(key, 0.0)
        w = self.weight(key)
        if w > self._tenant_w.get(key[0], 0.0) or key[0] not in self._budgets:
            # a tenant's budget weight is the strongest class it has ever
            # queued (sticky, so budgets don't flap per request)
            self._tenant_w[key[0]] = max(w, self._tenant_w.get(key[0], 0.0))
            self._refresh_budgets()
        q.append(r)

    def appendleft(self, r) -> None:
        self._front.appendleft(r)

    def popleft(self):
        if self._front:
            return self._front.popleft()
        key = self._pick(self._classes, self._credit, self._recent)
        if key is None:
            raise IndexError("pop from an empty PriorityClassQueues")
        r = self._classes[key].popleft()
        self._recent.append(key[0])
        return r

    def remove(self, r) -> None:
        if r in self._front:
            self._front.remove(r)
            return
        for q in self._classes.values():
            if r in q:
                q.remove(r)
                return
        raise ValueError("request not queued")

    def __len__(self) -> int:
        return len(self._front) + sum(len(q) for q in self._classes.values())

    def __bool__(self) -> bool:
        return len(self) > 0

    def __iter__(self):
        return iter(self._order())

    def __getitem__(self, i: int):
        if i == 0 and self._front:              # fast path: peek the head
            return self._front[0]
        return self._order()[i]

    def __delitem__(self, i: int) -> None:
        self.remove(self._order()[i])

    # --------------------------------------------------------------- pick
    def _spent(self, recent, tenant: str) -> int:
        return sum(1 for t in recent if t == tenant)

    def _pick(self, classes: Dict[ClassKey, deque],
              credit: Dict[ClassKey, float], recent) -> Optional[ClassKey]:
        """One smooth-WRR pick over the nonempty classes (mutates the
        passed credit dict — callers simulate by passing copies)."""
        keys = [k for k, q in classes.items() if q]
        if not keys:
            return None
        budgets = self._budgets
        elig = [k for k in keys
                if self._spent(recent, k[0]) < budgets.get(k[0], 1 << 30)]
        if not elig:                            # work-conserving fallback
            elig = keys
        total = 0.0
        for k in elig:
            credit[k] += self.weight(k)
            total += self.weight(k)
        best = max(elig, key=lambda k: (credit[k], k[1], k[0]))
        credit[best] -= total
        return best

    def _order(self) -> List:
        """The exact sequence successive popleft() calls would return,
        computed by simulating the pick on copies of the scheduler state.
        The admission lookahead indexes/iterates through this — so what
        it peeks is what it gets."""
        out = list(self._front)
        classes = {k: deque(q) for k, q in self._classes.items() if q}
        credit = dict(self._credit)
        recent = deque(self._recent, maxlen=self._recent.maxlen)
        while True:
            key = self._pick(classes, credit, recent)
            if key is None:
                return out
            out.append(classes[key].popleft())
            recent.append(key[0])

    # ---------------------------------------------------------- introspect
    def class_depths(self) -> Dict[ClassKey, int]:
        return {k: len(q) for k, q in self._classes.items() if q}

    def tenant_budget(self, tenant: str) -> int:
        return self._budgets.get(tenant, 1 << 30)
