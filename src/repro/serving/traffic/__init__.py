"""Production traffic subsystem: priority scheduling, radix-cheap
preemption policy, SLO-aware degradation, and async streaming.

Layered on :class:`~repro.serving.runtime.ContinuousBatchingRuntime`
via its ``traffic=TrafficConfig(...)`` constructor knob — the runtime
owns the ledger mechanics (preempt/requeue/resume); this package owns
the policy (who goes first, who gets evicted, how much to degrade).
"""
from repro.serving.traffic.controller import TrafficConfig, TrafficController
from repro.serving.traffic.scheduler import PriorityClassQueues
from repro.serving.traffic.stream import AsyncTokenStreamer

__all__ = [
    "TrafficConfig",
    "TrafficController",
    "PriorityClassQueues",
    "AsyncTokenStreamer",
]
