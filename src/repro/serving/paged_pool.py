"""Block-granular paged KV pool (vLLM-style paging for the decode tick).

The slot pool reserves a full ``max_len`` cache row per live child, so the
adaptive policy's saved *budget* never becomes saved *memory*: a b_i=1
request and a b_i=8 request with short prompts pin the same worst-case
footprint. Here sequence caches are carved into physical **blocks** of
``block_size`` positions shared by everyone:

* sequence-cache leaves (attention KV, MLA latents — anything whose spec
  names the ``kv_seq`` axis) become ``(n_repeat, n_blocks, block_size,
  ...)`` stores; one physical block spans every layer's KV for its token
  range, so a single block table per sequence drives all layers;
* recurrent-state leaves (mamba conv/ssm, mLSTM/sLSTM states, whisper
  cross-KV) have no sequence axis and stay per-*slot* ``(n_repeat,
  n_slots, ...)``, exactly as in the slot pool;
* blocks are allocated on demand as a sequence's ``pos`` crosses a block
  boundary, refcounted, and freed at retirement — memory tracks actual
  sequence length, not the worst case;
* the probe prefill's full prompt blocks are shared **copy-on-write**
  across all b_i fan-out children: each child increfs the full blocks and
  privately copies only the partial boundary block it will write into, so
  fan-out costs O(1) extra memory instead of b_i full rows.

Physical block 0 is reserved as the **null block**: retired slots' table
rows and table padding point at it, so the uniform decode tick can keep
writing (garbage) somewhere harmless without per-slot control flow.

A worst-case **reservation** ledger prevents admission deadlock: a
sequence is only admitted if the blocks it could ever need are still
unclaimed, so on-demand growth can never strand a half-decoded child
waiting for memory that will not be freed. (Admission-*level* sizing
mistakes — a hand-shrunk pool whose queued prompt tables alone exhaust
memory with nothing in flight to free it — cannot corrupt state; they
surface as a descriptive ``drain()`` stall report, and ``submit`` rejects
any single request that could never fit at all.)
"""
from __future__ import annotations

import functools
import os
from typing import Any, Dict, List, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.serving.kv_pool import FreeList


def cdiv(a: int, b: int) -> int:
    return -(-a // b)


class PoolPrograms(NamedTuple):
    """Jitted cache-IO programs for one cache *structure* (the pytree of
    paged/state leaf flags). Built once per structure at module level and
    shared by every pool / model store with that structure — per-instance
    jit wrappers recompiled these per runtime (the PR-4 gotcha: bench
    probes had to warm the runtime itself, and a weak/strong model pair
    would have paid the copy_block compile twice)."""
    copy_block: Any
    read_state: Any
    write_state: Any


@functools.lru_cache(maxsize=None)
def _pool_programs(treedef, flag_leaves) -> PoolPrograms:
    flags = jax.tree.unflatten(treedef, list(flag_leaves))

    def _copy_block(cache, src, dst):
        def one(f, x):
            if not f:
                return x
            row = jax.lax.dynamic_index_in_dim(x, src, axis=1,
                                               keepdims=False)
            return jax.lax.dynamic_update_index_in_dim(x, row, dst, axis=1)
        return jax.tree.map(one, flags, cache)

    def _read_state(cache, slot):
        def one(f, x):
            if f:
                return jnp.zeros((0,), x.dtype)     # placeholder leaf
            return jax.lax.dynamic_index_in_dim(x, slot, axis=1,
                                                keepdims=True)
        return jax.tree.map(one, flags, cache)

    def _write_state(cache, state, slot):
        def one(f, x, s):
            if f:
                return x
            row = jax.lax.dynamic_index_in_dim(s, 0, axis=1, keepdims=False)
            return jax.lax.dynamic_update_index_in_dim(x, row, slot, axis=1)
        return jax.tree.map(one, flags, cache, state)

    return PoolPrograms(
        copy_block=jax.jit(_copy_block, donate_argnums=(0,)),
        # read_state is a pure gather: the caller keeps the cache
        read_state=jax.jit(_read_state),       # analysis: allow(donation)
        write_state=jax.jit(_write_state, donate_argnums=(0,)))


def pool_programs_for(model, kv_quant: Optional[str] = None) -> PoolPrograms:
    """The shared jitted cache-IO programs for `model`'s cache structure
    (hash key: the flag pytree's treedef + leaf values, both hashable)."""
    leaves, treedef = jax.tree.flatten(_paged_leaf_flags(model, kv_quant))
    return _pool_programs(treedef, tuple(bool(v) for v in leaves))


def _paged_leaf_flags(model, kv_quant: Optional[str] = None) -> Any:
    """Pytree of bools matching the cache structure: True where the leaf
    has a ``kv_seq`` axis (pageable), False for per-sequence state. Under
    ``kv_quant`` the int8 stores AND their per-block scale leaves carry
    ``kv_seq``, so block-granular COW/copy moves scales with their
    blocks."""
    specs = model.cache_specs(kv_quant=kv_quant)
    return jax.tree.map(lambda s: "kv_seq" in s, specs,
                        is_leaf=lambda t: isinstance(t, tuple))


def kv_block_bytes(model, block_size: int,
                   kv_quant: Optional[str] = None) -> int:
    """Device bytes of paged store per physical block for `model` — every
    layer's K/V (plus scale leaves under quantization) for `block_size`
    positions, n_repeat included. Computed from the cache structure's own
    shapes/dtypes so equal-memory comparisons (the capacity probe) never
    hardcode an itemsize."""
    flags = _paged_leaf_flags(model, kv_quant)
    shapes = jax.eval_shape(
        lambda: model.init_cache(1, block_size, kv_quant=kv_quant))
    return sum(int(np.prod(s.shape)) * s.dtype.itemsize
               for f, s in zip(jax.tree.leaves(flags), jax.tree.leaves(shapes))
               if f)


def resolve_kv_quant(kv_quant: Optional[str],
                     pool_kind: str) -> Optional[str]:
    """Resolve the runtime's opt-in KV quantization mode: an explicit
    argument wins, else the ``REPRO_KV_QUANT`` env var engages it.
    Quantized KV is a paged-pool *layout* (int8 block stores + per-block
    scale stores), so any other pool kind — including a sliding-window
    config's silent fallback to the slot pool — rejects it rather than
    silently serving fp."""
    if kv_quant is None:
        kv_quant = os.environ.get("REPRO_KV_QUANT") or None
    if kv_quant not in (None, "int8"):
        raise ValueError(f"unknown kv_quant mode: {kv_quant!r}")
    if kv_quant is not None and pool_kind != "paged":
        raise ValueError(
            "kv_quant is a paged-pool layout (int8 blocks + per-block "
            "scales); the slot pool (or a sliding-window fallback to it) "
            "has no block granularity to attach scales to")
    return kv_quant


def supports_paging(model, max_len: int) -> bool:
    """Paged mode is exact whenever the cache never wraps: full-context
    configs always, sliding-window configs only while max_len fits inside
    the window (the ring is then degenerate: slot == pos)."""
    cfg = model.cfg
    if cfg.long_context == "sliding_window" and max_len > cfg.sliding_window:
        return False
    return True


class PagedKVPool:
    """Paged cache store(s) + host-side block/slot lifetime management.

    Each registered model's ``cache`` is one pytree fed straight to that
    model's ``decode_step(..., block_tables=...)``: paged leaves
    ``(r, n_blocks, B, ...)``, state leaves ``(r, n_slots, ...)``. Slots
    carry the per-sequence scalar state (logits/pos/keys rows in the
    runtime, recurrent states here); blocks carry the KV. Both have free
    lists; blocks also refcount for copy-on-write prompt sharing.

    **Multi-model sharing:** :meth:`add_model` registers further models
    (a weak/strong routing pair) on the SAME block ledger — one free
    list, one refcount table, one reservation counter, one slot pool —
    each with its own physical KV store indexed by the shared block ids.
    Token capacity is therefore a single budget the models compete for:
    admission gating, COW sharing, radix caching, and the deadlock-free
    reservation discipline all apply across models unchanged. (Physical
    stores stay per-model because leaf shapes differ per architecture;
    the *ledger* is the scheduling-relevant shared resource.) Added
    models must be stateless (attention/MLA) — recurrent state rows are
    per-slot and single-model only.
    """

    def __init__(self, model, n_slots: int, max_len: int, *,
                 block_size: int = 16, n_blocks: Optional[int] = None,
                 kv_quant: Optional[str] = None):
        assert kv_quant in (None, "int8"), \
            f"unknown kv_quant mode: {kv_quant!r}"
        self.model = model
        self.kv_quant = kv_quant
        self.n_slots = int(n_slots)
        self.max_len = int(max_len)
        self.block_size = int(block_size)
        self.blocks_per_seq = cdiv(self.max_len, self.block_size)  # T
        if n_blocks is None:
            # worst case for a full pool of children, + the null block
            n_blocks = self.n_slots * self.blocks_per_seq + 1
        assert n_blocks >= 2, "need at least the null block and one real one"
        self.n_blocks = int(n_blocks)

        # block 0 = reserved null block (never allocated)
        self._free_blocks = FreeList(range(1, self.n_blocks), "block")
        self._ref = [0] * self.n_blocks
        self._reserved = 0              # worst-case future allocations
        self.block_alloc_count = 0      # lifetime allocations (reuse metric)

        self._free_slots = FreeList(range(self.n_slots), "slot")

        self.caches: Dict[str, Any] = {}
        self._models: Dict[str, Any] = {}
        self._progs: Dict[str, PoolPrograms] = {}
        self._init_states: Dict[str, Any] = {}
        self._state_flags: Dict[str, bool] = {}
        self._register("default", model)

    def _register(self, model_id: str, model) -> None:
        if not supports_paging(model, self.max_len):
            raise ValueError(
                "paged KV needs a non-wrapping cache: max_len "
                f"{self.max_len} exceeds sliding window "
                f"{model.cfg.sliding_window}")
        flags = _paged_leaf_flags(model, self.kv_quant)
        # build under jit: XLA dead-code-eliminates the unselected half of
        # each init_cache call, so state leaves are never materialized at
        # batch=n_blocks (nor KV leaves at batch=n_slots) — without this,
        # a state-heavy (mamba/xLSTM) pool sized to just fit device memory
        # could OOM transiently during construction
        self.caches[model_id] = jax.jit(lambda: jax.tree.map(
            lambda f, p, s: p if f else s, flags,
            model.init_cache(self.n_blocks, self.block_size,
                             kv_quant=self.kv_quant),
            model.init_cache(self.n_slots, 1, kv_quant=self.kv_quant)))()
        self._models[model_id] = model
        self._progs[model_id] = pool_programs_for(model, self.kv_quant)
        has_state = any(not f for f in jax.tree.leaves(flags))
        self._state_flags[model_id] = has_state
        # pristine state rows (batch 1) for resetting a reused slot before
        # chunked prefill — init values matter (mLSTM's `m` starts at
        # -1e30, not zero), so they come from init_cache, not zeros_like
        if has_state:
            self._init_states[model_id] = jax.jit(lambda: jax.tree.map(
                lambda f, x: jnp.zeros((0,), x.dtype) if f else x,
                flags, model.init_cache(1, 1, kv_quant=self.kv_quant)))()
        else:
            self._init_states[model_id] = None

    def add_model(self, model_id: str, model) -> None:
        """Register an additional model on the shared block ledger (its
        own KV store, same block ids/slots/reservations)."""
        if model_id in self.caches:
            raise ValueError(f"model id {model_id!r} already registered")
        if self._has_state:
            raise ValueError("multi-model pools require stateless stacks: "
                             "the default model carries per-slot state")
        flags = _paged_leaf_flags(model, self.kv_quant)
        if any(not f for f in jax.tree.leaves(flags)):
            raise ValueError(
                f"model {model_id!r} carries recurrent state; only "
                "stateless (attention/MLA) stacks can share a pool")
        self._register(model_id, model)

    @property
    def model_ids(self) -> List[str]:
        return list(self.caches)

    # default-model views: the single-model runtime (and every pre-
    # procedure caller/test) reads and rebinds `pool.cache` directly
    @property
    def cache(self):
        return self.caches["default"]

    @cache.setter
    def cache(self, value) -> None:
        self.caches["default"] = value

    @property
    def _has_state(self) -> bool:
        return any(self._state_flags.values())

    @property
    def _init_state(self):
        return self._init_states["default"]

    # ------------------------------------------------------------- queries
    @property
    def n_free_blocks(self) -> int:
        return len(self._free_blocks)

    @property
    def n_free_slots(self) -> int:
        return len(self._free_slots)

    @property
    def blocks_in_use(self) -> int:
        return (self.n_blocks - 1) - self.n_free_blocks

    @property
    def available_blocks(self) -> int:
        """Free blocks not yet promised to anyone (admission headroom)."""
        return self.n_free_blocks - self._reserved

    @property
    def occupancy(self) -> float:
        return 1.0 - self.n_free_slots / self.n_slots

    def blocks_for(self, n_tokens: int) -> int:
        return cdiv(n_tokens, self.block_size)

    def kv_block_bytes_for(self, model_id: str = "default") -> int:
        """Device bytes one physical block pins in `model_id`'s store."""
        return kv_block_bytes(self._models[model_id], self.block_size,
                              self.kv_quant)

    def kv_bytes(self, model_id: Optional[str] = None) -> int:
        """Total device bytes of the paged block store(s): n_blocks ×
        per-block bytes, summed over registered models unless one is
        named. The honest equal-memory denominator for capacity
        comparisons across cache dtypes."""
        ids = [model_id] if model_id is not None else self.model_ids
        return self.n_blocks * sum(self.kv_block_bytes_for(m) for m in ids)

    # -------------------------------------------------------- reservations
    def can_reserve(self, k: int) -> bool:
        return self.n_free_blocks - self._reserved >= k

    def reserve(self, k: int) -> None:
        assert self.can_reserve(k)
        self._reserved += k

    def unreserve(self, k: int) -> None:
        assert 0 <= k <= self._reserved
        self._reserved -= k

    # ------------------------------------------------------- block lifetime
    def alloc_block(self, *, from_reservation: bool = True) -> int:
        """Claim the lowest free block (refcount 1). Reserved-draw by
        default: the caller pre-reserved this growth at admission."""
        blk = self._free_blocks.pop()
        if from_reservation:
            self.unreserve(1)
        self._ref[blk] = 1
        self.block_alloc_count += 1
        return blk

    def preallocate(self, table: List[int], end_pos: int, *,
                    from_reservation: bool = True) -> int:
        """Extend `table` in place with freshly allocated blocks until it
        covers every position below `end_pos`; returns how many blocks were
        appended. Reservation-backed like per-token growth (the caller
        pre-reserved this worst case at admission), so the horizon-fused
        decode path can claim a whole horizon's worth of blocks up front —
        the block table is then uploaded once per horizon instead of being
        rebuilt and re-transferred every token. Claiming early cannot
        deadlock anyone: the blocks come out of the owner's own standing
        reservation, not the open market."""
        need = self.blocks_for(end_pos) - len(table)
        for _ in range(need):
            table.append(self.alloc_block(from_reservation=from_reservation))
        return max(0, need)

    def dense_tables(self, tables) -> np.ndarray:
        """Pack per-slot block tables (``{slot: [block, ...]}``) into the
        ``(n_slots, blocks_per_seq)`` int32 operand every paged tick
        program takes. Absent slots (and the tail past each table) stay
        on the reserved null block 0 — dead rows compute harmless garbage
        there, which is what lets every dispatch run one static shape."""
        out = np.zeros((self.n_slots, self.blocks_per_seq), np.int32)
        for s, t in tables.items():
            out[s, :len(t)] = t
        return out

    def incref(self, blk: int) -> None:
        assert 0 < blk < self.n_blocks and self._ref[blk] > 0
        self._ref[blk] += 1

    def refcount(self, blk: int) -> int:
        return self._ref[blk]

    def decref(self, blk: int) -> None:
        if not (0 < blk < self.n_blocks) or self._ref[blk] <= 0:
            raise RuntimeError(
                f"double release / bad block id {blk} (ref="
                f"{self._ref[blk] if 0 <= blk < self.n_blocks else '?'})")
        self._ref[blk] -= 1
        if self._ref[blk] == 0:
            self._free_blocks.push(blk)

    def release_table(self, table: List[int]) -> None:
        """Release a sequence's block table: one decref per *distinct*
        block id. A table holds at most one reference per block no matter
        how it was assembled, so a repeated COW-shared id (or a defensive
        caller passing a padded view whose tail aliases an entry) must not
        decref twice — that silently corrupted the ledger by freeing a
        block other sequences still read. Entries pointing at the reserved
        null block are padding and are skipped; anything out of range or
        already free is a genuine caller bug and raises."""
        seen = set()
        for blk in table:
            if blk == 0:                # reserved null block: padding
                continue
            if not (0 < blk < self.n_blocks) or self._ref[blk] <= 0:
                raise RuntimeError(
                    f"release_table: invalid block id {blk} (ref="
                    f"{self._ref[blk] if 0 <= blk < self.n_blocks else '?'})")
            if blk in seen:
                continue
            seen.add(blk)
            self.decref(blk)

    def check_conservation(self) -> None:
        """Ledger invariant: every usable block is exactly one of free or
        in use (ref > 0), reservations never exceed the free supply, and
        the free heap agrees with the refcounts."""
        in_use = sum(1 for r in self._ref[1:] if r > 0)
        assert in_use == self.blocks_in_use, (in_use, self.blocks_in_use)
        assert self.n_free_blocks + in_use == self.n_blocks - 1, (
            self.n_free_blocks, in_use, self.n_blocks)
        assert 0 <= self._reserved <= self.n_free_blocks, (
            self._reserved, self.n_free_blocks)
        assert self.available_blocks + self._reserved + in_use \
            == self.n_blocks - 1

    # -------------------------------------------------------- slot lifetime
    def alloc_slot(self) -> int:
        return self._free_slots.pop()

    def release_slot(self, slot: int) -> None:
        self._free_slots.push(slot)

    # ------------------------------------------------------------- cache io
    def copy_block(self, src: int, dst: int,
                   model_id: str = "default") -> None:
        """COW: give a fan-out child its private copy of the partial
        boundary block it will write into (in the store of the model that
        prefilled — and will decode — that sequence)."""
        self.caches[model_id] = self._progs[model_id].copy_block(
            self.caches[model_id], src, dst)

    def snapshot_slot_state(self, slot: int,
                            model_id: str = "default") -> Any:
        """Recurrent-state rows of `slot` (empty placeholders for paged
        leaves). Saved at probe-prefill completion so fan-out children can
        start from the prompt's final state."""
        if not self._state_flags[model_id]:
            return None
        return self._progs[model_id].read_state(self.caches[model_id], slot)

    def restore_slot_state(self, state: Any, slot: int,
                           model_id: str = "default") -> None:
        if state is None:
            return
        self.caches[model_id] = self._progs[model_id].write_state(
            self.caches[model_id], state, slot)

    def reset_slot_state(self, slot: int,
                         model_id: str = "default") -> None:
        """Reinitialize a slot's recurrent-state rows before chunked
        prefill: the uniform tick keeps mutating freed slots' state rows
        with garbage, so a reused slot would otherwise leak the previous
        occupant's mamba/xLSTM state into the new prompt."""
        self.restore_slot_state(self._init_states[model_id], slot,
                                model_id)
