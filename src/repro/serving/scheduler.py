"""AdaptiveScheduler — the paper's allocation loop wired into serving.

Per batch of queries:
  1. prefill once            -> probe hidden states (free difficulty input)
  2. AdaptivePolicy.allocate -> per-query sample budgets b_i (Eq. 5 greedy)
  3. fan out Σ b_i decode slots (queries with b_i = 0 get the default
     response, per the paper)
  4. rerank with the reward fn; return the best response per query

Cost accounting (prefill tokens + generated tokens) is returned so the
benchmarks can plot reward-vs-compute exactly as the paper does.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, List, Optional, Sequence

import numpy as np

from repro.core.policy import AdaptivePolicy
from repro.serving.engine import ServingEngine


@dataclass
class ServeBatchResult:
    budgets: np.ndarray
    responses: List[Optional[np.ndarray]]    # token rows (or None: default)
    rewards: np.ndarray
    total_samples: int
    generated_tokens: int


class AdaptiveScheduler:
    def __init__(self, engine: ServingEngine, policy: AdaptivePolicy,
                 reward_fn: Callable, *, seed: int = 0):
        self.engine = engine
        self.policy = policy
        self.reward_fn = reward_fn    # (query, list_of_token_rows) -> scores
        self.seed = seed

    def serve_batch(self, queries: Sequence, prompts: np.ndarray,
                    avg_budget: float) -> ServeBatchResult:
        n = len(queries)
        hidden = self.engine.probe_features(prompts)
        budgets = self.policy.allocate(hidden, avg_budget)
        responses: List[Optional[np.ndarray]] = [None] * n
        rewards = np.zeros(n)
        total = int(budgets.sum())
        if total > 0:
            # fan out: each query with b_i>0 is replicated b_i times
            sel = np.repeat(np.arange(n), budgets)
            gen = self.engine.generate(prompts[sel], n_samples=1,
                                       seed=self.seed)
            offset = 0
            for i in range(n):
                b = int(budgets[i])
                if b == 0:
                    continue
                rows = gen.tokens[offset: offset + b]
                offset += b
                scores = np.asarray(self.reward_fn(queries[i], list(rows)))
                j = int(scores.argmax())
                responses[i] = rows[j]
                rewards[i] = scores[j]
        return ServeBatchResult(
            budgets=np.asarray(budgets), responses=responses,
            rewards=rewards, total_samples=total,
            generated_tokens=total * self.engine.max_new)
