"""AdaptiveScheduler — the paper's allocation loop wired into serving.

Per batch of queries:
  1. prefill once            -> probe hidden states (free difficulty input)
     AND the generation cache (no second prefill)
  2. AdaptivePolicy.allocate -> per-query sample budgets b_i (Eq. 5 greedy)
  3. fan out Σ b_i decode slots by replicating the prefill cache (queries
     with b_i = 0 get the default response, per the paper)
  4. rerank with the reward fn; return the best response per query

Two backends:

  backend="runtime"  (default) a thin synchronous facade over the
      continuous-batching ContinuousBatchingRuntime: children stream
      through a fixed slot pool, freed slots backfill immediately, and
      the whole batch runs under one compiled decode program regardless
      of the budget mix. Returns slot-occupancy/latency metrics.
      Internally this is the procedure API's BestOfK path — requests
      submit un-budgeted (the default BestOfK procedure parks them),
      and set_budget() re-plans each once the batch-exact allocation is
      known. New code should prefer submitting DecodeProcedure objects
      to the runtime directly (see serving/procedure.py and the
      migration table in docs/serving.md); this facade remains for the
      paper's batch-synchronous allocation protocol.

  backend="batch"    the legacy batch-synchronous path, patched to
      prefill ONCE (the old code probe-prefilled, threw the cache away,
      and engine.generate prefilled again — double-counting prefill cost
      in every benchmark).

Cost accounting (prefill tokens + generated tokens) is returned so the
benchmarks can plot reward-vs-compute exactly as the paper does.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Sequence

import numpy as np

from repro.core.policy import AdaptivePolicy
from repro.serving.engine import ServingEngine
from repro.serving.runtime import ContinuousBatchingRuntime


@dataclass
class ServeBatchResult:
    budgets: np.ndarray
    responses: List[Optional[np.ndarray]]    # token rows (b_i=0: empty row)
    rewards: np.ndarray
    total_samples: int
    generated_tokens: int
    prefill_tokens: int = 0
    metrics: Optional[Dict[str, float]] = None   # runtime backend only


class AdaptiveScheduler:
    def __init__(self, engine: ServingEngine, policy: AdaptivePolicy,
                 reward_fn: Callable, *, seed: int = 0,
                 backend: str = "runtime", n_slots: int = 8,
                 pool: str = "paged", block_size: int = 16,
                 prefix_cache: bool = True,
                 prefill_chunk: Optional[int] = None):
        assert backend in ("runtime", "batch")
        self.engine = engine
        self.policy = policy
        self.reward_fn = reward_fn    # (query, list_of_token_rows) -> scores
        self.seed = seed
        self.backend = backend
        self.n_slots = n_slots
        self.pool = pool
        self.block_size = block_size
        self.prefix_cache = prefix_cache      # radix cross-batch reuse
        self.prefill_chunk = prefill_chunk    # None: runtime default

    def serve_batch(self, queries: Sequence, prompts: np.ndarray,
                    avg_budget: float) -> ServeBatchResult:
        if self.backend == "runtime":
            return self._serve_runtime(queries, prompts, avg_budget)
        return self._serve_batch_sync(queries, prompts, avg_budget)

    # ----------------------------------------------------- runtime facade
    def _serve_runtime(self, queries, prompts, avg_budget) -> ServeBatchResult:
        n, sp = prompts.shape
        eng = self.engine
        max_len = sp + eng.max_new + 1
        # batch-exact allocation probes the whole batch before any budget
        # lands, so every request briefly holds its prompt blocks: size
        # the paged store for that plus a full pool of decode children
        from repro.serving.paged_pool import cdiv
        per_seq = cdiv(max_len, self.block_size)
        rt = ContinuousBatchingRuntime(
            eng.model, eng.params, n_slots=self.n_slots,
            max_len=max_len, max_new=eng.max_new,
            temperature=eng.temperature, seed=self.seed,
            reward_fn=self.reward_fn, pool=self.pool,
            block_size=self.block_size,
            n_blocks=(n + self.n_slots) * per_seq + 1,
            prefix_cache=self.prefix_cache,
            prefill_chunk=self.prefill_chunk)
        ids = rt.submit_batch(prompts, queries=list(queries))
        rt.prefill_queued()                       # the single probe prefill
        hidden = np.stack([rt.requests[i].hidden for i in ids])
        budgets = self.policy.allocate(hidden, avg_budget)
        for i, b in zip(ids, budgets):
            rt.set_budget(i, int(b))              # fan-out shares the prefill
        rt.drain()
        responses = [rt.requests[i].response for i in ids]
        rewards = np.asarray([rt.requests[i].reward for i in ids])
        total = int(np.asarray(budgets).sum())
        return ServeBatchResult(
            budgets=np.asarray(budgets), responses=responses,
            rewards=rewards, total_samples=total,
            generated_tokens=rt.metrics.decode_tokens,
            prefill_tokens=rt.metrics.prefill_tokens,
            metrics=rt.metrics.summary())

    # ------------------------------------------------- legacy batch path
    def _serve_batch_sync(self, queries, prompts, avg_budget
                          ) -> ServeBatchResult:
        n = len(queries)
        logits, hidden, cache, sp = self.engine.prefill_for_generate(prompts)
        budgets = self.policy.allocate(np.asarray(hidden, np.float32),
                                       avg_budget)
        # b_i = 0 answers with the documented default response (empty
        # token row, zero reward) — parity with the runtime backends
        responses: List[Optional[np.ndarray]] = [
            np.zeros((0,), np.int32)] * n
        rewards = np.zeros(n)
        total = int(budgets.sum())
        if total > 0:
            # fan out by gathering prefilled cache rows b_i times each
            sel = np.repeat(np.arange(n), budgets)
            rows_all = self.engine.generate_from_prefill(
                cache, logits, sel, sp, seed=self.seed)
            offset = 0
            for i in range(n):
                b = int(budgets[i])
                if b == 0:
                    continue
                rows = rows_all[offset: offset + b]
                offset += b
                scores = np.asarray(self.reward_fn(queries[i], list(rows)))
                j = int(scores.argmax())
                responses[i] = rows[j]
                rewards[i] = scores[j]
        return ServeBatchResult(
            budgets=np.asarray(budgets), responses=responses,
            rewards=rewards, total_samples=total,
            generated_tokens=total * self.engine.max_new,
            prefill_tokens=n * int(prompts.shape[1]))
