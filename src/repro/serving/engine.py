"""Serving engine: cache-based prefill + sampling decode.

Prefill is a `lax.scan` of the model's decode_step over prompt positions —
one jitted program that fills the real KV/state caches (so the decode path
is exercised end-to-end and prefill==forward equivalence is testable).
Generation continues the same scan with temperature sampling.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.model_zoo import Model


def _one_step(model: Model, params, token, cache, pos):
    logits, hidden, cache = model.decode_step(params, token, cache, pos)
    return logits[:, 0], hidden[:, 0], cache


@functools.partial(jax.jit, static_argnames=("model", "cache_len"))
def prefill(model: Model, params, prompts: jnp.ndarray, cache_len: int):
    """prompts (b, sp) -> (next_logits (b,V), last_hidden (b,d), cache).

    Scans decode_step over the prompt; the cache is left positioned at
    pos = sp - 1 (the next generated token writes slot sp).
    """
    b, sp = prompts.shape
    cache = model.init_cache(b, cache_len)

    def step(carry, t):
        cache = carry
        token = jax.lax.dynamic_slice_in_dim(prompts, t, 1, axis=1)
        pos = jnp.full((b,), t, jnp.int32)
        logits, hidden, cache = _one_step(model, params, token, cache, pos)
        return cache, (logits, hidden)

    cache, (all_logits, all_hidden) = jax.lax.scan(
        step, cache, jnp.arange(sp))
    return all_logits[-1], all_hidden[-1], cache


# the prefilled cache is deliberately NOT donated: best-of-k reuses the
# same prefill across all k continuations, so the caller must keep it
@functools.partial(jax.jit,
                   static_argnames=("model", "max_new", "temperature_zero"))
def generate_from_cache(model: Model, params, cache, first_logits,  # analysis: allow(donation)
                        start_pos: jnp.ndarray, key, *, max_new: int,
                        temperature: float = 1.0,
                        temperature_zero: bool = False):
    """Sample max_new tokens continuing from a prefilled cache."""

    def sample(logits, k):
        if temperature_zero:
            return jnp.argmax(logits, -1).astype(jnp.int32)
        return jax.random.categorical(k, logits / temperature, -1).astype(
            jnp.int32)

    def step(carry, i):
        cache, logits, key = carry
        key, sub = jax.random.split(key)
        tok = sample(logits, sub)
        pos = start_pos + 1 + i
        new_logits, _, cache = _one_step(model, params, tok[:, None], cache,
                                         pos.astype(jnp.int32))
        return (cache, new_logits, key), tok

    (_, _, _), toks = jax.lax.scan(step, (cache, first_logits, key),
                                   jnp.arange(max_new))
    return toks.swapaxes(0, 1)          # (b, max_new)


@dataclass
class GenerationResult:
    tokens: np.ndarray                   # (b, max_new)
    probe_hidden: np.ndarray             # (b, d) prefill last-token hidden


class ServingEngine:
    """Batched sampling over a fixed model; prompts must share a length."""

    def __init__(self, model: Model, params, *, max_new: int = 16,
                 temperature: float = 0.7):
        self.model = model
        self.params = params
        self.max_new = max_new
        self.temperature = temperature

    def generate(self, prompts: np.ndarray, *, n_samples: int = 1,
                 seed: int = 0, temperature: Optional[float] = None
                 ) -> GenerationResult:
        """prompts (b, sp); returns (b * n_samples, max_new) tokens,
        sample-major per query: row i*n_samples+j = sample j of query i."""
        logits, hidden, cache, sp = self.prefill_for_generate(prompts)
        sel = np.repeat(np.arange(prompts.shape[0]), n_samples)
        toks = self.generate_from_prefill(cache, logits, sel, sp, seed=seed,
                                          temperature=temperature)
        return GenerationResult(tokens=toks,
                                probe_hidden=np.asarray(hidden, np.float32))

    def prefill_for_generate(self, prompts: np.ndarray):
        """One prefill sized for generation: returns (next_logits (b,V),
        probe_hidden (b,d), cache, prompt_len). The hidden states feed the
        difficulty probe AND the cache feeds generation — callers that used
        probe_features + generate were prefilling twice."""
        b, sp = prompts.shape
        logits, hidden, cache = prefill(self.model, self.params,
                                        jnp.asarray(prompts),
                                        sp + self.max_new + 1)
        return logits, hidden, cache, sp

    def generate_from_prefill(self, cache, first_logits, sel: np.ndarray,
                              prompt_len: int, *, seed: int = 0,
                              temperature: Optional[float] = None
                              ) -> np.ndarray:
        """Fan out an existing prefill: row i of the output continues
        prefilled sequence sel[i] (cache rows are gathered, not re-run).
        With sel = repeat(arange(b), budgets) this is the adaptive
        best-of-k fan-out at the cost of a single prefill."""
        temp = self.temperature if temperature is None else temperature
        sel = jnp.asarray(sel, jnp.int32)
        cache = jax.tree.map(lambda x: jnp.take(x, sel, axis=1), cache)
        logits = jnp.take(first_logits, sel, axis=0)
        start = jnp.full((sel.shape[0],), prompt_len - 1, jnp.int32)
        toks = generate_from_cache(
            self.model, self.params, cache, logits, start,
            jax.random.PRNGKey(seed), max_new=self.max_new,
            temperature=temp, temperature_zero=(temp == 0.0))
        return np.asarray(toks)

    def probe_features(self, prompts: np.ndarray) -> np.ndarray:
        """Last-token hidden states only (the difficulty probe's input) —
        no decoding at all, matching the paper's 'free' predictor."""
        _, hidden, _ = prefill(self.model, self.params, jnp.asarray(prompts),
                               prompts.shape[1] + 1)
        return np.asarray(hidden, np.float32)
