"""Device tick programs for the serving runtime.

This is the middle layer of the tick pipeline (plan -> dispatch ->
retire, see serving/plan.py and serving/retire.py): every compiled
program a scheduler tick can launch lives here, hoisted out of the
runtime into module-level builders so programs are shared across
runtime instances and testable in isolation.

Paged programs are ``functools.lru_cache``d builders keyed on the model
(hashable) plus the static sampling/shape flags, returning ONE jitted
closure per key — the ``pool_programs_for`` idiom from paged_pool.py.
This is equivalent to the old module-level ``jax.jit(...,
static_argnames=("model", ...))`` functions (jit caches per static-arg
tuple either way) but makes the compilation key explicit and keeps
donation indices local to each builder.

The ``dispatch_*`` functions are the host half of a dispatch: they take
the runtime and one :class:`~repro.serving.plan.ProgramPlan`, build the
static-shape operands (allocating reservation-backed blocks where the
program's writes will land), launch the program, rebind the donated
cache/keys buffers, and return the host-visible results for the
retirement layer to consume. They mutate only device buffers and block
tables — token/EOS/stash accounting belongs to retirement.

The headline program is :func:`mixed_program`: a ``lax.scan`` horizon
that carries *prefill rows alongside decode rows*. Per-row ``roles``
masks extend the advance-mask machinery — a prefill row's next input
token comes from a prefetched ``(H, n_slots)`` fed-token buffer (its
queued prompt) instead of its sample, its RNG key never advances, and
the step its last prompt token lands its logits/hidden rows are
captured into carried probe buffers for the fan-out stash. Decode rows
run the exact pure-horizon transition, so their greedy tokens stay
bitwise identical whether or not a neighbor slot is prefilling — this
is what removes the old whole-pool per-token fallback whenever any
slot prefilled.
"""
from __future__ import annotations

import functools
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.model_zoo import Model


# ----------------------------------------------------------- slot pool
# cache/logits/pos/keys are donated: the caller rebinds all four every tick,
# and without donation XLA would copy the whole slot-pool KV cache per token.
@functools.partial(jax.jit, static_argnames=("model", "temperature_zero"),
                   donate_argnums=(2, 3, 4, 5))
def pool_tick(model: Model, params, cache, logits, pos, keys, active,
              temperature, *, temperature_zero: bool):
    """One slot-pool decode tick over every slot.

    Sample a token from each slot's current next-token logits, advance
    active slots' positions, and run one decode step over the whole pool.
    Inactive slots still flow through the model (their rows are unused and
    row-independent) but their pos/logits are frozen so admission state
    stays intact.
    """
    if temperature_zero:
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        new_keys = keys
    else:
        split = jax.vmap(jax.random.split)(keys)            # (N, 2, 2)
        new_keys = split[:, 0]
        tok = jax.vmap(jax.random.categorical)(
            split[:, 1], logits.astype(jnp.float32) / temperature
        ).astype(jnp.int32)
    new_pos = jnp.where(active, pos + 1, pos)
    new_logits, _, cache = model.decode_step(params, tok[:, None], cache,
                                             new_pos)
    logits = jnp.where(active[:, None], new_logits[:, 0], logits)
    return tok, logits, cache, new_pos, new_keys


@functools.partial(jax.jit, donate_argnums=(0, 1, 2))
def admit_slot(logits, pos, keys, src_logits, src_row, slot, start_pos,
               child_key):
    """Point a freshly allocated slot at a prefilled sequence: install its
    next-token logits, start position, and RNG stream."""
    lrow = jax.lax.dynamic_index_in_dim(src_logits, src_row, axis=0,
                                        keepdims=False)
    logits = jax.lax.dynamic_update_index_in_dim(logits, lrow, slot, axis=0)
    pos = jax.lax.dynamic_update_index_in_dim(
        pos, jnp.asarray(start_pos, pos.dtype), slot, axis=0)
    keys = jax.lax.dynamic_update_index_in_dim(keys, child_key, slot, axis=0)
    return logits, pos, keys


@functools.partial(jax.jit, static_argnames=("temperature_zero",))
def sample_first(logits, row, key, temperature, *, temperature_zero: bool):
    """Sample a fan-out child's first token from its request's stashed
    probe logits. Performs exactly the split/categorical sequence the
    slot-pool tick would, so per-child RNG streams are identical across
    pool backends. (The paged runtime admits through the vmapped
    admit_program, which is this program batched over children — kept as
    the single-child reference the tests compare against.)"""
    lrow = jax.lax.dynamic_index_in_dim(logits, row, axis=0, keepdims=False)
    if temperature_zero:
        return jnp.argmax(lrow).astype(jnp.int32), key
    split = jax.random.split(key)
    tok = jax.random.categorical(
        split[1], lrow.astype(jnp.float32) / temperature).astype(jnp.int32)
    return tok, split[0]


# ------------------------------------------------- paged program builders
#
# Cache-carrying builders key on `kv_quant` explicitly: the quantized
# cache is a different pytree (int8 stores + scale leaves), hence a
# different traced program, and the explicit static-arg key keeps the
# compile-cardinality accounting (`plan.compile_cardinality(kv_quant=)`)
# aligned with the lru_cache key space the recompile auditor bounds.
@functools.lru_cache(maxsize=None)
def token_program(model: Model, temperature_zero: bool, kv_quant=None):
    """One paged-pool tick: decode every slot's current token at its
    position through the block tables, then sample each slot's next token.

    The same program serves chunked prefill and decode: a prefilling slot's
    input token is the next *prompt* token (its sampled output is simply
    not used by the host), a decoding slot's input is its last sampled
    token. Dead slots point at the reserved null block and compute
    harmless garbage — no per-slot control flow, one compile total.

    `advance` flags the slots whose RNG streams this tick owns (this
    model's live decode children). Other slots still sample — their rows
    are unused garbage, vmapped counter-based threefry is element-wise so
    they cannot perturb the advancing rows — but their keys are frozen:
    with several models sharing the pool, another model's tick must never
    burn a live foreign child's stream.
    """
    @functools.partial(jax.jit, donate_argnums=(1, 5))   # cache, keys
    def run(params, cache, tables, tokens, pos, keys, advance, temperature):
        logits, hidden, cache = model.decode_step(params, tokens[:, None],
                                                  cache, pos,
                                                  block_tables=tables)
        lg = logits[:, 0]
        if temperature_zero:
            sampled = jnp.argmax(lg, axis=-1).astype(jnp.int32)
            new_keys = keys
        else:
            split = jax.vmap(jax.random.split)(keys)        # (N, 2, 2)
            new_keys = jnp.where(advance[:, None], split[:, 0], keys)
            sampled = jax.vmap(jax.random.categorical)(
                split[:, 1], lg.astype(jnp.float32) / temperature
            ).astype(jnp.int32)
        return sampled, lg, hidden[:, 0], cache, new_keys
    return run


@functools.lru_cache(maxsize=None)
def chunk_program(model: Model, kv_quant=None):
    """One varlen chunked-prefill program: every prefilling slot advances
    by up to C prompt tokens (its own `valid` count) in a single compiled
    step. Shapes are static — (prefill_slots, prefill_chunk) — so mixed
    prompt lengths, partial tail chunks, and idle prefill slots (valid 0,
    null tables) all run the same program; there is exactly one compile
    for the whole runtime, like the decode tick."""
    @functools.partial(jax.jit, donate_argnums=(1,))     # cache
    def run(params, cache, tables, tokens, pos, valid):
        logits, hidden, cache = model.decode_chunk(params, tokens, cache,
                                                   pos, valid,
                                                   block_tables=tables)
        return logits, hidden, cache
    return run


@functools.lru_cache(maxsize=None)
def admit_program(temperature_zero: bool):
    """Batched fan-out admission: derive every child's RNG stream
    (fold_in(fold_in(seed, request), child)), sample each first token
    from its request's stashed probe logits, and install the advanced
    keys into the pool rows — all children spawned this tick in ONE
    program, where the per-child path paid one jit dispatch for the
    fold_ins, one for the sample, and one `keys.at[slot].set` device op
    per child. The caller pads every argument to the pool width with
    out-of-range slot indices (scatter drops them), so exactly one
    program compiles regardless of how many children a tick admits.
    vmap of fold_in/split/categorical is element-wise (counter-based
    threefry), so per-child streams are bitwise the per-child
    program's."""
    @functools.partial(jax.jit, donate_argnums=(5,))     # keys
    def run(lrows, base_key, rids, idxs, slots, keys, temperature):
        lg = jnp.stack(lrows)                               # (m, V)
        ck = jax.vmap(lambda r, j: jax.random.fold_in(
            jax.random.fold_in(base_key, r), j))(rids, idxs)    # (m, 2)
        if temperature_zero:
            toks = jnp.argmax(lg, axis=-1).astype(jnp.int32)
            nk = ck
        else:
            split = jax.vmap(jax.random.split)(ck)          # (m, 2, 2)
            nk = split[:, 0]
            toks = jax.vmap(jax.random.categorical)(
                split[:, 1], lg.astype(jnp.float32) / temperature
            ).astype(jnp.int32)
        keys = keys.at[slots].set(nk)
        return toks, keys
    return run


@functools.lru_cache(maxsize=None)
def horizon_program(model: Model, H: int, temperature_zero: bool,
                    eos_id: Optional[int], kv_quant=None):
    """H decode steps fused into one compiled `lax.scan` program — the
    horizon tick. Per scan step this is exactly the token program's
    decode-then-sample sequence (greedy tokens are bitwise identical),
    but sampling, EOS detection, and budget exhaustion all stay on
    device: each slot carries a `remaining` counter, and a slot whose
    counter hits zero (EOS sampled, or max_new reached) is frozen mid-
    horizon — its token/pos stop advancing and its masked steps write
    garbage K/V at its frozen position, which lands in the finished
    child's private block and is never read. The host gets one
    (H, 2, n_slots) [token; alive] buffer per horizon — a single
    device->host sync where the per-token loop paid H.

    Block tables are scan-invariant: the caller pre-extends every live
    slot's table to cover the whole horizon (`PagedKVPool.preallocate`),
    so tables upload once per horizon. Unwritten preallocated blocks sit
    above each slot's current position and are masked by the `idx <= pos`
    validity rule, contributing exact zeros — values are unchanged.

    Slots outside this model's group (remaining = 0 at entry — dead, or
    live under ANOTHER registry model) never advance their keys: a
    member slot's stream evolves exactly as the per-token tick's, a
    foreign live child's stream is untouched by this model's horizon."""
    @functools.partial(jax.jit, donate_argnums=(1, 5))   # cache, keys
    def run(params, cache, tables, tok, pos, keys, remaining, temperature):
        member = remaining > 0              # this model's live slots

        def transition(lg, hid, tok, pos, aux, x):
            keys, remaining = aux
            if temperature_zero:
                sampled = jnp.argmax(lg, axis=-1).astype(jnp.int32)
                new_keys = keys
            else:
                split = jax.vmap(jax.random.split)(keys)    # (N, 2, 2)
                new_keys = jnp.where(member[:, None], split[:, 0], keys)
                sampled = jax.vmap(jax.random.categorical)(
                    split[:, 1], lg.astype(jnp.float32) / temperature
                ).astype(jnp.int32)
            alive = remaining > 0
            new_rem = jnp.maximum(remaining - 1, 0)
            if eos_id is not None:
                new_rem = jnp.where(sampled == eos_id, 0, new_rem)
            tok = jnp.where(alive, sampled, tok)
            pos = jnp.where(alive, pos + 1, pos)
            emit = jnp.stack([sampled, alive.astype(jnp.int32)])  # (2, N)
            return tok, pos, (new_keys, new_rem), emit

        tok, pos, cache, (keys, remaining), emits = model.decode_horizon(
            params, tok, cache, pos, (keys, remaining), H, transition,
            block_tables=tables)
        return emits, cache, keys
    return run


@functools.lru_cache(maxsize=None)
def mixed_program(model: Model, H: int, temperature_zero: bool,
                  eos_id: Optional[int], kv_quant=None):
    """The fused mixed tick: one `lax.scan` horizon carrying prefill rows
    alongside decode rows, so chunked prefill and H-step decode run in
    ONE dispatch with one host sync — an arriving request no longer
    drops every resident decode to per-token dispatch.

    Per-row ``roles`` (True = prefill) extend the horizon program's
    member mask. Decode rows run its exact transition — sample, advance,
    freeze on EOS/budget — so their greedy tokens are bitwise identical
    to a pure-decode horizon (sampling is element-wise counter-based
    threefry; the extra rows cannot perturb it). Prefill rows:

    * feed the next *prompt* token from the prefetched ``fed`` (H, N)
      buffer instead of their sample (their sampled output is garbage
      the host drops, exactly as in the per-token interleave);
    * never advance their RNG key (``member = remaining > 0 & ~roles``);
    * ignore EOS (a prompt may legitimately contain the EOS token);
    * count down ``remaining`` = prompt tokens left to compute, and the
      step the LAST prompt token lands, capture that step's logits and
      hidden rows into carried probe buffers — the fan-out stash and the
      difficulty probe, identical values to what the chunk program's
      final row would have produced (same positions, same cache).

    A prefill row that finishes mid-horizon freezes like an EOS'd decode
    row; its masked steps write garbage K/V at position ``prompt_len``,
    which lands either in the row's partial boundary block — overwritten
    by each fan-out child's first decode write before any read, and
    never published (the radix tree takes full blocks only) — or, when
    the prompt ends exactly on a block edge, in the null block. Returns
    ``(emits (H, 2, N), cache, keys, probe_lg (N, V), probe_hid (N, d))``.
    """
    @functools.partial(jax.jit, donate_argnums=(1, 5))   # cache, keys
    def run(params, cache, tables, tok, pos, keys, remaining, roles, fed,
            temperature):
        member = (remaining > 0) & ~roles   # this model's live decode rows

        def transition(lg, hid, tok, pos, aux, fed_tok):
            keys, remaining, plg, phid = aux
            if temperature_zero:
                sampled = jnp.argmax(lg, axis=-1).astype(jnp.int32)
                new_keys = keys
            else:
                split = jax.vmap(jax.random.split)(keys)    # (N, 2, 2)
                new_keys = jnp.where(member[:, None], split[:, 0], keys)
                sampled = jax.vmap(jax.random.categorical)(
                    split[:, 1], lg.astype(jnp.float32) / temperature
                ).astype(jnp.int32)
            alive = remaining > 0
            new_rem = jnp.maximum(remaining - 1, 0)
            if eos_id is not None:      # EOS retires decode rows only
                new_rem = jnp.where(~roles & (sampled == eos_id), 0,
                                    new_rem)
            done_probe = roles & alive & (new_rem == 0)
            plg = jnp.where(done_probe[:, None], lg.astype(plg.dtype), plg)
            phid = jnp.where(done_probe[:, None], hid.astype(phid.dtype),
                             phid)
            nxt = jnp.where(roles, fed_tok, sampled)
            tok = jnp.where(alive, nxt, tok)
            pos = jnp.where(alive, pos + 1, pos)
            emit = jnp.stack([sampled, alive.astype(jnp.int32)])  # (2, N)
            return tok, pos, (new_keys, new_rem, plg, phid), emit

        N = tok.shape[0]
        plg0 = jnp.zeros((N, model.lm.vocab_padded), model.lm.dtype)
        phid0 = jnp.zeros((N, model.cfg.d_model), model.lm.dtype)
        (tok, pos, cache, (keys, remaining, plg, phid), emits
         ) = model.decode_horizon(
            params, tok, cache, pos, (keys, remaining, plg0, phid0), H,
            transition, block_tables=tables, xs=fed)
        return emits, cache, keys, plg, phid
    return run


# ------------------------------------------------------------ dispatchers
def dispatch_token(rt, pp):
    """Per-token program over one model's slots (decode + the chunk-1
    prefill interleave): allocate on-demand blocks the tick's writes
    cross into, build operands, launch, return (sampled_np, logits,
    hidden_np) for retirement. Slots belonging to other models run
    through as dead rows: null tables, frozen keys, outputs dropped."""
    pool = rt.pool
    B = pool.block_size
    tables: Dict[int, List[int]] = {}
    for s in pp.decode_slots:
        c = rt.slots[s]
        if rt._pos[s] // B == len(c.table):
            c.table.append(pool.alloc_block())
            c.reserved -= 1
        tables[s] = c.table
    for s in pp.prefill_slots:
        r = rt._pref[s]
        if rt._pos[s] // B == len(r.table):
            r.table.append(pool.alloc_block())
        tables[s] = r.table
    advance = np.zeros((rt.n_slots,), bool)
    advance[list(pp.decode_slots)] = True
    run = token_program(rt.models[pp.model_id], rt.temperature == 0.0,
                        rt.kv_quant)
    sampled, logits, hidden, cache, rt.keys = run(
        rt.model_params[pp.model_id], pool.caches[pp.model_id],
        jnp.asarray(pool.dense_tables(tables)),
        jnp.asarray(rt._tok), jnp.asarray(rt._pos), rt.keys,
        jnp.asarray(advance), rt.temperature)
    pool.caches[pp.model_id] = cache
    rt.metrics.record_dispatch(model=pp.model_id)
    rt.metrics.record_tick(len(pp.decode_slots) + len(pp.prefill_slots),
                           n_sampled=len(pp.decode_slots),
                           model=pp.model_id)
    rt.metrics.record_blocks(pool.blocks_in_use)
    if pp.prefill_slots:
        rt.metrics.record_prefill(len(pp.prefill_slots), model=pp.model_id)
    sampled_np = np.asarray(sampled)        # analysis: allow(sync)
    rt.metrics.record_sync(model=pp.model_id)
    hidden_np = None
    if pp.prefill_slots:
        hidden_np = np.asarray(hidden, np.float32)  # analysis: allow(sync)
        rt.metrics.record_sync(model=pp.model_id)
    return sampled_np, logits, hidden_np


def dispatch_chunk(rt, pp):
    """Varlen chunked-prefill program over one model's prefilling slots:
    advance each by up to `prefill_chunk` prompt tokens. Chunk ends are
    aligned to the absolute C-grid, so a prefix-cache hit (which starts
    prefill mid-prompt) computes every remaining position in exactly the
    batch shape a cold run would — the hit path stays bitwise identical.
    Returns (logits, hidden, take) for retirement."""
    pool = rt.pool
    B, C, P = pool.block_size, rt.prefill_chunk, rt.prefill_slots
    toks = np.zeros((P, C), np.int32)
    pos = np.zeros((P,), np.int32)
    valid = np.zeros((P,), np.int32)
    tables = np.zeros((P, pool.blocks_per_seq), np.int32)
    take: Dict[int, int] = {}
    for i, s in enumerate(pp.prefill_slots):
        r = rt._pref[s]
        p = r.prefill_pos
        L = min(C - p % C, r.prompt_len - p)
        # allocate the blocks this chunk writes into up front
        # (reservation-backed, like per-token growth)
        while (p + L - 1) // B >= len(r.table):
            r.table.append(pool.alloc_block())
        toks[i, :L] = r.prompt[p:p + L]
        pos[i] = p
        valid[i] = L
        tables[i, :len(r.table)] = r.table
        take[s] = L
    run = chunk_program(rt.models[pp.model_id], rt.kv_quant)
    logits, hidden, cache = run(
        rt.model_params[pp.model_id], pool.caches[pp.model_id],
        jnp.asarray(tables), jnp.asarray(toks), jnp.asarray(pos),
        jnp.asarray(valid))
    pool.caches[pp.model_id] = cache
    rt.metrics.record_dispatch(model=pp.model_id)
    rt.metrics.record_prefill(int(valid.sum()), model=pp.model_id)
    rt.metrics.record_blocks(pool.blocks_in_use)
    return logits, hidden, take


def dispatch_horizon(rt, pp):
    """Horizon-fused scan over one model's live decode slots: ONE jitted
    dispatch and ONE blocking device->host sync for up to H x n_live
    generated tokens. Returns the (H, 2, n_slots) token/alive buffer.
    Slots of other registry models ride along frozen (remaining 0: no
    token/pos/key advance; their writes land in this model's null
    block)."""
    pool = rt.pool
    H = pp.horizon
    remaining = np.zeros(rt.n_slots, np.int32)
    tables: Dict[int, List[int]] = {}
    for s in pp.decode_slots:
        c = rt.slots[s]
        remaining[s] = c.max_new - len(c.tokens)
        # extend the slot's table to cover the whole horizon up front
        # (reservation-backed), so tables are scan-invariant and
        # upload once per horizon instead of once per token
        c.reserved -= pool.preallocate(c.table, int(rt._pos[s]) + H)
        tables[s] = c.table
    run = horizon_program(rt.models[pp.model_id], H,
                          rt.temperature == 0.0, rt.eos_id, rt.kv_quant)
    emits, cache, rt.keys = run(
        rt.model_params[pp.model_id], pool.caches[pp.model_id],
        jnp.asarray(pool.dense_tables(tables)),
        jnp.asarray(rt._tok), jnp.asarray(rt._pos), rt.keys,
        jnp.asarray(remaining), rt.temperature)
    pool.caches[pp.model_id] = cache
    rt.metrics.record_dispatch(model=pp.model_id)
    # the dispatch above is asynchronous: host-side bookkeeping that
    # does not depend on the sampled tokens overlaps device compute,
    # and the buffer is forced in one transfer at the end
    rt.metrics.record_blocks(pool.blocks_in_use)
    # (H, 2, N): [token; alive]
    buf = np.asarray(emits)                 # analysis: allow(sync)
    rt.metrics.record_sync(model=pp.model_id)
    return buf


def dispatch_mixed(rt, pp):
    """The fused mixed tick (see :func:`mixed_program`): decode rows get
    the horizon treatment (remaining counters, table preallocation to
    pos + H), prefill rows get their remaining-prompt counts, role
    flags, table preallocation to min(prompt_len, pos + H), and an
    (H, n_slots) fed-token buffer of their queued prompt tokens. One
    dispatch, one sync. Returns (buf, probe_lg, probe_hid, consumed)
    where consumed maps each prefill slot to the prompt tokens this
    horizon computes for it."""
    pool = rt.pool
    H = pp.horizon
    remaining = np.zeros(rt.n_slots, np.int32)
    roles = np.zeros(rt.n_slots, bool)
    fed = np.zeros((H, rt.n_slots), np.int32)
    tables: Dict[int, List[int]] = {}
    for s in pp.decode_slots:
        c = rt.slots[s]
        remaining[s] = c.max_new - len(c.tokens)
        c.reserved -= pool.preallocate(c.table, int(rt._pos[s]) + H)
        tables[s] = c.table
    consumed: Dict[int, int] = {}
    for s in pp.prefill_slots:
        r = rt._pref[s]
        p0 = r.prefill_pos
        left = r.prompt_len - p0
        roles[s] = True
        remaining[s] = left
        # prompt growth draws the request's implicit prefill reservation
        pool.preallocate(r.table, min(r.prompt_len, p0 + H))
        tables[s] = r.table
        # feed positions p0+1 .. : the row's step-h input is prompt[p0+h];
        # a row that finishes its prompt mid-horizon freezes, so the zero
        # padding past the last prompt token is never consumed
        feed = r.prompt[p0 + 1:p0 + min(H, left)]
        fed[:len(feed), s] = feed
        consumed[s] = min(H, left)
    run = mixed_program(rt.models[pp.model_id], H,
                        rt.temperature == 0.0, rt.eos_id, rt.kv_quant)
    emits, cache, rt.keys, probe_lg, probe_hid = run(
        rt.model_params[pp.model_id], pool.caches[pp.model_id],
        jnp.asarray(pool.dense_tables(tables)),
        jnp.asarray(rt._tok), jnp.asarray(rt._pos), rt.keys,
        jnp.asarray(remaining), jnp.asarray(roles), jnp.asarray(fed),
        rt.temperature)
    pool.caches[pp.model_id] = cache
    rt.metrics.record_dispatch(model=pp.model_id)
    rt.metrics.record_blocks(pool.blocks_in_use)
    # (H, 2, N): [token; alive]
    buf = np.asarray(emits)                 # analysis: allow(sync)
    rt.metrics.record_sync(model=pp.model_id)
    return buf, probe_lg, probe_hid, consumed


# --------------------------------------------------------------- registry
#: every lru_cached program builder, keyed by the ProgramPlan `kind` that
#: launches it (plus "admit", launched by fan-out admission rather than a
#: plan). `repro.analysis.recompiles` walks this registry to verify each
#: builder is module-level and memoized, and cross-checks coverage
#: against plan.PROGRAM_KINDS — a kind the planner can emit without a
#: registered builder (or vice versa) is a finding.
BUILDERS = {
    "token": token_program,
    "chunk": chunk_program,
    "horizon": horizon_program,
    "mixed": mixed_program,
    "admit": admit_program,
}

#: accounted device->host fetches per dispatcher, as (min, max) sync
#: *sites* in the function body — the statically-verified half of the
#: one-sync-per-horizon contract. `repro.analysis.programs` counts the
#: actual np.asarray/scalar-pull sites in each dispatcher's AST
#: (suppression comments don't hide them from this count) and fails on
#: drift in either direction: a new fetch breaks the budget, and a
#: removed one means the budget (and this table) should tighten.
#: dispatch_token is (1, 2): sampled always, hidden only under the
#: chunk-1 prefill interleave. dispatch_chunk is (0, 0): its hidden
#: fetch belongs to retirement (retire_chunk syncs lazily, only when a
#: slot actually finished its prompt this chunk).
DISPATCH_SYNC_BUDGET = {
    "dispatch_token": (1, 2),
    "dispatch_chunk": (0, 0),
    "dispatch_horizon": (1, 1),
    "dispatch_mixed": (1, 1),
}
