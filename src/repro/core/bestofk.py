"""Adaptive best-of-k decoding (paper §4.1).

    f(x, b) = argmax_{y_1..y_b ~ p(.|x)} r(x, y)          (paper Eq. 1)

`AdaptiveBestOfK` here is the *offline* loop over an opaque ``sample_fn``
(one decoder call per query): probe -> allocator -> fan-out sampling ->
reward-model rerank. Its serving-runtime counterpart is
``repro.serving.procedure.BestOfK`` — the same rule as a pluggable
DecodeProcedure on the continuous-batching runtime (shared probe
prefill, COW fan-out, streaming price-dual budgets). Evaluation helpers
implement the paper's bootstrap estimator of expected success / reward
at a budget.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Optional, Sequence

import numpy as np

from repro.core import allocator as alloc
from repro.core import marginal


@dataclass
class BestOfKResult:
    budgets: np.ndarray          # (n,) allocated sample counts
    responses: list              # best response per query (None if b=0)
    rewards: np.ndarray          # (n,) reward of the selected response
    total_samples: int


class AdaptiveBestOfK:
    """sample_fn(query, k) -> list of k responses;
    reward_fn(query, responses) -> np.ndarray of rewards;
    predict_fn(queries) -> difficulty predictions:
        binary=True  -> λ̂ (n,)
        binary=False -> Δ̂ matrix (n, B_max)
    """

    def __init__(self, *, sample_fn: Callable, reward_fn: Callable,
                 predict_fn: Callable, b_max: int, binary: bool = True,
                 b_min: int = 0,
                 offline_policy: Optional[alloc.OfflinePolicy] = None):
        self.sample_fn = sample_fn
        self.reward_fn = reward_fn
        self.predict_fn = predict_fn
        self.b_max = b_max
        self.binary = binary
        self.b_min = b_min
        self.offline_policy = offline_policy

    def allocate(self, queries: Sequence, avg_budget: float) -> np.ndarray:
        pred = self.predict_fn(queries)
        if self.offline_policy is not None:
            stat = pred if np.ndim(pred) == 1 else pred[:, 0]
            return np.minimum(self.offline_policy(stat), self.b_max)
        if self.binary:
            delta = marginal.binary_marginals(np.asarray(pred), self.b_max)
        else:
            delta = np.asarray(pred)
        total = int(round(avg_budget * len(queries)))
        return alloc.greedy_allocate(delta, total, b_min=self.b_min)

    def __call__(self, queries: Sequence, avg_budget: float) -> BestOfKResult:
        budgets = self.allocate(queries, avg_budget)
        responses, rewards = [], np.zeros(len(queries))
        total = 0
        for i, (q, b) in enumerate(zip(queries, budgets)):
            if b <= 0:
                responses.append(None)      # paper: default "I don't know"
                continue
            ys = self.sample_fn(q, int(b))
            total += len(ys)
            rs = np.asarray(self.reward_fn(q, ys), np.float64)
            j = int(rs.argmax())
            responses.append(ys[j])
            rewards[i] = rs[j]
        return BestOfKResult(budgets=budgets, responses=responses,
                             rewards=rewards, total_samples=total)


# ---------------------------------------------------------------------------
# paper-style evaluation (precomputed sample pools + bootstrap)
# ---------------------------------------------------------------------------

def eval_binary_allocation(lam_true: np.ndarray, budgets: np.ndarray
                           ) -> float:
    """Expected success rate (paper Eq. 9) under true per-sample success
    probabilities: mean_i [1 - (1-λ_i)^{b_i}]."""
    return float(np.mean(marginal.binary_q(np.asarray(lam_true),
                                           np.asarray(budgets))))


def eval_reward_allocation(reward_pool: np.ndarray, budgets: np.ndarray,
                           *, n_boot: int = 256, rng=None) -> float:
    """Expected reward (paper Eq. 10) by bootstrapping best-of-b_i from a
    pool of pre-sampled rewards (n, m)."""
    rng = rng or np.random.default_rng(0)
    n, m = reward_pool.shape
    out = np.zeros(n)
    for b in np.unique(budgets):
        sel = budgets == b
        if b <= 0:
            out[sel] = 0.0
        else:
            out[sel] = marginal.bootstrap_best_of_k(
                reward_pool[sel], int(b), n_boot=n_boot, rng=rng)
    return float(out.mean())


def uniform_curve_binary(lam: np.ndarray, budgets: Sequence[int]):
    return [eval_binary_allocation(lam, np.full(len(lam), b))
            for b in budgets]


def oracle_curve_binary(lam: np.ndarray, budgets: Sequence[int],
                        b_max: int):
    """Non-realizable skyline: allocate with the TRUE marginals."""
    delta = marginal.binary_marginals(np.asarray(lam), b_max)
    out = []
    for B in budgets:
        b = alloc.greedy_allocate(delta, int(round(B * len(lam))))
        out.append(eval_binary_allocation(lam, b))
    return out
