"""Adaptive routing between a weak and a strong decoder (paper §4.2).

    f(x,b) = y ~ p^W   if b = b^W        (paper Eq. 2)
           = y ~ p^S   if b = b^S

The learned Δ̂ models p(p^S ≻ p^W | x) (Eq. 8); online allocation routes the
top-B fraction of queries by predicted preference.

Two implementations exist:

* :class:`AdaptiveRouter` here — the paper's *offline* evaluation loop
  over opaque ``weak_fn``/``strong_fn`` callables (one decoder call per
  query, no serving machinery). Kept as the reference protocol behind
  :func:`eval_routing` / :func:`routing_curves`.
* ``repro.serving.procedure.Route`` — the same decision rule *online* in
  the continuous-batching runtime: both decoders are registry models
  sharing one paged pool, the probe prefill on the weak model feeds the
  predictor, and escalation re-prefills through the radix cache.
  :func:`preference_predictor` adapts a trained ``kind="pref"`` probe to
  its predictor interface.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Optional, Sequence

import numpy as np

from repro.core import allocator as alloc
from repro.core.difficulty import probe_predict


def preference_predictor(probe_params, kind: str = "pref") -> Callable:
    """Adapt a trained difficulty/preference probe to the serving
    ``Route`` procedure's ``predictor(request, probe_hidden) -> float``
    interface (the hidden state is the weak model's probe prefill
    output, exactly the paper's free predictor input)."""
    def predict(request, hidden) -> float:
        h = np.asarray(hidden, np.float32)[None]
        return float(np.asarray(probe_predict(probe_params, h, kind))[0])
    return predict


@dataclass
class RoutingResult:
    use_strong: np.ndarray       # bool (n,)
    responses: list
    rewards: np.ndarray
    strong_frac: float
    avg_cost: float


class AdaptiveRouter:
    def __init__(self, *, weak_fn: Callable, strong_fn: Callable,
                 reward_fn: Callable, predict_fn: Callable,
                 cost_weak: float = 1.0, cost_strong: float = 10.0):
        self.weak_fn = weak_fn
        self.strong_fn = strong_fn
        self.reward_fn = reward_fn
        self.predict_fn = predict_fn
        self.cost_weak = cost_weak
        self.cost_strong = cost_strong

    def __call__(self, queries: Sequence, strong_frac: float) -> RoutingResult:
        pref = np.asarray(self.predict_fn(queries))
        mask = alloc.route_by_preference(pref, strong_frac)
        responses, rewards = [], np.zeros(len(queries))
        for i, q in enumerate(queries):
            y = self.strong_fn(q) if mask[i] else self.weak_fn(q)
            responses.append(y)
            rewards[i] = self.reward_fn(q, y)
        cost = (mask.mean() * self.cost_strong
                + (1 - mask.mean()) * self.cost_weak)
        return RoutingResult(use_strong=mask, responses=responses,
                             rewards=rewards, strong_frac=float(mask.mean()),
                             avg_cost=float(cost))


# ---------------------------------------------------------------------------
# evaluation with precomputed reward pools (paper's protocol)
# ---------------------------------------------------------------------------

def eval_routing(rew_weak: np.ndarray, rew_strong: np.ndarray,
                 mask_strong: np.ndarray) -> float:
    """Expected reward when mask selects the strong decoder.

    rew_weak/rew_strong (n, m): pre-sampled rewards; single-sample decoding
    means expected reward per query = pool mean.
    """
    mw = rew_weak.mean(axis=1)
    ms = rew_strong.mean(axis=1)
    return float(np.where(mask_strong, ms, mw).mean())


def routing_curves(rew_weak: np.ndarray, rew_strong: np.ndarray,
                   pref_pred: np.ndarray, fracs: Sequence[float],
                   *, rng: Optional[np.random.Generator] = None):
    """Adaptive / random / oracle expected-reward curves vs strong fraction."""
    rng = rng or np.random.default_rng(0)
    n = len(pref_pred)
    oracle_stat = rew_strong.mean(1) - rew_weak.mean(1)
    out = {"frac": [], "adaptive": [], "random": [], "oracle": []}
    for f in fracs:
        out["frac"].append(f)
        out["adaptive"].append(eval_routing(
            rew_weak, rew_strong, alloc.route_by_preference(pref_pred, f)))
        rnd = np.zeros(n, bool)
        rnd[rng.permutation(n)[: int(round(f * n))]] = True
        out["random"].append(eval_routing(rew_weak, rew_strong, rnd))
        out["oracle"].append(eval_routing(
            rew_weak, rew_strong, alloc.route_by_preference(oracle_stat, f)))
    return out
