"""The paper's primary contribution: input-adaptive allocation of LM
computation — difficulty models, the matroid-greedy allocator, adaptive
best-of-k, and weak/strong routing."""
from repro.core import allocator, bestofk, difficulty, marginal, routing  # noqa: F401
from repro.core.policy import AdaptivePolicy  # noqa: F401
