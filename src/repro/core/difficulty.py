"""Difficulty models Δ̂(x; θ) — paper §3.1.

Two parameterizations, both implemented on top of our in-framework LMs:

* **MLPProbe** — a 2-layer MLP on the base LM's last hidden state of the
  encoded query ("extremely little overhead: its input are hidden states
  that are already computed as part of the decoding procedure").
* **LoRAProbe** — LoRA adapters on the base LM's attention projections plus
  a prediction head; trained end-to-end through the (merged-form) adapted
  forward pass.

Heads / losses:
    kind="mse"   : predict the Δ vector, MSE (paper Eq. 6)
    kind="bce"   : predict λ (binary-reward domains), BCE on soft labels
                   (paper Eq. 7); Δ then follows analytically
    kind="pref"  : predict p(p^S ≻ p^W | x) for routing (paper Eq. 8)
"""
from __future__ import annotations

import math
from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import modules as nn


# ---------------------------------------------------------------------------
# MLP probe
# ---------------------------------------------------------------------------

def init_mlp_probe(key, d_in: int, d_out: int, *, d_hidden: int = 128):
    k1, k2 = jax.random.split(key)
    return {
        "fc1": nn.init_linear(k1, d_in, d_hidden, bias=True),
        "fc2": nn.init_linear(k2, d_hidden, d_out, bias=True, zero=True),
    }


def mlp_probe_apply(p, h: jnp.ndarray) -> jnp.ndarray:
    """h (..., d_in) -> raw logits (..., d_out)."""
    z = jax.nn.relu(nn.linear(p["fc1"], h.astype(jnp.float32)))
    return nn.linear(p["fc2"], z)


def probe_loss(p, h, targets, kind: str) -> jnp.ndarray:
    out = mlp_probe_apply(p, h)
    if kind == "mse":
        return jnp.mean(jnp.sum((out - targets) ** 2, axis=-1))
    # bce / pref: scalar sigmoid head on soft labels
    logit = out[..., 0]
    t = targets.astype(jnp.float32)
    return jnp.mean(t * jax.nn.softplus(-logit)
                    + (1 - t) * jax.nn.softplus(logit))


def train_mlp_probe(key, feats: np.ndarray, targets: np.ndarray, *,
                    kind: str = "bce", d_hidden: int = 128,
                    steps: int = 2000, lr: float = 1e-3,
                    batch_size: int = 256, weight_decay: float = 1e-4,
                    val_frac: float = 0.1) -> Tuple[Dict, Dict[str, Any]]:
    """Full training loop (AdamW, minibatched). Returns (params, info)."""
    from repro.optim import adamw_init, adamw_update

    feats = np.asarray(feats, np.float32)
    targets = np.asarray(targets, np.float32)
    n = len(feats)
    n_val = max(1, int(n * val_frac))
    rng = np.random.default_rng(0)
    perm = rng.permutation(n)
    vi, ti = perm[:n_val], perm[n_val:]
    d_out = targets.shape[1] if (kind == "mse" and targets.ndim > 1) else 1
    if targets.ndim == 1:
        targets = targets[:, None] if kind == "mse" else targets
    params = init_mlp_probe(key, feats.shape[1], d_out, d_hidden=d_hidden)
    opt = adamw_init(params)
    ft, tt = jnp.asarray(feats[ti]), jnp.asarray(targets[ti])
    fv, tv = jnp.asarray(feats[vi]), jnp.asarray(targets[vi])

    @jax.jit
    def step(params, opt, idx):
        loss, g = jax.value_and_grad(probe_loss)(params, ft[idx], tt[idx], kind)
        params, opt = adamw_update(params, g, opt, lr=lr,
                                   weight_decay=weight_decay)
        return params, opt, loss

    val_loss_fn = jax.jit(lambda p: probe_loss(p, fv, tv, kind))
    losses, best, best_params = [], np.inf, params
    m = len(ti)
    for s in range(steps):
        idx = jnp.asarray(rng.integers(0, m, size=min(batch_size, m)))
        params, opt, loss = step(params, opt, idx)
        if s % 50 == 0 or s == steps - 1:
            vl = float(val_loss_fn(params))
            losses.append((s, float(loss), vl))
            if vl < best:
                best, best_params = vl, jax.tree.map(jnp.copy, params)
    return best_params, {"history": losses, "val_loss": best, "kind": kind}


def probe_predict(params, feats: np.ndarray, kind: str) -> np.ndarray:
    out = np.asarray(mlp_probe_apply(params, jnp.asarray(feats, jnp.float32)))
    if kind == "mse":
        return out
    return 1.0 / (1.0 + np.exp(-out[..., 0]))


# ---------------------------------------------------------------------------
# LoRA probe (adapter fine-tuning of the base LM + head)
# ---------------------------------------------------------------------------

_LORA_TARGETS = ("wq", "wo", "wx", "wz")   # attention & xlstm/mamba inputs


def init_lora_probe(key, base_params, d_model: int, d_out: int, *,
                    rank: int = 8):
    """LoRA params matching 2-D/3-D weight leaves named in _LORA_TARGETS,
    plus an MLP head on the final hidden state."""
    flat = jax.tree_util.tree_flatten_with_path(base_params)[0]
    lora: Dict[str, Any] = {}
    k = key
    for path, leaf in flat:
        names = [getattr(pp, "key", str(pp)) for pp in path]
        if len(names) >= 2 and names[-1] == "w" and names[-2] in _LORA_TARGETS:
            k, sub = jax.random.split(k)
            if leaf.ndim == 2:          # (d_in, d_out)
                lead, d_in, d_o = (), leaf.shape[0], leaf.shape[1]
            else:                        # (n_repeat, d_in, *out_dims)
                lead = (leaf.shape[0],)
                d_in = leaf.shape[1]
                d_o = int(np.prod(leaf.shape[2:]))
            a = (jax.random.normal(sub, lead + (d_in, rank), jnp.float32)
                 / math.sqrt(d_in))
            b = jnp.zeros(lead + (rank, d_o), jnp.float32)
            lora["/".join(names)] = {"a": a, "b": b}
    k, sub = jax.random.split(k)
    head = init_mlp_probe(sub, d_model, d_out)
    return {"adapters": lora, "head": head}


def apply_lora(base_params, lora, scale: float = 1.0):
    """Merged-form LoRA: returns params with w + a@b on adapted leaves."""
    adapters = lora["adapters"]

    def walk(tree, prefix):
        if isinstance(tree, dict):
            return {k: walk(v, prefix + [k]) for k, v in tree.items()}
        name = "/".join(prefix)
        if name in adapters:
            ad = adapters[name]
            delta = jnp.einsum("...ir,...ro->...io", ad["a"], ad["b"]) * scale
            if delta.shape != tree.shape:
                delta = delta.reshape(tree.shape)
            return tree + delta.astype(tree.dtype)
        return tree

    return walk(base_params, [])


def lora_probe_loss(lora, base_params, encode_fn: Callable, tokens,
                    targets, kind: str) -> jnp.ndarray:
    params = apply_lora(base_params, lora)
    h = encode_fn(params, tokens)          # (n, d) last hidden state
    return probe_loss(lora["head"], h, targets, kind)
