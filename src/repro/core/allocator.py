"""Computation allocation (paper §3.2).

The ILP (paper Eq. 5)

    max Σ_ij c_ij Δ_ij   s.t.  Σ c_ij <= B·n,  c_ij <= c_{i,j-1}

is a matroid (feasible prefix sets), so greedy is exact (Edmonds 1971) for
non-increasing rows. Rows predicted by a learned Δ̂ may be non-monotone; we
apply PAV "ironing" (pool-adjacent-violators averaging, sum-preserving)
first — greedy on the ironed rows selects the same prefixes the exact
matroid greedy would, up to one pooled block at the budget boundary.

Three implementations, all tested against each other + brute force:

    greedy_allocate       exact frontier greedy, numpy heap, O(nB log n)
    allocate_threshold    vectorized sort/threshold (jax or numpy), used
                          on-device inside the serving scheduler
    OfflinePolicy         paper's offline variant — bin by predicted
                          difficulty on held-out data, solve once with a
                          per-bin-equality constraint, ship a lookup table
"""
from __future__ import annotations

import heapq
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np


# ---------------------------------------------------------------------------
# ironing
# ---------------------------------------------------------------------------

def iron_rows(delta: np.ndarray) -> np.ndarray:
    """Sum-preserving non-increasing envelope per row (PAV)."""
    d = np.array(delta, np.float64, copy=True)
    n, B = d.shape
    for i in range(n):
        # stack of (value_sum, count)
        stack = []
        for j in range(B):
            v, c = d[i, j], 1
            while stack and stack[-1][0] / stack[-1][1] <= v / c:
                pv, pc = stack.pop()
                v += pv
                c += pc
            stack.append((v, c))
        out = []
        for v, c in stack:
            out.extend([v / c] * c)
        d[i] = out
    return d


def iron_rows_jnp(delta: jnp.ndarray) -> jnp.ndarray:
    """Vectorized PAV via iterated pooling (O(B) passes, B small)."""
    d = delta.astype(jnp.float32)
    B = d.shape[1]
    # Exact PAV via the minimax identity for decreasing isotonic regression:
    #   ironed[j] = min_{a < j} max_{b >= j} mean(d[a..b])
    # i.e. the derivative of the concave hull of the prefix sums.
    # O(B^2) memory; B <= a few hundred in all experiments.
    pre = jnp.concatenate([jnp.zeros((d.shape[0], 1), d.dtype),
                           jnp.cumsum(d, axis=1)], axis=1)   # (n,B+1)
    # concave hull of points (j, pre[j]) via upper envelope slopes
    jj = jnp.arange(B + 1, dtype=jnp.float32)
    # slope[a,b] = (pre[b]-pre[a])/(b-a) for b>a
    diff = pre[:, None, :] - pre[:, :, None]                 # (n,a,b)
    span = jj[None, :] - jj[:, None]
    slope = jnp.where(span > 0, diff / jnp.maximum(span, 1.0), -jnp.inf)
    # ironed[j] (1-indexed unit j) = min_{a<j} max_{b>=j} slope[a,b]
    maxb = jax.lax.cummax(slope[:, :, ::-1], axis=2)[:, :, ::-1]  # max over b'>=b
    # for unit j (1..B): candidates a in [0, j-1], b in [j, B]
    cand = maxb[:, :, 1:]                                    # b index >= 1
    # cand[n, a, j-1] = max_{b>=j} slope[a,b]; need min over a <= j-1
    cand = jnp.where(jnp.arange(B + 1)[None, :, None]
                     <= jnp.arange(1, B + 1)[None, None, :] - 1,
                     cand, jnp.inf)
    return jnp.min(cand, axis=1)


# ---------------------------------------------------------------------------
# exact greedy (reference + production host path)
# ---------------------------------------------------------------------------

def greedy_allocate(delta: np.ndarray, total_budget: int, *,
                    b_min: int = 0, allow_negative: bool = False,
                    iron: bool = False) -> np.ndarray:
    """Solve Eq. 5: returns integer budgets b (n,), Σb <= total_budget.

    b_min pre-assigns that many units to every query (chat experiments use
    b_min=1). Stops early when the best remaining marginal is <= 0 unless
    allow_negative (paper: impossible queries get b=0 and a default answer).

    iron=False (default) runs FRONTIER greedy on the raw marginals: exact
    for monotone rows (the matroid argument), and on noisy non-monotone
    rows it realizes the actual prefix values — measured better than
    hull-greedy, whose pooled blocks overestimate value when the budget
    cuts a block mid-way (see EXPERIMENTS.md §Repro chat notes). iron=True
    selects by the PAV concave hull instead (optimal w.r.t. the hull).
    """
    d = np.asarray(delta, np.float64)
    if iron:
        d = iron_rows(d)
    n, B = d.shape
    b = np.full(n, min(b_min, B), np.int64)
    spent = int(b.sum())
    heap = []
    for i in range(n):
        if b[i] < B:
            heap.append((-d[i, b[i]], i))
    heapq.heapify(heap)
    while heap and spent < total_budget:
        negv, i = heapq.heappop(heap)
        if not allow_negative and -negv <= 0:
            break
        b[i] += 1
        spent += 1
        if b[i] < B:
            heapq.heappush(heap, (-d[i, b[i]], i))
    return b


def allocate_threshold(delta, total_budget: int, *, b_min: int = 0,
                       assume_monotone: bool = False):
    """Vectorized allocation: global top-k over (ironed) marginals.

    Equivalent to greedy for monotone rows. Works on jnp or np arrays; used
    on-device by the serving scheduler (device-resident, no host sync).
    """
    xp = jnp if isinstance(delta, jnp.ndarray) else np
    d = delta
    if not assume_monotone:
        d = (iron_rows_jnp(d) if xp is jnp
             else iron_rows(np.asarray(d, np.float64)))
    n, B = d.shape
    base = min(b_min, B)
    remaining = max(0, total_budget - base * n)
    if xp is jnp:
        dm = jnp.where(jnp.arange(B)[None, :] < base, -jnp.inf, d)
        flat = dm.reshape(-1)
        k = min(remaining, flat.shape[0])
        if k == 0:
            return jnp.full((n,), base, jnp.int32)
        # exact top-k by index (ties broken toward earlier units, which
        # preserves the prefix property for monotone rows and hits the
        # budget exactly)
        _, idx = jax.lax.top_k(flat, k)
        take = jnp.zeros_like(flat, jnp.int32).at[idx].set(1).reshape(n, B)
        take = take * (dm > 0)
        b = jnp.sum(jnp.cumprod(take, axis=1), axis=1)
        return (base + b).astype(jnp.int32)
    else:
        dm = np.where(np.arange(B)[None, :] < base, -np.inf, d)
        flat = dm.reshape(-1)
        k = min(remaining, flat.size)
        if k == 0:
            return np.full(n, base, np.int64)
        idx = np.argsort(-flat, kind="stable")[:k]
        take = np.zeros(flat.size, np.int64)
        take[idx] = 1
        take = take.reshape(n, B) * (dm > 0)
        b = np.cumprod(take, axis=1).sum(axis=1)
        return base + b


# ---------------------------------------------------------------------------
# streaming (price-dual) allocation — for in-flight admission
# ---------------------------------------------------------------------------

def price_for_budget(delta_calib: np.ndarray, avg_budget: float, *,
                     b_min: int = 0, iron: bool = True) -> float:
    """Dual price λ* of Eq. 5 from a calibration set.

    Greedy/threshold allocation admits exactly the units whose (ironed)
    marginal is >= the value of the last unit inside the budget. Fixing
    that *price* turns the batch-coupled allocation into a per-query rule
    — b_i = len of the prefix of row i with Δ >= λ* — usable one request
    at a time by a streaming scheduler. On the calibration distribution
    the realized average budget matches avg_budget by construction.

    b_min units per query are granted unconditionally by the consumer
    (allocate_at_price's floor), so they are charged against the budget
    here and excluded from pricing — pass the same b_min to both.

    Pricing operates on the PAV-ironed (concave-hull) marginals: a single
    threshold can only express monotone prefix rules, so for non-monotone
    predicted rows the streaming allocation follows the hull, which can
    differ from frontier `greedy_allocate` on raw marginals (they agree
    exactly for monotone rows, e.g. the binary-λ "bce" predictor).
    """
    d = np.asarray(delta_calib, np.float64)
    if iron:
        d = iron_rows(d)
    n, B = d.shape
    base = min(b_min, B)
    total = int(round(avg_budget * n)) - base * n
    flat = np.sort(d[:, base:].reshape(-1))[::-1]
    if total <= 0:
        return float("inf")
    if total >= flat.size:
        return max(float(flat[-1]), 0.0) if flat.size else 0.0
    return max(float(flat[total - 1]), 0.0)


def allocate_at_price(delta: np.ndarray, price: float, *, b_min: int = 0,
                      iron: bool = True) -> np.ndarray:
    """Per-row streaming allocation at a fixed price: the longest prefix of
    (ironed) positive marginals valued >= price, floored at b_min.
    Batch-free: rows may be allocated one at a time as requests arrive.
    Calibrate the price with the same b_min (see price_for_budget, incl.
    the note on ironing vs frontier greedy for non-monotone rows)."""
    d = np.asarray(delta, np.float64)
    if d.ndim == 1:
        d = d[None]
    if iron:
        d = iron_rows(d)
    B = d.shape[1]
    ok = (d >= price) & (d > 0)
    b = np.cumprod(ok, axis=1).sum(axis=1)
    return np.maximum(b, min(b_min, B)).astype(np.int64)


# ---------------------------------------------------------------------------
# offline (binned) policy — paper §3.2 "Offline allocation"
# ---------------------------------------------------------------------------

@dataclass
class OfflinePolicy:
    """Fixed difficulty-bin -> budget lookup table."""
    bin_edges: np.ndarray       # (n_bins-1,) thresholds on the bin statistic
    budgets: np.ndarray         # (n_bins,) budget per bin

    def __call__(self, stat: np.ndarray) -> np.ndarray:
        """stat (n,): the per-query difficulty statistic (e.g. Δ̂_1 or λ̂)."""
        bins = np.searchsorted(self.bin_edges, np.asarray(stat))
        return self.budgets[bins]


def build_offline_policy(delta_holdout: np.ndarray, stat: np.ndarray,
                         avg_budget: float, *, n_bins: int = 10,
                         b_min: int = 0) -> OfflinePolicy:
    """Solve Eq. 5 on held-out data with per-bin equality constraints.

    delta_holdout (m, B): empirical marginals of the held-out queries.
    stat (m,): the statistic used to bin them at deployment (the paper uses
    the first-sample prediction Δ̂_1).
    """
    m, B = delta_holdout.shape
    qs = np.quantile(stat, np.linspace(0, 1, n_bins + 1)[1:-1])
    edges = np.unique(qs)
    bins = np.searchsorted(edges, stat)
    n_eff = len(edges) + 1
    # per-bin mean marginal rows + counts
    rows = np.zeros((n_eff, B))
    counts = np.zeros(n_eff, np.int64)
    for g in range(n_eff):
        sel = bins == g
        counts[g] = sel.sum()
        if counts[g]:
            rows[g] = iron_rows(delta_holdout[sel]).mean(axis=0)
    total = int(round(avg_budget * m))
    budgets = np.full(n_eff, b_min, np.int64)
    spent = int((budgets * counts).sum())
    heap = [(-rows[g, budgets[g]], g) for g in range(n_eff)
            if counts[g] and budgets[g] < B]
    heapq.heapify(heap)
    while heap:
        negv, g = heapq.heappop(heap)
        if -negv <= 0:
            break
        if spent + counts[g] > total:
            continue
        budgets[g] += 1
        spent += int(counts[g])
        if budgets[g] < B:
            heapq.heappush(heap, (-rows[g, budgets[g]], g))
    return OfflinePolicy(bin_edges=edges, budgets=budgets)


# ---------------------------------------------------------------------------
# routing allocation (paper §4.2)
# ---------------------------------------------------------------------------

def route_by_preference(pref: np.ndarray, strong_frac: float) -> np.ndarray:
    """Route the top strong_frac fraction (by predicted preference) to the
    strong decoder. Returns bool mask (n,). Matches the paper's top-B
    percentile rule."""
    n = len(pref)
    k = int(round(strong_frac * n))
    if k <= 0:
        return np.zeros(n, bool)
    if k >= n:
        return np.ones(n, bool)
    thresh = np.partition(pref, -k)[-k]
    mask = pref >= thresh
    # break ties deterministically to hit the exact count
    if mask.sum() > k:
        idx = np.where(pref == thresh)[0]
        drop = idx[: mask.sum() - k]
        mask[drop] = False
    return mask


def route_budgeted(pref: np.ndarray, cost_weak: float, cost_strong: float,
                   avg_budget: float) -> np.ndarray:
    """Cost-aware routing: strong calls cost (cost_strong - cost_weak) extra;
    fit as many of the highest-preference queries as the budget allows."""
    n = len(pref)
    extra = cost_strong - cost_weak
    spare = (avg_budget - cost_weak) * n
    k = int(spare // extra) if extra > 0 else n
    return route_by_preference(pref, min(max(k, 0), n) / n)
