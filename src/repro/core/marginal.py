"""Marginal-reward machinery (paper §3, §3.3).

Definitions (paper Eq. 4-5):
    q(x, b)   = E_{y ~ f(x,b)}[r(x, y)]                 expected reward
    Δ_ij      = q(x_i, j) - q(x_i, j-1)                 marginal reward

Binary-reward special case (§3.3): with per-sample success prob λ,
    q(x, b) = 1 - (1-λ)^b        Δ_ij = λ (1-λ)^{j-1}   (monotone ↓ in j)

Continuous-reward (best-of-k with a reward model): Δ is estimated by
bootstrap over a pool of sampled rewards, exactly as the paper's Appendix A
training pipelines do.
"""
from __future__ import annotations

from typing import Optional

import numpy as np


def binary_q(lam: np.ndarray, b: np.ndarray) -> np.ndarray:
    """q(x,b) = 1-(1-λ)^b; lam (...,), b (...,) broadcastable."""
    return 1.0 - np.power(1.0 - lam, b)


def binary_marginals(lam: np.ndarray, b_max: int) -> np.ndarray:
    """Δ matrix (n, b_max): Δ[:, j-1] = λ(1-λ)^{j-1}."""
    lam = np.asarray(lam, np.float64)[:, None]
    j = np.arange(b_max)[None, :]
    return lam * np.power(1.0 - lam, j)


def bootstrap_best_of_k(rewards: np.ndarray, k: int, *, n_boot: int = 256,
                        rng: Optional[np.random.Generator] = None) -> np.ndarray:
    """E[max of k samples] per query via bootstrap.

    rewards (n, m): m sampled rewards per query. Returns (n,) estimates of
    q(x, k) for best-of-k under the reward model (paper's evaluation
    procedure: sample B_max generations once, bootstrap subsets).
    """
    rng = rng or np.random.default_rng(0)
    n, m = rewards.shape
    if k <= 0:
        return np.zeros(n)
    if k >= m:
        return rewards.max(axis=1)
    idx = rng.integers(0, m, size=(n_boot, k))
    # (n_boot, n, k) -> max over k -> mean over boot
    return rewards[:, idx].max(axis=2).mean(axis=1)


def bootstrap_marginals(rewards: np.ndarray, b_max: int, *,
                        n_boot: int = 256,
                        rng: Optional[np.random.Generator] = None) -> np.ndarray:
    """Empirical Δ matrix (n, b_max) from sampled rewards (n, m)."""
    rng = rng or np.random.default_rng(0)
    q = np.stack([bootstrap_best_of_k(rewards, k, n_boot=n_boot, rng=rng)
                  for k in range(0, b_max + 1)], axis=1)   # (n, b_max+1)
    return np.diff(q, axis=1)


def empirical_lambda(successes: np.ndarray) -> np.ndarray:
    """Per-query single-sample success rate from binary outcomes (n, m)."""
    return np.asarray(successes, np.float64).mean(axis=1)


def preference_prob(rewards_strong: np.ndarray, rewards_weak: np.ndarray,
                    *, sigma_scale: float = 1.0) -> np.ndarray:
    """Monte-Carlo p(p^S ≻ p^W | x) = E[σ(r(y_S) − r(y_W))]  (paper Eq. 8/11).

    rewards_strong (n, mS), rewards_weak (n, mW): all pairs are used.
    """
    ds = rewards_strong[:, :, None] - rewards_weak[:, None, :]
    return (1.0 / (1.0 + np.exp(-sigma_scale * ds))).mean(axis=(1, 2))
