"""AdaptivePolicy: the paper's technique as a first-class serving feature.

Ties together (probe -> marginals -> allocator) behind one object the
serving scheduler calls per batch. Supports:
  * online mode  — exact batch solve of Eq. 5 (greedy on device or host)
  * offline mode — the fixed bin->budget table (per-query, batch-free)
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import numpy as np

from repro.core import allocator as alloc
from repro.core import marginal
from repro.core.difficulty import probe_predict


@dataclass
class AdaptivePolicy:
    probe_params: dict
    kind: str                     # "bce" (binary λ̂) | "mse" (Δ̂ vector)
    b_max: int
    b_min: int = 0
    offline: Optional[alloc.OfflinePolicy] = None

    def predict(self, hidden: np.ndarray) -> np.ndarray:
        """hidden (n, d) last-token hidden states from prefill."""
        return probe_predict(self.probe_params, hidden, self.kind)

    def marginals(self, hidden: np.ndarray) -> np.ndarray:
        pred = self.predict(hidden)
        if self.kind == "bce":
            return marginal.binary_marginals(pred, self.b_max)
        return np.asarray(pred)[:, : self.b_max]

    def _offline_budgets(self, hidden: np.ndarray) -> np.ndarray:
        pred = self.predict(hidden)
        stat = pred if pred.ndim == 1 else pred[:, 0]
        return np.minimum(self.offline(stat), self.b_max).astype(np.int64)

    def allocate(self, hidden: np.ndarray, avg_budget: float) -> np.ndarray:
        """Returns integer budgets (n,)."""
        if self.offline is not None:
            return self._offline_budgets(hidden)
        delta = self.marginals(hidden)
        total = int(round(avg_budget * len(delta)))
        return alloc.greedy_allocate(delta, total, b_min=self.b_min)

    # ----------------------------------------------------------- streaming
    def calibrate_price(self, hidden_calib: np.ndarray,
                        avg_budget: float) -> float:
        """Dual price λ* s.t. thresholding marginals at λ* spends
        avg_budget per query on the calibration distribution (the b_min
        floor is charged against the budget). Decouples allocation from
        the batch: the serving runtime can then budget each request the
        moment its probe prefill lands."""
        return alloc.price_for_budget(self.marginals(hidden_calib),
                                      avg_budget, b_min=self.b_min)

    def allocate_streaming(self, hidden: np.ndarray, price: float,
                           max_children: Optional[int] = None) -> np.ndarray:
        """Per-query budgets at a fixed price — batch-free (Eq. 5's dual
        form). hidden may be a single row (d,) or a batch (n, d).

        max_children gates admission on *memory*, not price: the paged
        serving runtime passes what its free blocks can eventually carry
        (``(free - reserved) // blocks_per_child``), so a difficulty
        spike cannot over-commit the KV pool. The cap trades that one
        request's tail samples for memory safety; the dual price — and so
        every later request's allocation — is unchanged. With the slot
        pool this was implicitly "free slots", which over-admits whenever
        sequences are shorter than the worst case."""
        h = np.asarray(hidden)
        if h.ndim == 1:
            h = h[None]
        if self.offline is not None:
            b = self._offline_budgets(h)
        else:
            b = alloc.allocate_at_price(self.marginals(h), price,
                                        b_min=self.b_min)
        if max_children is not None:
            b = np.minimum(b, int(max_children))
        return b
