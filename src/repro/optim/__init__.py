from repro.optim.adamw import adamw_init, adamw_update, AdamWState  # noqa: F401
from repro.optim.schedule import cosine_schedule, linear_warmup_cosine  # noqa: F401
from repro.optim.clip import clip_by_global_norm, global_norm  # noqa: F401
