"""AdamW in pure JAX (optax is not installed offline; deliberate substrate).

Moments are kept in fp32 regardless of param dtype (mixed-precision
training: bf16 params + fp32 m/v is the memory layout the dry-run
memory_analysis reports).
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jnp.ndarray
    m: Any
    v: Any


def adamw_init(params) -> AdamWState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return AdamWState(step=jnp.zeros((), jnp.int32), m=zeros,
                      v=jax.tree.map(jnp.copy, zeros))


def adamw_update(params, grads, state: AdamWState, *, lr,
                 b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
                 weight_decay: float = 0.0):
    step = state.step + 1
    t = step.astype(jnp.float32)
    c1 = 1.0 - b1 ** t
    c2 = 1.0 - b2 ** t

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * g32
        v = b2 * v + (1 - b2) * (g32 * g32)
        mh = m / c1
        vh = v / c2
        delta = mh / (jnp.sqrt(vh) + eps)
        if weight_decay:
            delta = delta + weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state.m)
    flat_v = jax.tree.leaves(state.v)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(tdef, [o[0] for o in out])
    new_m = jax.tree.unflatten(tdef, [o[1] for o in out])
    new_v = jax.tree.unflatten(tdef, [o[2] for o in out])
    return new_p, AdamWState(step=step, m=new_m, v=new_v)
