"""Fused streaming cross-entropy (TPU Pallas target).

For 256k-vocab models the (batch*seq, V) logits tensor dominates HBM during
training; this kernel streams (row_block x vocab_block) tiles, maintaining
running (m, l, gold) per row in VMEM scratch — the logsumexp analogue of
flash attention. The model's hidden @ W_vocab tiles can be fused upstream by
XLA; the kernel removes the fp32 logits materialization + second pass.

Grid (n_row_blocks, n_vocab_blocks), vocab innermost/sequential.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def tile_index_map(ri, vi):
    """logits tile: (block_rows, block_v) at (row block ri, vocab blk vi)."""
    return (ri, vi)


def row_index_map(ri, vi):
    """labels / loss tiles: (block_rows,), constant across the vocab loop."""
    return (ri,)


def _ce_kernel(logits_ref, labels_ref, loss_ref, m_scr, l_scr, gold_scr, *,
               block_v: int, vocab: int):
    vi = pl.program_id(1)
    nv = pl.num_programs(1)

    @pl.when(vi == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        gold_scr[...] = jnp.zeros_like(gold_scr)

    x = logits_ref[...].astype(jnp.float32)                   # (br, bv)
    v_start = vi * block_v
    col = v_start + jax.lax.broadcasted_iota(jnp.int32, x.shape, 1)
    x = jnp.where(col < vocab, x, NEG_INF)
    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(x, axis=1))
    alpha = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * alpha + jnp.sum(jnp.exp(x - m_new[:, None]),
                                              axis=1)
    m_scr[...] = m_new
    labels = labels_ref[...]                                  # (br,)
    hit = col == labels[:, None]
    gold_scr[...] = gold_scr[...] + jnp.sum(jnp.where(hit, x, 0.0), axis=1)

    @pl.when(vi == nv - 1)
    def _finish():
        loss_ref[...] = (m_scr[...] + jnp.log(jnp.maximum(l_scr[...], 1e-30))
                         - gold_scr[...]).astype(loss_ref.dtype)


def cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray, *,
                  block_rows: int = 128, block_v: int = 2048,
                  interpret: bool = True) -> jnp.ndarray:
    """logits (n, V); labels (n,) int32 -> per-row loss (n,) fp32."""
    n, V = logits.shape
    block_rows = min(block_rows, n)
    block_v = min(block_v, V)
    nr = pl.cdiv(n, block_rows)
    nv = pl.cdiv(V, block_v)
    assert n % block_rows == 0, "pad rows upstream"
    kernel = functools.partial(_ce_kernel, block_v=block_v, vocab=V)
    from jax.experimental.pallas import tpu as pltpu
    return pl.pallas_call(
        kernel,
        grid=(nr, nv),
        in_specs=[
            pl.BlockSpec((block_rows, block_v), tile_index_map),
            pl.BlockSpec((block_rows,), row_index_map),
        ],
        out_specs=pl.BlockSpec((block_rows,), row_index_map),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((block_rows,), jnp.float32),
            pltpu.VMEM((block_rows,), jnp.float32),
            pltpu.VMEM((block_rows,), jnp.float32),
        ],
        interpret=interpret,
    )(logits, labels.astype(jnp.int32))
