"""Flash-decoding attention (TPU Pallas target).

One new token's q (b, H, hd) attends to a long KV cache (b, S, KV, hd).
Grid (batch, n_kv_blocks) with the kv axis sequential; the (m, l, acc)
online-softmax state persists in VMEM scratch, so arbitrarily long caches
stream through (block_k x KV x hd) VMEM tiles with one final normalization.
This is the single-chip analogue of the framework's cross-chip
sequence-sharded decode (DESIGN.md): split-S within a chip here, split-S
over the `model` mesh axis there.

Validity masking uses the per-batch `pos` scalar (slots <= pos are live),
matching the serving engine's cache semantics.

`paged_decode_attention` is the block-granular variant for the paged KV
pool: K/V live in a shared physical block store (n_blocks, B, KV, hd) and
each sequence owns a block table (b, T) mapping logical block t (token
positions t*B .. t*B+B-1) to a physical block id. The table is a
scalar-prefetch argument, so the BlockSpec index maps gather exactly the
blocks a sequence owns — no dense copy of the cache is materialized.

`paged_chunk_attention` extends the paged kernel to C query tokens per
sequence (varlen chunked prefill): queries at positions pos .. pos+C-1
stream the same block-table gather, the chunk axis is folded into the
online-softmax row dimension (C*H rows of scratch), and per-row validity
`kpos <= pos + c` gives exact causality including within the chunk —
the new K/V rows are scattered into the sequence's freshly-owned blocks
*before* the kernel runs, so within-chunk keys are just cache reads.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


# dense decode maps (grid (b, n_kv_blocks))
def dense_pos_index_map(bi, ki):
    return (bi,)


def dense_q_index_map(bi, ki):
    """q / output: one (H, hd) tile per batch row, resident across k."""
    return (bi, 0, 0)


def dense_kv_index_map(bi, ki):
    """k / v: the ki-th (block_k, KV, hd) tile of batch row bi."""
    return (bi, ki, 0, 0)


# paged maps (grid (b, T), scalar-prefetch (tables, pos))
def paged_q_index_map(bi, ti, tbl, p):
    return (bi, 0, 0)


def paged_chunk_q_index_map(bi, ti, tbl, p):
    return (bi, 0, 0, 0)


def paged_kv_index_map(block_size: int):
    """Block-table gather map for `paged_decode_attention`'s k/v specs.

    Clamps the gather to the row's last live block: index maps feed the
    DMA pipeline regardless of the kernel's @pl.when compute skip, so
    without the clamp every grid step past `pos` still streamed a
    (B, KV, hd) tile — table padding and the horizon path's
    preallocated-but-unwritten blocks. Skipped steps never read the
    fetched tile, so re-fetching the live block is value-identical.

    Module-level (not a closure in the wrapper) so the static auditor
    (`repro.analysis.blockspecs`) can evaluate the exact production map
    over the full grid against poisoned block tables.
    """
    def kv_map(bi, ti, tbl, p):
        return (tbl[bi, jnp.minimum(ti, p[bi] // block_size)], 0, 0, 0)
    return kv_map


def chunk_kv_index_map(block_size: int, chunk: int):
    """Same DMA clamp as `paged_kv_index_map`, against the last block
    any query row of the chunk can see (the compute guard's bound)."""
    def kv_map(bi, ti, tbl, p):
        return (tbl[bi, jnp.minimum(ti, (p[bi] + chunk - 1) // block_size)],
                0, 0, 0)
    return kv_map


def paged_scale_index_map(block_size: int):
    """Scale-store gather map for the int8 quantized decode kernel: the
    per-(block, kv-head) scale tile (1, 1, KVp) travels with the same
    clamped physical block id as its K/V tile. Module-level so the static
    auditor evaluates it over the full grid like the K/V maps."""
    def scale_map(bi, ti, tbl, p):
        return (tbl[bi, jnp.minimum(ti, p[bi] // block_size)], 0, 0)
    return scale_map


def chunk_scale_index_map(block_size: int, chunk: int):
    """Quantized chunk variant of `paged_scale_index_map`, clamped to the
    last block any query row of the chunk can see."""
    def scale_map(bi, ti, tbl, p):
        return (tbl[bi, jnp.minimum(ti, (p[bi] + chunk - 1) // block_size)],
                0, 0)
    return scale_map


def _dec_kernel(pos_ref, q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
                *, block_k: int, groups: int, sm_scale: float, seq_k: int):
    ki = pl.program_id(1)
    nk = pl.num_programs(1)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    pos = pos_ref[0]
    k_start = ki * block_k

    @pl.when(k_start <= pos)
    def _compute():
        q = q_ref[0].astype(jnp.float32)                      # (H, hd)
        k = k_ref[0].astype(jnp.float32)                      # (bk, KV, hd)
        v = v_ref[0].astype(jnp.float32)
        krow = k_start + jax.lax.broadcasted_iota(jnp.int32, k.shape, 0)
        k = jnp.where(krow < seq_k, k, 0.0)
        v = jnp.where(krow < seq_k, v, 0.0)
        H, hd = q.shape
        KV = k.shape[1]
        qg = q.reshape(KV, groups, hd)
        # scores (KV, g, bk)
        s = jax.lax.dot_general(qg, k, (((2,), (2,)), ((0,), (1,))),
                                preferred_element_type=jnp.float32)
        s = s * sm_scale
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 2)
        ok = (kpos <= pos) & (kpos < seq_k)
        s = jnp.where(ok, s, NEG_INF)
        sf = s.reshape(H, -1)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(sf, axis=1))
        p = jnp.exp(sf - m_new[:, None]).reshape(KV, groups, -1)
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = (l_scr[...] * alpha
                      + jnp.sum(p.reshape(H, -1), axis=1))
        pv = jax.lax.dot_general(p, v, (((2,), (0,)), ((0,), (1,))),
                                 preferred_element_type=jnp.float32)
        acc_scr[...] = acc_scr[...] * alpha[:, None] + pv.reshape(H, -1)
        m_scr[...] = m_new

    @pl.when(ki == nk - 1)
    def _finish():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


def decode_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                     pos: jnp.ndarray, *, block_k: int = 256,
                     interpret: bool = True) -> jnp.ndarray:
    """q (b,H,hd); k,v (b,S,KV,hd); pos (b,) int32. Returns (b,H,hd)."""
    b, H, hd = q.shape
    S, KV = k.shape[1], k.shape[2]
    g = H // KV
    block_k = min(block_k, S)
    nk = pl.cdiv(S, block_k)
    kernel = functools.partial(_dec_kernel, block_k=block_k, groups=g,
                               sm_scale=1.0 / math.sqrt(hd), seq_k=S)
    from jax.experimental.pallas import tpu as pltpu
    return pl.pallas_call(
        kernel,
        grid=(b, nk),
        in_specs=[
            pl.BlockSpec((1,), dense_pos_index_map),
            pl.BlockSpec((1, H, hd), dense_q_index_map),
            pl.BlockSpec((1, block_k, KV, hd), dense_kv_index_map),
            pl.BlockSpec((1, block_k, KV, hd), dense_kv_index_map),
        ],
        out_specs=pl.BlockSpec((1, H, hd), dense_q_index_map),
        out_shape=jax.ShapeDtypeStruct((b, H, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((H,), jnp.float32),
            pltpu.VMEM((H,), jnp.float32),
            pltpu.VMEM((H, hd), jnp.float32),
        ],
        interpret=interpret,
    )(pos.astype(jnp.int32), q, k, v)


def _paged_kernel(tables_ref, pos_ref, q_ref, k_ref, v_ref, o_ref,
                  m_scr, l_scr, acc_scr, *, block_b: int, groups: int,
                  sm_scale: float):
    bi = pl.program_id(0)
    ti = pl.program_id(1)
    nt = pl.num_programs(1)

    @pl.when(ti == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    pos = pos_ref[bi]
    k_start = ti * block_b          # logical position of this block's row 0

    @pl.when(k_start <= pos)
    def _compute():
        q = q_ref[0].astype(jnp.float32)                      # (H, hd)
        k = k_ref[0].astype(jnp.float32)                      # (B, KV, hd)
        v = v_ref[0].astype(jnp.float32)
        H, hd = q.shape
        KV = k.shape[1]
        qg = q.reshape(KV, groups, hd)
        s = jax.lax.dot_general(qg, k, (((2,), (2,)), ((0,), (1,))),
                                preferred_element_type=jnp.float32)
        s = s * sm_scale                                      # (KV, g, B)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 2)
        s = jnp.where(kpos <= pos, s, NEG_INF)
        sf = s.reshape(H, -1)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(sf, axis=1))
        p = jnp.exp(sf - m_new[:, None]).reshape(KV, groups, -1)
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * alpha + jnp.sum(p.reshape(H, -1), axis=1)
        pv = jax.lax.dot_general(p, v, (((2,), (0,)), ((0,), (1,))),
                                 preferred_element_type=jnp.float32)
        acc_scr[...] = acc_scr[...] * alpha[:, None] + pv.reshape(H, -1)
        m_scr[...] = m_new

    @pl.when(ti == nt - 1)
    def _finish():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


def _chunk_kernel(tables_ref, pos_ref, q_ref, k_ref, v_ref, o_ref,
                  m_scr, l_scr, acc_scr, *, block_b: int, groups: int,
                  chunk: int, sm_scale: float):
    bi = pl.program_id(0)
    ti = pl.program_id(1)
    nt = pl.num_programs(1)

    @pl.when(ti == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    pos = pos_ref[bi]               # chunk start position
    k_start = ti * block_b

    # skip blocks wholly beyond the *last* query's position. Rows whose
    # own position is below k_start mask to all-NEG_INF here, but their
    # running max is already finite (their ti=0 block always has a valid
    # key), so exp(s - m) underflows to exact 0 — no 0/0.
    @pl.when(k_start <= pos + chunk - 1)
    def _compute():
        q = q_ref[0].astype(jnp.float32)                      # (C, H, hd)
        k = k_ref[0].astype(jnp.float32)                      # (B, KV, hd)
        v = v_ref[0].astype(jnp.float32)
        C, H, hd = q.shape
        KV = k.shape[1]
        # fold the chunk axis into the grouped-row axis: (KV, C*g, hd)
        qg = q.reshape(C, KV, groups, hd).transpose(1, 0, 2, 3)
        qg = qg.reshape(KV, C * groups, hd)
        s = jax.lax.dot_general(qg, k, (((2,), (2,)), ((0,), (1,))),
                                preferred_element_type=jnp.float32)
        s = s * sm_scale                                      # (KV, C*g, B)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 2)
        qpos = pos + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1) // groups
        s = jnp.where(kpos <= qpos, s, NEG_INF)
        sf = s.reshape(C * H, -1)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(sf, axis=1))
        p = jnp.exp(sf - m_new[:, None]).reshape(KV, C * groups, -1)
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * alpha + jnp.sum(p.reshape(C * H, -1),
                                                  axis=1)
        pv = jax.lax.dot_general(p, v, (((2,), (0,)), ((0,), (1,))),
                                 preferred_element_type=jnp.float32)
        acc_scr[...] = acc_scr[...] * alpha[:, None] + pv.reshape(C * H, -1)
        m_scr[...] = m_new

    @pl.when(ti == nt - 1)
    def _finish():
        l = jnp.maximum(l_scr[...], 1e-30)
        o = acc_scr[...] / l[:, None]                         # (C*H, hd)
        hd = o.shape[-1]
        # scratch rows are (KV, C, g)-ordered; emit (C, H=KV*g, hd)
        o = o.reshape(-1, chunk, groups, hd).transpose(1, 0, 2, 3)
        o_ref[0] = o.reshape(chunk, -1, hd).astype(o_ref.dtype)


def paged_decode_attention(q: jnp.ndarray, k_blocks: jnp.ndarray,
                           v_blocks: jnp.ndarray, tables: jnp.ndarray,
                           pos: jnp.ndarray, *,
                           interpret: bool = True) -> jnp.ndarray:
    """Flash decoding over a paged KV store.

    q (b, H, hd); k_blocks, v_blocks (n_blocks, B, KV, hd);
    tables (b, T) int32 physical block ids (entries past the live length
    may point anywhere — rows beyond `pos` are masked); pos (b,) int32.
    Returns (b, H, hd). Logical position of table entry t, row j is
    t*B + j, so validity is the same `<= pos` rule as the dense kernel.
    """
    from jax.experimental.pallas import tpu as pltpu
    b, H, hd = q.shape
    B, KV = k_blocks.shape[1], k_blocks.shape[2]
    T = tables.shape[1]
    g = H // KV
    kernel = functools.partial(_paged_kernel, block_b=B, groups=g,
                               sm_scale=1.0 / math.sqrt(hd))
    kv_map = paged_kv_index_map(B)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,                  # tables, pos
        grid=(b, T),
        in_specs=[
            pl.BlockSpec((1, H, hd), paged_q_index_map),
            pl.BlockSpec((1, B, KV, hd), kv_map),
            pl.BlockSpec((1, B, KV, hd), kv_map),
        ],
        out_specs=pl.BlockSpec((1, H, hd), paged_q_index_map),
        scratch_shapes=[
            pltpu.VMEM((H,), jnp.float32),
            pltpu.VMEM((H,), jnp.float32),
            pltpu.VMEM((H, hd), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, H, hd), q.dtype),
        interpret=interpret,
    )(tables.astype(jnp.int32), pos.astype(jnp.int32), q, k_blocks, v_blocks)


def paged_chunk_attention(q: jnp.ndarray, k_blocks: jnp.ndarray,
                          v_blocks: jnp.ndarray, tables: jnp.ndarray,
                          pos: jnp.ndarray, *,
                          interpret: bool = True) -> jnp.ndarray:
    """Varlen chunked-prefill flash attention over a paged KV store.

    q (b, C, H, hd) — up to C consecutive query tokens per sequence at
    positions pos[b] .. pos[b]+C-1 (the chunk's K/V rows are already in
    the block store); k_blocks, v_blocks (n_blocks, B, KV, hd);
    tables (b, T); pos (b,) int32 chunk start. Returns (b, C, H, hd).
    Causality is the per-row rule `kpos <= pos + c`, so rows past a
    sequence's true chunk length just compute garbage the host discards
    (they never write — the scatter happened before the kernel).
    Interpret-mode is the tested path on CPU; the (C*H)-row scratch and
    the final (KV,C,g)->(C,KV*g) transpose lower on TPU like the dense
    kernel's reshapes but are not lowering-tested here."""
    from jax.experimental.pallas import tpu as pltpu
    b, C, H, hd = q.shape
    B, KV = k_blocks.shape[1], k_blocks.shape[2]
    T = tables.shape[1]
    g = H // KV
    kernel = functools.partial(_chunk_kernel, block_b=B, groups=g,
                               chunk=C, sm_scale=1.0 / math.sqrt(hd))
    kv_map = chunk_kv_index_map(B, C)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,                  # tables, pos
        grid=(b, T),
        in_specs=[
            pl.BlockSpec((1, C, H, hd), paged_chunk_q_index_map),
            pl.BlockSpec((1, B, KV, hd), kv_map),
            pl.BlockSpec((1, B, KV, hd), kv_map),
        ],
        out_specs=pl.BlockSpec((1, C, H, hd), paged_chunk_q_index_map),
        scratch_shapes=[
            pltpu.VMEM((C * H,), jnp.float32),
            pltpu.VMEM((C * H,), jnp.float32),
            pltpu.VMEM((C * H, hd), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, C, H, hd), q.dtype),
        interpret=interpret,
    )(tables.astype(jnp.int32), pos.astype(jnp.int32), q, k_blocks, v_blocks)


# ----------------------------------------------------------------------------
# int8 quantized paged kernels: dequant fused into the online-softmax loop.
#
# Deliberate duplicates of `_paged_kernel` / `_chunk_kernel` (not a shared
# parameterized body): the fp kernels back token-bitwise reproducibility
# gates, so the quant path must not perturb their traced graphs. Each K/V
# tile is dequantized in VMEM right after the DMA — `int8 tile * scale`
# with the (1, 1, KVp) scale tile gathered through the same clamped block
# id — so no fp cache is ever materialized in HBM and the bytes streamed
# per step drop ~4x on the bandwidth-bound configs.
# ----------------------------------------------------------------------------

def _paged_quant_kernel(tables_ref, pos_ref, q_ref, k_ref, ks_ref, v_ref,
                        vs_ref, o_ref, m_scr, l_scr, acc_scr, *,
                        block_b: int, groups: int, sm_scale: float):
    bi = pl.program_id(0)
    ti = pl.program_id(1)
    nt = pl.num_programs(1)

    @pl.when(ti == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    pos = pos_ref[bi]
    k_start = ti * block_b          # logical position of this block's row 0

    @pl.when(k_start <= pos)
    def _compute():
        q = q_ref[0].astype(jnp.float32)                      # (H, hd)
        # fused dequant: int8 tile * per-(block, kv-head) scale
        k = k_ref[0].astype(jnp.float32) * ks_ref[0, 0][None, :, None]
        v = v_ref[0].astype(jnp.float32) * vs_ref[0, 0][None, :, None]
        H, hd = q.shape
        KV = k.shape[1]
        qg = q.reshape(KV, groups, hd)
        s = jax.lax.dot_general(qg, k, (((2,), (2,)), ((0,), (1,))),
                                preferred_element_type=jnp.float32)
        s = s * sm_scale                                      # (KV, g, B)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 2)
        s = jnp.where(kpos <= pos, s, NEG_INF)
        sf = s.reshape(H, -1)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(sf, axis=1))
        p = jnp.exp(sf - m_new[:, None]).reshape(KV, groups, -1)
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * alpha + jnp.sum(p.reshape(H, -1), axis=1)
        pv = jax.lax.dot_general(p, v, (((2,), (0,)), ((0,), (1,))),
                                 preferred_element_type=jnp.float32)
        acc_scr[...] = acc_scr[...] * alpha[:, None] + pv.reshape(H, -1)
        m_scr[...] = m_new

    @pl.when(ti == nt - 1)
    def _finish():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


def _chunk_quant_kernel(tables_ref, pos_ref, q_ref, k_ref, ks_ref, v_ref,
                        vs_ref, o_ref, m_scr, l_scr, acc_scr, *,
                        block_b: int, groups: int, chunk: int,
                        sm_scale: float):
    bi = pl.program_id(0)
    ti = pl.program_id(1)
    nt = pl.num_programs(1)

    @pl.when(ti == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    pos = pos_ref[bi]               # chunk start position
    k_start = ti * block_b

    @pl.when(k_start <= pos + chunk - 1)
    def _compute():
        q = q_ref[0].astype(jnp.float32)                      # (C, H, hd)
        k = k_ref[0].astype(jnp.float32) * ks_ref[0, 0][None, :, None]
        v = v_ref[0].astype(jnp.float32) * vs_ref[0, 0][None, :, None]
        C, H, hd = q.shape
        KV = k.shape[1]
        qg = q.reshape(C, KV, groups, hd).transpose(1, 0, 2, 3)
        qg = qg.reshape(KV, C * groups, hd)
        s = jax.lax.dot_general(qg, k, (((2,), (2,)), ((0,), (1,))),
                                preferred_element_type=jnp.float32)
        s = s * sm_scale                                      # (KV, C*g, B)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 2)
        qpos = pos + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1) // groups
        s = jnp.where(kpos <= qpos, s, NEG_INF)
        sf = s.reshape(C * H, -1)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(sf, axis=1))
        p = jnp.exp(sf - m_new[:, None]).reshape(KV, C * groups, -1)
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * alpha + jnp.sum(p.reshape(C * H, -1),
                                                  axis=1)
        pv = jax.lax.dot_general(p, v, (((2,), (0,)), ((0,), (1,))),
                                 preferred_element_type=jnp.float32)
        acc_scr[...] = acc_scr[...] * alpha[:, None] + pv.reshape(C * H, -1)
        m_scr[...] = m_new

    @pl.when(ti == nt - 1)
    def _finish():
        l = jnp.maximum(l_scr[...], 1e-30)
        o = acc_scr[...] / l[:, None]                         # (C*H, hd)
        hd = o.shape[-1]
        o = o.reshape(-1, chunk, groups, hd).transpose(1, 0, 2, 3)
        o_ref[0] = o.reshape(chunk, -1, hd).astype(o_ref.dtype)


def paged_decode_attention_quant(q: jnp.ndarray, k_blocks: jnp.ndarray,
                                 k_scales: jnp.ndarray,
                                 v_blocks: jnp.ndarray,
                                 v_scales: jnp.ndarray,
                                 tables: jnp.ndarray, pos: jnp.ndarray, *,
                                 interpret: bool = True) -> jnp.ndarray:
    """Flash decoding over an int8 quantized paged KV store.

    q (b, H, hd); k_blocks, v_blocks (n_blocks, B, KV, hd) int8;
    k_scales, v_scales (n_blocks, 1, KV) fp32 per-(block, kv-head) scales;
    tables (b, T); pos (b,). Returns (b, H, hd). Identical math to
    `paged_decode_attention` after the in-VMEM dequant of each tile.
    """
    from jax.experimental.pallas import tpu as pltpu
    b, H, hd = q.shape
    B, KV = k_blocks.shape[1], k_blocks.shape[2]
    T = tables.shape[1]
    g = H // KV
    kernel = functools.partial(_paged_quant_kernel, block_b=B, groups=g,
                               sm_scale=1.0 / math.sqrt(hd))
    kv_map = paged_kv_index_map(B)
    scale_map = paged_scale_index_map(B)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,                  # tables, pos
        grid=(b, T),
        in_specs=[
            pl.BlockSpec((1, H, hd), paged_q_index_map),
            pl.BlockSpec((1, B, KV, hd), kv_map),
            pl.BlockSpec((1, 1, KV), scale_map),
            pl.BlockSpec((1, B, KV, hd), kv_map),
            pl.BlockSpec((1, 1, KV), scale_map),
        ],
        out_specs=pl.BlockSpec((1, H, hd), paged_q_index_map),
        scratch_shapes=[
            pltpu.VMEM((H,), jnp.float32),
            pltpu.VMEM((H,), jnp.float32),
            pltpu.VMEM((H, hd), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, H, hd), q.dtype),
        interpret=interpret,
    )(tables.astype(jnp.int32), pos.astype(jnp.int32), q,
      k_blocks, k_scales, v_blocks, v_scales)


def paged_chunk_attention_quant(q: jnp.ndarray, k_blocks: jnp.ndarray,
                                k_scales: jnp.ndarray,
                                v_blocks: jnp.ndarray,
                                v_scales: jnp.ndarray,
                                tables: jnp.ndarray, pos: jnp.ndarray, *,
                                interpret: bool = True) -> jnp.ndarray:
    """Varlen chunked-prefill flash attention over an int8 quantized paged
    KV store; quantized twin of `paged_chunk_attention` (same causality and
    scratch layout, dequant fused per tile)."""
    from jax.experimental.pallas import tpu as pltpu
    b, C, H, hd = q.shape
    B, KV = k_blocks.shape[1], k_blocks.shape[2]
    T = tables.shape[1]
    g = H // KV
    kernel = functools.partial(_chunk_quant_kernel, block_b=B, groups=g,
                               chunk=C, sm_scale=1.0 / math.sqrt(hd))
    kv_map = chunk_kv_index_map(B, C)
    scale_map = chunk_scale_index_map(B, C)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,                  # tables, pos
        grid=(b, T),
        in_specs=[
            pl.BlockSpec((1, C, H, hd), paged_chunk_q_index_map),
            pl.BlockSpec((1, B, KV, hd), kv_map),
            pl.BlockSpec((1, 1, KV), scale_map),
            pl.BlockSpec((1, B, KV, hd), kv_map),
            pl.BlockSpec((1, 1, KV), scale_map),
        ],
        out_specs=pl.BlockSpec((1, C, H, hd), paged_chunk_q_index_map),
        scratch_shapes=[
            pltpu.VMEM((C * H,), jnp.float32),
            pltpu.VMEM((C * H,), jnp.float32),
            pltpu.VMEM((C * H, hd), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, C, H, hd), q.dtype),
        interpret=interpret,
    )(tables.astype(jnp.int32), pos.astype(jnp.int32), q,
      k_blocks, k_scales, v_blocks, v_scales)
