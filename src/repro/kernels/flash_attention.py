"""Flash attention forward (TPU Pallas target).

Tiling: grid (batch, q_heads, n_q_blocks, n_k_blocks) with the k axis
innermost/sequential; (block_q x head_dim) q tiles and (block_k x head_dim)
k/v tiles live in VMEM, the (block_q x block_k) score tile feeds the MXU,
and the online-softmax running stats (m, l, acc) persist in VMEM scratch
across the k loop. Causal / sliding-window blocks that are fully masked are
skipped with @pl.when (no MXU work issued). GQA is handled in the k/v
BlockSpec index maps (head h reads kv head h // group) — no repeated KV in
HBM.

Block sizes default to 128x128: MXU-aligned (128 lanes) and small enough
that q,k,v,acc tiles (4 x 128 x head_dim x 4B) stay well under VMEM.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def q_index_map(bi, hi, qi, ki):
    """q / output tiles: one (block_q, hd) tile per (batch, head, q block);
    constant in ki so the tile stays resident across the k loop."""
    return (bi, hi, qi, 0)


def gqa_kv_index_map(group: int):
    """k/v tiles under GQA: query head h reads kv head h // group, so the
    KV tensor is never repeated in HBM. Module-level (audited by
    `repro.analysis.blockspecs` over the full grid)."""
    def kv_map(bi, hi, qi, ki):
        return (bi, hi // group, ki, 0)
    return kv_map


def _fa_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
               block_q: int, block_k: int, sm_scale: float, causal: bool,
               window: int, seq_k: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = qi * block_q
    k_start = ki * block_k
    # static-shape block skip decisions must be dynamic on grid ids:
    run = jnp.bool_(True)
    if causal:
        run &= k_start <= q_start + block_q - 1
    if window > 0:
        run &= (k_start + block_k - 1) >= q_start - window + 1

    @pl.when(run)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)                   # (bq, hd)
        k = k_ref[0, 0].astype(jnp.float32)                   # (bk, hd)
        v = v_ref[0, 0].astype(jnp.float32)
        # zero the ragged tail (OOB block rows may hold garbage: 0 * NaN)
        krow = k_start + jax.lax.broadcasted_iota(jnp.int32, k.shape, 0)
        k = jnp.where(krow < seq_k, k, 0.0)
        v = jnp.where(krow < seq_k, v, 0.0)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        s = s * sm_scale                                      # (bq, bk)
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        ok = kpos < seq_k
        if causal:
            ok &= qpos >= kpos
        if window > 0:
            ok &= (qpos - kpos) < window
        s = jnp.where(ok, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=1)
        acc_scr[...] = (acc_scr[...] * alpha[:, None]
                        + jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                                              preferred_element_type=jnp.float32))
        m_scr[...] = m_new

    @pl.when(ki == nk - 1)
    def _finish():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    causal: bool = True, window: int = 0,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = True) -> jnp.ndarray:
    """q (b,sq,H,hd); k,v (b,sk,KV,hd), H % KV == 0. Returns (b,sq,H,hd).

    Assumes sq == sk (self-attention; right-aligned positions otherwise are
    handled by the decode kernel).
    """
    b, sq, H, hd = q.shape
    sk, KV = k.shape[1], k.shape[2]
    g = H // KV
    sm_scale = 1.0 / math.sqrt(hd)
    block_q = min(block_q, sq)
    block_k = min(block_k, sk)
    nq = pl.cdiv(sq, block_q)
    nk = pl.cdiv(sk, block_k)
    # layout: (b, heads, seq, hd)
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    kernel = functools.partial(
        _fa_kernel, block_q=block_q, block_k=block_k, sm_scale=sm_scale,
        causal=causal, window=window, seq_k=sk)
    out = pl.pallas_call(
        kernel,
        grid=(b, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, hd), q_index_map),
            pl.BlockSpec((1, 1, block_k, hd), gqa_kv_index_map(g)),
            pl.BlockSpec((1, 1, block_k, hd), gqa_kv_index_map(g)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, hd), q_index_map),
        out_shape=jax.ShapeDtypeStruct((b, H, sq, hd), q.dtype),
        scratch_shapes=[
            pltpu_vmem((block_q,), jnp.float32),
            pltpu_vmem((block_q,), jnp.float32),
            pltpu_vmem((block_q, hd), jnp.float32),
        ],
        interpret=interpret,
    )(qt, kt, vt)
    return out.transpose(0, 2, 1, 3)


def pltpu_vmem(shape, dtype):
    from jax.experimental.pallas import tpu as pltpu
    return pltpu.VMEM(shape, dtype)
