"""Audit registry for the Pallas kernels in this package.

Every kernel wrapper registers its grid and its *production* BlockSpec
index maps (the same module-level functions `pl.pallas_call` receives)
together with toy-but-representative grid extents and scalar-prefetch
arguments. `repro.analysis.blockspecs` evaluates each map concretely
over the FULL grid — including iterations the kernel body skips with
`@pl.when`, because index maps feed the DMA pipeline whether or not the
compute runs — and fails if any returned block coordinate falls outside
its legal extent.

For block-table gathers (the paged kernels) the registry plants POISON
physical block ids in every table entry past the row's live length.
The legal extent of the gathered axis is set below POISON, so a map
that forgets the `jnp.minimum(ti, live_last_block)` clamp fetches a
poison id and trips the checker: the unclamped-index-map bug (dead
horizon blocks streaming through the DMA pipeline) is a regression
class here, not a memory.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, List, Tuple

import numpy as np

from . import cross_entropy as _ce
from . import decode_attention as _dec
from . import flash_attention as _fa
from . import ssm_scan as _ssm

# any physical block id >= POISON marks a table entry the row does not
# own live data in (padding, or preallocated-but-unwritten horizon
# blocks). A correct gather map must never return one.
POISON = 1_000_000


@dataclass(frozen=True)
class IndexMapAudit:
    """One (kernel, operand) BlockSpec index map plus the toy grid to
    evaluate it over. `extents[k]` bounds returned coordinate k:
    0 <= coord < extent. For gathered axes the extent is POISON, so
    poison table entries are out of bounds by construction."""
    kernel: str
    operand: str
    grid: Tuple[int, ...]
    index_map: Callable
    extents: Tuple[int, ...]
    scalar_args: Tuple = ()
    notes: str = ""


def poison_tables(live_blocks, n_table: int) -> np.ndarray:
    """Block tables (b, n_table): row i owns `live_blocks[i]` live
    physical blocks (distinct small ids); every later entry is poison."""
    rows = []
    next_id = 1                      # id 0 is the pool's null block
    for n_live in live_blocks:
        row = []
        for j in range(n_table):
            if j < n_live:
                row.append(next_id)
                next_id += 1
            else:
                row.append(POISON + j)
        rows.append(row)
    return np.asarray(rows, dtype=np.int32)


def default_audits() -> List[IndexMapAudit]:
    """The shipped kernels' index maps over representative toy grids."""
    audits: List[IndexMapAudit] = []

    # --- paged_decode_attention: grid (b, T), scalars (tables, pos) ---
    B, T = 4, 5
    pos = np.asarray([0, 5, 19], dtype=np.int32)       # live blocks 1, 2, 5
    tables = poison_tables([int(p) // B + 1 for p in pos], T)
    audits += [
        IndexMapAudit("paged_decode_attention", "k/v",
                      grid=(len(pos), T),
                      index_map=_dec.paged_kv_index_map(B),
                      extents=(POISON, 1, 1, 1),
                      scalar_args=(tables, pos),
                      notes="block-table gather; must clamp to the row's "
                            "last live block (pos // B)"),
        IndexMapAudit("paged_decode_attention", "q/out",
                      grid=(len(pos), T),
                      index_map=_dec.paged_q_index_map,
                      extents=(len(pos), 1, 1),
                      scalar_args=(tables, pos)),
    ]

    # --- paged_chunk_attention: grid (b, T), scalars (tables, pos) ---
    C = 3
    cpos = np.asarray([0, 2, 9], dtype=np.int32)   # last query pos + C - 1
    ctables = poison_tables([(int(p) + C - 1) // B + 1 for p in cpos], T)
    audits += [
        IndexMapAudit("paged_chunk_attention", "k/v",
                      grid=(len(cpos), T),
                      index_map=_dec.chunk_kv_index_map(B, C),
                      extents=(POISON, 1, 1, 1),
                      scalar_args=(ctables, cpos),
                      notes="gather bound is the last block any chunk row "
                            "can see ((pos + C - 1) // B)"),
        IndexMapAudit("paged_chunk_attention", "q/out",
                      grid=(len(cpos), T),
                      index_map=_dec.paged_chunk_q_index_map,
                      extents=(len(cpos), 1, 1, 1),
                      scalar_args=(ctables, cpos)),
    ]

    # --- quantized twins: same grids/tables, plus (block, 1, KVp) scale
    # tiles gathered through the same clamped block id ---
    audits += [
        IndexMapAudit("paged_decode_attention_quant", "k/v (int8)",
                      grid=(len(pos), T),
                      index_map=_dec.paged_kv_index_map(B),
                      extents=(POISON, 1, 1, 1),
                      scalar_args=(tables, pos)),
        IndexMapAudit("paged_decode_attention_quant", "k/v scales",
                      grid=(len(pos), T),
                      index_map=_dec.paged_scale_index_map(B),
                      extents=(POISON, 1, 1),
                      scalar_args=(tables, pos),
                      notes="scale tile rides its block id; an unclamped "
                            "map would DMA a poison scale row"),
        IndexMapAudit("paged_decode_attention_quant", "q/out",
                      grid=(len(pos), T),
                      index_map=_dec.paged_q_index_map,
                      extents=(len(pos), 1, 1),
                      scalar_args=(tables, pos)),
        IndexMapAudit("paged_chunk_attention_quant", "k/v (int8)",
                      grid=(len(cpos), T),
                      index_map=_dec.chunk_kv_index_map(B, C),
                      extents=(POISON, 1, 1, 1),
                      scalar_args=(ctables, cpos)),
        IndexMapAudit("paged_chunk_attention_quant", "k/v scales",
                      grid=(len(cpos), T),
                      index_map=_dec.chunk_scale_index_map(B, C),
                      extents=(POISON, 1, 1),
                      scalar_args=(ctables, cpos),
                      notes="chunk gather bound (pos + C - 1) // B applies "
                            "to the scale store too"),
        IndexMapAudit("paged_chunk_attention_quant", "q/out",
                      grid=(len(cpos), T),
                      index_map=_dec.paged_chunk_q_index_map,
                      extents=(len(cpos), 1, 1, 1),
                      scalar_args=(ctables, cpos)),
    ]

    # --- decode_attention (dense): grid (b, n_kv_blocks) ---
    b, nk = 2, 4
    audits += [
        IndexMapAudit("decode_attention", "pos", (b, nk),
                      _dec.dense_pos_index_map, (b,)),
        IndexMapAudit("decode_attention", "q/out", (b, nk),
                      _dec.dense_q_index_map, (b, 1, 1)),
        IndexMapAudit("decode_attention", "k/v", (b, nk),
                      _dec.dense_kv_index_map, (b, nk, 1, 1)),
    ]

    # --- flash_attention: grid (b, H, nq, nk), GQA group g ---
    fb, H, KV, nq, fnk = 2, 4, 2, 3, 3
    g = H // KV
    audits += [
        IndexMapAudit("flash_attention", "q/out", (fb, H, nq, fnk),
                      _fa.q_index_map, (fb, H, nq, 1)),
        IndexMapAudit("flash_attention", "k/v", (fb, H, nq, fnk),
                      _fa.gqa_kv_index_map(g), (fb, KV, fnk, 1),
                      notes="GQA: head h reads kv head h // g; the kv-head "
                            "extent is KV, not H"),
    ]

    # --- ssm_scan: grid (bsz, nd, nc) ---
    bsz, nd, nc = 2, 3, 4
    audits += [
        IndexMapAudit("ssm_scan", "dt/x/y", (bsz, nd, nc),
                      _ssm.chan_index_map, (bsz, nc, nd)),
        IndexMapAudit("ssm_scan", "A", (bsz, nd, nc),
                      _ssm.a_index_map, (nd, 1)),
        IndexMapAudit("ssm_scan", "B/C", (bsz, nd, nc),
                      _ssm.state_seq_index_map, (bsz, nc, 1)),
        IndexMapAudit("ssm_scan", "hT", (bsz, nd, nc),
                      _ssm.state_out_index_map, (bsz, nd, 1)),
    ]

    # --- cross_entropy: grid (nr, nv) ---
    nr, nv = 2, 3
    audits += [
        IndexMapAudit("cross_entropy", "logits", (nr, nv),
                      _ce.tile_index_map, (nr, nv)),
        IndexMapAudit("cross_entropy", "labels/loss", (nr, nv),
                      _ce.row_index_map, (nr,)),
    ]
    return audits


#: kernel wrapper names the audits above cover; `repro.analysis.blockspecs`
#: cross-checks this against every `pl.pallas_call`-wrapping function it
#: finds in the package source, so adding a kernel without registering an
#: audit is itself a finding.
AUDITED_KERNELS = (
    "decode_attention", "paged_decode_attention", "paged_chunk_attention",
    "paged_decode_attention_quant", "paged_chunk_attention_quant",
    "flash_attention", "ssm_scan", "cross_entropy",
)
