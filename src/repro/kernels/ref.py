"""Pure-jnp oracles for every Pallas kernel (the allclose reference)."""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp


def flash_attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                        causal: bool = True, window: int = 0) -> jnp.ndarray:
    """q (b,sq,H,hd); k,v (b,sk,KV,hd) with H % KV == 0. fp32 softmax."""
    b, sq, H, hd = q.shape
    KV = k.shape[2]
    g = H // KV
    if g > 1:
        k = jnp.repeat(k, g, axis=2)
        v = jnp.repeat(v, g, axis=2)
    scores = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) / math.sqrt(hd)
    sk = k.shape[1]
    qpos = jnp.arange(sq)[:, None] + (sk - sq)   # right-aligned positions
    kpos = jnp.arange(sk)[None, :]
    ok = jnp.ones((sq, sk), bool)
    if causal:
        ok &= qpos >= kpos
    if window > 0:
        ok &= (qpos - kpos) < window
    scores = jnp.where(ok[None, None], scores, -jnp.inf)
    w = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", w,
                      v.astype(jnp.float32)).astype(q.dtype)


def decode_attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                         pos: jnp.ndarray) -> jnp.ndarray:
    """q (b,H,hd); k,v (b,S,KV,hd); pos (b,) — attends slots <= pos."""
    b, H, hd = q.shape
    S, KV = k.shape[1], k.shape[2]
    g = H // KV
    kk = jnp.repeat(k, g, axis=2) if g > 1 else k
    vv = jnp.repeat(v, g, axis=2) if g > 1 else v
    scores = jnp.einsum("bhd,bshd->bhs", q.astype(jnp.float32),
                        kk.astype(jnp.float32)) / math.sqrt(hd)
    valid = jnp.arange(S)[None, :] <= pos[:, None]
    scores = jnp.where(valid[:, None, :], scores, -jnp.inf)
    w = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bhs,bshd->bhd", w,
                      vv.astype(jnp.float32)).astype(q.dtype)


def ssm_scan_ref(dt: jnp.ndarray, A: jnp.ndarray, B: jnp.ndarray,
                 C: jnp.ndarray, x: jnp.ndarray,
                 h0: Optional[jnp.ndarray] = None):
    """Sequential selective scan (fp64-free oracle, fp32 math).

    dt,x (b,s,d); A (d,n); B,C (b,s,n); h0 (b,d,n).
    Returns (y (b,s,d), hT (b,d,n)).
    """
    bsz, s, d = x.shape
    n = A.shape[1]
    if h0 is None:
        h0 = jnp.zeros((bsz, d, n), jnp.float32)

    def step(h, args):
        dt_t, B_t, C_t, x_t = args
        dA = jnp.exp(dt_t[..., None] * A[None])              # (b,d,n)
        h = h * dA + (dt_t * x_t)[..., None] * B_t[:, None, :]
        y = jnp.einsum("bdn,bn->bd", h, C_t)
        return h, y

    args = (dt.swapaxes(0, 1).astype(jnp.float32),
            B.swapaxes(0, 1).astype(jnp.float32),
            C.swapaxes(0, 1).astype(jnp.float32),
            x.swapaxes(0, 1).astype(jnp.float32))
    hT, ys = jax.lax.scan(step, h0, args)
    return ys.swapaxes(0, 1), hT


def cross_entropy_ref(logits: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    """Per-row CE loss. logits (n,V); labels (n,) -> (n,) fp32."""
    lf = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(lf, axis=-1)
    gold = jnp.take_along_axis(lf, labels[:, None], axis=1)[:, 0]
    return lse - gold
