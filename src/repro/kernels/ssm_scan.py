"""Chunked selective scan (Mamba) — TPU Pallas target.

Grid (batch, n_d_blocks, n_chunks) with chunks innermost/sequential. Each
step loads a (chunk_len x d_block) tile of dt/x and (chunk_len x d_state)
B/C into VMEM, runs the recurrence time-step-by-time-step on the VPU
(elementwise (d_block x d_state) updates — the TPU-idiomatic port of
Mamba's CUDA parallel scan: parallel over channels, sequential in time,
chunked so the carried state (d_block x d_state) lives in VMEM scratch),
and writes the (chunk_len x d_block) outputs.

The wrapper also returns the final state (needed for prefill -> decode
handoff), read back from the scratch on the last chunk.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


# BlockSpec index maps, module-level so `repro.analysis.blockspecs` can
# evaluate the production maps over the full grid against array extents.
def chan_index_map(bi, di, ci):
    """dt / x / y tiles: (1, chunk, d_block) at (batch, chunk ci, d blk di)."""
    return (bi, ci, di)


def a_index_map(bi, di, ci):
    """A tile: (d_block, n) — per d block, constant over batch and chunks."""
    return (di, 0)


def state_seq_index_map(bi, di, ci):
    """B / C tiles: (1, chunk, n) — full state width every chunk."""
    return (bi, ci, 0)


def state_out_index_map(bi, di, ci):
    """hT output: (1, d_block, n) — constant in ci (written on last chunk)."""
    return (bi, di, 0)


def _ssm_kernel(dt_ref, a_ref, b_ref, c_ref, x_ref, y_ref, hT_ref, h_scr, *,
                chunk: int):
    ci = pl.program_id(2)
    nc = pl.num_programs(2)

    @pl.when(ci == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    A = a_ref[...].astype(jnp.float32)                        # (dblk, n)

    def step(t, h):
        dt_t = dt_ref[0, t].astype(jnp.float32)               # (dblk,)
        B_t = b_ref[0, t].astype(jnp.float32)                 # (n,)
        C_t = c_ref[0, t].astype(jnp.float32)                 # (n,)
        x_t = x_ref[0, t].astype(jnp.float32)                 # (dblk,)
        dA = jnp.exp(dt_t[:, None] * A)                       # (dblk,n)
        h = h * dA + (dt_t * x_t)[:, None] * B_t[None, :]
        y_ref[0, t] = (h @ C_t).astype(y_ref.dtype)
        return h

    h = jax.lax.fori_loop(0, chunk, step, h_scr[...])
    h_scr[...] = h

    @pl.when(ci == nc - 1)
    def _finish():
        hT_ref[0] = h_scr[...].astype(hT_ref.dtype)


def ssm_scan(dt: jnp.ndarray, A: jnp.ndarray, B: jnp.ndarray, C: jnp.ndarray,
             x: jnp.ndarray, *, chunk: int = 64, d_block: int = 128,
             interpret: bool = True):
    """dt,x (b,s,d); A (d,n); B,C (b,s,n). Returns (y (b,s,d), hT (b,d,n))."""
    bsz, s, d = x.shape
    n = A.shape[1]
    chunk = min(chunk, s)
    d_block = min(d_block, d)
    assert s % chunk == 0 and d % d_block == 0
    nc = s // chunk
    nd = d // d_block
    kernel = functools.partial(_ssm_kernel, chunk=chunk)
    from jax.experimental.pallas import tpu as pltpu
    y, hT = pl.pallas_call(
        kernel,
        grid=(bsz, nd, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, d_block), chan_index_map),
            pl.BlockSpec((d_block, n), a_index_map),
            pl.BlockSpec((1, chunk, n), state_seq_index_map),
            pl.BlockSpec((1, chunk, n), state_seq_index_map),
            pl.BlockSpec((1, chunk, d_block), chan_index_map),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, d_block), chan_index_map),
            pl.BlockSpec((1, d_block, n), state_out_index_map),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bsz, s, d), x.dtype),
            jax.ShapeDtypeStruct((bsz, d, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((d_block, n), jnp.float32)],
        interpret=interpret,
    )(dt, A, B, C, x)
    return y, hT
