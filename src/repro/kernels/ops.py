"""jit'd public wrappers for the Pallas kernels.

On this CPU container the kernels execute with interpret=True (correctness
mode); on TPU set REPRO_PALLAS_COMPILE=1 (or pass interpret=False) to lower
them for real. The model code selects kernel vs XLA-reference paths via
`use_pallas` flags; the dry-run always uses the XLA path (Pallas-TPU does
not lower on the CPU backend).
"""
from __future__ import annotations

import functools
import os

import jax

from repro.kernels import ref
from repro.kernels.cross_entropy import cross_entropy as _ce
from repro.kernels.decode_attention import decode_attention as _dec
from repro.kernels.decode_attention import paged_chunk_attention as _pchunk
from repro.kernels.decode_attention import (
    paged_chunk_attention_quant as _pchunk_q,
)
from repro.kernels.decode_attention import paged_decode_attention as _pdec
from repro.kernels.decode_attention import (
    paged_decode_attention_quant as _pdec_q,
)
from repro.kernels.flash_attention import flash_attention as _fa
from repro.kernels.ssm_scan import ssm_scan as _ssm


def _interpret_default() -> bool:
    return os.environ.get("REPRO_PALLAS_COMPILE", "0") != "1"


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q",
                                             "block_k", "interpret"))
def flash_attention(q, k, v, *, causal=True, window=0, block_q=128,
                    block_k=128, interpret=None):
    interpret = _interpret_default() if interpret is None else interpret
    return _fa(q, k, v, causal=causal, window=window, block_q=block_q,
               block_k=block_k, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("block_k", "interpret"))
def decode_attention(q, k, v, pos, *, block_k=256, interpret=None):
    interpret = _interpret_default() if interpret is None else interpret
    return _dec(q, k, v, pos, block_k=block_k, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_decode_attention(q, k_blocks, v_blocks, tables, pos, *,
                           interpret=None):
    interpret = _interpret_default() if interpret is None else interpret
    return _pdec(q, k_blocks, v_blocks, tables, pos, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_chunk_attention(q, k_blocks, v_blocks, tables, pos, *,
                         interpret=None):
    interpret = _interpret_default() if interpret is None else interpret
    return _pchunk(q, k_blocks, v_blocks, tables, pos, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_decode_attention_quant(q, k_blocks, k_scales, v_blocks, v_scales,
                                 tables, pos, *, interpret=None):
    interpret = _interpret_default() if interpret is None else interpret
    return _pdec_q(q, k_blocks, k_scales, v_blocks, v_scales, tables, pos,
                   interpret=interpret)


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_chunk_attention_quant(q, k_blocks, k_scales, v_blocks, v_scales,
                                tables, pos, *, interpret=None):
    interpret = _interpret_default() if interpret is None else interpret
    return _pchunk_q(q, k_blocks, k_scales, v_blocks, v_scales, tables, pos,
                     interpret=interpret)


@functools.partial(jax.jit, static_argnames=("chunk", "d_block", "interpret"))
def ssm_scan(dt, A, B, C, x, *, chunk=64, d_block=128, interpret=None):
    interpret = _interpret_default() if interpret is None else interpret
    return _ssm(dt, A, B, C, x, chunk=chunk, d_block=d_block,
                interpret=interpret)


@functools.partial(jax.jit, static_argnames=("block_rows", "block_v",
                                             "interpret"))
def cross_entropy(logits, labels, *, block_rows=128, block_v=2048,
                  interpret=None):
    interpret = _interpret_default() if interpret is None else interpret
    return _ce(logits, labels, block_rows=block_rows, block_v=block_v,
               interpret=interpret)


# re-export oracles for tests/benchmarks
flash_attention_ref = ref.flash_attention_ref
decode_attention_ref = ref.decode_attention_ref
ssm_scan_ref = ref.ssm_scan_ref
cross_entropy_ref = ref.cross_entropy_ref
