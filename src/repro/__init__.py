"""repro: production-grade JAX reproduction of "Learning How Hard to Think:
Input-Adaptive Allocation of LM Computation" (Damani et al., ICLR 2025)."""
__version__ = "0.1.0"
