"""Compile-cache cardinality analyzer.

The serving runtime's compile story rests on two disciplines the git
history shows being violated once each:

* ``jax.jit`` (or ``pl.pallas_call``) must never bind instance state.
  A jitted helper created per runtime/pool instance recompiles per
  instance — the PR 4/5 gotcha that made the second benchmark pool pay
  full XLA compilation again. Hard error here, in four AST shapes:
  a ``@jax.jit``-decorated method, ``self.f = jax.jit(...)``,
  ``jax.jit(self.method)``, and a non-memoized ``jax.jit`` call inside
  a method body (immediately-invoked ``jax.jit(fn)(args)`` is exempt —
  that is construction-time, once, and XLA caches by function object
  only within the expression).

* every tick-program builder must be a module-level ``lru_cache``d
  function (the ``pool_programs_for`` idiom): the cache key is the
  model + static flags, so programs are shared across runtime
  instances. Verified both syntactically (any module-level function
  that returns a locally-defined jitted closure must carry
  ``functools.lru_cache``) and at runtime against
  ``tick_programs.BUILDERS`` (every registered builder has
  ``cache_info``).

The pass also enumerates the static-arg key space reachable from
``plan.py``'s TickPlan — kind x pow2 horizon width x model x cache
layout — via ``plan.compile_cardinality`` and emits the worst-case
compile-count table per config, asserting the bound
``n_models * kva * (2 + 2 * log2(horizon)) + 1 + n_models * kva``
(kva = 2 when the process exercises both the fp and int8-quantized
cache layouts, else 1) the pow2 quantization exists to guarantee.
"""
from __future__ import annotations

import ast
import math
from pathlib import Path
from typing import List, Optional

from repro.analysis.common import (Finding, PassResult, apply_suppressions,
                                   assign_occurrences, iter_sources, rel)

PASS_ID = "recompile"
CATEGORY = "recompile"          # allow(recompile)

SUBDIRS = ("src/repro/serving", "src/repro/kernels", "src/repro/models")

#: worst-case configs for the compile-count table:
#: (horizon, n_models, kv_quant) — kv_quant=True means the process
#: exercises BOTH cache layouts (fp and int8+scales, e.g. an A/B
#: capacity probe), doubling every cache-carrying builder's key space
TABLE_CONFIGS = ((1, 1, False), (8, 1, False), (8, 2, False),
                 (8, 2, True), (16, 2, True))


def _dotted(node: ast.AST) -> Optional[str]:
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def _is_jit_expr(node: ast.AST) -> Optional[str]:
    """'jax.jit' / 'pl.pallas_call' (or partial(...) of one) when `node`
    creates a fresh compiled callable, else None."""
    if not isinstance(node, ast.Call):
        return None
    name = _dotted(node.func) or ""
    if name.endswith("partial") and node.args:
        return _is_jit_expr(node.args[0]) or _is_jit_name(node.args[0])
    return _is_jit_name(node.func)


def _is_jit_name(node: ast.AST) -> Optional[str]:
    name = _dotted(node) or ""
    if name.endswith("jit") or name.endswith("pallas_call"):
        return name
    return None


def _decorators(fn) -> List[str]:
    out = []
    for dec in fn.decorator_list:
        target = dec.func if isinstance(dec, ast.Call) else dec
        name = _dotted(target)
        if name:
            out.append(name)
        if isinstance(dec, ast.Call) and dec.args:
            inner = _dotted(dec.args[0])
            if inner:
                out.append(inner)
    return out


def _returns_jitted_closure(fn: ast.FunctionDef) -> bool:
    """Module-level builder pattern: defines a nested function that is
    jit-decorated and returns it."""
    jitted_locals = set()
    for stmt in fn.body:
        if isinstance(stmt, ast.FunctionDef) and any(
                n.endswith("jit") or n.endswith("pallas_call")
                for n in _decorators(stmt)):
            jitted_locals.add(stmt.name)
        if isinstance(stmt, ast.Assign) and _is_jit_expr(stmt.value):
            for t in stmt.targets:
                if isinstance(t, ast.Name):
                    jitted_locals.add(t.id)
    if not jitted_locals:
        return False
    for node in ast.walk(fn):
        if isinstance(node, ast.Return) and isinstance(node.value, ast.Name) \
                and node.value.id in jitted_locals:
            return True
    return False


def _audit_module(tree: ast.Module, relpath: str) -> List[Finding]:
    findings: List[Finding] = []

    def flag(node, code, msg):
        findings.append(Finding(PASS_ID, code, relpath, node.lineno,
                                scope, msg))

    def is_method(fn) -> bool:
        args = fn.args.posonlyargs + fn.args.args
        return bool(args) and args[0].arg in ("self", "cls")

    def visit(node, prefix: str, in_method: bool):
        nonlocal scope
        for child in ast.iter_child_nodes(node):
            if isinstance(child, ast.ClassDef):
                visit(child, f"{prefix}.{child.name}" if prefix
                      else child.name, in_method)
            elif isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                q = f"{prefix}.{child.name}" if prefix else child.name
                scope = q
                meth = is_method(child) and isinstance(node, ast.ClassDef)
                if meth and any(n.endswith("jit") or n.endswith("pallas_call")
                                for n in _decorators(child)):
                    flag(child, "bound-jit",
                         "jit-decorated method: the compiled callable "
                         "binds instance state, so every instance "
                         "recompiles (module-level lru_cached builders "
                         "are the supported idiom)")
                visit(child, q, in_method or meth)
            else:
                scope_stack = scope
                _scan_stmt(child, in_method)
                scope = scope_stack
                visit(child, prefix, in_method)

    def _scan_stmt(stmt, in_method: bool):
        if isinstance(stmt, ast.Assign):
            jname = _is_jit_expr(stmt.value)
            for t in stmt.targets:
                if isinstance(t, ast.Attribute) and \
                        isinstance(t.value, ast.Name) and \
                        t.value.id == "self" and jname:
                    flag(stmt, "bound-jit",
                         f"`self.{t.attr} = {jname}(...)` creates a "
                         "per-instance compile cache; hoist to a "
                         "module-level lru_cached builder")
        if not isinstance(stmt, (ast.Expr, ast.Assign, ast.Return,
                                 ast.AugAssign)):
            return
        for call in [n for n in ast.walk(stmt) if isinstance(n, ast.Call)]:
            jname = _is_jit_name(call.func)
            if not jname:
                continue
            # jax.jit(self.method): the bound method hashes per instance
            for a in call.args:
                adn = _dotted(a) or ""
                if adn.startswith("self."):
                    flag(call, "bound-jit",
                         f"{jname}({adn}) compiles a bound method — "
                         "cache key includes the instance")

    scope = ""
    visit(tree, "", False)

    # module-level builders returning jitted closures must be lru_cached
    for stmt in tree.body:
        if isinstance(stmt, ast.FunctionDef) and \
                _returns_jitted_closure(stmt):
            decs = _decorators(stmt)
            if not any(n.endswith("lru_cache") or n.endswith("cache")
                       for n in decs):
                scope = stmt.name
                findings.append(Finding(
                    PASS_ID, "uncached-builder", relpath, stmt.lineno,
                    stmt.name,
                    f"builder `{stmt.name}` returns a jitted closure but "
                    "is not lru_cached: every call creates a fresh "
                    "compile cache"))
    return findings


def _audit_registry() -> List[Finding]:
    """Runtime half: the builder registry really is memoized, and covers
    exactly the kinds the planner can emit."""
    findings: List[Finding] = []
    from repro.serving import plan, tick_programs
    for kind, builder in tick_programs.BUILDERS.items():
        if not hasattr(builder, "cache_info"):
            findings.append(Finding(
                PASS_ID, "uncached-builder", "src/repro/serving/tick_programs.py",
                0, kind,
                f"BUILDERS[{kind!r}] is not lru_cached"))
    missing = set(plan.PROGRAM_KINDS) - set(tick_programs.BUILDERS)
    for kind in sorted(missing):
        findings.append(Finding(
            PASS_ID, "unregistered-kind", "src/repro/serving/plan.py", 0,
            "PROGRAM_KINDS",
            f"plan can emit kind {kind!r} with no registered builder"))
    return findings


def compile_table() -> dict:
    """Worst-case compile counts per TABLE_CONFIGS entry, with the bound
    each must satisfy."""
    from repro.serving import plan
    rows = {}
    for horizon, n_models, kv_quant in TABLE_CONFIGS:
        counts = plan.compile_cardinality(horizon, n_models=n_models,
                                          kv_quant=kv_quant)
        kva = 2 if kv_quant else 1
        bound = (n_models * kva * (2 + 2 * int(math.log2(max(horizon, 1))))
                 + 1 + n_models * kva)
        rows[f"H={horizon},models={n_models},quant={kv_quant}"] = {
            **counts, "bound": bound, "ok": counts["total"] <= bound}
    return rows


def run(root: Path) -> PassResult:
    result = PassResult(PASS_ID)
    files = iter_sources(root, SUBDIRS)
    for path in files:
        text = path.read_text()
        findings = _audit_module(ast.parse(text), rel(path, root))
        result.findings += apply_suppressions(findings, text, CATEGORY)
    result.report["scanned"] = [rel(p, root) for p in files]
    result.report["suppress_category"] = CATEGORY
    in_repo = (root / "src/repro/serving/tick_programs.py").exists()
    if in_repo:
        result.findings += _audit_registry()
        table = compile_table()
        result.report["compile_table"] = table
        for cfg, row in table.items():
            if not row["ok"]:
                result.findings.append(Finding(
                    PASS_ID, "cardinality", "src/repro/serving/plan.py", 0,
                    "compile_cardinality",
                    f"config {cfg}: worst-case {row['total']} compiles "
                    f"exceeds the bound {row['bound']}"))
    assign_occurrences(result.findings)
    return result
