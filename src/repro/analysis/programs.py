"""One-sync-per-horizon contract, proven on the compiled artifacts.

The serving runtime's headline systems claim is that a horizon (or
mixed) tick costs ONE jitted dispatch and ONE blocking device->host
transfer for up to H x n_slots tokens. The host half of that contract
is the dispatcher's single ``np.asarray(emits)``; this pass verifies
the *device* half — that nothing inside the compiled program talks to
the host behind the dispatcher's back — without executing the serving
stack:

1. **jaxpr audit**: each tick program from ``tick_programs.BUILDERS``
   is traced with abstract operands (a 1-layer fixtures model, the
   paged cache structure from ``jax.eval_shape`` — no device memory)
   and every equation, sub-jaxprs included, is checked against the
   callback primitives (``io_callback`` / ``pure_callback`` /
   ``debug_callback``): a `jax.debug.print` left in a builder would
   compile a per-step host round-trip into the scan.
2. **HLO audit**: the same lowering is compiled and the optimized HLO
   walked through :func:`repro.launch.hlo_analysis.find_host_ops` —
   the call-graph parser counts infeed/outfeed/send/recv and
   host-callback custom-calls over every computation reachable from
   the entry, loop bodies included. The count must be zero: the
   program's only host contact is the dispatcher's fetch of its
   result buffers.
3. **dispatcher budget**: the AST sync auditor counts the actual fetch
   sites in each ``dispatch_*`` function (suppression comments do not
   hide them) against ``tick_programs.DISPATCH_SYNC_BUDGET`` —
   horizon and mixed must have exactly one.

Together: exactly one host fetch per horizon/mixed tick, statically.
"""
from __future__ import annotations

import os
import warnings
from pathlib import Path
from typing import Dict, List, Tuple

from repro.analysis.common import Finding, PassResult

PASS_ID = "program"

#: jaxpr primitives that re-enter the host mid-program
CALLBACK_PRIMS = {"io_callback", "pure_callback", "debug_callback",
                  "outside_call"}

#: horizon width used for the scan-carrying programs' abstract trace
AUDIT_H = 4
_N, _P, _C = 4, 2, 4          # slots, prefill rows, prefill chunk


def _collect_primitives(jaxpr, out: set) -> set:
    for eqn in jaxpr.eqns:
        out.add(eqn.primitive.name)
        for v in eqn.params.values():
            sub = getattr(v, "jaxpr", None)
            if sub is not None:
                _collect_primitives(sub, out)
            elif hasattr(v, "eqns"):
                _collect_primitives(v, out)
            elif isinstance(v, (list, tuple)):
                for w in v:
                    if hasattr(w, "jaxpr"):
                        _collect_primitives(w.jaxpr, out)
                    elif hasattr(w, "eqns"):
                        _collect_primitives(w, out)
    return out


def _kv_quant():
    """Cache layout under audit: the quant CI lane sets
    ``REPRO_KV_QUANT=int8`` so the int8 store + scale-leaf programs (a
    different pytree, hence a different traced program) get the same
    zero-host-contact proof as the fp layout."""
    return os.environ.get("REPRO_KV_QUANT") or None


def _abstract_operands(model, params):
    """ShapeDtypeStructs for every tick-program operand family, plus the
    paged cache structure WITHOUT materializing it (eval_shape)."""
    import jax
    import jax.numpy as jnp
    from repro.serving.paged_pool import _paged_leaf_flags

    n_blocks, B = _N * 4 + 1, 4
    kvq = _kv_quant()
    flags = _paged_leaf_flags(model, kvq)
    cache = jax.eval_shape(lambda: jax.tree.map(
        lambda f, p, s: p if f else s, flags,
        model.init_cache(n_blocks, B, kv_quant=kvq),
        model.init_cache(_N, 1, kv_quant=kvq)))
    sds = jax.ShapeDtypeStruct
    key = jax.eval_shape(lambda: jax.random.PRNGKey(0))
    return dict(
        params=params, cache=cache,
        tables=sds((_N, 8), jnp.int32),
        tok=sds((_N,), jnp.int32),
        pos=sds((_N,), jnp.int32),
        keys=sds((_N,) + key.shape, key.dtype),
        key=key,
        advance=sds((_N,), jnp.bool_),
        remaining=sds((_N,), jnp.int32),
        roles=sds((_N,), jnp.bool_),
        fed=sds((AUDIT_H, _N), jnp.int32),
        temp=sds((), jnp.float32),
        ptables=sds((_P, 8), jnp.int32),
        ptoks=sds((_P, _C), jnp.int32),
        ppos=sds((_P,), jnp.int32),
        pvalid=sds((_P,), jnp.int32),
        lrows=[sds((model.lm.vocab_padded,), model.lm.dtype)] * 2,
        rids=sds((2,), jnp.int32),
        idxs=sds((2,), jnp.int32),
        slots=sds((2,), jnp.int32),
    )


def _program_operands(model, params) -> Dict[str, Tuple]:
    """kind -> positional operands for the builder's `run`."""
    o = _abstract_operands(model, params)
    return {
        "token": (o["params"], o["cache"], o["tables"], o["tok"], o["pos"],
                  o["keys"], o["advance"], o["temp"]),
        "chunk": (o["params"], o["cache"], o["ptables"], o["ptoks"],
                  o["ppos"], o["pvalid"]),
        "horizon": (o["params"], o["cache"], o["tables"], o["tok"],
                    o["pos"], o["keys"], o["remaining"], o["temp"]),
        "mixed": (o["params"], o["cache"], o["tables"], o["tok"], o["pos"],
                  o["keys"], o["remaining"], o["roles"], o["fed"],
                  o["temp"]),
        "admit": (o["lrows"], o["key"], o["rids"], o["idxs"], o["slots"],
                  o["keys"], o["temp"]),
    }


def _builders(model):
    from repro.serving import tick_programs as tp
    tz, eos = True, 2
    kvq = _kv_quant()
    return {
        "token": tp.token_program(model, tz, kvq),
        "chunk": tp.chunk_program(model, kvq),
        "horizon": tp.horizon_program(model, AUDIT_H, tz, eos, kvq),
        "mixed": tp.mixed_program(model, AUDIT_H, tz, eos, kvq),
        "admit": tp.admit_program(tz),
    }


def audit_tick_programs() -> PassResult:
    """Trace + compile every tick program for a tiny fixtures model and
    prove the zero-hidden-host-contact contract."""
    import jax
    from repro.analysis.callgraph import find_host_ops
    from repro.models.fixtures import tiny_lm

    result = PassResult(PASS_ID)
    _, model, params = tiny_lm(n_layers=1)
    operands = _program_operands(model, params)
    tp_path = "src/repro/serving/tick_programs.py"
    for kind, run in _builders(model).items():
        args = operands[kind]
        prims = _collect_primitives(jax.make_jaxpr(run)(*args).jaxpr, set())
        callbacks = sorted(prims & CALLBACK_PRIMS)
        for prim in callbacks:
            result.findings.append(Finding(
                PASS_ID, "callback-in-program", tp_path, 0, kind,
                f"{kind} program jaxpr contains `{prim}`: a host "
                "round-trip compiled into the tick"))
        with warnings.catch_warnings():
            # CPU backend warns that donated buffers go unused; the
            # donation is real on TPU
            warnings.simplefilter("ignore")
            hlo = run.lower(*args).compile().as_text()
        host_ops = find_host_ops(hlo)
        for comp, opcode, opname in host_ops:
            result.findings.append(Finding(
                PASS_ID, "host-op-in-hlo", tp_path, 0, kind,
                f"{kind} program HLO op `{opname}` ({opcode}) in "
                f"computation `{comp}` transfers to the host "
                "mid-program"))
        result.report[kind] = {
            "jaxpr_callbacks": len(callbacks),
            "hlo_host_ops": len(host_ops),
        }
    return result


def audit_dispatcher_budget(root: Path) -> List[Finding]:
    """Static fetch-site counts per dispatcher vs DISPATCH_SYNC_BUDGET."""
    from repro.analysis import syncs
    from repro.serving.tick_programs import DISPATCH_SYNC_BUDGET

    tp_path = root / "src/repro/serving/tick_programs.py"
    text = tp_path.read_text()
    findings: List[Finding] = []
    for fn, (lo, hi) in sorted(DISPATCH_SYNC_BUDGET.items()):
        n = syncs.count_fetch_sites(text, fn)
        if not lo <= n <= hi:
            findings.append(Finding(
                PASS_ID, "sync-budget", "src/repro/serving/tick_programs.py",
                0, fn,
                f"{fn} has {n} device->host fetch sites; budget is "
                f"[{lo}, {hi}] — a new fetch breaks the one-sync "
                "contract, a removed one means the budget should "
                "tighten"))
    return findings


def run(root: Path) -> PassResult:
    if not (root / "src/repro/serving/tick_programs.py").exists():
        return PassResult(PASS_ID)      # fixture tree
    result = audit_tick_programs()
    result.findings += audit_dispatcher_budget(root)
    for fn in ("dispatch_horizon", "dispatch_mixed"):
        result.report[fn] = {"fetch_sites": __fetch_count(root, fn)}
    return result


def __fetch_count(root: Path, fn: str) -> int:
    from repro.analysis import syncs
    text = (root / "src/repro/serving/tick_programs.py").read_text()
    return syncs.count_fetch_sites(text, fn)
