"""Call graphs shared by the analysis passes — no launch-layer deps.

Two halves:

* the **HLO** half (``parse_hlo`` / ``build_call_graph`` /
  ``find_host_ops``), extracted from ``launch/hlo_analysis`` so the
  analysis package stands alone: parses computations out of optimized
  HLO text, builds the loop-aware call graph (calls= / to_apply= /
  body= / condition= edges with `known_trip_count` multipliers) and
  walks it for host-transfer ops. ``launch.hlo_analysis`` re-exports
  everything here for back-compat and keeps only the cost model.
* the **Python** half (``walk_functions`` / ``build_py_call_graph``),
  a name-keyed call graph over parsed source modules: which functions
  exist, and which *leaf* callee names each one mentions. The
  ownership pass iterates its interprocedural summaries to a fixpoint
  over this graph; names are deliberately unresolved (no type
  inference) — callers key summaries by leaf name and keep the
  protocol names collision-free.
"""
from __future__ import annotations

import ast
import re
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Dict, Iterable, List, Optional, Set, Tuple

# --------------------------------------------------------------- HLO half

DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1,
               "f8e5m2": 1, "s64": 8, "u64": 8, "s32": 4, "u32": 4,
               "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
               "c64": 8, "c128": 16, "s4": 1, "u4": 1}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def shape_info(s: str) -> Tuple[int, List[int]]:
    """'bf16[2,3]{1,0}' -> (bytes, dims). Tuples: sum of element bytes."""
    if s.startswith("("):
        total = 0
        for m in _SHAPE_RE.finditer(s):
            total += _one_shape_bytes(m.group(1), m.group(2))
        return total, []
    m = _SHAPE_RE.match(s)
    if not m:
        return 0, []
    dt, dims_s = m.groups()
    dims = [int(d) for d in dims_s.split(",") if d]
    return _one_shape_bytes(dt, dims_s), dims


def _one_shape_bytes(dt: str, dims_s) -> int:
    if isinstance(dims_s, str):
        dims = [int(d) for d in dims_s.split(",") if d]
    else:
        dims = dims_s
    n = 1
    for d in dims:
        n *= d
    return n * DTYPE_BYTES.get(dt, 0)


@dataclass
class Op:
    name: str
    opcode: str
    result_shape: str
    operands: List[str]
    attrs: str
    is_root: bool = False

    @property
    def result_bytes(self) -> int:
        return shape_info(self.result_shape)[0]


@dataclass
class Computation:
    name: str
    is_entry: bool = False
    params: Dict[str, str] = field(default_factory=dict)   # name -> shape
    ops: List[Op] = field(default_factory=list)
    shapes: Dict[str, str] = field(default_factory=dict)   # symbol table


_HEADER_RE = re.compile(
    r"^(ENTRY\s+)?%?([\w\.\-]+)\s*\((.*)\)\s*->\s*(.+?)\s*{\s*$")
_OP_RE = re.compile(
    r"^\s+(ROOT\s+)?%?([\w\.\-]+)\s*=\s*(\([^)]*\)|[a-z0-9]+\[[0-9,]*\]\S*)\s+"
    r"([\w\-]+)\((.*)$")
_PARAM_RE = re.compile(r"%?([\w\.\-]+):\s*(\([^)]*\)|[a-z0-9]+\[[0-9,]*\]\S*)")


def parse_hlo(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for line in text.splitlines():
        if cur is None:
            m = _HEADER_RE.match(line)
            if m:
                is_entry, name, params, _ = m.groups()
                cur = Computation(name=name, is_entry=bool(is_entry))
                for pm in _PARAM_RE.finditer(params):
                    cur.params[pm.group(1)] = pm.group(2)
                    cur.shapes[pm.group(1)] = pm.group(2)
                comps[name] = cur
            continue
        if line.startswith("}"):
            cur = None
            continue
        m = _OP_RE.match(line)
        if not m:
            continue
        root_kw, name, shape, opcode, rest = m.groups()
        # operands: %names before attrs; attrs after final ')'
        depth, i = 1, 0
        while i < len(rest) and depth:
            if rest[i] == "(":
                depth += 1
            elif rest[i] == ")":
                depth -= 1
            i += 1
        arg_str, attrs = rest[: i - 1], rest[i:]
        operands = re.findall(r"%([\w\.\-]+)", arg_str)
        op = Op(name=name, opcode=opcode, result_shape=shape,
                operands=operands, attrs=attrs, is_root=bool(root_kw))
        cur.ops.append(op)
        cur.shapes[name] = shape
    return comps


def _parse_trip_count(attrs: str) -> int:
    m = re.search(r'known_trip_count[":{\s]+n[":\s]+"?(\d+)', attrs)
    return int(m.group(1)) if m else 1


@dataclass
class CallGraph:
    """Loop-aware call graph of one HLO module: BFS `order` from the
    entry computation, per-computation trip-count `mult`ipliers, and a
    `fusion_ctx` flag marking computations only reachable through fusion
    bodies (their ops are register/VMEM traffic, not HBM). The roofline
    cost model (`launch.hlo_analysis.analyze`) and the one-sync audit
    (`analysis.programs`) walk the same graph."""
    comps: Dict[str, Computation]
    entry: Optional[Computation]
    order: List[str]
    mult: Dict[str, float]
    fusion_ctx: Dict[str, bool]

    def reachable(self):
        """Reachable computations in BFS order (skips dangling refs)."""
        for cname in self.order:
            comp = self.comps.get(cname)
            if comp is not None:
                yield comp


def build_call_graph(comps: Dict[str, Computation]) -> CallGraph:
    """Accumulate loop multipliers by BFS over calls= / to_apply= /
    body= / condition= edges, scaling by `known_trip_count`."""
    entry = next((c for c in comps.values() if c.is_entry), None)
    mult: Dict[str, float] = defaultdict(float)
    fusion_ctx: Dict[str, bool] = defaultdict(bool)   # inside a fusion body?
    if entry is None:
        return CallGraph(comps, None, [], mult, fusion_ctx)
    mult[entry.name] = 1.0
    order = [entry.name]
    seen = {entry.name}
    i = 0
    while i < len(order):
        cname = order[i]
        i += 1
        comp = comps.get(cname)
        if comp is None:
            continue
        for op in comp.ops:
            callees: List[Tuple[str, float, bool]] = []
            if op.opcode == "while":
                trip = _parse_trip_count(op.attrs)
                for kw in ("body", "condition"):
                    m = re.search(kw + r"=%?([\w\.\-]+)", op.attrs)
                    if m:
                        callees.append((m.group(1), float(trip), False))
            elif op.opcode == "fusion":
                m = re.search(r"calls=%?([\w\.\-]+)", op.attrs)
                if m:
                    callees.append((m.group(1), 1.0, True))
            else:
                for kw in ("calls", "to_apply", "body", "condition",
                           "true_computation", "false_computation"):
                    m = re.search(kw + r"=%?([\w\.\-]+)", op.attrs)
                    if m:
                        callees.append((m.group(1), 1.0,
                                        fusion_ctx[cname]))
            for callee, k, fus in callees:
                mult[callee] += mult[cname] * k
                fusion_ctx[callee] = fusion_ctx[callee] or fus or \
                    (op.opcode == "fusion")
                if callee not in seen:
                    seen.add(callee)
                    order.append(callee)
    return CallGraph(comps, entry, order, mult, fusion_ctx)


# HLO opcodes that move data between device and host (or between
# devices) outside the normal result buffer: any of these inside a tick
# program would be a hidden round-trip the dispatcher cannot account.
HOST_TRANSFER_OPS = ("outfeed", "infeed", "send", "recv",
                     "send-done", "recv-done")
# custom-call targets that re-enter Python on the host mid-program
# (io_callback / pure_callback / jax.debug lower to these)
_HOST_CALLBACK_RE = re.compile(r"callback|host", re.IGNORECASE)


def find_host_ops(text: str) -> List[Tuple[str, str, str]]:
    """Scan every computation reachable from the entry for ops that
    talk to the host: (computation, opcode, op name) triples. Used by
    the one-sync-per-horizon audit — a compiled tick program must have
    ZERO of these (its only host contact is the dispatcher's single
    fetch of the result buffer)."""
    graph = build_call_graph(parse_hlo(text))
    out: List[Tuple[str, str, str]] = []
    for comp in graph.reachable():
        for op in comp.ops:
            if op.opcode in HOST_TRANSFER_OPS:
                out.append((comp.name, op.opcode, op.name))
            elif op.opcode == "custom-call" and \
                    _HOST_CALLBACK_RE.search(op.attrs):
                out.append((comp.name, op.opcode, op.name))
    return out


# ------------------------------------------------------------ Python half

def dotted(node: ast.AST) -> Optional[str]:
    """'a.b.c' for Name/Attribute chains, else None."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


@dataclass
class PyFunc:
    """One function definition in a scanned module."""
    name: str               # bare name (summary key)
    qualname: str           # Class.method for findings
    relpath: str
    node: ast.AST           # the FunctionDef


def walk_functions(tree: ast.Module, relpath: str) -> List[PyFunc]:
    """Every function in a module, nested and methods included."""
    out: List[PyFunc] = []

    def visit(node, prefix: str):
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                q = f"{prefix}.{child.name}" if prefix else child.name
                out.append(PyFunc(child.name, q, relpath, child))
                visit(child, q)
            elif isinstance(child, ast.ClassDef):
                visit(child, f"{prefix}.{child.name}"
                      if prefix else child.name)
            else:
                visit(child, prefix)

    visit(tree, "")
    return out


def called_leaf_names(fn: ast.AST) -> Set[str]:
    """Leaf names of every call inside `fn` (nested defs excluded):
    ``self.pool.decref(x)`` contributes ``decref``."""
    out: Set[str] = set()

    def visit(node):
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef,
                                  ast.ClassDef)):
                continue
            if isinstance(child, ast.Call):
                name = dotted(child.func)
                if name:
                    out.add(name.rsplit(".", 1)[-1])
            visit(child)

    visit(fn)
    return out


@dataclass
class PyCallGraph:
    """Name-keyed call graph over a set of modules: leaf name ->
    definitions, plus each function's called leaf names. Good enough
    for a summary fixpoint; not an alias analysis."""
    funcs: Dict[str, List[PyFunc]] = field(default_factory=dict)
    calls: Dict[str, Set[str]] = field(default_factory=dict)  # qualname ->

    def all_funcs(self) -> Iterable[PyFunc]:
        for defs in self.funcs.values():
            yield from defs


def build_py_call_graph(
        modules: Iterable[Tuple[str, ast.Module]]) -> PyCallGraph:
    """modules: (relpath, parsed tree) pairs."""
    graph = PyCallGraph()
    for relpath, tree in modules:
        for pf in walk_functions(tree, relpath):
            graph.funcs.setdefault(pf.name, []).append(pf)
            graph.calls[f"{relpath}:{pf.qualname}"] = \
                called_leaf_names(pf.node)
    return graph
