"""Host-sync auditor: AST taint pass for implicit device->host pulls.

Every blocking device->host transfer on the tick path is scheduler
overhead the horizon fusion exists to amortize (one sync per horizon,
not per token). This pass finds the *implicit* ones — the innocuous
Python that secretly forces a transfer:

* ``float(x)`` / ``int(x)`` / ``bool(x)`` on a device value  (scalar-pull)
* ``len(x)`` on a device value                               (len)
* ``np.asarray(x)`` / ``np.array(x)`` / np scalar casts      (asarray)
* ``x.item()`` / ``x.tolist()``                              (item)
* ``for _ in x`` iterating a device value                    (iterate)
* ``if x:`` branching on a device value in host code         (branch)

and, inside jitted program builders, host re-entry that should never
compile into a tick program:

* ``jax.debug.print`` / ``jax.debug.callback``          (debug-callback)
* ``io_callback`` / ``pure_callback``                   (callback)
* ``if x:`` on a traced value (a trace error in waiting) (traced-branch)

Device values are found by forward dataflow within each function:
results of ``jax.*`` / ``jnp.*`` calls, results of calling a tick
program (``*_program`` builders and their returned closures, plus the
module-jitted helpers in tick_programs), parameters with
device-conventional names (``logits``/``hidden``/``cache``/``keys``/…),
and attribute reads of those names (``rt.keys``, ``pool.caches``).
Taint propagates through assignment, tuple unpacking, subscripts and
arithmetic. The pass is intentionally shallow-but-sound-enough: it is a
lint with a baseline, not an alias analysis — accounted fetches carry
``# analysis: allow(sync)``, accepted cold-path pulls live in the
committed baseline, and anything new fails CI.
"""
from __future__ import annotations

import ast
from pathlib import Path
from typing import List, Optional, Set

from repro.analysis.common import (Finding, PassResult, apply_suppressions,
                                   assign_occurrences, iter_sources, rel)

PASS_ID = "sync"
CATEGORY = "sync"               # allow(sync)

#: scan targets relative to the repo root
SUBDIRS = ("src/repro/serving", "src/repro/kernels")

#: parameters assumed to carry device arrays (the runtime's naming
#: conventions — see tick_programs.py / retire.py signatures)
DEVICE_PARAMS = {"logits", "hidden", "lg", "hid", "lrow", "lrows",
                 "probe_lg", "probe_hid", "emits", "cache", "keys",
                 "src_logits", "child_key", "base_key"}

#: attribute names that hold device arrays on runtime/pool objects
DEVICE_ATTRS = {"keys", "caches", "logits", "probe_lg", "probe_hid"}

#: module-level device helpers callable by bare / dotted name
DEVICE_FNS = {"pool_tick", "admit_slot", "sample_first", "prefill",
              "decode_step", "decode_chunk", "decode_horizon"}

#: builder suffix: `token_program(model, tz)` returns a jitted closure;
#: both the builder call result and the closure's call result are device
BUILDER_SUFFIX = "_program"

#: sink codes that are *fetch sites* (count toward the dispatcher sync
#: budget in repro.analysis.programs, suppressed or not)
FETCH_CODES = ("scalar-pull", "len", "asarray", "item", "iterate")

_NP_SINKS = {"asarray", "array", "float32", "float64", "int32", "int64",
             "ascontiguousarray"}


def _dotted(node: ast.AST) -> Optional[str]:
    """'a.b.c' for Name/Attribute chains, else None."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def _is_jitted(fn: ast.AST) -> bool:
    """Decorated with jax.jit, functools.partial(jax.jit, ...), or a
    pallas_call wrapper."""
    for dec in getattr(fn, "decorator_list", []):
        target = dec.args[0] if (isinstance(dec, ast.Call) and dec.args) \
            else dec
        name = _dotted(target.func if isinstance(target, ast.Call)
                       else target) or ""
        if name.endswith("jit") or name.endswith("pallas_call"):
            return True
    return False


class _FunctionAuditor:
    """Linear forward taint scan of one function body (two passes, so
    loop-carried taint converges; findings recorded on the last)."""

    def __init__(self, fn, qualname: str, relpath: str, jitted: bool):
        self.fn = fn
        self.qualname = qualname
        self.relpath = relpath
        self.jitted = jitted
        self.tainted: Set[str] = set()
        self.device_fns: Set[str] = set(DEVICE_FNS)
        self.findings: List[Finding] = []
        self.record = False
        args = fn.args
        for a in (args.posonlyargs + args.args + args.kwonlyargs):
            if a.arg in DEVICE_PARAMS:
                self.tainted.add(a.arg)

    # ---------------------------------------------------------- taint
    def _call_is_device(self, call: ast.Call) -> bool:
        func = call.func
        if isinstance(func, ast.Call):            # builder()(...) chains
            return self._call_is_device(func)
        name = _dotted(func)
        if name is None:
            return False
        root, leaf = name.split(".", 1)[0], name.rsplit(".", 1)[-1]
        if root in ("jnp", "jax"):
            # host-side jax helpers that never return device buffers
            return leaf not in ("eval_shape", "make_jaxpr",
                                "tree_structure")
        return (leaf in self.device_fns or name in self.device_fns
                or leaf.endswith(BUILDER_SUFFIX))

    def _tainted_expr(self, node: ast.AST) -> bool:
        if isinstance(node, ast.Name):
            return node.id in self.tainted
        if isinstance(node, ast.Attribute):
            if node.attr in ("shape", "dtype", "ndim", "size"):
                return False    # array metadata lives on the host
            return node.attr in DEVICE_ATTRS or self._tainted_expr(node.value)
        if isinstance(node, ast.Call):
            return self._call_is_device(node)
        if isinstance(node, ast.Subscript):
            return self._tainted_expr(node.value)
        if isinstance(node, ast.BinOp):
            return (self._tainted_expr(node.left)
                    or self._tainted_expr(node.right))
        if isinstance(node, ast.UnaryOp):
            return self._tainted_expr(node.operand)
        if isinstance(node, ast.Compare):
            if all(isinstance(op, (ast.Is, ast.IsNot)) for op in node.ops):
                return False    # identity checks never transfer
            tainted = self._tainted_expr(node.left)
            for op, cmp in zip(node.ops, node.comparators):
                if isinstance(op, (ast.In, ast.NotIn)) and \
                        isinstance(cmp, ast.Attribute) and \
                        cmp.attr in DEVICE_ATTRS:
                    # membership in a host dict OF device values
                    # (e.g. `model_id in pool.caches`)
                    continue
                tainted = tainted or self._tainted_expr(cmp)
            return tainted
        if isinstance(node, ast.BoolOp):
            return any(self._tainted_expr(v) for v in node.values)
        if isinstance(node, (ast.Tuple, ast.List)):
            return any(self._tainted_expr(e) for e in node.elts)
        if isinstance(node, ast.IfExp):
            return (self._tainted_expr(node.body)
                    or self._tainted_expr(node.orelse))
        if isinstance(node, ast.Starred):
            return self._tainted_expr(node.value)
        return False

    def _taint_target(self, target: ast.AST) -> None:
        if isinstance(target, ast.Name):
            self.tainted.add(target.id)
        elif isinstance(target, (ast.Tuple, ast.List)):
            for e in target.elts:
                self._taint_target(e)
        elif isinstance(target, ast.Starred):
            self._taint_target(target.value)
        # attribute/subscript targets: the base object's taint is
        # name-conventional (DEVICE_ATTRS), not tracked per instance

    def _untaint_target(self, target: ast.AST) -> None:
        if isinstance(target, ast.Name):
            self.tainted.discard(target.id)
        elif isinstance(target, (ast.Tuple, ast.List)):
            for e in target.elts:
                self._untaint_target(e)

    # -------------------------------------------------------- findings
    def _flag(self, node: ast.AST, code: str, message: str) -> None:
        if self.record:
            self.findings.append(Finding(
                PASS_ID, code, self.relpath, node.lineno, self.qualname,
                message))

    def _check_call(self, call: ast.Call) -> None:
        name = _dotted(call.func) or ""
        leaf = name.rsplit(".", 1)[-1]
        args_tainted = any(self._tainted_expr(a) for a in call.args)
        if name in ("float", "int", "bool") and args_tainted:
            self._flag(call, "scalar-pull",
                       f"{name}() on a device value forces a blocking "
                       "device->host transfer of one scalar")
        elif name == "len" and args_tainted:
            self._flag(call, "len",
                       "len() on a device value blocks on the device")
        elif name.startswith("np.") and leaf in _NP_SINKS and args_tainted:
            self._flag(call, "asarray",
                       f"{name}() on a device value is a blocking "
                       "device->host transfer")
        elif isinstance(call.func, ast.Attribute) and \
                call.func.attr in ("item", "tolist") and \
                self._tainted_expr(call.func.value):
            self._flag(call, "item",
                       f".{call.func.attr}() forces a device->host "
                       "transfer")
        if self.jitted:
            if name.startswith("jax.debug."):
                self._flag(call, "debug-callback",
                           f"{name} compiles a host callback into a "
                           "jitted tick program")
            elif leaf in ("io_callback", "pure_callback"):
                self._flag(call, "callback",
                           f"{leaf} re-enters Python on the host from "
                           "inside a jitted program")
            elif name.startswith("np.") and args_tainted:
                self._flag(call, "numpy-in-jit",
                           f"{name} on a traced value inside a jitted "
                           "function forces concretization")

    # ------------------------------------------------------------ walk
    def _scan_stmt(self, stmt: ast.stmt) -> None:
        # check calls in this statement's own expressions only; nested
        # statements are visited by the recursion below (once each)
        for child in ast.iter_child_nodes(stmt):
            if isinstance(child, ast.expr):
                for call in ast.walk(child):
                    if isinstance(call, ast.Call):
                        self._check_call(call)
        if isinstance(stmt, ast.Assign):
            dev = self._tainted_expr(stmt.value)
            for t in stmt.targets:
                (self._taint_target if dev else self._untaint_target)(t)
            # `run = token_program(...)`: the bound closure is a device fn
            if isinstance(stmt.value, ast.Call):
                name = _dotted(stmt.value.func) or ""
                if name.rsplit(".", 1)[-1].endswith(BUILDER_SUFFIX):
                    for t in stmt.targets:
                        if isinstance(t, ast.Name):
                            self.device_fns.add(t.id)
        elif isinstance(stmt, ast.AugAssign):
            if self._tainted_expr(stmt.value):
                self._taint_target(stmt.target)
        elif isinstance(stmt, ast.For):
            if self._tainted_expr(stmt.iter):
                self._flag(stmt, "iterate",
                           "iterating a device value transfers it "
                           "element-by-element")
                self._taint_target(stmt.target)
        elif isinstance(stmt, (ast.If, ast.While)):
            if self._tainted_expr(stmt.test):
                if self.jitted:
                    self._flag(stmt, "traced-branch",
                               "Python branch on a traced value inside "
                               "a jitted function (trace error / "
                               "implicit concretization)")
                else:
                    self._flag(stmt, "branch",
                               "Python branch on a device value blocks "
                               "on the device")
        for child in ast.iter_child_nodes(stmt):
            if isinstance(child, ast.stmt) and not isinstance(
                    child, (ast.FunctionDef, ast.AsyncFunctionDef,
                            ast.ClassDef)):
                self._scan_stmt(child)

    def run(self) -> List[Finding]:
        for final in (False, True):
            self.record = final
            for stmt in self.fn.body:
                if not isinstance(stmt, (ast.FunctionDef,
                                         ast.AsyncFunctionDef,
                                         ast.ClassDef)):
                    self._scan_stmt(stmt)
        return self.findings


def _walk_functions(tree: ast.Module):
    """Yield (fn_node, qualname, enclosing_jitted) for every function,
    nested included."""
    def visit(node, prefix: str, jitted: bool):
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                q = f"{prefix}.{child.name}" if prefix else child.name
                j = jitted or _is_jitted(child)
                yield child, q, j
                yield from visit(child, q, j)
            elif isinstance(child, ast.ClassDef):
                yield from visit(child, f"{prefix}.{child.name}"
                                 if prefix else child.name, jitted)
            else:
                yield from visit(child, prefix, jitted)
    yield from visit(tree, "", False)


def audit_source(text: str, relpath: str) -> List[Finding]:
    """All sync findings in one file; `allow(sync)` sites are returned
    with ``suppressed=True`` (the budget count still sees them)."""
    tree = ast.parse(text)
    findings: List[Finding] = []
    for fn, qualname, jitted in _walk_functions(tree):
        findings += _FunctionAuditor(fn, qualname, relpath, jitted).run()
    findings = apply_suppressions(findings, text, CATEGORY)
    return assign_occurrences(findings)


def count_fetch_sites(text: str, func_name: str) -> int:
    """Device->host fetch sites (FETCH_CODES) inside top-level
    `func_name`, counting suppressed sites too — the static side of the
    dispatcher sync budget."""
    return sum(1 for f in audit_source(text, "<mem>")
               if f.code in FETCH_CODES
               and (f.scope == func_name
                    or f.scope.startswith(func_name + ".")))


def run(root: Path) -> PassResult:
    result = PassResult(PASS_ID)
    files = iter_sources(root, SUBDIRS)
    for path in files:
        findings = audit_source(path.read_text(), rel(path, root))
        result.findings += findings
    result.report["files"] = len(files)
    result.report["scanned"] = [rel(p, root) for p in files]
    result.report["suppress_category"] = CATEGORY
    return result
