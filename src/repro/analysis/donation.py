"""Buffer-donation / aliasing audit for the jitted tick programs.

Donation is the reason a tick costs one cache write instead of one
cache copy: every cache-carrying program donates its cache (and keys)
operand so XLA aliases the output buffer over the input. That contract
has two failure modes, both silent on the CPU test backend (which
ignores donation) and both catastrophic on a real accelerator:

* a builder that *forgets* to donate: every tick copies the whole KV
  cache — the multi-GB buffer the paged pool exists to never copy;
* a dispatcher that *reads* a donated operand after the call: the
  buffer was aliased away, the read returns garbage (XLA raises on
  some backends, silently serves freed memory on others).

So this pass checks, purely on the AST:

* ``donation-missing`` — a jitted function (decorator or
  ``jax.jit(f, ...)`` call form) with a parameter named ``cache`` or
  ``keys`` whose position is not in ``donate_argnums``. Read-only uses
  (`read_state`, the engine's reusable prefill cache) carry
  ``# analysis: allow(donation)`` on the line.
* ``donated-read`` — at a call site of a known donating runner (a
  ``*_program`` builder closure, the module-jitted helpers, the pool's
  ``_progs`` members), a donated argument expression is read again
  after the dispatch and before being rebound.
* ``donated-no-rebind`` — a donated persistent operand (attribute /
  subscript expression: ``pool.caches[mid]``, ``rt.keys``) is never
  rebound after the call — the caller keeps a reference to a dead
  buffer.

Expression matching is textual (``ast.unparse``), which is exactly as
strong as the runtime's own discipline: dispatchers donate
``pool.caches[pp.model_id]`` and must rebind the same spelling on the
next line.
"""
from __future__ import annotations

import ast
from dataclasses import dataclass, field
from pathlib import Path
from typing import Dict, List, Optional, Set, Tuple

from repro.analysis.callgraph import dotted, walk_functions
from repro.analysis.common import (Finding, PassResult, apply_suppressions,
                                   assign_occurrences, iter_sources, rel)

PASS_ID = "donation"
CATEGORY = "donation"           # allow(donation)

SUBDIRS = ("src/repro/serving",)

#: parameter names that carry the big per-model device buffers the
#: donation contract exists for
DONATABLE_PARAMS = ("cache", "keys")

#: builder suffix: `token_program(model, ...)` returns a jitted closure
BUILDER_SUFFIX = "_program"


@dataclass
class JitDef:
    """One jitted callable: its positional params and donated indices."""
    name: str
    qualname: str
    relpath: str
    line: int
    params: List[str]
    donated: Set[int]


@dataclass
class Registry:
    """Donating runners visible at call sites, across all scanned
    modules: by definition name, by builder name (the nested jitted
    closure's donations), and by `_pool_programs`-style keyword name
    (matched only on `._progs` attribute chains)."""
    defs: Dict[str, Set[int]] = field(default_factory=dict)
    builders: Dict[str, Set[int]] = field(default_factory=dict)
    progs: Dict[str, Set[int]] = field(default_factory=dict)


def _is_jit_name(name: Optional[str]) -> bool:
    return bool(name) and (name == "jit" or name.endswith(".jit"))


def _donate_argnums(call: ast.Call) -> Set[int]:
    for kw in call.keywords:
        if kw.arg != "donate_argnums":
            continue
        v = kw.value
        if isinstance(v, (ast.Tuple, ast.List)):
            return {e.value for e in v.elts
                    if isinstance(e, ast.Constant)
                    and isinstance(e.value, int)}
        if isinstance(v, ast.Constant) and isinstance(v.value, int):
            return {v.value}
    return set()


def _decorator_jit(fn: ast.AST) -> Optional[Set[int]]:
    """Donated indices if `fn` is decorated with jax.jit (directly or
    through functools.partial); None if not jitted."""
    for dec in getattr(fn, "decorator_list", []):
        if isinstance(dec, ast.Call):
            name = dotted(dec.func)
            if _is_jit_name(name):
                return _donate_argnums(dec)
            if name and name.endswith("partial") and dec.args and \
                    _is_jit_name(dotted(dec.args[0])):
                return _donate_argnums(dec)
        elif _is_jit_name(dotted(dec)):
            return set()
    return None


def _positional_params(fn: ast.AST) -> List[str]:
    a = fn.args
    return [p.arg for p in (a.posonlyargs + a.args)]


def collect_jitted(tree: ast.Module, relpath: str,
                   registry: Registry) -> List[JitDef]:
    """All jitted callables in one module, filling `registry` with the
    donating ones (callable by name at other call sites)."""
    out: List[JitDef] = []
    by_name = {pf.name: pf for pf in walk_functions(tree, relpath)}
    for pf in walk_functions(tree, relpath):
        donated = _decorator_jit(pf.node)
        if donated is None:
            continue
        jd = JitDef(pf.name, pf.qualname, relpath, pf.node.lineno,
                    _positional_params(pf.node), donated)
        out.append(jd)
        if donated:
            registry.defs[pf.name] = donated
            # `X_program`'s nested jitted closure: donations apply at
            # `run = X_program(...); run(...)` call sites
            head = pf.qualname.split(".")[0]
            if head.endswith(BUILDER_SUFFIX):
                registry.builders[head] = donated
    # call-form jits: jax.jit(_copy_block, donate_argnums=(0,)),
    # including as keyword values (`PoolPrograms(copy_block=...)`)
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call) or not _is_jit_name(
                dotted(node.func)):
            continue
        if not node.args or not isinstance(node.args[0], ast.Name):
            continue        # jax.jit(lambda: ...) etc: nothing to check
        target = by_name.get(node.args[0].id)
        if target is None:
            continue
        donated = _donate_argnums(node)
        jd = JitDef(target.name, target.qualname, relpath, node.lineno,
                    _positional_params(target.node), donated)
        out.append(jd)
        if donated:
            registry.defs[target.name] = donated
    for node in ast.walk(tree):
        if isinstance(node, ast.Call):
            for kw in node.keywords:
                if isinstance(kw.value, ast.Call) and kw.arg and \
                        _is_jit_name(dotted(kw.value.func)):
                    donated = _donate_argnums(kw.value)
                    if donated:
                        registry.progs[kw.arg] = donated
    return out


def _missing_donation_findings(jits: List[JitDef]) -> List[Finding]:
    seen: Set[Tuple[str, str]] = set()
    out: List[Finding] = []
    for jd in jits:
        for i, p in enumerate(jd.params):
            if p in DONATABLE_PARAMS and i not in jd.donated and \
                    (jd.qualname, p) not in seen:
                seen.add((jd.qualname, p))
                out.append(Finding(
                    PASS_ID, "donation-missing", jd.relpath, jd.line,
                    jd.qualname,
                    f"jitted `{jd.name}` takes `{p}` (arg {i}) without "
                    "donating it — every call copies the buffer instead "
                    "of aliasing in place; add it to donate_argnums, or "
                    "mark a deliberate read-only use with "
                    "`# analysis: allow(donation)`"))
    return out


class _CallSiteAuditor:
    """Post-dispatch use checks for one function: donated argument
    expressions must be rebound before any further read."""

    def __init__(self, fn: ast.AST, qualname: str, relpath: str,
                 registry: Registry):
        self.fn = fn
        self.qualname = qualname
        self.relpath = relpath
        self.registry = registry
        self.findings: List[Finding] = []

    # each statement that can rebind: (lineno, {target texts})
    def _rebind_sites(self) -> List[Tuple[int, Set[str]]]:
        out = []
        for node in ast.walk(self.fn):
            texts: Set[str] = set()
            if isinstance(node, ast.Assign):
                for t in node.targets:
                    elts = t.elts if isinstance(
                        t, (ast.Tuple, ast.List)) else [t]
                    texts |= {ast.unparse(e) for e in elts}
            elif isinstance(node, ast.AugAssign):
                texts = {ast.unparse(node.target)}
            if texts:
                out.append((node.lineno, texts))
        return out

    def _donating_positions(self, call: ast.Call,
                            builder_locals: Dict[str, Set[str]]) \
            -> Optional[Set[int]]:
        func = call.func
        if isinstance(func, ast.Name) and func.id in builder_locals:
            return self.registry.builders.get(
                next(iter(builder_locals[func.id])))
        if isinstance(func, ast.Call):      # X_program(...)(args)
            inner = dotted(func.func)
            leaf = (inner or "").rsplit(".", 1)[-1]
            return self.registry.builders.get(leaf)
        name = dotted(func)
        if name is None:
            return None
        leaf = name.rsplit(".", 1)[-1]
        if leaf in self.registry.defs:
            return self.registry.defs[leaf]
        if isinstance(func, ast.Attribute) and \
                leaf in self.registry.progs and \
                "_progs" in ast.unparse(func.value):
            return self.registry.progs[leaf]
        return None

    def run(self) -> List[Finding]:
        # local `run = token_program(...)` binds
        builder_locals: Dict[str, Set[str]] = {}
        for node in ast.walk(self.fn):
            if isinstance(node, ast.Assign) and \
                    isinstance(node.value, ast.Call):
                name = dotted(node.value.func) or ""
                leaf = name.rsplit(".", 1)[-1]
                if leaf in self.registry.builders:
                    for t in node.targets:
                        if isinstance(t, ast.Name):
                            builder_locals[t.id] = {leaf}
        rebinds = self._rebind_sites()

        # enclosing SIMPLE statement of each donating call (for target
        # texts and end lineno) — compound statements (the function
        # itself, If/For/Try bodies) would claim the call too and span
        # the wrong line range
        simple = (ast.Assign, ast.AugAssign, ast.AnnAssign, ast.Expr,
                  ast.Return, ast.Raise, ast.Assert)
        stmts = [n for n in ast.walk(self.fn) if isinstance(n, simple)]
        for stmt in stmts:
            own_targets: Set[str] = set()
            if isinstance(stmt, ast.Assign):
                for t in stmt.targets:
                    elts = t.elts if isinstance(
                        t, (ast.Tuple, ast.List)) else [t]
                    own_targets |= {ast.unparse(e) for e in elts}
            for call in ast.walk(stmt):
                if not isinstance(call, ast.Call):
                    continue
                positions = self._donating_positions(call, builder_locals)
                if not positions:
                    continue
                self._check_call(stmt, call, positions, own_targets,
                                 rebinds)
        return self.findings

    def _check_call(self, stmt: ast.stmt, call: ast.Call,
                    positions: Set[int], own_targets: Set[str],
                    rebinds: List[Tuple[int, Set[str]]]) -> None:
        end = getattr(stmt, "end_lineno", stmt.lineno)
        for i in sorted(positions):
            if i >= len(call.args):
                continue
            arg = call.args[i]
            if not isinstance(arg, (ast.Name, ast.Attribute,
                                    ast.Subscript)):
                continue        # a temporary: nothing aliases it
            text = ast.unparse(arg)
            if text in own_targets:
                continue        # rebound by the call's own unpacking
            rebind = min((ln for ln, ts in rebinds
                          if text in ts and ln >= end), default=None)
            for node in ast.walk(self.fn):
                if isinstance(node, (ast.Name, ast.Attribute,
                                     ast.Subscript)) and \
                        isinstance(getattr(node, "ctx", None), ast.Load) \
                        and node.lineno > end and \
                        (rebind is None or node.lineno < rebind) and \
                        ast.unparse(node) == text:
                    self.findings.append(Finding(
                        PASS_ID, "donated-read", self.relpath,
                        node.lineno, self.qualname,
                        f"`{text}` was donated to "
                        f"`{ast.unparse(call.func)}` (line {call.lineno})"
                        " and read here before being rebound — the "
                        "buffer is aliased away; read the program's "
                        "RESULT instead"))
                    break       # one finding per donated operand
            if rebind is None and any(ch in text for ch in ".["):
                self.findings.append(Finding(
                    PASS_ID, "donated-no-rebind", self.relpath,
                    call.lineno, self.qualname,
                    f"`{text}` is donated to "
                    f"`{ast.unparse(call.func)}` but never rebound in "
                    "this function — the caller keeps a reference to a "
                    "dead buffer; assign the program's result back"))


def audit_source(text: str, relpath: str,
                 registry: Registry) -> List[Finding]:
    tree = ast.parse(text)
    findings = _missing_donation_findings(
        collect_jitted(tree, relpath, Registry()))
    for pf in walk_functions(tree, relpath):
        findings += _CallSiteAuditor(pf.node, pf.qualname, relpath,
                                     registry).run()
    findings = apply_suppressions(findings, text, CATEGORY)
    return assign_occurrences(findings)


def run(root: Path) -> PassResult:
    result = PassResult(PASS_ID)
    files = iter_sources(root, SUBDIRS)
    parsed: List[Tuple[str, ast.Module, str]] = []
    registry = Registry()
    for path in files:
        text = path.read_text()
        tree = ast.parse(text)
        parsed.append((rel(path, root), tree, text))
        collect_jitted(tree, rel(path, root), registry)
    for relpath, _, text in parsed:
        result.findings += audit_source(text, relpath, registry)
    result.report["scanned"] = [r for r, _, _ in parsed]
    result.report["suppress_category"] = CATEGORY
    result.report["jitted"] = len(registry.defs) + len(registry.progs)
    return result
