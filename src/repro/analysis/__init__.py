"""Static hot-path auditor for the serving runtime.

Three passes over the repo, none of which execute the serving stack,
each turning a bug class the git history paid for once into a
machine-checked invariant:

* :mod:`repro.analysis.syncs` — AST host-sync lint over
  ``src/repro/serving/`` + ``src/repro/kernels/``: implicit
  device->host transfers (``float``/``int``/``bool``/``len``/
  ``np.asarray``/``.item``/iteration on values dataflow-reachable from
  jax arrays), host callbacks inside jitted builders, and Python
  branching on traced values. Per-line ``# analysis: allow(sync)``
  suppressions; committed baseline for accepted cold-path uses.
* :mod:`repro.analysis.recompiles` — compile-cache cardinality:
  ``jax.jit``/``pallas_call`` bound to instance state is a hard error
  (the per-instance-jit gotcha), every tick-program builder must be
  module-level ``lru_cache``d, and the static-arg key space reachable
  from ``plan.py`` is enumerated into a worst-case compile-count table.
* :mod:`repro.analysis.blockspecs` — Pallas BlockSpec bounds: every
  registered kernel index map is evaluated concretely over its full
  grid (including ``@pl.when``-skipped iterations, which still feed the
  DMA pipeline) against block-table extents with poisoned dead entries.
* :mod:`repro.analysis.programs` — the dynamic complement (still no
  serving stack): lowers the tick programs for a tiny model and proves
  the one-sync-per-horizon contract on the jaxpr and optimized HLO.

CLI: ``python -m repro.analysis --check`` (see ``__main__.py``).
"""
from repro.analysis.common import Finding  # noqa: F401  (public API)
