"""Static hot-path auditor for the serving runtime.

Six passes over the repo, none of which execute the serving stack,
each turning a bug class the git history paid for once into a
machine-checked invariant:

* :mod:`repro.analysis.syncs` — AST host-sync lint over
  ``src/repro/serving/`` + ``src/repro/kernels/``: implicit
  device->host transfers (``float``/``int``/``bool``/``len``/
  ``np.asarray``/``.item``/iteration on values dataflow-reachable from
  jax arrays), host callbacks inside jitted builders, and Python
  branching on traced values. Per-line ``# analysis: allow(sync)``
  suppressions; committed baseline for accepted cold-path uses.
* :mod:`repro.analysis.recompiles` — compile-cache cardinality:
  ``jax.jit``/``pallas_call`` bound to instance state is a hard error
  (the per-instance-jit gotcha), every tick-program builder must be
  module-level ``lru_cache``d, and the static-arg key space reachable
  from ``plan.py`` is enumerated into a worst-case compile-count table.
* :mod:`repro.analysis.blockspecs` — Pallas BlockSpec bounds: every
  registered kernel index map is evaluated concretely over its full
  grid (including ``@pl.when``-skipped iterations, which still feed the
  DMA pipeline) against block-table extents with poisoned dead entries.
* :mod:`repro.analysis.programs` — the dynamic complement (still no
  serving stack): lowers the tick programs for a tiny model and proves
  the one-sync-per-horizon contract on the jaxpr and optimized HLO.
  Honours ``REPRO_KV_QUANT`` so CI audits the quantized cache layout
  too.
* :mod:`repro.analysis.ownership` — interprocedural typestate pass
  over the paged-KV ledger protocol: every ``alloc_block``/``incref``
  ref must reach exactly one owner on **every** path including
  exception edges; double-release, unmatched ``reserve`` and raw
  ``decref`` loops that bypass ``release_table``'s dedup are flagged.
  ``# analysis: allow(ownership)`` on protocol-internal lines.
* :mod:`repro.analysis.donation` — buffer-donation/aliasing audit of
  the jitted tick programs: jitted cache/keys parameters must be
  donated (or carry ``allow(donation)`` for deliberate read-only
  uses), and donated call-site operands must never be read again
  before being rebound.

Shared AST call-graph plumbing (plus the HLO parser the ``programs``
pass uses) lives in :mod:`repro.analysis.callgraph`.

CLI: ``python -m repro.analysis --check`` (see ``__main__.py``).
``--check`` also fails on *stale* suppressions — dead inline
``allow(...)`` comments and baseline entries whose finding is fixed —
so the suppression surface can only shrink; ``--prune-baseline``
rewrites the baseline accordingly.
"""
from repro.analysis.common import Finding  # noqa: F401  (public API)
