"""CLI for the static hot-path auditor.

    python -m repro.analysis --check
    python -m repro.analysis --check --baseline experiments/analysis_baseline.json
    python -m repro.analysis --update-baseline
    python -m repro.analysis --prune-baseline

Exit status: 0 when every finding is suppressed or baselined AND the
suppression machinery itself is clean, 1 on new findings *or* stale
suppressions (CI gates on this), 2 on bad usage.

Suppression hygiene (checked under ``--check``): an inline
``# analysis: allow(<category>)`` comment that no longer suppresses
anything, or a baseline entry whose finding has been fixed, is itself
a failure — dead suppressions are how the *next* real finding at that
line/key sails through unreviewed. ``--prune-baseline`` rewrites the
baseline keeping only entries that still match a finding (entries
owned by skipped passes are preserved); stale ``allow`` comments must
be removed by hand (they carry justification prose worth reading
before deletion).

``--root`` points the file-scanning passes (syncs, recompiles,
ownership, donation) at a different tree — used by the tests to run
them over seeded-violation fixtures; the repo-bound passes
(blockspecs, programs) skip themselves when the root is not this
repo. ``--skip PASS`` disables a pass by name (``programs`` is the
only one that compiles anything; the others are pure AST/eval and run
in milliseconds).
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path
from typing import Dict, List

from repro.analysis import (blockspecs, common, donation, ownership,
                            programs, recompiles, syncs)

PASSES = {
    "syncs": syncs.run,
    "recompiles": recompiles.run,
    "blockspecs": blockspecs.run,
    "programs": programs.run,
    "ownership": ownership.run,
    "donation": donation.run,
}


def stale_allows(root: Path,
                 results: List[common.PassResult]) -> List[str]:
    """Inline ``allow(<cat>)`` comments in scanned files that suppress
    nothing — each is a latent hole where a future finding of that
    category would vanish without review."""
    out: List[str] = []
    for r in results:
        cat = r.report.get("suppress_category")
        scanned = r.report.get("scanned")
        if not cat or not scanned:
            continue
        live = {(f.path, f.line) for f in r.findings if f.suppressed}
        for relpath in scanned:
            path = root / relpath
            if not path.exists():
                continue
            sups = common.line_suppressions(path.read_text())
            for line_no in sorted(sups):
                if cat in sups[line_no] and \
                        (relpath, line_no) not in live:
                    out.append(
                        f"{relpath}:{line_no}: stale `# analysis: "
                        f"allow({cat})` — no {r.pass_id} finding is "
                        "suppressed here; remove the comment")
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="static hot-path auditor (host syncs, compile-cache "
                    "cardinality, BlockSpec bounds, one-sync contract, "
                    "block ownership, buffer donation)")
    ap.add_argument("--check", action="store_true",
                    help="run all passes; exit non-zero on new findings "
                         "or stale suppressions (default action)")
    ap.add_argument("--baseline", type=Path,
                    default=Path("experiments/analysis_baseline.json"),
                    help="accepted-findings file (repo-relative)")
    ap.add_argument("--update-baseline", action="store_true",
                    help="rewrite the baseline from current findings")
    ap.add_argument("--prune-baseline", action="store_true",
                    help="drop baseline entries whose finding is fixed "
                         "(entries of skipped passes are kept)")
    ap.add_argument("--root", type=Path, default=None,
                    help="tree to scan (default: this repo)")
    ap.add_argument("--skip", action="append", default=[],
                    choices=sorted(PASSES),
                    help="skip a pass (repeatable)")
    ap.add_argument("--table", action="store_true",
                    help="print the worst-case compile-count table")
    args = ap.parse_args(argv)

    root = (args.root or common.repo_root()).resolve()
    baseline_path = args.baseline if args.baseline.is_absolute() \
        else root / args.baseline

    results: List[common.PassResult] = []
    for name, fn in PASSES.items():
        if name in args.skip:
            continue
        results.append(fn(root))

    findings = [f for r in results for f in r.findings]
    if args.update_baseline:
        common.write_baseline(baseline_path, findings)
        print(f"baseline: wrote {sum(not f.suppressed for f in findings)} "
              f"finding(s) to {baseline_path}")
        return 0

    baseline = common.load_baseline(baseline_path)
    current = {f.key for f in findings if not f.suppressed}
    ran = {r.pass_id for r in results}
    new = [f for f in findings
           if not f.suppressed and f.key not in baseline]
    # an entry is stale only when its pass actually ran this invocation
    # and produced no matching finding — skipped passes prove nothing
    stale = sorted(k for k in baseline
                   if k.split(":", 1)[0] in ran and k not in current)

    if args.prune_baseline:
        kept = {k: v for k, v in baseline.items() if k not in stale}
        common.write_baseline_entries(baseline_path, kept)
        print(f"baseline: pruned {len(stale)} stale entr"
              f"{'y' if len(stale) == 1 else 'ies'}, kept {len(kept)}")
        return 0

    n_suppressed = sum(f.suppressed for f in findings)
    n_baselined = len(findings) - n_suppressed - len(new)
    for r in results:
        extra = ""
        if r.report and r.pass_id == "blockspec":
            extra = (f" ({r.report.get('audits', 0)} maps, "
                     f"{r.report.get('grid_points', 0)} grid points)")
        print(f"pass {r.pass_id:<9} findings: "
              f"{sum(1 for f in r.findings if not f.suppressed):>3}"
              f"{extra}")
    print(f"total: {len(findings)} finding(s) — {n_suppressed} allowed "
          f"inline, {n_baselined} baselined, {len(new)} new")

    sync_report: Dict = next((r.report for r in results
                              if r.pass_id == "program"), {})
    if sync_report:
        one_sync = all(
            sync_report.get(fn, {}).get("fetch_sites") == 1
            for fn in ("dispatch_horizon", "dispatch_mixed"))
        hidden = sum(v.get("jaxpr_callbacks", 0) + v.get("hlo_host_ops", 0)
                     for v in sync_report.values() if isinstance(v, dict))
        print("one-sync contract: dispatcher fetch sites "
              f"{'OK' if one_sync else 'VIOLATED'}, hidden host "
              f"ops in compiled programs: {hidden}")

    if args.table:
        table = next((r.report.get("compile_table") for r in results
                      if r.pass_id == "recompile"), None)
        if table:
            print(json.dumps({"compile_table": table}, indent=1))

    failed = False
    dead_allows = stale_allows(root, results)
    if dead_allows:
        failed = True
        print(f"\n{len(dead_allows)} stale inline suppression(s):")
        for msg in dead_allows:
            print(f"  {msg}")
    if stale:
        failed = True
        print(f"\n{len(stale)} stale baseline entr"
              f"{'y' if len(stale) == 1 else 'ies'} (fixed findings); "
              "drop with --prune-baseline:")
        for k in stale:
            print(f"  - {k}")
    if new:
        failed = True
        print(f"\n{len(new)} new finding(s):")
        for f in sorted(new, key=lambda f: (f.path, f.line)):
            print(f"  {f.render()}")
        print("\nfix the finding, add `# analysis: allow(<category>)` "
              "on the line if it is accounted, or accept it with "
              "--update-baseline.")
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
