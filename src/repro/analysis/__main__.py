"""CLI for the static hot-path auditor.

    python -m repro.analysis --check
    python -m repro.analysis --check --baseline experiments/analysis_baseline.json
    python -m repro.analysis --update-baseline

Exit status: 0 when every finding is suppressed or baselined, 1 when
new findings exist (CI gates on this), 2 on bad usage.

``--root`` points the file-scanning passes (syncs, recompiles) at a
different tree — used by the tests to run them over seeded-violation
fixtures; the repo-bound passes (blockspecs, programs) skip themselves
when the root is not this repo. ``--skip PASS`` disables a pass by
name (``programs`` is the only one that compiles anything; the other
three are pure AST/eval and run in milliseconds).
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path
from typing import Dict, List

from repro.analysis import blockspecs, common, programs, recompiles, syncs

PASSES = {
    "syncs": syncs.run,
    "recompiles": recompiles.run,
    "blockspecs": blockspecs.run,
    "programs": programs.run,
}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="static hot-path auditor (host syncs, compile-cache "
                    "cardinality, BlockSpec bounds, one-sync contract)")
    ap.add_argument("--check", action="store_true",
                    help="run all passes; exit non-zero on new findings "
                         "(default action)")
    ap.add_argument("--baseline", type=Path,
                    default=Path("experiments/analysis_baseline.json"),
                    help="accepted-findings file (repo-relative)")
    ap.add_argument("--update-baseline", action="store_true",
                    help="rewrite the baseline from current findings")
    ap.add_argument("--root", type=Path, default=None,
                    help="tree to scan (default: this repo)")
    ap.add_argument("--skip", action="append", default=[],
                    choices=sorted(PASSES),
                    help="skip a pass (repeatable)")
    ap.add_argument("--table", action="store_true",
                    help="print the worst-case compile-count table")
    args = ap.parse_args(argv)

    root = (args.root or common.repo_root()).resolve()
    baseline_path = args.baseline if args.baseline.is_absolute() \
        else root / args.baseline

    results: List[common.PassResult] = []
    for name, fn in PASSES.items():
        if name in args.skip:
            continue
        results.append(fn(root))

    findings = [f for r in results for f in r.findings]
    if args.update_baseline:
        common.write_baseline(baseline_path, findings)
        print(f"baseline: wrote {sum(not f.suppressed for f in findings)} "
              f"finding(s) to {baseline_path}")
        return 0

    baseline = common.load_baseline(baseline_path)
    new = [f for f in findings
           if not f.suppressed and f.key not in baseline]
    stale = sorted(set(baseline)
                   - {f.key for f in findings if not f.suppressed})

    n_suppressed = sum(f.suppressed for f in findings)
    n_baselined = len(findings) - n_suppressed - len(new)
    for r in results:
        extra = ""
        if r.report and r.pass_id == "blockspec":
            extra = (f" ({r.report.get('audits', 0)} maps, "
                     f"{r.report.get('grid_points', 0)} grid points)")
        print(f"pass {r.pass_id:<9} findings: "
              f"{sum(1 for f in r.findings if not f.suppressed):>3}"
              f"{extra}")
    print(f"total: {len(findings)} finding(s) — {n_suppressed} allowed "
          f"inline, {n_baselined} baselined, {len(new)} new")

    sync_report: Dict = next((r.report for r in results
                              if r.pass_id == "program"), {})
    if sync_report:
        one_sync = all(
            sync_report.get(fn, {}).get("fetch_sites") == 1
            for fn in ("dispatch_horizon", "dispatch_mixed"))
        hidden = sum(v.get("jaxpr_callbacks", 0) + v.get("hlo_host_ops", 0)
                     for v in sync_report.values() if isinstance(v, dict))
        print("one-sync contract: dispatcher fetch sites "
              f"{'OK' if one_sync else 'VIOLATED'}, hidden host "
              f"ops in compiled programs: {hidden}")

    if args.table:
        table = next((r.report.get("compile_table") for r in results
                      if r.pass_id == "recompile"), None)
        if table:
            print(json.dumps({"compile_table": table}, indent=1))

    if stale:
        print(f"note: {len(stale)} stale baseline entr"
              f"{'y' if len(stale) == 1 else 'ies'} (fixed findings); "
              "refresh with --update-baseline:")
        for k in stale:
            print(f"  - {k}")
    if new:
        print(f"\n{len(new)} new finding(s):")
        for f in sorted(new, key=lambda f: (f.path, f.line)):
            print(f"  {f.render()}")
        print("\nfix the finding, add `# analysis: allow(<category>)` "
              "on the line if it is accounted, or accept it with "
              "--update-baseline.")
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
