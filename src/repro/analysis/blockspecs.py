"""Pallas BlockSpec bounds checker.

A BlockSpec index map computes the DMA source coordinates for EVERY
grid iteration — including the iterations the kernel body skips with
``@pl.when``. The compute guard gates MXU work, not the prefetch
pipeline, so an index map that walks past a row's live data still
streams those blocks through VMEM. That was the PR 7 kernel bug: the
paged decode kernel's k/v gather indexed ``tbl[bi, ti]`` for all T
table entries, pulling table padding and the horizon path's
preallocated-but-unwritten blocks through the DMA engine on every tick;
the fix clamps to the row's last live block
(``jnp.minimum(ti, pos // B)``).

This pass makes that fix a regression class: every kernel in
``src/repro/kernels/`` registers its production index maps (module
level, the same objects ``pl.pallas_call`` receives) in
``kernels/registry.py`` together with a toy grid, scalar-prefetch
arguments whose dead block-table entries are POISON ids, and per-axis
extents. The checker evaluates each map concretely over the FULL grid
and fails on any coordinate outside its extent — a missing clamp
fetches a poison id, which is out of bounds by construction.

Coverage is itself checked: the pass AST-scans the kernels package for
functions that invoke ``pl.pallas_call`` and fails if any is missing
from ``registry.AUDITED_KERNELS``.
"""
from __future__ import annotations

import ast
import itertools
import math
from pathlib import Path
from typing import List, Optional

from repro.analysis.common import (Finding, PassResult, assign_occurrences,
                                   iter_sources, rel)

PASS_ID = "blockspec"
KERNELS_DIR = "src/repro/kernels"


def check_audit(audit) -> List[Finding]:
    """Evaluate one registry entry's index map over its full grid."""
    findings: List[Finding] = []
    path = f"{KERNELS_DIR}/registry.py"
    for ids in itertools.product(*[range(n) for n in audit.grid]):
        coords = audit.index_map(*ids, *audit.scalar_args)
        if len(coords) != len(audit.extents):
            findings.append(Finding(
                PASS_ID, "arity", path, 0,
                f"{audit.kernel}:{audit.operand}",
                f"index map returned {len(coords)} coords for "
                f"{len(audit.extents)} extents"))
            return findings
        for axis, (c, extent) in enumerate(zip(coords, audit.extents)):
            ci = int(c)
            if not 0 <= ci < extent:
                findings.append(Finding(
                    PASS_ID, "out-of-bounds", path, 0,
                    f"{audit.kernel}:{audit.operand}",
                    f"grid point {ids}: axis {axis} block coord {ci} "
                    f"outside [0, {extent}) — a @pl.when skip does NOT "
                    "stop this DMA; the map must clamp to the row's "
                    "last live block"))
                return findings      # one hit per (kernel, operand)
    return findings


def _pallas_wrappers(tree: ast.Module) -> List[str]:
    """Module-level function names whose body (closures included) calls
    pl.pallas_call."""
    out = []
    for stmt in tree.body:
        if not isinstance(stmt, ast.FunctionDef):
            continue
        for node in ast.walk(stmt):
            if isinstance(node, ast.Call):
                name = _dotted(node.func) or ""
                if name.endswith("pallas_call"):
                    out.append(stmt.name)
                    break
    return out


def _dotted(node: ast.AST) -> Optional[str]:
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def run(root: Path) -> PassResult:
    result = PassResult(PASS_ID)
    kdir = root / KERNELS_DIR
    if not kdir.is_dir():
        return result               # fixture tree: nothing to audit
    from repro.kernels import registry
    audits = registry.default_audits()
    for audit in audits:
        result.findings += check_audit(audit)
    # coverage: every pallas_call wrapper in the package must be audited
    for path in iter_sources(root, (KERNELS_DIR,)):
        wrappers = _pallas_wrappers(ast.parse(path.read_text()))
        for name in wrappers:
            if name.startswith("_"):
                continue            # kernel bodies / private helpers
            if name not in registry.AUDITED_KERNELS:
                result.findings.append(Finding(
                    PASS_ID, "unregistered-kernel", rel(path, root), 0,
                    name,
                    f"`{name}` wraps pl.pallas_call but registers no "
                    "IndexMapAudit in kernels/registry.py"))
    result.report["audits"] = len(audits)
    result.report["grid_points"] = sum(math.prod(a.grid) for a in audits)
    assign_occurrences(result.findings)
    return result
