"""Shared finding / suppression / baseline plumbing for the passes.

A :class:`Finding` is one pass hit. Its `key` deliberately excludes the
line number — ``pass:code:path:scope:occurrence`` — so the committed
baseline survives unrelated edits that shift lines, while a *new*
occurrence of the same code in the same function still shows up as new.

Suppression is per-line: a ``# analysis: allow(<category>)`` comment on
the offending line accepts that single site forever (used for accounted
syncs — the dispatcher fetch that `record_sync` meters). The baseline
(``experiments/analysis_baseline.json``) accepts existing cold-path
findings without editing them; CI fails only on findings that are
neither suppressed nor baselined.
"""
from __future__ import annotations

import json
import re
from dataclasses import dataclass, field
from pathlib import Path
from typing import Dict, Iterable, List, Optional

_ALLOW_RE = re.compile(r"#\s*analysis:\s*allow\(([a-z0-9_,\s-]+)\)")


@dataclass
class Finding:
    pass_id: str            # "sync" | "recompile" | "blockspec" | "program"
    code: str               # short slug, e.g. "asarray", "bound-jit"
    path: str               # repo-relative posix path
    line: int
    scope: str              # enclosing function qualname ("" = module)
    message: str
    suppressed: bool = False
    occurrence: int = 0     # index among same (pass, code, path, scope)

    @property
    def key(self) -> str:
        return (f"{self.pass_id}:{self.code}:{self.path}:"
                f"{self.scope}:{self.occurrence}")

    def render(self) -> str:
        where = f"{self.path}:{self.line}"
        scope = f" [{self.scope}]" if self.scope else ""
        return f"{where}: {self.pass_id}/{self.code}{scope}: {self.message}"


def assign_occurrences(findings: List[Finding]) -> List[Finding]:
    """Number findings within each (pass, code, path, scope) group in
    line order, making keys stable and unique."""
    counts: Dict[str, int] = {}
    for f in sorted(findings, key=lambda f: (f.path, f.line, f.code)):
        group = f"{f.pass_id}:{f.code}:{f.path}:{f.scope}"
        f.occurrence = counts.get(group, 0)
        counts[group] = f.occurrence + 1
    return findings


def line_suppressions(text: str) -> Dict[int, set]:
    """1-based line -> set of allowed categories on that line."""
    out: Dict[int, set] = {}
    for i, line in enumerate(text.splitlines(), start=1):
        m = _ALLOW_RE.search(line)
        if m:
            out[i] = {c.strip() for c in m.group(1).split(",") if c.strip()}
    return out


def apply_suppressions(findings: Iterable[Finding], text: str,
                       category: str) -> List[Finding]:
    """Mark findings whose line carries an allow(<category>) comment."""
    allowed = line_suppressions(text)
    out = []
    for f in findings:
        if category in allowed.get(f.line, ()):
            f.suppressed = True
        out.append(f)
    return out


def load_baseline(path: Path) -> Dict[str, str]:
    """key -> message of accepted findings; {} if the file is absent."""
    if not path.exists():
        return {}
    data = json.loads(path.read_text())
    return dict(data.get("findings", {}))


def write_baseline(path: Path, findings: Iterable[Finding]) -> None:
    write_baseline_entries(
        path, {f.key: f.message for f in findings if not f.suppressed})


def write_baseline_entries(path: Path, entries: Dict[str, str]) -> None:
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_text(json.dumps(
        {"version": 1,
         "note": "accepted findings; regenerate with "
                 "`python -m repro.analysis --update-baseline`",
         "findings": dict(sorted(entries.items()))}, indent=1) + "\n")


@dataclass
class PassResult:
    """One pass's findings plus any free-form report payload (e.g. the
    compile-count table) the CLI prints."""
    pass_id: str
    findings: List[Finding] = field(default_factory=list)
    report: Dict = field(default_factory=dict)


def repo_root(start: Optional[Path] = None) -> Path:
    """The repo checkout containing this package (…/src/repro/analysis)."""
    here = (start or Path(__file__)).resolve()
    return here.parents[3]


def iter_sources(root: Path, subdirs: Iterable[str]) -> List[Path]:
    """Python sources under root/<subdir> for each subdir that exists;
    if none exist (fixture trees in tests), every .py under root."""
    files: List[Path] = []
    for sub in subdirs:
        d = root / sub
        if d.is_dir():
            files += sorted(d.rglob("*.py"))
    if not files:
        files = sorted(root.rglob("*.py"))
    return files


def rel(path: Path, root: Path) -> str:
    try:
        return path.resolve().relative_to(root.resolve()).as_posix()
    except ValueError:
        return path.as_posix()
