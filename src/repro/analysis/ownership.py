"""Block-ownership typestate pass for the paged-KV ledger.

Every correctness incident in this runtime's history was a block-
ownership bug caught *dynamically* — the double-decref in
``release_table``, the chunk->mixed prefill-handoff bug, the
stash-window leak. This pass makes the ledger protocol a *static*
regression class: an AST-level abstract interpreter runs over the
serving modules and checks every function against the
:class:`~repro.serving.paged_pool.PagedKVPool` typestate machine.

**States** a tracked binding moves through::

    owned      holds ledger refs (alloc_block / match / incref result)
    moved      transferred to exactly one owner (table attr, append
               into an owner table, returned to the caller)
    released   explicitly freed (decref / release_table / unmatch)
    reg        alias of an owner container (`c.table = t = []` — appends
               into `t` are registration, not accumulation)
    empty      fresh local list, owns nothing yet
    borrowed   alias of an owner-held value (`t = c.table`) — releasing
               it spends the owner's ref
    param      caller-owned argument (releasing it makes this function a
               consumer in its summary)

**Owners** are the request table / child table (``.table`` attribute
assignment, appends into owner-aliased tables), the radix tree
(``publish`` keeps its own ref), the caller (``return``), or an
explicit release.

**Rules** (finding codes):

* ``leak`` — an owned binding or incref obligation reaches a function
  exit without an owner on some path.
* ``leak-on-raise`` — an owned binding is live across a may-raise
  protocol call before registration (the exception edge between
  acquisition and registration), outside any try.
* ``double-release`` — a second ``decref``/``release_table``/``unmatch``
  is reachable on one binding (including release-after-transfer).
* ``decref-loop`` — a raw ``for blk in table: pool.decref(blk)`` loop
  bypasses ``release_table``'s seen-set dedup (a table that holds the
  same block twice — COW boundary + shared prefix — double-frees).
* ``unmatched-reserve`` — ``reserve`` opens a reservation that some
  path neither ``unreserve``s, claims (``alloc_block``/``preallocate``
  with ``from_reservation=True``), nor transfers to an owner's
  ``.reserved`` field.

Interprocedural: per-function summaries (returns-owned / consumed
params / may-raise) are iterated to a fixpoint over the name-keyed
call graph from :mod:`repro.analysis.callgraph`, seeded with the pool
protocol. Summaries key on *leaf* call names — the protocol names are
collision-free within the scanned modules (checked when they were
chosen; `.match`/`.clear` collisions were grepped out).

Escape hatches are the standard ones: ``# analysis: allow(ownership)``
on the acquisition line for accounted patterns (the radix tree's own
refs), the committed baseline for accepted findings.
"""
from __future__ import annotations

import ast
from dataclasses import dataclass, field
from pathlib import Path
from typing import Dict, FrozenSet, List, Optional, Set, Tuple

from repro.analysis.callgraph import (build_py_call_graph, dotted,
                                      walk_functions)
from repro.analysis.common import (Finding, PassResult, apply_suppressions,
                                   assign_occurrences, rel)

PASS_ID = "ownership"
CATEGORY = "ownership"          # allow(ownership)

#: scan targets relative to the repo root (files or directories); when
#: none exist (fixture trees in tests) every .py under root is scanned
MODULES = (
    "src/repro/serving/runtime.py",
    "src/repro/serving/retire.py",
    "src/repro/serving/plan.py",
    "src/repro/serving/tick_programs.py",
    "src/repro/serving/radix_cache.py",
    "src/repro/serving/procedure.py",
    "src/repro/serving/traffic",
)

#: attribute whose assignment registers a table/block with an owner
OWNER_ATTRS = {"table"}

#: parameter names treated as block tables for the decref-loop rule
TABLE_PARAMS = {"table", "tables", "blocks"}


@dataclass(frozen=True)
class Summary:
    """Ledger-relevant facts about one callable, keyed by leaf name."""
    returns_owned: bool = False
    consumes: FrozenSet[int] = frozenset()      # positional args released
    acquires_into: FrozenSet[int] = frozenset()  # args extended with blocks
    increfs: bool = False                       # arg0 gains an obligation
    reserves: bool = False
    unreserves: bool = False
    claims: bool = False                        # closes one reservation
    may_raise: bool = False

    def merged(self, other: "Summary") -> "Summary":
        return Summary(
            returns_owned=self.returns_owned or other.returns_owned,
            consumes=self.consumes | other.consumes,
            acquires_into=self.acquires_into | other.acquires_into,
            increfs=self.increfs or other.increfs,
            reserves=self.reserves or other.reserves,
            unreserves=self.unreserves or other.unreserves,
            claims=self.claims or other.claims,
            may_raise=self.may_raise or other.may_raise)


#: the PagedKVPool / RadixCache protocol, by leaf method name. Every
#: entry is may_raise: the ledger asserts on bad ids, double frees and
#: reservation overdraft, and the device calls can fail — these are
#: exactly the exception edges the leak-on-raise rule walks.
PROTOCOL: Dict[str, Summary] = {
    "alloc_block":    Summary(returns_owned=True, claims=True,
                              may_raise=True),
    "preallocate":    Summary(acquires_into=frozenset({0}), claims=True,
                              may_raise=True),
    "incref":         Summary(increfs=True, may_raise=True),
    "decref":         Summary(consumes=frozenset({0}), may_raise=True),
    "release_table":  Summary(consumes=frozenset({0}), may_raise=True),
    "unmatch":        Summary(consumes=frozenset({0}), may_raise=True),
    "match":          Summary(returns_owned=True, may_raise=True),
    "publish":        Summary(may_raise=True),
    "evict":          Summary(may_raise=True),
    "copy_block":     Summary(may_raise=True),
    "reserve":        Summary(reserves=True, may_raise=True),
    "unreserve":      Summary(unreserves=True, may_raise=True),
    "alloc_slot":     Summary(may_raise=True),
    "release_slot":   Summary(may_raise=True),
    "reset_slot_state":   Summary(may_raise=True),
    "restore_slot_state": Summary(may_raise=True),
    "release_request":    Summary(may_raise=True),
}

_OWNED = "owned"
_MOVED = "moved"
_RELEASED = "released"
_REG = "reg"
_EMPTY = "empty"
_BORROWED = "borrowed"
_PARAM = "param"

#: states a release transitions cleanly out of
_RELEASABLE = {_OWNED, _BORROWED, _EMPTY, _PARAM}


@dataclass
class Env:
    """Abstract state at one program point. `vars` maps a local name to
    the set of states it may be in (sets join path unions); `obligations`
    are increfs of non-name expressions awaiting a textual discharge;
    `reserves` is the set of possible open-reservation stacks (tuples of
    reserve line numbers)."""
    vars: Dict[str, Set[str]] = field(default_factory=dict)
    acq: Dict[str, int] = field(default_factory=dict)
    obligations: Dict[str, int] = field(default_factory=dict)
    reserves: Set[Tuple[int, ...]] = field(
        default_factory=lambda: {()})
    terminated: bool = False

    def copy(self) -> "Env":
        return Env({k: set(v) for k, v in self.vars.items()},
                   dict(self.acq), dict(self.obligations),
                   set(self.reserves), self.terminated)

    def join(self, other: "Env") -> "Env":
        """Path union of two non-terminated states (a terminated branch
        contributes nothing)."""
        if other.terminated:
            return self
        if self.terminated:
            return other
        out = self.copy()
        for k, v in other.vars.items():
            out.vars.setdefault(k, set()).update(v)
        for k, ln in other.acq.items():
            out.acq.setdefault(k, ln)
        for k, ln in other.obligations.items():
            out.obligations.setdefault(k, ln)
        out.reserves |= other.reserves
        return out


@dataclass
class Facts:
    """Summary-relevant observations from one interpretation."""
    returns_owned: bool = False
    consumed_params: Set[int] = field(default_factory=set)
    may_raise: bool = False


def _unparse(node: ast.AST) -> str:
    try:
        return ast.unparse(node)
    except Exception:       # pragma: no cover - malformed nodes
        return ""


def _arg_names(call: ast.Call) -> Set[str]:
    out: Set[str] = set()
    for a in list(call.args) + [kw.value for kw in call.keywords]:
        for n in ast.walk(a):
            if isinstance(n, ast.Name):
                out.add(n.id)
    return out


def _kwarg_false(call: ast.Call, name: str) -> bool:
    for kw in call.keywords:
        if kw.arg == name and isinstance(kw.value, ast.Constant):
            return kw.value.value is False
    return False


class _OwnershipAuditor:
    """Abstract interpretation of one function body against the ledger
    typestate machine. Loop bodies run twice so loop-carried state
    converges; branch joins are path unions; findings dedupe on
    (code, line, detail)."""

    def __init__(self, fn: ast.AST, qualname: str, relpath: str,
                 summaries: Dict[str, Summary], record: bool):
        self.fn = fn
        self.qualname = qualname
        self.relpath = relpath
        self.summaries = summaries
        self.record = record
        self.in_try = 0
        self.facts = Facts()
        self.found: Dict[Tuple[str, int, str], str] = {}
        self.hazard_seen: Set[str] = set()
        args = fn.args
        names = [a.arg for a in (args.posonlyargs + args.args)]
        if names and names[0] in ("self", "cls"):
            names = names[1:]
        self.param_index = {n: i for i, n in enumerate(names)}

    # --------------------------------------------------------- findings
    def _flag(self, code: str, line: int, detail: str, msg: str) -> None:
        if self.record:
            self.found.setdefault((code, line, detail), msg)

    def findings(self) -> List[Finding]:
        return [Finding(PASS_ID, code, self.relpath, line, self.qualname,
                        msg)
                for (code, line, _), msg in sorted(self.found.items(),
                                                   key=lambda kv: kv[0][1])]

    # ------------------------------------------------------------ calls
    def _summary_for(self, call: ast.Call) -> Optional[Summary]:
        name = dotted(call.func)
        if name is None:
            return None
        return self.summaries.get(name.rsplit(".", 1)[-1])

    def _apply_call(self, call: ast.Call, env: Env) -> None:
        s = self._summary_for(call)
        if s is None:
            return
        line = call.lineno
        if s.may_raise and self.in_try == 0:
            self._raise_hazard(call, env, line)
        for i in sorted(s.consumes):
            if i < len(call.args):
                self._consume(call.args[i], env, line)
        if s.increfs and call.args:
            a = call.args[0]
            if isinstance(a, ast.Name):
                env.vars[a.id] = {_OWNED}
                env.acq[a.id] = line
            else:
                env.obligations.setdefault(_unparse(a), line)
        if s.acquires_into and call.args:
            a = call.args[0]
            if isinstance(a, ast.Name):
                st = env.vars.get(a.id, set())
                if _EMPTY in st or _OWNED in st:
                    st.discard(_EMPTY)
                    st.add(_OWNED)
                    env.vars[a.id] = st
                    env.acq.setdefault(a.id, line)
        if s.reserves:
            env.reserves = {st + (line,) for st in env.reserves}
        if s.unreserves or (s.claims and not
                            _kwarg_false(call, "from_reservation")):
            env.reserves = {st[:-1] if st else st for st in env.reserves}
        if s.may_raise:
            self.facts.may_raise = True

    def _raise_hazard(self, call: ast.Call, env: Env, line: int) -> None:
        """An owned binding live across a may-raise protocol call: the
        exception edge loses the refs before any owner sees them.
        Bindings named in the call's own arguments are exempt (the call
        is part of their handling), as is anything under a try."""
        args = _arg_names(call)
        call_text = _unparse(call)
        for var, st in env.vars.items():
            if _OWNED in st and var not in args and \
                    var not in self.hazard_seen:
                self.hazard_seen.add(var)
                self._flag(
                    "leak-on-raise", env.acq.get(var, line), var,
                    f"`{var}` holds block refs with no owner when "
                    f"`{_unparse(call.func)}` (line {line}) raises — "
                    "register it (owner table / return / release) before "
                    "the call, or wrap the window in try/finally")
        for text, oline in env.obligations.items():
            if text not in call_text and text not in self.hazard_seen:
                self.hazard_seen.add(text)
                self._flag(
                    "leak-on-raise", oline, text,
                    f"incref of `{text}` has no owner when "
                    f"`{_unparse(call.func)}` (line {line}) raises")

    def _consume(self, node: ast.AST, env: Env, line: int) -> None:
        if isinstance(node, ast.Name):
            st = env.vars.get(node.id)
            if st is None:
                return
            if _RELEASED in st:
                self._flag(
                    "double-release", line, node.id,
                    f"`{node.id}` is released twice on some path — the "
                    "second decref/release_table double-frees its blocks")
            elif _MOVED in st:
                self._flag(
                    "double-release", line, node.id,
                    f"`{node.id}` is released after its ownership was "
                    "transferred — owner and release both free it")
            if _PARAM in st:
                idx = self.param_index.get(node.id)
                if idx is not None:
                    self.facts.consumed_params.add(idx)
            env.vars[node.id] = {_RELEASED}
        elif isinstance(node, (ast.List, ast.Tuple)):
            for e in node.elts:
                self._consume(e, env, line)
        else:
            env.obligations.pop(_unparse(node), None)

    # ------------------------------------------------------- statements
    def _calls_in_expr(self, node: ast.AST, env: Env) -> None:
        for n in ast.walk(node):
            if isinstance(n, ast.Call):
                self._apply_call(n, env)

    def _is_owned_value(self, node: ast.AST, env: Env) -> bool:
        if isinstance(node, ast.Name):
            return _OWNED in env.vars.get(node.id, set())
        if isinstance(node, ast.Call):
            s = self._summary_for(node)
            return bool(s and s.returns_owned)
        return False

    def _handle_append(self, call: ast.Call, env: Env) -> bool:
        """`X.append(y)` / `X.extend(y)`: registration when X is (an
        alias of) an owner table, accumulation when X is a fresh local."""
        if not (isinstance(call.func, ast.Attribute)
                and call.func.attr in ("append", "extend")
                and len(call.args) == 1):
            return False
        target, arg = call.func.value, call.args[0]
        arg_owned = self._is_owned_value(arg, env)
        arg_text = _unparse(arg)
        discharged = env.obligations.pop(arg_text, None)
        if isinstance(arg, ast.Name) and arg_owned:
            env.vars[arg.id] = {_MOVED}
        if isinstance(target, ast.Name):
            st = env.vars.get(target.id, set())
            if (arg_owned or discharged is not None) and \
                    not st & {_REG, _PARAM, _BORROWED}:
                st.discard(_EMPTY)
                st.add(_OWNED)
                env.vars[target.id] = st
                env.acq.setdefault(
                    target.id,
                    discharged if discharged is not None else call.lineno)
        return True

    def _assign_value_state(self, value: ast.AST,
                            env: Env) -> Optional[Set[str]]:
        """State for a Name target bound to `value`; None = untracked."""
        if isinstance(value, ast.Call):
            s = self._summary_for(value)
            if s and s.returns_owned:
                return {_OWNED}
            return None
        if isinstance(value, (ast.List, ast.Tuple)) and not value.elts:
            return {_EMPTY}
        if isinstance(value, ast.Name):
            st = env.vars.get(value.id)
            return set(st) if st is not None else None
        if isinstance(value, ast.Attribute) and value.attr in OWNER_ATTRS:
            return {_BORROWED}
        if isinstance(value, ast.IfExp):
            a = self._assign_value_state(value.body, env)
            b = self._assign_value_state(value.orelse, env)
            if a or b:
                return (a or set()) | (b or set())
        return None

    def _do_assign(self, targets: List[ast.AST], value: ast.AST,
                   env: Env, line: int) -> None:
        names = [t for t in targets if isinstance(t, ast.Name)]
        sinks = [t for t in targets if not isinstance(t, ast.Name)]
        # registration sinks: owner-attr / subscript targets take over
        if sinks:
            if isinstance(value, ast.Name) and \
                    _OWNED in env.vars.get(value.id, set()):
                env.vars[value.id] = {_MOVED}
            elif isinstance(value, (ast.List, ast.Tuple)):
                for e in value.elts:
                    if isinstance(e, ast.Name) and \
                            _OWNED in env.vars.get(e.id, set()):
                        env.vars[e.id] = {_MOVED}
            env.obligations.pop(_unparse(value), None)
            # `.reserved = ...` transfers open reservations to an owner
            if any(isinstance(t, ast.Attribute) and t.attr == "reserved"
                   for t in sinks):
                env.reserves = {()}
        for t in names:
            if sinks:
                # `c.table = t = []`: t aliases the owner's container
                env.vars[t.id] = {_REG}
                env.acq.pop(t.id, None)
                continue
            st = self._assign_value_state(value, env)
            if st is None:
                env.vars.pop(t.id, None)
                env.acq.pop(t.id, None)
            else:
                env.vars[t.id] = st
                if _OWNED in st:
                    env.acq[t.id] = line
                else:
                    env.acq.pop(t.id, None)
        for t in targets:
            if isinstance(t, (ast.Tuple, ast.List)):
                for e in t.elts:
                    if isinstance(e, ast.Name):
                        env.vars.pop(e.id, None)

    def _check_exit(self, env: Env, line: int) -> None:
        for var, st in env.vars.items():
            if _OWNED in st:
                self._flag(
                    "leak", env.acq.get(var, line), var,
                    f"`{var}` can reach a function exit still owning "
                    "block refs — no owner table, return, or release on "
                    "this path")
        for text, oline in env.obligations.items():
            self._flag(
                "leak", oline, text,
                f"incref of `{text}` reaches a function exit without an "
                "owner")
        seen: Set[int] = set()
        for stack in env.reserves:
            for ln in stack:
                if ln not in seen:
                    seen.add(ln)
                    self._flag(
                        "unmatched-reserve", ln, str(ln),
                        "reservation opened here is neither unreserved, "
                        "claimed by alloc_block/preallocate, nor "
                        "transferred to an owner's `.reserved` on every "
                        "path")

    def _check_decref_loop(self, stmt: ast.For, env: Env) -> None:
        it = stmt.iter
        table_typed = False
        if isinstance(it, ast.Name):
            st = env.vars.get(it.id, set())
            table_typed = bool(st & {_OWNED, _EMPTY, _BORROWED, _REG}) or \
                (it.id in self.param_index and it.id in TABLE_PARAMS)
        elif isinstance(it, ast.Attribute):
            table_typed = it.attr in OWNER_ATTRS
        if not table_typed or not isinstance(stmt.target, ast.Name):
            return
        loopvar = stmt.target.id
        for n in ast.walk(stmt):
            if isinstance(n, ast.Call):
                name = dotted(n.func) or ""
                if name.rsplit(".", 1)[-1] == "decref" and n.args and \
                        isinstance(n.args[0], ast.Name) and \
                        n.args[0].id == loopvar:
                    self._flag(
                        "decref-loop", stmt.lineno, loopvar,
                        "raw decref loop over a block table bypasses "
                        "release_table's dedup — a table holding the "
                        "same block twice (COW boundary, shared prefix) "
                        "double-frees it")

    def _exec_block(self, stmts: List[ast.stmt], env: Env) -> None:
        for stmt in stmts:
            if env.terminated:
                break
            self._exec_stmt(stmt, env)

    def _exec_stmt(self, stmt: ast.stmt, env: Env) -> None:
        if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.ClassDef)):
            return
        if isinstance(stmt, ast.Expr):
            if isinstance(stmt.value, ast.Call):
                call = stmt.value
                # evaluate nested protocol calls (e.g. the alloc inside
                # `t.append(pool.alloc_block())`) before the append
                for n in ast.walk(call):
                    if isinstance(n, ast.Call) and n is not call:
                        self._apply_call(n, env)
                if not self._handle_append(call, env):
                    self._apply_call(call, env)
            else:
                self._calls_in_expr(stmt.value, env)
        elif isinstance(stmt, (ast.Assign, ast.AnnAssign, ast.AugAssign)):
            value = stmt.value
            if value is not None:
                self._calls_in_expr(value, env)
            if isinstance(stmt, ast.Assign):
                targets = stmt.targets
            else:
                targets = [stmt.target]
            if isinstance(stmt, ast.AugAssign):
                if isinstance(stmt.target, ast.Attribute) and \
                        stmt.target.attr == "reserved":
                    env.reserves = {()}
            elif value is not None:
                self._do_assign(targets, value, env, stmt.lineno)
        elif isinstance(stmt, ast.Return):
            if stmt.value is not None:
                self._calls_in_expr(stmt.value, env)
                v = stmt.value
                if self._is_owned_value(v, env):
                    self.facts.returns_owned = True
                    if isinstance(v, ast.Name):
                        env.vars[v.id] = {_MOVED}
                elif isinstance(v, (ast.List, ast.Tuple)):
                    for e in v.elts:
                        if isinstance(e, ast.Name) and \
                                _OWNED in env.vars.get(e.id, set()):
                            env.vars[e.id] = {_MOVED}
                            self.facts.returns_owned = True
                env.obligations.pop(_unparse(stmt.value), None)
            self._check_exit(env, stmt.lineno)
            env.terminated = True
        elif isinstance(stmt, ast.Raise):
            self.facts.may_raise = True
            if self.in_try == 0:
                for var, st in env.vars.items():
                    if _OWNED in st and var not in self.hazard_seen:
                        self.hazard_seen.add(var)
                        self._flag(
                            "leak-on-raise", env.acq.get(var, stmt.lineno),
                            var,
                            f"`{var}` holds block refs with no owner on "
                            f"the raise at line {stmt.lineno}")
            env.terminated = True
        elif isinstance(stmt, ast.If):
            self._calls_in_expr(stmt.test, env)
            b = env.copy()
            self._exec_block(stmt.body, b)
            o = env.copy()
            self._exec_block(stmt.orelse, o)
            joined = b.join(o)
            env.vars, env.acq = joined.vars, joined.acq
            env.obligations, env.reserves = \
                joined.obligations, joined.reserves
            env.terminated = joined.terminated
        elif isinstance(stmt, (ast.For, ast.While)):
            if isinstance(stmt, ast.For):
                self._calls_in_expr(stmt.iter, env)
                self._check_decref_loop(stmt, env)
                if isinstance(stmt.target, ast.Name):
                    env.vars.pop(stmt.target.id, None)
            else:
                self._calls_in_expr(stmt.test, env)
            pre = env.copy()
            for _ in range(2):      # converge loop-carried state
                self._exec_block(stmt.body, env)
                env.terminated = False
            self._exec_block(stmt.orelse, env)
            joined = env.join(pre)
            env.vars, env.acq = joined.vars, joined.acq
            env.obligations, env.reserves = \
                joined.obligations, joined.reserves
            env.terminated = False
        elif isinstance(stmt, ast.Try):
            pre = env.copy()
            self.in_try += 1
            self._exec_block(stmt.body, env)
            self.in_try -= 1
            merged = env.join(pre)
            for h in stmt.handlers:
                he = merged.copy()
                he.terminated = False
                self._exec_block(h.body, he)
                merged = merged.join(he)
            env.vars, env.acq = merged.vars, merged.acq
            env.obligations, env.reserves = \
                merged.obligations, merged.reserves
            env.terminated = merged.terminated
            self._exec_block(stmt.finalbody, env)
        elif isinstance(stmt, ast.With):
            for item in stmt.items:
                self._calls_in_expr(item.context_expr, env)
            self._exec_block(stmt.body, env)
        elif isinstance(stmt, (ast.Assert, ast.Delete)):
            for child in ast.iter_child_nodes(stmt):
                if isinstance(child, ast.expr):
                    self._calls_in_expr(child, env)
        else:
            for child in ast.iter_child_nodes(stmt):
                if isinstance(child, ast.expr):
                    self._calls_in_expr(child, env)
                elif isinstance(child, ast.stmt):
                    self._exec_stmt(child, env)

    def run(self) -> "_OwnershipAuditor":
        env = Env()
        for name in self.param_index:
            env.vars[name] = {_PARAM}
        self._exec_block(self.fn.body, env)
        if not env.terminated:
            end = getattr(self.fn, "end_lineno", self.fn.lineno)
            self._check_exit(env, end)
        return self


# --------------------------------------------------------------- driver

def _scan_files(root: Path) -> List[Path]:
    files: List[Path] = []
    for entry in MODULES:
        p = root / entry
        if p.is_dir():
            files += sorted(p.rglob("*.py"))
        elif p.is_file():
            files.append(p)
    if not files:
        files = sorted(root.rglob("*.py"))
    return files


def derive_summaries(
        modules: List[Tuple[str, ast.Module]]) -> Dict[str, Summary]:
    """Fixpoint of per-function summaries over the call graph, seeded
    with the pool protocol. A round re-interprets only functions whose
    callee summaries changed in the previous round."""
    graph = build_py_call_graph(modules)
    summaries = dict(PROTOCOL)
    dirty: Optional[Set[str]] = None        # changed names; None = all
    for _ in range(6):
        changed: Set[str] = set()
        for pf in graph.all_funcs():
            if dirty is not None and not (
                    graph.calls[f"{pf.relpath}:{pf.qualname}"] & dirty):
                continue
            aud = _OwnershipAuditor(pf.node, pf.qualname, pf.relpath,
                                    summaries, record=False).run()
            derived = Summary(
                returns_owned=aud.facts.returns_owned,
                consumes=frozenset(aud.facts.consumed_params),
                may_raise=aud.facts.may_raise)
            cur = summaries.get(pf.name, Summary())
            new = cur.merged(derived)
            if new != cur:
                summaries[pf.name] = new
                changed.add(pf.name)
        if not changed:
            break
        dirty = changed
    return summaries


def audit_source(text: str, relpath: str,
                 summaries: Dict[str, Summary]) -> List[Finding]:
    tree = ast.parse(text)
    findings: List[Finding] = []
    for pf in walk_functions(tree, relpath):
        findings += _OwnershipAuditor(pf.node, pf.qualname, relpath,
                                      summaries, record=True).run() \
            .findings()
    findings = apply_suppressions(findings, text, CATEGORY)
    return assign_occurrences(findings)


def run(root: Path) -> PassResult:
    result = PassResult(PASS_ID)
    files = _scan_files(root)
    modules: List[Tuple[str, ast.Module, str]] = []
    for path in files:
        text = path.read_text()
        modules.append((rel(path, root), ast.parse(text), text))
    summaries = derive_summaries([(r, t) for r, t, _ in modules])
    for relpath, _, text in modules:
        result.findings += audit_source(text, relpath, summaries)
    result.report["scanned"] = [r for r, _, _ in modules]
    result.report["suppress_category"] = CATEGORY
    result.report["functions"] = sum(
        1 for _, tree, _ in modules for _f in walk_functions(tree, ""))
    return result
