from repro.models.model_zoo import Model, build_model, cross_entropy_loss  # noqa: F401
