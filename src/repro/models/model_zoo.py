"""Model factory + step functions for every assigned architecture.

`build_model(cfg, tp)` returns a `Model` bundle exposing:
    init / specs                          parameters
    loss_fn(params, batch)                training loss (CE + MoE aux)
    train_inputs / prefill_inputs / ...   ShapeDtypeStruct builders live in
                                          launch.dryrun (they need shapes)
    forward / decode_step / init_cache    delegated to the family modules
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.encdec import AudioEncoder
from repro.models.transformer import TransformerLM


def cross_entropy_loss(logits: jnp.ndarray, labels: jnp.ndarray,
                       mask: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Token-mean CE in fp32. logits (b,s,V); labels (b,s) int32."""
    logits = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - gold
    if mask is None:
        return jnp.mean(nll)
    mask = mask.astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)


@dataclass(eq=False)     # id-hash: usable as a jit static argument
class Model:
    cfg: ModelConfig
    lm: TransformerLM
    encoder: Optional[AudioEncoder] = None

    # ------------------------------------------------------------- params
    def init(self, key):
        if self.encoder is not None:
            k1, k2 = jax.random.split(key)
            return {"lm": self.lm.init(k1), "encoder": self.encoder.init(k2)}
        return {"lm": self.lm.init(key)}

    def specs(self):
        s = {"lm": self.lm.specs()}
        if self.encoder is not None:
            s["encoder"] = self.encoder.specs()
        return s

    def param_shapes(self, key=None):
        key = key if key is not None else jax.random.PRNGKey(0)
        return jax.eval_shape(self.init, key)

    # ------------------------------------------------------------ forward
    def forward(self, params, tokens, *, frames=None, patches=None,
                train: bool = False):
        """Returns (logits, hidden, aux). `frames` (audio) / `patches` (vlm)
        are the stubbed-modality embeddings."""
        enc_out = None
        if self.encoder is not None:
            assert frames is not None, "audio model needs frame embeddings"
            enc_out = self.encoder.forward(params["encoder"], frames)
        return self.lm.forward(params["lm"], tokens,
                               prefix_embeds=patches,
                               encoder_out=enc_out, train=train)

    def loss_fn(self, params, batch: Dict[str, jnp.ndarray]) -> jnp.ndarray:
        """batch: tokens (b,s), labels (b,s), optional frames/patches/mask."""
        logits, _, aux = self.forward(
            params, batch["tokens"], frames=batch.get("frames"),
            patches=batch.get("patches"), train=True)
        labels = batch["labels"]
        if self.cfg.family == "vlm" and batch.get("patches") is not None:
            # loss over the text suffix only
            P = batch["patches"].shape[1]
            logits = logits[:, P:]
        loss = cross_entropy_loss(logits, labels, batch.get("mask"))
        if self.cfg.moe is not None:
            loss = loss + self.cfg.moe.router_aux_loss * aux / self.cfg.n_layers
        return loss

    # ------------------------------------------------------------- decode
    def init_cache(self, batch: int, seq_len: int, kv_quant=None):
        enc_len = self.cfg.encoder.seq_len if self.cfg.is_encdec else 0
        return self.lm.init_cache(batch, seq_len, encoder_len=enc_len,
                                  kv_quant=kv_quant)

    def cache_specs(self, kv_quant=None):
        return self.lm.cache_specs(kv_quant=kv_quant)

    def decode_step(self, params, token, cache, pos, block_tables=None):
        return self.lm.decode_step(params["lm"], token, cache, pos,
                                   block_tables=block_tables)

    def decode_chunk(self, params, tokens, cache, pos, valid, block_tables):
        """Varlen chunked prefill (paged, attention/MLA stacks only)."""
        return self.lm.decode_chunk(params["lm"], tokens, cache, pos,
                                    valid, block_tables=block_tables)

    def decode_horizon(self, params, token, cache, pos, aux, H, transition,
                       block_tables=None, xs=None):
        """H decode steps fused into one lax.scan; see
        TransformerLM.decode_horizon. `transition` owns sampling/masking
        and per-row roles (serving-policy concerns), the model owns
        threading its cache and positions through the scan; `xs` is the
        optional per-step scan input (e.g. the mixed program's prefetched
        fed-token buffer)."""
        return self.lm.decode_horizon(params["lm"], token, cache, pos, aux,
                                      H, transition,
                                      block_tables=block_tables, xs=xs)

    @property
    def supports_chunked_prefill(self) -> bool:
        """Chunked prefill batches C tick-steps into one program, which is
        only a pure batching transform for stateless (attention/MLA)
        mixers — recurrent state must advance token-by-token."""
        return (not self.cfg.is_encdec
                and all(d.mixer in ("attn", "mla") and not d.cross
                        for d in self.lm.pattern))


def build_model(cfg: ModelConfig, tp: int = 1, remat: bool = False,
                block_q: int = 512) -> Model:
    lm = TransformerLM(cfg, tp=tp, block_q=block_q, remat=remat)
    encoder = None
    if cfg.is_encdec and cfg.encoder is not None and cfg.encoder.n_layers:
        encoder = AudioEncoder(cfg, tp=tp)
    return Model(cfg=cfg, lm=lm, encoder=encoder)
