"""Mamba selective-state-space block (Jamba's sequence mixer).

TPU adaptation: the selective scan is computed **chunkwise** — a sequential
`lax.scan` over chunks with a parallel `associative_scan` inside each chunk —
so the live (b, chunk, d_inner, d_state) tensor stays VMEM-sized instead of
materializing the full (b, seq, d_inner, d_state) scan. The inner dimension
(d_inner = expand * d_model) shards over the `model` mesh axis; the scan is
per-channel so the recurrence needs **zero collectives** (this is why hybrid
SSMs are ICI-friendly at long context — visible in the roofline tables).

kernels/ssm_scan.py is the Pallas TPU target for the inner chunk scan; this
module is the XLA path and the oracle's substrate.
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import modules as nn


def _dims(cfg: ModelConfig) -> Tuple[int, int, int, int]:
    s = cfg.ssm
    d_in = s.expand * cfg.d_model
    dt_rank = s.dt_rank or max(1, math.ceil(cfg.d_model / 16))
    return d_in, s.d_state, s.d_conv, dt_rank


def init_mamba(key, cfg: ModelConfig, dtype):
    d = cfg.d_model
    d_in, d_state, d_conv, dt_rank = _dims(cfg)
    ks = jax.random.split(key, 7)
    p = {
        "wx": nn.init_linear(ks[0], d, d_in, dtype=dtype),
        "wz": nn.init_linear(ks[1], d, d_in, dtype=dtype),
        "conv_w": (jax.random.normal(ks[2], (d_conv, d_in), jnp.float32)
                   / math.sqrt(d_conv)).astype(dtype),
        "conv_b": jnp.zeros((d_in,), dtype),
        "x_proj": nn.init_linear(ks[3], d_in, dt_rank + 2 * d_state, dtype=dtype),
        "dt_proj": nn.init_linear(ks[4], dt_rank, d_in, bias=True, dtype=dtype),
        # S4D-real initialization for A
        "A_log": jnp.broadcast_to(
            jnp.log(jnp.arange(1, d_state + 1, dtype=jnp.float32)),
            (d_in, d_state)).astype(jnp.float32) * jnp.ones((d_in, 1), jnp.float32),
        "D": jnp.ones((d_in,), jnp.float32),
        "out_proj": nn.init_linear(ks[5], d_in, d, dtype=dtype),
    }
    # dt bias init so softplus(dt) starts in [1e-3, 1e-1]
    dt_init = jnp.exp(jax.random.uniform(ks[6], (d_in,), jnp.float32)
                      * (math.log(0.1) - math.log(1e-3)) + math.log(1e-3))
    p["dt_proj"]["b"] = (dt_init + jnp.log(-jnp.expm1(-dt_init))).astype(dtype)
    return p


def mamba_specs(cfg: ModelConfig):
    return {
        "wx": {"w": ("embed", "mamba_inner")},
        "wz": {"w": ("embed", "mamba_inner")},
        "conv_w": (None, "mamba_inner"),
        "conv_b": ("mamba_inner",),
        "x_proj": {"w": ("mamba_inner", None)},
        "dt_proj": {"w": (None, "mamba_inner"), "b": ("mamba_inner",)},
        "A_log": ("mamba_inner", None),
        "D": ("mamba_inner",),
        "out_proj": {"w": ("mamba_inner", "embed")},
    }


def _causal_conv(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray,
                 state: jnp.ndarray = None) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Depthwise causal conv. x (b,s,d_in); w (d_conv,d_in).

    state (b, d_conv-1, d_in) holds the trailing inputs from the previous
    segment (zeros at sequence start). Returns (y, new_state).
    """
    d_conv = w.shape[0]
    bsz, s, d_in = x.shape
    if state is None:
        state = jnp.zeros((bsz, d_conv - 1, d_in), x.dtype)
    xp = jnp.concatenate([state.astype(x.dtype), x], axis=1)
    y = sum(xp[:, j:j + s] * w[j][None, None, :].astype(x.dtype)
            for j in range(d_conv))
    y = y + b[None, None, :].astype(x.dtype)
    new_state = xp[:, -(d_conv - 1):] if d_conv > 1 else state
    return y, new_state


def _ssm_params(p, xc: jnp.ndarray, cfg: ModelConfig):
    """xc (..., d_in) -> dt (..., d_in), B, C (..., d_state) in fp32."""
    _, d_state, _, dt_rank = _dims(cfg)
    proj = nn.linear(p["x_proj"], xc)
    dt, B, C = jnp.split(proj, [dt_rank, dt_rank + d_state], axis=-1)
    dt = nn.linear(p["dt_proj"], dt).astype(jnp.float32)
    dt = jax.nn.softplus(dt)
    return dt, B.astype(jnp.float32), C.astype(jnp.float32)


def _scan_chunk(A: jnp.ndarray, dt, B, C, xc, h0):
    """One chunk of the selective scan via associative_scan (fp32).

    dt (b,L,d); B,C (b,L,n); xc (b,L,d); h0 (b,d,n) -> (y (b,L,d), hL).
    """
    dA = jnp.exp(dt[..., None] * A[None, None])              # (b,L,d,n)
    dBx = (dt * xc.astype(jnp.float32))[..., None] * B[:, :, None, :]

    def combine(a, b):
        (a1, b1), (a2, b2) = a, b
        return a1 * a2, a2 * b1 + b2

    accA, accB = jax.lax.associative_scan(combine, (dA, dBx), axis=1)
    h = accA * h0[:, None] + accB                            # (b,L,d,n)
    y = jnp.einsum("bldn,bln->bld", h, C)
    return y, h[:, -1]


def mamba_mix(p, x: jnp.ndarray, cfg: ModelConfig, *, chunk: int = 256
              ) -> jnp.ndarray:
    """Full-sequence mamba mixer (train / prefill). x (b,s,d_model)."""
    d_in, d_state, d_conv, _ = _dims(cfg)
    b, s, _ = x.shape
    xi = nn.linear(p["wx"], x)                               # (b,s,d_in)
    z = nn.linear(p["wz"], x)
    xc, _ = _causal_conv(xi, p["conv_w"], p["conv_b"])
    xc = jax.nn.silu(xc)
    dt, B, C = _ssm_params(p, xc, cfg)
    A = -jnp.exp(p["A_log"])                                 # (d_in,n) fp32
    L = min(chunk, s)
    n_chunks = (s + L - 1) // L
    pad = n_chunks * L - s
    if pad:
        z5 = lambda t: jnp.pad(t, ((0, 0), (0, pad)) + ((0, 0),) * (t.ndim - 2))
        xc, dt, B, C = z5(xc), z5(dt), z5(B), z5(C)

    def step(h, args):
        xcc, dtc, Bc, Cc = args
        y, h = _scan_chunk(A, dtc, Bc, Cc, xcc, h)
        return h, y

    resh = lambda t: t.reshape(b, n_chunks, L, t.shape[-1]).swapaxes(0, 1)
    h0 = jnp.zeros((b, d_in, d_state), jnp.float32)
    _, ys = jax.lax.scan(step, h0, (resh(xc), resh(dt), resh(B), resh(C)))
    y = ys.swapaxes(0, 1).reshape(b, n_chunks * L, d_in)[:, :s]
    y = y + xc.astype(jnp.float32)[:, :s] * p["D"][None, None]
    y = y.astype(x.dtype) * jax.nn.silu(z)
    return nn.linear(p["out_proj"], y)


def init_mamba_cache(batch: int, cfg: ModelConfig, dtype) -> dict:
    d_in, d_state, d_conv, _ = _dims(cfg)
    return {"conv": jnp.zeros((batch, d_conv - 1, d_in), dtype),
            "ssm": jnp.zeros((batch, d_in, d_state), jnp.float32)}


def mamba_cache_specs() -> dict:
    return {"conv": ("batch", None, "mamba_inner"),
            "ssm": ("batch", "mamba_inner", None)}


def mamba_decode(p, x: jnp.ndarray, cache: dict, cfg: ModelConfig
                 ) -> Tuple[jnp.ndarray, dict]:
    """Single-token recurrent step. x (b,1,d_model)."""
    xi = nn.linear(p["wx"], x)
    z = nn.linear(p["wz"], x)
    xc, conv_state = _causal_conv(xi, p["conv_w"], p["conv_b"],
                                  state=cache["conv"])
    xc = jax.nn.silu(xc)
    dt, B, C = _ssm_params(p, xc, cfg)
    A = -jnp.exp(p["A_log"])
    dA = jnp.exp(dt[:, 0, :, None] * A[None])                # (b,d,n)
    dBx = (dt[:, 0] * xc[:, 0].astype(jnp.float32))[..., None] * B[:, 0, None, :]
    h = cache["ssm"] * dA + dBx
    y = jnp.einsum("bdn,bn->bd", h, C[:, 0])[:, None]
    y = y + xc.astype(jnp.float32) * p["D"][None, None]
    y = y.astype(x.dtype) * jax.nn.silu(z)
    return nn.linear(p["out_proj"], y), {"conv": conv_state, "ssm": h}
