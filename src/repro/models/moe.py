"""Mixture-of-Experts FFN.

Two execution paths with identical routing math:

* **local** (no mesh / smoke tests / tiny training): all experts are computed
  densely and combined with the (zeroed) top-k gate weights — exact, no
  capacity drops.

* **sharded** (production meshes): a `shard_map` over the `model` axis.
  Activations arrive sequence-sharded (Megatron-SP residual); each device
  all-gathers its model-row's tokens, routes, runs *only its share* of
  experts on a per-expert top-capacity gather (honest top-k FLOPs), and the
  partial outputs are combined + re-seq-sharded with a single
  `psum_scatter`. Expert placement is rule-driven (repro.sharding):
  experts shard over `model` when n_experts % tp == 0 (DeepSeek 160,
  Jamba 16); otherwise each expert is tensor-sharded over its ff dim
  (Grok 8 x 32768) and the same psum combines the ff partials.

Capacity follows GShard: C = ceil(T * top_k / E * capacity_factor); overflow
tokens are dropped by the gather (kept by the local path).
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models import modules as nn
from repro.sharding import current_rules, logical_spec

if hasattr(jax, "shard_map"):                    # jax >= 0.6
    _shard_map, _SM_KW = jax.shard_map, {"check_vma": False}
else:                                            # 0.4.x experimental API
    from jax.experimental.shard_map import shard_map as _shard_map
    _SM_KW = {"check_rep": False}


def init_moe(key, cfg: ModelConfig, dtype):
    m = cfg.moe
    d = cfg.d_model
    ff = m.expert_d_ff or cfg.d_ff
    ks = jax.random.split(key, 5)
    scale = 1.0 / max(1.0, math.sqrt(d))

    def ew(k, shape):
        return (jax.random.truncated_normal(k, -2, 2, shape, jnp.float32)
                * scale).astype(dtype)

    def maybe_quant(w):
        """W8A16 expert weights (per-expert, per-out-channel scales)."""
        if not cfg.quant_int8:
            return w
        amax = jnp.max(jnp.abs(w.astype(jnp.float32)), axis=1,
                       keepdims=True) + 1e-8                  # (E,1,out)
        q = jnp.clip(jnp.round(w.astype(jnp.float32) / amax * 127),
                     -127, 127).astype(jnp.int8)
        return {"q8": q, "scale": (amax[:, 0] / 127).astype(dtype)}

    p = {
        "router": {"w": ew(ks[0], (d, m.n_experts))},
        "up": maybe_quant(ew(ks[1], (m.n_experts, d, ff))),
        "down": maybe_quant(ew(ks[2], (m.n_experts, ff, d))),
    }
    if cfg.gated_mlp:
        p["gate"] = maybe_quant(ew(ks[3], (m.n_experts, d, ff)))
    if m.n_shared_experts:
        p["shared"] = nn.init_mlp(ks[4], d, ff * m.n_shared_experts,
                                  gated=cfg.gated_mlp, dtype=dtype,
                                  quant=cfg.quant_int8)
    return p


def moe_specs(cfg: ModelConfig):
    def wspec(in_name, out_name):
        names = ("experts", in_name, out_name)
        if cfg.quant_int8:
            return {"q8": names, "scale": ("experts", out_name)}
        return names

    s = {
        "router": {"w": ("embed", None)},
        "up": wspec("embed", "expert_ff"),
        "down": wspec("expert_ff", "embed"),
    }
    if cfg.gated_mlp:
        s["gate"] = wspec("embed", "expert_ff")
    if cfg.moe.n_shared_experts:
        s["shared"] = nn.mlp_specs(gated=cfg.gated_mlp,
                                   quant=cfg.quant_int8)
    return s


def _route(p, x2d: jnp.ndarray, cfg: ModelConfig):
    """x2d (T,d) -> (weights (T,k), idx (T,k), aux_loss scalar)."""
    m = cfg.moe
    logits = (x2d.astype(jnp.float32) @ p["router"]["w"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)                  # (T,E)
    top_w, top_i = jax.lax.top_k(probs, m.top_k)
    top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)
    # Switch-style load-balance aux loss
    density = jnp.mean(jax.nn.one_hot(top_i[:, 0], m.n_experts), axis=0)
    density_prob = jnp.mean(probs, axis=0)
    aux = jnp.sum(density * density_prob) * float(m.n_experts)
    return top_w.astype(x2d.dtype), top_i, aux.astype(jnp.float32)


def _w(pw, dtype):
    """Expert weight, dequantizing W8A16 storage on read."""
    if isinstance(pw, dict) and "q8" in pw:
        return pw["q8"].astype(dtype) * pw["scale"][:, None, :].astype(dtype)
    return pw.astype(dtype)


def _expert_ffn(p, xs: jnp.ndarray, act: str) -> jnp.ndarray:
    """xs (E, C, d) through per-expert (gated) FFN -> (E, C, d)."""
    fn = nn.activation(act)
    h = jnp.einsum("ecd,edf->ecf", xs, _w(p["up"], xs.dtype))
    if "gate" in p:
        h = h * fn(jnp.einsum("ecd,edf->ecf", xs, _w(p["gate"], xs.dtype)))
    else:
        h = fn(h)
    return jnp.einsum("ecf,efd->ecd", h, _w(p["down"], xs.dtype))


def _moe_local(p, x: jnp.ndarray, cfg: ModelConfig) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Exact dense-combine path (all experts on all tokens)."""
    m = cfg.moe
    b, s, d = x.shape
    x2 = x.reshape(-1, d)
    w, idx, aux = _route(p, x2, cfg)
    combine = jnp.zeros((x2.shape[0], m.n_experts), x.dtype)
    combine = jax.vmap(lambda c, i, v: c.at[i].add(v))(combine, idx, w)
    outs = _expert_ffn(p, jnp.broadcast_to(x2, (m.n_experts,) + x2.shape),
                       cfg.act)                               # (E,T,d)
    y = jnp.einsum("te,etd->td", combine, outs)
    return y.reshape(b, s, d), aux


def moe_apply(p, x: jnp.ndarray, cfg: ModelConfig) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (y, aux_loss). Chooses local vs shard_map path from context."""
    m = cfg.moe
    y_shared = None
    if m.n_shared_experts:
        y_shared = nn.mlp(p["shared"], x, act=cfg.act)
    cur = current_rules()
    if cur is None or cur[0] is None:
        y, aux = _moe_local(p, x, cfg)
    else:
        mesh, rules = cur
        tp = mesh.shape["model"]
        b, s, d = x.shape
        expert_parallel = rules.get("experts") == "model"
        e_loc = m.n_experts // tp if expert_parallel else m.n_experts
        seq_shard = (s % tp == 0) and s >= tp
        batch_axes = rules.get("batch") or ()
        if isinstance(batch_axes, str):
            batch_axes = (batch_axes,)
        nb = 1
        for a in batch_axes:
            nb *= mesh.shape[a]
        batch_shard = (b % nb == 0) and b >= nb
        x_spec = logical_spec(("batch" if batch_shard else None,
                               "seq_sp" if seq_shard else None, None),
                              rules)
        all_specs = {k: v for k, v in moe_specs(cfg).items() if k != "shared"}
        w_specs = jax.tree.map(lambda names: logical_spec(names, rules),
                               all_specs,
                               is_leaf=lambda t: isinstance(t, tuple))
        p_in = {k: p[k] for k in w_specs}
        # tokens visible per device AFTER the row all-gather: local batch
        # shard x full sequence
        T_loc = (b // nb if batch_shard else b) * s
        capacity = min(T_loc, max(1, int(math.ceil(
            T_loc * m.top_k / m.n_experts * m.capacity_factor))))

        def body(xl, pl):
            xg = (jax.lax.all_gather(xl, "model", axis=1, tiled=True)
                  if seq_shard else xl)
            x2 = xg.reshape(-1, d)
            off = (jax.lax.axis_index("model") * e_loc
                   if expert_parallel else 0)
            w, idx, aux = _route(pl, x2, cfg)

            def per_expert(e_off):
                e = off + e_off
                we = jnp.sum(jnp.where(idx == e, w, 0.0), axis=-1)   # (T,)
                vals, ti = jax.lax.top_k(we, capacity)
                return jnp.take(x2, ti, axis=0), vals, ti

            xs, vals, gidx = jax.vmap(per_expert)(jnp.arange(e_loc))
            out = _expert_ffn(pl, xs, cfg.act)                # (E_loc,C,d)
            out = out * vals[..., None].astype(out.dtype)
            y = jnp.zeros_like(x2)
            y = y.at[gidx.reshape(-1)].add(out.reshape(-1, d))
            y = y.reshape(xg.shape)
            if seq_shard:
                y = jax.lax.psum_scatter(y, "model", scatter_dimension=1,
                                         tiled=True)
            else:
                y = jax.lax.psum(y, "model")
            return y, jax.lax.pmean(aux, "model")

        fn = _shard_map(body, mesh=mesh, in_specs=(x_spec, w_specs),
                        out_specs=(x_spec, P()), **_SM_KW)
        y, aux = fn(x, p_in)
    if y_shared is not None:
        y = y + y_shared
    return y, aux
