"""Whisper-style audio encoder (transformer over stubbed frame embeddings).

Per the assignment carve-out, the mel-spectrogram + conv feature extractor is
a STUB: `input_specs()` feeds precomputed frame embeddings (b, 1500, d). The
12-layer bidirectional encoder transformer itself is real and trained.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models import modules as nn
from repro.sharding import lshard


class AudioEncoder:
    def __init__(self, cfg: ModelConfig, tp: int = 1):
        self.cfg = cfg
        self.enc = cfg.encoder
        self.tp = tp
        # encoder uses the same head geometry as the decoder in whisper-small
        self.dims = attn.attn_dims(cfg, tp)
        self.dtype = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32

    def init(self, key) -> Dict[str, Any]:
        cfg = self.cfg
        keys = jax.random.split(key, self.enc.n_layers + 1)

        def one(k):
            ks = jax.random.split(k, 2)
            return {
                "norm1": nn.init_norm(cfg.d_model, kind=cfg.norm,
                                      dtype=self.dtype, bias=cfg.mlp_bias),
                "mix": attn.init_attention(ks[0], cfg, self.tp, self.dtype),
                "norm2": nn.init_norm(cfg.d_model, kind=cfg.norm,
                                      dtype=self.dtype, bias=cfg.mlp_bias),
                "ffn": nn.init_mlp(ks[1], cfg.d_model, self.enc.d_ff,
                                   gated=cfg.gated_mlp, bias=cfg.mlp_bias,
                                   dtype=self.dtype),
            }

        stacked = jax.vmap(one)(keys[: self.enc.n_layers])
        return {"layers": stacked,
                "final_norm": nn.init_norm(cfg.d_model, kind=cfg.norm,
                                           dtype=self.dtype)}

    def specs(self) -> Dict[str, Any]:
        cfg = self.cfg
        layer = {
            "norm1": nn.norm_specs(cfg.norm, cfg.mlp_bias),
            "mix": attn.attention_specs(cfg),
            "norm2": nn.norm_specs(cfg.norm, cfg.mlp_bias),
            "ffn": nn.mlp_specs(gated=cfg.gated_mlp, bias=cfg.mlp_bias),
        }
        layer = jax.tree.map(lambda t: (None,) + tuple(t), layer,
                             is_leaf=lambda t: isinstance(t, tuple))
        return {"layers": layer, "final_norm": nn.norm_specs(cfg.norm)}

    def forward(self, params, frames: jnp.ndarray) -> jnp.ndarray:
        """frames (b, n_frames, d) precomputed embeddings -> encoder states."""
        cfg = self.cfg
        x = frames.astype(self.dtype)
        x = x + nn.sinusoidal_positions(x.shape[1], cfg.d_model,
                                        self.dtype)[None]
        x = lshard(x, "batch", None, None)

        def block(x, p):
            h = nn.apply_norm(p["norm1"], x, kind=cfg.norm, eps=cfg.norm_eps)
            h = attn.attention_forward(p["mix"], h, self.dims, cos=None,
                                       sin=None, causal=False, block_q=512)
            x = x + h
            h = nn.apply_norm(p["norm2"], x, kind=cfg.norm, eps=cfg.norm_eps)
            x = x + nn.mlp(p["ffn"], h, act=cfg.act)
            return x, None

        x, _ = jax.lax.scan(block, x, params["layers"])
        return nn.apply_norm(params["final_norm"], x, kind=cfg.norm,
                             eps=cfg.norm_eps)
