"""xLSTM blocks (arXiv:2405.04517): mLSTM (matrix-memory, parallelizable)
and sLSTM (scalar-memory, sequential) in the paper's 7:1 interleave.

TPU adaptation:

* **mLSTM** is computed in the exact *chunkwise-parallel* form (GLA-style):
  a sequential `lax.scan` over chunks carrying the stabilized state
  (C (dqk,dv), n (dqk), m scalar) per head, with fully parallel intra-chunk
  (L x L) score tiles — the linear-attention analogue of flash attention's
  tiling, matched to MXU-sized blocks.
* **mLSTM shards the value/state dim**, not heads (4 monolithic dh=1024
  heads are TP-hostile): C-state and value matmuls are 16-way local, q·k
  scores replicate (4x cheaper than the state terms — §Perf math in
  EXPERIMENTS.md). sLSTM keeps padded-head sharding for its block-diagonal
  recurrence.
* **sLSTM** keeps its per-head block-diagonal recurrence as a `lax.scan`
  over time (inherently sequential; this is the paper's own trade-off).
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import modules as nn


def mlstm_dims(cfg: ModelConfig, tp: int) -> Tuple[int, int, int]:
    """(heads, d_inner, head_dim).

    §Perf (beyond-paper): mLSTM heads are NOT padded/sharded — with 4 heads
    of dh=1024 on a 16-way model axis, head padding wastes 4x of every
    tensor. Instead the VALUE/state dim shards over `model` ("mlstm_v"):
    C-state (b,H,dhq,dhv/16) and all value-side matmuls are 16-way local;
    only the (4x cheaper) q·k score terms replicate.
    """
    x = cfg.xlstm
    d_in = int(x.mlstm_proj_factor * cfg.d_model)
    return cfg.n_heads, d_in, d_in // cfg.n_heads


# ----------------------------------------------------------------------------
# mLSTM block
# ----------------------------------------------------------------------------

def init_mlstm(key, cfg: ModelConfig, tp: int, dtype):
    d = cfg.d_model
    H, d_in, hd = mlstm_dims(cfg, tp)
    ks = jax.random.split(key, 8)
    p = {
        "wx": nn.init_linear(ks[0], d, (H, hd), dtype=dtype),
        "wz": nn.init_linear(ks[1], d, (H, hd), dtype=dtype),
        "conv_w": (jax.random.normal(ks[2], (cfg.xlstm.d_conv, H, hd),
                                     jnp.float32) / 2.0).astype(dtype),
        "conv_b": jnp.zeros((H, hd), dtype),
        "wq": nn.init_linear(ks[3], hd, (hd,), dtype=dtype),
        "wk": nn.init_linear(ks[4], hd, (hd,), dtype=dtype),
        "wv": nn.init_linear(ks[5], hd, (hd,), dtype=dtype),
        # scalar gates per head from the block input
        "w_if": nn.init_linear(ks[6], d, (H, 2), bias=True, dtype=dtype),
        "out_norm": {"scale": jnp.ones((H, hd), dtype)},
        # 3-D so the value-dim sharding survives the output contraction
        "wo": {"w": nn.truncnorm_init(ks[7], (H, hd, d), 1.0, dtype)},
    }
    # forget-gate bias init: strongly positive => long memory at init
    b = p["w_if"]["b"]
    p["w_if"]["b"] = b.at[:, 1].set(3.0)
    return p


def mlstm_specs():
    return {
        "wx": {"w": ("embed", None, "mlstm_v")},
        "wz": {"w": ("embed", None, "mlstm_v")},
        "conv_w": (None, None, "mlstm_v"),
        "conv_b": (None, "mlstm_v"),
        "wq": {"w": (None, None)},
        "wk": {"w": (None, None)},
        "wv": {"w": (None, "mlstm_v")},
        "w_if": {"w": ("embed", None, None), "b": (None, None)},
        "out_norm": {"scale": (None, "mlstm_v")},
        "wo": {"w": (None, "mlstm_v", "embed")},
    }


def _mlstm_chunk(q, k, v, li, lf, state, *, matmul_dtype=jnp.bfloat16):
    """Exact-stabilized chunkwise mLSTM with mixed precision.

    q,k,v (b,H,L,hd); li,lf (b,H,L) log input/forget gates (fp32).
    state = (C (b,H,hd,hd), n (b,H,hd), m (b,H)) — fp32 carries.

    §Perf: matmul operands run in bf16 (fp32 accumulation via
    preferred_element_type) — gate math, stabilizers, and the carried state
    stay fp32. Halves the intra-chunk HBM footprint and doubles effective
    MXU rate; max-abs output delta vs full-fp32 measured < 2e-2 (test).
    """
    C0, n0, m0 = state
    b, H, L, hd = q.shape
    mm = lambda e, x, y: jnp.einsum(e, x.astype(matmul_dtype),
                                    y.astype(matmul_dtype),
                                    preferred_element_type=jnp.float32)
    F = jnp.cumsum(lf, axis=-1)                              # inclusive
    a = li - F                                               # (b,H,L)
    m_intra = jax.lax.cummax(a, axis=2) + F
    m = jnp.maximum(F + m0[..., None], m_intra)              # (b,H,L)
    # intra-chunk decay matrix D[t,s] = exp(F_t - F_s + li_s - m_t), s<=t
    logD = (F[..., :, None] - F[..., None, :] + li[..., None, :]
            - m[..., :, None])
    tri = jnp.tril(jnp.ones((L, L), bool))
    D = jnp.where(tri, jnp.exp(logD), 0.0)
    scores = mm("bhtd,bhsd->bhts", q, k) / math.sqrt(hd)
    w = scores * D
    num = mm("bhts,bhsd->bhtd", w, v)
    n_intra = mm("bhts,bhsd->bhtd", D, k) / math.sqrt(hd)
    inter_scale = jnp.exp(F + m0[..., None] - m)             # (b,H,L)
    num = num + mm("bhtd,bhde->bhte", q, C0) * inter_scale[..., None]
    n = n_intra + n0[:, :, None] * inter_scale[..., None]
    qn = jnp.abs(jnp.einsum("bhtd,bhtd->bht", q, n))
    denom = jnp.maximum(qn, jnp.exp(-m))
    h = num / denom[..., None]
    # carry to next chunk
    mL = m[..., -1]
    gL = jnp.exp(F[..., -1:] - F + li - mL[..., None])       # (b,H,L)
    CL = (C0 * jnp.exp(F[..., -1] + m0 - mL)[..., None, None]
          + mm("bhld,bhle->bhde", (k / math.sqrt(hd)) * gL[..., None], v))
    nL = (n0 * jnp.exp(F[..., -1] + m0 - mL)[..., None]
          + jnp.sum((k / math.sqrt(hd)) * gL[..., None], axis=2))
    return h, (CL, nL, mL)


def mlstm_mix(p, x: jnp.ndarray, cfg: ModelConfig, tp: int, *,
              chunk: int = 256, matmul_dtype=jnp.bfloat16) -> jnp.ndarray:
    """Full-sequence mLSTM block core. x (b,s,d)."""
    from repro.sharding import lshard
    Hp, d_in, hd = mlstm_dims(cfg, tp)
    b, s, _ = x.shape
    xi = nn.linear(p["wx"], x)                               # (b,s,H,hd)
    xi = lshard(xi, "batch", None, None, "mlstm_v")
    z = nn.linear(p["wz"], x)
    z = lshard(z, "batch", None, None, "mlstm_v")
    # causal depthwise conv over time per (head, dim)
    d_conv = p["conv_w"].shape[0]
    xp = jnp.pad(xi, ((0, 0), (d_conv - 1, 0), (0, 0), (0, 0)))
    xc = sum(xp[:, j:j + s] * p["conv_w"][j][None, None].astype(x.dtype)
             for j in range(d_conv)) + p["conv_b"][None, None].astype(x.dtype)
    xc = jax.nn.silu(xc)
    # q,k need the full head dim (scores replicate over model — measured
    # cheaper than padding heads 4->16; see EXPERIMENTS.md §Perf).
    # kept in model dtype through the chunk scan (fp32 q/k doubled the
    # saved-activation footprint — §Perf iteration)
    q = nn.linear(p["wq"], lshard(xc, "batch", None, None, None))
    k = nn.linear(p["wk"], lshard(xc, "batch", None, None, None))
    v = nn.linear(p["wv"], xi)
    v = lshard(v, "batch", None, None, "mlstm_v")
    gates = nn.linear(p["w_if"], x).astype(jnp.float32)      # (b,s,H,2)
    li = gates[..., 0]
    lf = jax.nn.log_sigmoid(gates[..., 1])
    # to (b,H,s,hd)
    tr = lambda t: t.swapaxes(1, 2)
    q, k, v = tr(q), tr(k), tr(v)
    li, lf = li.swapaxes(1, 2), lf.swapaxes(1, 2)
    L = min(chunk, s)
    n_chunks = (s + L - 1) // L
    pad = n_chunks * L - s
    if pad:
        q, k, v = (jnp.pad(t, ((0, 0), (0, 0), (0, pad), (0, 0)))
                   for t in (q, k, v))
        li = jnp.pad(li, ((0, 0), (0, 0), (0, pad)))
        lf = jnp.pad(lf, ((0, 0), (0, 0), (0, pad)))

    def step(state, args):
        qc, kc, vc, lic, lfc = args
        h, state = _mlstm_chunk(qc, kc, vc, lic, lfc, state,
                                matmul_dtype=matmul_dtype)
        return state, h

    chunked = lambda t: t.reshape(b, Hp, n_chunks, L, *t.shape[3:]).swapaxes(0, 2).swapaxes(1, 2)
    # -> (n_chunks, b, H, L, ...)
    state0 = (jnp.zeros((b, Hp, hd, hd), jnp.float32),
              jnp.zeros((b, Hp, hd), jnp.float32),
              jnp.full((b, Hp), -1e30, jnp.float32))
    _, hs = jax.lax.scan(step, state0,
                         (chunked(q), chunked(k), chunked(v),
                          chunked(li), chunked(lf)))
    h = hs.swapaxes(0, 1).swapaxes(1, 2).reshape(b, Hp, n_chunks * L, hd)
    h = h[:, :, :s].swapaxes(1, 2)                           # (b,s,H,hd)
    h = h * jax.lax.rsqrt(jnp.mean(h * h, -1, keepdims=True) + 1e-6)
    h = (h * p["out_norm"]["scale"][None, None].astype(jnp.float32)).astype(x.dtype)
    h = h * jax.nn.silu(z)
    h = lshard(h, "batch", None, None, "mlstm_v")
    # 3-D contraction keeps the value-dim sharding local until the psum
    return jnp.einsum("bsnd,nde->bse", h, p["wo"]["w"].astype(h.dtype))


def init_mlstm_cache(batch: int, cfg: ModelConfig, tp: int) -> dict:
    Hp, d_in, hd = mlstm_dims(cfg, tp)
    d_conv = cfg.xlstm.d_conv
    return {
        "C": jnp.zeros((batch, Hp, hd, hd), jnp.float32),
        "n": jnp.zeros((batch, Hp, hd), jnp.float32),
        "m": jnp.full((batch, Hp), -1e30, jnp.float32),
        "conv": jnp.zeros((batch, d_conv - 1, Hp, hd), jnp.float32),
    }


def mlstm_cache_specs() -> dict:
    return {"C": ("batch", None, None, "mlstm_v"),
            "n": ("batch", None, None),
            "m": ("batch", None),
            "conv": ("batch", None, None, "mlstm_v")}


def mlstm_decode(p, x: jnp.ndarray, cache: dict, cfg: ModelConfig, tp: int
                 ) -> Tuple[jnp.ndarray, dict]:
    """Single-token recurrent mLSTM step. x (b,1,d)."""
    Hp, d_in, hd = mlstm_dims(cfg, tp)
    xi = nn.linear(p["wx"], x)[:, 0]                         # (b,Hp,hd)
    z = nn.linear(p["wz"], x)[:, 0]
    hist = jnp.concatenate([cache["conv"].astype(x.dtype), xi[:, None]], axis=1)
    xc = jnp.einsum("bjhd,jhd->bhd", hist, p["conv_w"].astype(x.dtype))
    xc = jax.nn.silu(xc + p["conv_b"][None].astype(x.dtype))
    q = nn.linear(p["wq"], xc).astype(jnp.float32)
    k = nn.linear(p["wk"], xc).astype(jnp.float32) / math.sqrt(hd)
    v = nn.linear(p["wv"], xi).astype(jnp.float32)
    gates = nn.linear(p["w_if"], x)[:, 0].astype(jnp.float32)
    li = gates[..., 0]
    lf = jax.nn.log_sigmoid(gates[..., 1])
    C0, n0, m0 = cache["C"], cache["n"], cache["m"]
    m = jnp.maximum(lf + m0, li)
    fg = jnp.exp(lf + m0 - m)[..., None]
    ig = jnp.exp(li - m)[..., None]
    C = C0 * fg[..., None] + ig[..., None] * k[..., :, None] * v[..., None, :]
    n = n0 * fg + ig * k
    num = jnp.einsum("bhd,bhde->bhe", q, C)
    qn = jnp.abs(jnp.einsum("bhd,bhd->bh", q, n))
    h = num / jnp.maximum(qn, jnp.exp(-m))[..., None]
    h = h * jax.lax.rsqrt(jnp.mean(h * h, -1, keepdims=True) + 1e-6)
    h = (h * p["out_norm"]["scale"][None].astype(jnp.float32)).astype(x.dtype)
    h = h * jax.nn.silu(z)
    out = jnp.einsum("bnd,nde->be", h, p["wo"]["w"].astype(h.dtype))[:, None]
    new_conv = hist[:, 1:].astype(jnp.float32)
    return out, {"C": C, "n": n, "m": m, "conv": new_conv}


# ----------------------------------------------------------------------------
# sLSTM block
# ----------------------------------------------------------------------------

def init_slstm(key, cfg: ModelConfig, tp: int, dtype):
    d = cfg.d_model
    Hp = ((cfg.n_heads + tp - 1) // tp) * tp if cfg.n_heads % tp else cfg.n_heads
    hd = d // cfg.n_heads
    ks = jax.random.split(key, 4)
    real = (jnp.arange(Hp) < cfg.n_heads).astype(dtype)
    p = {
        # z,i,f,o input projections: (d, Hp, 4*hd)
        "wx": nn.init_linear(ks[0], d, (Hp, 4 * hd), bias=True, dtype=dtype),
        # per-head recurrent block-diagonal (Hp, hd, 4*hd)
        "r": (jax.random.normal(ks[1], (Hp, hd, 4 * hd), jnp.float32)
              / math.sqrt(hd)).astype(dtype),
        "out_norm": {"scale": jnp.ones((Hp, hd), dtype)},
        "wo": nn.init_linear(ks[2], Hp * hd, d, dtype=dtype),
    }
    b = p["wx"]["b"].reshape(Hp, 4, hd)
    p["wx"]["b"] = b.at[:, 2].set(3.0).reshape(Hp, 4 * hd)   # forget bias
    p["wx"]["w"] = p["wx"]["w"] * real[None, :, None]
    p["r"] = p["r"] * real[:, None, None]
    p["wo"]["w"] = p["wo"]["w"] * jnp.repeat(real, hd)[:, None]
    return p


def slstm_specs():
    return {
        "wx": {"w": ("embed", "heads", None), "b": ("heads", None)},
        "r": ("heads", None, None),
        "out_norm": {"scale": ("heads", None)},
        "wo": {"w": ("heads", "embed")},
    }


def _slstm_cell(p, xg: jnp.ndarray, state):
    """xg (b,Hp,4*hd) pre-activation input projections; one time step."""
    h0, c0, n0, m0 = state                                   # (b,Hp,hd)x3,(b,Hp,hd)
    Hp, hd = h0.shape[1], h0.shape[2]
    rec = jnp.einsum("bhd,hde->bhe", h0, p["r"].astype(h0.dtype))
    g = (xg + rec).astype(jnp.float32).reshape(-1, Hp, 4, hd)
    z = jnp.tanh(g[:, :, 0])
    li = g[:, :, 1]
    lf = jax.nn.log_sigmoid(g[:, :, 2])
    o = jax.nn.sigmoid(g[:, :, 3])
    m = jnp.maximum(lf + m0, li)
    ig = jnp.exp(li - m)
    fg = jnp.exp(lf + m0 - m)
    c = fg * c0 + ig * z
    n = fg * n0 + ig
    h = o * c / jnp.maximum(n, 1e-6)
    return (h.astype(h0.dtype), c, n, m)


def slstm_mix(p, x: jnp.ndarray, cfg: ModelConfig, tp: int) -> jnp.ndarray:
    """Sequential sLSTM over the sequence. x (b,s,d)."""
    b, s, d = x.shape
    Hp = p["r"].shape[0]
    hd = p["r"].shape[1]
    xg = nn.linear(p["wx"], x)                               # (b,s,Hp,4hd)
    state = (jnp.zeros((b, Hp, hd), x.dtype),
             jnp.zeros((b, Hp, hd), jnp.float32),
             jnp.zeros((b, Hp, hd), jnp.float32),
             jnp.zeros((b, Hp, hd), jnp.float32))

    def step(st, xt):
        st = _slstm_cell(p, xt, st)
        return st, st[0]

    _, hs = jax.lax.scan(step, state, xg.swapaxes(0, 1))
    h = hs.swapaxes(0, 1).astype(jnp.float32)                # (b,s,Hp,hd)
    h = h * jax.lax.rsqrt(jnp.mean(h * h, -1, keepdims=True) + 1e-6)
    h = (h * p["out_norm"]["scale"][None, None].astype(jnp.float32)).astype(x.dtype)
    return nn.linear(p["wo"], h.reshape(b, s, Hp * hd))


def init_slstm_cache(batch: int, cfg: ModelConfig, tp: int) -> dict:
    Hp = ((cfg.n_heads + tp - 1) // tp) * tp if cfg.n_heads % tp else cfg.n_heads
    hd = cfg.d_model // cfg.n_heads
    z = lambda: jnp.zeros((batch, Hp, hd), jnp.float32)
    return {"h": z(), "c": z(), "n": z(), "m": z()}


def slstm_cache_specs() -> dict:
    names = ("batch", "heads", None)
    return {"h": names, "c": names, "n": names, "m": names}


def slstm_decode(p, x: jnp.ndarray, cache: dict, cfg: ModelConfig, tp: int
                 ) -> Tuple[jnp.ndarray, dict]:
    b = x.shape[0]
    Hp, hd = p["r"].shape[0], p["r"].shape[1]
    xg = nn.linear(p["wx"], x)[:, 0]                         # (b,Hp,4hd)
    state = (cache["h"].astype(x.dtype), cache["c"], cache["n"], cache["m"])
    h, c, n, m = _slstm_cell(p, xg, state)
    hf = h.astype(jnp.float32)
    hf = hf * jax.lax.rsqrt(jnp.mean(hf * hf, -1, keepdims=True) + 1e-6)
    hf = (hf * p["out_norm"]["scale"][None].astype(jnp.float32)).astype(x.dtype)
    out = nn.linear(p["wo"], hf.reshape(b, Hp * hd))[:, None, :]
    return out, {"h": h.astype(jnp.float32), "c": c, "n": n, "m": m}
