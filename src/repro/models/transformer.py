"""Unified decoder LM covering all six assigned families.

An architecture is a repeating **pattern** of layer descriptors (mixer + ffn):

    dense / vlm        [attn+dense]                      x L
    moe (grok/dsv2)    [attn|mla + moe]                  x L
    hybrid (jamba)     [7x mamba, 1x attn; moe every 2]  x L/8
    ssm (xlstm)        [7x mlstm, 1x slstm]              x L/8
    audio (whisper)    [attn+cross+dense]                x L   (decoder)

Parameters for each pattern position are **stacked over repeats** and the
stack is driven by `jax.lax.scan`, keeping HLO size O(pattern) instead of
O(L) — essential for 64-72 layer configs to compile quickly and for remat
to apply uniformly. Caches mirror the same (repeat-stacked) structure.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models import modules as nn
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models import xlstm as xlstm_mod
from repro.sharding import lshard


@dataclass(frozen=True)
class LayerDesc:
    mixer: str                  # attn | mla | mamba | mlstm | slstm
    ffn: str                    # dense | moe | none
    d_ff: int = 0               # override (sLSTM post-FFN)
    cross: bool = False         # whisper decoder cross-attention


def build_pattern(cfg: ModelConfig) -> Tuple[Tuple[LayerDesc, ...], int]:
    """Returns (pattern, n_repeat) with len(pattern) * n_repeat == n_layers."""
    if cfg.family == "ssm" and cfg.xlstm is not None:
        k = cfg.xlstm.slstm_every
        assert cfg.n_layers % k == 0
        ds = []
        for i in range(k):
            if i == k - 1:
                # round the 4/3 projection up to a TP-shardable multiple
                d_ff = int(cfg.xlstm.slstm_proj_factor * cfg.d_model)
                d_ff = ((d_ff + 127) // 128) * 128
                ds.append(LayerDesc("slstm", "dense", d_ff=d_ff))
            else:
                ds.append(LayerDesc("mlstm", "none"))
        return tuple(ds), cfg.n_layers // k
    if cfg.family == "hybrid":
        k = cfg.attn_every
        assert cfg.n_layers % k == 0
        ds = []
        for i in range(k):
            mixer = "attn" if i == k - 1 else "mamba"
            ffn = "moe" if (cfg.moe is not None and i % cfg.moe_every == 0) \
                else "dense"
            ds.append(LayerDesc(mixer, ffn))
        return tuple(ds), cfg.n_layers // k
    mixer = "mla" if cfg.mla is not None else "attn"
    ffn = "moe" if cfg.moe is not None else "dense"
    cross = cfg.is_encdec
    return (LayerDesc(mixer, ffn, cross=cross),), cfg.n_layers


def _dtype(cfg: ModelConfig):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


class TransformerLM:
    """Functional LM: `init` -> params pytree, `specs` -> logical-axis tree,
    `forward` / `decode_step` / `init_cache`."""

    def __init__(self, cfg: ModelConfig, tp: int = 1, block_q: int = 512,
                 remat: bool = False):
        self.cfg = cfg
        self.tp = tp
        self.block_q = block_q
        self.remat = remat
        self.pattern, self.n_repeat = build_pattern(cfg)
        self.dims = attn.attn_dims(cfg, tp) if cfg.mla is None else None
        self.dtype = _dtype(cfg)
        # pad the vocab so the LM head shards over the model axis (padded
        # logits are masked to -inf; exactness preserved)
        self.vocab_padded = ((cfg.vocab_size + tp - 1) // tp) * tp

    # ------------------------------------------------------------------ init
    def _init_layer(self, key, desc: LayerDesc):
        cfg = self.cfg
        ks = jax.random.split(key, 6)
        p: Dict[str, Any] = {}
        bias = cfg.norm == "layernorm" and cfg.mlp_bias
        p["norm1"] = nn.init_norm(cfg.d_model, kind=cfg.norm,
                                  dtype=self.dtype, bias=bias)
        if desc.mixer == "attn":
            p["mix"] = attn.init_attention(ks[0], cfg, self.tp, self.dtype)
        elif desc.mixer == "mla":
            p["mix"] = attn.init_mla(ks[0], cfg, self.tp, self.dtype)
        elif desc.mixer == "mamba":
            p["mix"] = ssm_mod.init_mamba(ks[0], cfg, self.dtype)
        elif desc.mixer == "mlstm":
            p["mix"] = xlstm_mod.init_mlstm(ks[0], cfg, self.tp, self.dtype)
        elif desc.mixer == "slstm":
            p["mix"] = xlstm_mod.init_slstm(ks[0], cfg, self.tp, self.dtype)
        if desc.cross:
            p["norm_cross"] = nn.init_norm(cfg.d_model, kind=cfg.norm,
                                           dtype=self.dtype, bias=bias)
            p["cross"] = attn.init_attention(ks[1], cfg, self.tp, self.dtype)
        if desc.ffn != "none":
            p["norm2"] = nn.init_norm(cfg.d_model, kind=cfg.norm,
                                      dtype=self.dtype, bias=bias)
            if desc.ffn == "moe":
                p["ffn"] = moe_mod.init_moe(ks[2], cfg, self.dtype)
            else:
                d_ff = desc.d_ff or cfg.d_ff
                p["ffn"] = nn.init_mlp(ks[2], cfg.d_model, d_ff,
                                       gated=cfg.gated_mlp, bias=cfg.mlp_bias,
                                       dtype=self.dtype,
                                       quant=cfg.quant_int8)
        return p

    def _layer_specs(self, desc: LayerDesc):
        cfg = self.cfg
        bias = cfg.norm == "layernorm" and cfg.mlp_bias
        s: Dict[str, Any] = {"norm1": nn.norm_specs(cfg.norm, bias)}
        if desc.mixer == "attn":
            s["mix"] = attn.attention_specs(cfg)
        elif desc.mixer == "mla":
            s["mix"] = attn.mla_specs(cfg)
        elif desc.mixer == "mamba":
            s["mix"] = ssm_mod.mamba_specs(cfg)
        elif desc.mixer == "mlstm":
            s["mix"] = xlstm_mod.mlstm_specs()
        elif desc.mixer == "slstm":
            s["mix"] = xlstm_mod.slstm_specs()
        if desc.cross:
            s["norm_cross"] = nn.norm_specs(cfg.norm, bias)
            s["cross"] = attn.attention_specs(cfg)
        if desc.ffn != "none":
            s["norm2"] = nn.norm_specs(cfg.norm, bias)
            if desc.ffn == "moe":
                s["ffn"] = moe_mod.moe_specs(cfg)
            else:
                s["ffn"] = nn.mlp_specs(gated=cfg.gated_mlp,
                                        bias=cfg.mlp_bias,
                                        quant=cfg.quant_int8)
        return s

    def init(self, key) -> Dict[str, Any]:
        cfg = self.cfg
        k_emb, k_layers, k_head = jax.random.split(key, 3)
        params: Dict[str, Any] = {
            "embed": nn.init_embedding(k_emb, self.vocab_padded, cfg.d_model,
                                       self.dtype),
            "final_norm": nn.init_norm(cfg.d_model, kind=cfg.norm,
                                       dtype=self.dtype),
        }
        layer_keys = jax.random.split(k_layers, self.n_repeat)
        layers = {}
        for i, desc in enumerate(self.pattern):
            def one(k, d=desc):
                return self._init_layer(k, d)
            sub_keys = jax.vmap(lambda k: jax.random.fold_in(k, i))(layer_keys)
            layers[f"pos{i}"] = jax.vmap(one)(sub_keys)
        params["layers"] = layers
        if not cfg.tie_embeddings:
            params["lm_head"] = nn.init_linear(k_head, cfg.d_model,
                                               self.vocab_padded,
                                               dtype=self.dtype)
        return params

    def _mask_padded_logits(self, logits: jnp.ndarray) -> jnp.ndarray:
        if self.vocab_padded == self.cfg.vocab_size:
            return logits
        col = jnp.arange(self.vocab_padded)
        return jnp.where(col < self.cfg.vocab_size, logits,
                         jnp.asarray(-1e30, logits.dtype))

    def specs(self) -> Dict[str, Any]:
        cfg = self.cfg
        s: Dict[str, Any] = {
            "embed": nn.embedding_specs(),
            "final_norm": nn.norm_specs(cfg.norm),
        }
        layers = {}
        for i, desc in enumerate(self.pattern):
            ls = self._layer_specs(desc)
            layers[f"pos{i}"] = jax.tree.map(
                lambda t: (None,) + tuple(t), ls,
                is_leaf=lambda t: isinstance(t, tuple))
        s["layers"] = layers
        if not cfg.tie_embeddings:
            s["lm_head"] = {"w": ("embed", "vocab")}
        return s

    # --------------------------------------------------------------- forward
    def _apply_mixer(self, desc: LayerDesc, p, h, *, cos, sin, prefix_len,
                     encoder_out, window):
        cfg = self.cfg
        if desc.mixer == "attn":
            return attn.attention_forward(
                p["mix"], h, self.dims, cos=cos, sin=sin, causal=True,
                window=window, prefix_len=prefix_len, block_q=self.block_q)
        if desc.mixer == "mla":
            return attn.mla_forward(p["mix"], h, cfg,
                                    positions=jnp.arange(h.shape[1]),
                                    block_q=self.block_q)
        if desc.mixer == "mamba":
            return ssm_mod.mamba_mix(p["mix"], h, cfg)
        if desc.mixer == "mlstm":
            return xlstm_mod.mlstm_mix(p["mix"], h, cfg, self.tp)
        if desc.mixer == "slstm":
            return xlstm_mod.slstm_mix(p["mix"], h, cfg, self.tp)
        raise ValueError(desc.mixer)

    def _block(self, layer_params, x, *, cos, sin, prefix_len, encoder_out,
               window, train):
        cfg = self.cfg
        aux = jnp.zeros((), jnp.float32)
        for i, desc in enumerate(self.pattern):
            p = layer_params[f"pos{i}"]
            h = nn.apply_norm(p["norm1"], x, kind=cfg.norm, eps=cfg.norm_eps)
            # (measured in §Perf: explicit Megatron AG/RS boundary
            # constraints here EMIT MORE collectives than GSPMD's own
            # propagation from the residual constraint — refuted, reverted)
            h = self._apply_mixer(desc, p, h, cos=cos, sin=sin,
                                  prefix_len=prefix_len,
                                  encoder_out=encoder_out, window=window)
            x = lshard(x + h, "batch", "seq_sp", None)
            if desc.cross:
                hc = nn.apply_norm(p["norm_cross"], x, kind=cfg.norm,
                                   eps=cfg.norm_eps)
                kv_k = nn.linear(p["cross"]["wk"], encoder_out)
                kv_v = nn.linear(p["cross"]["wv"], encoder_out)
                hc = attn.attention_forward(
                    p["cross"], hc, self.dims, cos=None, sin=None,
                    causal=False, kv_override=(kv_k, kv_v),
                    block_q=self.block_q)
                x = lshard(x + hc, "batch", "seq_sp", None)
            if desc.ffn != "none":
                h = nn.apply_norm(p["norm2"], x, kind=cfg.norm,
                                  eps=cfg.norm_eps)
                if desc.ffn == "moe":
                    h, a = moe_mod.moe_apply(p["ffn"], h, cfg)
                    aux = aux + a
                else:
                    h = nn.mlp(p["ffn"], h, act=cfg.act)
                x = lshard(x + h, "batch", "seq_sp", None)
        return x, aux

    def forward(self, params, tokens: jnp.ndarray, *,
                prefix_embeds: Optional[jnp.ndarray] = None,
                encoder_out: Optional[jnp.ndarray] = None,
                window_override: Optional[int] = None,
                train: bool = False
                ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
        """tokens (b,s) -> (logits (b,s_total,V), hidden (b,s_total,d), aux).

        prefix_embeds (b,P,d): VLM patch embeddings (prefix-LM attention).
        encoder_out (b,Se,d): whisper encoder states for cross-attention.
        """
        cfg = self.cfg
        x = nn.embed(params["embed"], tokens, self.dtype)
        prefix_len = None
        if prefix_embeds is not None:
            x = jnp.concatenate([prefix_embeds.astype(self.dtype), x], axis=1)
            prefix_len = prefix_embeds.shape[1]
        s = x.shape[1]
        if cfg.is_encdec:  # whisper: sinusoidal absolute positions, no rope
            x = x + nn.sinusoidal_positions(s, cfg.d_model, self.dtype)[None]
            cos = sin = None
        else:
            hd = cfg.resolved_head_dim if cfg.mla is None else 0
            if cfg.mla is None:
                cos, sin = nn.rope_cos_sin(jnp.arange(s), hd, cfg.rope_theta)
            else:
                cos = sin = None
        x = lshard(x, "batch", "seq_sp", None)
        window = window_override
        if window is None:
            window = 0  # training/prefill default: full causal attention
        block = lambda lp, xx: self._block(
            lp, xx, cos=cos, sin=sin, prefix_len=prefix_len,
            encoder_out=encoder_out, window=window, train=train)
        if self.remat:
            block = jax.checkpoint(block,
                                   policy=jax.checkpoint_policies.nothing_saveable)

        def step(carry, layer_params):
            x, aux = carry
            x, a = block(layer_params, x)
            return (x, aux + a), None

        (x, aux), _ = jax.lax.scan(step, (x, jnp.zeros((), jnp.float32)),
                                   params["layers"])
        hidden = nn.apply_norm(params["final_norm"], x, kind=cfg.norm,
                               eps=cfg.norm_eps)
        if cfg.tie_embeddings:
            logits = nn.unembed(params["embed"], hidden)
        else:
            logits = nn.linear(params["lm_head"], hidden)
        logits = self._mask_padded_logits(logits)
        logits = lshard(logits, "batch", "seq_sp", "vocab")
        return logits, hidden, aux

    # ----------------------------------------------------------------- cache
    def effective_cache_len(self, seq_len: int) -> int:
        if self.cfg.long_context == "sliding_window":
            return min(seq_len, self.cfg.sliding_window)
        return seq_len

    def _layer_cache(self, desc: LayerDesc, batch: int, cache_len: int,
                     encoder_len: int, kv_quant=None):
        cfg = self.cfg
        c: Dict[str, Any] = {}
        if desc.mixer == "attn":
            c["kv"] = attn.init_kv_cache(batch, cache_len, self.dims,
                                         self.dtype, kv_quant=kv_quant)
        elif desc.mixer == "mla":
            c["kv"] = attn.init_mla_cache(batch, cache_len, cfg, self.dtype)
        elif desc.mixer == "mamba":
            c["state"] = ssm_mod.init_mamba_cache(batch, cfg, self.dtype)
        elif desc.mixer == "mlstm":
            c["state"] = xlstm_mod.init_mlstm_cache(batch, cfg, self.tp)
        elif desc.mixer == "slstm":
            c["state"] = xlstm_mod.init_slstm_cache(batch, cfg, self.tp)
        if desc.cross:
            d = self.dims
            c["cross_kv"] = {
                "k": jnp.zeros((batch, encoder_len, d.kv_padded, d.head_dim),
                               self.dtype),
                "v": jnp.zeros((batch, encoder_len, d.kv_padded, d.head_dim),
                               self.dtype),
            }
        return c

    def _layer_cache_specs(self, desc: LayerDesc, kv_quant=None):
        c: Dict[str, Any] = {}
        if desc.mixer == "attn":
            c["kv"] = attn.kv_cache_specs(kv_quant)
        elif desc.mixer == "mla":
            c["kv"] = attn.mla_cache_specs()
        elif desc.mixer == "mamba":
            c["state"] = ssm_mod.mamba_cache_specs()
        elif desc.mixer == "mlstm":
            c["state"] = xlstm_mod.mlstm_cache_specs()
        elif desc.mixer == "slstm":
            c["state"] = xlstm_mod.slstm_cache_specs()
        if desc.cross:
            c["cross_kv"] = {"k": ("batch", None, "kv_heads", None),
                             "v": ("batch", None, "kv_heads", None)}
        return c

    def init_cache(self, batch: int, seq_len: int, encoder_len: int = 0,
                   kv_quant=None):
        cache_len = self.effective_cache_len(seq_len)
        out = {}
        for i, desc in enumerate(self.pattern):
            piece = self._layer_cache(desc, batch, cache_len, encoder_len,
                                      kv_quant=kv_quant)
            out[f"pos{i}"] = jax.tree.map(
                lambda a: jnp.broadcast_to(a, (self.n_repeat,) + a.shape),
                piece)
        return out

    def cache_specs(self, kv_quant=None):
        out = {}
        for i, desc in enumerate(self.pattern):
            cs = self._layer_cache_specs(desc, kv_quant=kv_quant)
            out[f"pos{i}"] = jax.tree.map(
                lambda t: (None,) + tuple(t), cs,
                is_leaf=lambda t: isinstance(t, tuple))
        return out

    # ---------------------------------------------------------------- decode
    def decode_step(self, params, token: jnp.ndarray, cache, pos: jnp.ndarray,
                    block_tables: Optional[jnp.ndarray] = None
                    ) -> Tuple[jnp.ndarray, jnp.ndarray, Any]:
        """token (b,1); pos (b,) absolute positions. Returns
        (logits (b,1,V), hidden (b,1,d), new_cache).

        block_tables (b, T): paged-KV mode — sequence-cache leaves (attn
        KV, MLA latents) are physical block stores (n_blocks, B, ...)
        indexed through the tables; recurrent-state leaves stay per-row.
        All layers share one table (a physical block spans every layer's
        KV for its token range). Incompatible with sliding-window configs.
        """
        cfg = self.cfg
        x = nn.embed(params["embed"], token, self.dtype)
        if cfg.is_encdec:
            # per-token sinusoidal position (computed directly)
            x = x + _sinusoid_at(pos, cfg.d_model, self.dtype)[:, None, :]
        window = (cfg.sliding_window
                  if cfg.long_context == "sliding_window" else 0)
        if block_tables is not None:
            # Paged mode never wraps: the serving runtime only selects it
            # when max_len <= sliding_window, where the ring is degenerate
            # (slot == pos) and full-causal validity is exact.
            window = 0

        def block(carry, xs):
            x = carry
            layer_params, layer_cache = xs
            new_cache = {}
            for i, desc in enumerate(self.pattern):
                p = layer_params[f"pos{i}"]
                c = layer_cache[f"pos{i}"]
                nc: Dict[str, Any] = {}
                h = nn.apply_norm(p["norm1"], x, kind=cfg.norm,
                                  eps=cfg.norm_eps)
                if desc.mixer == "attn":
                    h, kv = attn.attention_decode(
                        p["mix"], h, c["kv"], pos, self.dims,
                        rope_theta=0.0 if cfg.is_encdec else cfg.rope_theta,
                        window=window, block_tables=block_tables)
                    nc["kv"] = kv
                elif desc.mixer == "mla":
                    h, kv = attn.mla_decode(p["mix"], h, c["kv"], pos, cfg,
                                            block_tables=block_tables)
                    nc["kv"] = kv
                elif desc.mixer == "mamba":
                    h, st = ssm_mod.mamba_decode(p["mix"], h, c["state"], cfg)
                    nc["state"] = st
                elif desc.mixer == "mlstm":
                    h, st = xlstm_mod.mlstm_decode(p["mix"], h, c["state"],
                                                   cfg, self.tp)
                    nc["state"] = st
                elif desc.mixer == "slstm":
                    h, st = xlstm_mod.slstm_decode(p["mix"], h, c["state"],
                                                   cfg, self.tp)
                    nc["state"] = st
                x = x + h
                if desc.cross:
                    hc = nn.apply_norm(p["norm_cross"], x, kind=cfg.norm,
                                       eps=cfg.norm_eps)
                    hc = _cross_decode(p["cross"], hc, c["cross_kv"],
                                       self.dims)
                    nc["cross_kv"] = c["cross_kv"]
                    x = x + hc
                if desc.ffn != "none":
                    h = nn.apply_norm(p["norm2"], x, kind=cfg.norm,
                                      eps=cfg.norm_eps)
                    if desc.ffn == "moe":
                        h, _ = moe_mod.moe_apply(p["ffn"], h, cfg)
                    else:
                        h = nn.mlp(p["ffn"], h, act=cfg.act)
                    x = x + h
                new_cache[f"pos{i}"] = nc
            return x, new_cache

        x, new_cache = jax.lax.scan(block, x, (params["layers"], cache))
        hidden = nn.apply_norm(params["final_norm"], x, kind=cfg.norm,
                               eps=cfg.norm_eps)
        if cfg.tie_embeddings:
            logits = nn.unembed(params["embed"], hidden)
        else:
            logits = nn.linear(params["lm_head"], hidden)
        logits = self._mask_padded_logits(logits)
        logits = lshard(logits, "batch", None, "vocab")
        return logits, hidden, new_cache

    def decode_horizon(self, params, token: jnp.ndarray, cache,
                       pos: jnp.ndarray, aux, H: int, transition,
                       block_tables: Optional[jnp.ndarray] = None,
                       xs=None):
        """Fuse `H` decode steps into one `jax.lax.scan` program.

        Each scan iteration runs exactly the per-token :meth:`decode_step`
        (same traced computation, so greedy tokens are bitwise identical
        to H separate tick dispatches) and then hands the fresh next-token
        logits AND hidden state to the caller-supplied ``transition``:

            transition(logits (b,V), hidden (b,d), token (b,), pos (b,),
                       aux, x)
                -> (next_token, next_pos, next_aux, emit)

        The serving runtime's transition samples on device, freezes
        finished sequences under a per-sequence mask (EOS / budget), and
        emits the (token, alive) pair the host reads back once per
        horizon. `aux` is an arbitrary pytree carried across steps (RNG
        keys, remaining-token counters); `block_tables` is scan-invariant,
        which is why the caller must pre-extend every live sequence's
        table to cover the whole horizon before dispatch.

        ``xs`` is an optional pytree of per-step scan inputs (leading
        axis H), delivered to ``transition`` as ``x`` (None when ``xs``
        is None). The serving runtime's *mixed* program threads a
        prefetched ``(H, b)`` fed-token buffer through it so prefill
        rows consume queued prompt tokens while decode rows feed back
        their samples — the per-row role mask lives in the transition,
        the model only threads cache and positions. ``hidden`` lets the
        transition capture a prefill row's probe state the step its last
        prompt token lands; callers that ignore it cost nothing (dead
        code under XLA). Returns ``(token, pos, cache, aux, emits)``
        with ``emits`` stacked over the H steps."""
        def step(carry, x):
            tok, p, cch, ax = carry
            logits, hidden, cch = self.decode_step(params, tok[:, None],
                                                   cch, p,
                                                   block_tables=block_tables)
            tok, p, ax, emit = transition(logits[:, 0], hidden[:, 0],
                                          tok, p, ax, x)
            return (tok, p, cch, ax), emit

        (token, pos, cache, aux), emits = jax.lax.scan(
            step, (token, pos, cache, aux), xs, length=H)
        return token, pos, cache, aux, emits

    def decode_chunk(self, params, tokens: jnp.ndarray, cache,
                     pos: jnp.ndarray, valid: jnp.ndarray,
                     block_tables: jnp.ndarray
                     ) -> Tuple[jnp.ndarray, jnp.ndarray, Any]:
        """Varlen chunked prefill: tokens (b, C) are up to C consecutive
        prompt tokens per sequence starting at absolute position pos (b,),
        of which valid (b,) are real. Returns (logits (b,C,V),
        hidden (b,C,d), new_cache) — position j's row is exactly what C
        single-token decode_steps would produce (all C K/V rows are
        scattered before attention, and each query masks `idx <= pos+j`),
        so chunking is a pure batching transform of the tick.

        Attention/MLA mixers only: recurrent-state families advance their
        state one token at a time, and the serving runtime keeps them on
        the per-token interleave (prefill_chunk=1). Paged caches only.
        """
        cfg = self.cfg
        assert not cfg.is_encdec, "chunked prefill: decoder-only stacks"
        x = nn.embed(params["embed"], tokens, self.dtype)

        def block(carry, xs):
            x = carry
            layer_params, layer_cache = xs
            new_cache = {}
            for i, desc in enumerate(self.pattern):
                p = layer_params[f"pos{i}"]
                c = layer_cache[f"pos{i}"]
                nc: Dict[str, Any] = {}
                h = nn.apply_norm(p["norm1"], x, kind=cfg.norm,
                                  eps=cfg.norm_eps)
                if desc.mixer == "attn":
                    h, kv = attn.attention_decode_chunk(
                        p["mix"], h, c["kv"], pos, valid, self.dims,
                        rope_theta=cfg.rope_theta,
                        block_tables=block_tables)
                    nc["kv"] = kv
                elif desc.mixer == "mla":
                    h, kv = attn.mla_decode(p["mix"], h, c["kv"], pos, cfg,
                                            block_tables=block_tables,
                                            valid=valid)
                    nc["kv"] = kv
                else:
                    raise NotImplementedError(
                        "chunked prefill does not support mixer "
                        f"'{desc.mixer}' (recurrent state advances "
                        "per-token; the runtime gates on this)")
                x = x + h
                if desc.ffn != "none":
                    h = nn.apply_norm(p["norm2"], x, kind=cfg.norm,
                                      eps=cfg.norm_eps)
                    if desc.ffn == "moe":
                        h, _ = moe_mod.moe_apply(p["ffn"], h, cfg)
                    else:
                        h = nn.mlp(p["ffn"], h, act=cfg.act)
                    x = x + h
                new_cache[f"pos{i}"] = nc
            return x, new_cache

        x, new_cache = jax.lax.scan(block, x, (params["layers"], cache))
        hidden = nn.apply_norm(params["final_norm"], x, kind=cfg.norm,
                               eps=cfg.norm_eps)
        if cfg.tie_embeddings:
            logits = nn.unembed(params["embed"], hidden)
        else:
            logits = nn.linear(params["lm_head"], hidden)
        logits = self._mask_padded_logits(logits)
        logits = lshard(logits, "batch", None, "vocab")
        return logits, hidden, new_cache

    # ---------------------------------------------------------------- prefill
    def prefill(self, params, tokens: jnp.ndarray, *,
                encoder_out: Optional[jnp.ndarray] = None,
                prefix_embeds: Optional[jnp.ndarray] = None):
        """Forward pass that also builds the decode cache.

        Implemented (for the serving engine on small models) by running
        `forward` and re-deriving per-layer kv/state via a second annotated
        pass; for large-scale serving the dry-run lowers `decode_step` with a
        pre-filled cache ShapeDtypeStruct, so prefill cost is the `forward`
        cost. Returns (logits, hidden, cache).
        """
        raise NotImplementedError("use serving.engine.prefill")


def _sinusoid_at(pos: jnp.ndarray, d: int, dtype) -> jnp.ndarray:
    half = d // 2
    freq = jnp.exp(-math.log(10000.0)
                   * jnp.arange(half, dtype=jnp.float32) / max(half - 1, 1))
    ang = pos.astype(jnp.float32)[:, None] * freq[None, :]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1).astype(dtype)


def _cross_decode(p, x: jnp.ndarray, cross_kv, dims) -> jnp.ndarray:
    """Single-token cross-attention against precomputed encoder K/V."""
    b = x.shape[0]
    q = nn.linear(p["wq"], x)                                # (b,1,Hp,hd)
    k, v = cross_kv["k"], cross_kv["v"]                      # (b,Se,KVp,hd)
    g = dims.group
    qg = q.reshape(b, 1, dims.kv_padded, g, dims.head_dim)
    scores = jnp.einsum("bqkgd,bskd->bkgqs", qg, k.astype(q.dtype),
                        preferred_element_type=jnp.float32)
    scores = scores / math.sqrt(dims.head_dim)
    w = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    o = jnp.einsum("bkgqs,bskd->bqkgd", w, v.astype(x.dtype))
    o = o.reshape(b, 1, dims.heads_padded * dims.head_dim)
    return nn.linear(p["wo"], o)
