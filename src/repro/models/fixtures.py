"""Canonical tiny weak/strong model pair for tests and benchmarks.

Every routing/traffic fixture needs two registry models with a *nonzero*
greedy reward gap, and there is exactly one gotcha in building them from
random init: at init scale, tied-embedding logit dominance makes every
random tiny model greedily echo its last prompt token — two such models
produce identical rows and the weak/strong gap collapses to zero (a
routing test passes vacuously). The fix, shipped with the procedure API,
is scaling one side's params away from init scale (×3 by default).

That fixture used to live copy-pasted in ``tests/test_procedure.py`` and
``benchmarks/bench_serving.py``; this module is the single source, so a
future routing test cannot silently reintroduce a zero gap by rebuilding
the pair from raw init. Imports are lazy: pulling in the fixture helper
must not drag jax into collection-time paths that do not use it.
"""
from __future__ import annotations

import dataclasses


def tiny_lm(arch: str = "qwen2-0.5b", *, n_layers: int = 2, seed: int = 0,
            dtype: str = "float32"):
    """Reduced tiny LM at init scale: (cfg, model, params)."""
    import jax

    from repro.configs import get_config
    from repro.models import build_model

    cfg = dataclasses.replace(get_config(arch).reduced(), dtype=dtype,
                              n_layers=n_layers)
    model = build_model(cfg)
    return cfg, model, model.init(jax.random.PRNGKey(seed))


def scaled_strong_lm(arch: str = "qwen2-0.5b", *, n_layers: int = 1,
                     seed: int = 99, scale: float = 3.0,
                     dtype: str = "float32"):
    """The 'strong' half of a routing pair: (cfg, model, params) with
    params scaled ×``scale`` off init — breaks the tied-embedding
    greedy-echo degeneracy so the weak/strong reward gap is nonzero.
    The roles are symbolic; what matters is distinct weights and a
    distinct KV store on the shared pool."""
    import jax

    from repro.configs import get_config
    from repro.models import build_model

    cfg = dataclasses.replace(get_config(arch).reduced(), dtype=dtype,
                              n_layers=n_layers)
    model = build_model(cfg)
    params = jax.tree.map(lambda x: x * scale,
                          model.init(jax.random.PRNGKey(seed)))
    return cfg, model, params


def weak_strong_pair(arch: str = "qwen2-0.5b", *, weak_seed: int = 0,
                     strong_seed: int = 99, scale: float = 3.0,
                     dtype: str = "float32"):
    """Both halves at once: ((cfg_w, model_w, params_w),
    (cfg_s, model_s, params_s))."""
    return (tiny_lm(arch, seed=weak_seed, dtype=dtype),
            scaled_strong_lm(arch, seed=strong_seed, scale=scale,
                             dtype=dtype))
