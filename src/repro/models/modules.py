"""Core parameterized building blocks (pytree params + parallel logical-spec
trees). flax is unavailable offline, so this is a from-scratch functional
module system:

    params = init_linear(key, d_in, d_out)          # dict of arrays
    specs  = linear_specs(("embed",), ("mlp",))     # same-shape dict of
                                                    # logical-axis tuples
    y      = linear(params, x)

Spec trees mirror param trees exactly; ``repro.sharding.logical_spec``
resolves them against a mesh at launch time.
"""
from __future__ import annotations

import math
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp


def truncnorm_init(key, shape, scale: float, dtype) -> jax.Array:
    stddev = scale / max(1.0, math.sqrt(shape[0] if len(shape) > 1 else 1))
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
            * stddev).astype(dtype)


def init_linear(key, d_in: int, d_out, *, bias: bool = False,
                dtype=jnp.float32, scale: float = 1.0, zero: bool = False,
                quant: bool = False):
    """d_out may be an int or a tuple (e.g. (heads, head_dim)).

    quant=True stores the weight as int8 + per-output-channel fp scales
    (W8A16 serving quantization — §Perf: halves the weight-read bandwidth
    that dominates decode)."""
    out_shape = (d_out,) if isinstance(d_out, int) else tuple(d_out)
    shape = (d_in,) + out_shape
    if zero:
        w = jnp.zeros(shape, dtype)
    else:
        w = truncnorm_init(key, shape, scale, dtype)
    if quant:
        amax = jnp.max(jnp.abs(w.astype(jnp.float32)), axis=0) + 1e-8
        p = {"w_q8": jnp.clip(jnp.round(w.astype(jnp.float32) / amax * 127),
                              -127, 127).astype(jnp.int8),
             "w_scale": (amax / 127).astype(dtype)}
    else:
        p = {"w": w}
    if bias:
        p["b"] = jnp.zeros(out_shape, dtype)
    return p


def linear_specs(in_names: Sequence, out_names: Sequence, *,
                 bias: bool = False, quant: bool = False):
    if quant:
        s = {"w_q8": tuple(in_names) + tuple(out_names),
             "w_scale": tuple(out_names)}
    else:
        s = {"w": tuple(in_names) + tuple(out_names)}
    if bias:
        s["b"] = tuple(out_names)
    return s


def linear(p, x: jax.Array, *, out_ndim: Optional[int] = None) -> jax.Array:
    """Contract the last dim of x with the first dim of w."""
    if "w_q8" in p:
        w = p["w_q8"].astype(x.dtype) * p["w_scale"].astype(x.dtype)
    else:
        w = p["w"].astype(x.dtype)
    y = jax.lax.dot_general(
        x, w,
        dimension_numbers=(((x.ndim - 1,), (0,)), ((), ())))
    if "b" in p:
        y = y + p["b"].astype(y.dtype)
    return y


def init_norm(d: int, *, kind: str = "rmsnorm", dtype=jnp.float32,
              bias: bool = False):
    p = {"scale": jnp.ones((d,), dtype)}
    if kind == "layernorm" and bias:
        p["bias"] = jnp.zeros((d,), dtype)
    return p


def norm_specs(kind: str = "rmsnorm", bias: bool = False):
    s = {"scale": ("embed",)}
    if kind == "layernorm" and bias:
        s["bias"] = ("embed",)
    return s


def apply_norm(p, x: jax.Array, *, kind: str = "rmsnorm",
               eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    if kind == "rmsnorm":
        xf = xf * jax.lax.rsqrt(jnp.mean(xf * xf, -1, keepdims=True) + eps)
    else:
        mu = jnp.mean(xf, -1, keepdims=True)
        xf = xf - mu
        xf = xf * jax.lax.rsqrt(jnp.mean(xf * xf, -1, keepdims=True) + eps)
    y = xf * p["scale"].astype(jnp.float32)
    if "bias" in p:
        y = y + p["bias"].astype(jnp.float32)
    return y.astype(x.dtype)


def init_embedding(key, vocab: int, d: int, dtype=jnp.float32):
    return {"table": truncnorm_init(key, (vocab, d), math.sqrt(d), dtype)}


def embedding_specs():
    return {"table": ("vocab", "embed")}


def embed(p, tokens: jax.Array, dtype=None) -> jax.Array:
    t = p["table"]
    if dtype is not None:
        t = t.astype(dtype)
    return jnp.take(t, tokens, axis=0)


def unembed(p, x: jax.Array) -> jax.Array:
    """Tied LM head: x @ table^T."""
    return jax.lax.dot_general(
        x, p["table"].astype(x.dtype),
        dimension_numbers=(((x.ndim - 1,), (1,)), ((), ())))


def activation(name: str):
    if name == "silu":
        return jax.nn.silu
    if name == "gelu":
        return jax.nn.gelu
    if name == "relu":
        return jax.nn.relu
    raise ValueError(name)


# ----------------------------------------------------------------------------
# Rotary position embeddings (GPT-NeoX half-rotation convention)
# ----------------------------------------------------------------------------

def rope_cos_sin(positions: jax.Array, head_dim: int, theta: float,
                 dtype=jnp.float32) -> Tuple[jax.Array, jax.Array]:
    """positions (...,) -> cos/sin (..., head_dim//2)."""
    half = head_dim // 2
    freq = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * freq
    return jnp.cos(ang).astype(dtype), jnp.sin(ang).astype(dtype)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x (..., seq, heads, head_dim); cos/sin (..., seq, head_dim//2)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    c = cos[..., None, :].astype(x.dtype)   # broadcast over heads
    s = sin[..., None, :].astype(x.dtype)
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1)


def sinusoidal_positions(seq_len: int, d: int, dtype=jnp.float32) -> jax.Array:
    """Whisper-style sinusoidal absolute position table (seq_len, d)."""
    half = d // 2
    freq = jnp.exp(-math.log(10000.0) * jnp.arange(half, dtype=jnp.float32)
                   / max(half - 1, 1))
    ang = jnp.arange(seq_len, dtype=jnp.float32)[:, None] * freq[None, :]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=1).astype(dtype)


# ----------------------------------------------------------------------------
# Gated / plain MLP
# ----------------------------------------------------------------------------

def init_mlp(key, d: int, d_ff: int, *, gated: bool, bias: bool = False,
             dtype=jnp.float32, quant: bool = False):
    ks = jax.random.split(key, 3)
    p = {"up": init_linear(ks[0], d, d_ff, bias=bias, dtype=dtype,
                           quant=quant),
         "down": init_linear(ks[1], d_ff, d, bias=bias, dtype=dtype,
                             quant=quant)}
    if gated:
        p["gate"] = init_linear(ks[2], d, d_ff, bias=bias, dtype=dtype,
                                quant=quant)
    return p


def mlp_specs(*, gated: bool, bias: bool = False, ff_name: str = "mlp",
              quant: bool = False):
    s = {"up": linear_specs(("embed",), (ff_name,), bias=bias, quant=quant),
         "down": linear_specs((ff_name,), ("embed",), bias=bias,
                              quant=quant)}
    if gated:
        s["gate"] = linear_specs(("embed",), (ff_name,), bias=bias,
                                 quant=quant)
    return s


def mlp(p, x: jax.Array, *, act: str = "silu") -> jax.Array:
    fn = activation(act)
    h = linear(p["up"], x)
    if "gate" in p:
        h = h * fn(linear(p["gate"], x))
    else:
        h = fn(h)
    return linear(p["down"], h)


# ----------------------------------------------------------------------------
# LoRA adapters (paper §3.1 difficulty-model variant)
# ----------------------------------------------------------------------------

def init_lora(key, d_in: int, d_out: int, rank: int, dtype=jnp.float32):
    k1, k2 = jax.random.split(key)
    return {"a": truncnorm_init(k1, (d_in, rank), 1.0, dtype),
            "b": jnp.zeros((rank, d_out), dtype)}


def lora_specs():
    return {"a": ("embed", None), "b": (None, "embed")}


def lora_delta(p, x: jax.Array, scale: float = 1.0) -> jax.Array:
    return (x @ p["a"].astype(x.dtype)) @ p["b"].astype(x.dtype) * scale
